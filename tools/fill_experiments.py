#!/usr/bin/env python3
"""Inline generated reports into EXPERIMENTS.md placeholders.

Each `<!-- TAG -->` marker is replaced by the body of the corresponding
reports/<id>.md (minus its own H1 title). Idempotent: reruns refresh the
blocks. Missing reports leave a note instead.
"""

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
MAP = {
    "TABLE3": "table3", "TABLE4": "table4", "TABLE5": "table5",
    "TABLE6": "table6", "TABLE7": "table7", "TABLE8": "table8",
    "FIG3": "fig3", "FIG4": "fig4", "FIG5": "fig5", "FIG6": "fig6",
    "NEIGHBORS": "neighbors", "CODES": "codes",
}


def body_of(report: Path) -> str:
    lines = report.read_text().splitlines()
    # drop the H1 title line and leading blanks
    while lines and (lines[0].startswith("# ") or not lines[0].strip()):
        lines.pop(0)
    return "\n".join(lines).strip()


def main():
    exp = ROOT / "EXPERIMENTS.md"
    text = exp.read_text()
    for tag, rid in MAP.items():
        report = ROOT / "reports" / f"{rid}.md"
        if report.exists():
            block = (f"<!-- {tag}:begin -->\n{body_of(report)}\n"
                     f"<!-- {tag}:end -->")
        else:
            block = (f"<!-- {tag}:begin -->\n*(report not generated on this "
                     f"machine yet -- run `repro experiment {rid}`)*\n"
                     f"<!-- {tag}:end -->")
        # replace either the bare placeholder or a previously filled block
        pat = re.compile(
            rf"<!-- {tag}:begin -->.*?<!-- {tag}:end -->|<!-- {tag} -->",
            re.S)
        if pat.search(text):
            text = pat.sub(lambda _: block, text, count=1)
        else:
            print(f"warning: no placeholder for {tag}", file=sys.stderr)
    exp.write_text(text)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
