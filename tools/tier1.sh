#!/usr/bin/env bash
# Tier-1 verification (see ROADMAP.md): release build, full test suite,
# and a compile of every bench target so bench code cannot bit-rot.
set -euo pipefail
cd "$(dirname "$0")/.."

cargo build --release
cargo test -q
cargo bench --no-run
echo "tier1: OK"
