#!/usr/bin/env bash
# Tier-1 verification (see ROADMAP.md): release build, full test suite,
# a compile of every bench target and every example so neither can
# bit-rot, a second pass over the server integration tests with a
# pinned 2-thread worker pool so the multi-table serving path is
# exercised off the default thread heuristic, a rustdoc build where a
# broken intra-doc link is an error, and a docs-coverage check that
# every file under docs/ is reachable from the README.
#
# Residency coverage: the spill-tier suites (residency_faults,
# residency_soak) run in both passes. In-memory-only mode (no
# --spill-dir) must behave exactly as PR 3 did -- that is pinned by the
# unmodified registry_lifecycle suite, which runs drop-mode eviction
# with no spill tier configured.
#
# Replica coverage: replica_equivalence (replicas=3 bit-identical to
# replicas=1, live set_replicas under traffic) and spill_recovery
# (restart over a populated spill dir) also run in BOTH thread passes --
# replica routing must be invisible in the bytes at every pool size.
#
# Adversarial-wire coverage: the committed crasher corpus replays via
# the fuzz_corpus suite, the hostile-client scenarios (slow-loris,
# byte-at-a-time, mid-frame disconnect, panic injection, busy cap) run
# via conn_hardening, and a 2000-iteration seeded fuzz of the live wire
# runs in BOTH thread passes -- zero panics, wedges, or unclean closes
# is a tier-1 gate, not a nightly aspiration. Both the fuzz and the
# hostile suites exercise the DEFAULT connection plane (event-driven,
# --pollers 2); conn_plane additionally pins the event-plane-specific
# claims (flat thread count under 1k idle + 64 hot conns, pipelined
# in-order responses, streamed == unstreamed results, event bytes ==
# threaded bytes) in BOTH thread passes.
#
# Compute-on-codes coverage: scoring_equivalence (ADC LUT vs
# reconstruct-then-score reference, topk determinism across threads /
# shards / replicas, spilled-table scoring) runs in BOTH thread passes --
# score bits must not depend on the pool size.
#
# Artifact-integrity coverage: artifact_integrity (one-byte flips in
# spill and snapshot artifacts answer typed errors off the recorded
# SHA-256 digests -- never silently wrong bytes -- snapshot dedupe by
# content digest, and a cold registry hydrated purely over the v2
# `fetch_artifact` op serving bit-identically) runs in BOTH thread
# passes -- digest verification must be invisible in the bytes at every
# pool size.
#
# Skew-aware-serving coverage: cache_equivalence (hot-row cache on vs a
# cache-disabled twin, bit-compared over a randomized op mix, plus
# deterministic LRU admission/eviction and budget-accounting checks) and
# backend_granular (MultiGranular + hashing backends through the full
# registry / spill / snapshot lifecycle) run in BOTH thread passes --
# the cache and the segment router must be invisible in the bytes at
# every pool size.
set -euo pipefail
cd "$(dirname "$0")/.."

cargo build --release
cargo test -q
cargo build --release --examples
target/release/repro fuzz --seed 42 --iters 2000
DPQ_THREADS=2 cargo test -q --test multi_table --test server_integration \
    --test registry_lifecycle --test residency_faults --test residency_soak \
    --test replica_equivalence --test spill_recovery \
    --test conn_hardening --test fuzz_corpus --test scoring_equivalence \
    --test cache_equivalence --test backend_granular --test conn_plane \
    --test artifact_integrity
DPQ_THREADS=2 target/release/repro fuzz --seed 42 --iters 2000
RUSTDOCFLAGS="-D rustdoc::broken-intra-doc-links" cargo doc --no-deps -q
for f in docs/*.md; do
    name="$(basename "$f")"
    if ! grep -q "$name" README.md; then
        echo "tier1: FAIL — $f is not referenced from README.md" >&2
        exit 1
    fi
done
cargo bench --no-run
# perf trail summary (informational: skipped when no bench has run yet,
# since the BENCH_*.json trail only accumulates on actual bench runs)
if ls BENCH_*.json >/dev/null 2>&1; then
    tools/perf_report.sh
fi
echo "tier1: OK"
