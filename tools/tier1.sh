#!/usr/bin/env bash
# Tier-1 verification (see ROADMAP.md): release build, full test suite,
# a compile of every bench target and every example so neither can
# bit-rot, and a second pass over the server integration tests with a
# pinned 2-thread worker pool so the multi-table serving path is
# exercised off the default thread heuristic.
set -euo pipefail
cd "$(dirname "$0")/.."

cargo build --release
cargo test -q
cargo build --release --examples
DPQ_THREADS=2 cargo test -q --test multi_table --test server_integration
cargo bench --no-run
echo "tier1: OK"
