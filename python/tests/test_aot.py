"""AOT builder tests: registry integrity, manifest consistency with traced
output shapes, and the state-roundtrip convention the Rust trainer relies
on (train outputs = metrics + state in input order)."""

import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot

aot.REGISTRY.clear()
aot.build_registry()
ARTS = {a.name: a for a in aot.REGISTRY}


class TestRegistry:
    def test_no_duplicate_names(self):
        names = [a.name for a in aot.REGISTRY]
        assert len(names) == len(set(names))

    def test_every_family_has_init_and_train(self):
        trains = {n[:-6] for n in ARTS if n.endswith("_train")}
        inits = {n[:-5] for n in ARTS if n.endswith("_init")}
        # every train has a matching init except bert ft (shares bert init)
        missing = {t for t in trains if t not in inits
                   and not t.endswith("_ft")}
        assert not missing, missing

    def test_kinds_are_known(self):
        assert {a.kind for a in aot.REGISTRY} <= {
            "init", "train", "eval", "decode", "export"}

    def test_train_outputs_are_metrics_then_state_in_input_order(self):
        for a in aot.REGISTRY:
            if a.kind != "train":
                continue
            state_in = [x.name for x in a.args if x.role == "state"]
            n_metrics = len([r for r in a.out_roles if r == "metric"])
            state_out = a.out_names[n_metrics:]
            assert state_out == state_in, a.name

    def test_train_inputs_end_with_lr(self):
        for a in aot.REGISTRY:
            if a.kind == "train":
                assert a.args[-1].name == "lr"
                assert a.args[-1].dtype == "f32"

    def test_meta_carries_cr_accounting(self):
        for a in aot.REGISTRY:
            if a.meta.get("variant") in ("sx", "vq"):
                assert a.meta["cr"] > 1.0, a.name


class TestLoweringRoundtrip:
    @pytest.mark.parametrize("name", [
        "lm_ptbsmall_full_train",
        "lm_ptbsmall_sx_K32D32_train",
        "lm_ptbsmall_vq_K32D32_train",
    ])
    def test_train_step_numerics_match_direct_eval(self, name):
        """Executing the lowered fn via jax.jit equals calling fn directly;
        and state threading converges (2 steps on a learnable mapping)."""
        a = ARTS[name]
        init = ARTS[name.replace("_train", "_init")]
        state = init.fn(jnp.asarray(0, jnp.int32))
        rng = np.random.RandomState(0)
        vocab = a.meta["vocab"]
        x = rng.randint(0, vocab, (a.meta["batch"], a.meta["seq"]))
        y = (x * 7 + 3) % vocab
        args = list(state) + [jnp.asarray(x, jnp.int32),
                              jnp.asarray(y, jnp.int32),
                              jnp.asarray(0.5, jnp.float32)]
        out1 = a.fn(*args)
        out2 = jax.jit(a.fn)(*args)
        np.testing.assert_allclose(out1[0], out2[0], rtol=1e-4, atol=1e-5)
        # threading: feed state back, loss finite
        state2 = out1[1:]
        args2 = list(state2) + args[len(state):]
        out3 = a.fn(*args2)
        assert np.isfinite(float(out3[0]))

    def test_export_matches_manifest_shapes(self):
        a = ARTS["lm_ptb_sx_K32D32_export"]
        sds = [x.sds() for x in a.args]
        outs = jax.eval_shape(a.fn, *sds)
        assert list(outs[0].shape) == [a.meta["vocab"], a.meta["D"]]
        assert list(outs[2].shape) == [a.meta["vocab"], a.meta["d"]]


class TestEmittedFiles:
    ART_DIR = Path(__file__).resolve().parents[2] / "artifacts"

    @pytest.mark.skipif(not (ART_DIR / "lm_ptb_full_train.manifest.json").exists(),
                        reason="artifacts not built")
    def test_manifest_matches_registry(self):
        man = json.loads(
            (self.ART_DIR / "lm_ptb_full_train.manifest.json").read_text())
        a = ARTS["lm_ptb_full_train"]
        assert [i["name"] for i in man["inputs"]] == [x.name for x in a.args]
        assert [o["name"] for o in man["outputs"]] == a.out_names
        assert man["meta"]["vocab"] == a.meta["vocab"]

    @pytest.mark.skipif(not (ART_DIR / "lm_ptb_full_train.hlo.txt").exists(),
                        reason="artifacts not built")
    def test_hlo_text_parses_as_hlo_module(self):
        txt = (self.ART_DIR / "lm_ptb_full_train.hlo.txt").read_text()
        assert txt.startswith("HloModule")
        assert "ENTRY" in txt
