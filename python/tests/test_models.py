"""L2 model tests: shapes, loss-decreases-on-learnable-data smoke runs for
each task family and embedding variant, and greedy-decode sanity."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import layers, optim
from compile.layers import EmbedCfg
from compile.models import bert_tiny, lstm_lm, nmt, textclass


def _sgd_steps(init, loss, batches, lr=0.5, clip=True):
    opt = optim.Sgd(clip=5.0 if clip else None)
    params = init
    losses = []

    @jax.jit
    def step(p, b):
        def lf(q):
            return loss(q, *b)[0]

        l, g = jax.value_and_grad(lf)(p)
        newp, _ = opt.apply(p, g, {}, lr)
        return l, newp

    for b in batches:
        l, params = step(params, b)
        losses.append(float(l))
    return losses, params


def _markov_batch(rng, vocab, B, T):
    """Deterministic successor structure: y = (x * 7 + 3) % vocab is
    perfectly learnable, so loss must fall quickly."""
    x = rng.randint(0, vocab, (B, T)).astype(np.int32)
    y = ((x * 7 + 3) % vocab).astype(np.int32)
    return jnp.asarray(x), jnp.asarray(y)


class TestLstmLm:
    @pytest.mark.parametrize("variant", ["full", "sx", "vq", "chen18"])
    def test_loss_decreases(self, variant):
        vocab, d, h = 64, 16, 32
        ecfg = EmbedCfg(variant=variant, vocab=vocab, d=d, K=4, D=4)
        cfg = lstm_lm.LmCfg(emb=ecfg, hidden=h, batch=8, seq=12)
        params = lstm_lm.init(jax.random.PRNGKey(0), cfg)
        rng = np.random.RandomState(0)
        batches = [_markov_batch(rng, vocab, 8, 12) for _ in range(120)]
        losses, _ = _sgd_steps(params,
                               lambda p, x, y: lstm_lm.loss_fn(p, x, y, cfg),
                               batches, lr=2.0)
        assert losses[-1] < losses[0] - 0.5, losses[::20]

    def test_loss_close_to_entropy_floor_on_random(self):
        vocab = 32
        ecfg = EmbedCfg(variant="full", vocab=vocab, d=8, K=4, D=4)
        cfg = lstm_lm.LmCfg(emb=ecfg, hidden=16, batch=4, seq=8)
        params = lstm_lm.init(jax.random.PRNGKey(0), cfg)
        x = jnp.zeros((4, 8), jnp.int32)
        y = jnp.zeros((4, 8), jnp.int32)
        total, ce = lstm_lm.loss_fn(params, x, y, cfg)
        assert 0 < float(ce) < 2 * np.log(vocab)


class TestTextClass:
    @pytest.mark.parametrize("variant", ["full", "sx", "vq", "lowrank"])
    def test_acc_improves(self, variant):
        vocab, classes = 128, 4
        ecfg = EmbedCfg(variant=variant, vocab=vocab, d=16, K=4, D=4, rank=4)
        cfg = textclass.TextCfg(emb=ecfg, hidden=16, classes=classes,
                                batch=16, seq=10)
        params = textclass.init(jax.random.PRNGKey(0), cfg)
        rng = np.random.RandomState(0)

        def batch():
            # class c draws tokens from slice [c*32, (c+1)*32)
            y = rng.randint(0, classes, (16,)).astype(np.int32)
            x = (rng.randint(0, 32, (16, 10)) + y[:, None] * 32).astype(np.int32)
            return jnp.asarray(x), jnp.asarray(y)

        batches = [batch() for _ in range(40)]
        opt = optim.Adam()
        state = opt.init_state(params)
        accs = []
        for x, y in batches:
            def lf(p):
                total, ce, acc = textclass.loss_fn(p, x, y, cfg)
                return total, acc
            (_, acc), g = jax.value_and_grad(lf, has_aux=True)(params)
            params, state = opt.apply(params, g, state, 3e-3)
            accs.append(float(acc))
        assert np.mean(accs[-5:]) > np.mean(accs[:5]) + 0.2, accs


class TestNmt:
    def test_teacher_forced_loss_decreases(self):
        vocab = 64
        ecfg = EmbedCfg(variant="sx", vocab=vocab, d=16, K=4, D=4)
        cfg = nmt.NmtCfg(emb=ecfg, tgt_vocab=vocab, hidden=24, batch=8,
                         src_len=6, tgt_len=8)
        params = nmt.init(jax.random.PRNGKey(0), cfg)
        rng = np.random.RandomState(0)
        opt = optim.Adam()
        state = opt.init_state(params)
        losses = []
        for _ in range(80):
            src = rng.randint(3, vocab, (8, 6)).astype(np.int32)
            # target = "translated" source: deterministic relabel + EOS
            t = (src * 5 + 1) % (vocab - 3) + 3
            tgt_in = np.concatenate([np.full((8, 1), nmt.BOS), t[:, :7]], 1)
            tgt_out = np.concatenate([t[:, :7], np.full((8, 1), nmt.EOS)], 1)
            b = (jnp.asarray(src), jnp.asarray(tgt_in.astype(np.int32)),
                 jnp.asarray(tgt_out.astype(np.int32)))

            def lf(p):
                total, ce = nmt.loss_fn(p, *b, cfg)
                return total, ce

            (_, ce), g = jax.value_and_grad(lf, has_aux=True)(params)
            params, state = opt.apply(params, g, state, 3e-3)
            losses.append(float(ce))
        assert losses[-1] < losses[0] - 0.5, losses[::10]

    def test_greedy_decode_shape_and_range(self):
        vocab = 32
        ecfg = EmbedCfg(variant="full", vocab=vocab, d=8, K=4, D=4)
        cfg = nmt.NmtCfg(emb=ecfg, tgt_vocab=vocab, hidden=16, batch=4,
                         src_len=5, tgt_len=7)
        params = nmt.init(jax.random.PRNGKey(0), cfg)
        src = jnp.asarray(np.random.RandomState(0).randint(3, vocab, (4, 5)),
                          jnp.int32)
        hyp = nmt.greedy_decode(params, src, cfg)
        assert hyp.shape == (4, 7)
        h = np.asarray(hyp)
        assert h.min() >= 0 and h.max() < vocab


class TestBert:
    def test_mlm_loss_decreases(self):
        vocab = 64
        ecfg = EmbedCfg(variant="sx", vocab=vocab, d=16, K=4, D=16)
        cfg = bert_tiny.BertCfg(emb=ecfg, layers_n=1, heads=2, ff=32,
                                batch=4, seq=12)
        params = bert_tiny.init(jax.random.PRNGKey(0), cfg)
        rng = np.random.RandomState(0)
        opt = optim.Adam()
        state = opt.init_state(params)
        losses = []
        MASK = 3
        for _ in range(30):
            y = rng.randint(4, vocab, (4, 12)).astype(np.int32)
            w = (rng.rand(4, 12) < 0.3).astype(np.int32)
            x = np.where(w == 1, MASK, y).astype(np.int32)
            b = (jnp.asarray(x), jnp.asarray(y), jnp.asarray(w))

            def lf(p):
                total, ce = bert_tiny.mlm_loss(p, *b, cfg)
                return total, ce

            (_, ce), g = jax.value_and_grad(lf, has_aux=True)(params)
            params, state = opt.apply(params, g, state, 1e-3)
            losses.append(float(ce))
        # random targets: floor is log(vocab); check it heads down
        assert losses[-1] < losses[0], losses[::6]

    def test_cls_outputs(self):
        vocab = 32
        ecfg = EmbedCfg(variant="full", vocab=vocab, d=16, K=4, D=4)
        cfg = bert_tiny.BertCfg(emb=ecfg, layers_n=1, heads=2, ff=32,
                                batch=4, seq=8, classes=3)
        params = bert_tiny.init(jax.random.PRNGKey(0), cfg)
        x = jnp.asarray(np.random.RandomState(0).randint(4, vocab, (4, 8)),
                        jnp.int32)
        y = jnp.asarray([0, 1, 2, 0], jnp.int32)
        total, ce, acc = bert_tiny.cls_loss(params, x, y, cfg)
        assert 0.0 <= float(acc) <= 1.0
        assert float(ce) > 0


class TestOptim:
    def test_clip_by_global_norm(self):
        g = {"a": jnp.full((3,), 100.0), "b": jnp.full((4,), -100.0)}
        c = optim.clip_by_global_norm(g, 1.0)
        total = float(jnp.sqrt(sum(jnp.sum(x * x) for x in c.values())))
        assert abs(total - 1.0) < 1e-4

    def test_clip_noop_when_small(self):
        g = {"a": jnp.asarray([0.1, 0.2])}
        c = optim.clip_by_global_norm(g, 5.0)
        np.testing.assert_allclose(c["a"], g["a"], rtol=1e-5)

    def test_adam_bias_correction_first_step(self):
        p = {"w": jnp.asarray([1.0])}
        opt = optim.Adam()
        st = opt.init_state(p)
        g = {"w": jnp.asarray([0.5])}
        newp, st = opt.apply(p, g, st, 0.1)
        # first Adam step moves by ~lr * sign(g)
        assert abs(float(newp["w"][0]) - (1.0 - 0.1)) < 1e-3
