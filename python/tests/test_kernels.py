"""Pallas kernels vs the pure-jnp oracle (ref.py) -- the core L1
correctness signal. hypothesis sweeps shapes / K / D / block sizes."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import dpq_sx, dpq_vq, pallas_util, reconstruct, ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def _mk(n, K, D, s, seed=0):
    rng = np.random.RandomState(seed)
    q3 = jnp.asarray(rng.randn(n, D, s), jnp.float32)
    key3 = jnp.asarray(rng.randn(K, D, s), jnp.float32)
    val3 = jnp.asarray(rng.randn(K, D, s), jnp.float32)
    return q3, key3, val3


shape_st = st.tuples(
    st.integers(1, 200),          # n (exercises padding: not block-aligned)
    st.sampled_from([2, 4, 16, 32]),   # K
    st.sampled_from([1, 2, 8]),   # D
    st.sampled_from([1, 2, 4]),   # s
)


class TestScores:
    @given(shape_st)
    def test_sx_scores_matches_ref(self, dims):
        n, K, D, s = dims
        q3, key3, _ = _mk(n, K, D, s)
        got = dpq_sx.sx_scores(q3, key3)
        want = ref.sx_scores_ref(q3, key3)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    @given(shape_st)
    def test_vq_scores_matches_ref(self, dims):
        n, K, D, s = dims
        q3, key3, _ = _mk(n, K, D, s)
        got = dpq_vq.vq_scores(q3, key3)
        want = ref.vq_scores_ref(q3, key3)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_vq_scores_are_negative_distances(self):
        q3, key3, _ = _mk(13, 4, 2, 3)
        scores = np.asarray(dpq_vq.vq_scores(q3, key3))
        assert (scores <= 1e-5).all()

    def test_identical_row_and_key_scores_zero_distance(self):
        # a query equal to centroid k must have distance 0 to it
        _, key3, _ = _mk(1, 8, 4, 2, seed=3)
        q3 = key3[5][None]                      # [1, D, s]
        scores = np.asarray(dpq_vq.vq_scores(q3, key3))
        np.testing.assert_allclose(scores[0, :, 5], 0.0, atol=1e-5)

    @pytest.mark.parametrize("block", [8, 32, 128])
    def test_block_size_invariance(self, block):
        q3, key3, _ = _mk(100, 16, 4, 4)
        a = dpq_sx.sx_scores(q3, key3, block_n=block)
        b = ref.sx_scores_ref(q3, key3)
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


class TestSelectGather:
    @given(shape_st)
    def test_select_gather_matches_ref(self, dims):
        n, K, D, s = dims
        q3, key3, val3 = _mk(n, K, D, s)
        scores = ref.sx_scores_ref(q3, key3)
        h, codes = reconstruct.select_gather(scores, val3)
        np.testing.assert_allclose(h, ref.select_gather_ref(scores, val3),
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_array_equal(codes, ref.codes_ref(scores))

    @given(shape_st)
    def test_gather_codes_matches_ref(self, dims):
        n, K, D, s = dims
        rng = np.random.RandomState(1)
        codes = jnp.asarray(rng.randint(0, K, (n, D)), jnp.int32)
        _, _, val3 = _mk(n, K, D, s)
        got = reconstruct.gather_codes(codes, val3)
        np.testing.assert_allclose(got, ref.gather_codes_ref(codes, val3),
                                   rtol=1e-6, atol=1e-6)

    def test_codes_within_range(self):
        q3, key3, val3 = _mk(77, 8, 4, 2)
        scores = ref.vq_scores_ref(q3, key3)
        _, codes = reconstruct.select_gather(scores, val3)
        codes = np.asarray(codes)
        assert codes.min() >= 0 and codes.max() < 8

    def test_roundtrip_codes_reconstruct(self):
        """select_gather output == gather_codes(select codes)."""
        q3, key3, val3 = _mk(40, 16, 8, 2)
        scores = ref.sx_scores_ref(q3, key3)
        h1, codes = reconstruct.select_gather(scores, val3)
        h2 = reconstruct.gather_codes(codes, val3)
        np.testing.assert_allclose(h1, h2, rtol=1e-6)


class TestDistBn:
    def test_bn_normalizes_over_batch(self):
        q3, key3, _ = _mk(256, 8, 4, 2)
        s = ref.dist_bn_ref(ref.sx_scores_ref(q3, key3))
        s = np.asarray(s)
        np.testing.assert_allclose(s.mean(axis=0), 0.0, atol=1e-4)
        np.testing.assert_allclose(s.std(axis=0), 1.0, atol=1e-2)

    def test_bn_preserves_argmax_monotonic_per_column(self):
        # BN is a per-(j,k) affine map over N with positive scale; it can
        # change the argmax across k. This just checks determinism/shape.
        q3, key3, _ = _mk(64, 8, 4, 2)
        s = ref.dist_bn_ref(ref.sx_scores_ref(q3, key3))
        assert s.shape == (64, 4, 8)


class TestPallasUtil:
    @given(st.integers(1, 300), st.sampled_from([8, 32, 128]))
    def test_pad_unpad_roundtrip(self, n, block):
        x = jnp.arange(n * 3, dtype=jnp.float32).reshape(n, 3)
        padded, orig = pallas_util.pad_rows(x, block)
        assert padded.shape[0] % block == 0
        np.testing.assert_array_equal(pallas_util.unpad_rows(padded, orig), x)

    def test_block_for_fits_budget(self):
        for (d, K, D) in [(64, 32, 16), (128, 128, 8), (256, 128, 128)]:
            b = pallas_util.block_for(d, K, D)
            resident = 2 * K * d * 4
            per_row = (2 * d + D * K) * 4
            assert resident + b * per_row <= pallas_util.VMEM_BUDGET * 1.01
            assert b >= 8
