"""L2 embedding-layer tests: forward semantics, gradient identities of the
two approximation schemes (Eq. 5, Eq. 7), CR accounting, and whole-vocab
code extraction / reconstruction consistency."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import layers
from compile.kernels import ref
from compile.layers import EmbedCfg


def _cfg(variant, **kw):
    base = dict(variant=variant, vocab=50, d=16, K=4, D=4)
    base.update(kw)
    return EmbedCfg(**base)


def _params(cfg, seed=0):
    return layers.init_params(jax.random.PRNGKey(seed), cfg)


IDS = jnp.asarray([[1, 2, 3], [4, 5, 1]], jnp.int32)


class TestForward:
    @pytest.mark.parametrize("variant", ["full", "sx", "vq", "lowrank",
                                         "chen18"])
    def test_shapes(self, variant):
        cfg = _cfg(variant)
        out, reg = layers.embed(_params(cfg), IDS, cfg)
        assert out.shape == (2, 3, 16)
        assert reg.shape == ()

    def test_full_is_plain_lookup(self):
        cfg = _cfg("full")
        ps = _params(cfg)
        out, _ = layers.embed(ps, IDS, cfg)
        np.testing.assert_allclose(out[0, 0], ps["emb/table"][1])

    @pytest.mark.parametrize("variant", ["sx", "vq"])
    def test_same_id_same_vector(self, variant):
        """Quantization is a per-row function: equal ids -> equal outputs
        (within a batch, where BN statistics are shared)."""
        cfg = _cfg(variant)
        out, _ = layers.embed(_params(cfg), IDS, cfg)
        np.testing.assert_allclose(out[0, 0], out[1, 2], rtol=1e-6)

    @pytest.mark.parametrize("variant", ["sx", "vq"])
    def test_forward_emits_hard_quantization(self, variant):
        """Without BN, the forward output must equal the oracle's hard
        quantization of the accessed query rows."""
        cfg = _cfg(variant, dist_bn=False)
        ps = _params(cfg)
        out, _ = layers.embed(ps, IDS, cfg)
        q_rows = ps["emb/q"][IDS.reshape(-1)]
        key = ps["emb/key"] if variant == "sx" else ps["emb/kv"]
        value = ps["emb/value"] if variant == "sx" else ps["emb/kv"]
        metric = "dot" if variant == "sx" else "l2"
        want, _ = ref.dpq_forward_hard_ref(q_rows, key, value, metric=metric)
        np.testing.assert_allclose(out.reshape(-1, 16), want, rtol=1e-5,
                                   atol=1e-6)

    def test_subspace_sharing_broadcasts(self):
        cfg = _cfg("sx", share=True)
        ps = _params(cfg)
        assert ps["emb/key"].shape == (4, 1, 4)
        out, _ = layers.embed(ps, IDS, cfg)
        assert out.shape == (2, 3, 16)

    def test_vq_reg_positive(self):
        cfg = _cfg("vq")
        _, reg = layers.embed(_params(cfg), IDS, cfg)
        assert float(reg) > 0.0


class TestGradients:
    def test_sx_gradient_matches_soft_path(self):
        """Eq. 5: backward == gradient of the tau=1 soft path."""
        cfg = _cfg("sx", dist_bn=False)
        ps = _params(cfg)

        def through_layer(p):
            out, _ = layers.embed(p, IDS, cfg)
            return jnp.sum(out ** 2) * 0.0 + jnp.sum(out * w)

        def soft_only(p):
            q3 = ref.split_subspaces(p["emb/q"][IDS.reshape(-1)], cfg.D)
            scores = ref.sx_scores_ref(q3, p["emb/key"])
            soft = jax.nn.softmax(scores / cfg.tau, -1)
            h = jnp.einsum("ndk,kds->nds", soft, p["emb/value"])
            return jnp.sum(h.reshape(IDS.shape + (cfg.d,)) * w)

        w = jax.random.normal(jax.random.PRNGKey(7), IDS.shape + (cfg.d,))
        g1 = jax.grad(through_layer)(ps)
        g2 = jax.grad(soft_only)(ps)
        for k in ("emb/q", "emb/key", "emb/value"):
            np.testing.assert_allclose(g1[k], g2[k], rtol=1e-4, atol=1e-6)

    def test_vq_gradient_passes_straight_through_to_q(self):
        """Eq. 7: d/dQ of sum(H * w) == w scattered to accessed rows."""
        cfg = _cfg("vq", dist_bn=False, beta=0.0)
        ps = _params(cfg)
        w = jax.random.normal(jax.random.PRNGKey(8), IDS.shape + (cfg.d,))

        def f(p):
            out, _ = layers.embed(p, IDS, cfg)
            return jnp.sum(out * w)

        g = jax.grad(f)(ps)
        expected = np.zeros_like(np.asarray(ps["emb/q"]))
        for (b, t), idx in np.ndenumerate(np.asarray(IDS)):
            expected[idx] += np.asarray(w)[b, t]
        np.testing.assert_allclose(g["emb/q"], expected, rtol=1e-5, atol=1e-6)

    def test_vq_reg_moves_centroids(self):
        """The Sec. 2.3 regularizer must produce nonzero centroid grads."""
        cfg = _cfg("vq", dist_bn=False)
        ps = _params(cfg)

        def f(p):
            _, reg = layers.embed(p, IDS, cfg)
            return reg

        g = jax.grad(f)(ps)
        assert float(jnp.max(jnp.abs(g["emb/kv"]))) > 0.0

    def test_sx_grad_nonzero_for_all_tables(self):
        cfg = _cfg("sx")
        ps = _params(cfg)

        def f(p):
            out, _ = layers.embed(p, IDS, cfg)
            return jnp.sum(out ** 2)

        g = jax.grad(f)(ps)
        for k in ("emb/q", "emb/key", "emb/value"):
            assert float(jnp.max(jnp.abs(g[k]))) > 0.0, k


class TestWholeVocab:
    @pytest.mark.parametrize("variant", ["sx", "vq"])
    def test_extract_codes_shape_range(self, variant):
        cfg = _cfg(variant)
        codes = layers.extract_codes(_params(cfg), cfg)
        assert codes.shape == (50, 4)
        c = np.asarray(codes)
        assert c.min() >= 0 and c.max() < 4

    @pytest.mark.parametrize("variant", ["sx", "vq"])
    def test_reconstruct_equals_gather_of_extracted(self, variant):
        cfg = _cfg(variant, dist_bn=False)
        ps = _params(cfg)
        table = layers.reconstruct_table(ps, cfg)
        codes = layers.extract_codes(ps, cfg)
        want = ref.gather_codes_ref(codes, layers.value_matrix(ps, cfg))
        np.testing.assert_allclose(table, want, rtol=1e-6)

    def test_full_rank_proposition1(self):
        """Prop. 1: with full-rank one-hot codebook B, full-rank V^(j) and
        KD >= d, the reconstructed table has rank d."""
        cfg = _cfg("vq", vocab=200, d=16, K=8, D=4, dist_bn=False)
        ps = _params(cfg)
        table = np.asarray(layers.reconstruct_table(ps, cfg))
        # random init at vocab >> K*D almost surely satisfies the premises
        assert np.linalg.matrix_rank(table, tol=1e-5) == 16


class TestCompressionRatio:
    def test_full_cr_is_one(self):
        assert _cfg("full").compression_ratio() == 1.0

    def test_paper_formula(self):
        """CR = 32nd / (nD log2 K + 32Kd) for DPQ without sharing."""
        import math
        cfg = _cfg("sx", vocab=10000, d=256, K=32, D=64)
        want = (32 * 10000 * 256) / (10000 * 64 * math.log2(32)
                                     + 32 * 32 * 256)
        assert abs(cfg.compression_ratio() - want) < 1e-9

    def test_sharing_increases_cr(self):
        a = _cfg("sx", vocab=10000, d=256)
        b = _cfg("sx", vocab=10000, d=256, share=True)
        assert b.compression_ratio() > a.compression_ratio()

    def test_cr_grows_with_vocab(self):
        a = _cfg("sx", vocab=1000, d=64)
        b = _cfg("sx", vocab=100000, d=64)
        assert b.compression_ratio() > a.compression_ratio()

    def test_lowrank_cr(self):
        cfg = _cfg("lowrank", vocab=1000, d=64, rank=8)
        want = (32 * 1000 * 64) / (32 * (1000 * 8 + 8 * 64))
        assert abs(cfg.compression_ratio() - want) < 1e-9
