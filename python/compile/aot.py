"""AOT artifact builder: lowers every (task x embedding-variant x K x D)
configuration used by the experiments to **HLO text** + a JSON manifest.

This is the only place Python runs; the Rust coordinator loads the HLO via
`HloModuleProto::from_text_file`, compiles it on the PJRT CPU client, and
drives training/eval/serving from there.

Interchange is HLO *text*, not `.serialize()`: jax >= 0.5 emits protos with
64-bit instruction ids which xla_extension 0.5.1 rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifact conventions (mirrored by rust/src/runtime/manifest.rs):

  <name>.hlo.txt       HLO text of the entry computation (root is a tuple)
  <name>.manifest.json {"name", "kind", "inputs": [...], "outputs": [...],
                        "meta": {...}}

  kind=init    inputs: seed:i32[]             outputs: state...
  kind=train   inputs: state..., batch..., lr outputs: metrics..., state...
  kind=eval    inputs: state..., batch...     outputs: metrics...
  kind=decode  inputs: state..., src          outputs: hyp ids
  kind=export  inputs: state...               outputs: codes/values/table

State entries are ordered by sorted(name); training artifacts return the
new state in exactly the input order so the Rust trainer can feed outputs
straight back in.

Usage:  python -m compile.aot --out-dir ../artifacts [--only REGEX] [--list]
"""

import argparse
import json
import os
import re
import sys
import time
from dataclasses import dataclass, field, replace
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import layers, optim
from .layers import EmbedCfg
from .models import bert_tiny, lstm_lm, nmt, textclass

F32, I32 = jnp.float32, jnp.int32


# ---------------------------------------------------------------------------
# Artifact plumbing
# ---------------------------------------------------------------------------

@dataclass
class Arg:
    name: str
    shape: Tuple[int, ...]
    dtype: str           # "f32" | "i32"
    role: str            # "state" | "input"

    def sds(self):
        return jax.ShapeDtypeStruct(self.shape, F32 if self.dtype == "f32" else I32)


@dataclass
class Artifact:
    name: str
    kind: str
    fn: Callable          # positional over Arg order
    args: List[Arg]
    out_names: List[str]  # names; roles derived from kind
    out_roles: List[str]
    meta: dict


REGISTRY: List[Artifact] = []


def _dt(x):
    return "i32" if jnp.issubdtype(x.dtype, jnp.integer) else "f32"


def _state_args(state0) -> List[Arg]:
    return [Arg(k, tuple(state0[k].shape), _dt(state0[k]), "state")
            for k in sorted(state0)]


def _shapes_of(init_params, opt):
    params0 = jax.eval_shape(init_params, jax.random.PRNGKey(0))
    ostate0 = jax.eval_shape(lambda p: opt.init_state(p), params0)
    return params0, ostate0


def task_bundle(prefix, init_params, loss, metric_names, batch_args,
                opt_name, meta, with_eval=False):
    """Registers <prefix>_init and <prefix>_train (and optionally _eval).

    init_params: rng -> params dict
    loss: (params_dict, *batch) -> (total, *metrics) with
          len(metrics) == len(metric_names)
    batch_args: [Arg(role=input)] excluding the trailing lr scalar.
    """
    opt = optim.get(opt_name)
    params0, ostate0 = _shapes_of(init_params, opt)
    state0 = {**params0, **ostate0}
    names = sorted(state0)
    sargs = _state_args(state0)
    ns = len(names)

    def init_fn(seed):
        params = init_params(jax.random.PRNGKey(seed))
        st = {**params, **opt.init_state(params)}
        return tuple(st[k] for k in names)

    def train_fn(*flat):
        state = dict(zip(names, flat[:ns]))
        batch = flat[ns:-1]
        lr = flat[-1]
        params = {k: v for k, v in state.items() if not k.startswith("opt/")}
        ostate = {k: v for k, v in state.items() if k.startswith("opt/")}

        def lf(p):
            out = loss(p, *batch)
            return out[0], out[1:]

        (_, metrics), grads = jax.value_and_grad(lf, has_aux=True)(params)
        new_params, new_ostate = opt.apply(params, grads, ostate, lr)
        new_state = {**new_params, **new_ostate}
        return tuple(metrics) + tuple(new_state[k] for k in names)

    def eval_fn(*flat):
        state = dict(zip(names, flat[:ns]))
        params = {k: v for k, v in state.items() if not k.startswith("opt/")}
        out = loss(params, *flat[ns:])
        return tuple(out[1:])

    REGISTRY.append(Artifact(
        f"{prefix}_init", "init", init_fn,
        [Arg("seed", (), "i32", "input")],
        list(names), ["state"] * ns, meta))
    REGISTRY.append(Artifact(
        f"{prefix}_train", "train", train_fn,
        sargs + batch_args + [Arg("lr", (), "f32", "input")],
        list(metric_names) + list(names),
        ["metric"] * len(metric_names) + ["state"] * ns, meta))
    if with_eval:
        REGISTRY.append(Artifact(
            f"{prefix}_eval", "eval", eval_fn,
            sargs + batch_args, list(metric_names),
            ["metric"] * len(metric_names), meta))
    return names, sargs, state0


def export_bundle(prefix, init_params, opt_name, ecfg: EmbedCfg, meta):
    """Registers <prefix>_export: state -> (codes, values, table)."""
    opt = optim.get(opt_name)
    params0, ostate0 = _shapes_of(init_params, opt)
    state0 = {**params0, **ostate0}
    names = sorted(state0)
    sargs = _state_args(state0)

    def export_fn(*flat):
        params = dict(zip(names, flat))
        if ecfg.variant in ("sx", "vq"):
            codes = layers.extract_codes(params, ecfg)
            values = layers.value_matrix(params, ecfg)
            from .kernels.reconstruct import gather_codes
            table = gather_codes(codes, values)
            return codes, values, table
        table = layers.reconstruct_table(params, ecfg)
        return (table,)

    if ecfg.variant in ("sx", "vq"):
        outs = ["codes", "values", "table"]
    else:
        outs = ["table"]
    REGISTRY.append(Artifact(
        f"{prefix}_export", "export", export_fn, sargs,
        outs, ["output"] * len(outs), meta))


# ---------------------------------------------------------------------------
# Embedding configs
# ---------------------------------------------------------------------------

def _ecfg(variant, vocab, d, K=32, D=32, share=False, rank=8, **kw):
    return EmbedCfg(variant=variant, vocab=vocab, d=d, K=K, D=D,
                    share=share, rank=rank, **kw)


def _emb_meta(e: EmbedCfg):
    return {
        "variant": e.variant, "vocab": e.vocab, "d": e.d, "K": e.K,
        "D": e.D, "share": e.share, "rank": e.rank,
        "bits": e.bits(), "cr": e.compression_ratio(),
    }


def _suffix(e: EmbedCfg):
    if e.variant in ("sx", "vq", "chen18"):
        s = f"{e.variant}_K{e.K}D{e.D}"
        s += "s" if e.share else ""
        s += "" if e.dist_bn else "nb"
        return s
    if e.variant == "lowrank":
        return f"lowrank{e.rank}"
    return e.variant


# ---------------------------------------------------------------------------
# Task families
# ---------------------------------------------------------------------------

LM_BATCH, LM_SEQ = 16, 24


def lm_family(ds, vocab, d, h, ecfg: EmbedCfg, with_eval=False,
              with_export=False):
    cfg = lstm_lm.LmCfg(emb=ecfg, hidden=h, batch=LM_BATCH, seq=LM_SEQ)
    prefix = f"lm_{ds}_{_suffix(ecfg)}"
    meta = {"task": "lm", "dataset": ds, "hidden": h,
            "batch": LM_BATCH, "seq": LM_SEQ, "metrics": ["ce"],
            **_emb_meta(ecfg)}
    batch = [Arg("x", (LM_BATCH, LM_SEQ), "i32", "input"),
             Arg("y", (LM_BATCH, LM_SEQ), "i32", "input")]

    def loss(p, x, y):
        total, ce = lstm_lm.loss_fn(p, x, y, cfg)
        return total, ce

    task_bundle(prefix, lambda r: lstm_lm.init(r, cfg), loss, ["ce"],
                batch, "sgd", meta, with_eval=with_eval)
    if with_export:
        export_bundle(prefix, lambda r: lstm_lm.init(r, cfg), "sgd", ecfg, meta)


NMT_B, NMT_TS, NMT_TT = 32, 14, 16


def nmt_family(ds, vocab, ecfg: EmbedCfg, with_eval=False, with_export=False,
               with_decode=True, h=96):
    cfg = nmt.NmtCfg(emb=ecfg, tgt_vocab=vocab, hidden=h, batch=NMT_B,
                     src_len=NMT_TS, tgt_len=NMT_TT)
    prefix = f"nmt_{ds}_{_suffix(ecfg)}"
    meta = {"task": "nmt", "dataset": ds, "hidden": h, "batch": NMT_B,
            "src_len": NMT_TS, "tgt_len": NMT_TT, "tgt_vocab": vocab,
            "metrics": ["ce"], **_emb_meta(ecfg)}
    batch = [Arg("src", (NMT_B, NMT_TS), "i32", "input"),
             Arg("tgt_in", (NMT_B, NMT_TT), "i32", "input"),
             Arg("tgt_out", (NMT_B, NMT_TT), "i32", "input")]

    def loss(p, src, ti, to):
        total, ce = nmt.loss_fn(p, src, ti, to, cfg)
        return total, ce

    names, sargs, _ = task_bundle(
        prefix, lambda r: nmt.init(r, cfg), loss, ["ce"], batch, "adam",
        meta, with_eval=with_eval)
    if with_decode:
        ns = len(names)

        def decode_fn(*flat):
            params = {k: v for k, v in zip(names, flat[:ns])
                      if not k.startswith("opt/")}
            return (nmt.greedy_decode(params, flat[ns], cfg),)

        REGISTRY.append(Artifact(
            f"{prefix}_decode", "decode", decode_fn,
            sargs + [Arg("src", (NMT_B, NMT_TS), "i32", "input")],
            ["hyp"], ["output"], meta))
    if with_export:
        export_bundle(prefix, lambda r: nmt.init(r, cfg), "adam", ecfg, meta)


TC_B, TC_T = 32, 32


def textc_family(ds, vocab, classes, ecfg: EmbedCfg, with_eval=False):
    cfg = textclass.TextCfg(emb=ecfg, hidden=64, classes=classes,
                            batch=TC_B, seq=TC_T)
    prefix = f"textc_{ds}_{_suffix(ecfg)}"
    meta = {"task": "textc", "dataset": ds, "classes": classes,
            "batch": TC_B, "seq": TC_T, "metrics": ["ce", "acc"],
            **_emb_meta(ecfg)}
    batch = [Arg("x", (TC_B, TC_T), "i32", "input"),
             Arg("y", (TC_B,), "i32", "input")]

    def loss(p, x, y):
        return textclass.loss_fn(p, x, y, cfg)

    task_bundle(prefix, lambda r: textclass.init(r, cfg), loss,
                ["ce", "acc"], batch, "adam", meta, with_eval=with_eval)


BERT_B, BERT_T = 8, 48


def bert_family(ecfg: EmbedCfg):
    cfg = bert_tiny.BertCfg(emb=ecfg, layers_n=2, heads=4, ff=256,
                            batch=BERT_B, seq=BERT_T, classes=2)
    prefix = f"bert_{_suffix(ecfg)}"
    meta = {"task": "bert", "dataset": "synthmlm", "batch": BERT_B,
            "seq": BERT_T, "classes": 2, "metrics": ["ce"],
            **_emb_meta(ecfg)}
    mlm_batch = [Arg("x", (BERT_B, BERT_T), "i32", "input"),
                 Arg("y", (BERT_B, BERT_T), "i32", "input"),
                 Arg("w", (BERT_B, BERT_T), "i32", "input")]

    def mlm(p, x, y, w):
        total, ce = bert_tiny.mlm_loss(p, x, y, w, cfg)
        return total, ce

    names, sargs, _ = task_bundle(
        prefix, lambda r: bert_tiny.init(r, cfg), mlm, ["ce"],
        mlm_batch, "adam", meta)

    # fine-tune probe: same state, classification loss
    ns = len(names)
    ft_batch = [Arg("x", (BERT_B, BERT_T), "i32", "input"),
                Arg("y", (BERT_B,), "i32", "input")]
    opt = optim.get("adam")

    def ft_train(*flat):
        state = dict(zip(names, flat[:ns]))
        x, y, lr = flat[ns], flat[ns + 1], flat[-1]
        params = {k: v for k, v in state.items() if not k.startswith("opt/")}
        ostate = {k: v for k, v in state.items() if k.startswith("opt/")}

        def lf(p):
            total, ce, acc = bert_tiny.cls_loss(p, x, y, cfg)
            return total, (ce, acc)

        (_, (ce, acc)), grads = jax.value_and_grad(lf, has_aux=True)(params)
        new_params, new_ostate = opt.apply(params, grads, ostate, lr)
        new_state = {**new_params, **new_ostate}
        return (ce, acc) + tuple(new_state[k] for k in names)

    ft_meta = dict(meta, metrics=["ce", "acc"])
    REGISTRY.append(Artifact(
        f"{prefix}_ft_train", "train", ft_train,
        sargs + ft_batch + [Arg("lr", (), "f32", "input")],
        ["ce", "acc"] + list(names),
        ["metric", "metric"] + ["state"] * ns, ft_meta))


# ---------------------------------------------------------------------------
# Chen'18+ (distillation) and Shu'17 (3-step) baselines -- LM medium only
# ---------------------------------------------------------------------------

def chen18p_family(ds, vocab, d, h):
    """Chen'18+ : Chen'18 code-learning with an extra distillation loss
    against a pre-trained full embedding table (passed in as an input)."""
    ecfg = _ecfg("chen18", vocab, d, K=32, D=16)
    cfg = lstm_lm.LmCfg(emb=ecfg, hidden=h, batch=LM_BATCH, seq=LM_SEQ)
    prefix = f"lm_{ds}_chen18p_K{ecfg.K}D{ecfg.D}"
    meta = {"task": "lm", "dataset": ds, "hidden": h, "batch": LM_BATCH,
            "seq": LM_SEQ, "metrics": ["ce"], **_emb_meta(ecfg)}
    opt = optim.get("sgd")
    params0, ostate0 = _shapes_of(lambda r: lstm_lm.init(r, cfg), opt)
    state0 = {**params0, **ostate0}
    names = sorted(state0)
    sargs = _state_args(state0)
    ns = len(names)

    def init_fn(seed):
        p = lstm_lm.init(jax.random.PRNGKey(seed), cfg)
        st = {**p, **opt.init_state(p)}
        return tuple(st[k] for k in names)

    def train_fn(*flat):
        state = dict(zip(names, flat[:ns]))
        x, y, target, dw, lr = flat[ns], flat[ns + 1], flat[ns + 2], flat[ns + 3], flat[-1]
        params = {k: v for k, v in state.items() if not k.startswith("opt/")}

        def lf(p):
            total, ce = lstm_lm.loss_fn(p, x, y, cfg)
            emb, _ = layers.embed(p, x, ecfg)
            distill = jnp.mean(jnp.sum((emb - target[x]) ** 2, -1))
            return total + dw * distill, ce

        (_, ce), grads = jax.value_and_grad(lf, has_aux=True)(params)
        new_params, _ = opt.apply(params, grads, {}, lr)
        return (ce,) + tuple({**new_params}[k] for k in names)

    REGISTRY.append(Artifact(f"{prefix}_init", "init", init_fn,
                             [Arg("seed", (), "i32", "input")],
                             list(names), ["state"] * ns, meta))
    REGISTRY.append(Artifact(
        f"{prefix}_train", "train", train_fn,
        sargs + [Arg("x", (LM_BATCH, LM_SEQ), "i32", "input"),
                 Arg("y", (LM_BATCH, LM_SEQ), "i32", "input"),
                 Arg("target", (vocab, d), "f32", "input"),
                 Arg("dw", (), "f32", "input"),
                 Arg("lr", (), "f32", "input")],
        ["ce"] + list(names), ["metric"] + ["state"] * ns, meta))


def shu17_family(ds, vocab, d, h):
    """Shu & Nakayama 2017: (2) learn codes that reconstruct a pre-trained
    table, (3) freeze codes, train the task model over composed embeddings.
    Step (1) -- training the full model -- reuses lm_<ds>_full."""
    K, D = 32, 16
    ecfg = _ecfg("chen18", vocab, d, K=K, D=D)

    # ---- stage 2: code learning (reconstruction autoencoder) ----
    prefix2 = f"shu17_{ds}_codelearn_K{K}D{D}"
    meta2 = {"task": "shu17_codelearn", "dataset": ds, "metrics": ["mse"],
             **_emb_meta(ecfg)}
    opt2 = optim.get("adam")
    CB = 256  # rows per reconstruction step

    def init2_params(rng):
        return layers.init_params(rng, ecfg)

    params0, ostate0 = _shapes_of(init2_params, opt2)
    st0 = {**params0, **ostate0}
    names2 = sorted(st0)
    sargs2 = _state_args(st0)
    ns2 = len(names2)

    def init2(seed):
        p = init2_params(jax.random.PRNGKey(seed))
        st = {**p, **opt2.init_state(p)}
        return tuple(st[k] for k in names2)

    def train2(*flat):
        state = dict(zip(names2, flat[:ns2]))
        ids, rows, lr = flat[ns2], flat[ns2 + 1], flat[-1]
        params = {k: v for k, v in state.items() if not k.startswith("opt/")}
        ostate = {k: v for k, v in state.items() if k.startswith("opt/")}

        def lf(p):
            emb, _ = layers.embed(p, ids, ecfg)
            mse = jnp.mean(jnp.sum((emb - rows) ** 2, -1))
            return mse, mse

        (_, mse), grads = jax.value_and_grad(lf, has_aux=True)(params)
        new_params, new_ostate = opt2.apply(params, grads, ostate, lr)
        new_state = {**new_params, **new_ostate}
        return (mse,) + tuple(new_state[k] for k in names2)

    def export2(*flat):
        params = dict(zip(names2, flat))
        logits = params["emb/logits"]
        return (jnp.argmax(logits, -1).astype(jnp.int32),)

    REGISTRY.append(Artifact(f"{prefix2}_init", "init", init2,
                             [Arg("seed", (), "i32", "input")],
                             list(names2), ["state"] * ns2, meta2))
    REGISTRY.append(Artifact(
        f"{prefix2}_train", "train", train2,
        sargs2 + [Arg("ids", (CB,), "i32", "input"),
                  Arg("rows", (CB, d), "f32", "input"),
                  Arg("lr", (), "f32", "input")],
        ["mse"] + list(names2), ["metric"] + ["state"] * ns2, meta2))
    REGISTRY.append(Artifact(f"{prefix2}_export", "export", export2, sargs2,
                             ["codes"], ["output"], meta2))

    # ---- stage 3: task training with frozen codes ----
    prefix3 = f"shu17_{ds}_task_K{K}D{D}"
    meta3 = {"task": "lm", "dataset": ds, "hidden": h, "batch": LM_BATCH,
             "seq": LM_SEQ, "metrics": ["ce"], **_emb_meta(ecfg),
             "frozen_codes": True}
    opt3 = optim.get("sgd")

    def init3_params(rng):
        ps = lstm_lm.init(rng, lstm_lm.LmCfg(emb=ecfg, hidden=h,
                                             batch=LM_BATCH, seq=LM_SEQ))
        ps.pop("emb/logits")  # codes are frozen inputs in stage 3
        return ps

    params30, ostate30 = _shapes_of(init3_params, opt3)
    st30 = {**params30, **ostate30}
    names3 = sorted(st30)
    sargs3 = _state_args(st30)
    ns3 = len(names3)
    cfg3 = lstm_lm.LmCfg(emb=ecfg, hidden=h, batch=LM_BATCH, seq=LM_SEQ)

    def loss3(p, codes, x, y):
        onehot = jax.nn.one_hot(codes[x.reshape(-1)], K, dtype=jnp.float32)
        emb = layers.chen18_compose(onehot, p, ecfg)
        emb = emb.reshape(x.shape + (d,))
        # replicate lstm_lm.loss_fn body with a precomputed embedding
        B = x.shape[0]
        h0 = jnp.zeros((B, h), jnp.float32)
        hs = lstm_lm._lstm_scan(p, emb, h0, h0)
        logits = hs @ p["out/w"] + p["out/b"]
        logp = jax.nn.log_softmax(logits, axis=-1)
        ce = -jnp.mean(jnp.take_along_axis(logp, y[..., None], axis=-1))
        return ce, ce

    def init3(seed):
        p = init3_params(jax.random.PRNGKey(seed))
        st = {**p, **opt3.init_state(p)}
        return tuple(st[k] for k in names3)

    def train3(*flat):
        state = dict(zip(names3, flat[:ns3]))
        # batch first, then the frozen codes (the Trainer appends constant
        # extra inputs after the generated batch), then lr.
        x, y, codes, lr = flat[ns3], flat[ns3 + 1], flat[ns3 + 2], flat[-1]
        params = {k: v for k, v in state.items() if not k.startswith("opt/")}

        def lf(p):
            return loss3(p, codes, x, y)

        (_, ce), grads = jax.value_and_grad(lf, has_aux=True)(params)
        new_params, _ = opt3.apply(params, grads, {}, lr)
        return (ce,) + tuple(new_params[k] for k in names3)

    REGISTRY.append(Artifact(f"{prefix3}_init", "init", init3,
                             [Arg("seed", (), "i32", "input")],
                             list(names3), ["state"] * ns3, meta3))
    REGISTRY.append(Artifact(
        f"{prefix3}_train", "train", train3,
        sargs3 + [Arg("x", (LM_BATCH, LM_SEQ), "i32", "input"),
                  Arg("y", (LM_BATCH, LM_SEQ), "i32", "input"),
                  Arg("codes", (vocab, D), "i32", "input"),
                  Arg("lr", (), "f32", "input")],
        ["ce"] + list(names3), ["metric"] + ["state"] * ns3, meta3))


# ---------------------------------------------------------------------------
# The full artifact set (see DESIGN.md experiment index)
# ---------------------------------------------------------------------------

LM_SIZES = {"small": (64, 64), "medium": (128, 128), "large": (256, 256)}
PTB_VOCAB = 2000
WIKI2_VOCAB = 4000
NMT_DATASETS = {"envi": 3000, "vien": 2000, "ende": 4000}
TC_DATASETS = {"agnews": (8000, 4), "yahoo": (24000, 10),
               "dbpedia": (16000, 14), "yelpp": (12000, 2),
               "yelpf": (12000, 5)}


def build_registry():
    # ---- LM / PTB-shaped: Tables 3, 4, 5; Figures 3, 4, 5, 6 ----
    for size, (d, h) in LM_SIZES.items():
        ds = f"ptb{size}" if size != "medium" else "ptb"
        full = size == "medium"
        lm_family(ds, PTB_VOCAB, d, h, _ecfg("full", PTB_VOCAB, d),
                  with_eval=full, with_export=full)
        for v in ("sx", "vq"):
            lm_family(ds, PTB_VOCAB, d, h, _ecfg(v, PTB_VOCAB, d, K=32, D=32),
                      with_export=full)
    # Fig 3 K x D grid + Fig 6 K ladder (LM medium, d=128)
    d, h = LM_SIZES["medium"]
    for v in ("sx", "vq"):
        for K in (2, 8, 32, 128):
            for D in (8, 32):
                if (K, D) == (32, 32):
                    continue  # default config above
                export = D == 32 and K in (8, 128)  # Fig 6 code tracking
                lm_family("ptb", PTB_VOCAB, d, h,
                          _ecfg(v, PTB_VOCAB, d, K=K, D=D),
                          with_export=export)
    # ablations (Sec. 2.4): subspace-sharing and distance batch-norm
    for v in ("sx", "vq"):
        lm_family("ptb", PTB_VOCAB, d, h,
                  _ecfg(v, PTB_VOCAB, d, K=32, D=32, share=True))
        lm_family("ptb", PTB_VOCAB, d, h,
                  _ecfg(v, PTB_VOCAB, d, K=32, D=32, dist_bn=False))
    # Chen'18 / Chen'18+ / Shu'17 baselines (Table 4, medium)
    lm_family("ptb", PTB_VOCAB, d, h, _ecfg("chen18", PTB_VOCAB, d, K=32, D=16))
    chen18p_family("ptb", PTB_VOCAB, d, h)
    shu17_family("ptb", PTB_VOCAB, d, h)

    # ---- LM / Wikitext2-shaped (Table 3) ----
    lm_family("wiki2", WIKI2_VOCAB, d, h, _ecfg("full", WIKI2_VOCAB, d))
    for v in ("sx", "vq"):
        lm_family("wiki2", WIKI2_VOCAB, d, h,
                  _ecfg(v, WIKI2_VOCAB, d, K=32, D=32))

    # ---- NMT (Tables 3, 8; Fig 3 grid on envi) ----
    for ds, vocab in NMT_DATASETS.items():
        ende = ds == "ende"
        nmt_family(ds, vocab, _ecfg("full", vocab, 64), with_eval=ende)
        for v in ("sx", "vq"):
            nmt_family(ds, vocab, _ecfg(v, vocab, 64, K=32, D=16),
                       with_export=ende)
    for v in ("sx", "vq"):
        for K in (2, 32, 128):
            for D in (8, 16):
                if (K, D) == (32, 16):
                    continue
                nmt_family("envi", NMT_DATASETS["envi"],
                           _ecfg(v, NMT_DATASETS["envi"], 64, K=K, D=D),
                           with_decode=True)

    # ---- Text classification (Tables 3, 6) ----
    for ds, (vocab, classes) in TC_DATASETS.items():
        textc_family(ds, vocab, classes, _ecfg("full", vocab, 64))
        for v in ("sx", "vq"):
            textc_family(ds, vocab, classes, _ecfg(v, vocab, 64, K=32, D=16))
        for rank in (6, 3):  # ~10x and ~20x CR at d=64
            textc_family(ds, vocab, classes,
                         _ecfg("lowrank", vocab, 64, rank=rank))

    # ---- BERT (Table 7) ----
    bert_family(_ecfg("full", 4000, 128))
    bert_family(_ecfg("sx", 4000, 128, K=32, D=128))


# ---------------------------------------------------------------------------
# Lowering
# ---------------------------------------------------------------------------

def to_hlo_text(fn, arg_sds):
    # keep_unused: eval/decode/export graphs ignore optimizer slots, but the
    # Rust runtime passes the full state positionally -- the lowered program
    # must keep every declared parameter.
    lowered = jax.jit(fn, keep_unused=True).lower(*arg_sds)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def emit(art: Artifact, out_dir: str) -> bool:
    hlo_path = os.path.join(out_dir, f"{art.name}.hlo.txt")
    man_path = os.path.join(out_dir, f"{art.name}.manifest.json")
    if os.path.exists(hlo_path) and os.path.exists(man_path):
        return False
    arg_sds = [a.sds() for a in art.args]
    out_shapes = jax.eval_shape(art.fn, *arg_sds)
    text = to_hlo_text(art.fn, arg_sds)
    manifest = {
        "name": art.name,
        "kind": art.kind,
        "inputs": [{"name": a.name, "shape": list(a.shape),
                    "dtype": a.dtype, "role": a.role} for a in art.args],
        "outputs": [{"name": n, "shape": list(o.shape), "dtype": _dt(o),
                     "role": r}
                    for n, o, r in zip(art.out_names, out_shapes,
                                       art.out_roles)],
        "meta": art.meta,
    }
    tmp = hlo_path + ".tmp"
    with open(tmp, "w") as f:
        f.write(text)
    os.replace(tmp, hlo_path)
    with open(man_path, "w") as f:
        json.dump(manifest, f, indent=1)
    return True


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="regex filter on artifact names")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    build_registry()
    sel = [a for a in REGISTRY
           if args.only is None or re.search(args.only, a.name)]
    if args.list:
        for a in sel:
            print(a.name)
        print(f"{len(sel)} artifacts")
        return
    os.makedirs(args.out_dir, exist_ok=True)
    t0 = time.time()
    built = 0
    for i, a in enumerate(sel):
        t1 = time.time()
        if emit(a, args.out_dir):
            built += 1
            print(f"[{i + 1}/{len(sel)}] {a.name}  ({time.time() - t1:.1f}s)",
                  flush=True)
    print(f"done: {built} built, {len(sel) - built} up-to-date, "
          f"{time.time() - t0:.0f}s total")


if __name__ == "__main__":
    main()
