"""Pure-jnp reference oracles for the DPQ Pallas kernels.

These are the ground truth the Pallas kernels (dpq_sx.py, dpq_vq.py,
reconstruct.py) are tested against in python/tests/test_kernels.py.
Everything here follows the paper's notation:

  Q in R^{N x d}           query rows (the raw embedding rows in use)
  K in R^{K x D x d/D}     product keys, split into D subspaces
  V in R^{K x D x d/D}     product values (tied to K for DPQ-VQ)
  C in {0..K-1}^{N x D}    KD codes (0-based here; the paper is 1-based)

`scores` are always "higher is better": dot products for DPQ-SX (Eq. 3),
negative squared Euclidean distance for DPQ-VQ (Eq. 6).
"""

import jax.numpy as jnp


def split_subspaces(x, D):
    """[N, d] -> [N, D, d/D] subspace view (paper's column grouping)."""
    N, d = x.shape
    assert d % D == 0, f"d={d} not divisible by D={D}"
    return x.reshape(N, D, d // D)


def merge_subspaces(x):
    """[N, D, s] -> [N, D*s] (the concat of Eq. 2)."""
    N, D, s = x.shape
    return x.reshape(N, D * s)


def sx_scores_ref(q3, key3):
    """Dot-product scores of Eq. 3 (pre-softmax logits).

    q3: [N, D, s], key3: [K, D, s]  ->  [N, D, K]
    """
    return jnp.einsum("nds,kds->ndk", q3, key3)


def vq_scores_ref(q3, key3):
    """Negative squared Euclidean distances of Eq. 6 ("higher is better").

    q3: [N, D, s], key3: [K, D, s]  ->  [N, D, K]
    """
    # ||q - k||^2 = ||q||^2 - 2 q.k + ||k||^2
    qsq = jnp.sum(q3 * q3, axis=-1)[:, :, None]           # [N, D, 1]
    ksq = jnp.sum(key3 * key3, axis=-1).T[None, :, :]     # [1, D, K]
    qk = jnp.einsum("nds,kds->ndk", q3, key3)             # [N, D, K]
    return -(qsq - 2.0 * qk + ksq)


def dist_bn_ref(scores, eps=1e-5):
    """Distance batch-normalization (Sec. 2.4): per (j, k), normalize the
    score distribution over the batch axis N. No learned scale/offset."""
    mean = jnp.mean(scores, axis=0, keepdims=True)
    var = jnp.var(scores, axis=0, keepdims=True)
    return (scores - mean) / jnp.sqrt(var + eps)


def codes_ref(scores):
    """argmax_k over scores -> KD codes. [N, D, K] -> int32 [N, D]."""
    return jnp.argmax(scores, axis=-1).astype(jnp.int32)


def gather_codes_ref(codes, value3):
    """Algorithm 1: index each subspace of V with the code, concat.

    codes: int [N, D], value3: [K, D, s] -> [N, D*s]
    """
    D = codes.shape[1]
    cols = jnp.arange(D)[None, :]                         # [1, D]
    picked = value3[codes, cols]                          # [N, D, s]
    return merge_subspaces(picked)


def select_gather_ref(scores, value3):
    """Hard top-1 selection + product-value gather (Eq. 1 + Eq. 2).

    scores: [N, D, K], value3: [K, D, s] -> [N, d]
    """
    return gather_codes_ref(codes_ref(scores), value3)


def dpq_forward_hard_ref(q, key3, value3, metric="dot", use_bn=False):
    """End-to-end hard forward: split -> scores -> (BN) -> argmax -> gather.

    q: [N, d]; key3/value3: [K, D, s]; returns ([N, d], codes [N, D]).
    """
    D = key3.shape[1]
    q3 = split_subspaces(q, D)
    if metric == "dot":
        scores = sx_scores_ref(q3, key3)
    elif metric == "l2":
        scores = vq_scores_ref(q3, key3)
    else:
        raise ValueError(metric)
    if use_bn:
        scores = dist_bn_ref(scores)
    codes = codes_ref(scores)
    return gather_codes_ref(codes, value3), codes
