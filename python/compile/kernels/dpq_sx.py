"""Pallas kernel: DPQ-SX dot-product scores (Eq. 3, pre-softmax logits).

Computes scores[n, j, k] = <Q_n^(j), K_k^(j)> for every token n, subspace j
and centroid k. This is the DPQ hot-spot: a [N*D, s] x [s, K] contraction
per subspace, mapped to the MXU on TPU. The token axis is tiled into VMEM
blocks; the key matrix stays fully resident across the grid.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import pallas_util as pu


def _sx_scores_kernel(q_ref, key_ref, out_ref):
    """One token block.

    q_ref:   [bn, D, s]   VMEM block of query subvectors
    key_ref: [K, D, s]    full product-key matrix (resident)
    out_ref: [bn, D, K]   dot-product scores
    """
    q = q_ref[...]
    k = key_ref[...]
    # Contract the subspace axis: (bn, D, s) x (K, D, s) -> (bn, D, K).
    # dot_general with batch dim D keeps the contraction MXU-shaped.
    out_ref[...] = jax.lax.dot_general(
        jnp.swapaxes(q, 0, 1),            # [D, bn, s]
        jnp.transpose(k, (1, 2, 0)),      # [D, s, K]
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    ).transpose(1, 0, 2)                   # [bn, D, K]


def sx_scores(q3, key3, block_n=None):
    """q3: [N, D, s], key3: [K, D, s] -> [N, D, K] dot-product scores."""
    N, D, s = q3.shape
    K = key3.shape[0]
    if block_n is None:
        block_n = pu.block_for(D * s, K, D)
    q3, n_orig = pu.pad_rows(q3, block_n)
    grid = (q3.shape[0] // block_n,)
    out = pl.pallas_call(
        _sx_scores_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, D, s), lambda i: (i, 0, 0)),
            pl.BlockSpec((K, D, s), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, D, K), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((q3.shape[0], D, K), jnp.float32),
        interpret=True,
    )(q3, key3)
    return pu.unpad_rows(out, n_orig)
