"""Pallas kernels for the selection/reconstruction half of DPQ.

- select_gather: argmax over K + product-value gather (Eq. 1 + Eq. 2),
  used in the hard forward path of training (inside stop_gradient) and in
  code extraction.
- gather_codes: Algorithm 1 -- reconstruct embedding rows from integer KD
  codes and the value matrix. This is the *inference* hot path the paper
  claims is as cheap as a plain table lookup; the gather is expressed as a
  one-hot matmul so it runs on the MXU instead of scalar loads.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import pallas_util as pu


def _select_gather_kernel(scores_ref, value_ref, out_ref, codes_ref):
    """scores: [bn, D, K]; value: [K, D, s] -> out [bn, D*s], codes [bn, D]."""
    scores = scores_ref[...]
    v = value_ref[...]
    codes = jnp.argmax(scores, axis=-1)                    # [bn, D]
    K = v.shape[0]
    onehot = jax.nn.one_hot(codes, K, dtype=jnp.float32)   # [bn, D, K]
    picked = jax.lax.dot_general(
        jnp.swapaxes(onehot, 0, 1),       # [D, bn, K]
        jnp.transpose(v, (1, 0, 2)),      # [D, K, s]
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    ).transpose(1, 0, 2)                   # [bn, D, s]
    bn = picked.shape[0]
    out_ref[...] = picked.reshape(bn, -1)
    codes_ref[...] = codes.astype(jnp.int32)


def select_gather(scores, value3, block_n=None):
    """scores: [N, D, K], value3: [K, D, s] -> (H [N, D*s], codes [N, D])."""
    N, D, K = scores.shape
    s = value3.shape[2]
    if block_n is None:
        block_n = pu.block_for(D * s, K, D)
    scores, n_orig = pu.pad_rows(scores, block_n)
    grid = (scores.shape[0] // block_n,)
    out, codes = pl.pallas_call(
        _select_gather_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, D, K), lambda i: (i, 0, 0)),
            pl.BlockSpec((K, D, s), lambda i: (0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_n, D * s), lambda i: (i, 0)),
            pl.BlockSpec((block_n, D), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((scores.shape[0], D * s), jnp.float32),
            jax.ShapeDtypeStruct((scores.shape[0], D), jnp.int32),
        ],
        interpret=True,
    )(scores, value3)
    return pu.unpad_rows(out, n_orig), pu.unpad_rows(codes, n_orig)


def _gather_codes_kernel(codes_ref, value_ref, out_ref):
    """codes: [bn, D] int32; value: [K, D, s] -> out [bn, D*s]."""
    codes = codes_ref[...]
    v = value_ref[...]
    K = v.shape[0]
    onehot = jax.nn.one_hot(codes, K, dtype=jnp.float32)   # [bn, D, K]
    picked = jax.lax.dot_general(
        jnp.swapaxes(onehot, 0, 1),
        jnp.transpose(v, (1, 0, 2)),
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    ).transpose(1, 0, 2)
    bn = picked.shape[0]
    out_ref[...] = picked.reshape(bn, -1)


def gather_codes(codes, value3, block_n=None):
    """codes: int32 [N, D], value3: [K, D, s] -> H [N, D*s] (Algorithm 1)."""
    N, D = codes.shape
    K, _, s = value3.shape
    if block_n is None:
        block_n = pu.block_for(D * s, K, D)
    codes, n_orig = pu.pad_rows(codes, block_n)
    grid = (codes.shape[0] // block_n,)
    out = pl.pallas_call(
        _gather_codes_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, D), lambda i: (i, 0)),
            pl.BlockSpec((K, D, s), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, D * s), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((codes.shape[0], D * s), jnp.float32),
        interpret=True,
    )(codes, value3)
    return pu.unpad_rows(out, n_orig)
