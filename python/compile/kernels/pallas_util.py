"""Shared plumbing for the Pallas kernels.

All kernels tile the token axis N into VMEM-sized blocks and keep the
product key/value matrices fully resident (they are K*d floats -- tens of
KiB, far below the ~16 MiB TPU VMEM budget; see DESIGN.md
section "Hardware adaptation"). The grid iterates over token blocks only.

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls, so kernels are lowered through the Pallas interpreter into
plain HLO. Block/tiling structure is still the real TPU design.
"""

import functools

import jax
import jax.numpy as jnp

# Default token-block: 128 rows keeps q-block + score-block + out-block
# comfortably inside VMEM for every configuration exercised in this repo
# (worst case d=256, K=128, D=128: ~128*256*4 + 128*128*128*4 + 128*256*4
# which overflows -- the sweep harness lowers the block to 32 for such
# corner configs via `block_for`).
DEFAULT_BLOCK_N = 128

# Soft VMEM budget used by `block_for` (bytes). Real TPUs have ~16 MiB;
# we keep kernels under half of it to leave room for double-buffering.
VMEM_BUDGET = 8 * 1024 * 1024


def block_for(d, K, D, budget=VMEM_BUDGET):
    """Pick a token-block size whose VMEM footprint fits the budget.

    Footprint per block row: q (d f32) + scores (D*K f32) + out (d f32).
    Resident key/value: K*d f32 each.
    """
    resident = 2 * K * d * 4
    per_row = (2 * d + D * K) * 4
    bn = max(8, (budget - resident) // max(per_row, 1))
    # round down to a power of two, capped at DEFAULT_BLOCK_N
    b = 8
    while b * 2 <= min(bn, DEFAULT_BLOCK_N):
        b *= 2
    return b


def pad_rows(x, block_n):
    """Pad axis 0 up to a multiple of block_n. Returns (padded, orig_n)."""
    n = x.shape[0]
    rem = (-n) % block_n
    if rem == 0:
        return x, n
    pad = [(0, rem)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, pad), n


def unpad_rows(x, n):
    return x[:n]


def cdiv(a, b):
    return (a + b - 1) // b
