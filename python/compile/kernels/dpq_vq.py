"""Pallas kernel: DPQ-VQ negative squared Euclidean scores (Eq. 6).

scores[n, j, k] = -||Q_n^(j) - K_k^(j)||^2, expanded as
-(||q||^2 - 2 q.k + ||k||^2) so the bulk of the work is the same MXU
contraction as DPQ-SX plus two cheap squared-norm reductions. Token axis
tiled into VMEM blocks; keys resident.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import pallas_util as pu


def _vq_scores_kernel(q_ref, key_ref, out_ref):
    """q_ref: [bn, D, s]; key_ref: [K, D, s]; out_ref: [bn, D, K]."""
    q = q_ref[...]
    k = key_ref[...]
    qk = jax.lax.dot_general(
        jnp.swapaxes(q, 0, 1),            # [D, bn, s]
        jnp.transpose(k, (1, 2, 0)),      # [D, s, K]
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    ).transpose(1, 0, 2)                   # [bn, D, K]
    qsq = jnp.sum(q * q, axis=-1)[:, :, None]            # [bn, D, 1]
    ksq = jnp.sum(k * k, axis=-1).T[None, :, :]          # [1, D, K]
    out_ref[...] = 2.0 * qk - qsq - ksq


def vq_scores(q3, key3, block_n=None):
    """q3: [N, D, s], key3: [K, D, s] -> [N, D, K] = -squared distances."""
    N, D, s = q3.shape
    K = key3.shape[0]
    if block_n is None:
        block_n = pu.block_for(D * s, K, D)
    q3, n_orig = pu.pad_rows(q3, block_n)
    grid = (q3.shape[0] // block_n,)
    out = pl.pallas_call(
        _vq_scores_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, D, s), lambda i: (i, 0, 0)),
            pl.BlockSpec((K, D, s), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, D, K), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((q3.shape[0], D, K), jnp.float32),
        interpret=True,
    )(q3, key3)
    return pu.unpad_rows(out, n_orig)
