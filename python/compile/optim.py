"""Functional optimizers compiled *into* the train-step artifacts.

The Rust coordinator owns the optimizer state as opaque named literals; the
update rule itself lives inside the lowered XLA graph, so Python never runs
at training time. Two rules cover the paper's setups:

  - sgd   : plain SGD + global-norm gradient clipping (Zaremba et al. LM).
  - adam  : Adam with bias correction (Transformer/BERT-style tasks; the
            paper's SM3 is substituted with Adam, see DESIGN.md).
"""

import jax
import jax.numpy as jnp


def clip_by_global_norm(grads: dict, max_norm: float) -> dict:
    total = jnp.sqrt(sum(jnp.sum(g * g) for g in grads.values()))
    scale = jnp.minimum(1.0, max_norm / (total + 1e-8))
    return {k: g * scale for k, g in grads.items()}


class Sgd:
    name = "sgd"

    def __init__(self, clip=5.0):
        self.clip = clip

    def init_state(self, params: dict) -> dict:
        return {}

    def apply(self, params, grads, state, lr):
        if self.clip is not None:
            grads = clip_by_global_norm(grads, self.clip)
        new_params = {k: p - lr * grads[k] for k, p in params.items()}
        return new_params, {}


class Adam:
    name = "adam"

    def __init__(self, b1=0.9, b2=0.999, eps=1e-8, clip=None):
        self.b1, self.b2, self.eps, self.clip = b1, b2, eps, clip

    def init_state(self, params: dict) -> dict:
        st = {"opt/t": jnp.zeros((), jnp.float32)}
        for k, p in params.items():
            st[f"opt/m/{k}"] = jnp.zeros_like(p)
            st[f"opt/v/{k}"] = jnp.zeros_like(p)
        return st

    def apply(self, params, grads, state, lr):
        if self.clip is not None:
            grads = clip_by_global_norm(grads, self.clip)
        t = state["opt/t"] + 1.0
        new_state = {"opt/t": t}
        new_params = {}
        bc1 = 1.0 - self.b1 ** t
        bc2 = 1.0 - self.b2 ** t
        for k, p in params.items():
            g = grads[k]
            m = self.b1 * state[f"opt/m/{k}"] + (1.0 - self.b1) * g
            v = self.b2 * state[f"opt/v/{k}"] + (1.0 - self.b2) * (g * g)
            mhat = m / bc1
            vhat = v / bc2
            new_params[k] = p - lr * mhat / (jnp.sqrt(vhat) + self.eps)
            new_state[f"opt/m/{k}"] = m
            new_state[f"opt/v/{k}"] = v
        return new_params, new_state


def get(name: str):
    if name == "sgd":
        return Sgd()
    if name == "adam":
        return Adam()
    raise ValueError(name)
