"""Embedding-layer variants (L2), all sharing one functional interface:

    embed(params: dict, ids: int32[...], cfg) -> (vectors [..., d], reg_loss)

Variants:
  - FullEmbedding      : the uncompressed baseline table.
  - DPQ-SX (Eq. 3-5)   : softmax relaxation; forward emits the *hard*
                         quantization (computed by the Pallas kernels),
                         gradient flows through the tau=1 soft path.
  - DPQ-VQ (Eq. 6-7)   : straight-through centroids with tied K=V and the
                         VQ-VAE-style regularizer (Sec. 2.3).
  - LowRankEmbedding   : E = A B end-to-end trained factorization baseline.
  - Chen18Embedding    : learned KD codes as free logits + MLP composition
                         (Chen et al. 2018b baseline of Table 4).

The Pallas score kernels are wrapped in jax.custom_vjp: forward runs the
kernel, backward applies the analytic gradients of the dot / -L2 scores.
This keeps the kernels usable in the differentiable soft path too.
"""

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.dpq_sx import sx_scores as _sx_scores_pallas
from .kernels.dpq_vq import vq_scores as _vq_scores_pallas
from .kernels.reconstruct import select_gather as _select_gather_pallas


@dataclass(frozen=True)
class EmbedCfg:
    """Configuration of the embedding layer under compression."""
    variant: str            # full | sx | vq | lowrank | chen18
    vocab: int
    d: int
    K: int = 32             # centroids per subspace
    D: int = 32             # number of subspaces (code length)
    share: bool = False     # subspace-sharing (Sec. 2.4)
    dist_bn: bool = True    # distance batch-norm (Sec. 2.4)
    tau: float = 1.0        # softmax temperature of the backward path
    rank: int = 8           # low-rank baseline rank
    chen_hidden: int = 64   # Chen'18 MLP hidden width
    beta: float = 0.25      # VQ commitment coefficient (VQ-VAE style)

    @property
    def sub(self):
        assert self.d % self.D == 0
        return self.d // self.D

    def bits(self) -> float:
        """Inference-time storage in bits (Sec. 3 'CR' accounting)."""
        import math
        n, d, K, D = self.vocab, self.d, self.K, self.D
        if self.variant == "full":
            return 32.0 * n * d
        if self.variant in ("sx", "vq"):
            value_bits = 32.0 * K * d / (D if self.share else 1)
            return n * D * math.log2(K) + value_bits
        if self.variant == "lowrank":
            return 32.0 * (n * self.rank + self.rank * d)
        if self.variant == "chen18":
            # codes + code-embedding table + MLP composition parameters
            h = self.chen_hidden
            return (n * D * math.log2(K)
                    + 32.0 * K * D * self.sub
                    + 32.0 * (D * self.sub * h + h + h * d + d))
        raise ValueError(self.variant)

    def compression_ratio(self) -> float:
        return (32.0 * self.vocab * self.d) / self.bits()


# ---------------------------------------------------------------------------
# Pallas score kernels with analytic VJPs
# ---------------------------------------------------------------------------

@jax.custom_vjp
def sx_scores(q3, key3):
    return _sx_scores_pallas(q3, key3)


def _sx_scores_fwd(q3, key3):
    return _sx_scores_pallas(q3, key3), (q3, key3)


def _sx_scores_bwd(res, g):
    q3, key3 = res
    dq = jnp.einsum("ndk,kds->nds", g, key3)
    dkey = jnp.einsum("ndk,nds->kds", g, q3)
    return dq, dkey


sx_scores.defvjp(_sx_scores_fwd, _sx_scores_bwd)


@jax.custom_vjp
def vq_scores(q3, key3):
    return _vq_scores_pallas(q3, key3)


def _vq_scores_fwd(q3, key3):
    return _vq_scores_pallas(q3, key3), (q3, key3)


def _vq_scores_bwd(res, g):
    # s_ndk = -(||q_nd||^2 - 2 q_nd.k_kd + ||k_kd||^2)
    # ds/dq_nds = -2 (q_nds - k_kds);  ds/dk_kds = 2 (q_nds - k_kds)
    q3, key3 = res
    gsum_n = jnp.sum(g, axis=-1)                          # [N, D]
    dq = -2.0 * (q3 * gsum_n[:, :, None]
                 - jnp.einsum("ndk,kds->nds", g, key3))
    gsum_k = jnp.sum(g, axis=0).T                         # [K, D]
    dkey = 2.0 * (jnp.einsum("ndk,nds->kds", g, q3)
                  - key3 * gsum_k[:, :, None])
    return dq, dkey


vq_scores.defvjp(_vq_scores_fwd, _vq_scores_bwd)


def hard_select(scores, value3):
    """Non-differentiable hard path (Pallas): argmax + gather.

    Inputs are stop-gradient'ed so autodiff never tries to linearize the
    pallas_call -- this branch only ever feeds the forward value (Eq. 5/7).
    """
    h, codes = _select_gather_pallas(
        jax.lax.stop_gradient(scores), jax.lax.stop_gradient(value3))
    return h, codes


# ---------------------------------------------------------------------------
# Parameter initialization
# ---------------------------------------------------------------------------

def init_params(rng, cfg: EmbedCfg):
    """Returns an ordered dict name -> array for the chosen variant."""
    n, d, K, D, s = cfg.vocab, cfg.d, cfg.K, cfg.D, cfg.sub
    Dk = 1 if cfg.share else D
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    ps = {}
    if cfg.variant == "full":
        ps["emb/table"] = jax.random.uniform(rng, (n, d), jnp.float32, -0.1, 0.1)
    elif cfg.variant == "sx":
        r1, r2, r3 = jax.random.split(rng, 3)
        ps["emb/q"] = jax.random.uniform(r1, (n, d), jnp.float32, -0.1, 0.1)
        ps["emb/key"] = jax.random.normal(r2, (K, Dk, s), jnp.float32) * scale
        ps["emb/value"] = jax.random.normal(r3, (K, Dk, s), jnp.float32) * scale
    elif cfg.variant == "vq":
        r1, r2 = jax.random.split(rng)
        ps["emb/q"] = jax.random.uniform(r1, (n, d), jnp.float32, -0.1, 0.1)
        # tied key = value ("centroids"); init from the same range as q so
        # initial assignments are balanced.
        ps["emb/kv"] = jax.random.uniform(r2, (K, Dk, s), jnp.float32, -0.1, 0.1)
    elif cfg.variant == "lowrank":
        r1, r2 = jax.random.split(rng)
        ps["emb/a"] = jax.random.normal(r1, (n, cfg.rank), jnp.float32) * 0.1
        ps["emb/b"] = jax.random.normal(r2, (cfg.rank, d), jnp.float32) * scale
    elif cfg.variant == "chen18":
        r1, r2, r3, r4 = jax.random.split(rng, 4)
        h = cfg.chen_hidden
        ps["emb/logits"] = jax.random.normal(r1, (n, D, K), jnp.float32) * 0.1
        ps["emb/codeemb"] = jax.random.normal(r2, (K, D, s), jnp.float32) * scale
        ps["emb/w1"] = jax.random.normal(r3, (D * s, h), jnp.float32) / jnp.sqrt(float(D * s))
        ps["emb/b1"] = jnp.zeros((h,), jnp.float32)
        ps["emb/w2"] = jax.random.normal(r4, (h, d), jnp.float32) / jnp.sqrt(float(h))
        ps["emb/b2"] = jnp.zeros((d,), jnp.float32)
    else:
        raise ValueError(cfg.variant)
    return ps


def _expand_key(k, cfg: EmbedCfg):
    """[K, 1, s] -> [K, D, s] when subspace-sharing is on."""
    if cfg.share:
        return jnp.broadcast_to(k, (cfg.K, cfg.D, cfg.sub))
    return k


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def _dpq_rows_sx(q_rows, params, cfg: EmbedCfg):
    """DPQ-SX over a set of query rows [N, d] -> ([N, d], reg=0)."""
    q3 = ref.split_subspaces(q_rows, cfg.D)
    key3 = _expand_key(params["emb/key"], cfg)
    value3 = _expand_key(params["emb/value"], cfg)
    scores = sx_scores(q3, key3)
    if cfg.dist_bn:
        scores = ref.dist_bn_ref(scores)
    # tau=1 soft path (differentiable)
    soft = jax.nn.softmax(scores / cfg.tau, axis=-1)      # [N, D, K]
    h_soft = jnp.einsum("ndk,kds->nds", soft, value3).reshape(q_rows.shape)
    # tau=0 hard path (Pallas, inside stop_gradient)
    h_hard, _ = hard_select(scores, value3)
    # Eq. 5: forward = hard, backward = soft
    h = h_soft + jax.lax.stop_gradient(h_hard - h_soft)
    return h, jnp.zeros((), jnp.float32)


def _dpq_rows_vq(q_rows, params, cfg: EmbedCfg):
    """DPQ-VQ over query rows [N, d] -> ([N, d], reg loss)."""
    q3 = ref.split_subspaces(q_rows, cfg.D)
    kv3 = _expand_key(params["emb/kv"], cfg)
    scores = vq_scores(q3, jax.lax.stop_gradient(kv3))
    if cfg.dist_bn:
        scores = ref.dist_bn_ref(scores)
    codes = jax.lax.stop_gradient(ref.codes_ref(scores))  # [N, D]
    # differentiable-in-V gather (indexing is linear in V)
    cols = jnp.arange(cfg.D)[None, :]
    quant = kv3[codes, cols].reshape(q_rows.shape)        # T(Q), [N, d]
    # Eq. 7: forward = centroid, gradient passes straight through to Q.
    h = q_rows - jax.lax.stop_gradient(q_rows - quant)
    # Sec 2.3 regularizer: pulls centroids to the mean of their members,
    # plus a VQ-VAE commitment term pulling Q toward its centroid.
    reg = (jnp.mean(jnp.sum((quant - jax.lax.stop_gradient(q_rows)) ** 2, -1))
           + cfg.beta * jnp.mean(jnp.sum(
               (jax.lax.stop_gradient(quant) - q_rows) ** 2, -1)))
    return h, reg


def chen18_compose(onehot, params, cfg: EmbedCfg):
    """MLP composition of (soft) one-hot codes (Chen'18 / Shu'17 style).

    onehot: [N, D, K] -> [N, d]
    """
    code3 = jnp.einsum("ndk,kds->nds", onehot, params["emb/codeemb"])
    flat = code3.reshape(code3.shape[0], -1)              # [N, D*s]
    hsz = jnp.tanh(flat @ params["emb/w1"] + params["emb/b1"])
    return hsz @ params["emb/w2"] + params["emb/b2"]


def _chen18_rows(q_ids_rows_unused, params, cfg: EmbedCfg, ids):
    """Chen'18: free code logits per symbol + MLP composition."""
    logits = params["emb/logits"][ids]                    # [N, D, K]
    soft = jax.nn.softmax(logits / cfg.tau, axis=-1)
    hard = jax.nn.one_hot(jnp.argmax(logits, -1), cfg.K, dtype=jnp.float32)
    onehot = soft + jax.lax.stop_gradient(hard - soft)    # ST-softmax
    out = chen18_compose(onehot, params, cfg)
    return out, jnp.zeros((), jnp.float32)


def embed(params, ids, cfg: EmbedCfg):
    """Look up (and, for DPQ, quantize) embeddings for integer ids.

    ids: int32[...]; returns (vectors [..., d], reg_loss scalar).
    DPQ is applied to the *accessed* rows only -- the quantization of a row
    depends only on that row and the shared key/value matrices, so this is
    exactly the paper's computation restricted to the batch (the distance
    batch-norm then normalizes over batch tokens, which is the natural
    reading of 'over batch samples' in Sec. 2.4).
    """
    flat = ids.reshape(-1)
    if cfg.variant == "full":
        out = params["emb/table"][flat]
        reg = jnp.zeros((), jnp.float32)
    elif cfg.variant == "sx":
        out, reg = _dpq_rows_sx(params["emb/q"][flat], params, cfg)
    elif cfg.variant == "vq":
        out, reg = _dpq_rows_vq(params["emb/q"][flat], params, cfg)
    elif cfg.variant == "lowrank":
        out = params["emb/a"][flat] @ params["emb/b"]
        reg = jnp.zeros((), jnp.float32)
    elif cfg.variant == "chen18":
        out, reg = _chen18_rows(None, params, cfg, flat)
    else:
        raise ValueError(cfg.variant)
    return out.reshape(ids.shape + (cfg.d,)), reg


# ---------------------------------------------------------------------------
# Whole-vocabulary operations (code extraction / table reconstruction)
# ---------------------------------------------------------------------------

def extract_codes(params, cfg: EmbedCfg):
    """Quantize the entire query matrix -> codebook C int32 [n, D].

    Distance BN statistics are computed over the full vocabulary here;
    training used per-batch statistics (see `embed`).
    """
    q3 = ref.split_subspaces(_query_matrix(params, cfg), cfg.D)
    key3 = _expand_key(params["emb/key"] if cfg.variant == "sx"
                       else params["emb/kv"], cfg)
    scores = (sx_scores if cfg.variant == "sx" else vq_scores)(q3, key3)
    if cfg.dist_bn:
        scores = ref.dist_bn_ref(scores)
    value3 = _expand_key(params["emb/value"] if cfg.variant == "sx"
                         else params["emb/kv"], cfg)
    _, codes = hard_select(scores, value3)
    return codes


def _query_matrix(params, cfg: EmbedCfg):
    return params["emb/q"]


def value_matrix(params, cfg: EmbedCfg):
    """The [K, D, s] value matrix kept at inference."""
    if cfg.variant == "sx":
        return _expand_key(params["emb/value"], cfg)
    if cfg.variant == "vq":
        return _expand_key(params["emb/kv"], cfg)
    raise ValueError(cfg.variant)


def reconstruct_table(params, cfg: EmbedCfg):
    """Full embedding table as seen at inference time.

    full:    the table itself;  lowrank: A @ B;
    sx/vq:   gather_codes(extract_codes(Q), V)  (Algorithm 1).
    """
    if cfg.variant == "full":
        return params["emb/table"]
    if cfg.variant == "lowrank":
        return params["emb/a"] @ params["emb/b"]
    if cfg.variant in ("sx", "vq"):
        from .kernels.reconstruct import gather_codes
        codes = extract_codes(params, cfg)
        return gather_codes(codes, value_matrix(params, cfg))
    if cfg.variant == "chen18":
        n = cfg.vocab
        ids = jnp.arange(n, dtype=jnp.int32)
        out, _ = _chen18_rows(None, params, cfg, ids)
        return out
    raise ValueError(cfg.variant)
