"""LSTM language model (L2), the Zaremba et al. (2014) base model of the
paper's LM experiments, with the input embedding layer swappable for any
variant in layers.py. The softmax/output table stays uncompressed, matching
Sec. 3: "we focus on the embedding table in the encoder side".
"""

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .. import layers


@dataclass(frozen=True)
class LmCfg:
    emb: layers.EmbedCfg
    hidden: int
    batch: int
    seq: int
    reg_weight: float = 1.0   # weight on the DPQ-VQ regularizer


def init(rng, cfg: LmCfg):
    d, h, v = cfg.emb.d, cfg.hidden, cfg.emb.vocab
    r_emb, r1, r2, r3 = jax.random.split(rng, 4)
    ps = layers.init_params(r_emb, cfg.emb)
    sd = 1.0 / jnp.sqrt(jnp.asarray(h, jnp.float32))
    ps["lstm/wx"] = jax.random.normal(r1, (d, 4 * h), jnp.float32) * (1.0 / jnp.sqrt(float(d)))
    ps["lstm/wh"] = jax.random.normal(r2, (h, 4 * h), jnp.float32) * sd
    ps["lstm/b"] = jnp.zeros((4 * h,), jnp.float32)
    ps["out/w"] = jax.random.normal(r3, (h, v), jnp.float32) * sd
    ps["out/b"] = jnp.zeros((v,), jnp.float32)
    return ps


def _lstm_scan(params, emb, h0, c0):
    """emb: [B, T, d] -> hidden states [B, T, h]."""
    wx, wh, b = params["lstm/wx"], params["lstm/wh"], params["lstm/b"]
    hsz = wh.shape[0]

    def step(carry, x_t):
        h, c = carry
        z = x_t @ wx + h @ wh + b
        i, f, g, o = jnp.split(z, 4, axis=-1)
        c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (h, c), h

    xs = jnp.swapaxes(emb, 0, 1)                      # [T, B, d]
    (_, _), hs = jax.lax.scan(step, (h0, c0), xs)
    return jnp.swapaxes(hs, 0, 1)                      # [B, T, h]


def loss_fn(params, x, y, cfg: LmCfg):
    """x, y: int32 [B, T]. Returns (total_loss, ce_loss)."""
    emb, reg = layers.embed(params, x, cfg.emb)
    B = x.shape[0]
    h0 = jnp.zeros((B, cfg.hidden), jnp.float32)
    hs = _lstm_scan(params, emb, h0, h0)
    logits = hs @ params["out/w"] + params["out/b"]    # [B, T, V]
    logp = jax.nn.log_softmax(logits, axis=-1)
    ce = -jnp.mean(jnp.take_along_axis(logp, y[..., None], axis=-1))
    return ce + cfg.reg_weight * reg, ce
