"""Tiny BERT-style masked-LM (L2) for the Table 7 experiment: a small
transformer encoder whose input embedding is swappable for DPQ. Masking is
applied by the Rust coordinator (it supplies masked input ids, original
target ids and a mask-weight matrix); the graph only computes the weighted
MLM cross-entropy. A classification probe head (`ft_*`) reuses the encoder
for the fine-tuning half of Table 7.
"""

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .. import layers


@dataclass(frozen=True)
class BertCfg:
    emb: layers.EmbedCfg
    layers_n: int
    heads: int
    ff: int
    batch: int
    seq: int
    classes: int = 2            # probe task
    reg_weight: float = 1.0


def init(rng, cfg: BertCfg):
    d = cfg.emb.d
    rs = jax.random.split(rng, 4 + 6 * cfg.layers_n)
    ps = layers.init_params(rs[0], cfg.emb)
    ps["pos/table"] = jax.random.normal(rs[1], (cfg.seq, d), jnp.float32) * 0.02
    sd = 0.02
    for l in range(cfg.layers_n):
        r = rs[4 + 6 * l: 4 + 6 * (l + 1)]
        ps[f"l{l}/wqkv"] = jax.random.normal(r[0], (d, 3 * d), jnp.float32) * sd
        ps[f"l{l}/wo"] = jax.random.normal(r[1], (d, d), jnp.float32) * sd
        ps[f"l{l}/ff1"] = jax.random.normal(r[2], (d, cfg.ff), jnp.float32) * sd
        ps[f"l{l}/ff1b"] = jnp.zeros((cfg.ff,), jnp.float32)
        ps[f"l{l}/ff2"] = jax.random.normal(r[3], (cfg.ff, d), jnp.float32) * sd
        ps[f"l{l}/ff2b"] = jnp.zeros((d,), jnp.float32)
        ps[f"l{l}/ln1g"] = jnp.ones((d,), jnp.float32)
        ps[f"l{l}/ln1b"] = jnp.zeros((d,), jnp.float32)
        ps[f"l{l}/ln2g"] = jnp.ones((d,), jnp.float32)
        ps[f"l{l}/ln2b"] = jnp.zeros((d,), jnp.float32)
    ps["mlm/w"] = jax.random.normal(rs[2], (d, cfg.emb.vocab), jnp.float32) * sd
    ps["mlm/b"] = jnp.zeros((cfg.emb.vocab,), jnp.float32)
    ps["cls/w"] = jax.random.normal(rs[3], (d, cfg.classes), jnp.float32) * sd
    ps["cls/b"] = jnp.zeros((cfg.classes,), jnp.float32)
    return ps


def _layer_norm(x, g, b, eps=1e-6):
    mu = jnp.mean(x, -1, keepdims=True)
    var = jnp.var(x, -1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _encoder(params, x, cfg: BertCfg):
    """x int32 [B, T] -> hidden [B, T, d]; also returns DPQ reg loss."""
    emb, reg = layers.embed(params, x, cfg.emb)
    h = emb + params["pos/table"][None, :, :]
    B, T, d = h.shape
    hd = d // cfg.heads
    mask = (x != 0)[:, None, None, :]                   # [B,1,1,T]
    for l in range(cfg.layers_n):
        qkv = h @ params[f"l{l}/wqkv"]                  # [B,T,3d]
        q, k, v = jnp.split(qkv, 3, axis=-1)

        def heads(t):
            return t.reshape(B, T, cfg.heads, hd).transpose(0, 2, 1, 3)

        q, k, v = heads(q), heads(k), heads(v)
        att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(hd))
        att = jnp.where(mask, att, -1e9)
        att = jax.nn.softmax(att, -1)
        ctx = jnp.einsum("bhqk,bhkd->bhqd", att, v)
        ctx = ctx.transpose(0, 2, 1, 3).reshape(B, T, d)
        h = _layer_norm(h + ctx @ params[f"l{l}/wo"],
                        params[f"l{l}/ln1g"], params[f"l{l}/ln1b"])
        ffo = jax.nn.gelu(h @ params[f"l{l}/ff1"] + params[f"l{l}/ff1b"])
        ffo = ffo @ params[f"l{l}/ff2"] + params[f"l{l}/ff2b"]
        h = _layer_norm(h + ffo, params[f"l{l}/ln2g"], params[f"l{l}/ln2b"])
    return h, reg


def mlm_loss(params, x, y, w, cfg: BertCfg):
    """Masked-LM loss. x = masked ids, y = original ids, w = mask weights."""
    h, reg = _encoder(params, x, cfg)
    logits = h @ params["mlm/w"] + params["mlm/b"]
    logp = jax.nn.log_softmax(logits, -1)
    tok = jnp.take_along_axis(logp, y[..., None], -1)[..., 0]
    wf = w.astype(jnp.float32)
    ce = -jnp.sum(tok * wf) / (jnp.sum(wf) + 1e-6)
    return ce + cfg.reg_weight * reg, ce


def cls_loss(params, x, y, cfg: BertCfg):
    """Fine-tuning probe: first-token pooling + linear head. y int32 [B]."""
    h, reg = _encoder(params, x, cfg)
    pooled = h[:, 0, :]
    logits = pooled @ params["cls/w"] + params["cls/b"]
    logp = jax.nn.log_softmax(logits, -1)
    ce = -jnp.mean(jnp.take_along_axis(logp, y[:, None], -1))
    acc = jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))
    return ce + cfg.reg_weight * reg, ce, acc
