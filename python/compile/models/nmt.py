"""Seq2seq NMT model (L2): GRU encoder + GRU decoder with dot-product
attention (Luong et al. 2017 style). The *source/encoder* embedding is the
compressed variant, the decoder embedding and output softmax stay full,
matching the paper's Sec. 3 setup.

Conventions: id 0 = PAD, id 1 = BOS, id 2 = EOS.
"""

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .. import layers

PAD, BOS, EOS = 0, 1, 2


@dataclass(frozen=True)
class NmtCfg:
    emb: layers.EmbedCfg        # source-side (compressed)
    tgt_vocab: int
    hidden: int
    batch: int
    src_len: int
    tgt_len: int
    reg_weight: float = 1.0


def _gru_params(rng, din, h, prefix):
    r1, r2 = jax.random.split(rng)
    si = 1.0 / jnp.sqrt(jnp.asarray(din, jnp.float32))
    sh = 1.0 / jnp.sqrt(jnp.asarray(h, jnp.float32))
    return {
        f"{prefix}/wx": jax.random.normal(r1, (din, 3 * h), jnp.float32) * si,
        f"{prefix}/wh": jax.random.normal(r2, (h, 3 * h), jnp.float32) * sh,
        f"{prefix}/b": jnp.zeros((3 * h,), jnp.float32),
    }


def init(rng, cfg: NmtCfg):
    d, h = cfg.emb.d, cfg.hidden
    r_emb, r_enc, r_dec, r_demb, r_att, r_out = jax.random.split(rng, 6)
    ps = layers.init_params(r_emb, cfg.emb)
    ps.update(_gru_params(r_enc, d, h, "enc"))
    ps.update(_gru_params(r_dec, d, h, "dec"))
    ps["dec/emb"] = jax.random.uniform(r_demb, (cfg.tgt_vocab, d), jnp.float32, -0.1, 0.1)
    ps["att/w"] = jax.random.normal(r_att, (2 * h, h), jnp.float32) / jnp.sqrt(float(2 * h))
    ps["out/w"] = jax.random.normal(r_out, (h, cfg.tgt_vocab), jnp.float32) / jnp.sqrt(float(h))
    ps["out/b"] = jnp.zeros((cfg.tgt_vocab,), jnp.float32)
    return ps


def _gru_step(params, prefix, x_t, hprev):
    wx, wh, b = (params[f"{prefix}/wx"], params[f"{prefix}/wh"], params[f"{prefix}/b"])
    z = x_t @ wx + hprev @ wh + b
    hsz = wh.shape[0]
    r, u, n = z[..., :hsz], z[..., hsz:2 * hsz], z[..., 2 * hsz:]
    r = jax.nn.sigmoid(r)
    u = jax.nn.sigmoid(u)
    # standard GRU candidate: tanh(x Wxn + b_n + r * (h Whn)). The z slice
    # already contains h Whn once, so add (r - 1) * (h Whn) to gate it.
    n = jnp.tanh(n + (r - 1.0) * (hprev @ wh[:, 2 * hsz:]))
    return (1.0 - u) * n + u * hprev


def _encode(params, src, cfg: NmtCfg):
    emb, reg = layers.embed(params, src, cfg.emb)       # [B, Ts, d]
    B = src.shape[0]
    h0 = jnp.zeros((B, cfg.hidden), jnp.float32)

    def step(h, x_t):
        h = _gru_step(params, "enc", x_t, h)
        return h, h

    xs = jnp.swapaxes(emb, 0, 1)
    hT, hs = jax.lax.scan(step, h0, xs)
    states = jnp.swapaxes(hs, 0, 1)                     # [B, Ts, h]
    mask = (src != PAD).astype(jnp.float32)             # [B, Ts]
    return states, mask, hT, reg


def _attend(params, dec_h, enc_states, enc_mask):
    """Luong dot attention. dec_h [B,h]; enc_states [B,Ts,h] -> [B,h]."""
    scores = jnp.einsum("bh,bth->bt", dec_h, enc_states)
    scores = jnp.where(enc_mask > 0, scores, -1e9)
    alpha = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bt,bth->bh", alpha, enc_states)
    att = jnp.tanh(jnp.concatenate([ctx, dec_h], -1) @ params["att/w"])
    return att


def loss_fn(params, src, tgt_in, tgt_out, cfg: NmtCfg):
    """Teacher-forced CE. src [B,Ts]; tgt_in/tgt_out [B,Tt]."""
    enc_states, enc_mask, hT, reg = _encode(params, src, cfg)
    demb = params["dec/emb"][tgt_in]                    # [B, Tt, d]

    def step(h, x_t):
        h = _gru_step(params, "dec", x_t, h)
        att = _attend(params, h, enc_states, enc_mask)
        return h, att

    xs = jnp.swapaxes(demb, 0, 1)
    _, atts = jax.lax.scan(step, hT, xs)
    atts = jnp.swapaxes(atts, 0, 1)                     # [B, Tt, h]
    logits = atts @ params["out/w"] + params["out/b"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    tok_ll = jnp.take_along_axis(logp, tgt_out[..., None], axis=-1)[..., 0]
    tmask = (tgt_out != PAD).astype(jnp.float32)
    ce = -jnp.sum(tok_ll * tmask) / (jnp.sum(tmask) + 1e-6)
    return ce + cfg.reg_weight * reg, ce


def greedy_decode(params, src, cfg: NmtCfg):
    """Greedy decoding for BLEU eval. src [B,Ts] -> hyp int32 [B,Tt]."""
    enc_states, enc_mask, hT, _ = _encode(params, src, cfg)
    B = src.shape[0]

    def step(carry, _):
        h, tok = carry
        x_t = params["dec/emb"][tok]                    # [B, d]
        h = _gru_step(params, "dec", x_t, h)
        att = _attend(params, h, enc_states, enc_mask)
        logits = att @ params["out/w"] + params["out/b"]
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        return (h, nxt), nxt

    tok0 = jnp.full((B,), BOS, jnp.int32)
    _, toks = jax.lax.scan(step, (hT, tok0), None, length=cfg.tgt_len)
    return jnp.swapaxes(toks, 0, 1)                     # [B, Tt]
