"""fastText-style text classifier (Joulin et al. 2017 / paper Table 2):
mean pooling of word vectors + one hidden layer. The pooled embedding layer
is the compressed one.
"""

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .. import layers


@dataclass(frozen=True)
class TextCfg:
    emb: layers.EmbedCfg
    hidden: int
    classes: int
    batch: int
    seq: int
    reg_weight: float = 1.0


def init(rng, cfg: TextCfg):
    r_emb, r1, r2 = jax.random.split(rng, 3)
    ps = layers.init_params(r_emb, cfg.emb)
    d, h = cfg.emb.d, cfg.hidden
    ps["mlp/w1"] = jax.random.normal(r1, (d, h), jnp.float32) / jnp.sqrt(float(d))
    ps["mlp/b1"] = jnp.zeros((h,), jnp.float32)
    ps["mlp/w2"] = jax.random.normal(r2, (h, cfg.classes), jnp.float32) / jnp.sqrt(float(h))
    ps["mlp/b2"] = jnp.zeros((cfg.classes,), jnp.float32)
    return ps


def loss_fn(params, x, y, cfg: TextCfg):
    """x: int32 [B, T] (0 = pad), y: int32 [B]. -> (total, ce, accuracy)."""
    emb, reg = layers.embed(params, x, cfg.emb)        # [B, T, d]
    mask = (x != 0).astype(jnp.float32)[..., None]     # pad id 0
    pooled = jnp.sum(emb * mask, axis=1) / (jnp.sum(mask, axis=1) + 1e-6)
    hid = jnp.tanh(pooled @ params["mlp/w1"] + params["mlp/b1"])
    logits = hid @ params["mlp/w2"] + params["mlp/b2"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    ce = -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=-1))
    acc = jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))
    return ce + cfg.reg_weight * reg, ce, acc
