//! Minimal offline shim for the subset of the `anyhow` crate this
//! workspace uses: [`Error`], [`Result`], the [`anyhow!`] / [`bail!`]
//! macros, and the [`Context`] extension trait. Error values carry a
//! flattened message chain (context strings prepended `": "`-joined),
//! which is what the CLI prints anyway. Mirrors anyhow's coherence trick:
//! `Error` deliberately does NOT implement `std::error::Error`, so the
//! blanket `From<E: std::error::Error>` impl and the `Context` impls do
//! not overlap with the concrete `Error` impls.

use std::fmt;

/// Flattened error: message with any context chain already prepended.
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { msg: m.to_string() }
    }

    /// Prepend a context layer (outermost first, anyhow-style).
    pub fn context(self, c: impl fmt::Display) -> Error {
        Error { msg: format!("{c}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `{e:#}` (alternate) prints the full chain in real anyhow; the
        // shim's message is already the full chain.
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // include one level of source, the common case for io errors
        match e.source() {
            Some(src) => Error { msg: format!("{e}: {src}") },
            None => Error::msg(&e),
        }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Sealed conversion helper so `Context` applies both to results whose
/// error is a `std::error::Error` and to `anyhow::Result` itself.
mod private {
    pub trait IntoError {
        fn into_error(self) -> super::Error;
    }

    impl<E: std::error::Error + Send + Sync + 'static> IntoError for E {
        fn into_error(self) -> super::Error {
            super::Error::from(self)
        }
    }

    impl IntoError for super::Error {
        fn into_error(self) -> super::Error {
            self
        }
    }
}

/// `.context(..)` / `.with_context(..)` on fallible results.
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: private::IntoError> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error> {
        self.map_err(|e| e.into_error().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into_error().context(f()))
    }
}

/// Construct an [`Error`] from a format string (or any Display value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Early-return with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Early-return with an error when a condition fails (anyhow::ensure!).
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/path/ever")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn context_prepends() {
        let e = io_fail().context("loading config").unwrap_err();
        assert!(e.to_string().starts_with("loading config: "), "{e}");
    }

    #[test]
    fn with_context_and_chained() {
        let e = io_fail()
            .with_context(|| format!("step {}", 2))
            .context("outer")
            .unwrap_err();
        let s = e.to_string();
        assert!(s.starts_with("outer: step 2: "), "{s}");
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("x = {}", 42);
        assert_eq!(e.to_string(), "x = 42");
        let k = 7;
        let e = anyhow!("inline {k}");
        assert_eq!(e.to_string(), "inline 7");
        fn f() -> Result<()> {
            bail!("boom {}", 1)
        }
        assert_eq!(f().unwrap_err().to_string(), "boom 1");
        fn g() -> Result<()> {
            ensure!(1 + 1 == 3, "math broke");
            Ok(())
        }
        assert_eq!(g().unwrap_err().to_string(), "math broke");
    }
}
