//! Offline shim of the `xla` (xla-rs) API surface used by this workspace.
//!
//! The host-side [`Literal`] type is fully functional (typed storage,
//! reshape, tuple decompose) so every unit test and the whole non-PJRT
//! runtime compiles and runs. The PJRT pieces ([`PjRtClient`],
//! [`PjRtLoadedExecutable`]) are present with the right signatures but
//! fail at `compile` time with a clear message -- executing real AOT
//! artifacts requires the actual PJRT-backed bindings, which the offline
//! container does not ship. Integration tests already skip when the
//! `artifacts/` directory is absent, so the stub keeps tier-1 green.

use std::fmt;

#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn err(msg: impl Into<String>) -> Error {
    Error(msg.into())
}

/// Element types representable in a [`Literal`].
#[derive(Clone, Debug, PartialEq)]
enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Host-side element types (subset of xla-rs `NativeType`).
pub trait NativeType: Copy + 'static {
    fn wrap(data: Vec<Self>) -> Data;
    fn unwrap(data: &Data) -> Option<&[Self]>;
    const DTYPE: &'static str;
}

impl NativeType for f32 {
    fn wrap(data: Vec<f32>) -> Data {
        Data::F32(data)
    }
    fn unwrap(data: &Data) -> Option<&[f32]> {
        match data {
            Data::F32(v) => Some(v),
            _ => None,
        }
    }
    const DTYPE: &'static str = "f32";
}

impl NativeType for i32 {
    fn wrap(data: Vec<i32>) -> Data {
        Data::I32(data)
    }
    fn unwrap(data: &Data) -> Option<&[i32]> {
        match data {
            Data::I32(v) => Some(v),
            _ => None,
        }
    }
    const DTYPE: &'static str = "i32";
}

/// Array (or tuple) of typed host data with a shape.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    dims: Vec<i64>,
    data: Data,
}

/// Shape of a non-tuple literal.
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { dims: vec![data.len() as i64], data: T::wrap(data.to_vec()) }
    }

    /// Reshape (element count must match; `&[]` makes a scalar).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        let have = self.element_count() as i64;
        if want != have {
            return Err(err(format!(
                "reshape {:?} -> {dims:?}: {have} elements != {want}",
                self.dims
            )));
        }
        Ok(Literal { dims: dims.to_vec(), data: self.data.clone() })
    }

    pub fn element_count(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::Tuple(t) => t.iter().map(|l| l.element_count()).sum(),
        }
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        match &self.data {
            Data::Tuple(_) => Err(err("array_shape on tuple literal")),
            _ => Ok(ArrayShape { dims: self.dims.clone() }),
        }
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data)
            .map(|s| s.to_vec())
            .ok_or_else(|| err(format!("literal is not {}", T::DTYPE)))
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        T::unwrap(&self.data)
            .and_then(|s| s.first().copied())
            .ok_or_else(|| err(format!("empty or non-{} literal", T::DTYPE)))
    }

    /// Build a tuple literal (what executables return).
    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal { dims: vec![], data: Data::Tuple(parts) }
    }

    /// Split a tuple literal into its parts.
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        match std::mem::replace(&mut self.data, Data::Tuple(Vec::new())) {
            Data::Tuple(parts) => Ok(parts),
            other => {
                self.data = other;
                Err(err("decompose_tuple on non-tuple literal"))
            }
        }
    }
}

const PJRT_UNAVAILABLE: &str =
    "PJRT backend unavailable: this build uses the offline xla shim \
     (vendor/xla). Artifact execution requires the real xla-rs bindings.";

/// Parsed HLO module (opaque; the shim only checks the file is readable).
pub struct HloModuleProto {
    _text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| err(format!("read {path}: {e}")))?;
        Ok(HloModuleProto { _text: text })
    }
}

pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Device buffer handle (never constructed by the shim).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(err(PJRT_UNAVAILABLE))
    }
}

pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(err(PJRT_UNAVAILABLE))
    }
}

pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _private: () })
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(err(PJRT_UNAVAILABLE))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vec1_reshape_roundtrip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.array_shape().unwrap().dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(r.element_count(), 4);
        assert!(l.reshape(&[3]).is_err());
    }

    #[test]
    fn scalar_reshape() {
        let l = Literal::vec1(&[7i32]).reshape(&[]).unwrap();
        assert!(l.array_shape().unwrap().dims().is_empty());
        assert_eq!(l.get_first_element::<i32>().unwrap(), 7);
        assert!(l.get_first_element::<f32>().is_err());
    }

    #[test]
    fn tuple_decompose() {
        let mut t = Literal::tuple(vec![
            Literal::vec1(&[1.0f32]),
            Literal::vec1(&[2i32, 3]),
        ]);
        assert_eq!(t.element_count(), 3);
        let parts = t.decompose_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[1].to_vec::<i32>().unwrap(), vec![2, 3]);
        let mut nt = Literal::vec1(&[1.0f32]);
        assert!(nt.decompose_tuple().is_err());
        assert_eq!(nt.to_vec::<f32>().unwrap(), vec![1.0]); // data restored
    }

    #[test]
    fn pjrt_is_stubbed_with_clear_error() {
        let c = PjRtClient::cpu().unwrap();
        let comp = XlaComputation::from_proto(&HloModuleProto {
            _text: String::new(),
        });
        let e = c.compile(&comp).unwrap_err();
        assert!(e.to_string().contains("offline xla shim"));
    }
}
