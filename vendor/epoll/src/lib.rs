//! Minimal vendored epoll + eventfd shim (Linux only).
//!
//! The serving plane needs exactly four kernel facilities to run an
//! event-driven readiness loop: `epoll_create1`, `epoll_ctl`,
//! `epoll_wait`, and `eventfd` (for cross-thread wakeups). Pulling in
//! an external crate for that would violate the repo's offline vendor
//! discipline, so this crate declares the raw syscall wrappers itself.
//! `std` already links the platform libc on Linux, so plain
//! `extern "C"` declarations resolve without any build-time dependency.
//!
//! On non-Linux targets the crate compiles to an empty library; the
//! server falls back to its threaded connection plane there.

#![allow(non_camel_case_types)]

#[cfg(target_os = "linux")]
pub use linux::*;

#[cfg(target_os = "linux")]
mod linux {
    use std::io;
    use std::os::raw::{c_int, c_uint, c_void};
    use std::os::unix::io::RawFd;

    // Interest / readiness bits (uapi/linux/eventpoll.h).
    pub const EPOLLIN: u32 = 0x001;
    pub const EPOLLOUT: u32 = 0x004;
    pub const EPOLLERR: u32 = 0x008;
    pub const EPOLLHUP: u32 = 0x010;
    pub const EPOLLRDHUP: u32 = 0x2000;

    const EPOLL_CTL_ADD: c_int = 1;
    const EPOLL_CTL_DEL: c_int = 2;
    const EPOLL_CTL_MOD: c_int = 3;
    const EPOLL_CLOEXEC: c_int = 0o2000000;
    const EFD_CLOEXEC: c_int = 0o2000000;
    const EFD_NONBLOCK: c_int = 0o4000;

    /// Mirror of `struct epoll_event`. The kernel ABI packs this to
    /// 12 bytes on x86_64; other architectures use natural alignment.
    #[cfg_attr(target_arch = "x86_64", repr(C, packed))]
    #[cfg_attr(not(target_arch = "x86_64"), repr(C))]
    #[derive(Clone, Copy)]
    pub struct Event {
        pub events: u32,
        pub data: u64,
    }

    impl Event {
        pub const fn empty() -> Event {
            Event { events: 0, data: 0 }
        }
    }

    extern "C" {
        fn epoll_create1(flags: c_int) -> c_int;
        fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut Event) -> c_int;
        fn epoll_wait(epfd: c_int, events: *mut Event, maxevents: c_int, timeout: c_int) -> c_int;
        fn eventfd(initval: c_uint, flags: c_int) -> c_int;
        fn close(fd: c_int) -> c_int;
        fn read(fd: c_int, buf: *mut c_void, count: usize) -> isize;
        fn write(fd: c_int, buf: *const c_void, count: usize) -> isize;
    }

    fn cvt(ret: c_int) -> io::Result<c_int> {
        if ret < 0 {
            Err(io::Error::last_os_error())
        } else {
            Ok(ret)
        }
    }

    /// An epoll instance. One per poller thread; closed on drop.
    pub struct Epoll {
        fd: RawFd,
    }

    impl Epoll {
        pub fn new() -> io::Result<Epoll> {
            let fd = cvt(unsafe { epoll_create1(EPOLL_CLOEXEC) })?;
            Ok(Epoll { fd })
        }

        fn ctl(&self, op: c_int, fd: RawFd, events: u32, token: u64) -> io::Result<()> {
            let mut ev = Event { events, data: token };
            let evp = if op == EPOLL_CTL_DEL {
                std::ptr::null_mut()
            } else {
                &mut ev as *mut Event
            };
            cvt(unsafe { epoll_ctl(self.fd, op, fd, evp) }).map(|_| ())
        }

        /// Register `fd` for `events` (level-triggered), tagged with `token`.
        pub fn add(&self, fd: RawFd, events: u32, token: u64) -> io::Result<()> {
            self.ctl(EPOLL_CTL_ADD, fd, events, token)
        }

        /// Change the interest set for an already-registered `fd`.
        pub fn modify(&self, fd: RawFd, events: u32, token: u64) -> io::Result<()> {
            self.ctl(EPOLL_CTL_MOD, fd, events, token)
        }

        /// Remove `fd` from the interest set.
        pub fn del(&self, fd: RawFd) -> io::Result<()> {
            self.ctl(EPOLL_CTL_DEL, fd, 0, 0)
        }

        /// Wait up to `timeout_ms` for readiness; returns events filled.
        /// A negative timeout blocks indefinitely; zero polls.
        pub fn wait(&self, events: &mut [Event], timeout_ms: i32) -> io::Result<usize> {
            let max = events.len().min(i32::MAX as usize) as c_int;
            loop {
                let ret = unsafe { epoll_wait(self.fd, events.as_mut_ptr(), max, timeout_ms) };
                if ret < 0 {
                    let err = io::Error::last_os_error();
                    if err.kind() == io::ErrorKind::Interrupted {
                        continue; // EINTR: retry with the same timeout budget
                    }
                    return Err(err);
                }
                return Ok(ret as usize);
            }
        }
    }

    impl Drop for Epoll {
        fn drop(&mut self) {
            unsafe { close(self.fd) };
        }
    }

    /// A nonblocking eventfd used to wake a poller from other threads.
    pub struct EventFd {
        fd: RawFd,
    }

    impl EventFd {
        pub fn new() -> io::Result<EventFd> {
            let fd = cvt(unsafe { eventfd(0, EFD_CLOEXEC | EFD_NONBLOCK) })?;
            Ok(EventFd { fd })
        }

        pub fn as_raw_fd(&self) -> RawFd {
            self.fd
        }

        /// Post a wakeup. Safe to call from any thread; best-effort
        /// (a full counter still leaves the fd readable, which is all
        /// a level-triggered waiter needs).
        pub fn raise(&self) {
            let one: u64 = 1;
            unsafe { write(self.fd, &one as *const u64 as *const c_void, 8) };
        }

        /// Drain pending wakeups so level-triggered polls go quiet.
        pub fn drain(&self) {
            let mut buf: u64 = 0;
            loop {
                let n = unsafe { read(self.fd, &mut buf as *mut u64 as *mut c_void, 8) };
                if n != 8 {
                    break; // EAGAIN (empty) or error: either way, done
                }
            }
        }
    }

    impl Drop for EventFd {
        fn drop(&mut self) {
            unsafe { close(self.fd) };
        }
    }

    // EventFd wakeups cross threads by design; Epoll handles are owned
    // by one poller but registration happens before the thread spawns.
    unsafe impl Send for Epoll {}
    unsafe impl Sync for EventFd {}
    unsafe impl Send for EventFd {}

    #[cfg(test)]
    mod tests {
        use super::*;
        use std::io::{Read as _, Write as _};
        use std::net::{TcpListener, TcpStream};
        use std::os::unix::io::AsRawFd;

        #[test]
        fn eventfd_raises_and_drains_through_epoll() {
            let ep = Epoll::new().unwrap();
            let ev = EventFd::new().unwrap();
            ep.add(ev.as_raw_fd(), EPOLLIN, 7).unwrap();

            let mut events = [Event::empty(); 8];
            // Nothing raised yet: a zero-timeout wait sees no events.
            assert_eq!(ep.wait(&mut events, 0).unwrap(), 0);

            ev.raise();
            let n = ep.wait(&mut events, 1000).unwrap();
            assert_eq!(n, 1);
            let (events_bits, data) = (events[0].events, events[0].data);
            assert_eq!(data, 7);
            assert_ne!(events_bits & EPOLLIN, 0);

            // Level-triggered: still readable until drained.
            assert_eq!(ep.wait(&mut events, 0).unwrap(), 1);
            ev.drain();
            assert_eq!(ep.wait(&mut events, 0).unwrap(), 0);
        }

        #[test]
        fn socket_readiness_and_interest_changes() {
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = listener.local_addr().unwrap();
            let mut client = TcpStream::connect(addr).unwrap();
            let (mut served, _) = listener.accept().unwrap();
            served.set_nonblocking(true).unwrap();

            let ep = Epoll::new().unwrap();
            ep.add(served.as_raw_fd(), EPOLLIN, 42).unwrap();

            let mut events = [Event::empty(); 8];
            assert_eq!(ep.wait(&mut events, 0).unwrap(), 0);

            client.write_all(b"ping").unwrap();
            let n = ep.wait(&mut events, 1000).unwrap();
            assert_eq!(n, 1);
            assert_eq!({ events[0].data }, 42);

            let mut buf = [0u8; 16];
            let got = served.read(&mut buf).unwrap();
            assert_eq!(&buf[..got], b"ping");

            // Writable interest reports immediately on an idle socket.
            ep.modify(served.as_raw_fd(), EPOLLOUT, 42).unwrap();
            let n = ep.wait(&mut events, 1000).unwrap();
            assert_eq!(n, 1);
            assert_ne!({ events[0].events } & EPOLLOUT, 0);

            ep.del(served.as_raw_fd()).unwrap();
            assert_eq!(ep.wait(&mut events, 0).unwrap(), 0);
        }
    }
}
