//! Multi-table serving demo (no artifacts needed): one server hosting
//! four tables behind four different backends -- a DPQ codebook, an
//! 8-bit scalar-quant table, a low-rank factorization, and the dense
//! baseline -- routed by table name over protocol v2, with hot
//! load/unload admin ops, cross-table fan-out in one frame, a spill
//! tier (demote + transparent reload-on-lookup), a live registry
//! snapshot (and offline restore), and per-table latency stats.
//!
//!     cargo run --release --example multi_table_server

use std::sync::{mpsc, Arc};

use anyhow::Result;
use dpq_embed::backend::DenseTable;
use dpq_embed::dpq::toy_embedding;
use dpq_embed::quant::{LowRank, ScalarQuant};
use dpq_embed::server::{Client, EmbeddingServer, ServerConfig, TableRegistry};
use dpq_embed::tensor::TensorF;
use dpq_embed::util::Rng;

fn random_table(n: usize, d: usize, rng: &mut Rng) -> TensorF {
    TensorF {
        shape: vec![n, d],
        data: (0..n * d).map(|_| rng.normal() * 0.1).collect(),
    }
}

fn main() -> Result<()> {
    let mut rng = Rng::new(42);

    // four backends, four widths -- one server
    let dpq = toy_embedding(5000, 32, 16, 4, 42); // d = 64
    let sq = ScalarQuant::fit(&random_table(2000, 32, &mut rng), 8);
    let lr = LowRank::fit(&random_table(1000, 48, &mut rng), 8);
    let dense = DenseTable::new(random_table(500, 16, &mut rng))?;

    // a spill tier so the demote/transparent-reload demo below works
    let spill_dir = std::env::temp_dir().join("multi_table_demo_spill");
    std::fs::create_dir_all(&spill_dir)?;
    let registry = TableRegistry::open(ServerConfig {
        max_batch: 64,
        shards_per_table: 2, // id space split across two batcher shards
        spill_dir: Some(spill_dir),
        ..ServerConfig::default()
    })?;
    // the hot table gets 2 batcher-shard replicas over one shared
    // backend: lookups route to the least-loaded replica, and the
    // served bytes stay bit-identical to replicas=1
    registry.insert_with_replicas("dpq", Arc::new(dpq), 2)?;
    registry.insert("sq8", Arc::new(sq))?;
    registry.insert("lowrank", Arc::new(lr))?;
    registry.insert("dense", Arc::new(dense))?;

    let server = Arc::new(EmbeddingServer::new(registry));
    let (tx, rx) = mpsc::channel();
    let s2 = server.clone();
    let handle = std::thread::spawn(move || {
        s2.serve("127.0.0.1:0", move |a| tx.send(a).unwrap()).unwrap();
    });
    let addr = rx.recv().unwrap();
    println!("listening on {addr}\n");

    let mut c = Client::connect(addr)?;
    println!("{:<10} {:>10} {:>5} {:>12} {:>8} {:>7}  default",
             "table", "vocab", "d", "kind", "CR", "shards");
    for t in c.tables()? {
        println!(
            "{:<10} {:>10} {:>5} {:>12} {:>7.1}x {:>7}  {}",
            t.name, t.vocab, t.d, t.kind, t.compression_ratio, t.shards,
            if t.is_default { "*" } else { "" }
        );
    }

    // route lookups by table name; every response is self-sizing
    println!("\nlookups (d comes from the response header, never guessed):");
    for table in ["dpq", "sq8", "lowrank", "dense"] {
        let rows = c.lookup_bin(table, &[0, 1, 2])?;
        println!("  {table:<8} 3 rows x d={} first={:+.4}",
                 rows.d(), rows.row(0)[0]);
    }

    // hot admin ops: save a second DPQ table, load it, use it, drop it
    let path = std::env::temp_dir().join("multi_table_demo.dpq");
    toy_embedding(300, 16, 8, 2, 43).save(&path)?;
    let desc = c.admin_load("hot", path.to_str().unwrap())?;
    println!("\nhot-loaded table {:?}: vocab={} d={}", desc.name, desc.vocab,
             desc.d);
    println!("  lookup -> d={}", c.lookup_bin("hot", &[7])?.d());
    c.admin_unload("hot")?;
    println!("  unloaded; lookup now fails: {}",
             c.lookup_bin("hot", &[7]).unwrap_err());

    // cross-table fan-out: a recommender-style "user + item + context"
    // lookup spanning three tables in ONE round trip
    let sections = c.lookup_fanout(&[
        ("dpq", &[11, 22, 33][..]),
        ("sq8", &[5][..]),
        ("lowrank", &[0, 1][..]),
    ])?;
    println!("\nfan-out: 3 tables, 1 frame ->");
    for (name, rows) in ["dpq", "sq8", "lowrank"].iter().zip(&sections) {
        println!("  {name:<8} {} rows x d={}", rows.n(), rows.d());
    }

    // tiered residency: demote a cold table to the spill tier, watch it
    // report residency "spilled", then let a lookup transparently
    // reload it (bit-identical bytes, exactly one promote)
    let before = c.lookup_bin("dense", &[0, 1])?;
    let file = c.admin_demote("dense")?;
    let st = c.stats(Some("dense"))?;
    println!(
        "\ndemoted \"dense\" -> {} (residency {})",
        file, st.get("residency").and_then(|v| v.as_str()).unwrap_or("?")
    );
    let after = c.lookup_bin("dense", &[0, 1])?;
    assert_eq!(before, after, "transparent reload must be bit-exact");
    let st = c.stats(None)?;
    println!(
        "  lookup transparently reloaded it: {} spill(s), {} promote(s), \
         rows bit-identical",
        st.get("spills").and_then(|v| v.as_usize()).unwrap_or(0),
        st.get("promotes").and_then(|v| v.as_usize()).unwrap_or(0),
    );

    // compute on codes: top-k similarity served straight off the DPQ
    // codes via a per-query ADC lookup table -- no rows materialized.
    // "items like item 7" is the query_id form; an explicit query
    // vector works the same way (here: item 7's own row, so id 7 must
    // come back on top with the identical score)
    let query = c.lookup_bin("dpq", &[7])?.row(0).to_vec();
    let best = c.topk("dpq", &query, 5, None)?;
    println!("\ntopk(dpq, k=5) via the ADC lut path:");
    for (id, score) in &best {
        println!("  id {id:<5} score {score:+.4}");
    }
    let by_id = c.topk_by_id("dpq", 7, 5, None)?;
    assert_eq!(by_id, best, "query_id:7 must equal querying row 7's vector");
    // ... and `score` prices an explicit candidate list against the query
    let scores = c.score_with_id("dpq", 7, &[11, 22, 33])?;
    println!("  score(query_id=7, ids=[11,22,33]) -> {scores:+.4?}");

    // snapshot the whole registry live, then restore it offline
    let snap_dir = std::env::temp_dir().join("multi_table_demo_snapshot");
    let manifest = c.admin_snapshot(snap_dir.to_str().unwrap())?;
    println!("\nsnapshot -> {manifest}");
    let restored = dpq_embed::server::TableRegistry::restore(
        std::path::Path::new(&manifest), None)?;
    println!(
        "restored registry: {} tables, default {:?} (bit-identical rows; \
         `repro serve --restore {manifest}` does the same)",
        restored.len(), restored.default_name().unwrap_or_default()
    );
    restored.shutdown();

    // per-table serving stats with batch-latency percentiles
    let mut load_rng = Rng::new(7);
    for _ in 0..200 {
        let ids: Vec<usize> = (0..16).map(|_| load_rng.below(5000)).collect();
        c.lookup_bin("dpq", &ids)?;
    }
    let st = c.stats(Some("dpq"))?;
    println!(
        "\ndpq stats: {} requests, {} ids, {} batches, batch p50 {:.1}us \
         p99 {:.1}us, {} replica(s)",
        st.get("requests").unwrap().as_usize().unwrap(),
        st.get("ids_served").unwrap().as_usize().unwrap(),
        st.get("batches").unwrap().as_usize().unwrap(),
        st.get("batch_p50_s").and_then(|v| v.as_f64()).unwrap_or(0.0) * 1e6,
        st.get("batch_p99_s").and_then(|v| v.as_f64()).unwrap_or(0.0) * 1e6,
        st.get("replicas").and_then(|v| v.as_usize()).unwrap_or(1),
    );

    // live resize: scale the hot table to 3 replicas mid-serving (the
    // swap is invisible to traffic), then back down to 1
    println!("set_replicas(dpq, 3) -> {}", c.admin_set_replicas("dpq", 3)?);
    println!("  lookup still serves: d={}", c.lookup_bin("dpq", &[9])?.d());
    println!("set_replicas(dpq, 1) -> {}", c.admin_set_replicas("dpq", 1)?);

    c.shutdown()?;
    handle.join().unwrap();
    Ok(())
}
