//! Quickstart: train a PTB-shaped LSTM LM with a DPQ-SX compressed
//! embedding for a few hundred steps, report perplexity vs the full
//! baseline, and print the compression accounting.
//!
//!     make artifacts && cargo run --release --example quickstart

use anyhow::Result;
use dpq_embed::config::{LrSchedule, RunConfig};
use dpq_embed::coordinator::{experiments, Trainer};
use dpq_embed::runtime::Runtime;

fn cfg(artifact: &str, steps: usize) -> RunConfig {
    RunConfig {
        artifact: artifact.into(),
        steps,
        seed: 17,
        lr: LrSchedule { base: 1.0, decay_after: usize::MAX, decay: 1.0 },
        log_every: steps / 5,
        eval_batches: 10,
        artifacts_dir: "artifacts".into(),
        checkpoint_dir: None,
        checkpoint_every: 0,
        export_every: 0,
    }
}

fn main() -> Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(200);
    let rt = Runtime::new("artifacts")?;

    println!("== full embedding baseline ==");
    let full = Trainer::new(&rt, cfg("lm_ptb_full", steps)).run()?;
    println!("full: held-out ppl {:.2}\n", full.ppl().unwrap());

    println!("== DPQ-SX (K=32, D=32) ==");
    let prefix = "lm_ptb_sx_K32D32";
    let sx = Trainer::new(&rt, cfg(prefix, steps)).run()?;
    println!("dpq-sx: held-out ppl {:.2}", sx.ppl().unwrap());

    let ce = experiments::compress_state(&rt, prefix, &sx.state, false)?;
    println!(
        "compressed embedding: {} symbols x d={}  ->  {} KiB \
         (codes {} bits/symbol + values), CR = {:.1}x",
        ce.vocab(),
        ce.d,
        ce.storage_bits() / 8 / 1024,
        ce.codebook.bits() as usize * ce.codebook.d_groups,
        ce.compression_ratio()
    );
    println!(
        "full table would be {} KiB",
        ce.vocab() * ce.d * 4 / 1024
    );
    println!(
        "\nppl gap (dpq - full): {:+.2}  -- the paper's claim is that this \
         gap is ~0 at tens-of-x compression.",
        sx.ppl().unwrap() - full.ppl().unwrap()
    );
    Ok(())
}
