//! Post-hoc compression walkthrough (the Table 5 story): train a full
//! embedding LM, then compress the trained table with scalar quantization,
//! k-means product quantization, and truncated-SVD low-rank -- all
//! implemented in-repo -- and evaluate each reconstructed table through
//! the same eval executable. Shows why end-to-end DPQ wins: post-hoc
//! methods degrade sharply as CR grows.
//!
//!     cargo run --release --example posthoc_compress [steps]

use anyhow::Result;
use dpq_embed::config::{LrSchedule, RunConfig};
use dpq_embed::coordinator::{TaskGen, Trainer};
use dpq_embed::metrics;
use dpq_embed::quant::{Compressor, LowRank, ProductQuant, ScalarQuant};
use dpq_embed::runtime::{self, Runtime, Value};
use dpq_embed::util::Rng;

fn main() -> Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);
    let rt = Runtime::new("artifacts")?;
    let prefix = "lm_ptb_full";
    eprintln!("training {prefix} for {steps} steps...");
    let cfg = RunConfig {
        artifact: prefix.into(),
        steps,
        seed: 29,
        lr: LrSchedule { base: 1.0, decay_after: steps * 2 / 3, decay: 0.5 },
        log_every: steps / 4,
        eval_batches: 10,
        artifacts_dir: "artifacts".into(),
        checkpoint_dir: None,
        checkpoint_every: 0,
        export_every: 0,
    };
    let out = Trainer::new(&rt, cfg).run()?;
    let table = out.state.get("emb/table").unwrap().as_f()?.clone();
    let (n, d) = (table.rows(), table.cols());

    let eval = rt.load(&format!("{prefix}_eval"))?;
    let mut gen = TaskGen::from_manifest(&eval.manifest, 999)?;
    let batches: Vec<Vec<Value>> = (0..8).map(|_| gen.next_batch()).collect();
    let ppl_of = |table_opt: Option<&dyn Compressor>| -> Result<f64> {
        let mut st = out.state.clone();
        if let Some(c) = table_opt {
            st.set("emb/table", Value::F(c.reconstruct()))?;
        }
        let mut total = 0.0f64;
        for b in &batches {
            total += runtime::run_eval(&eval, &st, b)?[0] as f64;
        }
        Ok(metrics::perplexity(total / batches.len() as f64))
    };

    println!("\n{:<34} {:>9} {:>7} {:>10}", "method", "PPL", "CR", "rel-err");
    println!("{:<34} {:>9.2} {:>7} {:>10}", "full (trained)",
             ppl_of(None)?, "1.0x", "-");
    let mut report = |name: String, c: &dyn Compressor| -> Result<()> {
        let rec = c.reconstruct();
        println!(
            "{:<34} {:>9.2} {:>6.1}x {:>10.4}",
            name,
            ppl_of(Some(c))?,
            c.compression_ratio(n, d),
            table.rel_err(&rec)
        );
        Ok(())
    };
    for bits in [8, 6, 4, 2] {
        report(format!("scalar quant ({bits}-bit)"),
               &ScalarQuant::fit(&table, bits))?;
    }
    for (k, dg) in [(256, 32), (64, 32), (32, 16), (16, 8)] {
        report(
            format!("product quant (K={k}, D={dg})"),
            &ProductQuant::fit(&table, k, dg, 12, &mut Rng::new(7)),
        )?;
    }
    for rank in [32, 16, 8, 4] {
        report(format!("low-rank SVD (r={rank})"),
               &LowRank::fit(&table, rank))?;
    }
    println!(
        "\nCompare with `cargo run --release --example quickstart`: \
         end-to-end DPQ reaches these CRs *without* the PPL cliff."
    );
    Ok(())
}
