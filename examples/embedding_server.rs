//! Serving demo: train + compress a DPQ embedding, serve it over TCP with
//! micro-batching, then run a small closed-loop load test (multiple client
//! threads) and report latency/throughput -- the "no inference cost"
//! claim of paper Sec. 3.4 in serving form.
//!
//!     cargo run --release --example embedding_server [requests]

use std::sync::{mpsc, Arc};
use std::time::Instant;

use anyhow::Result;
use dpq_embed::config::{LrSchedule, RunConfig};
use dpq_embed::coordinator::{experiments, Trainer};
use dpq_embed::metrics::LatencyStats;
use dpq_embed::runtime::Runtime;
use dpq_embed::server::{Client, EmbeddingServer};
use dpq_embed::util::Rng;

fn main() -> Result<()> {
    let requests: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(500);
    let rt = Runtime::new("artifacts")?;
    let prefix = "lm_ptb_sx_K32D32";
    eprintln!("training {prefix} briefly to get a real codebook...");
    let cfg = RunConfig {
        artifact: prefix.into(),
        steps: 60,
        seed: 3,
        lr: LrSchedule { base: 1.0, decay_after: usize::MAX, decay: 1.0 },
        log_every: 30,
        eval_batches: 4,
        artifacts_dir: "artifacts".into(),
        checkpoint_dir: None,
        checkpoint_every: 0,
        export_every: 0,
    };
    let out = Trainer::new(&rt, cfg).quiet().run()?;
    let ce = experiments::compress_state(&rt, prefix, &out.state, false)?;
    let vocab = ce.vocab();
    println!(
        "serving compressed embedding: {} KiB vs {} KiB full (CR {:.1}x)",
        ce.storage_bits() / 8 / 1024,
        vocab * ce.d * 4 / 1024,
        ce.compression_ratio()
    );

    let server = Arc::new(EmbeddingServer::single("ptb", ce, 64));
    let (tx, rx) = mpsc::channel();
    let s2 = server.clone();
    let handle = std::thread::spawn(move || {
        s2.serve("127.0.0.1:0", move |a| tx.send(a).unwrap()).unwrap();
    });
    let addr = rx.recv().unwrap();
    println!("listening on {addr}; running load test...");

    const CLIENTS: usize = 4;
    let per_client = requests / CLIENTS;
    let t0 = Instant::now();
    let workers: Vec<_> = (0..CLIENTS)
        .map(|w| {
            std::thread::spawn(move || -> Result<LatencyStats> {
                let mut c = Client::connect(addr)?;
                let mut rng = Rng::new(w as u64 + 100);
                let mut lat = LatencyStats::default();
                for _ in 0..per_client {
                    let ids: Vec<usize> =
                        (0..8).map(|_| rng.below(2000)).collect();
                    let t = Instant::now();
                    let v = c.lookup("ptb", &ids)?;
                    lat.record(t.elapsed().as_secs_f64());
                    assert_eq!(v.n(), 8);
                }
                Ok(lat)
            })
        })
        .collect();
    let mut all = LatencyStats::default();
    for w in workers {
        all.merge(&w.join().unwrap()?);
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = server
        .registry()
        .get("ptb")
        .expect("table is loaded")
        .stats
        .clone();
    println!("client-side lookup latency: {}", all.summary(1.0));
    if let Some((p50, p99)) = stats.batch_latency() {
        println!("server-side batch latency: p50 {:.3}ms p99 {:.3}ms",
                 p50 * 1e3, p99 * 1e3);
    }
    println!(
        "aggregate: {} requests ({} ids) in {wall:.2}s = {:.0} req/s, \
         {} batches formed",
        requests,
        requests * 8,
        requests as f64 / wall,
        stats.batches.load(std::sync::atomic::Ordering::Relaxed)
    );

    let mut c = Client::connect(addr)?;
    c.shutdown()?;
    handle.join().unwrap();
    Ok(())
}
