//! Sub-word pipeline demo: the in-repo BPE learner standing in for
//! SentencePiece (paper's WMT19 En-De tokenization). Learns merges from a
//! synthetic morphology-rich corpus, builds a sub-word vocabulary, encodes
//! text, and shows the compression effect of sub-words on vocabulary size
//! -- the setting where the paper shows DPQ can compress *further* (the
//! "already-compact sub-word representations" claim of Sec. 3.1).
//!
//!     cargo run --release --example bpe_pipeline

use std::collections::HashMap;

use dpq_embed::data::synth::{pseudo_word, MarkovLm};
use dpq_embed::data::{bpe::Bpe, Vocab};

fn main() {
    // 1. synthesize a corpus of pseudo-words with shared stems/suffixes
    let mut lm = MarkovLm::new(800, 42);
    let tokens: Vec<String> =
        lm.tokens(50_000).into_iter().map(pseudo_word).collect();
    let mut counts: HashMap<String, usize> = HashMap::new();
    for t in &tokens {
        *counts.entry(t.clone()).or_insert(0) += 1;
    }
    println!("corpus: {} tokens, {} distinct words", tokens.len(),
             counts.len());

    // 2. learn BPE merges
    for merges in [16usize, 64, 256] {
        let bpe = Bpe::learn(&counts, merges);
        // sub-word inventory = distinct segments over the corpus
        let mut inv: HashMap<String, usize> = HashMap::new();
        let mut total_segs = 0usize;
        for (w, c) in &counts {
            let segs = bpe.segment(w);
            total_segs += segs.len() * c;
            for s in segs {
                *inv.entry(s).or_insert(0) += c;
            }
        }
        println!(
            "merges={merges:<4} learned={} sub-word inventory={} \
             avg segs/word={:.2}",
            bpe.num_merges(),
            inv.len(),
            total_segs as f64 / tokens.len() as f64
        );
    }

    // 3. word-level vs sub-word vocabulary + embedding-table sizes
    let bpe = Bpe::learn(&counts, 256);
    let word_vocab = Vocab::from_corpus(tokens.iter().map(|s| s.as_str()),
                                        usize::MAX);
    let sub_tokens: Vec<String> = tokens
        .iter()
        .flat_map(|w| bpe.segment(w))
        .collect();
    let sub_vocab = Vocab::from_corpus(sub_tokens.iter().map(|s| s.as_str()),
                                       usize::MAX);
    let d = 64usize;
    println!(
        "\nword-level vocab {} -> full table {} KiB",
        word_vocab.len(),
        word_vocab.len() * d * 4 / 1024
    );
    println!(
        "sub-word vocab  {} -> full table {} KiB",
        sub_vocab.len(),
        sub_vocab.len() * d * 4 / 1024
    );
    println!(
        "DPQ (K=32, D=16) on the sub-word table would use {:.1} KiB \
         (CR formula of Sec. 3) -- compression on top of sub-words, \
         which is Table 3's WMT19 row.",
        (sub_vocab.len() as f64 * 16.0 * 5.0 + 32.0 * 32.0 * d as f64)
            / 8.0
            / 1024.0
    );

    // 4. encode/decode round-trip demo
    let sample = "kana boren telir";
    let ids = sub_vocab.encode(
        &bpe.tokenize(sample).join(" "));
    println!("\n'{sample}' -> sub-words {:?} -> ids {:?}",
             bpe.tokenize(sample), ids);
}
