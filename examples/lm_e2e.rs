//! End-to-end validation driver (the EXPERIMENTS.md headline run): train
//! the PTB-shaped LSTM LM with all three embedding variants for several
//! hundred steps each, logging the full loss curve, then export + verify
//! the compressed embedding and print the paper-style summary row.
//!
//!     cargo run --release --example lm_e2e [steps]

use anyhow::Result;
use dpq_embed::config::{LrSchedule, RunConfig};
use dpq_embed::coordinator::{experiments, Trainer};
use dpq_embed::dpq::stats as dstats;
use dpq_embed::metrics;
use dpq_embed::runtime::Runtime;

fn main() -> Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(400);
    let rt = Runtime::new("artifacts")?;
    let mut summary: Vec<(String, f64, f64, f64)> = Vec::new();

    for (label, prefix) in [
        ("full", "lm_ptb_full"),
        ("dpq-sx", "lm_ptb_sx_K32D32"),
        ("dpq-vq", "lm_ptb_vq_K32D32"),
    ] {
        println!("\n===== {label} ({prefix}) =====");
        let cfg = RunConfig {
            artifact: prefix.into(),
            steps,
            seed: 17,
            lr: LrSchedule { base: 1.0, decay_after: steps * 2 / 3, decay: 0.5 },
            log_every: (steps / 20).max(1),
            eval_batches: 16,
            artifacts_dir: "artifacts".into(),
            checkpoint_dir: Some("checkpoints".into()),
            checkpoint_every: steps / 2,
            export_every: 0,
        };
        let tr = Trainer::new(&rt, cfg);
        let out = tr.run()?;
        println!("loss curve (step, ce):");
        for (s, m) in &out.history {
            println!("  {s:>5}  {:.4}", m[0]);
        }
        let ppl = out.ppl().unwrap();
        let (cr, util) = if label == "full" {
            (1.0, f64::NAN)
        } else {
            let ce = experiments::compress_state(&rt, prefix, &out.state,
                                                 false)?;
            let codes = ce.codebook.to_tensor();
            (ce.compression_ratio(), dstats::utilization(&codes, ce.codebook.k))
        };
        println!(
            "{label}: held-out ppl {ppl:.2}  CR {cr:.1}x  \
             steps/s {:.2}{}",
            out.steps_per_sec,
            if util.is_nan() {
                String::new()
            } else {
                format!("  code-utilization {util:.2}")
            }
        );
        summary.push((label.to_string(), ppl, cr, out.steps_per_sec));
    }

    println!("\n===== summary (paper Table 3 row shape) =====");
    println!("{:<8} {:>10} {:>8} {:>9}", "method", "PPL", "CR", "steps/s");
    for (l, p, c, s) in &summary {
        println!("{l:<8} {p:>10.2} {c:>7.1}x {s:>9.2}");
    }
    let base = summary[0].1;
    for (l, p, _, _) in summary.iter().skip(1) {
        let gap = p - base;
        println!(
            "{l}: ppl gap vs full = {gap:+.2} ({})",
            if gap.abs() < 0.05 * base {
                "within 5% -- matches the paper's 'negligible cost' claim"
            } else {
                "outside 5%"
            }
        );
    }
    let _ = metrics::perplexity(0.0);
    Ok(())
}
