//! Inference-path bench (paper Sec. 3.4 / Algorithm 1): embedding lookup
//! from the compressed codebook vs a plain full-table row copy. The
//! paper's claim: DPQ inference adds negligible cost. Also measures the
//! batch (whole-table) reconstruction used at model-load time.

use dpq_embed::dpq::{Codebook, CompressedEmbedding};
use dpq_embed::tensor::{TensorF, TensorI};
use dpq_embed::util::bench::{self, bench, section};
use dpq_embed::util::{pool, Rng};

fn toy(n: usize, k: usize, dg: usize, s: usize) -> (CompressedEmbedding, TensorF) {
    let mut rng = Rng::new(1);
    let codes = TensorI::new(vec![n, dg],
                             (0..n * dg).map(|_| rng.below(k) as i32).collect())
        .unwrap();
    let values = TensorF::new(vec![k, dg, s],
                              (0..k * dg * s).map(|_| rng.normal()).collect())
        .unwrap();
    let full = TensorF::new(vec![n, dg * s],
                            (0..n * dg * s).map(|_| rng.normal()).collect())
        .unwrap();
    (
        CompressedEmbedding::new(Codebook::from_codes(&codes, k).unwrap(),
                                 values, false)
            .unwrap(),
        full,
    )
}

fn main() {
    bench::init("inference");
    println!("worker pool: {} thread(s) (DPQ_THREADS to change)",
             pool::current_threads());
    // PTB-medium shape: n=2000 d=128 K=32 D=32; plus a large-vocab shape.
    for (n, k, dg, s, label) in [
        (2000usize, 32usize, 32usize, 4usize, "ptb-medium (n=2k, d=128)"),
        (50000, 32, 16, 4, "large-vocab (n=50k, d=64)"),
    ] {
        section(label);
        let (ce, full) = toy(n, k, dg, s);
        let d = dg * s;
        let mut rng = Rng::new(2);
        let ids: Vec<usize> = (0..512).map(|_| rng.below(n)).collect();
        let mut out = vec![0.0f32; d];

        bench("full-table row copy x512", 20, 200, || {
            for &i in &ids {
                out.copy_from_slice(full.row(i));
                std::hint::black_box(&out);
            }
        });
        bench("dpq reconstruct_row x512 (Algorithm 1)", 20, 200, || {
            for &i in &ids {
                ce.reconstruct_row_into(i, &mut out);
                std::hint::black_box(&out);
            }
        });
        let m = bench("dpq reconstruct full table", 3, 20, || {
            std::hint::black_box(ce.reconstruct_table());
        });
        println!(
            "   -> {:.1} M rows/s whole-table; storage {} KiB vs {} KiB full (CR {:.1}x)",
            n as f64 / m.mean_s / 1e6,
            ce.storage_bits() / 8 / 1024,
            n * d * 4 / 1024,
            ce.compression_ratio()
        );
    }
}
