//! Post-hoc compressor benches (Table 5 baselines): fit + reconstruct
//! cost of scalar quantization, k-means product quantization and Jacobi
//! SVD low-rank on a trained-table-shaped matrix.

use dpq_embed::quant::{Compressor, LowRank, ProductQuant, ScalarQuant};
use dpq_embed::tensor::TensorF;
use dpq_embed::util::bench::{self, bench, section};
use dpq_embed::util::Rng;

fn table(n: usize, d: usize) -> TensorF {
    let mut rng = Rng::new(3);
    TensorF::new(vec![n, d], (0..n * d).map(|_| rng.normal() * 0.1).collect())
        .unwrap()
}

fn main() {
    bench::init("quant");
    let t = table(2000, 128);
    section("scalar quantization (n=2000, d=128)");
    for bits in [4u32, 8] {
        bench(&format!("fit {bits}-bit"), 2, 10, || {
            std::hint::black_box(ScalarQuant::fit(&t, bits));
        });
    }
    let sq = ScalarQuant::fit(&t, 8);
    bench("reconstruct 8-bit", 2, 10, || {
        std::hint::black_box(sq.reconstruct());
    });

    section("product quantization (k-means, n=2000, d=128)");
    for (k, dg) in [(32usize, 16usize), (64, 32)] {
        let m = bench(&format!("fit K={k} D={dg} (10 iters)"), 0, 3, || {
            std::hint::black_box(ProductQuant::fit(&t, k, dg, 10,
                                                   &mut Rng::new(5)));
        });
        println!("   -> {:.2} s per fit", m.mean_s);
    }
    let pq = ProductQuant::fit(&t, 32, 16, 10, &mut Rng::new(5));
    bench("reconstruct PQ", 2, 10, || {
        std::hint::black_box(pq.reconstruct());
    });
    println!("   CR {:.1}x", pq.compression_ratio(2000, 128));

    section("low-rank SVD (one-sided Jacobi, n=2000, d=128)");
    for rank in [8usize, 32] {
        let m = bench(&format!("fit r={rank}"), 0, 3, || {
            std::hint::black_box(LowRank::fit(&t, rank));
        });
        println!("   -> {:.2} s per fit", m.mean_s);
    }
    let lr = LowRank::fit(&t, 16);
    bench("reconstruct low-rank", 2, 10, || {
        std::hint::black_box(lr.reconstruct());
    });
}
