//! Embedding-server throughput bench: closed-loop clients against the
//! micro-batching TCP server (L3 serving path).

use std::sync::{mpsc, Arc};
use std::time::Instant;

use dpq_embed::dpq::{Codebook, CompressedEmbedding};
use dpq_embed::server::{Client, EmbeddingServer};
use dpq_embed::tensor::{TensorF, TensorI};
use dpq_embed::util::bench::{self, section};
use dpq_embed::util::{pool, Rng};

fn main() {
    bench::init("server");
    println!("worker pool: {} thread(s) (DPQ_THREADS to change)",
             pool::current_threads());
    let (n, k, dg, s) = (10_000usize, 32usize, 16usize, 4usize);
    let mut rng = Rng::new(1);
    let codes = TensorI::new(vec![n, dg],
                             (0..n * dg).map(|_| rng.below(k) as i32).collect())
        .unwrap();
    let values = TensorF::new(vec![k, dg, s],
                              (0..k * dg * s).map(|_| rng.normal()).collect())
        .unwrap();
    let ce = CompressedEmbedding::new(
        Codebook::from_codes(&codes, k).unwrap(), values, false).unwrap();

    for (clients, binary) in [(1usize, false), (1, true), (4, false),
                              (4, true), (8, false), (8, true)] {
        section(&format!(
            "{clients} client(s), 16 ids per request, {} protocol",
            if binary { "binary" } else { "json" }
        ));
        let server = Arc::new(EmbeddingServer::new(ce.clone(), 64));
        let (tx, rx) = mpsc::channel();
        let s2 = server.clone();
        let h = std::thread::spawn(move || {
            s2.serve("127.0.0.1:0", move |a| tx.send(a).unwrap()).unwrap();
        });
        let addr = rx.recv().unwrap();
        let per_client = 400usize;
        let t0 = Instant::now();
        let d = 64usize; // dg * s
        let ws: Vec<_> = (0..clients)
            .map(|w| {
                std::thread::spawn(move || {
                    let mut c = Client::connect(addr).unwrap();
                    let mut rng = Rng::new(w as u64);
                    for _ in 0..per_client {
                        let ids: Vec<usize> =
                            (0..16).map(|_| rng.below(10_000)).collect();
                        if binary {
                            c.lookup_bin(&ids, d).unwrap();
                        } else {
                            c.lookup(&ids).unwrap();
                        }
                    }
                })
            })
            .collect();
        for w in ws {
            w.join().unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        let reqs = clients * per_client;
        println!(
            "{} requests in {:.2}s = {:.0} req/s, {:.0} ids/s, {} batches",
            reqs,
            wall,
            reqs as f64 / wall,
            (reqs * 16) as f64 / wall,
            server
                .stats
                .batches
                .load(std::sync::atomic::Ordering::Relaxed)
        );
        // sustained-lookup trail: mean seconds per request at this load
        bench::record(
            &format!(
                "sustained_lookup_{}_{}c",
                if binary { "bin" } else { "json" },
                clients
            ),
            wall / reqs as f64,
            0.0,
            reqs,
        );
        let mut c = Client::connect(addr).unwrap();
        c.shutdown().unwrap();
        h.join().unwrap();
    }
}
