//! Embedding-server throughput bench: closed-loop clients against the
//! micro-batching multi-table TCP server (L3 serving path). Records
//! sustained per-request latency AND the server-side batch p50/p99 (from
//! the per-table latency ring, fetched over the `stats` op) to
//! `BENCH_server.json`, so the perf trajectory has serving-latency
//! numbers per protocol, client count, table count, and shard count.

use std::sync::{mpsc, Arc};
use std::time::Instant;

use dpq_embed::dpq::toy_embedding;
use dpq_embed::quant::ScalarQuant;
use dpq_embed::server::{
    Client, EmbeddingServer, ServerConfig, TableRegistry,
};
use dpq_embed::tensor::TensorF;
use dpq_embed::util::bench::{self, section};
use dpq_embed::util::{pool, Rng};

/// Run `clients` closed-loop workers against `server`, each issuing
/// `per_client` requests of 16 random ids to its table, then append
/// sustained latency + server-side batch percentiles under `tag`.
fn drive(server: Arc<EmbeddingServer>, tables: &[(&str, usize)], clients: usize,
         binary: bool, tag: &str) {
    let (tx, rx) = mpsc::channel();
    let s2 = server.clone();
    let h = std::thread::spawn(move || {
        s2.serve("127.0.0.1:0", move |a| tx.send(a).unwrap()).unwrap();
    });
    let addr = rx.recv().unwrap();
    let per_client = 400usize;
    let t0 = Instant::now();
    let ws: Vec<_> = (0..clients)
        .map(|w| {
            // client w hammers table w % tables.len()
            let (table, vocab) = tables[w % tables.len()];
            let table = table.to_string();
            std::thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                let mut rng = Rng::new(w as u64);
                for _ in 0..per_client {
                    let ids: Vec<usize> =
                        (0..16).map(|_| rng.below(vocab)).collect();
                    if binary {
                        c.lookup_bin(&table, &ids).unwrap();
                    } else {
                        c.lookup(&table, &ids).unwrap();
                    }
                }
            })
        })
        .collect();
    for w in ws {
        w.join().unwrap();
    }
    let wall = t0.elapsed().as_secs_f64();
    let reqs = clients * per_client;
    let registry = server.registry();
    let batches: u64 = registry
        .list()
        .iter()
        .map(|e| e.stats.batches.load(std::sync::atomic::Ordering::Relaxed))
        .sum();
    println!(
        "{} requests in {:.2}s = {:.0} req/s, {:.0} ids/s, {} batches",
        reqs, wall, reqs as f64 / wall, (reqs * 16) as f64 / wall, batches
    );
    // sustained-lookup trail: mean seconds per request at this load
    bench::record(&format!("sustained_lookup_{tag}"), wall / reqs as f64,
                  0.0, reqs);
    // server-side batch latency percentiles, over the wire (stats op)
    let mut c = Client::connect(addr).unwrap();
    let stats = c.stats(None).unwrap();
    for (table, _) in tables {
        let Some(t) = stats.get("tables").and_then(|m| m.get(table)) else {
            continue;
        };
        if let (Some(p50), Some(p99)) = (
            t.get("batch_p50_s").and_then(|v| v.as_f64()),
            t.get("batch_p99_s").and_then(|v| v.as_f64()),
        ) {
            println!("  {table}: batch p50 {:.1}us p99 {:.1}us",
                     p50 * 1e6, p99 * 1e6);
            bench::record(&format!("batch_p50_{tag}_{table}"), p50, 0.0, reqs);
            bench::record(&format!("batch_p99_{tag}_{table}"), p99, 0.0, reqs);
        }
    }
    c.shutdown().unwrap();
    h.join().unwrap();
}

fn main() {
    bench::init("server");
    println!("worker pool: {} thread(s) (DPQ_THREADS to change)",
             pool::current_threads());
    let (n, k, dg, s) = (10_000usize, 32usize, 16usize, 4usize);
    let ce = toy_embedding(n, k, dg, s, 1);

    // single table, the PR-1 comparison grid
    for (clients, binary) in [(1usize, false), (1, true), (4, false),
                              (4, true), (8, false), (8, true)] {
        let proto = if binary { "bin" } else { "json" };
        section(&format!(
            "1 table, {clients} client(s), 16 ids per request, {proto}"));
        let server = Arc::new(EmbeddingServer::single("emb", ce.clone(), 64));
        drive(server, &[("emb", n)], clients, binary,
              &format!("{proto}_{clients}c"));
    }

    // two tables of different kinds behind one server: clients alternate
    section("2 tables (dpq + scalar_quant), 4 clients, bin");
    let mut rng = Rng::new(7);
    let sq_table = TensorF {
        shape: vec![4000, 32],
        data: (0..4000 * 32).map(|_| rng.normal()).collect(),
    };
    let registry = TableRegistry::new(ServerConfig::default());
    registry.insert("emb", Arc::new(ce.clone())).unwrap();
    registry
        .insert("sq", Arc::new(ScalarQuant::fit(&sq_table, 8)))
        .unwrap();
    drive(Arc::new(EmbeddingServer::new(registry)),
          &[("emb", n), ("sq", 4000)], 4, true, "bin_4c_2tables");

    // id-space partitioning: same table, 2 batcher shards
    section("1 table, 2 batcher shards, 4 clients, bin");
    let registry = TableRegistry::new(ServerConfig {
        max_batch: 64,
        shards_per_table: 2,
    });
    registry.insert("emb", Arc::new(ce.clone())).unwrap();
    drive(Arc::new(EmbeddingServer::new(registry)),
          &[("emb", n)], 4, true, "bin_4c_2shards");
}
