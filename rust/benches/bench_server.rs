//! Embedding-server throughput bench: closed-loop clients against the
//! micro-batching multi-table TCP server (L3 serving path). Records
//! sustained per-request latency AND the server-side batch p50/p99 (from
//! the per-table latency ring, fetched over the `stats` op) to
//! `BENCH_server.json`, so the perf trajectory has serving-latency
//! numbers per protocol, client count, table count, and shard count.

use std::sync::{mpsc, Arc};
use std::time::Instant;

use dpq_embed::backend::{DenseTable, MultiGranular};
use dpq_embed::dpq::toy_embedding;
use dpq_embed::quant::ScalarQuant;
use dpq_embed::scoring::{self, ExactScorer, ScoreBackend};
use dpq_embed::server::{
    Client, EmbeddingServer, ServerConfig, TableRegistry,
};
use dpq_embed::tensor::TensorF;
use dpq_embed::util::bench::{self, section};
use dpq_embed::util::{pool, Rng};

/// Run `clients` closed-loop workers against `server`, each issuing
/// `per_client` requests of 16 random ids to its table, then append
/// sustained latency + server-side batch percentiles under `tag`.
fn drive(server: Arc<EmbeddingServer>, tables: &[(&str, usize)], clients: usize,
         binary: bool, tag: &str) {
    let (tx, rx) = mpsc::channel();
    let s2 = server.clone();
    let h = std::thread::spawn(move || {
        s2.serve("127.0.0.1:0", move |a| tx.send(a).unwrap()).unwrap();
    });
    let addr = rx.recv().unwrap();
    let per_client = 400usize;
    let t0 = Instant::now();
    let ws: Vec<_> = (0..clients)
        .map(|w| {
            // client w hammers table w % tables.len()
            let (table, vocab) = tables[w % tables.len()];
            let table = table.to_string();
            std::thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                let mut rng = Rng::new(w as u64);
                for _ in 0..per_client {
                    let ids: Vec<usize> =
                        (0..16).map(|_| rng.below(vocab)).collect();
                    if binary {
                        c.lookup_bin(&table, &ids).unwrap();
                    } else {
                        c.lookup(&table, &ids).unwrap();
                    }
                }
            })
        })
        .collect();
    for w in ws {
        w.join().unwrap();
    }
    let wall = t0.elapsed().as_secs_f64();
    let reqs = clients * per_client;
    let registry = server.registry();
    let batches: u64 = registry
        .list()
        .iter()
        .map(|e| e.stats.batches.load(std::sync::atomic::Ordering::Relaxed))
        .sum();
    println!(
        "{} requests in {:.2}s = {:.0} req/s, {:.0} ids/s, {} batches",
        reqs, wall, reqs as f64 / wall, (reqs * 16) as f64 / wall, batches
    );
    // sustained-lookup trail: mean seconds per request at this load
    bench::record(&format!("sustained_lookup_{tag}"), wall / reqs as f64,
                  0.0, reqs);
    // server-side batch latency percentiles, over the wire (stats op)
    let mut c = Client::connect(addr).unwrap();
    let stats = c.stats(None).unwrap();
    for (table, _) in tables {
        let Some(t) = stats.get("tables").and_then(|m| m.get(table)) else {
            continue;
        };
        if let (Some(p50), Some(p99)) = (
            t.get("batch_p50_s").and_then(|v| v.as_f64()),
            t.get("batch_p99_s").and_then(|v| v.as_f64()),
        ) {
            println!("  {table}: batch p50 {:.1}us p99 {:.1}us",
                     p50 * 1e6, p99 * 1e6);
            bench::record(&format!("batch_p50_{tag}_{table}"), p50, 0.0, reqs);
            bench::record(&format!("batch_p99_{tag}_{table}"), p99, 0.0, reqs);
        }
    }
    c.shutdown().unwrap();
    h.join().unwrap();
}

/// Bind `server` on an ephemeral port and return its address + thread.
fn boot(server: Arc<EmbeddingServer>)
    -> (std::net::SocketAddr, std::thread::JoinHandle<()>) {
    let (tx, rx) = mpsc::channel();
    let h = std::thread::spawn(move || {
        server.serve("127.0.0.1:0", move |a| tx.send(a).unwrap()).unwrap();
    });
    (rx.recv().unwrap(), h)
}

/// Normalized Zipf(s) CDF over ranks `1..=n` (harmonic weights).
fn zipf_cdf(n: usize, s: f64) -> Vec<f64> {
    let mut cdf = Vec::with_capacity(n);
    let mut acc = 0.0f64;
    for i in 0..n {
        acc += 1.0 / ((i + 1) as f64).powf(s);
        cdf.push(acc);
    }
    for v in &mut cdf {
        *v /= acc;
    }
    cdf
}

/// One Zipf draw: a 53-bit uniform into the CDF by binary search.
fn zipf_sample(cdf: &[f64], rng: &mut Rng) -> usize {
    let u = (rng.next_u64() >> 11) as f64 / (1u64 << 53) as f64;
    cdf.partition_point(|&c| c < u).min(cdf.len() - 1)
}

fn main() {
    bench::init("server");
    println!("worker pool: {} thread(s) (DPQ_THREADS to change)",
             pool::current_threads());
    let (n, k, dg, s) = (10_000usize, 32usize, 16usize, 4usize);
    let ce = toy_embedding(n, k, dg, s, 1);

    // single table, the PR-1 comparison grid
    for (clients, binary) in [(1usize, false), (1, true), (4, false),
                              (4, true), (8, false), (8, true)] {
        let proto = if binary { "bin" } else { "json" };
        section(&format!(
            "1 table, {clients} client(s), 16 ids per request, {proto}"));
        let server = Arc::new(EmbeddingServer::single("emb", ce.clone(), 64));
        drive(server, &[("emb", n)], clients, binary,
              &format!("{proto}_{clients}c"));
    }

    // two tables of different kinds behind one server: clients alternate
    section("2 tables (dpq + scalar_quant), 4 clients, bin");
    let mut rng = Rng::new(7);
    let sq_table = TensorF {
        shape: vec![4000, 32],
        data: (0..4000 * 32).map(|_| rng.normal()).collect(),
    };
    let registry = TableRegistry::new(ServerConfig::default());
    registry.insert("emb", Arc::new(ce.clone())).unwrap();
    registry
        .insert("sq", Arc::new(ScalarQuant::fit(&sq_table, 8)))
        .unwrap();
    drive(Arc::new(EmbeddingServer::new(registry)),
          &[("emb", n), ("sq", 4000)], 4, true, "bin_4c_2tables");

    // id-space partitioning: same table, 2 batcher shards
    section("1 table, 2 batcher shards, 4 clients, bin");
    let registry = TableRegistry::new(ServerConfig {
        max_batch: 64,
        shards_per_table: 2,
        ..ServerConfig::default()
    });
    registry.insert("emb", Arc::new(ce.clone())).unwrap();
    drive(Arc::new(EmbeddingServer::new(registry)),
          &[("emb", n)], 4, true, "bin_4c_2shards");

    // cross-table fan-out: one frame spanning two tables vs two
    // sequential binary lookups on the same connection
    section("fan-out: 2 tables in one frame vs 2 sequential lookups");
    let registry = TableRegistry::new(ServerConfig::default());
    registry.insert("emb", Arc::new(ce.clone())).unwrap();
    registry
        .insert("sq", Arc::new(ScalarQuant::fit(&sq_table, 8)))
        .unwrap();
    let server = Arc::new(EmbeddingServer::new(registry));
    let (tx, rx) = mpsc::channel();
    let s2 = server.clone();
    let h = std::thread::spawn(move || {
        s2.serve("127.0.0.1:0", move |a| tx.send(a).unwrap()).unwrap();
    });
    let addr = rx.recv().unwrap();
    let mut c = Client::connect(addr).unwrap();
    let iters = 2000usize;
    let mut rng = Rng::new(11);
    let t0 = Instant::now();
    for _ in 0..iters {
        let a: Vec<usize> = (0..16).map(|_| rng.below(n)).collect();
        let b: Vec<usize> = (0..16).map(|_| rng.below(4000)).collect();
        c.lookup_bin("emb", &a).unwrap();
        c.lookup_bin("sq", &b).unwrap();
    }
    let seq = t0.elapsed().as_secs_f64() / iters as f64;
    let mut rng = Rng::new(11);
    let t0 = Instant::now();
    for _ in 0..iters {
        let a: Vec<usize> = (0..16).map(|_| rng.below(n)).collect();
        let b: Vec<usize> = (0..16).map(|_| rng.below(4000)).collect();
        c.lookup_fanout(&[("emb", &a[..]), ("sq", &b[..])]).unwrap();
    }
    let fan = t0.elapsed().as_secs_f64() / iters as f64;
    println!(
        "sequential {:.1}us vs fan-out {:.1}us per 2-table round \
         ({:.2}x)",
        seq * 1e6, fan * 1e6, seq / fan
    );
    bench::record("sequential_2tables", seq, 0.0, iters);
    bench::record("fanout_2tables", fan, 0.0, iters);
    c.shutdown().unwrap();
    h.join().unwrap();

    // eviction pressure: rotating hot loads under a memory budget that
    // holds ~3.5 of the 6 tables, so (almost) every load evicts the LRU
    section("eviction pressure: rotating table loads under --mem-budget");
    let small: Vec<_> = (0..6u64)
        .map(|i| toy_embedding(2000, 16, 8, 4, 100 + i))
        .collect();
    let per_bytes = (small[0].storage_bits() as u64).div_ceil(8);
    let registry = TableRegistry::new(ServerConfig {
        max_batch: 64,
        shards_per_table: 1,
        mem_budget_bytes: Some(3 * per_bytes + per_bytes / 2),
        ..ServerConfig::default()
    });
    registry.insert("t0", Arc::new(small[0].clone())).unwrap();
    let server = Arc::new(EmbeddingServer::new(registry));
    let (tx, rx) = mpsc::channel();
    let s2 = server.clone();
    let h = std::thread::spawn(move || {
        s2.serve("127.0.0.1:0", move |a| tx.send(a).unwrap()).unwrap();
    });
    let addr = rx.recv().unwrap();
    let mut c = Client::connect(addr).unwrap();
    let cycles = 200usize;
    let t0 = Instant::now();
    for cyc in 0..cycles {
        let i = 1 + (cyc % 5);
        let name = format!("t{i}");
        // (re)load the table if a previous cycle's budget pass evicted it
        if server.registry().get(&name).is_none() {
            server
                .registry()
                .insert(&name, Arc::new(small[i].clone()))
                .unwrap();
        }
        let ids: Vec<usize> = (0..16).map(|_| rng.below(2000)).collect();
        c.lookup_bin(&name, &ids).unwrap();
    }
    let wall = t0.elapsed().as_secs_f64();
    let reg = server.registry();
    println!(
        "{cycles} load+lookup cycles in {:.2}s ({:.0}/s): {} evictions, \
         {} tables / {} bytes resident (budget {})",
        wall, cycles as f64 / wall, reg.eviction_count(), reg.len(),
        reg.resident_bytes(), 3 * per_bytes + per_bytes / 2
    );
    bench::record("eviction_cycle", wall / cycles as f64, 0.0, cycles);
    bench::record("evictions_per_cycle",
                  reg.eviction_count() as f64 / cycles as f64, 0.0, cycles);
    c.shutdown().unwrap();
    h.join().unwrap();

    // spill tier: cold-promote latency vs resident lookups. Each cycle
    // demotes the table and pays one transparent reload on the next
    // lookup; the resident grid is the same lookup with the table hot.
    section("spill tier: promote_cold vs lookup_resident");
    let spill_dir = std::env::temp_dir().join("dpq_bench_server_spill");
    let _ = std::fs::remove_dir_all(&spill_dir);
    std::fs::create_dir_all(&spill_dir).unwrap();
    let registry = TableRegistry::open(ServerConfig {
        max_batch: 64,
        spill_dir: Some(spill_dir.clone()),
        ..ServerConfig::default()
    })
    .unwrap();
    registry.insert("emb", Arc::new(ce.clone())).unwrap();
    let server = Arc::new(EmbeddingServer::new(registry));
    let (tx, rx) = mpsc::channel();
    let s2 = server.clone();
    let h = std::thread::spawn(move || {
        s2.serve("127.0.0.1:0", move |a| tx.send(a).unwrap()).unwrap();
    });
    let addr = rx.recv().unwrap();
    let mut c = Client::connect(addr).unwrap();
    let mut rng = Rng::new(13);
    // resident baseline
    let iters = 400usize;
    let t0 = Instant::now();
    for _ in 0..iters {
        let ids: Vec<usize> = (0..16).map(|_| rng.below(n)).collect();
        c.lookup_bin("emb", &ids).unwrap();
    }
    let resident = t0.elapsed().as_secs_f64() / iters as f64;
    // cold: demote, then the first lookup pays the reload
    let cold_cycles = 25usize;
    let mut rng = Rng::new(13);
    let t0 = Instant::now();
    for _ in 0..cold_cycles {
        server.registry().demote("emb").unwrap();
        let ids: Vec<usize> = (0..16).map(|_| rng.below(n)).collect();
        c.lookup_bin("emb", &ids).unwrap();
    }
    let cold = t0.elapsed().as_secs_f64() / cold_cycles as f64;
    let reg = server.registry();
    let (p50, p99) = reg.promote_latency().unwrap_or((0.0, 0.0));
    println!(
        "resident lookup {:.1}us vs cold (demote+reload) {:.1}us per \
         request ({:.1}x); promote p50 {:.1}us p99 {:.1}us over {} promotes",
        resident * 1e6, cold * 1e6, cold / resident.max(1e-12),
        p50 * 1e6, p99 * 1e6, reg.promote_count()
    );
    bench::record("promote_cold", cold, 0.0, cold_cycles);
    bench::record("lookup_resident", resident, 0.0, iters);
    bench::record("lookup_resident_vs_spilled",
                  cold / resident.max(1e-12), 0.0, cold_cycles);
    bench::record("promote_p50_s", p50, 0.0, cold_cycles);
    c.shutdown().unwrap();
    h.join().unwrap();
    let _ = std::fs::remove_dir_all(&spill_dir);

    // replica shards for one hot table: the same 8-client closed loop
    // against replicas=1 vs replicas=3 (one shared backend, 3x the
    // batcher drain). replica_speedup = mean per-request latency ratio.
    let mut per_request = [0.0f64; 2];
    for (slot, replicas) in [(0usize, 1usize), (1, 3)] {
        section(&format!(
            "hot table, {replicas} replica(s), 8 clients, bin"));
        let registry = TableRegistry::new(ServerConfig::default());
        registry
            .insert_with_replicas("emb", Arc::new(ce.clone()), replicas)
            .unwrap();
        let server = Arc::new(EmbeddingServer::new(registry));
        let (tx, rx) = mpsc::channel();
        let s2 = server.clone();
        let h = std::thread::spawn(move || {
            s2.serve("127.0.0.1:0", move |a| tx.send(a).unwrap()).unwrap();
        });
        let addr = rx.recv().unwrap();
        let clients = 8usize;
        let per_client = 400usize;
        let t0 = Instant::now();
        let ws: Vec<_> = (0..clients)
            .map(|w| {
                std::thread::spawn(move || {
                    let mut c = Client::connect(addr).unwrap();
                    let mut rng = Rng::new(w as u64 + 1000);
                    for _ in 0..per_client {
                        let ids: Vec<usize> =
                            (0..16).map(|_| rng.below(n)).collect();
                        c.lookup_bin("emb", &ids).unwrap();
                    }
                })
            })
            .collect();
        for w in ws {
            w.join().unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        let reqs = clients * per_client;
        per_request[slot] = wall / reqs as f64;
        println!(
            "{replicas} replica(s): {reqs} requests in {wall:.2}s = \
             {:.0} req/s", reqs as f64 / wall
        );
        bench::record(&format!("lookup_replicas{replicas}_8c"),
                      per_request[slot], 0.0, reqs);
        let mut c = Client::connect(addr).unwrap();
        c.shutdown().unwrap();
        h.join().unwrap();
    }
    println!(
        "replica speedup (1 -> 3 replicas, 8 clients): {:.2}x",
        per_request[0] / per_request[1].max(1e-12)
    );
    bench::record("replica_speedup",
                  per_request[0] / per_request[1].max(1e-12), 0.0, 1);

    // TTL eviction throughput: a deterministic-clock registry with many
    // idle tables; one sweep demotes them all. Records how many tables
    // a single expire pass can retire (and that the sweep itself is
    // cheap enough to ride on the accept loop).
    section("TTL: one sweep over idle tables (ManualClock)");
    let ttl_spill = std::env::temp_dir().join("dpq_bench_server_ttl");
    let _ = std::fs::remove_dir_all(&ttl_spill);
    std::fs::create_dir_all(&ttl_spill).unwrap();
    let clock = Arc::new(dpq_embed::server::ManualClock::new());
    let registry = TableRegistry::open_with_clock(
        ServerConfig {
            max_batch: 64,
            spill_dir: Some(ttl_spill.clone()),
            ttl_secs: Some(60),
            ..ServerConfig::default()
        },
        clock.clone(),
    )
    .unwrap();
    let idle_tables = 6usize;
    registry.insert("default", Arc::new(small[0].clone())).unwrap();
    for (i, emb) in small.iter().enumerate().take(idle_tables).skip(1) {
        registry.insert(&format!("t{i}"), Arc::new(emb.clone())).unwrap();
    }
    clock.advance(std::time::Duration::from_secs(61));
    let t0 = Instant::now();
    let expired = registry.expire_idle();
    let sweep = t0.elapsed().as_secs_f64();
    println!(
        "{expired} idle tables demoted in {:.1}ms ({} resident after; \
         default pinned)",
        sweep * 1e3, registry.list().len()
    );
    bench::record("ttl_demotions", expired as f64, 0.0, 1);
    bench::record("ttl_sweep_s", sweep, 0.0, expired.max(1));
    registry.shutdown();
    let _ = std::fs::remove_dir_all(&ttl_spill);

    // connection plane: does a crowd of idle connections (each holding
    // a handler thread polling under the deadline discipline) tax the
    // hot lookup path? 64 idle peers vs none, same closed-loop client.
    section("conn plane: hot lookups with 64 idle connections held open");
    let registry = TableRegistry::new(ServerConfig {
        max_batch: 64,
        conn_timeout: Some(std::time::Duration::from_secs(600)),
        max_conns: Some(1024),
        ..ServerConfig::default()
    });
    registry.insert("emb", Arc::new(ce.clone())).unwrap();
    let server = Arc::new(EmbeddingServer::new(registry));
    let (tx, rx) = mpsc::channel();
    let s2 = server.clone();
    let h = std::thread::spawn(move || {
        s2.serve("127.0.0.1:0", move |a| tx.send(a).unwrap()).unwrap();
    });
    let addr = rx.recv().unwrap();
    let mut c = Client::connect(addr).unwrap();
    let mut rng = Rng::new(17);
    let iters = 2000usize;
    let mut lat = [0.0f64; 2];
    let mut idle: Vec<std::net::TcpStream> = Vec::new();
    for (slot, idlers) in [(0usize, 0usize), (1, 64)] {
        while idle.len() < idlers {
            idle.push(std::net::TcpStream::connect(addr).unwrap());
        }
        let t0 = Instant::now();
        for _ in 0..iters {
            let ids: Vec<usize> = (0..16).map(|_| rng.below(n)).collect();
            c.lookup_bin("emb", &ids).unwrap();
        }
        lat[slot] = t0.elapsed().as_secs_f64() / iters as f64;
    }
    println!(
        "lookup {:.1}us with 0 idle conns vs {:.1}us with 64 ({:.2}x)",
        lat[0] * 1e6, lat[1] * 1e6, lat[1] / lat[0].max(1e-12)
    );
    bench::record("lookup_0_idle_conns", lat[0], 0.0, iters);
    bench::record("lookup_64_idle_conns", lat[1], 0.0, iters);
    drop(idle);
    c.shutdown().unwrap();
    h.join().unwrap();

    // compute on codes: per-query ADC lookup-table topk vs the exact
    // reconstruct-then-dot path, over the same d=64 DPQ table. The LUT
    // scan reads 16 table entries per candidate instead of rebuilding a
    // 64-float row -- this ratio is the subsystem's reason to exist.
    section("compute on codes: topk LUT vs exact (dpq, d=64)");
    let queries: Vec<Vec<f32>> = {
        let mut rng = Rng::new(29);
        (0..20)
            .map(|_| (0..ce.d).map(|_| rng.normal()).collect())
            .collect()
    };
    let k_top = 100usize;
    let mut lut_best = Vec::new();
    let t0 = Instant::now();
    for q in &queries {
        lut_best = scoring::topk(&*ce.query_scorer(q), 0, n, k_top);
    }
    let lut_s = t0.elapsed().as_secs_f64() / queries.len() as f64;
    let mut exact_best = Vec::new();
    let t0 = Instant::now();
    for q in &queries {
        exact_best = scoring::topk(&ExactScorer::new(&ce, q), 0, n, k_top);
    }
    let exact_s = t0.elapsed().as_secs_f64() / queries.len() as f64;
    // sanity, not the equivalence proof (tests own that): rank-for-rank
    // scores stay close. The slack covers adjacent-rank swaps where two
    // candidates sit within the ADC tolerance of each other.
    let tol = scoring::adc_tolerance(ce.d) * 4.0;
    assert_eq!(lut_best.len(), exact_best.len());
    for (l, e) in lut_best.iter().zip(&exact_best) {
        assert!(
            (l.score - e.score).abs() <= tol,
            "lut topk diverged from exact: {} vs {}", l.score, e.score
        );
    }
    println!(
        "topk(k={k_top}) over {n} rows: lut {:.1}us vs exact {:.1}us per \
         query ({:.1}x); {:.1}M candidates/s on the lut path",
        lut_s * 1e6, exact_s * 1e6, exact_s / lut_s.max(1e-12),
        n as f64 / lut_s.max(1e-12) / 1e6
    );
    bench::record("topk_lut_d64", lut_s, 0.0, queries.len());
    bench::record("topk_exact_d64", exact_s, 0.0, queries.len());
    bench::record("topk_lut_vs_exact", exact_s / lut_s.max(1e-12), 0.0,
                  queries.len());
    bench::record("score_candidates_per_s", n as f64 / lut_s.max(1e-12),
                  0.0, queries.len());

    // ... and over the wire: sustained score/topk latency plus the
    // server-side score-latency ring percentiles from the stats op
    section("compute on codes: score/topk over the wire");
    let server = Arc::new(EmbeddingServer::single("emb", ce.clone(), 64));
    let (tx, rx) = mpsc::channel();
    let s2 = server.clone();
    let h = std::thread::spawn(move || {
        s2.serve("127.0.0.1:0", move |a| tx.send(a).unwrap()).unwrap();
    });
    let addr = rx.recv().unwrap();
    let mut c = Client::connect(addr).unwrap();
    let mut rng = Rng::new(31);
    let q0 = &queries[0];
    let iters = 300usize;
    let t0 = Instant::now();
    for _ in 0..iters {
        let ids: Vec<usize> = (0..64).map(|_| rng.below(n)).collect();
        c.score("emb", q0, &ids).unwrap();
    }
    let score_wire = t0.elapsed().as_secs_f64() / iters as f64;
    let topk_iters = 50usize;
    let t0 = Instant::now();
    for _ in 0..topk_iters {
        c.topk("emb", q0, 10, None).unwrap();
    }
    let topk_wire = t0.elapsed().as_secs_f64() / topk_iters as f64;
    let st = c.stats(Some("emb")).unwrap();
    let p50 = st.get("score_p50_s").and_then(|v| v.as_f64()).unwrap_or(0.0);
    let p99 = st.get("score_p99_s").and_then(|v| v.as_f64()).unwrap_or(0.0);
    println!(
        "score(64 ids) {:.1}us, topk(k=10) {:.1}us per request over the \
         wire; server-side score p50 {:.1}us p99 {:.1}us",
        score_wire * 1e6, topk_wire * 1e6, p50 * 1e6, p99 * 1e6
    );
    bench::record("score_wire_64ids", score_wire, 0.0, iters);
    bench::record("topk_wire_k10", topk_wire, 0.0, topk_iters);
    bench::record("score_p50", p50, 0.0, iters + topk_iters);
    bench::record("score_p99", p99, 0.0, iters + topk_iters);
    c.shutdown().unwrap();
    h.join().unwrap();

    // Skew-aware serving: a seeded Zipfian id stream (the access skew
    // the hot-row cache banks on) at two exponents, cache off vs 64 MiB
    // on the same table. tests/cache_equivalence.rs proves the cache is
    // bit-invisible, so this records pure latency + hit-rate movement.
    // The untagged records use s=1.2; the gentler s=1.01 runs carry a
    // _s101 suffix.
    section("skew-aware serving: Zipf lookups, row cache 0 vs 64M");
    let zvocab = 100_000usize;
    let zemb = toy_embedding(zvocab, 32, 16, 4, 37); // d = 64
    for (s_tag, s) in [("_s101", 1.01f64), ("", 1.2)] {
        let cdf = zipf_cdf(zvocab, s);
        for (c_tag, cache) in [("cache0", 0u64), ("cache64M", 64 << 20)] {
            let registry = TableRegistry::open(ServerConfig {
                max_batch: 64,
                row_cache_bytes: cache,
                ..ServerConfig::default()
            })
            .unwrap();
            registry.insert("emb", Arc::new(zemb.clone())).unwrap();
            let (addr, h) = boot(Arc::new(EmbeddingServer::new(registry)));
            let mut c = Client::connect(addr).unwrap();
            let mut rng = Rng::new(97);
            let reqs = 600usize;
            let t0 = Instant::now();
            for _ in 0..reqs {
                let ids: Vec<usize> =
                    (0..16).map(|_| zipf_sample(&cdf, &mut rng)).collect();
                c.lookup_bin("emb", &ids).unwrap();
            }
            let lat = t0.elapsed().as_secs_f64() / reqs as f64;
            let st = c.stats(Some("emb")).unwrap();
            let rate = st.get("cache_hit_rate").and_then(|v| v.as_f64())
                .unwrap_or(0.0);
            println!(
                "zipf s={s} {c_tag}: {:.1}us/req, cache hit rate {:.3}",
                lat * 1e6, rate
            );
            bench::record(&format!("lookup_zipf_{c_tag}{s_tag}"), lat,
                          0.0, reqs);
            if cache > 0 {
                bench::record(&format!("cache_hit_rate{s_tag}"), rate,
                              0.0, reqs);
            }
            c.shutdown().unwrap();
            h.join().unwrap();
        }
    }

    // MGQE-style multi-granular table (raw dense head for the hot ids,
    // DPQ tail for the cold mass) vs a flat DPQ table of the same
    // shape, under the same skewed stream: the head rows skip the
    // codebook gather entirely, which is the whole point of routing by
    // frequency.
    section("skew-aware serving: multi-granular (dense head) vs flat dpq");
    let head_n = 2_000usize;
    let head = {
        let mut rng = Rng::new(39);
        TensorF {
            shape: vec![head_n, 64],
            data: (0..head_n * 64).map(|_| rng.normal()).collect(),
        }
    };
    let mg = MultiGranular::new(vec![
        (0, Arc::new(DenseTable::new(head).unwrap()) as _),
        (head_n, Arc::new(toy_embedding(zvocab - head_n, 32, 16, 4, 38))
            as _),
    ])
    .unwrap();
    let cdf = zipf_cdf(zvocab, 1.2);
    let mut lats = [0.0f64; 2];
    for (i, backend) in [
        Arc::new(mg) as Arc<dyn dpq_embed::backend::EmbeddingBackend>,
        Arc::new(zemb.clone()) as _,
    ]
    .into_iter()
    .enumerate()
    {
        let registry = TableRegistry::new(ServerConfig {
            max_batch: 64,
            ..ServerConfig::default()
        });
        registry.insert("emb", backend).unwrap();
        let (addr, h) = boot(Arc::new(EmbeddingServer::new(registry)));
        let mut c = Client::connect(addr).unwrap();
        let mut rng = Rng::new(97); // same stream for both contenders
        let reqs = 600usize;
        let t0 = Instant::now();
        for _ in 0..reqs {
            let ids: Vec<usize> =
                (0..16).map(|_| zipf_sample(&cdf, &mut rng)).collect();
            c.lookup_bin("emb", &ids).unwrap();
        }
        lats[i] = t0.elapsed().as_secs_f64() / reqs as f64;
        c.shutdown().unwrap();
        h.join().unwrap();
    }
    let [mg_lat, dpq_lat] = lats;
    println!(
        "multi-granular {:.1}us/req vs flat dpq {:.1}us/req ({:.2}x)",
        mg_lat * 1e6, dpq_lat * 1e6, mg_lat / dpq_lat.max(1e-12)
    );
    bench::record("lookup_zipf_multigranular", mg_lat, 0.0, 600);
    bench::record("multigranular_vs_dpq", mg_lat / dpq_lat.max(1e-12),
                  0.0, 600);

    // Event-driven connection plane at scale: 1000 idle connections
    // held open (costing epoll registrations, not threads) while 64
    // hot closed-loop clients hammer lookups. The number to watch
    // across PRs is the hot-path latency staying flat vs the small
    // grids above.
    section("conn plane: 1000 idle conns + 64 hot clients (event-driven)");
    let registry = TableRegistry::new(ServerConfig {
        max_batch: 64,
        conn_timeout: Some(std::time::Duration::from_secs(600)),
        ..ServerConfig::default()
    });
    registry.insert("emb", Arc::new(ce.clone())).unwrap();
    let server = Arc::new(EmbeddingServer::new(registry));
    let (addr, h) = boot(server);
    let mut idle: Vec<std::net::TcpStream> = Vec::with_capacity(1000);
    for _ in 0..1000 {
        idle.push(std::net::TcpStream::connect(addr).unwrap());
    }
    let hot = 64usize;
    let per_client = 50usize;
    let t0 = Instant::now();
    let ws: Vec<_> = (0..hot)
        .map(|w| {
            std::thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                let mut rng = Rng::new(w as u64 + 5000);
                for _ in 0..per_client {
                    let ids: Vec<usize> =
                        (0..16).map(|_| rng.below(n)).collect();
                    c.lookup_bin("emb", &ids).unwrap();
                }
            })
        })
        .collect();
    for w in ws {
        w.join().unwrap();
    }
    let wall = t0.elapsed().as_secs_f64();
    let reqs = hot * per_client;
    println!(
        "{reqs} requests from {hot} hot clients with 1000 idle conns \
         attached: {:.2}s = {:.0} req/s",
        wall, reqs as f64 / wall
    );
    bench::record("lookup_1k_idle_64_hot", wall / reqs as f64, 0.0, reqs);
    drop(idle);
    let mut c = Client::connect(addr).unwrap();
    c.shutdown().unwrap();
    h.join().unwrap();

    // Request pipelining on one connection: the same lookup_bin frames
    // written one-at-a-time (write, read, repeat) vs all-at-once with
    // the responses read back afterwards. The gap is the per-round-trip
    // decode/dispatch overlap the readiness loop buys.
    section("conn plane: pipelined vs serial, one connection");
    let server = Arc::new(EmbeddingServer::single("emb", ce.clone(), 64));
    let (addr, h) = boot(server);
    let frame_bytes = |i: usize| -> Vec<u8> {
        let req = format!(
            "{{\"v\":2,\"op\":\"lookup_bin\",\"table\":\"emb\",\
             \"ids\":[{}]}}", i % n);
        let mut b = (req.len() as u32).to_le_bytes().to_vec();
        b.extend_from_slice(req.as_bytes());
        b
    };
    let read_frame = |s: &mut std::net::TcpStream| {
        use std::io::Read as _;
        let mut len4 = [0u8; 4];
        s.read_exact(&mut len4).unwrap();
        let mut buf = vec![0u8; u32::from_le_bytes(len4) as usize];
        s.read_exact(&mut buf).unwrap();
        buf
    };
    let iters = 2000usize;
    let mut s = std::net::TcpStream::connect(addr).unwrap();
    s.set_nodelay(true).unwrap();
    let t0 = Instant::now();
    for i in 0..iters {
        use std::io::Write as _;
        s.write_all(&frame_bytes(i)).unwrap();
        read_frame(&mut s);
    }
    let serial = t0.elapsed().as_secs_f64() / iters as f64;
    let batch: Vec<u8> =
        (0..iters).flat_map(frame_bytes).collect();
    let t0 = Instant::now();
    {
        use std::io::Write as _;
        s.write_all(&batch).unwrap();
    }
    for _ in 0..iters {
        read_frame(&mut s);
    }
    let pipelined = t0.elapsed().as_secs_f64() / iters as f64;
    println!(
        "serial {:.1}us vs pipelined {:.1}us per request ({:.2}x)",
        serial * 1e6, pipelined * 1e6, serial / pipelined.max(1e-12)
    );
    bench::record("pipelined_vs_serial_1conn",
                  serial / pipelined.max(1e-12), 0.0, iters);
    bench::record("lookup_serial_1conn", serial, 0.0, iters);
    bench::record("lookup_pipelined_1conn", pipelined, 0.0, iters);
    drop(s);
    let mut c = Client::connect(addr).unwrap();
    c.shutdown().unwrap();
    h.join().unwrap();

    // Chunked streaming: a full-vocab topk whose response is too big
    // for one frame (the JSON path rejects it too_large) delivered via
    // the v2 chunk channel.
    section("conn plane: streamed full-vocab topk past the frame cap");
    let svocab = 540_000usize;
    let sd = 4usize;
    let mut rng = Rng::new(43);
    let dense = DenseTable::new(TensorF {
        shape: vec![svocab, sd],
        data: (0..svocab * sd).map(|_| rng.normal()).collect(),
    })
    .unwrap();
    let registry = TableRegistry::new(ServerConfig::default());
    registry.insert("big", Arc::new(dense)).unwrap();
    let (addr, h) = boot(Arc::new(EmbeddingServer::new(registry)));
    let mut c = Client::connect(addr).unwrap();
    let q: Vec<f32> = (0..sd).map(|i| i as f32 - 1.5).collect();
    let stream_iters = 5usize;
    let t0 = Instant::now();
    let mut got = 0usize;
    for _ in 0..stream_iters {
        got = c.topk_stream("big", &q, svocab, None).unwrap().len();
    }
    let stream_s = t0.elapsed().as_secs_f64() / stream_iters as f64;
    assert_eq!(got, svocab);
    println!(
        "streamed topk(k = vocab = {svocab}): {:.1}ms per request \
         ({:.1} MiB payload)",
        stream_s * 1e3, (svocab * 12 + 8) as f64 / (1 << 20) as f64
    );
    bench::record("streamed_topk_full_vocab", stream_s, 0.0, stream_iters);
    c.shutdown().unwrap();
    h.join().unwrap();

    // Content-addressed artifact fetch: pull a spilled artifact back by
    // its SHA-256 digest over the v2 chunked channel -- the peer-
    // hydration transfer path (server-side read + re-hash + stream,
    // client-side reassembly + the caller's own verify).
    section("artifact store: fetch_artifact by content digest");
    let dir = std::env::temp_dir().join(format!(
        "dpq_bench_fetch_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let registry = TableRegistry::open(ServerConfig {
        spill_dir: Some(dir.clone()),
        ..ServerConfig::default()
    })
    .unwrap();
    registry.insert("emb", Arc::new(ce.clone())).unwrap();
    let slot = registry.demote("emb").unwrap();
    let (sha, art_bytes) = slot.digest().expect("fresh spill has a digest");
    let (addr, h) = boot(Arc::new(EmbeddingServer::new(registry)));
    let mut c = Client::connect(addr).unwrap();
    let fetch_iters = 50usize;
    let t0 = Instant::now();
    for _ in 0..fetch_iters {
        let got = c.fetch_artifact(&sha).unwrap();
        assert_eq!(got.len() as u64, art_bytes);
    }
    let fetch_s = t0.elapsed().as_secs_f64() / fetch_iters as f64;
    println!(
        "fetch_artifact({} KiB): {:.1}us per pull, {:.1} MiB/s",
        art_bytes / 1024, fetch_s * 1e6,
        art_bytes as f64 / fetch_s / (1 << 20) as f64
    );
    bench::record("fetch_artifact_pull", fetch_s, 0.0, fetch_iters);
    c.shutdown().unwrap();
    h.join().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}
