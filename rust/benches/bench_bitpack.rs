//! Codebook bit-packing throughput: pack / unpack / random access across
//! K (bit widths). Supports the storage claims of paper Sec. 2.1.

use dpq_embed::dpq::Codebook;
use dpq_embed::tensor::TensorI;
use dpq_embed::util::bench::{self, bench, section};
use dpq_embed::util::Rng;

fn main() {
    bench::init("bitpack");
    let n = 50_000usize;
    let dg = 32usize;
    for k in [2usize, 8, 32, 128] {
        section(&format!("n={n} D={dg} K={k}"));
        let mut rng = Rng::new(k as u64);
        let codes = TensorI::new(
            vec![n, dg],
            (0..n * dg).map(|_| rng.below(k) as i32).collect(),
        )
        .unwrap();
        let cb = Codebook::from_codes(&codes, k).unwrap();
        let m = bench("pack", 2, 20, || {
            std::hint::black_box(Codebook::from_codes(&codes, k).unwrap());
        });
        println!("   -> {:.1} M codes/s", (n * dg) as f64 / m.mean_s / 1e6);
        let m = bench("unpack to tensor", 2, 20, || {
            std::hint::black_box(cb.to_tensor());
        });
        println!("   -> {:.1} M codes/s", (n * dg) as f64 / m.mean_s / 1e6);
        let mut rng2 = Rng::new(7);
        let rows: Vec<usize> = (0..1024).map(|_| rng2.below(n)).collect();
        bench("random row access x1024", 5, 100, || {
            for &r in &rows {
                std::hint::black_box(cb.row(r));
            }
        });
        println!(
            "   storage: {} KiB ({} bits/code)",
            cb.storage_bits() / 8 / 1024,
            cb.bits()
        );
    }
}
