//! Train-step overhead bench (paper Fig. 4): wall-clock of one train step
//! for full vs DPQ-SX vs DPQ-VQ across K and D, through the real PJRT
//! executables. Prints the relative overhead the paper reports.
//!
//! Requires `make artifacts`.

use dpq_embed::coordinator::TaskGen;
use dpq_embed::runtime::{self, Runtime};
use dpq_embed::util::bench::{self, bench, section};

fn main() {
    bench::init("step_overhead");
    let dir = std::path::Path::new("artifacts");
    if !dir.join("lm_ptb_full_train.manifest.json").exists() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    let rt = Runtime::new(dir).unwrap();
    let mut step_time = |prefix: &str| -> Option<f64> {
        if !rt.exists(&format!("{prefix}_train")) {
            return None;
        }
        let init = rt.load(&format!("{prefix}_init")).unwrap();
        let train = rt.load(&format!("{prefix}_train")).unwrap();
        let mut state = runtime::run_init(&init, 7).unwrap();
        let mut gen = TaskGen::from_manifest(&train.manifest, 7).unwrap();
        let m = bench(prefix, 3, 15, || {
            let b = gen.next_batch();
            runtime::run_train(&train, &mut state, &b, 0.1).unwrap();
        });
        Some(m.mean_s)
    };

    section("LM train step (B=16, T=24, vocab=2000, d=128)");
    let full = step_time("lm_ptb_full").unwrap();
    let mut rows = Vec::new();
    for v in ["sx", "vq"] {
        for k in [2usize, 8, 32, 128] {
            for d in [8usize, 32] {
                if let Some(t) = step_time(&format!("lm_ptb_{v}_K{k}D{d}")) {
                    rows.push((v, k, d, t));
                }
            }
        }
    }
    println!("\n{:<8} {:>4} {:>4} {:>10} {:>10}", "variant", "K", "D",
             "ms/step", "overhead");
    println!("{:<8} {:>4} {:>4} {:>10.1} {:>10}", "full", "-", "-",
             full * 1e3, "0.0%");
    for (v, k, d, t) in rows {
        println!(
            "{v:<8} {k:>4} {d:>4} {:>10.1} {:>9.1}%",
            t * 1e3,
            100.0 * (t - full) / full
        );
    }
    println!(
        "\npaper Fig. 4: extra training time within ~10% for most K, D; \
         growing with K*D as the score computation dominates."
    );
}
