//! Regression tests for spill-tier startup recovery: a server restarted
//! over a populated spill directory must re-adopt every table recorded
//! in `spill.json` and serve it **bit-exactly** on first lookup, with
//! no operator intervention -- across backend kinds, across a second
//! restart, with replica counts preserved, and degrading a table whose
//! artifact vanished to the usual typed `reload_failed` (never a failed
//! startup).

use std::path::PathBuf;
use std::sync::{mpsc, Arc};

use dpq_embed::backend::DenseTable;
use dpq_embed::dpq::toy_embedding;
use dpq_embed::quant::ScalarQuant;
use dpq_embed::server::{
    Client, EmbeddingServer, Residency, Rows, ServerConfig, TableRegistry,
    WireError,
};
use dpq_embed::tensor::TensorF;
use dpq_embed::util::Rng;

fn spawn(server: Arc<EmbeddingServer>)
    -> (std::net::SocketAddr, std::thread::JoinHandle<()>) {
    let (tx, rx) = mpsc::channel();
    let h = std::thread::spawn(move || {
        server.serve("127.0.0.1:0", move |a| tx.send(a).unwrap()).unwrap();
    });
    (rx.recv().unwrap(), h)
}

fn bits_equal(a: &Rows, b: &Rows) -> bool {
    a.n() == b.n()
        && a.d() == b.d()
        && a.as_slice().iter().zip(b.as_slice())
            .all(|(x, y)| x.to_bits() == y.to_bits())
}

fn fresh_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("dpq_spill_recovery_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn cfg(dir: &PathBuf) -> ServerConfig {
    ServerConfig {
        max_batch: 16,
        shards_per_table: 2,
        spill_dir: Some(dir.clone()),
        ..ServerConfig::default()
    }
}

/// The acceptance path: populate a spill tier (three backend kinds, one
/// table replicated), kill the server, restart over the same directory,
/// and bit-compare every table's bytes over the wire. Then restart a
/// SECOND time to prove recovery is re-entrant (the manifest tracks
/// promote/demote churn between restarts).
#[test]
fn restarted_server_serves_spilled_tables_bit_exactly() {
    let dir = fresh_dir("roundtrip");
    let mut rng = Rng::new(31);
    let table = TensorF {
        shape: vec![40, 8],
        data: (0..40 * 8).map(|_| rng.normal()).collect(),
    };

    let ids_dpq: Vec<usize> = (0..20).map(|i| (i * 17) % 200).collect();
    let ids_40: Vec<usize> = (0..20).map(|i| (i * 7) % 40).collect();
    let (expect_dpq, expect_dense, expect_sq);

    // ---- first life: load, record bytes, demote everything, die ----
    {
        let reg = TableRegistry::open(cfg(&dir)).unwrap();
        reg.insert("dpq", Arc::new(toy_embedding(200, 16, 4, 3, 5))).unwrap();
        reg.insert_with_replicas(
            "dense", Arc::new(DenseTable::new(table.clone()).unwrap()), 3)
            .unwrap();
        reg.insert("sq", Arc::new(ScalarQuant::fit(&table, 6))).unwrap();
        let server = Arc::new(EmbeddingServer::new(reg));
        let (addr, h) = spawn(server.clone());
        let mut c = Client::connect(addr).unwrap();
        expect_dpq = c.lookup_bin("dpq", &ids_dpq).unwrap();
        expect_dense = c.lookup_bin("dense", &ids_40).unwrap();
        expect_sq = c.lookup_bin("sq", &ids_40).unwrap();
        // demote every table (the default included -- allowed) so the
        // whole registry lives in the spill tier when the process dies
        for name in ["dpq", "dense", "sq"] {
            c.admin_demote(name).unwrap();
        }
        c.shutdown().unwrap();
        h.join().unwrap();
    }

    // ---- second life: recovery is automatic at open() ----
    let reg = TableRegistry::open(cfg(&dir)).unwrap();
    assert_eq!(reg.len(), 3, "all spilled tables must be re-adopted");
    for name in ["dpq", "dense", "sq"] {
        assert_eq!(reg.residency(name), Some(Residency::Spilled), "{name}");
    }
    let server = Arc::new(EmbeddingServer::new(reg));
    let (addr, h) = spawn(server.clone());
    let mut c = Client::connect(addr).unwrap();
    // first lookups transparently promote; bytes bit-identical
    let got_dpq = c.lookup_bin("dpq", &ids_dpq).unwrap();
    let got_dense = c.lookup_bin("dense", &ids_40).unwrap();
    let got_sq = c.lookup_bin("sq", &ids_40).unwrap();
    assert!(bits_equal(&got_dpq, &expect_dpq), "dpq diverged after restart");
    assert!(bits_equal(&got_dense, &expect_dense),
            "dense diverged after restart");
    assert!(bits_equal(&got_sq, &expect_sq), "sq diverged after restart");
    // the recorded replica count came back with the table
    let entry = server.registry().get("dense").unwrap();
    assert_eq!((entry.replica_count(), entry.shard_count()), (3, 2));
    let st = c.stats(None).unwrap();
    assert_eq!(st.get("promotes").and_then(|v| v.as_usize()), Some(3));

    // ---- third life: re-entrant -- demote ONE table, restart again ----
    c.admin_demote("sq").unwrap();
    c.shutdown().unwrap();
    h.join().unwrap();
    let reg = TableRegistry::open(cfg(&dir)).unwrap();
    // only sq was spilled when the second life ended; dpq/dense were
    // resident (their promotion consumed the artifacts) and are gone --
    // recovery recovers the spill TIER, residency is not a snapshot
    assert_eq!(reg.len(), 1);
    assert_eq!(reg.residency("sq"), Some(Residency::Spilled));
    let server = Arc::new(EmbeddingServer::new(reg));
    let (addr, h) = spawn(server.clone());
    let mut c = Client::connect(addr).unwrap();
    let got_sq = c.lookup_bin("sq", &ids_40).unwrap();
    assert!(bits_equal(&got_sq, &expect_sq), "sq diverged after 2nd restart");
    c.shutdown().unwrap();
    h.join().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Degraded recovery: an artifact deleted while the server was down
/// adopts as Lost (startup succeeds; lookups answer `reload_failed`;
/// restoring the file heals it), and a spilled table is a valid default
/// -- the first v1 frame transparently promotes it.
#[test]
fn recovery_degrades_lost_artifacts_and_promotes_spilled_default() {
    let dir = fresh_dir("lost");
    let mut rng = Rng::new(57);
    let t_keep = TensorF {
        shape: vec![30, 4],
        data: (0..30 * 4).map(|_| rng.normal()).collect(),
    };
    let keep_file;
    let gone_file;
    {
        let reg = TableRegistry::open(cfg(&dir)).unwrap();
        reg.insert("keep", Arc::new(DenseTable::new(t_keep.clone()).unwrap()))
            .unwrap();
        reg.insert("gone", Arc::new(DenseTable::new(TensorF {
            shape: vec![10, 3],
            data: (0..30).map(|_| rng.normal()).collect(),
        }).unwrap())).unwrap();
        keep_file = reg.demote("keep").unwrap().file().to_string();
        gone_file = reg.demote("gone").unwrap().file().to_string();
        reg.shutdown();
    }
    // the crash window ate one artifact
    std::fs::remove_file(dir.join(&gone_file)).unwrap();
    let backup = std::fs::read(dir.join(&keep_file)).unwrap();

    let reg = TableRegistry::open(cfg(&dir)).unwrap();
    assert_eq!(reg.residency("keep"), Some(Residency::Spilled));
    assert_eq!(reg.residency("gone"), Some(Residency::Lost));
    // "gone" sorts first, so it was adopted first and elected default;
    // that is fine -- defaults may be spilled or even lost
    let server = Arc::new(EmbeddingServer::new(reg));
    let (addr, h) = spawn(server.clone());
    let mut c = Client::connect(addr).unwrap();
    match c.lookup_bin("gone", &[0]) {
        Err(WireError::Rejected { code, .. }) => {
            assert_eq!(code, "reload_failed")
        }
        other => panic!("{other:?}"),
    }
    // the healthy table serves bit-exact rows regardless
    let rows = c.lookup_bin("keep", &[3, 29, 0]).unwrap();
    assert_eq!(rows.row(0), &t_keep.data[3 * 4..4 * 4]);
    // a file reappears at the lost path -- but with the WRONG content
    // (it is keep's artifact): the probe heals the Lost phase, and the
    // promote must then fail loudly on the recorded content digest
    // (before any parse) rather than serve keep's rows under gone's name
    std::fs::write(dir.join(&gone_file), &backup).unwrap();
    match c.lookup_bin("gone", &[0]) {
        Err(WireError::Rejected { code, message }) => {
            assert_eq!(code, "reload_failed");
            assert!(message.contains("digest"), "{message}");
        }
        other => panic!("{other:?}"),
    }
    c.shutdown().unwrap();
    h.join().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}
