//! Registry lifecycle acceptance tests (no artifacts needed):
//!
//! * **snapshot -> restore**: a 2-table registry (DPQ + LowRank) is
//!   snapshotted over the wire (`snapshot` op), the server is torn down,
//!   and a registry restored from the manifest serves bytes
//!   BIT-identical to the pre-snapshot server -- including one
//!   cross-table fan-out frame spanning both tables, which must match
//!   the per-table `lookup_bin` answers exactly.
//! * **memory budget / LRU eviction**: eviction fires when a hot `load`
//!   pushes the resident total past `--mem-budget`, evicts the
//!   least-recently-looked-up table, pins the default, marks the victim
//!   in `stats` (and on the rejection frame as `"evicted": true`), and
//!   the server keeps serving the surviving tables -- a lookup to the
//!   evicted table is a typed `no_such_table`, never a wedged batcher.

use std::net::TcpStream;
use std::sync::{mpsc, Arc};

use dpq_embed::backend::EmbeddingBackend;
use dpq_embed::dpq::toy_embedding;
use dpq_embed::jsonx::Json;
use dpq_embed::quant::LowRank;
use dpq_embed::server::{
    read_frame, write_frame, Client, EmbeddingServer, Rows, ServerConfig,
    TableRegistry, WireError, SNAPSHOT_MANIFEST,
};
use dpq_embed::tensor::TensorF;
use dpq_embed::util::Rng;

fn spawn(server: Arc<EmbeddingServer>)
    -> (std::net::SocketAddr, std::thread::JoinHandle<()>) {
    let (tx, rx) = mpsc::channel();
    let h = std::thread::spawn(move || {
        server.serve("127.0.0.1:0", move |a| tx.send(a).unwrap()).unwrap();
    });
    (rx.recv().unwrap(), h)
}

fn bits_equal(a: &Rows, b: &Rows) -> bool {
    a.n() == b.n()
        && a.d() == b.d()
        && a.as_slice().iter().zip(b.as_slice())
            .all(|(x, y)| x.to_bits() == y.to_bits())
}

#[test]
fn snapshot_restore_serves_bit_identical_bytes_and_fanout_matches() {
    let dir = std::env::temp_dir().join("dpq_lifecycle_snapshot");
    let _ = std::fs::remove_dir_all(&dir);

    // two backends with different widths: DPQ d = 12, LowRank d = 20
    let dpq = toy_embedding(300, 16, 4, 3, 5);
    let mut rng = Rng::new(17);
    let table = TensorF {
        shape: vec![120, 20],
        data: (0..120 * 20).map(|_| rng.normal()).collect(),
    };
    let lr = LowRank::fit(&table, 5);

    let registry = TableRegistry::new(ServerConfig {
        max_batch: 32,
        shards_per_table: 2,
        ..ServerConfig::default()
    });
    registry.insert("dpq", Arc::new(dpq)).unwrap();
    registry.insert("lr", Arc::new(lr)).unwrap();
    registry.set_default("lr").unwrap();

    let server = Arc::new(EmbeddingServer::new(registry));
    let (addr, h) = spawn(server.clone());
    let mut c = Client::connect(addr).unwrap();

    let dpq_ids: Vec<usize> = (0..64).map(|i| (i * 37) % 300).collect();
    let lr_ids: Vec<usize> = (0..32).map(|i| (i * 11) % 120).collect();
    let before_dpq = c.lookup_bin("dpq", &dpq_ids).unwrap();
    let before_lr = c.lookup_bin("lr", &lr_ids).unwrap();

    // acceptance: one fan-out frame spanning both tables matches the
    // per-table lookups exactly
    let sections = c
        .lookup_fanout(&[("dpq", &dpq_ids[..]), ("lr", &lr_ids[..])])
        .unwrap();
    assert_eq!(sections.len(), 2);
    assert!(bits_equal(&sections[0], &before_dpq),
            "fan-out dpq section differs from lookup_bin");
    assert!(bits_equal(&sections[1], &before_lr),
            "fan-out lr section differs from lookup_bin");

    // live snapshot over the wire, then tear the first server down
    let manifest = c.admin_snapshot(dir.to_str().unwrap()).unwrap();
    assert!(manifest.ends_with(SNAPSHOT_MANIFEST), "{manifest}");
    assert!(std::path::Path::new(&manifest).is_file());
    c.shutdown().unwrap();
    h.join().unwrap();

    // restore: same tables, same default, same shard config ...
    let restored =
        TableRegistry::restore(std::path::Path::new(&manifest), None).unwrap();
    assert_eq!(restored.len(), 2);
    assert_eq!(restored.default_name().as_deref(), Some("lr"));
    let cfg = restored.config();
    assert_eq!((cfg.max_batch, cfg.shards_per_table), (32, 2));

    let server2 = Arc::new(EmbeddingServer::new(restored));
    let (addr2, h2) = spawn(server2.clone());
    let mut c2 = Client::connect(addr2).unwrap();
    for t in c2.tables().unwrap() {
        assert_eq!(t.shards, 2);
        assert_eq!(t.is_default, t.name == "lr");
    }

    // ... and bit-identical served bytes, per table and fanned out
    let after_dpq = c2.lookup_bin("dpq", &dpq_ids).unwrap();
    let after_lr = c2.lookup_bin("lr", &lr_ids).unwrap();
    assert!(bits_equal(&after_dpq, &before_dpq),
            "restored dpq table serves different bytes");
    assert!(bits_equal(&after_lr, &before_lr),
            "restored lr table serves different bytes");
    let sections = c2
        .lookup_fanout(&[("dpq", &dpq_ids[..]), ("lr", &lr_ids[..])])
        .unwrap();
    assert!(bits_equal(&sections[0], &before_dpq));
    assert!(bits_equal(&sections[1], &before_lr));
    // restored sections stay self-describing (d from the header)
    let sections = c2.lookup_fanout(&[("dpq", &dpq_ids[..2])]).unwrap();
    assert_eq!((sections[0].n(), sections[0].d()), (2, 12));

    c2.shutdown().unwrap();
    h2.join().unwrap();
}

#[test]
fn eviction_fires_at_budget_pins_default_and_stays_serving() {
    use dpq_embed::backend::DenseTable;

    let dense = |seed: u64| {
        let mut rng = Rng::new(seed);
        Arc::new(DenseTable::new(TensorF {
            shape: vec![10, 4],
            data: (0..40).map(|_| rng.normal()).collect(),
        }).unwrap())
    };
    let bytes_per_dense = 10 * 4 * 4u64; // 160

    // the hot-loaded DPQ table that will push the registry over budget
    let hot = toy_embedding(16, 8, 2, 2, 1);
    let hot_bytes = (EmbeddingBackend::storage_bits(&hot) as u64).div_ceil(8);
    let hot_path = std::env::temp_dir().join("dpq_lifecycle_hot.dpq");
    hot.save(&hot_path).unwrap();

    // budget fits both dense tables plus half the hot table: the load
    // must evict exactly one table to fit
    let registry = TableRegistry::new(ServerConfig {
        max_batch: 8,
        shards_per_table: 1,
        mem_budget_bytes: Some(2 * bytes_per_dense + hot_bytes / 2),
        ..ServerConfig::default()
    });
    registry.insert("base", dense(1)).unwrap(); // default -> pinned
    registry.insert("aux", dense(2)).unwrap();

    let server = Arc::new(EmbeddingServer::new(registry));
    let (addr, h) = spawn(server.clone());
    let mut c = Client::connect(addr).unwrap();

    // LRU order: touch aux, then base, so aux is the stalest non-default
    c.lookup_bin("aux", &[0, 1]).unwrap();
    c.lookup_bin("base", &[2]).unwrap();

    // hot load exceeds the budget -> aux is evicted (base is pinned as
    // default, "hot" is pinned as the fresh insert)
    let desc = c.admin_load("hot", hot_path.to_str().unwrap()).unwrap();
    assert_eq!(desc.kind, "dpq");
    let names: Vec<String> =
        c.tables().unwrap().into_iter().map(|t| t.name).collect();
    assert_eq!(names, vec!["base".to_string(), "hot".to_string()]);

    // a lookup to the evicted table is a typed no_such_table on both
    // protocols -- not a hang, not a wedged batcher
    match c.lookup_bin("aux", &[0]) {
        Err(WireError::NoSuchTable(t)) => assert_eq!(t, "aux"),
        other => panic!("expected typed no_such_table, got {other:?}"),
    }
    match c.lookup("aux", &[0]) {
        Err(WireError::NoSuchTable(t)) => assert_eq!(t, "aux"),
        other => panic!("expected typed no_such_table, got {other:?}"),
    }
    // the JSON rejection frame distinguishes "evicted" from "never
    // existed"
    let mut raw = TcpStream::connect(addr).unwrap();
    write_frame(&mut raw, r#"{"v":2,"op":"lookup","table":"aux","ids":[0]}"#)
        .unwrap();
    let resp = Json::parse(&read_frame(&mut raw).unwrap()).unwrap();
    assert_eq!(resp.get("code").and_then(|v| v.as_str()), Some("no_such_table"));
    assert_eq!(resp.get("evicted").and_then(|v| v.as_bool()), Some(true));
    write_frame(&mut raw, r#"{"v":2,"op":"lookup","table":"ghost","ids":[0]}"#)
        .unwrap();
    let resp = Json::parse(&read_frame(&mut raw).unwrap()).unwrap();
    assert_eq!(resp.get("code").and_then(|v| v.as_str()), Some("no_such_table"));
    assert!(resp.get("evicted").is_none(),
            "a never-loaded table must not be marked evicted");

    // eviction telemetry in the aggregate stats
    let st = c.stats(None).unwrap();
    assert_eq!(st.get("evictions").unwrap().as_usize(), Some(1));
    assert!(st.get("mem_budget_bytes").unwrap().as_f64().unwrap() > 0.0);
    assert!(st.get("resident_bytes").unwrap().as_f64().unwrap() > 0.0);
    assert_eq!(
        st.get("evicted").unwrap().get("aux").unwrap().as_usize(), Some(1));

    // the survivors keep serving: default pinned, fresh insert live
    let row = c.lookup_bin("base", &[9]).unwrap();
    assert_eq!((row.n(), row.d()), (1, 4));
    let row = c.lookup_bin("hot", &[15]).unwrap();
    assert_eq!((row.n(), row.d()), (1, 4));

    // Reloading the evicted name serves again and clears its marker.
    // This re-insert itself exceeds the budget; "base" is pinned
    // (default) and "aux" is pinned (fresh insert), so "hot" -- the only
    // candidate -- is evicted in turn.
    let mut rng = Rng::new(3);
    server
        .registry()
        .insert("aux", Arc::new(DenseTable::new(TensorF {
            shape: vec![10, 4],
            data: (0..40).map(|_| rng.normal()).collect(),
        }).unwrap()))
        .unwrap();
    let st = c.stats(None).unwrap();
    assert!(st.get("evicted").map(|e| e.get("aux").is_none()).unwrap_or(true),
            "reload must clear the evicted marker");
    let row = c.lookup_bin("aux", &[3]).unwrap();
    assert_eq!((row.n(), row.d()), (1, 4));

    c.shutdown().unwrap();
    h.join().unwrap();
}
