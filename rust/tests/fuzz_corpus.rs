//! Tier-1 corpus replay: every committed crasher/regression input under
//! `tests/corpus/` is driven through a live in-process server by the
//! in-tree fuzzer harness (`server::fuzz`), asserting the full wire
//! invariant set -- typed rejection or clean close, no handler panic,
//! no wedge, bounded shutdown join. A fresh hostile input that slips
//! past the defenses fails HERE first, before any long fuzz run.

use std::path::PathBuf;

use dpq_embed::server::fuzz::{run, FuzzConfig};

fn corpus_dir() -> PathBuf {
    // cargo runs integration tests with CWD = crate root, but resolve
    // via the manifest dir so `cargo test` works from anywhere
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests").join("corpus")
}

#[test]
fn committed_corpus_replays_clean() {
    let dir = corpus_dir();
    assert!(dir.is_dir(), "committed corpus missing at {dir:?}");
    let report = run(&FuzzConfig {
        seed: 1,
        iters: 0, // replay only -- generation is the fuzz subcommand's job
        corpus_dir: Some(dir),
        ..FuzzConfig::default()
    })
    .expect("fuzz harness failed to start");
    assert!(
        report.corpus_replayed >= 24,
        "corpus shrank? only {} inputs replayed", report.corpus_replayed
    );
    assert_eq!(report.handler_panics, 0, "corpus input panicked a handler");
    assert!(report.ok(), "corpus replay failures: {:?}", report.failures);
}

/// A short generated run doubles as a smoke test that the generator +
/// oracle machinery itself stays healthy under `cargo test`.
#[test]
fn short_generated_run_is_clean() {
    let report = run(&FuzzConfig {
        seed: 1302,
        iters: 60,
        corpus_dir: None,
        ..FuzzConfig::default()
    })
    .expect("fuzz harness failed to start");
    assert_eq!(report.cases_sent, 60);
    assert!(report.ok(), "generated-run failures: {:?}", report.failures);
}
