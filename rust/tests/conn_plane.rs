//! Event-driven connection-plane acceptance tests: the epoll readiness
//! loop must hold the OS-thread count FLAT in the connection count
//! (1000 idle + 64 hot connections, zero extra threads), answer
//! pipelined same-connection requests strictly in request order, stream
//! responses past the single-frame cap, keep the hardened-close
//! semantics (slow-loris timeout, mid-frame disconnect) of the threaded
//! plane, and serve bytes **bit-identical** to it (`--pollers 0`).
//!
//! Everything here runs without artifacts, like `conn_hardening.rs`
//! (which exercises the same defenses on the DEFAULT config -- also the
//! event plane -- while this file pins poller counts explicitly).

#![cfg(target_os = "linux")]

use std::io::{Read as _, Write as _};
use std::net::TcpStream;
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc};
use std::time::Duration;

use dpq_embed::backend::DenseTable;
use dpq_embed::dpq::{toy_embedding, CompressedEmbedding};
use dpq_embed::jsonx::Json;
use dpq_embed::scoring;
use dpq_embed::server::{
    Client, EmbeddingServer, ServerConfig, TableRegistry, WireError,
};
use dpq_embed::tensor::TensorF;
use dpq_embed::util::Rng;

fn toy() -> CompressedEmbedding {
    toy_embedding(48, 8, 4, 3, 1)
}

/// Boot a server over one DPQ table ("emb") with the given config.
fn spawn(cfg: ServerConfig) -> (
    std::net::SocketAddr,
    std::thread::JoinHandle<()>,
    Arc<TableRegistry>,
) {
    let registry = TableRegistry::new(cfg);
    registry.insert("emb", Arc::new(toy())).unwrap();
    let server = Arc::new(EmbeddingServer::new(registry));
    let registry = server.registry();
    let (tx, rx) = mpsc::channel();
    let h = std::thread::spawn(move || {
        server.serve("127.0.0.1:0", move |a| tx.send(a).unwrap()).unwrap();
    });
    (rx.recv().unwrap(), h, registry)
}

/// Read one length-prefixed frame raw (None on EOF / short read).
fn read_raw_frame(s: &mut TcpStream) -> Option<Vec<u8>> {
    let mut len4 = [0u8; 4];
    s.read_exact(&mut len4).ok()?;
    let n = u32::from_le_bytes(len4) as usize;
    let mut buf = vec![0u8; n];
    s.read_exact(&mut buf).ok()?;
    Some(buf)
}

fn frame_code(payload: &[u8]) -> Option<String> {
    let j = Json::parse(std::str::from_utf8(payload).ok()?).ok()?;
    Some(j.get("code")?.as_str()?.to_string())
}

fn frame(payload: &[u8]) -> Vec<u8> {
    let mut b = (payload.len() as u32).to_le_bytes().to_vec();
    b.extend_from_slice(payload);
    b
}

fn assert_bit_exact(c: &mut Client, emb: &CompressedEmbedding, ids: &[usize]) {
    let rows = c.lookup_bin("emb", ids).unwrap();
    for (k, &id) in ids.iter().enumerate() {
        assert_eq!(rows.row(k), &emb.reconstruct_row(id)[..],
                   "served row for id {id} not bit-exact");
    }
}

/// This process's live OS-thread count (`Threads:` in
/// `/proc/self/status`) -- server and test share the process, so a
/// plane that spawned per-connection threads would show up here.
fn os_thread_count() -> usize {
    std::fs::read_to_string("/proc/self/status")
        .unwrap()
        .lines()
        .find_map(|l| l.strip_prefix("Threads:"))
        .expect("/proc/self/status without a Threads: line")
        .trim()
        .parse()
        .unwrap()
}

/// THE tentpole claim: 1000 idle connections plus 64 actively-served
/// ones add ZERO threads beyond the fixed poller/worker pool, while
/// every hot connection keeps getting bit-exact rows. (The threaded
/// plane would sit at +1064 here.)
#[test]
fn thousand_idle_and_64_hot_conns_flat_thread_count() {
    let (addr, h, registry) = spawn(ServerConfig {
        pollers: 2,
        ..ServerConfig::default()
    });
    let emb = toy();
    // warm up: first connection, first batch, lazy pools
    let mut warm = Client::connect(addr).unwrap();
    assert_bit_exact(&mut warm, &emb, &[0, 1]);
    let baseline = os_thread_count();

    let mut idle: Vec<TcpStream> = Vec::with_capacity(1000);
    for i in 0..1000 {
        // bounded retry: a briefly-full accept queue must not flake
        let mut conn = None;
        for _ in 0..50 {
            match TcpStream::connect(addr) {
                Ok(s) => { conn = Some(s); break; }
                Err(_) => std::thread::sleep(Duration::from_millis(10)),
            }
        }
        idle.push(conn.unwrap_or_else(|| panic!("idle conn {i} refused")));
    }
    let mut hot: Vec<Client> = (0..64)
        .map(|_| Client::connect(addr).unwrap())
        .collect();
    for round in 0..4 {
        for (ci, c) in hot.iter_mut().enumerate() {
            assert_bit_exact(c, &emb, &[(ci + round) % 48, (ci * 7) % 48]);
        }
    }
    // Sibling tests in this binary run on parallel harness threads and
    // boot their own (fixed-size) server pools, so give the count a
    // moment to settle and allow a small unrelated-noise slack: the
    // claim under test is the ABSENCE of the +1064 a thread-per-
    // connection plane would add, and 64 is 16x below that.
    let mut loaded = os_thread_count();
    for _ in 0..40 {
        if loaded <= baseline {
            break;
        }
        std::thread::sleep(Duration::from_millis(50));
        loaded = os_thread_count();
    }
    assert!(
        loaded <= baseline + 64,
        "1064 extra connections grew the thread count \
         ({baseline} -> {loaded}): the plane is not event-driven"
    );
    assert!(
        registry.conn_stats().conns_open.load(Ordering::Relaxed) >= 1065,
        "all idle + hot connections must be accepted and open"
    );

    // hot connections still bit-exact with the idle herd attached
    for (ci, c) in hot.iter_mut().enumerate() {
        assert_bit_exact(c, &emb, &[(ci * 13 + 5) % 48]);
    }
    warm.shutdown().unwrap();
    h.join().unwrap();
    // graceful drain closed the idle herd too
    assert_eq!(registry.conn_stats().conns_open.load(Ordering::Relaxed), 0);
    for (i, s) in idle.iter_mut().enumerate() {
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let mut b = [0u8; 1];
        assert_eq!(s.read(&mut b).unwrap_or(0), 0,
                   "idle conn {i} must see EOF after shutdown");
    }
}

/// Pipelining: a client that writes many frames back-to-back (more than
/// the per-connection inbox holds) gets every response, strictly in
/// request order, each bit-exact -- including a typed `malformed` error
/// frame in the middle that must NOT desync the stream.
#[test]
fn pipelined_requests_answered_in_request_order() {
    let (addr, h, _registry) = spawn(ServerConfig {
        pollers: 1,
        ..ServerConfig::default()
    });
    let emb = toy();
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let bad_at = 7usize;
    let mut burst = Vec::new();
    for i in 0..20usize {
        if i == bad_at {
            burst.extend_from_slice(&frame(b"{\"op\":")); // bad JSON
        } else {
            burst.extend_from_slice(&frame(format!(
                "{{\"v\":2,\"op\":\"lookup_bin\",\"table\":\"emb\",\
                 \"ids\":[{}]}}", i % 48).as_bytes()));
        }
    }
    // one write: decode of frame k+1 overlaps dispatch of frame k
    s.write_all(&burst).unwrap();
    for i in 0..20usize {
        let f = read_raw_frame(&mut s)
            .unwrap_or_else(|| panic!("response {i} missing"));
        if i == bad_at {
            assert_eq!(frame_code(&f).as_deref(), Some("malformed"),
                       "response {i} must be the typed malformed answer");
            continue;
        }
        assert_eq!(&f[..4], &1u32.to_le_bytes(), "response {i}: n");
        assert_eq!(&f[4..8], &12u32.to_le_bytes(), "response {i}: d");
        let got: Vec<f32> = f[8..].chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        assert_eq!(got, emb.reconstruct_row(i % 48),
                   "response {i} out of order or not bit-exact");
    }
    let mut c = Client::connect(addr).unwrap();
    c.shutdown().unwrap();
    h.join().unwrap();
}

/// Streamed responses return exactly the unstreamed results where both
/// paths exist (small topk, small fanout) -- the chunked channel is an
/// encoding change, not a semantics change.
#[test]
fn streamed_results_match_unstreamed() {
    let (addr, h, _registry) = spawn(ServerConfig {
        pollers: 2,
        ..ServerConfig::default()
    });
    let mut c = Client::connect(addr).unwrap();
    let query = vec![0.25f32; 12];
    let plain = c.topk("emb", &query, 9, None).unwrap();
    let streamed = c.topk_stream("emb", &query, 9, None).unwrap();
    assert_eq!(plain, streamed, "streamed topk diverged from unstreamed");
    let ids: Vec<usize> = (0..17).collect();
    let queries: Vec<(&str, &[usize])> =
        vec![("emb", &ids[..]), ("emb", &ids[..3])];
    let plain = c.lookup_fanout(&queries).unwrap();
    let streamed = c.lookup_fanout_stream(&queries).unwrap();
    assert_eq!(plain, streamed, "streamed fanout diverged from unstreamed");
    // streamed rejections arrive typed on the binary channel
    match c.topk_stream("missing", &query, 3, None) {
        Err(WireError::NoSuchTable(t)) => assert_eq!(t, "missing"),
        other => panic!("expected NoSuchTable, got {other:?}"),
    }
    // ... and the connection is still usable afterwards
    assert_eq!(c.topk("emb", &query, 1, None).unwrap().len(), 1);
    c.shutdown().unwrap();
    h.join().unwrap();
}

/// A full-vocab `topk` whose response exceeds the 64 MiB single-frame
/// cap: the unstreamed op answers the typed `too_large` rejection it
/// always has, while `"stream": true` delivers all `vocab` results in
/// bounded chunks, identical to a local reference scan.
#[test]
fn full_vocab_topk_streams_past_the_frame_cap() {
    // k * 2 * 64 > MAX_FRAME (64 MiB) at k > 524288: this vocab is past
    // the cap for the JSON path, modest in memory (d stays tiny)
    let vocab = 540_000usize;
    let d = 4usize;
    let mut rng = Rng::new(11);
    let table = TensorF {
        shape: vec![vocab, d],
        data: (0..vocab * d).map(|_| rng.normal()).collect(),
    };
    let dense = DenseTable::new(table).unwrap();
    let query: Vec<f32> = (0..d).map(|i| 0.5 + i as f32).collect();
    let want = {
        let sb = dense.scorer().expect("dense tables score");
        let qs = sb.query_scorer(&query);
        scoring::topk(&*qs, 0, vocab, vocab)
    };
    let registry = TableRegistry::new(ServerConfig {
        pollers: 2,
        ..ServerConfig::default()
    });
    registry.insert("big", Arc::new(dense)).unwrap();
    let server = Arc::new(EmbeddingServer::new(registry));
    let (tx, rx) = mpsc::channel();
    let h = std::thread::spawn(move || {
        server.serve("127.0.0.1:0", move |a| tx.send(a).unwrap()).unwrap();
    });
    let addr = rx.recv().unwrap();
    let mut c = Client::connect(addr).unwrap();
    match c.topk("big", &query, vocab, None) {
        Err(WireError::Rejected { code, .. }) => assert_eq!(
            code, "too_large",
            "unstreamed full-vocab topk must reject over the frame cap"),
        other => panic!("expected too_large, got {other:?}"),
    }
    let got = c.topk_stream("big", &query, vocab, None).unwrap();
    assert_eq!(got.len(), vocab, "streamed topk must return ALL results");
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        assert_eq!(*g, (w.id, w.score),
                   "streamed rank {i} diverged from the local reference");
    }
    c.shutdown().unwrap();
    h.join().unwrap();
}

/// The hardened-close semantics carry over to the event plane: a
/// mid-frame trickle staller and an idle connection both get the typed
/// `timeout` close (counted), mid-frame disconnects close silently, and
/// a concurrent healthy client never notices any of it.
#[test]
fn slow_loris_and_mid_frame_disconnects_on_event_plane() {
    let (addr, h, registry) = spawn(ServerConfig {
        pollers: 1,
        conn_timeout: Some(Duration::from_millis(400)),
        ..ServerConfig::default()
    });
    let emb = toy();
    // staller 1: length prefix claiming 64 bytes, then silence
    let mut loris = TcpStream::connect(addr).unwrap();
    loris.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    loris.write_all(&64u32.to_le_bytes()).unwrap();
    // staller 2: never writes a byte
    let mut idle = TcpStream::connect(addr).unwrap();
    idle.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    // vanishing peers: mid-frame, mid-prefix, and right after connect
    for i in 0..6 {
        let mut s = TcpStream::connect(addr).unwrap();
        match i % 3 {
            0 => {
                s.write_all(&100u32.to_le_bytes()).unwrap();
                s.write_all(&[b'x'; 10]).unwrap();
            }
            1 => s.write_all(&[0x01]).unwrap(),
            _ => {}
        }
        drop(s);
    }
    // oversized claim: typed rejection (bit-identical message), close
    let mut over = TcpStream::connect(addr).unwrap();
    over.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    over.write_all(&(((64u32) << 20) + 1).to_le_bytes()).unwrap();
    let f = read_raw_frame(&mut over).expect("expected too_large frame");
    assert_eq!(frame_code(&f).as_deref(), Some("too_large"));
    // healthy client throughout
    let mut c = Client::connect(addr).unwrap();
    for i in 0..20 {
        assert_bit_exact(&mut c, &emb, &[i % 48, (i * 5 + 2) % 48]);
    }
    for (name, s) in [("loris", &mut loris), ("idle", &mut idle)] {
        let f = read_raw_frame(s)
            .unwrap_or_else(|| panic!("{name}: expected a timeout frame"));
        assert_eq!(frame_code(&f).as_deref(), Some("timeout"), "{name}");
        let mut rest = [0u8; 1];
        assert_eq!(s.read(&mut rest).unwrap_or(0), 0, "{name}: expected EOF");
    }
    assert!(
        registry.conn_stats().conn_timeouts.load(Ordering::Relaxed) >= 2,
        "both stalled connections must be counted"
    );
    c.shutdown().unwrap();
    h.join().unwrap();
}

/// Run one scripted mixed workload against a server, returning every
/// raw response frame (requests sent one at a time, one frame back
/// each, so the comparison is framing-inclusive).
fn scripted_responses(addr: std::net::SocketAddr) -> Vec<Vec<u8>> {
    let reqs: Vec<Vec<u8>> = vec![
        br#"{"op":"lookup","ids":[0,5,11]}"#.to_vec(),
        br#"{"v":2,"op":"lookup_bin","table":"emb","ids":[7,7,46]}"#.to_vec(),
        br#"{"v":2,"op":"lookup_fanout","queries":[{"table":"emb","ids":[1,2]},{"table":"emb","ids":[]}]}"#.to_vec(),
        br#"{"v":2,"op":"topk","table":"emb","query_id":3,"k":5}"#.to_vec(),
        br#"{"v":2,"op":"score","table":"emb","query_id":1,"ids":[0,1,2]}"#.to_vec(),
        br#"{"v":2,"op":"nonsense"}"#.to_vec(),
        br#"{"v":99,"op":"lookup"}"#.to_vec(),
        br#"not json"#.to_vec(),
        br#"{"v":2,"op":"lookup","table":"ghost","ids":[0]}"#.to_vec(),
        br#"{"v":2,"op":"topk","table":"emb","query":[0.5,-1.0,0.25,0.0,1.5,-0.5,2.0,0.125,-2.0,1.0,0.75,-0.25],"k":600000}"#.to_vec(),
    ];
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut out = Vec::with_capacity(reqs.len());
    for r in &reqs {
        s.write_all(&frame(r)).unwrap();
        out.push(read_raw_frame(&mut s).expect("response frame"));
    }
    out
}

/// The acceptance bar for the whole refactor: the event plane serves
/// byte-for-byte what the thread-per-connection plane serves, success
/// and rejection paths alike.
#[test]
fn event_plane_bytes_match_threaded_plane() {
    let mut per_plane: Vec<Vec<Vec<u8>>> = Vec::new();
    for pollers in [0usize, 2] {
        let (addr, h, _registry) = spawn(ServerConfig {
            pollers,
            ..ServerConfig::default()
        });
        per_plane.push(scripted_responses(addr));
        let mut c = Client::connect(addr).unwrap();
        c.shutdown().unwrap();
        h.join().unwrap();
    }
    let (threaded, event) = (&per_plane[0], &per_plane[1]);
    assert_eq!(threaded.len(), event.len());
    for (i, (a, b)) in threaded.iter().zip(event).enumerate() {
        assert_eq!(a, b, "response {i} differs between planes");
    }
}
