//! Tier-1 equivalence tests for the compute-on-codes scoring subsystem
//! (`dpq_embed::scoring` + the `score`/`topk` wire ops):
//!
//! - the DPQ ADC lookup-table path matches the reconstruct-then-score
//!   reference within the documented tolerance, at every thread count,
//!   and is bit-stable across thread counts;
//! - the scalar-quant LUT and the dense/low-rank exact paths are
//!   BIT-equal to the reference;
//! - `topk` is deterministic (ids and score bits) across thread counts,
//!   batcher shard counts and replica counts, including the f32 -> JSON
//!   -> f32 roundtrip;
//! - scoring a table that lives in the spill tier transparently
//!   promotes it, answering bit-identically to an always-resident twin.
//!
//! `tools/tier1.sh` runs this file under the default AND `DPQ_THREADS=2`
//! passes, so the cross-process thread invariance is pinned too.

use std::path::PathBuf;
use std::sync::{mpsc, Arc};

use dpq_embed::backend::DenseTable;
use dpq_embed::dpq::toy_embedding;
use dpq_embed::quant::{LowRank, ScalarQuant};
use dpq_embed::scoring::{self, ScoreBackend};
use dpq_embed::server::{
    Client, EmbeddingServer, Residency, ServerConfig, TableRegistry,
};
use dpq_embed::tensor::TensorF;
use dpq_embed::util::{pool, Rng};

fn spawn(server: Arc<EmbeddingServer>)
    -> (std::net::SocketAddr, std::thread::JoinHandle<()>) {
    let (tx, rx) = mpsc::channel();
    let h = std::thread::spawn(move || {
        server.serve("127.0.0.1:0", move |a| tx.send(a).unwrap()).unwrap();
    });
    (rx.recv().unwrap(), h)
}

fn query(d: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..d).map(|_| rng.normal()).collect()
}

fn rand_table(n: usize, d: usize, seed: u64) -> TensorF {
    let mut rng = Rng::new(seed);
    TensorF {
        shape: vec![n, d],
        data: (0..n * d).map(|_| rng.normal()).collect(),
    }
}

/// Score every id in `ids` with the backend's own scorer under a pinned
/// pool size, asserting the expected path tag.
fn scores_at(
    sb: &dyn ScoreBackend,
    q: &[f32],
    ids: &[usize],
    threads: usize,
    want_path: &str,
) -> Vec<f32> {
    pool::with_threads(threads, || {
        let scorer = sb.query_scorer(q);
        assert_eq!(scorer.path(), want_path);
        let mut out = vec![0.0f32; ids.len()];
        scoring::score_into(&*scorer, ids, &mut out);
        out
    })
}

fn assert_bits_equal(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(
            g.to_bits(), w.to_bits(),
            "{what}: entry {i} differs ({g} vs {w})"
        );
    }
}

/// The DPQ ADC lookup table re-associates each subspace's partial sums,
/// so it matches the reconstruct-then-dot reference within the
/// documented tolerance -- and, being a per-candidate serial
/// accumulation, it is BIT-stable across pool sizes.
#[test]
fn dpq_lut_matches_reference_within_tolerance() {
    let emb = toy_embedding(300, 16, 8, 4, 11); // d = 32
    let d = emb.d;
    let q = query(d, 5);
    let ids: Vec<usize> = (0..300).collect();
    let reference = scoring::reference_scores(&emb, &q, &ids);
    let tol = scoring::adc_tolerance(d);
    let base = scores_at(&emb, &q, &ids, 1, "lut");
    for (i, (g, r)) in base.iter().zip(&reference).enumerate() {
        assert!(
            (g - r).abs() <= tol,
            "id {i}: lut {g} vs reference {r} (tol {tol})"
        );
    }
    for threads in [2usize, 7] {
        let got = scores_at(&emb, &q, &ids, threads, "lut");
        assert_bits_equal(&got, &base, &format!("dpq lut at {threads} threads"));
    }
}

/// The scalar-quant LUT holds the exact f32 products the reference
/// computes, accumulated in the same column order -- bit-equal, not
/// merely close. Dense and low-rank take the exact path, which IS the
/// reference computation.
#[test]
fn sq_lut_and_exact_paths_are_bit_equal_to_reference() {
    let table = rand_table(120, 16, 77);
    let q = query(16, 9);
    let ids: Vec<usize> = (0..120).rev().collect();

    let sq = ScalarQuant::fit(&table, 8);
    let want_sq = scoring::reference_scores(&sq, &q, &ids);
    for threads in [1usize, 2, 7] {
        let got = scores_at(&sq, &q, &ids, threads, "lut");
        assert_bits_equal(&got, &want_sq, &format!("sq lut at {threads} threads"));
    }

    let dense = DenseTable::new(table.clone()).unwrap();
    let want_dense = scoring::reference_scores(&dense, &q, &ids);
    let lr = LowRank::fit(&table, 4);
    let want_lr = scoring::reference_scores(&lr, &q, &ids);
    for threads in [1usize, 2, 7] {
        let got = scores_at(&dense, &q, &ids, threads, "exact");
        assert_bits_equal(&got, &want_dense, &format!("dense at {threads} threads"));
        let got = scores_at(&lr, &q, &ids, threads, "exact");
        assert_bits_equal(&got, &want_lr, &format!("low_rank at {threads} threads"));
    }
}

/// `topk` answers the same ids in the same order with the same score
/// BITS at every pool size, every batcher shard count and every replica
/// count -- including over the wire, where scores survive the
/// f32 -> JSON -> f32 roundtrip exactly.
#[test]
fn topk_is_deterministic_across_threads_shards_and_replicas() {
    let emb = toy_embedding(500, 16, 8, 4, 23); // d = 32
    let q = query(emb.d, 3);
    let expect = pool::with_threads(1, || {
        scoring::topk(&*emb.query_scorer(&q), 0, 500, 25)
    });
    assert_eq!(expect.len(), 25);
    // best first, ties ascending: the order the merge contract promises
    for w in expect.windows(2) {
        assert!(
            w[0].score > w[1].score
                || (w[0].score == w[1].score && w[0].id < w[1].id),
            "topk order violated: {:?} before {:?}",
            (w[0].id, w[0].score), (w[1].id, w[1].score)
        );
    }
    for threads in [2usize, 7] {
        let got = pool::with_threads(threads, || {
            scoring::topk(&*emb.query_scorer(&q), 0, 500, 25)
        });
        assert_eq!(got.len(), expect.len());
        for (g, e) in got.iter().zip(&expect) {
            assert_eq!(g.id, e.id, "{threads} threads: id order");
            assert_eq!(
                g.score.to_bits(), e.score.to_bits(),
                "{threads} threads: score bits"
            );
        }
    }
    // over the wire, across server topologies
    for (shards, replicas) in [(1usize, 1usize), (2, 1), (2, 2)] {
        let registry = TableRegistry::new(ServerConfig {
            max_batch: 16,
            shards_per_table: shards,
            ..ServerConfig::default()
        });
        registry
            .insert("emb", Arc::new(toy_embedding(500, 16, 8, 4, 23)))
            .unwrap();
        let server = Arc::new(EmbeddingServer::new(registry));
        let (addr, h) = spawn(server);
        let mut c = Client::connect(addr).unwrap();
        if replicas > 1 {
            c.admin_set_replicas("emb", replicas).unwrap();
        }
        let got = c.topk("emb", &q, 25, None).unwrap();
        assert_eq!(got.len(), expect.len());
        for (g, e) in got.iter().zip(&expect) {
            assert_eq!(g.0, e.id, "{shards} shards / {replicas} replicas: ids");
            assert_eq!(
                g.1.to_bits(), e.score.to_bits(),
                "{shards} shards / {replicas} replicas: the JSON roundtrip \
                 must be exact"
            );
        }
        c.shutdown().unwrap();
        h.join().unwrap();
    }
}

/// Scoring a table that was demoted to the spill tier transparently
/// promotes it -- same contract as lookup -- and every answer is
/// bit-identical to an always-resident twin registry serving the same
/// artifact.
#[test]
fn scoring_a_spilled_table_transparently_promotes_it() {
    let dir: PathBuf =
        std::env::temp_dir().join("dpq_scoring_equivalence_spill");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let make = || toy_embedding(200, 8, 4, 4, 91); // d = 16
    let q = query(16, 41);
    let ids: Vec<usize> = (0..40).map(|i| (i * 13) % 200).collect();

    let resident = TableRegistry::new(ServerConfig::default());
    resident.insert("t", Arc::new(make())).unwrap();
    let (addr_r, h_r) = spawn(Arc::new(EmbeddingServer::new(resident)));
    let mut c_res = Client::connect(addr_r).unwrap();

    let spilling = TableRegistry::new(ServerConfig {
        spill_dir: Some(dir.clone()),
        ..ServerConfig::default()
    });
    spilling.insert("t", Arc::new(make())).unwrap();
    let (addr_s, h_s) = spawn(Arc::new(EmbeddingServer::new(spilling)));
    let mut c_spill = Client::connect(addr_s).unwrap();

    // demote, prove it left residency, then let topk promote it back
    c_spill.admin_demote("t").unwrap();
    let st = c_spill.stats(Some("t")).unwrap();
    assert_eq!(
        st.get("residency").and_then(|v| v.as_str()),
        Some(Residency::Spilled.as_str())
    );
    let top_s = c_spill.topk("t", &q, 9, None).unwrap();
    let top_r = c_res.topk("t", &q, 9, None).unwrap();
    assert_eq!(top_s.len(), top_r.len());
    for (s, r) in top_s.iter().zip(&top_r) {
        assert_eq!(s.0, r.0, "spilled-vs-resident topk ids");
        assert_eq!(s.1.to_bits(), r.1.to_bits(), "spilled-vs-resident bits");
    }
    let st = c_spill.stats(Some("t")).unwrap();
    assert_eq!(
        st.get("residency").and_then(|v| v.as_str()),
        Some(Residency::Resident.as_str()),
        "topk on a spilled table must promote it"
    );

    // demote again and drive the promotion through `score` this time
    c_spill.admin_demote("t").unwrap();
    let s_scores = c_spill.score("t", &q, &ids).unwrap();
    let r_scores = c_res.score("t", &q, &ids).unwrap();
    assert_bits_equal(&s_scores, &r_scores, "spilled-vs-resident score");

    // ... and query_id resolution promotes too (the query row itself
    // comes off the just-promoted table)
    c_spill.admin_demote("t").unwrap();
    let s_byid = c_spill.score_with_id("t", 7, &ids).unwrap();
    let r_byid = c_res.score_with_id("t", 7, &ids).unwrap();
    assert_bits_equal(&s_byid, &r_byid, "spilled-vs-resident score_with_id");

    for (mut c, h) in [(c_res, h_r), (c_spill, h_s)] {
        c.shutdown().unwrap();
        h.join().unwrap();
    }
    let _ = std::fs::remove_dir_all(&dir);
}
