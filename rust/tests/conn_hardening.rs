//! Connection-plane hardening acceptance tests (no artifacts needed):
//! hostile clients -- slow-loris writers, idle stallers, mid-frame
//! disconnects, over-cap floods, deliberately-panicking ops -- must
//! never delay, corrupt, or kill service for a concurrent healthy
//! client, and every defended close must be TYPED (`timeout`, `busy`,
//! `too_large`, `internal`) so well-behaved peers learn what happened.
//!
//! Also pins the graceful-shutdown contract: `serve` returns only
//! after every connection thread is joined, even with idle raw
//! connections still open.

use std::io::{Read as _, Write as _};
use std::net::TcpStream;
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use dpq_embed::dpq::{toy_embedding, CompressedEmbedding};
use dpq_embed::jsonx::Json;
use dpq_embed::server::{
    Client, EmbeddingServer, ServerConfig, TableRegistry, WireError,
};

fn toy() -> CompressedEmbedding {
    toy_embedding(48, 8, 4, 3, 1)
}

/// Boot a server over one DPQ table ("emb") with the given config.
fn spawn(cfg: ServerConfig) -> (
    std::net::SocketAddr,
    std::thread::JoinHandle<()>,
    Arc<TableRegistry>,
) {
    let registry = TableRegistry::new(cfg);
    registry.insert("emb", Arc::new(toy())).unwrap();
    let server = Arc::new(EmbeddingServer::new(registry));
    let registry = server.registry();
    let (tx, rx) = mpsc::channel();
    let h = std::thread::spawn(move || {
        server.serve("127.0.0.1:0", move |a| tx.send(a).unwrap()).unwrap();
    });
    (rx.recv().unwrap(), h, registry)
}

/// Read one length-prefixed frame raw (None on EOF / short read).
fn read_raw_frame(s: &mut TcpStream) -> Option<Vec<u8>> {
    let mut len4 = [0u8; 4];
    s.read_exact(&mut len4).ok()?;
    let n = u32::from_le_bytes(len4) as usize;
    let mut buf = vec![0u8; n];
    s.read_exact(&mut buf).ok()?;
    Some(buf)
}

fn frame_code(payload: &[u8]) -> Option<String> {
    let j = Json::parse(std::str::from_utf8(payload).ok()?).ok()?;
    Some(j.get("code")?.as_str()?.to_string())
}

fn assert_bit_exact(c: &mut Client, emb: &CompressedEmbedding, ids: &[usize]) {
    let rows = c.lookup_bin("emb", ids).unwrap();
    for (k, &id) in ids.iter().enumerate() {
        assert_eq!(rows.row(k), &emb.reconstruct_row(id)[..],
                   "served row for id {id} not bit-exact");
    }
}

/// A stalled slow-loris (mid-frame trickle stopped) and an idle staller
/// must each get a typed `timeout` close -- and neither may delay a
/// concurrent healthy client's bit-exact lookups by ANY perceptible
/// amount (connections are independent threads; the deadline only
/// polices its own connection).
#[test]
fn slow_loris_cannot_delay_healthy_client() {
    let (addr, h, registry) = spawn(ServerConfig {
        conn_timeout: Some(Duration::from_millis(400)),
        ..ServerConfig::default()
    });
    let emb = toy();
    // staller 1: writes a length prefix claiming 64 bytes, then stalls
    let mut loris = TcpStream::connect(addr).unwrap();
    loris.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    loris.write_all(&64u32.to_le_bytes()).unwrap();
    // staller 2: connects and never writes a byte
    let mut idle = TcpStream::connect(addr).unwrap();
    idle.set_read_timeout(Some(Duration::from_secs(10))).unwrap();

    // healthy client, concurrent with both stallers: every lookup must
    // come back fast and bit-exact
    let mut c = Client::connect(addr).unwrap();
    let t0 = Instant::now();
    for i in 0..30 {
        assert_bit_exact(&mut c, &emb, &[i % 48, (i * 7 + 3) % 48]);
    }
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "healthy client was delayed: {:?} for 30 lookups", t0.elapsed()
    );

    // both stallers get the typed timeout close, then EOF
    for (name, s) in [("loris", &mut loris), ("idle", &mut idle)] {
        let f = read_raw_frame(s)
            .unwrap_or_else(|| panic!("{name}: expected a timeout frame"));
        assert_eq!(frame_code(&f).as_deref(), Some("timeout"), "{name}");
        let mut rest = [0u8; 1];
        assert_eq!(s.read(&mut rest).unwrap_or(0), 0, "{name}: expected EOF");
    }
    assert!(
        registry.conn_stats().conn_timeouts.load(Ordering::Relaxed) >= 2,
        "both stalled connections must be counted"
    );
    c.shutdown().unwrap();
    h.join().unwrap();
}

/// The deadline is a whole-frame budget, not a per-read idle reset: a
/// slow-but-legitimate writer that finishes inside the budget is served
/// normally, byte-at-a-time framing and all.
#[test]
fn byte_at_a_time_writer_within_deadline_is_served() {
    let (addr, h, _registry) = spawn(ServerConfig {
        conn_timeout: Some(Duration::from_secs(5)),
        ..ServerConfig::default()
    });
    let emb = toy();
    let payload = br#"{"v":2,"op":"lookup_bin","table":"emb","ids":[7]}"#;
    let mut bytes = (payload.len() as u32).to_le_bytes().to_vec();
    bytes.extend_from_slice(payload);
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    for b in &bytes {
        s.write_all(std::slice::from_ref(b)).unwrap();
        std::thread::sleep(Duration::from_millis(5));
    }
    // v2 binary response: u32 len, then (n, d) header + rows
    let f = read_raw_frame(&mut s).expect("expected a binary response");
    assert_eq!(&f[..4], &1u32.to_le_bytes(), "n = 1");
    assert_eq!(&f[4..8], &12u32.to_le_bytes(), "d = 12");
    let want = emb.reconstruct_row(7);
    let got: Vec<f32> = f[8..].chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect();
    assert_eq!(got, want, "trickled frame must serve bit-exactly");
    let mut c = Client::connect(addr).unwrap();
    c.shutdown().unwrap();
    h.join().unwrap();
}

/// Peers vanishing mid-frame (and oversized length prefixes) must leave
/// the server fully healthy for everyone else.
#[test]
fn mid_frame_disconnects_and_oversize_prefixes_leave_server_healthy() {
    let (addr, h, _registry) = spawn(ServerConfig::default());
    let emb = toy();
    for i in 0..10 {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(&100u32.to_le_bytes()).unwrap();
        s.write_all(&[b'x'; 10]).unwrap();
        drop(s); // vanish mid-frame
        if i % 2 == 0 {
            // oversized claim: typed rejection, then close
            let mut s = TcpStream::connect(addr).unwrap();
            s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
            s.write_all(&(((64u32) << 20) + 1).to_le_bytes()).unwrap();
            let f = read_raw_frame(&mut s).expect("expected too_large frame");
            assert_eq!(frame_code(&f).as_deref(), Some("too_large"));
        }
    }
    let mut c = Client::connect(addr).unwrap();
    assert_bit_exact(&mut c, &emb, &[0, 13, 47]);
    c.shutdown().unwrap();
    h.join().unwrap();
}

/// A handler panic is isolated to its own connection: the victim gets a
/// typed `internal` close, `handler_panics` increments, every OTHER
/// connection keeps serving bit-exactly, and shutdown still joins
/// cleanly afterwards.
#[test]
fn handler_panic_kills_one_connection_not_the_server() {
    let (addr, h, registry) = spawn(ServerConfig {
        debug_ops: true, // test-only panic injection
        ..ServerConfig::default()
    });
    let emb = toy();
    let mut healthy = Client::connect(addr).unwrap();
    assert_bit_exact(&mut healthy, &emb, &[1, 2, 3]);

    let mut victim = TcpStream::connect(addr).unwrap();
    victim.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let payload = br#"{"v":2,"op":"debug_panic"}"#;
    victim.write_all(&(payload.len() as u32).to_le_bytes()).unwrap();
    victim.write_all(payload).unwrap();
    let f = read_raw_frame(&mut victim).expect("expected internal frame");
    assert_eq!(frame_code(&f).as_deref(), Some("internal"));
    let mut rest = [0u8; 1];
    assert_eq!(victim.read(&mut rest).unwrap_or(0), 0,
               "panicked connection must be closed");

    // the server survived: counter up, healthy client unaffected
    assert_eq!(
        registry.conn_stats().handler_panics.load(Ordering::Relaxed), 1);
    assert_bit_exact(&mut healthy, &emb, &[4, 5, 6]);
    let stats = healthy.stats(None).unwrap();
    assert_eq!(stats.get("handler_panics").unwrap().as_usize(), Some(1));

    healthy.shutdown().unwrap();
    h.join().unwrap();
}

/// With `debug_ops` off (the default, and the only CLI-reachable
/// state), `debug_panic` is just an unknown op.
#[test]
fn debug_panic_is_unreachable_without_debug_ops() {
    let (addr, h, registry) = spawn(ServerConfig::default());
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let payload = br#"{"v":2,"op":"debug_panic"}"#;
    s.write_all(&(payload.len() as u32).to_le_bytes()).unwrap();
    s.write_all(payload).unwrap();
    let f = read_raw_frame(&mut s).expect("expected a response");
    assert_eq!(frame_code(&f).as_deref(), Some("unknown_op"));
    assert_eq!(
        registry.conn_stats().handler_panics.load(Ordering::Relaxed), 0);
    let mut c = Client::connect(addr).unwrap();
    c.shutdown().unwrap();
    h.join().unwrap();
}

/// Over the `--max-conns` cap: typed `busy` rejection + close, no
/// handler thread; a freed slot is reusable immediately after.
#[test]
fn max_conns_cap_rejects_typed_and_recovers() {
    let (addr, h, registry) = spawn(ServerConfig {
        max_conns: Some(2),
        ..ServerConfig::default()
    });
    let emb = toy();
    let mut c1 = Client::connect(addr).unwrap();
    let mut c2 = Client::connect(addr).unwrap();
    assert_bit_exact(&mut c1, &emb, &[0]);
    assert_bit_exact(&mut c2, &emb, &[1]);

    // third connection: typed busy frame, then EOF
    let mut over = TcpStream::connect(addr).unwrap();
    over.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let f = read_raw_frame(&mut over).expect("expected busy frame");
    assert_eq!(frame_code(&f).as_deref(), Some("busy"));
    let mut rest = [0u8; 1];
    assert_eq!(over.read(&mut rest).unwrap_or(0), 0, "busy must close");
    assert!(registry.conn_stats().busy_rejections.load(Ordering::Relaxed) >= 1);

    // free a slot; the cap must admit a new connection once the closed
    // connection's thread winds down (bounded retry, not a sleep)
    drop(c2);
    let mut admitted = None;
    for _ in 0..100 {
        if let Ok(mut c) = Client::connect(addr) {
            if c.lookup_bin("emb", &[2]).is_ok() {
                admitted = Some(c);
                break;
            }
        }
        std::thread::sleep(Duration::from_millis(50));
    }
    let mut c3 = admitted.expect("freed slot was never re-admitted");
    assert_bit_exact(&mut c3, &emb, &[3, 4]);
    c1.shutdown().unwrap();
    h.join().unwrap();
}

/// Graceful shutdown joins every connection thread: `serve` returns
/// with idle raw connections still open (each sees a clean EOF), so no
/// thread outlives the server.
#[test]
fn shutdown_joins_all_connection_threads() {
    let (addr, h, registry) = spawn(ServerConfig::default());
    let emb = toy();
    let mut raws: Vec<TcpStream> = (0..3)
        .map(|_| {
            let s = TcpStream::connect(addr).unwrap();
            s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
            s
        })
        .collect();
    let mut c = Client::connect(addr).unwrap();
    assert_bit_exact(&mut c, &emb, &[5]);
    assert!(registry.conn_stats().conns_total.load(Ordering::Relaxed) >= 4);
    c.shutdown().unwrap();
    // serve() must return even though 3 idle connections never spoke --
    // each handler observes the stop flag and closes
    h.join().unwrap();
    for (i, s) in raws.iter_mut().enumerate() {
        let mut b = [0u8; 1];
        assert_eq!(s.read(&mut b).unwrap_or(0), 0,
                   "idle conn {i} must see EOF after shutdown");
    }
    assert_eq!(registry.conn_stats().conns_open.load(Ordering::Relaxed), 0,
               "every connection thread must have exited");
}

/// `conn_timeout: None` (the in-process default) really means no
/// deadline: an idle connection outlives a long pause and still works.
#[test]
fn no_timeout_config_keeps_idle_connections() {
    let (addr, h, _registry) = spawn(ServerConfig::default());
    let emb = toy();
    let mut c = Client::connect(addr).unwrap();
    std::thread::sleep(Duration::from_millis(600));
    assert_bit_exact(&mut c, &emb, &[9, 10]);
    c.shutdown().unwrap();
    h.join().unwrap();
}

/// The fanout section-count cap answers typed, and the connection (and
/// server) stay healthy -- the amplification defense the fuzzer's
/// flood case leans on.
#[test]
fn fanout_section_flood_is_a_typed_rejection() {
    let (addr, h, _registry) = spawn(ServerConfig::default());
    let emb = toy();
    let mut c = Client::connect(addr).unwrap();
    let ids: Vec<usize> = vec![0];
    let queries: Vec<(&str, &[usize])> =
        (0..2000).map(|_| ("emb", &ids[..])).collect();
    match c.lookup_fanout(&queries) {
        Err(WireError::Rejected { code, .. }) => assert_eq!(code, "too_large"),
        other => panic!("expected too_large, got {other:?}"),
    }
    // same connection still serves
    assert_bit_exact(&mut c, &emb, &[11]);
    c.shutdown().unwrap();
    h.join().unwrap();
}
