//! Content-addressed artifact-store integrity: a one-byte flip in ANY
//! persisted artifact (spill tier or snapshot) must surface as a typed
//! error -- never as silently wrong served bytes -- while undamaged
//! tables keep serving; snapshots dedupe identical tables by content
//! digest; and a cold registry hydrated purely over the v2
//! `fetch_artifact` wire op serves bit-identical lookups to its peer.

use std::path::PathBuf;
use std::sync::{mpsc, Arc};
use std::time::Duration;

use dpq_embed::backend::DenseTable;
use dpq_embed::dpq::toy_embedding;
use dpq_embed::server::{
    hydrate_from_peer, Client, EmbeddingServer, Residency, Rows,
    ServerConfig, TableRegistry, WireError, SNAPSHOT_MANIFEST,
};
use dpq_embed::tensor::TensorF;
use dpq_embed::util::Rng;

fn spawn(server: Arc<EmbeddingServer>)
    -> (std::net::SocketAddr, std::thread::JoinHandle<()>) {
    let (tx, rx) = mpsc::channel();
    let h = std::thread::spawn(move || {
        server.serve("127.0.0.1:0", move |a| tx.send(a).unwrap()).unwrap();
    });
    (rx.recv().unwrap(), h)
}

fn bits_equal(a: &Rows, b: &Rows) -> bool {
    a.n() == b.n()
        && a.d() == b.d()
        && a.as_slice().iter().zip(b.as_slice())
            .all(|(x, y)| x.to_bits() == y.to_bits())
}

fn fresh_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("dpq_artifact_integ_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn spill_cfg(dir: &PathBuf) -> ServerConfig {
    ServerConfig {
        max_batch: 16,
        shards_per_table: 2,
        spill_dir: Some(dir.clone()),
        ..ServerConfig::default()
    }
}

fn random_table(vocab: usize, d: usize, seed: u64) -> TensorF {
    let mut rng = Rng::new(seed);
    TensorF {
        shape: vec![vocab, d],
        data: (0..vocab * d).map(|_| rng.normal()).collect(),
    }
}

/// Flip one bit of one byte in the MIDDLE of a file (payload region,
/// past any header whose parse might coincidentally object) and return
/// the pristine bytes for healing.
fn flip_one_byte(path: &std::path::Path) -> Vec<u8> {
    let good = std::fs::read(path).unwrap();
    let mut bad = good.clone();
    let at = bad.len() / 2;
    bad[at] ^= 0x10;
    std::fs::write(path, &bad).unwrap();
    good
}

/// A single flipped bit in a spill artifact -- small enough that every
/// structural check (magic, shape, sizes) can still pass -- must answer
/// the typed `reload_failed` citing the content digest, on promote,
/// while the registry's other tables keep serving. Restoring the
/// pristine bytes heals the table bit-exactly.
#[test]
fn one_byte_flip_in_spill_artifact_is_typed_reload_failed() {
    let dir = fresh_dir("spill_flip");
    let registry = TableRegistry::open(spill_cfg(&dir)).unwrap();
    registry.insert("victim", Arc::new(toy_embedding(50, 8, 4, 3, 9)))
        .unwrap();
    registry.insert(
        "bystander",
        Arc::new(DenseTable::new(random_table(20, 6, 2)).unwrap()),
    ).unwrap();
    let server = Arc::new(EmbeddingServer::new(registry));
    let (addr, h) = spawn(server.clone());
    let mut c = Client::connect(addr).unwrap();

    let ids = [0usize, 49, 17, 3];
    let before = c.lookup_bin("victim", &ids).unwrap();
    let file = c.admin_demote("victim").unwrap();
    let good = flip_one_byte(&dir.join(&file));

    match c.lookup_bin("victim", &ids) {
        Err(WireError::Rejected { code, message }) => {
            assert_eq!(code, "reload_failed");
            assert!(message.contains("digest"), "{message}");
            assert!(message.contains("victim"), "{message}");
        }
        Ok(_) => panic!("a flipped artifact byte was served"),
        other => panic!("{other:?}"),
    }
    // the table stays registered (and spilled), others keep serving
    let st = c.stats(Some("victim")).unwrap();
    assert_eq!(st.get("residency").and_then(|v| v.as_str()), Some("spilled"));
    assert_eq!(c.lookup_bin("bystander", &[5]).unwrap().n(), 1);

    // healing: pristine bytes back -> digest matches -> bit-exact rows
    std::fs::write(dir.join(&file), &good).unwrap();
    let after = c.lookup_bin("victim", &ids).unwrap();
    assert!(bits_equal(&before, &after), "healed table serves wrong bytes");
    c.shutdown().unwrap();
    h.join().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

/// A single flipped bit in a snapshot artifact fails `restore` with the
/// typed `restore_failed` citing the manifest digest -- BEFORE any
/// parse -- and healing the artifact restores bit-exact serving. Also
/// pins the content-addressed artifact naming (`sha256-<hex>.art`) and
/// the per-table digest provenance fields in the manifest.
#[test]
fn one_byte_flip_in_snapshot_artifact_is_typed_restore_failed() {
    let dir = fresh_dir("snap_flip");
    let registry = TableRegistry::new(ServerConfig::default());
    registry.insert("emb", Arc::new(toy_embedding(40, 8, 4, 3, 4))).unwrap();
    let server = Arc::new(EmbeddingServer::new(registry));
    let (addr, h) = spawn(server.clone());
    let mut c = Client::connect(addr).unwrap();
    let ids = [1usize, 39, 8];
    let want = c.lookup_bin("emb", &ids).unwrap();
    let manifest = c.admin_snapshot(dir.to_str().unwrap()).unwrap();
    assert!(manifest.ends_with(SNAPSHOT_MANIFEST), "{manifest}");
    c.shutdown().unwrap();
    h.join().unwrap();

    // exactly one artifact, named by its own content digest
    let arts: Vec<PathBuf> = std::fs::read_dir(&dir).unwrap()
        .flatten()
        .map(|e| e.path())
        .filter(|p| p.extension().is_some_and(|x| x == "art"))
        .collect();
    assert_eq!(arts.len(), 1, "{arts:?}");
    let name = arts[0].file_name().unwrap().to_string_lossy().into_owned();
    assert!(name.starts_with("sha256-"), "{name}");

    let good = flip_one_byte(&arts[0]);
    let manifest_path = std::path::Path::new(&manifest);
    match TableRegistry::restore(manifest_path, None) {
        Err(WireError::Rejected { code, message }) => {
            assert_eq!(code, "restore_failed");
            assert!(message.contains("digest"), "{message}");
        }
        Ok(_) => panic!("restore accepted a flipped artifact byte"),
        other => panic!("{other:?}"),
    }

    std::fs::write(&arts[0], &good).unwrap();
    let reg2 = TableRegistry::restore(manifest_path, None).unwrap();
    let server2 = Arc::new(EmbeddingServer::new(reg2));
    let (addr2, h2) = spawn(server2.clone());
    let mut c2 = Client::connect(addr2).unwrap();
    let got = c2.lookup_bin("emb", &ids).unwrap();
    assert!(bits_equal(&want, &got), "restored table serves wrong bytes");
    c2.shutdown().unwrap();
    h2.join().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Two tables with identical bytes snapshot to ONE content-addressed
/// artifact (cross-table dedupe), and a registry restored from that
/// manifest serves both bit-exactly.
#[test]
fn snapshot_dedupes_identical_tables_by_digest() {
    let dir = fresh_dir("dedupe");
    let registry = TableRegistry::new(ServerConfig::default());
    let emb = Arc::new(toy_embedding(30, 8, 4, 3, 11));
    registry.insert("a", emb.clone()).unwrap();
    registry.insert("b", emb).unwrap();
    let server = Arc::new(EmbeddingServer::new(registry));
    let (addr, h) = spawn(server.clone());
    let mut c = Client::connect(addr).unwrap();
    let ids = [0usize, 29, 13];
    let want = c.lookup_bin("a", &ids).unwrap();
    let manifest = c.admin_snapshot(dir.to_str().unwrap()).unwrap();
    c.shutdown().unwrap();
    h.join().unwrap();

    let arts = std::fs::read_dir(&dir).unwrap()
        .flatten()
        .filter(|e| e.path().extension().is_some_and(|x| x == "art"))
        .count();
    assert_eq!(arts, 1, "identical tables must share one artifact");

    let reg2 =
        TableRegistry::restore(std::path::Path::new(&manifest), None).unwrap();
    let server2 = Arc::new(EmbeddingServer::new(reg2));
    let (addr2, h2) = spawn(server2.clone());
    let mut c2 = Client::connect(addr2).unwrap();
    assert!(bits_equal(&want, &c2.lookup_bin("a", &ids).unwrap()));
    assert!(bits_equal(&want, &c2.lookup_bin("b", &ids).unwrap()));
    c2.shutdown().unwrap();
    h2.join().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

/// The acceptance path for peer hydration: a cold registry with an
/// EMPTY spill dir pulls every artifact its peer advertises over the v2
/// `fetch_artifact` op (digest-verified as it lands), adopts them as
/// Spilled slots, and then serves lookups bit-identical to the peer --
/// zero shared disk. A second hydrate is a no-op, an unknown digest is
/// a typed `not_found`, and a malformed digest a typed `bad_digest`.
#[test]
fn cold_registry_hydrates_over_the_wire_bit_exactly() {
    let dir_a = fresh_dir("hydrate_a");
    let dir_b = fresh_dir("hydrate_b");

    // peer A: two backend kinds, one replicated, both demoted so the
    // spill tier (with recorded digests) is what B can pull
    let reg_a = TableRegistry::open(spill_cfg(&dir_a)).unwrap();
    reg_a.insert("dpq", Arc::new(toy_embedding(60, 8, 4, 3, 21))).unwrap();
    reg_a.insert_with_replicas(
        "dense",
        Arc::new(DenseTable::new(random_table(25, 6, 22)).unwrap()),
        3,
    ).unwrap();
    let server_a = Arc::new(EmbeddingServer::new(reg_a));
    let (addr_a, h_a) = spawn(server_a.clone());
    let mut ca = Client::connect(addr_a).unwrap();
    let ids_dpq: Vec<usize> = (0..12).map(|i| (i * 13) % 60).collect();
    let ids_dense: Vec<usize> = (0..12).map(|i| (i * 7) % 25).collect();
    let want_dpq = ca.lookup_bin("dpq", &ids_dpq).unwrap();
    let want_dense = ca.lookup_bin("dense", &ids_dense).unwrap();
    ca.admin_demote("dpq").unwrap();
    ca.admin_demote("dense").unwrap();

    // cold B: empty spill dir, nothing registered; hydrate over the
    // wire through a deadline-bearing client
    let reg_b = TableRegistry::open(spill_cfg(&dir_b)).unwrap();
    assert_eq!(reg_b.len(), 0);
    let mut hc = Client::with_timeout(addr_a, Duration::from_secs(10))
        .unwrap();
    assert_eq!(hydrate_from_peer(&reg_b, &mut hc).unwrap(), 2);
    assert_eq!(reg_b.residency("dpq"), Some(Residency::Spilled));
    assert_eq!(reg_b.residency("dense"), Some(Residency::Spilled));
    // hydration is idempotent: everything is already here
    assert_eq!(hydrate_from_peer(&reg_b, &mut hc).unwrap(), 0);

    // an unknown (but well-formed) digest is a typed not_found; a
    // malformed digest a typed bad_digest -- the connection survives
    match hc.fetch_artifact(&"0".repeat(64)) {
        Err(WireError::Rejected { code, .. }) => assert_eq!(code, "not_found"),
        other => panic!("{other:?}"),
    }
    match hc.fetch_artifact("not-a-digest") {
        Err(WireError::Rejected { code, .. }) => {
            assert_eq!(code, "bad_digest")
        }
        other => panic!("{other:?}"),
    }

    // B serves both tables bit-identical to what A served, with the
    // peer's replica count carried across
    let server_b = Arc::new(EmbeddingServer::new(reg_b));
    let (addr_b, h_b) = spawn(server_b.clone());
    let mut cb = Client::connect(addr_b).unwrap();
    let got_dpq = cb.lookup_bin("dpq", &ids_dpq).unwrap();
    let got_dense = cb.lookup_bin("dense", &ids_dense).unwrap();
    assert!(bits_equal(&want_dpq, &got_dpq), "dpq diverged after hydration");
    assert!(bits_equal(&want_dense, &got_dense),
            "dense diverged after hydration");
    let entry = server_b.registry().get("dense").unwrap();
    assert_eq!(entry.replica_count(), 3);
    // the new manifest-publish failure counter is wired into stats
    let st = cb.stats(None).unwrap();
    assert_eq!(
        st.get("spill_manifest_write_failures").and_then(|v| v.as_usize()),
        Some(0)
    );

    cb.shutdown().unwrap();
    h_b.join().unwrap();
    ca.shutdown().unwrap();
    h_a.join().unwrap();
    let _ = std::fs::remove_dir_all(&dir_a);
    let _ = std::fs::remove_dir_all(&dir_b);
}
