//! End-to-end serving test: train a DPQ LM briefly, export the compressed
//! embedding, serve it over TCP, and check served vectors equal both the
//! local reconstruction and the XLA-side reconstructed table.

use std::sync::{mpsc, Arc};

use dpq_embed::config::{LrSchedule, RunConfig};
use dpq_embed::coordinator::{experiments, Trainer};
use dpq_embed::runtime::{self, Runtime};
use dpq_embed::server::{Client, EmbeddingServer};

fn artifacts_dir() -> std::path::PathBuf {
    let mut d = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    d.push("artifacts");
    d
}

#[test]
fn serve_compressed_embedding_end_to_end() {
    let d = artifacts_dir();
    if !d.join("lm_ptb_sx_K32D32_train.manifest.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return;
    }
    let rt = Runtime::new(&d).unwrap();
    let prefix = "lm_ptb_sx_K32D32";
    let cfg = RunConfig {
        artifact: prefix.into(),
        steps: 20,
        seed: 5,
        lr: LrSchedule { base: 1.0, decay_after: usize::MAX, decay: 1.0 },
        log_every: 50,
        eval_batches: 3,
        artifacts_dir: d,
        checkpoint_dir: None,
        checkpoint_every: 0,
        export_every: 0,
    };
    let out = Trainer::new(&rt, cfg).quiet().run().unwrap();
    // XLA-side reconstructed table (ground truth for the server)
    let exp = rt.load(&format!("{prefix}_export")).unwrap();
    let res = runtime::run_aux(&exp, &out.state, &[]).unwrap();
    let xla_table = res[2].as_f().unwrap().clone();
    let ce = experiments::compress_state(&rt, prefix, &out.state, false)
        .unwrap();
    assert!(ce.compression_ratio() > 5.0);

    // save/load roundtrip through the on-disk format the CLI uses
    let tmp = std::env::temp_dir().join("dpq_server_int.dpq");
    ce.save(&tmp).unwrap();
    let loaded = dpq_embed::dpq::CompressedEmbedding::load(&tmp).unwrap();

    let server = Arc::new(EmbeddingServer::single("ptb", loaded, 32));
    let (tx, rx) = mpsc::channel();
    let s2 = server.clone();
    let h = std::thread::spawn(move || {
        s2.serve("127.0.0.1:0", move |a| tx.send(a).unwrap()).unwrap();
    });
    let addr = rx.recv().unwrap();

    // multiple clients, overlapping lookups -> batching exercised
    let mut clients: Vec<Client> =
        (0..3).map(|_| Client::connect(addr).unwrap()).collect();
    for (ci, c) in clients.iter_mut().enumerate() {
        let ids: Vec<usize> = (0..16).map(|i| (ci * 37 + i * 13) % 2000).collect();
        let rows = c.lookup("ptb", &ids).unwrap();
        assert_eq!((rows.n(), rows.d()), (16, 128));
        for (row, &id) in rows.iter().zip(&ids) {
            for (a, b) in row.iter().zip(xla_table.row(id)) {
                assert!((a - b).abs() < 1e-4,
                        "client {ci} id {id}: {a} vs {b}");
            }
        }
    }
    let stats = clients[0].stats(None).unwrap();
    assert!(stats.get("ids_served").unwrap().as_usize().unwrap() >= 48);
    clients[0].shutdown().unwrap();
    h.join().unwrap();
}
