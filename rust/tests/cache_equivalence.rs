//! The hot-row cache's one non-negotiable contract: it is INVISIBLE in
//! every served byte. A cache-enabled server and a cache-disabled twin
//! driven through the same randomized op mix -- `lookup`,
//! `lookup_fanout`, `score`, `topk`, `demote` (with transparent
//! promotion), `set_replicas`, `set_row_cache` resizes -- over three
//! tables of three backend kinds (DPQ, dense, multi-granular) must
//! answer bit-identically everywhere, while the subject's cache
//! demonstrably takes hits. Tier-1 reruns this file under
//! `DPQ_THREADS=2`, so the equivalence is also pinned across pool
//! widths.
//!
//! Deterministic companions pin the mechanics the randomized driver
//! can't assert exactly: LRU admission/eviction ordering and hit/miss
//! accounting through the wire stats, invalidation across
//! demote/promote (fresh empty cache, capacity carried, counters
//! surviving), and the memory-budget charge (cache CAPACITY counts
//! against `--mem-budget`; caches shrink before any table is evicted).

use std::path::PathBuf;
use std::sync::{mpsc, Arc};

use dpq_embed::backend::{
    DenseTable, EmbeddingBackend, HashingTable, MultiGranular,
};
use dpq_embed::dpq::toy_embedding;
use dpq_embed::server::{
    Client, EmbeddingServer, Residency, Rows, ServerConfig, TableRegistry,
};
use dpq_embed::tensor::TensorF;
use dpq_embed::util::prop::prop_check;
use dpq_embed::util::Rng;

/// (name, vocab, d) of the three tables both registries serve.
const DIMS: [(&str, usize, usize); 3] =
    [("alpha", 60, 8), ("beta", 40, 6), ("gamma", 40, 5)];

fn spawn(server: Arc<EmbeddingServer>)
    -> (std::net::SocketAddr, std::thread::JoinHandle<()>) {
    let (tx, rx) = mpsc::channel();
    let h = std::thread::spawn(move || {
        server.serve("127.0.0.1:0", move |a| tx.send(a).unwrap()).unwrap();
    });
    (rx.recv().unwrap(), h)
}

fn bits_equal(a: &Rows, b: &Rows) -> bool {
    a.n() == b.n()
        && a.d() == b.d()
        && a.as_slice().iter().zip(b.as_slice())
            .all(|(x, y)| x.to_bits() == y.to_bits())
}

fn fresh_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("dpq_cache_equiv_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn toy(n: usize, d: usize, seed: u64) -> TensorF {
    let mut rng = Rng::new(seed);
    TensorF {
        shape: vec![n, d],
        data: (0..n * d).map(|_| rng.normal()).collect(),
    }
}

/// The three tables, built fresh (construction is deterministic, so
/// the subject's and the twin's backends hold identical bits).
fn backends() -> Vec<(&'static str, Arc<dyn EmbeddingBackend>)> {
    vec![
        ("alpha", Arc::new(toy_embedding(60, 8, 4, 2, 5))),
        ("beta", Arc::new(DenseTable::new(toy(40, 6, 6)).unwrap())),
        ("gamma", Arc::new(MultiGranular::new(vec![
            (0, Arc::new(DenseTable::new(toy(10, 5, 7)).unwrap()) as _),
            (10, Arc::new(
                HashingTable::compress(&toy(30, 5, 8), 8).unwrap()) as _),
        ]).unwrap())),
    ]
}

fn u64_stat(j: &dpq_embed::jsonx::Json, key: &str) -> u64 {
    j.get(key).and_then(|v| v.as_usize()).unwrap_or(0) as u64
}

#[test]
fn cached_server_is_bit_identical_to_cache_disabled_twin() {
    let mut case_no = 0u64;
    prop_check(4, |rng| {
        case_no += 1;
        let dir_s = fresh_dir(&format!("subject_{case_no}"));
        let dir_t = fresh_dir(&format!("twin_{case_no}"));
        let mk = |spill: &PathBuf, cache: u64| ServerConfig {
            max_batch: 8,
            shards_per_table: 2,
            spill_dir: Some(spill.clone()),
            row_cache_bytes: cache,
            ..ServerConfig::default()
        };
        let subject =
            Arc::new(EmbeddingServer::new(
                TableRegistry::open(mk(&dir_s, 4096))
                    .map_err(|e| format!("open subject: {e}"))?));
        let twin =
            Arc::new(EmbeddingServer::new(
                TableRegistry::open(mk(&dir_t, 0))
                    .map_err(|e| format!("open twin: {e}"))?));
        for (name, b) in backends() {
            subject.registry().insert(name, b).unwrap();
        }
        for (name, b) in backends() {
            twin.registry().insert(name, b).unwrap();
        }
        let (addr_s, h_s) = spawn(subject.clone());
        let (addr_t, h_t) = spawn(twin.clone());
        let mut cs = Client::connect(addr_s).unwrap();
        let mut ct = Client::connect(addr_t).unwrap();

        for step in 0..140 {
            let (name, vocab, d) = DIMS[rng.below(3)];
            match rng.below(10) {
                // ---- lookup (40%): repeated ids drive admissions and
                // hits on the subject ----
                0..=3 => {
                    let n = 1 + rng.below(6);
                    let ids: Vec<usize> =
                        (0..n).map(|_| rng.below(vocab)).collect();
                    let a = cs.lookup_bin(name, &ids)
                        .map_err(|e| format!("step {step}: subject: {e}"))?;
                    let b = ct.lookup_bin(name, &ids)
                        .map_err(|e| format!("step {step}: twin: {e}"))?;
                    if !bits_equal(&a, &b) {
                        return Err(format!(
                            "step {step}: {name} lookup bytes diverged \
                             (ids {ids:?})"));
                    }
                }
                // ---- fan-out across all three tables ----
                4 => {
                    let idlists: Vec<Vec<usize>> = DIMS
                        .iter()
                        .map(|&(_, v, _)| {
                            (0..rng.below(5)).map(|_| rng.below(v)).collect()
                        })
                        .collect();
                    let queries: Vec<(&str, &[usize])> = DIMS
                        .iter()
                        .zip(&idlists)
                        .map(|(&(n, _, _), ids)| (n, &ids[..]))
                        .collect();
                    let a = cs.lookup_fanout(&queries)
                        .map_err(|e| format!("step {step}: subject: {e}"))?;
                    let b = ct.lookup_fanout(&queries)
                        .map_err(|e| format!("step {step}: twin: {e}"))?;
                    if a.len() != b.len()
                        || a.iter().zip(&b).any(|(x, y)| !bits_equal(x, y))
                    {
                        return Err(format!(
                            "step {step}: fan-out sections diverged"));
                    }
                }
                // ---- score: the exact path substitutes cached rows on
                // the subject; scores must still match bitwise ----
                5 => {
                    let query: Vec<f32> =
                        (0..d).map(|_| rng.normal()).collect();
                    let ids: Vec<usize> = (0..1 + rng.below(5))
                        .map(|_| rng.below(vocab))
                        .collect();
                    let a = cs.score(name, &query, &ids)
                        .map_err(|e| format!("step {step}: subject: {e}"))?;
                    let b = ct.score(name, &query, &ids)
                        .map_err(|e| format!("step {step}: twin: {e}"))?;
                    if a.iter().map(|s| s.to_bits()).collect::<Vec<_>>()
                        != b.iter().map(|s| s.to_bits()).collect::<Vec<_>>()
                    {
                        return Err(format!(
                            "step {step}: {name} scores diverged"));
                    }
                }
                // ---- topk: ranking AND score bits must agree ----
                6 => {
                    let query: Vec<f32> =
                        (0..d).map(|_| rng.normal()).collect();
                    let k = 1 + rng.below(5);
                    let a = cs.topk(name, &query, k, None)
                        .map_err(|e| format!("step {step}: subject: {e}"))?;
                    let b = ct.topk(name, &query, k, None)
                        .map_err(|e| format!("step {step}: twin: {e}"))?;
                    if a.iter().map(|(i, s)| (*i, s.to_bits()))
                        .collect::<Vec<_>>()
                        != b.iter().map(|(i, s)| (*i, s.to_bits()))
                            .collect::<Vec<_>>()
                    {
                        return Err(format!(
                            "step {step}: {name} topk diverged"));
                    }
                }
                // ---- demote both; the next touch transparently
                // promotes (the subject's cache restarts empty) ----
                7 => {
                    let a = cs.admin_demote(name);
                    let b = ct.admin_demote(name);
                    if a.is_ok() != b.is_ok() {
                        return Err(format!(
                            "step {step}: demote({name}) diverged: \
                             {a:?} vs {b:?}"));
                    }
                }
                // ---- set_replicas both: resizes are bit-invisible ----
                8 => {
                    let n = 1 + rng.below(3);
                    let a = cs.admin_set_replicas(name, n)
                        .map_err(|e| format!("step {step}: subject: {e}"))?;
                    let b = ct.admin_set_replicas(name, n)
                        .map_err(|e| format!("step {step}: twin: {e}"))?;
                    if a != n || b != n {
                        return Err(format!(
                            "step {step}: set_replicas answered {a}/{b}"));
                    }
                }
                // ---- set_row_cache, SUBJECT only (the twin must stay
                // cacheless): resizes drop rows, never change bytes ----
                _ => {
                    let bytes = [0u64, 512, 4096, 1 << 20][rng.below(4)];
                    cs.admin_set_row_cache(name, bytes)
                        .map_err(|e| format!("step {step}: subject: {e}"))?;
                }
            }
        }

        // deterministic closing sweep: cache beta fully, scan it twice
        // -- the second pass is all hits -- then bit-compare EVERY row
        // of every table one last time
        cs.admin_set_row_cache("beta", 64 * 1024)
            .map_err(|e| format!("closing set_row_cache: {e}"))?;
        for (name, vocab, _) in DIMS {
            let all: Vec<usize> = (0..vocab).collect();
            for pass in 0..2 {
                let a = cs.lookup_bin(name, &all)
                    .map_err(|e| format!("sweep {name}/{pass}: {e}"))?;
                let b = ct.lookup_bin(name, &all)
                    .map_err(|e| format!("sweep {name}/{pass}: {e}"))?;
                if !bits_equal(&a, &b) {
                    return Err(format!(
                        "closing sweep pass {pass}: {name} diverged"));
                }
            }
        }
        let st = cs.stats(Some("beta")).unwrap();
        if u64_stat(&st, "cache_hits") == 0 {
            return Err("subject cache took no hits -- the equivalence \
                        run never exercised the cache".into());
        }
        for (name, _, _) in DIMS {
            let tw = ct.stats(Some(name)).unwrap();
            if u64_stat(&tw, "cache_hits") != 0
                || u64_stat(&tw, "row_cache_cap_bytes") != 0
            {
                return Err(format!("twin {name} grew a cache"));
            }
        }

        cs.shutdown().unwrap();
        ct.shutdown().unwrap();
        h_s.join().unwrap();
        h_t.join().unwrap();
        let _ = std::fs::remove_dir_all(&dir_s);
        let _ = std::fs::remove_dir_all(&dir_t);
        Ok(())
    });
}

/// LRU mechanics through the wire, pinned exactly: a cache sized for
/// two rows admits on miss, serves repeats from the cache, evicts
/// least-recently-USED (a hit refreshes recency), and the hit/miss
/// counters in `stats` account for every step. One shard, one client,
/// single-id lookups: every admission is sequential and deterministic.
#[test]
fn admission_eviction_and_counters_are_deterministic() {
    let dir = fresh_dir("lru");
    let table = toy(10, 4, 42);
    // row cost = 64-byte overhead + 16 data bytes = 80; cap = 2 rows
    let reg = TableRegistry::open(ServerConfig {
        max_batch: 8,
        shards_per_table: 1,
        spill_dir: Some(dir.clone()),
        row_cache_bytes: 160,
        ..ServerConfig::default()
    }).unwrap();
    reg.insert("t", Arc::new(DenseTable::new(table.clone()).unwrap()))
        .unwrap();
    let server = Arc::new(EmbeddingServer::new(reg));
    let (addr, h) = spawn(server.clone());
    let mut c = Client::connect(addr).unwrap();

    // (id, expected hits so far, expected misses so far)
    let script: [(usize, u64, u64); 7] = [
        (0, 0, 1), // miss, admit 0           cache: [0]
        (0, 1, 1), // hit                     cache: [0]
        (1, 1, 2), // miss, admit 1           cache: [0, 1]
        (2, 1, 3), // miss, evict LRU 0       cache: [1, 2]
        (1, 2, 3), // hit, refreshes 1        cache: [2, 1]
        (0, 2, 4), // miss, evict LRU 2       cache: [1, 0]
        (2, 2, 5), // miss, evict LRU 1       cache: [0, 2]
    ];
    for (step, &(id, hits, misses)) in script.iter().enumerate() {
        let rows = c.lookup_bin("t", &[id]).unwrap();
        assert_eq!(rows.row(0), table.row(id), "step {step}: wrong bytes");
        let st = c.stats(Some("t")).unwrap();
        assert_eq!(
            (u64_stat(&st, "cache_hits"), u64_stat(&st, "cache_misses")),
            (hits, misses),
            "step {step} (id {id})"
        );
    }
    let st = c.stats(Some("t")).unwrap();
    assert_eq!(u64_stat(&st, "row_cache_cap_bytes"), 160);
    assert_eq!(u64_stat(&st, "row_cache_bytes"), 160, "2 rows resident");

    // demote + transparent promote: contents are STRUCTURALLY dropped
    // (fresh cache), capacity carries over, counters keep accumulating
    // on the table's Stats across the residency transition
    c.admin_demote("t").unwrap();
    let rows = c.lookup_bin("t", &[5]).unwrap();
    assert_eq!(rows.row(0), table.row(5));
    let st = c.stats(Some("t")).unwrap();
    assert_eq!(u64_stat(&st, "row_cache_cap_bytes"), 160, "cap carried");
    assert_eq!(u64_stat(&st, "row_cache_bytes"), 80,
               "only the post-promote row may be cached");
    assert_eq!(
        (u64_stat(&st, "cache_hits"), u64_stat(&st, "cache_misses")),
        (2, 6),
        "counters survive the residency transition"
    );

    // resizing to 0 disables and drops everything, immediately
    assert_eq!(c.admin_set_row_cache("t", 0).unwrap(), 0);
    let st = c.stats(Some("t")).unwrap();
    assert_eq!(u64_stat(&st, "row_cache_bytes"), 0);
    c.shutdown().unwrap();
    h.join().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

/// The budget charge: cache CAPACITY (not fill) counts against
/// `--mem-budget`. A resize is clamped to the budget headroom, the
/// invariant `resident + capacity <= budget` holds after every
/// mutation, and under pressure caches shrink to zero BEFORE any
/// resident table is evicted.
#[test]
fn cache_capacity_counts_against_mem_budget() {
    let dir = fresh_dir("budget");
    const BUDGET: u64 = 1000;
    let reg = TableRegistry::open(ServerConfig {
        max_batch: 8,
        shards_per_table: 1,
        mem_budget_bytes: Some(BUDGET),
        spill_dir: Some(dir.clone()),
        spill_on_evict: true,
        ..ServerConfig::default()
    }).unwrap();
    let charged = |reg: &TableRegistry| -> u64 {
        reg.resident_bytes()
            + reg.list().iter()
                .map(|e| e.row_cache.cap_bytes())
                .sum::<u64>()
    };

    // two 320-byte tables leave 360 bytes of headroom
    reg.insert("a", Arc::new(DenseTable::new(toy(20, 4, 1)).unwrap()))
        .unwrap();
    reg.insert("b", Arc::new(DenseTable::new(toy(20, 4, 2)).unwrap()))
        .unwrap();
    assert_eq!(reg.resident_bytes(), 640);

    // an oversized resize is clamped to exactly the headroom, and the
    // tuned table is never evicted to make room for its own cache
    let cap = reg.set_row_cache("a", 10_000).unwrap();
    assert_eq!(cap, 360, "cap must clamp to the budget headroom");
    assert!(charged(&reg) <= BUDGET);
    assert_eq!(reg.residency("a"), Some(Residency::Resident));

    // a second oversized resize forces shrinks but never an eviction
    let cap_b = reg.set_row_cache("b", 10_000).unwrap();
    assert!(cap_b <= 360, "no headroom was conjured: {cap_b}");
    assert!(charged(&reg) <= BUDGET);
    assert_eq!(reg.residency("a"), Some(Residency::Resident));
    assert_eq!(reg.residency("b"), Some(Residency::Resident));

    // pressure from a third table: caches shrink first (to zero here),
    // and with 960 resident bytes fitting the budget, NO table may be
    // evicted to protect a cache
    reg.insert("c", Arc::new(DenseTable::new(toy(20, 4, 3)).unwrap()))
        .unwrap();
    assert!(charged(&reg) <= BUDGET);
    for name in ["a", "b", "c"] {
        assert_eq!(reg.residency(name), Some(Residency::Resident),
                   "{name} was evicted while caches could still shrink");
    }
    assert_eq!(reg.resident_bytes(), 960);
    let caps: u64 =
        reg.list().iter().map(|e| e.row_cache.cap_bytes()).sum();
    assert!(caps <= BUDGET - 960, "caches must fit the leftover headroom");

    // a fourth table cannot fit even with every cache at zero: now a
    // table is evicted -- and every surviving cache is already zero
    reg.insert("d", Arc::new(DenseTable::new(toy(20, 4, 4)).unwrap()))
        .unwrap();
    assert!(charged(&reg) <= BUDGET);
    let spilled = ["a", "b", "c", "d"]
        .iter()
        .filter(|n| reg.residency(n) == Some(Residency::Spilled))
        .count();
    assert_eq!(spilled, 1, "exactly one table spills under pressure");
    for e in reg.list() {
        assert_eq!(e.row_cache.cap_bytes(), 0,
                   "{}: caches must hit zero before any eviction", e.name);
    }
    reg.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}
