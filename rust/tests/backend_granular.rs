//! Registry-path tests for the skew-aware backends: [`MultiGranular`]
//! (MGQE dense-head + compressed-tail routing) and [`HashingTable`]
//! (the hashing-trick baseline) must be full citizens of every serving
//! lifecycle -- wire lookups, demote/promote through the spill tier,
//! snapshot/restore, magic-sniffed hot-loads -- with every served byte
//! bit-identical to querying the backend directly, and every corrupt
//! artifact failing typed instead of serving mis-routed rows.

use std::path::PathBuf;
use std::sync::{mpsc, Arc};

use dpq_embed::backend::{DenseTable, EmbeddingBackend, HashingTable, MultiGranular};
use dpq_embed::dpq::toy_embedding;
use dpq_embed::server::{
    Client, EmbeddingServer, Residency, Rows, ServerConfig, TableRegistry,
    WireError,
};
use dpq_embed::tensor::TensorF;
use dpq_embed::util::Rng;

fn spawn(server: Arc<EmbeddingServer>)
    -> (std::net::SocketAddr, std::thread::JoinHandle<()>) {
    let (tx, rx) = mpsc::channel();
    let h = std::thread::spawn(move || {
        server.serve("127.0.0.1:0", move |a| tx.send(a).unwrap()).unwrap();
    });
    (rx.recv().unwrap(), h)
}

fn fresh_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("dpq_backend_granular_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn cfg(dir: &PathBuf) -> ServerConfig {
    ServerConfig {
        max_batch: 16,
        shards_per_table: 2,
        spill_dir: Some(dir.clone()),
        ..ServerConfig::default()
    }
}

fn toy(n: usize, d: usize, seed: u64) -> TensorF {
    let mut rng = Rng::new(seed);
    TensorF {
        shape: vec![n, d],
        data: (0..n * d).map(|_| rng.normal()).collect(),
    }
}

/// Gather `ids` straight from the backend, bypassing the server.
fn direct(b: &dyn EmbeddingBackend, ids: &[usize]) -> Vec<f32> {
    let mut out = vec![0.0f32; ids.len() * b.d()];
    b.reconstruct_rows_into(ids, &mut out);
    out
}

fn assert_bits(rows: &Rows, want: &[f32], what: &str) {
    assert_eq!(rows.as_slice().len(), want.len(), "{what}: shape");
    assert!(
        rows.as_slice().iter().zip(want)
            .all(|(a, b)| a.to_bits() == b.to_bits()),
        "{what}: served bytes diverge from the backend's own rows"
    );
}

/// The MGQE arrangement end to end: a dense head spliced onto a DPQ
/// tail serves over the wire (boundary ids included), survives
/// demote + transparent promotion, and restores from a snapshot -- all
/// bit-identical to querying the assembled backend directly. Scoring
/// answers match a dense reference table of the same rows bit-for-bit
/// (exact-everywhere: segment routing must be invisible to `topk`).
#[test]
fn multigranular_roundtrips_through_registry_and_spill_tier() {
    let dir = fresh_dir("mg_lifecycle");
    let head = toy(12, 8, 11);
    let mg: Arc<dyn EmbeddingBackend> = Arc::new(MultiGranular::new(vec![
        (0, Arc::new(DenseTable::new(head.clone()).unwrap()) as _),
        (12, Arc::new(toy_embedding(52, 8, 4, 2, 3)) as _),
    ]).unwrap());
    assert_eq!((mg.vocab(), mg.d(), mg.kind()), (64, 8, "multi_granular"));
    // boundary ids: 11 is the head's last row, 12 the tail's first
    let ids = [11usize, 12, 0, 63, 12, 40];
    let want = direct(&*mg, &ids);

    // a dense reference table holding the SAME rows, for scoring
    let all: Vec<usize> = (0..64).collect();
    let full = TensorF { shape: vec![64, 8], data: direct(&*mg, &all) };
    let reference = Arc::new(DenseTable::new(full).unwrap());

    let reg = TableRegistry::open(cfg(&dir)).unwrap();
    reg.insert("mg", mg.clone()).unwrap();
    reg.insert("reference", reference).unwrap();
    let server = Arc::new(EmbeddingServer::new(reg));
    let (addr, h) = spawn(server.clone());
    let mut c = Client::connect(addr).unwrap();

    assert_bits(&c.lookup_bin("mg", &ids).unwrap(), &want, "resident lookup");

    // scoring routes exact-everywhere: ids AND score bits must match
    // the dense reference
    let query: Vec<f32> = head.row(3).to_vec();
    let top_mg = c.topk("mg", &query, 7, None).unwrap();
    let top_ref = c.topk("reference", &query, 7, None).unwrap();
    assert_eq!(
        top_mg.iter().map(|(i, s)| (*i, s.to_bits())).collect::<Vec<_>>(),
        top_ref.iter().map(|(i, s)| (*i, s.to_bits())).collect::<Vec<_>>(),
        "multi-granular topk diverges from a dense table of the same rows"
    );
    let s_mg = c.score("mg", &query, &ids).unwrap();
    let s_ref = c.score("reference", &query, &ids).unwrap();
    assert_eq!(
        s_mg.iter().map(|s| s.to_bits()).collect::<Vec<_>>(),
        s_ref.iter().map(|s| s.to_bits()).collect::<Vec<_>>(),
    );

    // demote writes the DPQM artifact; the next lookup transparently
    // promotes and must serve the same bytes
    c.admin_demote("mg").unwrap();
    assert_eq!(server.registry().residency("mg"), Some(Residency::Spilled));
    assert_bits(&c.lookup_bin("mg", &ids).unwrap(), &want, "promoted lookup");
    assert_eq!(server.registry().residency("mg"), Some(Residency::Resident));

    // snapshot/restore: a second registry rebuilt from the manifest
    // serves the same bytes under the same kind
    let snap = dir.join("snap");
    let manifest = c.admin_snapshot(snap.to_str().unwrap()).unwrap();
    c.shutdown().unwrap();
    h.join().unwrap();
    let reg2 = TableRegistry::restore(std::path::Path::new(&manifest), None)
        .unwrap();
    assert_eq!(reg2.residency("mg"), Some(Residency::Resident));
    let server2 = Arc::new(EmbeddingServer::new(reg2));
    let (addr2, h2) = spawn(server2.clone());
    let mut c2 = Client::connect(addr2).unwrap();
    assert_bits(&c2.lookup_bin("mg", &ids).unwrap(), &want, "restored lookup");
    let top2 = c2.topk("mg", &query, 7, None).unwrap();
    assert_eq!(
        top2.iter().map(|(i, s)| (*i, s.to_bits())).collect::<Vec<_>>(),
        top_mg.iter().map(|(i, s)| (*i, s.to_bits())).collect::<Vec<_>>(),
        "restored multi-granular topk diverges"
    );
    c2.shutdown().unwrap();
    h2.join().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

/// The hashing-trick baseline through the same lifecycle: collisions
/// are part of the contract (two ids in one bucket serve identical
/// rows), and they must survive demote/promote and snapshot/restore
/// unchanged -- the fixed unseeded hash may never re-route an id across
/// an artifact roundtrip.
#[test]
fn hashing_backend_roundtrips_through_registry() {
    let dir = fresh_dir("hash_lifecycle");
    let ht = Arc::new(HashingTable::compress(&toy(100, 6, 7), 16).unwrap());
    let colliding = (1..100)
        .find(|&i| ht.bucket_of(i) == ht.bucket_of(0))
        .expect("100 ids into 16 buckets must collide");
    let ids = [0usize, colliding, 99, 50, 0];
    let want = direct(&*ht, &ids);

    let reg = TableRegistry::open(cfg(&dir)).unwrap();
    reg.insert("hash", ht).unwrap();
    let server = Arc::new(EmbeddingServer::new(reg));
    let (addr, h) = spawn(server.clone());
    let mut c = Client::connect(addr).unwrap();

    let rows = c.lookup_bin("hash", &ids).unwrap();
    assert_bits(&rows, &want, "resident lookup");
    assert_eq!(
        rows.row(0), rows.row(1),
        "colliding ids must serve the same bucket row"
    );

    c.admin_demote("hash").unwrap();
    assert_bits(&c.lookup_bin("hash", &ids).unwrap(), &want, "promoted lookup");

    let snap = dir.join("snap");
    let manifest = c.admin_snapshot(snap.to_str().unwrap()).unwrap();
    c.shutdown().unwrap();
    h.join().unwrap();
    let reg2 = TableRegistry::restore(std::path::Path::new(&manifest), None)
        .unwrap();
    let server2 = Arc::new(EmbeddingServer::new(reg2));
    let (addr2, h2) = spawn(server2.clone());
    let mut c2 = Client::connect(addr2).unwrap();
    assert_bits(&c2.lookup_bin("hash", &ids).unwrap(), &want,
                "restored lookup");
    c2.shutdown().unwrap();
    h2.join().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Artifact-level defenses: a `DPQM` file whose segment ranges were
/// tampered into a gap or an overlap fails with the same typed errors
/// as direct construction, a lying vocab header fails the assembled
/// shape cross-check, truncation fails the up-front size check, and a
/// foreign artifact fails the magic check. None of them may load.
#[test]
fn multigranular_artifact_corruption_fails_typed() {
    let dir = fresh_dir("mg_corrupt");
    let mg = MultiGranular::new(vec![
        (0, Arc::new(DenseTable::new(toy(12, 4, 1)).unwrap()) as _),
        (12, Arc::new(DenseTable::new(toy(20, 4, 2)).unwrap()) as _),
    ]).unwrap();
    let path = dir.join("mg.dpqm");
    mg.save(&path).unwrap();

    // the pristine artifact roundtrips bit-exactly
    let ids: Vec<usize> = (0..32).collect();
    let loaded = MultiGranular::load(&path).unwrap();
    let (a, b) = (direct(&mg, &ids), direct(&loaded, &ids));
    assert!(a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()));

    // layout: 4-byte magic, 4 u64 header dims, then the segment blob
    // whose first field is segment 0's u64 LE `end` (= 12)
    let pristine = std::fs::read(&path).unwrap();
    let end0_at = 4 + 4 * 8;
    assert_eq!(
        u64::from_le_bytes(pristine[end0_at..end0_at + 8].try_into().unwrap()),
        12
    );

    let tamper = |end0: u64| -> String {
        let mut bytes = pristine.clone();
        bytes[end0_at..end0_at + 8].copy_from_slice(&end0.to_le_bytes());
        std::fs::write(&path, bytes).unwrap();
        MultiGranular::load(&path).unwrap_err().to_string()
    };
    // segment 1 now starts past / inside segment 0's actual coverage
    let err = tamper(13);
    assert!(err.contains("gap"), "{err}");
    let err = tamper(11);
    assert!(err.contains("overlap"), "{err}");

    // header vocab lies about what the segments assemble to
    let mut bytes = pristine.clone();
    bytes[4..12].copy_from_slice(&33u64.to_le_bytes());
    std::fs::write(&path, bytes).unwrap();
    let err = MultiGranular::load(&path).unwrap_err().to_string();
    assert!(err.contains("header declares"), "{err}");

    // truncation fails the up-front total-size check
    std::fs::write(&path, &pristine[..pristine.len() - 7]).unwrap();
    assert!(MultiGranular::load(&path).is_err());

    // a foreign artifact (hashing) fails the magic check
    let hpath = dir.join("h.dpqh");
    HashingTable::compress(&toy(10, 4, 3), 4).unwrap().save(&hpath).unwrap();
    let err = MultiGranular::load(&hpath).unwrap_err().to_string();
    assert!(err.contains("magic"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// The admin `load` op sniffs the artifact kind from its magic: the
/// same wire op hot-loads multi-granular, hashing and dense artifacts
/// (serving each bit-exactly), and answers a typed `load_failed` for
/// garbage bytes and unknown magics.
#[test]
fn hot_load_sniffs_artifact_kind_over_the_wire() {
    let dir = fresh_dir("sniff");
    let mg = MultiGranular::new(vec![
        (0, Arc::new(DenseTable::new(toy(8, 4, 21)).unwrap()) as _),
        (8, Arc::new(DenseTable::new(toy(24, 4, 22)).unwrap()) as _),
    ]).unwrap();
    let ids = [7usize, 8, 0, 31];
    let want_mg = direct(&mg, &ids);
    let mg_path = dir.join("mg.artifact");
    mg.save(&mg_path).unwrap();
    let ht = HashingTable::compress(&toy(40, 4, 23), 8).unwrap();
    let want_ht = direct(&ht, &ids);
    let ht_path = dir.join("h.artifact");
    ht.save(&ht_path).unwrap();
    let dense = DenseTable::new(toy(32, 4, 24)).unwrap();
    let want_dense = direct(&dense, &ids);
    let dense_path = dir.join("d.artifact");
    dense.save(&dense_path).unwrap();
    std::fs::write(dir.join("garbage"), b"XXXXnot an artifact").unwrap();

    let reg = TableRegistry::open(cfg(&dir)).unwrap();
    let server = Arc::new(EmbeddingServer::new(reg));
    let (addr, h) = spawn(server.clone());
    let mut c = Client::connect(addr).unwrap();

    for (name, path, kind, vocab, want) in [
        ("mg", &mg_path, "multi_granular", 32, &want_mg),
        ("hash", &ht_path, "hashing", 40, &want_ht),
        ("dense", &dense_path, "dense", 32, &want_dense),
    ] {
        let desc = c.admin_load(name, path.to_str().unwrap()).unwrap();
        assert_eq!((desc.kind.as_str(), desc.vocab, desc.d),
                   (kind, vocab, 4), "{name}");
        assert_bits(&c.lookup_bin(name, &ids).unwrap(), want, name);
    }
    match c.admin_load("bad", dir.join("garbage").to_str().unwrap()) {
        Err(WireError::Rejected { code, message }) => {
            assert_eq!(code, "load_failed");
            assert!(message.contains("magic"), "{message}");
        }
        other => panic!("{other:?}"),
    }
    c.shutdown().unwrap();
    h.join().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}
