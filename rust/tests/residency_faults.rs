//! Fault-injection and acceptance tests for the tiered-residency
//! serving stack (no artifacts needed):
//!
//! * **demote -> lookup round trip** is BIT-exact for every backend kind
//!   (dpq, dense, scalar_quant, low_rank) at 1 and 2 worker threads:
//!   rows served after the transparent reload are byte-identical to the
//!   pre-demotion `lookup_bin` output.
//! * **corrupted spill artifact**: promoting it answers a typed
//!   `reload_failed` rejection and the registry keeps serving its other
//!   tables; restoring the artifact's bytes heals the table.
//! * **artifact deleted out-of-band**: `stats` reports
//!   `residency: "lost"` instead of panicking anything; lookups answer
//!   `reload_failed`.
//! * **missing spill dir at startup** fails loudly and typed.
//! * **demote mid-flight** (regression for the all-or-nothing fan-out
//!   promise): a table demoted while a `lookup_fanout` section is
//!   queued answers `no_such_table` (residency `"spilled"`) for the
//!   WHOLE frame -- never a partial frame, never a wedged batcher.
//! * **single-flight promotion**: N clients hammering one demoted table
//!   cause exactly ONE promote; every caller gets bit-correct rows.

use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::sync::{mpsc, Arc, Barrier, Condvar, Mutex};

use anyhow::Result as AnyResult;
use dpq_embed::backend::{DenseTable, EmbeddingBackend};
use dpq_embed::dpq::toy_embedding;
use dpq_embed::jsonx::Json;
use dpq_embed::quant::{LowRank, ScalarQuant};
use dpq_embed::server::{
    read_frame, write_frame, Client, EmbeddingServer, Residency, Rows,
    ServerConfig, TableRegistry, WireError,
};
use dpq_embed::tensor::TensorF;
use dpq_embed::util::{pool, Rng};

fn spawn(server: Arc<EmbeddingServer>)
    -> (std::net::SocketAddr, std::thread::JoinHandle<()>) {
    let (tx, rx) = mpsc::channel();
    let h = std::thread::spawn(move || {
        server.serve("127.0.0.1:0", move |a| tx.send(a).unwrap()).unwrap();
    });
    (rx.recv().unwrap(), h)
}

fn bits_equal(a: &Rows, b: &Rows) -> bool {
    a.n() == b.n()
        && a.d() == b.d()
        && a.as_slice().iter().zip(b.as_slice())
            .all(|(x, y)| x.to_bits() == y.to_bits())
}

fn fresh_spill_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("dpq_residency_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn spill_cfg(dir: &Path, budget: Option<u64>, shards: usize) -> ServerConfig {
    ServerConfig {
        max_batch: 16,
        shards_per_table: shards,
        mem_budget_bytes: budget,
        spill_dir: Some(dir.to_path_buf()),
        spill_on_evict: true,
        ..ServerConfig::default()
    }
}

fn random_table(n: usize, d: usize, seed: u64) -> TensorF {
    let mut rng = Rng::new(seed);
    TensorF {
        shape: vec![n, d],
        data: (0..n * d).map(|_| rng.normal()).collect(),
    }
}

/// Acceptance: demote -> lookup is bit-exact through the spill tier for
/// EVERY backend kind, with 2 batcher shards, at 1 and 2 worker threads
/// (`pool::set_threads` is process-wide, like tests/multi_table.rs, so
/// both settings live in this one #[test]).
#[test]
fn demote_lookup_roundtrip_bit_exact_all_kinds_at_1_and_2_threads() {
    let dir = fresh_spill_dir("roundtrip");
    let registry =
        TableRegistry::open(spill_cfg(&dir, None, 2)).unwrap();
    let table = random_table(60, 8, 11);
    registry.insert("dpq", Arc::new(toy_embedding(300, 16, 4, 3, 5))).unwrap();
    registry
        .insert("dense", Arc::new(DenseTable::new(table.clone()).unwrap()))
        .unwrap();
    registry.insert("sq", Arc::new(ScalarQuant::fit(&table, 6))).unwrap();
    registry.insert("lr", Arc::new(LowRank::fit(&table, 3))).unwrap();
    let server = Arc::new(EmbeddingServer::new(registry));
    let (addr, h) = spawn(server.clone());
    let mut c = Client::connect(addr).unwrap();

    let ids_for = |vocab: usize| -> Vec<usize> {
        (0..32).map(|i| (i * 13) % vocab).collect()
    };
    let mut promotes_expected = 0usize;
    for threads in [1usize, 2] {
        pool::set_threads(threads);
        for name in ["dpq", "dense", "sq", "lr"] {
            let vocab = match name {
                "dpq" => 300,
                _ => 60,
            };
            let ids = ids_for(vocab);
            let before = c.lookup_bin(name, &ids).unwrap();

            let file = c.admin_demote(name).unwrap();
            assert!(dir.join(&file).is_file(),
                    "{name}: spill artifact {file:?} not published");
            let st = c.stats(Some(name)).unwrap();
            assert_eq!(st.get("residency").and_then(|v| v.as_str()),
                       Some("spilled"), "{name} must report spilled");
            // double demote of a now-spilled table is typed
            match c.admin_demote(name) {
                Err(WireError::Rejected { code, .. }) => {
                    assert_eq!(code, "not_resident", "{name}")
                }
                other => panic!("{name}: {other:?}"),
            }

            // the NEXT lookup transparently reloads -- bytes identical
            let after = c.lookup_bin(name, &ids).unwrap();
            promotes_expected += 1;
            assert!(bits_equal(&before, &after),
                    "{name}: promoted rows differ at {threads} thread(s)");
            let st = c.stats(Some(name)).unwrap();
            assert_eq!(st.get("residency").and_then(|v| v.as_str()),
                       Some("resident"), "{name} must be resident again");
            assert!(!dir.join(&file).is_file(),
                    "{name}: promote must consume the artifact");
        }
        let st = c.stats(None).unwrap();
        assert_eq!(st.get("promotes").and_then(|v| v.as_usize()),
                   Some(promotes_expected));
        assert_eq!(st.get("spills").and_then(|v| v.as_usize()),
                   Some(promotes_expected));
        assert!(st.get("promote_p50_s").and_then(|v| v.as_f64()).unwrap()
                >= 0.0);
        assert!(st.get("promote_p99_s").and_then(|v| v.as_f64()).unwrap()
                >= st.get("promote_p50_s").and_then(|v| v.as_f64()).unwrap());
    }
    pool::set_threads(0); // restore env/auto resolution

    c.shutdown().unwrap();
    h.join().unwrap();
}

/// A corrupted spill artifact must answer a typed `reload_failed` on
/// promote -- not a panic, not a wedged batcher -- and the registry
/// keeps serving its other tables. Restoring the original bytes heals
/// the table with bit-exact rows.
#[test]
fn corrupted_spill_artifact_promote_is_typed_reload_failed() {
    let dir = fresh_spill_dir("corrupt");
    let registry = TableRegistry::open(spill_cfg(&dir, None, 1)).unwrap();
    let table = random_table(30, 6, 3);
    registry
        .insert("base", Arc::new(DenseTable::new(random_table(10, 4, 1)).unwrap()))
        .unwrap();
    registry
        .insert("cold", Arc::new(DenseTable::new(table.clone()).unwrap()))
        .unwrap();
    let server = Arc::new(EmbeddingServer::new(registry));
    let (addr, h) = spawn(server.clone());
    let mut c = Client::connect(addr).unwrap();

    let ids = [0usize, 29, 7];
    let before = c.lookup_bin("cold", &ids).unwrap();
    let file = c.admin_demote("cold").unwrap();
    let artifact = dir.join(&file);
    let good = std::fs::read(&artifact).unwrap();

    // truncate the artifact: the promote must fail typed
    std::fs::write(&artifact, &good[..good.len() / 2]).unwrap();
    match c.lookup_bin("cold", &ids) {
        Err(WireError::Rejected { code, message }) => {
            assert_eq!(code, "reload_failed");
            assert!(message.contains("cold"), "{message}");
        }
        other => panic!("expected reload_failed, got {other:?}"),
    }
    // ... on BOTH protocols, and the connection stays usable
    match c.lookup("cold", &ids) {
        Err(WireError::Rejected { code, .. }) => assert_eq!(code, "reload_failed"),
        other => panic!("{other:?}"),
    }
    // the registry keeps serving its other tables
    assert_eq!(c.lookup_bin("base", &[9]).unwrap().n(), 1);
    // the table is still registered and still spilled
    let st = c.stats(Some("cold")).unwrap();
    assert_eq!(st.get("residency").and_then(|v| v.as_str()), Some("spilled"));

    // healing: restore the artifact bytes; the next lookup serves the
    // exact pre-demotion rows
    std::fs::write(&artifact, &good).unwrap();
    let after = c.lookup_bin("cold", &ids).unwrap();
    assert!(bits_equal(&before, &after), "healed table serves wrong bytes");

    c.shutdown().unwrap();
    h.join().unwrap();
}

/// A spill artifact deleted out-of-band: `stats` reports
/// `residency: "lost"` (per table AND in the aggregate map), lookups
/// answer `reload_failed`, nothing panics, other tables keep serving.
#[test]
fn out_of_band_deleted_artifact_reports_lost_in_stats() {
    let dir = fresh_spill_dir("lost");
    let registry = TableRegistry::open(spill_cfg(&dir, None, 1)).unwrap();
    registry
        .insert("base", Arc::new(DenseTable::new(random_table(10, 4, 1)).unwrap()))
        .unwrap();
    registry
        .insert("gone", Arc::new(DenseTable::new(random_table(12, 5, 2)).unwrap()))
        .unwrap();
    let server = Arc::new(EmbeddingServer::new(registry));
    let (addr, h) = spawn(server.clone());
    let mut c = Client::connect(addr).unwrap();

    let file = c.admin_demote("gone").unwrap();
    std::fs::remove_file(dir.join(&file)).unwrap();

    let st = c.stats(Some("gone")).unwrap();
    assert_eq!(st.get("residency").and_then(|v| v.as_str()), Some("lost"));
    let agg = c.stats(None).unwrap();
    assert_eq!(
        agg.get("tables").unwrap().get("gone").unwrap()
            .get("residency").and_then(|v| v.as_str()),
        Some("lost")
    );
    match c.lookup_bin("gone", &[0]) {
        Err(WireError::Rejected { code, message }) => {
            assert_eq!(code, "reload_failed");
            assert!(message.contains("lost") || message.contains("missing"),
                    "{message}");
        }
        other => panic!("{other:?}"),
    }
    // the shard/batcher layer never saw the lost table: base still serves
    assert_eq!(c.lookup_bin("base", &[3, 4]).unwrap().n(), 2);

    c.shutdown().unwrap();
    h.join().unwrap();
}

/// A configured spill dir that does not exist fails loudly and typed at
/// startup -- for `open` and for `restore` with a spill override.
#[test]
fn missing_spill_dir_at_startup_fails_loudly() {
    let missing = std::env::temp_dir().join("dpq_residency_no_such_dir");
    let _ = std::fs::remove_dir_all(&missing);
    let cfg = ServerConfig {
        spill_dir: Some(missing.clone()),
        ..ServerConfig::default()
    };
    match TableRegistry::open(cfg.clone()) {
        Err(WireError::Rejected { code, message }) => {
            assert_eq!(code, "spill_dir_missing");
            assert!(message.contains("dpq_residency_no_such_dir"), "{message}");
        }
        other => panic!("expected spill_dir_missing, got {other:?}"),
    }

    // restore with a bogus spill override fails the same way
    let snap = fresh_spill_dir("snap_for_missing");
    let reg = TableRegistry::new(ServerConfig::default());
    reg.insert("t", Arc::new(DenseTable::new(random_table(4, 2, 1)).unwrap()))
        .unwrap();
    reg.snapshot(&snap).unwrap();
    reg.shutdown();
    match TableRegistry::restore(&snap, Some(cfg)) {
        Err(WireError::Rejected { code, .. }) => {
            assert_eq!(code, "spill_dir_missing")
        }
        other => panic!("{other:?}"),
    }
}

/// A dense-backed table whose reconstruct blocks on a gate, so a test
/// can hold a batcher shard mid-batch deterministically. `kind()` is
/// "dense" and `save_artifact` delegates, so a demoted SlowDense
/// promotes back as a plain `DenseTable` serving identical bytes.
struct SlowDense {
    inner: DenseTable,
    /// false until the first reconstruct may proceed.
    gate: Arc<(Mutex<bool>, Condvar)>,
    /// set when a reconstruct has started (the shard is now held).
    entered: Arc<(Mutex<bool>, Condvar)>,
}

impl SlowDense {
    fn wait_entered(entered: &Arc<(Mutex<bool>, Condvar)>) {
        let (m, cv) = &**entered;
        let mut g = m.lock().unwrap();
        while !*g {
            g = cv.wait(g).unwrap();
        }
    }

    fn open_gate(gate: &Arc<(Mutex<bool>, Condvar)>) {
        let (m, cv) = &**gate;
        *m.lock().unwrap() = true;
        cv.notify_all();
    }
}

impl EmbeddingBackend for SlowDense {
    fn kind(&self) -> &'static str {
        "dense"
    }

    fn d(&self) -> usize {
        self.inner.d()
    }

    fn vocab(&self) -> usize {
        self.inner.vocab()
    }

    fn reconstruct_rows_into(&self, ids: &[usize], out: &mut [f32]) {
        {
            let (m, cv) = &*self.entered;
            *m.lock().unwrap() = true;
            cv.notify_all();
        }
        {
            let (m, cv) = &*self.gate;
            let mut g = m.lock().unwrap();
            while !*g {
                g = cv.wait(g).unwrap();
            }
        }
        self.inner.reconstruct_rows_into(ids, out);
    }

    fn storage_bits(&self) -> usize {
        self.inner.storage_bits()
    }

    fn save_artifact(&self, path: &Path) -> AnyResult<()> {
        self.inner.save(path)
    }
}

/// Regression for the all-or-nothing fan-out promise: a table demoted
/// while a `lookup_fanout` section is QUEUED on its batcher answers
/// `no_such_table` (residency `"spilled"`) for the WHOLE frame. The
/// blocking backend holds the shard mid-batch so the interleaving is
/// deterministic: lookup in flight -> fan-out queued behind it ->
/// demote closes the queue -> whole-frame rejection; the in-flight
/// lookup still completes (it happened-before the demote).
#[test]
fn demote_between_fanout_enqueue_and_wait_rejects_whole_frame() {
    let dir = fresh_spill_dir("midflight");
    let registry = TableRegistry::open(spill_cfg(&dir, None, 1)).unwrap();
    let table = random_table(20, 4, 9);
    let gate = Arc::new((Mutex::new(false), Condvar::new()));
    let entered = Arc::new((Mutex::new(false), Condvar::new()));
    let slow = SlowDense {
        inner: DenseTable::new(table.clone()).unwrap(),
        gate: gate.clone(),
        entered: entered.clone(),
    };
    registry
        .insert("base", Arc::new(DenseTable::new(random_table(10, 4, 1)).unwrap()))
        .unwrap(); // default stays out of the way
    registry.insert("slow", Arc::new(slow)).unwrap();
    let server = Arc::new(EmbeddingServer::new(registry));
    let (addr, h) = spawn(server.clone());
    let reg = server.registry();
    let entry = reg.get("slow").unwrap();

    // client 1: a lookup that will hold the shard mid-batch
    let addr1 = addr;
    let t1 = std::thread::spawn(move || {
        let mut c1 = Client::connect(addr1).unwrap();
        c1.lookup_bin("slow", &[2, 3])
    });
    SlowDense::wait_entered(&entered); // the shard is now inside run_batch

    // client 2 (raw framing so the rejection JSON is inspectable):
    // a fan-out with a healthy section AND a slow-table section; the
    // slow section queues BEHIND the held batch
    let mut raw = TcpStream::connect(addr).unwrap();
    write_frame(
        &mut raw,
        r#"{"v":2,"op":"lookup_fanout","queries":[{"table":"base","ids":[0,1]},{"table":"slow","ids":[5]}]}"#,
    )
    .unwrap();
    // wait until the fan-out's slow section is queued (requests counter
    // ticks in begin_lookup: 1 for client 1's lookup + 1 for the section)
    while entry.stats.requests.load(std::sync::atomic::Ordering::Relaxed) < 2 {
        std::thread::yield_now();
    }

    // demote while the section is queued; stop() joins the held shard,
    // so run it on its own thread and let close() fail the queued section
    let reg2 = server.registry();
    let td = std::thread::spawn(move || reg2.demote("slow"));

    // the WHOLE frame is rejected, typed: sentinel + JSON error frame
    let mut len4 = [0u8; 4];
    use std::io::Read as _;
    raw.read_exact(&mut len4).unwrap();
    assert_eq!(u32::from_le_bytes(len4), u32::MAX,
               "fan-out must answer the rejection sentinel, not a frame");
    let err = Json::parse(&read_frame(&mut raw).unwrap()).unwrap();
    assert_eq!(err.get("code").and_then(|v| v.as_str()), Some("no_such_table"));
    assert_eq!(err.get("table").and_then(|v| v.as_str()), Some("slow"));
    assert_eq!(err.get("residency").and_then(|v| v.as_str()), Some("spilled"),
               "mid-flight demote must report the three-state residency");
    assert!(err.get("evicted").is_none(),
            "spilled is not the legacy dropped-evicted state");

    // release the held batch: client 1's in-flight lookup completes
    // (it happened-before the demote) and the demote finishes cleanly
    SlowDense::open_gate(&gate);
    let rows = t1.join().unwrap().expect("in-flight lookup must complete");
    assert_eq!(rows.row(0), &table.data[2 * 4..3 * 4]);
    td.join().unwrap().expect("demote must succeed");

    // the demoted table transparently reloads (as a plain DenseTable)
    // with bit-identical bytes
    let mut c = Client::connect(addr).unwrap();
    let back = c.lookup_bin("slow", &[5, 19]).unwrap();
    assert_eq!(back.row(0), &table.data[5 * 4..6 * 4]);
    assert_eq!(back.row(1), &table.data[19 * 4..20 * 4]);

    c.shutdown().unwrap();
    h.join().unwrap();
}

/// A fan-out spanning a SPILLED table and a resident one under a
/// budget that holds only two tables: frame-wide protection means the
/// spilled section's promotion cannot demote the frame's other table
/// (without it, each section's reload would evict the other and the
/// frame could never succeed), the answer is bit-exact, and by the
/// time the response arrives the budget has been re-enforced.
#[test]
fn fanout_promotion_under_tight_budget_protects_frame_tables() {
    let dir = fresh_spill_dir("fanout_budget");
    let bytes_per = (10 * 4 * 4) as u64;
    let registry =
        TableRegistry::open(spill_cfg(&dir, Some(2 * bytes_per), 1)).unwrap();
    let t_a = random_table(10, 4, 31);
    let t_b = random_table(10, 4, 32);
    registry
        .insert("base", Arc::new(DenseTable::new(random_table(10, 4, 30)).unwrap()))
        .unwrap(); // default -> pinned
    registry
        .insert("a", Arc::new(DenseTable::new(t_a.clone()).unwrap()))
        .unwrap();
    // inserting "b" exceeds the budget; "a" (stalest unpinned) spills
    registry
        .insert("b", Arc::new(DenseTable::new(t_b.clone()).unwrap()))
        .unwrap();
    assert_eq!(registry.residency("a"), Some(Residency::Spilled));
    let server = Arc::new(EmbeddingServer::new(registry));
    let (addr, h) = spawn(server.clone());
    let mut c = Client::connect(addr).unwrap();

    // one frame over the spilled "a" AND the resident "b"
    let sections = c
        .lookup_fanout(&[("a", &[1, 2][..]), ("b", &[3][..])])
        .unwrap();
    assert_eq!(sections.len(), 2);
    assert_eq!(sections[0].row(0), &t_a.data[1 * 4..2 * 4]);
    assert_eq!(sections[0].row(1), &t_a.data[2 * 4..3 * 4]);
    assert_eq!(sections[1].row(0), &t_b.data[3 * 4..4 * 4]);

    // the budget was settled BEFORE the response: back within budget,
    // with the frame's LRU table ("a", touched first) re-spilled
    let reg = server.registry();
    assert!(reg.resident_bytes() <= 2 * bytes_per,
            "budget must be re-enforced before the fan-out answers");
    assert_eq!(reg.residency("b"), Some(Residency::Resident),
               "the frame's other table must not be demoted mid-frame");
    assert_eq!(reg.residency("a"), Some(Residency::Spilled));

    // and the frame is repeatable -- no promote/evict livelock
    let again = c
        .lookup_fanout(&[("a", &[1, 2][..]), ("b", &[3][..])])
        .unwrap();
    assert!(bits_equal(&again[0], &sections[0]));
    assert!(bits_equal(&again[1], &sections[1]));

    c.shutdown().unwrap();
    h.join().unwrap();
}

/// Single-flight promotion: N clients hammer one demoted table from a
/// barrier; exactly ONE promote happens (promote counter == 1) and
/// every caller gets bit-correct rows.
#[test]
fn concurrent_lookups_share_one_promotion() {
    let dir = fresh_spill_dir("singleflight");
    let registry = TableRegistry::open(spill_cfg(&dir, None, 1)).unwrap();
    let table = random_table(40, 6, 21);
    registry
        .insert("base", Arc::new(DenseTable::new(random_table(10, 4, 1)).unwrap()))
        .unwrap();
    registry
        .insert("cold", Arc::new(DenseTable::new(table.clone()).unwrap()))
        .unwrap();
    let server = Arc::new(EmbeddingServer::new(registry));
    let (addr, h) = spawn(server.clone());
    let mut c = Client::connect(addr).unwrap();
    c.admin_demote("cold").unwrap();

    const CLIENTS: usize = 6;
    let barrier = Arc::new(Barrier::new(CLIENTS));
    let workers: Vec<_> = (0..CLIENTS)
        .map(|w| {
            let barrier = barrier.clone();
            std::thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                let ids: Vec<usize> = (0..8).map(|i| (w + i * 5) % 40).collect();
                barrier.wait();
                let rows = c.lookup_bin("cold", &ids).unwrap();
                (ids, rows)
            })
        })
        .collect();
    for wkr in workers {
        let (ids, rows) = wkr.join().unwrap();
        assert_eq!((rows.n(), rows.d()), (ids.len(), 6));
        for (r, &id) in ids.iter().enumerate() {
            let want = &table.data[id * 6..(id + 1) * 6];
            let got = rows.row(r);
            assert!(
                got.iter().zip(want).all(|(a, b)| a.to_bits() == b.to_bits()),
                "client got wrong bytes for id {id}"
            );
        }
    }
    assert_eq!(server.registry().promote_count(), 1,
               "exactly one promotion must serve all concurrent callers");
    let st = c.stats(None).unwrap();
    assert_eq!(st.get("promotes").and_then(|v| v.as_usize()), Some(1));

    c.shutdown().unwrap();
    h.join().unwrap();
}
