//! Multi-table serving acceptance test (no artifacts needed): one server
//! hosting a DPQ table and a LowRank table with different embedding
//! widths, routed by table name over protocol v2.
//!
//! Verifies, for DPQ_THREADS in {1, 2, 7} (via the process-wide pool
//! override -- batcher threads resolve the worker count themselves, so a
//! scoped thread-local pin can't reach them):
//!   * served binary rows are BIT-equal to a direct
//!     `EmbeddingBackend::reconstruct_rows_into` on both tables,
//!   * a v1 (version-less) frame still resolves to the default table,
//!   * the self-describing (n, d) binary header reports each table's
//!     width and `lookup_into` mismatches are typed errors,
//!   * hot load/unload admin ops work mid-serving,
//!   * per-table stats carry batch-latency percentiles.
//!
//! Everything lives in ONE #[test] because `pool::set_threads` is
//! process-wide: a sibling test running concurrently would race it.

use std::sync::{mpsc, Arc};

use dpq_embed::backend::EmbeddingBackend;
use dpq_embed::dpq::{toy_embedding, CompressedEmbedding};
use dpq_embed::quant::LowRank;
use dpq_embed::server::{
    read_frame, write_frame, Client, EmbeddingServer, ServerConfig,
    TableRegistry, WireError,
};
use dpq_embed::jsonx::Json;
use dpq_embed::tensor::TensorF;
use dpq_embed::util::{pool, Rng};

fn direct_rows(b: &dyn EmbeddingBackend, ids: &[usize]) -> Vec<f32> {
    let mut out = vec![0.0f32; ids.len() * b.d()];
    b.reconstruct_rows_into(ids, &mut out);
    out
}

fn bits_equal(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len()
        && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

#[test]
fn multi_table_v2_routing_bit_exact_across_thread_counts() {
    // two backends with DIFFERENT widths: DPQ d = 4*3 = 12, LowRank d = 20
    let dpq = toy_embedding(300, 16, 4, 3, 5);
    assert_eq!(dpq.d, 12);
    let mut rng = Rng::new(17);
    let table = TensorF {
        shape: vec![120, 20],
        data: (0..120 * 20).map(|_| rng.normal()).collect(),
    };
    let lr = Arc::new(LowRank::fit(&table, 5));
    assert_eq!((lr.vocab(), lr.d()), (120, 20));
    let dpq_backend: Arc<CompressedEmbedding> = Arc::new(dpq.clone());

    // 2 shards per table so the id-space partitioning is exercised
    let registry = TableRegistry::new(ServerConfig {
        max_batch: 32,
        shards_per_table: 2,
        ..ServerConfig::default()
    });
    registry.insert("dpq", dpq_backend.clone()).unwrap();
    registry.insert("lr", lr.clone()).unwrap();
    assert_eq!(registry.default_name().as_deref(), Some("dpq"));

    let server = Arc::new(EmbeddingServer::new(registry));
    let (tx, rx) = mpsc::channel();
    let s2 = server.clone();
    let h = std::thread::spawn(move || {
        s2.serve("127.0.0.1:0", move |a| tx.send(a).unwrap()).unwrap();
    });
    let addr = rx.recv().unwrap();
    let mut c = Client::connect(addr).unwrap();

    // ---- bit-equality across worker-pool sizes, both tables ----
    // 16k ids x d=12 is ~196k ops: past the pool's serial threshold, so
    // 2- and 7-thread settings genuinely take the multi-worker path.
    let mut baseline: Option<(Vec<f32>, Vec<f32>)> = None;
    for threads in [1usize, 2, 7] {
        pool::set_threads(threads);
        let mut idrng = Rng::new(99); // same id sequence for every setting
        let dpq_ids: Vec<usize> = (0..16384).map(|_| idrng.below(300)).collect();
        let lr_ids: Vec<usize> = (0..512).map(|_| idrng.below(120)).collect();

        let got_dpq = c.lookup_bin("dpq", &dpq_ids).unwrap();
        assert_eq!((got_dpq.n(), got_dpq.d()), (dpq_ids.len(), 12));
        assert!(
            bits_equal(got_dpq.as_slice(), &direct_rows(&*dpq_backend, &dpq_ids)),
            "dpq rows differ from direct gather at {threads} threads"
        );

        let got_lr = c.lookup_bin("lr", &lr_ids).unwrap();
        assert_eq!((got_lr.n(), got_lr.d()), (lr_ids.len(), 20));
        assert!(
            bits_equal(got_lr.as_slice(), &direct_rows(&*lr, &lr_ids)),
            "lr rows differ from direct gather at {threads} threads"
        );

        match &baseline {
            None => baseline = Some((got_dpq.as_slice().to_vec(),
                                     got_lr.as_slice().to_vec())),
            Some((bd, bl)) => {
                assert!(bits_equal(got_dpq.as_slice(), bd),
                        "dpq bits changed between thread counts");
                assert!(bits_equal(got_lr.as_slice(), bl),
                        "lr bits changed between thread counts");
            }
        }
    }
    pool::set_threads(0); // restore env/auto resolution (DPQ_THREADS in tier-1)

    // ---- the header kills the d-guessing wart: width mismatch is typed ----
    let ids = [1usize, 7, 299];
    let mut right = vec![0.0f32; ids.len() * 12];
    assert_eq!(c.lookup_into("dpq", &ids, &mut right).unwrap(), 12);
    assert!(bits_equal(&right, &direct_rows(&*dpq_backend, &ids)));
    let mut wrong = vec![0.0f32; ids.len() * 20]; // lr width against dpq table
    match c.lookup_into("dpq", &ids, &mut wrong) {
        Err(WireError::WidthMismatch { expected: 20, got: 12 }) => {}
        other => panic!("expected typed width mismatch, got {other:?}"),
    }
    // the connection survived the mismatch
    assert_eq!(c.lookup_bin("dpq", &ids).unwrap().n(), 3);

    // ---- v1 (version-less) frame resolves to the default table ----
    let mut raw = std::net::TcpStream::connect(addr).unwrap();
    write_frame(&mut raw, r#"{"op":"lookup","ids":[0,42]}"#).unwrap();
    let resp = Json::parse(&read_frame(&mut raw).unwrap()).unwrap();
    assert_eq!(resp.get("ok").and_then(|v| v.as_bool()), Some(true));
    let vecs = resp.get("vectors").unwrap().as_arr().unwrap();
    let want = direct_rows(&*dpq_backend, &[0, 42]);
    for (r, row) in vecs.iter().enumerate() {
        let row: Vec<f32> = row.as_arr().unwrap().iter()
            .map(|x| x.as_f64().unwrap() as f32).collect();
        assert!(bits_equal(&row, &want[r * 12..(r + 1) * 12]),
                "v1 frame did not serve the default (dpq) table");
    }

    // ---- tables / stats / hot admin ops ----
    let descs = c.tables().unwrap();
    let names: Vec<&str> = descs.iter().map(|t| t.name.as_str()).collect();
    assert_eq!(names, ["dpq", "lr"]);
    let dpq_desc = &descs[0];
    assert!(dpq_desc.is_default);
    assert_eq!((dpq_desc.kind.as_str(), dpq_desc.vocab, dpq_desc.d, dpq_desc.shards),
               ("dpq", 300, 12, 2));
    assert_eq!((descs[1].kind.as_str(), descs[1].d), ("low_rank", 20));

    let st = c.stats(Some("lr")).unwrap();
    assert!(st.get("requests").unwrap().as_usize().unwrap() >= 3);
    assert!(st.get("batch_p50_s").unwrap().as_f64().unwrap() >= 0.0);
    assert!(st.get("batch_p99_s").unwrap().as_f64().unwrap()
            >= st.get("batch_p50_s").unwrap().as_f64().unwrap());

    let hot_path = std::env::temp_dir().join("dpq_multi_table_hot.dpq");
    let hot = toy_embedding(40, 8, 2, 4, 31);
    hot.save(&hot_path).unwrap();
    let desc = c.admin_load("hot", hot_path.to_str().unwrap()).unwrap();
    assert_eq!((desc.kind.as_str(), desc.vocab, desc.d), ("dpq", 40, 8));
    let got = c.lookup_bin("hot", &[0, 39]).unwrap();
    assert!(bits_equal(got.as_slice(), &direct_rows(&hot, &[0, 39])));
    c.admin_unload("hot").unwrap();
    match c.lookup_bin("hot", &[0]) {
        Err(WireError::NoSuchTable(t)) => assert_eq!(t, "hot"),
        other => panic!("{other:?}"),
    }

    c.shutdown().unwrap();
    h.join().unwrap();
}
