//! Bit-exactness of every parallel kernel against the serial path.
//!
//! The worker pool (`util::pool`) requires every kernel's per-row output
//! to be independent of chunk placement, with float reductions either
//! exact (min/max) or folded per-row on the caller thread -- so
//! `DPQ_THREADS=1` and any other thread count must produce IDENTICAL
//! bits. These property tests pin that promise for random shapes and
//! thread counts {1, 2, 7} (serial fallback, even split, uneven split
//! with more workers than some inputs have chunks). A scoped
//! `with_threads` pin bypasses the small-work serial heuristic
//! (`pool::workers_for`), so these tests genuinely execute the
//! multi-worker dispatch even at small test sizes.

use std::sync::{mpsc, Arc};

use dpq_embed::dpq::{Codebook, CompressedEmbedding};
use dpq_embed::linalg;
use dpq_embed::prop_assert;
use dpq_embed::quant::{Compressor, ProductQuant, ScalarQuant};
use dpq_embed::server::{Client, EmbeddingServer};
use dpq_embed::tensor::{TensorF, TensorI};
use dpq_embed::util::pool::{set_threads, with_threads};
use dpq_embed::util::prop::prop_check;
use dpq_embed::util::Rng;

const THREADS: [usize; 3] = [1, 2, 7];

fn randn(shape: Vec<usize>, rng: &mut Rng) -> TensorF {
    let n: usize = shape.iter().product();
    TensorF { shape, data: (0..n).map(|_| rng.normal()).collect() }
}

fn toy_emb(n: usize, k: usize, dg: usize, s: usize, rng: &mut Rng)
           -> CompressedEmbedding {
    let codes = TensorI::new(
        vec![n, dg],
        (0..n * dg).map(|_| rng.below(k) as i32).collect(),
    )
    .unwrap();
    let values = TensorF::new(
        vec![k, dg, s],
        (0..k * dg * s).map(|_| rng.normal()).collect(),
    )
    .unwrap();
    CompressedEmbedding::new(Codebook::from_codes(&codes, k).unwrap(),
                             values, false)
        .unwrap()
}

#[test]
fn prop_matmul_bit_exact_across_thread_counts() {
    prop_check(16, |rng| {
        let m = 1 + rng.below(40);
        let k = 1 + rng.below(300); // crosses the k-block boundary (256)
        let n = 1 + rng.below(24);
        let a = randn(vec![m, k], rng);
        let b = randn(vec![k, n], rng);
        let serial = with_threads(1, || linalg::matmul(&a, &b));
        for t in THREADS {
            let par = with_threads(t, || linalg::matmul(&a, &b));
            prop_assert!(par.data == serial.data,
                         "matmul m={m} k={k} n={n} differs at {t} threads");
        }
        Ok(())
    });
}

#[test]
fn prop_reconstruct_table_bit_exact_across_thread_counts() {
    prop_check(16, |rng| {
        let n = 1 + rng.below(200);
        let k = 2 + rng.below(60);
        let dg = 1 + rng.below(8);
        let s = 1 + rng.below(6);
        let ce = toy_emb(n, k, dg, s, rng);
        let serial = with_threads(1, || ce.reconstruct_table());
        // serial reference: plain per-row loop, no pool involved
        for i in 0..n {
            prop_assert!(serial.row(i) == &ce.reconstruct_row(i)[..],
                         "row {i} differs from reconstruct_row");
        }
        for t in THREADS {
            let par = with_threads(t, || ce.reconstruct_table());
            prop_assert!(par.data == serial.data,
                         "table n={n} dg={dg} differs at {t} threads");
        }
        Ok(())
    });
}

#[test]
fn prop_kmeans_bit_exact_across_thread_counts() {
    prop_check(8, |rng| {
        let n = 10 + rng.below(120);
        let d = 1 + rng.below(6);
        let k = 1 + rng.below(8);
        let x = randn(vec![n, d], rng);
        let seed = rng.next_u64();
        let run = |t: usize| {
            with_threads(t, || linalg::kmeans(&x, k, 12, &mut Rng::new(seed)))
        };
        let (c1, a1, i1) = run(1);
        for t in THREADS {
            let (ct, at, it) = run(t);
            prop_assert!(ct.data == c1.data && at == a1
                             && it.to_bits() == i1.to_bits(),
                         "kmeans n={n} d={d} k={k} differs at {t} threads");
        }
        Ok(())
    });
}

#[test]
fn prop_quantizer_fits_bit_exact_across_thread_counts() {
    prop_check(8, |rng| {
        let n = 8 + rng.below(80);
        let dgs = [1usize, 2, 4];
        let d_groups = dgs[rng.below(3)];
        let d = d_groups * (1 + rng.below(4));
        let k = 2 + rng.below(10);
        let t0 = randn(vec![n, d], rng);
        let seed = rng.next_u64();
        let bits = 2 + rng.below(7) as u32;

        let sq1 = with_threads(1, || ScalarQuant::fit(&t0, bits).reconstruct());
        let pq1 = with_threads(1, || {
            ProductQuant::fit(&t0, k, d_groups, 6, &mut Rng::new(seed))
        });
        for t in THREADS {
            let sqt =
                with_threads(t, || ScalarQuant::fit(&t0, bits).reconstruct());
            prop_assert!(sqt.data == sq1.data,
                         "scalar fit n={n} d={d} differs at {t} threads");
            let pqt = with_threads(t, || {
                ProductQuant::fit(&t0, k, d_groups, 6, &mut Rng::new(seed))
            });
            prop_assert!(
                pqt.embedding().codebook == pq1.embedding().codebook
                    && pqt.reconstruct().data == pq1.reconstruct().data,
                "pq fit n={n} d={d} K={k} D={d_groups} differs at {t} threads"
            );
        }
        Ok(())
    });
}

/// End-to-end: the sharded server batcher serves bit-identical vectors
/// for every pool size. Uses the process-wide override because the
/// batcher runs on its own thread (scoped overrides are thread-local);
/// safe here because every kernel is thread-count invariant by design.
/// The global override is a heuristic ceiling, not a pin, so the
/// workload is sized (3584 ids x d=128 = ~459k ops per request) to put
/// the batcher genuinely on the multi-worker path at 2 and 7 threads.
#[test]
fn server_batcher_bit_exact_across_thread_counts() {
    let mut rng = Rng::new(42);
    let emb = toy_emb(500, 16, 8, 16, &mut rng);
    let d = emb.d; // 128
    let expect: Vec<Vec<f32>> = (0..500).map(|i| emb.reconstruct_row(i)).collect();
    for t in THREADS {
        set_threads(t);
        let server = Arc::new(EmbeddingServer::single("default", emb.clone(), 32));
        let (tx, rx) = mpsc::channel();
        let s2 = server.clone();
        let h = std::thread::spawn(move || {
            s2.serve("127.0.0.1:0", move |a| tx.send(a).unwrap()).unwrap();
        });
        let addr = rx.recv().unwrap();
        let mut c = Client::connect(addr).unwrap();
        let mut idrng = Rng::new(7); // same id sequence for every t
        for _ in 0..2 {
            let ids: Vec<usize> =
                (0..3584).map(|_| idrng.below(500)).collect();
            let got = c.lookup_bin("default", &ids).unwrap();
            assert_eq!(got.d(), d);
            for (row, &id) in got.iter().zip(&ids) {
                assert_eq!(row, &expect[id][..], "threads={t} id={id}");
            }
        }
        c.shutdown().unwrap();
        h.join().unwrap();
    }
    set_threads(0); // restore auto resolution
}
