//! Acceptance tests for replicated hot-table serving: a table
//! registered with `replicas = 3` must serve bytes **bit-identical** to
//! `replicas = 1` -- for `lookup_bin` and `lookup_fanout`, at 1 AND 2
//! worker threads, with 2 batcher shards per replica -- including a
//! demote -> promote round trip of the replicated table (the replica
//! count rides the spill tier) and a live `set_replicas` resize under
//! concurrent traffic (the handler's retry makes the swap invisible:
//! no lookup may fail or serve wrong bytes mid-resize).
//!
//! Everything lives in ONE #[test] because `pool::set_threads` is
//! process-wide (like tests/multi_table.rs); tier-1 additionally reruns
//! this file under `DPQ_THREADS=2`.

use std::sync::{mpsc, Arc};

use dpq_embed::backend::DenseTable;
use dpq_embed::dpq::toy_embedding;
use dpq_embed::server::{
    Client, EmbeddingServer, Rows, ServerConfig, TableRegistry, WireError,
};
use dpq_embed::tensor::TensorF;
use dpq_embed::util::{pool, Rng};

const DENSE_N: usize = 50;
const DENSE_D: usize = 6;
const EMB_N: usize = 120;

fn spawn(server: Arc<EmbeddingServer>)
    -> (std::net::SocketAddr, std::thread::JoinHandle<()>) {
    let (tx, rx) = mpsc::channel();
    let h = std::thread::spawn(move || {
        server.serve("127.0.0.1:0", move |a| tx.send(a).unwrap()).unwrap();
    });
    (rx.recv().unwrap(), h)
}

fn bits_equal(a: &Rows, b: &Rows) -> bool {
    a.n() == b.n()
        && a.d() == b.d()
        && a.as_slice().iter().zip(b.as_slice())
            .all(|(x, y)| x.to_bits() == y.to_bits())
}

fn dense_table() -> TensorF {
    let mut rng = Rng::new(77);
    TensorF {
        shape: vec![DENSE_N, DENSE_D],
        data: (0..DENSE_N * DENSE_D).map(|_| rng.normal()).collect(),
    }
}

/// A 2-shard registry holding the same two tables under `replicas`
/// replica sets each (the backends are deterministic, so the 1-replica
/// and 3-replica registries hold identical bytes).
fn registry_with(replicas: usize, spill: Option<std::path::PathBuf>)
    -> TableRegistry {
    let reg = TableRegistry::open(ServerConfig {
        max_batch: 16,
        shards_per_table: 2,
        spill_dir: spill,
        ..ServerConfig::default()
    })
    .unwrap();
    reg.insert_with_replicas(
        "emb", Arc::new(toy_embedding(EMB_N, 8, 4, 3, 9)), replicas)
        .unwrap();
    reg.insert_with_replicas(
        "dense", Arc::new(DenseTable::new(dense_table()).unwrap()), replicas)
        .unwrap();
    reg
}

#[test]
fn replicas_bit_identical_to_single_at_1_and_2_threads() {
    let spill = std::env::temp_dir().join("dpq_replica_equivalence_spill");
    let _ = std::fs::remove_dir_all(&spill);
    std::fs::create_dir_all(&spill).unwrap();

    let single = Arc::new(EmbeddingServer::new(registry_with(1, None)));
    let triple = Arc::new(EmbeddingServer::new(
        registry_with(3, Some(spill.clone()))));
    let (addr1, h1) = spawn(single.clone());
    let (addr3, h3) = spawn(triple.clone());
    let mut c1 = Client::connect(addr1).unwrap();
    let mut c3 = Client::connect(addr3).unwrap();

    let entry3 = triple.registry().get("emb").unwrap();
    assert_eq!((entry3.replica_count(), entry3.shard_count()), (3, 2));

    let mut rng = Rng::new(4242);
    for threads in [1usize, 2] {
        pool::set_threads(threads);
        // ---- lookup_bin: many patterns, both tables ----
        for round in 0..12 {
            for (table, vocab) in [("emb", EMB_N), ("dense", DENSE_N)] {
                let n_ids = rng.below(9);
                let mut ids: Vec<usize> =
                    (0..n_ids).map(|_| rng.below(vocab)).collect();
                if round == 0 {
                    ids = (0..vocab).rev().collect(); // all ids, reversed
                }
                let a = c1.lookup_bin(table, &ids).unwrap();
                let b = c3.lookup_bin(table, &ids).unwrap();
                assert!(bits_equal(&a, &b),
                        "{table} diverged at {threads} thread(s): {ids:?}");
            }
        }
        // ---- lookup_fanout across both tables ----
        for _ in 0..6 {
            let a: Vec<usize> =
                (0..rng.below(6)).map(|_| rng.below(EMB_N)).collect();
            let b: Vec<usize> =
                (0..rng.below(6)).map(|_| rng.below(DENSE_N)).collect();
            let queries = [("emb", &a[..]), ("dense", &b[..])];
            let xs = c1.lookup_fanout(&queries).unwrap();
            let ys = c3.lookup_fanout(&queries).unwrap();
            assert_eq!(xs.len(), ys.len());
            for (k, (x, y)) in xs.iter().zip(&ys).enumerate() {
                assert!(bits_equal(x, y),
                        "fan-out section {k} diverged at {threads} thread(s)");
            }
        }
        // replication is load-bearing, not decorative: with depth ties
        // round-robined, sequential traffic reaches several replicas
        let st = c3.stats(Some("emb")).unwrap();
        assert_eq!(st.get("replicas").and_then(|v| v.as_usize()), Some(3));
        let reps = st.get("replica").unwrap();
        let busy = (0..3)
            .filter(|&i| {
                reps.as_arr().unwrap()[i]
                    .get("batches")
                    .and_then(|v| v.as_usize())
                    .unwrap()
                    > 0
            })
            .count();
        assert!(busy >= 2, "traffic must spread across replicas: {reps:?}");
    }
    pool::set_threads(0); // restore env/auto resolution

    // ---- demote -> promote round trip of a replicated table ----
    let ids: Vec<usize> = (0..24).map(|i| (i * 11) % EMB_N).collect();
    let before = c3.lookup_bin("emb", &ids).unwrap();
    c3.admin_demote("emb").unwrap();
    let after = c3.lookup_bin("emb", &ids).unwrap(); // transparent reload
    assert!(bits_equal(&before, &after),
            "promoted replicated table serves different bytes");
    let entry = triple.registry().get("emb").unwrap();
    assert_eq!(entry.replica_count(), 3,
               "replica count must survive the spill round trip");

    // ---- live set_replicas resize under concurrent traffic ----
    // a worker hammers "dense" while the main thread flips the replica
    // count; the handler's retry-on-swap means every lookup succeeds
    // with bit-correct rows -- the resize is invisible mid-traffic
    let table = dense_table();
    let worker = {
        let addr = addr3;
        std::thread::spawn(move || -> Result<usize, String> {
            let mut c = Client::connect(addr).unwrap();
            let mut rng = Rng::new(99);
            for i in 0..400 {
                let ids: Vec<usize> =
                    (0..4).map(|_| rng.below(DENSE_N)).collect();
                let rows = c.lookup_bin("dense", &ids)
                    .map_err(|e| format!("lookup {i} failed mid-resize: {e}"))?;
                for (r, &id) in ids.iter().enumerate() {
                    let want = &table.data[id * DENSE_D..(id + 1) * DENSE_D];
                    if rows.row(r).iter().zip(want)
                        .any(|(a, b)| a.to_bits() != b.to_bits())
                    {
                        return Err(format!(
                            "lookup {i} served wrong bytes for id {id} \
                             mid-resize"));
                    }
                }
            }
            Ok(400)
        })
    };
    for n in [2usize, 4, 1, 3, 1] {
        assert_eq!(c3.admin_set_replicas("dense", n).unwrap(), n);
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    assert_eq!(worker.join().unwrap().unwrap(), 400);
    // settled state: the final resize is in force and still bit-exact
    assert_eq!(
        triple.registry().get("dense").unwrap().replica_count(), 1);
    let a = c1.lookup_bin("dense", &[0, DENSE_N - 1]).unwrap();
    let b = c3.lookup_bin("dense", &[0, DENSE_N - 1]).unwrap();
    assert!(bits_equal(&a, &b));

    // typed rejections over the wire
    match c3.admin_set_replicas("dense", 0) {
        Err(WireError::Rejected { code, .. }) => assert_eq!(code, "bad_replicas"),
        other => panic!("{other:?}"),
    }
    match c3.admin_set_replicas("nope", 2) {
        Err(WireError::NoSuchTable(t)) => assert_eq!(t, "nope"),
        other => panic!("{other:?}"),
    }
    // tables op reports the replica count
    let descs = c3.tables().unwrap();
    let emb = descs.iter().find(|t| t.name == "emb").unwrap();
    assert_eq!((emb.replicas, emb.shards), (3, 2));

    c1.shutdown().unwrap();
    c3.shutdown().unwrap();
    h1.join().unwrap();
    h3.join().unwrap();
    let _ = std::fs::remove_dir_all(&spill);
}
