//! Property/soak test for tiered residency: randomized interleavings of
//! `load` / `demote` / `lookup` / `lookup_fanout` / `unload` /
//! `set_replicas` / TTL clock ticks / full restart-recovery against 3
//! tables under a tiny `--mem-budget` with a spill tier, driven at a
//! 2-thread worker pool. The subject additionally runs per-table
//! hot-row caches (the reference does not -- the bit-compare proves the
//! cache is invisible under residency churn). Every successful lookup
//! must be BIT-identical to a pinned always-resident reference registry
//! (no budget, no spill, no TTL, 1 replica) mirroring the same
//! load/unload history, and resident bytes plus cache CAPACITY must
//! never exceed the budget after each op completes (quiescence: the
//! driver is synchronous, and demote/promote/evict all finish before
//! returning).
//!
//! TTL is driven through the registry's injected [`ManualClock`], so
//! "time passes" is an explicit deterministic op in the mix, not a
//! sleep. A "restart" op demotes every resident table, tears the
//! subject server down, and reopens a fresh registry over the same
//! spill directory -- startup recovery must re-adopt everything and
//! keep serving the exact reference bytes.
//!
//! Everything lives in ONE #[test] because `pool::set_threads` is
//! process-wide; tier-1 additionally reruns this file under
//! `DPQ_THREADS=2`.

use std::sync::{mpsc, Arc};
use std::time::Duration;

use dpq_embed::backend::DenseTable;
use dpq_embed::server::{
    Client, EmbeddingServer, ManualClock, Rows, ServerConfig, TableRegistry,
    WireError,
};
use dpq_embed::tensor::TensorF;
use dpq_embed::util::prop::prop_check;
use dpq_embed::util::{pool, Rng};

const NAMES: [&str; 3] = ["t0", "t1", "t2"];
const VOCAB: usize = 10;
const D: usize = 4;
const BYTES_PER: u64 = (VOCAB * D * 4) as u64; // dense f32 table
// fits 2 of the 3 tables plus some (not all) of their hot-row caches,
// so the budget pass must shrink caches before it may evict a table
const BUDGET: u64 = 2 * BYTES_PER + 100;
// capacity for one raw row (64-byte overhead + 16 data bytes) per table
const ROW_CACHE: u64 = 96;
const TTL_SECS: u64 = 40;

fn spawn(server: Arc<EmbeddingServer>)
    -> (std::net::SocketAddr, std::thread::JoinHandle<()>) {
    let (tx, rx) = mpsc::channel();
    let h = std::thread::spawn(move || {
        server.serve("127.0.0.1:0", move |a| tx.send(a).unwrap()).unwrap();
    });
    (rx.recv().unwrap(), h)
}

fn bits_equal(a: &Rows, b: &Rows) -> bool {
    a.n() == b.n()
        && a.d() == b.d()
        && a.as_slice().iter().zip(b.as_slice())
            .all(|(x, y)| x.to_bits() == y.to_bits())
}

/// Fresh deterministic table content per (table, load-epoch), so a
/// reload after unload serves NEW bytes -- a stale spill artifact or a
/// stale reference entry would be caught by the bit-compare.
fn fresh_table(slot: usize, epoch: u64) -> TensorF {
    let mut rng = Rng::new(1000 + slot as u64 * 97 + epoch * 7919);
    TensorF {
        shape: vec![VOCAB, D],
        data: (0..VOCAB * D).map(|_| rng.normal()).collect(),
    }
}

#[test]
fn randomized_ops_match_always_resident_reference_under_budget() {
    pool::set_threads(2); // the DPQ_THREADS=2 semantics, pinned in-process
    let mut case_no = 0u64;
    prop_check(6, |rng| {
        case_no += 1;
        let spill = std::env::temp_dir()
            .join(format!("dpq_residency_soak_{case_no}"));
        let _ = std::fs::remove_dir_all(&spill);
        std::fs::create_dir_all(&spill)
            .map_err(|e| format!("create spill dir: {e}"))?;

        let clock = Arc::new(ManualClock::new());
        let subject_cfg = ServerConfig {
            max_batch: 8,
            shards_per_table: 1,
            mem_budget_bytes: Some(BUDGET),
            spill_dir: Some(spill.clone()),
            spill_on_evict: true,
            ttl_secs: Some(TTL_SECS),
            row_cache_bytes: ROW_CACHE,
            ..ServerConfig::default()
        };
        let subject_reg =
            TableRegistry::open_with_clock(subject_cfg.clone(), clock.clone())
                .map_err(|e| format!("open: {e}"))?;
        let reference = TableRegistry::new(ServerConfig {
            max_batch: 8,
            ..ServerConfig::default()
        });

        let mut subject = Arc::new(EmbeddingServer::new(subject_reg));
        let reference = Arc::new(EmbeddingServer::new(reference));
        let (addr_s, mut h_s) = spawn(subject.clone());
        let (addr_r, h_r) = spawn(reference.clone());
        let mut cs = Client::connect(addr_s).unwrap();
        let mut cr = Client::connect(addr_r).unwrap();

        let mut epochs = [0u64; 3];
        // start with every table loaded once (the subject immediately
        // spills one of the three to fit the budget)
        for (i, name) in NAMES.iter().enumerate() {
            epochs[i] += 1;
            let t = fresh_table(i, epochs[i]);
            subject
                .registry()
                .insert(name, Arc::new(DenseTable::new(t.clone()).unwrap()))
                .unwrap();
            reference
                .registry()
                .insert(name, Arc::new(DenseTable::new(t).unwrap()))
                .unwrap();
        }

        for step in 0..60 {
            let i = rng.below(3);
            let name = NAMES[i];
            let registered = subject.registry().residency(name).is_some();
            // the registration sets must never diverge (TTL expiry and
            // restarts spill, they never unregister)
            if registered != reference.registry().residency(name).is_some() {
                return Err(format!(
                    "step {step}: registration diverged for {name}"));
            }
            match rng.below(100) {
                // ---- lookup (40%) ----
                0..=39 => {
                    let n_ids = rng.below(7);
                    let ids: Vec<usize> =
                        (0..n_ids).map(|_| rng.below(VOCAB)).collect();
                    let got = cs.lookup_bin(name, &ids);
                    let want = cr.lookup_bin(name, &ids);
                    match (got, want) {
                        (Ok(a), Ok(b)) => {
                            if !bits_equal(&a, &b) {
                                return Err(format!(
                                    "step {step}: {name} served bytes != \
                                     reference (ids {ids:?})"));
                            }
                        }
                        (Err(WireError::NoSuchTable(_)),
                         Err(WireError::NoSuchTable(_))) if !registered => {}
                        (g, w) => {
                            return Err(format!(
                                "step {step}: outcome diverged for {name}: \
                                 subject {g:?} vs reference {w:?}"));
                        }
                    }
                }
                // ---- fan-out across two tables (12%) ----
                40..=51 => {
                    let j = rng.below(3);
                    let other = NAMES[j];
                    let a: Vec<usize> =
                        (0..rng.below(5)).map(|_| rng.below(VOCAB)).collect();
                    let b: Vec<usize> =
                        (0..rng.below(5)).map(|_| rng.below(VOCAB)).collect();
                    let queries = [(name, &a[..]), (other, &b[..])];
                    let got = cs.lookup_fanout(&queries);
                    let want = cr.lookup_fanout(&queries);
                    match (got, want) {
                        (Ok(xs), Ok(ys)) => {
                            if xs.len() != 2 || ys.len() != 2
                                || !bits_equal(&xs[0], &ys[0])
                                || !bits_equal(&xs[1], &ys[1])
                            {
                                return Err(format!(
                                    "step {step}: fan-out diverged for \
                                     ({name}, {other})"));
                            }
                        }
                        (Err(_), Err(_)) => {} // both all-or-nothing rejected
                        (g, w) => {
                            return Err(format!(
                                "step {step}: fan-out outcome diverged: \
                                 subject {g:?} vs reference {w:?}"));
                        }
                    }
                }
                // ---- demote (13%, subject only) ----
                52..=64 => {
                    let res = subject.registry().demote(name);
                    let resident = matches!(
                        subject.registry().residency(name),
                        Some(dpq_embed::server::Residency::Resident));
                    match res {
                        Ok(_) => {
                            if resident {
                                return Err(format!(
                                    "step {step}: demote left {name} resident"));
                            }
                        }
                        Err(WireError::NoSuchTable(_)) if !registered => {}
                        Err(WireError::Rejected { ref code, .. })
                            if code == "not_resident" => {}
                        Err(e) => {
                            return Err(format!(
                                "step {step}: demote({name}) failed: {e}"));
                        }
                    }
                }
                // ---- load (10%) ----
                65..=74 => {
                    if !registered {
                        epochs[i] += 1;
                        let t = fresh_table(i, epochs[i]);
                        subject
                            .registry()
                            .insert(name,
                                    Arc::new(DenseTable::new(t.clone()).unwrap()))
                            .map_err(|e| format!("step {step}: load: {e}"))?;
                        reference
                            .registry()
                            .insert(name, Arc::new(DenseTable::new(t).unwrap()))
                            .map_err(|e| format!("step {step}: ref load: {e}"))?;
                    } else {
                        // loading over a registered (even spilled) name
                        // is TableExists on both registries
                        let t = fresh_table(i, 999);
                        match subject.registry().insert(
                            name, Arc::new(DenseTable::new(t).unwrap())) {
                            Err(WireError::TableExists(_)) => {}
                            Err(e) => {
                                return Err(format!(
                                    "step {step}: duplicate load of {name} \
                                     was not TableExists: {e}"));
                            }
                            Ok(_) => {
                                return Err(format!(
                                    "step {step}: duplicate load of {name} \
                                     succeeded"));
                            }
                        }
                    }
                }
                // ---- unload (8%) ----
                75..=82 => {
                    let got = subject.registry().unload(name);
                    let want = reference.registry().unload(name);
                    match (got, want) {
                        (Ok(_), Ok(_)) if registered => {}
                        (Err(WireError::NoSuchTable(_)),
                         Err(WireError::NoSuchTable(_))) if !registered => {}
                        (g, w) => {
                            return Err(format!(
                                "step {step}: unload diverged for {name}: \
                                 {g:?} vs {w:?}"));
                        }
                    }
                }
                // ---- set_replicas (8%, subject only): resizes must be
                // invisible in the served bytes ----
                83..=90 => {
                    let n = 1 + rng.below(3);
                    match subject.registry().set_replicas(name, n) {
                        Ok(got) if got == n => {}
                        Ok(got) => {
                            return Err(format!(
                                "step {step}: set_replicas({name}, {n}) \
                                 answered {got}"));
                        }
                        Err(WireError::NoSuchTable(_)) if !registered => {}
                        Err(e) => {
                            return Err(format!(
                                "step {step}: set_replicas({name}): {e}"));
                        }
                    }
                }
                // ---- TTL tick (5%): advance the injected clock and
                // sweep; expiry spills, it never unregisters. (The
                // server's accept loop also sweeps concurrently, so no
                // exact counter assertion here -- the bit-compares and
                // the registration-parity check below prove expiry is
                // invisible in the served bytes.) ----
                91..=95 => {
                    let secs = 10 + rng.below(50) as u64;
                    clock.advance(Duration::from_secs(secs));
                    subject.registry().expire_idle();
                }
                // ---- restart (4%): flush to the spill tier, tear the
                // subject down, reopen over the same directory ----
                _ => {
                    for e in subject.registry().list() {
                        match subject.registry().demote(&e.name) {
                            Ok(_) => {}
                            // the accept loop's TTL sweep may have
                            // demoted it between list() and here
                            Err(WireError::Rejected { ref code, .. })
                                if code == "not_resident" => {}
                            Err(e2) => {
                                return Err(format!(
                                    "step {step}: restart demote: {e2}"));
                            }
                        }
                    }
                    cs.shutdown().unwrap();
                    h_s.join().unwrap();
                    let reg = TableRegistry::open_with_clock(
                        subject_cfg.clone(), clock.clone())
                        .map_err(|e| format!("step {step}: reopen: {e}"))?;
                    subject = Arc::new(EmbeddingServer::new(reg));
                    let (addr2, h2) = spawn(subject.clone());
                    h_s = h2;
                    cs = Client::connect(addr2).unwrap();
                    // recovery must re-adopt the whole registration set
                    for (k, n) in NAMES.iter().enumerate() {
                        let want =
                            reference.registry().residency(n).is_some();
                        let got = subject.registry().residency(n).is_some();
                        if got != want {
                            return Err(format!(
                                "step {step}: restart lost table {} \
                                 (slot {k})", n));
                        }
                    }
                }
            }
            // quiescence invariant: the driver is synchronous and every
            // transition completes before returning, so resident bytes
            // PLUS hot-row cache capacity must respect the budget after
            // EVERY op (the two pinnable tables together fit under the
            // budget, so the soft over-budget escape hatch can never
            // trigger here -- caches are charged at capacity and shrink
            // before any table may be evicted)
            let resident = subject.registry().resident_bytes();
            let caps: u64 = subject
                .registry()
                .list()
                .iter()
                .map(|e| e.row_cache.cap_bytes())
                .sum();
            if resident + caps > BUDGET {
                return Err(format!(
                    "step {step}: resident {resident} + cache capacity \
                     {caps} bytes exceeds the {BUDGET}-byte budget after \
                     quiescence"));
            }
        }

        cs.shutdown().unwrap();
        cr.shutdown().unwrap();
        h_s.join().unwrap();
        h_r.join().unwrap();
        let _ = std::fs::remove_dir_all(&spill);
        Ok(())
    });
    pool::set_threads(0); // restore env/auto resolution
}
