//! Integration tests over real AOT artifacts: the full L3 -> PJRT -> L2/L1
//! path. Requires `make artifacts` (skipped with a clear message if the
//! artifacts directory is missing).

use dpq_embed::config::{LrSchedule, RunConfig};
use dpq_embed::coordinator::experiments;
use dpq_embed::coordinator::{checkpoint, TaskGen, Trainer};
use dpq_embed::dpq::stats as dstats;
use dpq_embed::metrics;
use dpq_embed::quant::{Compressor, ProductQuant, ScalarQuant};
use dpq_embed::runtime::{self, Runtime, Value};
use dpq_embed::util::Rng;

fn artifacts_dir() -> std::path::PathBuf {
    let mut d = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    d.push("artifacts");
    d
}

macro_rules! require_artifacts {
    () => {{
        let d = artifacts_dir();
        if !d.join("lm_ptb_full_train.manifest.json").exists() {
            eprintln!("SKIP: artifacts not built (run `make artifacts`)");
            return;
        }
        Runtime::new(d).expect("pjrt runtime")
    }};
}

fn quick_cfg(artifact: &str, steps: usize, lr: f32) -> RunConfig {
    RunConfig {
        artifact: artifact.into(),
        steps,
        seed: 11,
        lr: LrSchedule { base: lr, decay_after: usize::MAX, decay: 1.0 },
        log_every: steps,
        eval_batches: 5,
        artifacts_dir: artifacts_dir(),
        checkpoint_dir: None,
        checkpoint_every: 0,
        export_every: 0,
    }
}

#[test]
fn lm_full_loss_decreases() {
    let rt = require_artifacts!();
    let tr = Trainer::new(&rt, quick_cfg("lm_ptb_full", 60, 1.0)).quiet();
    let out = tr.run().unwrap();
    let first = out.history.first().unwrap().1[0];
    let last = out.final_metrics[0];
    assert!(last < first - 1.0, "ce {first} -> {last}");
}

#[test]
fn lm_dpq_variants_train_and_export_codes() {
    let rt = require_artifacts!();
    for v in ["sx", "vq"] {
        let prefix = format!("lm_ptb_{v}_K32D32");
        let tr = Trainer::new(&rt, quick_cfg(&prefix, 40, 1.0)).quiet();
        let out = tr.run().unwrap();
        assert!(out.final_metrics[0] < 7.0, "{v}: ce {}", out.final_metrics[0]);
        // export: codes in range, table shape matches manifest meta
        let exp = rt.load(&format!("{prefix}_export")).unwrap();
        let res = runtime::run_aux(&exp, &out.state, &[]).unwrap();
        let codes = res[0].as_i().unwrap();
        let table = res[2].as_f().unwrap();
        assert_eq!(codes.shape, vec![2000, 32]);
        assert_eq!(table.shape, vec![2000, 128]);
        assert!(codes.data.iter().all(|&c| (0..32).contains(&c)));
        // runtime-side reconstruction equals the XLA-side gather
        let ce = experiments::compress_state(&rt, &prefix, &out.state, false)
            .unwrap();
        let rec = ce.reconstruct_table();
        let err = table.rel_err(&rec);
        assert!(err < 1e-5, "{v}: reconstruct mismatch {err}");
    }
}

#[test]
fn train_state_roundtrips_through_checkpoint() {
    let rt = require_artifacts!();
    let tr = Trainer::new(&rt, quick_cfg("lm_ptb_full", 5, 1.0)).quiet();
    let out = tr.run().unwrap();
    let dir = std::env::temp_dir().join("dpq_integration_ckpt");
    std::fs::create_dir_all(&dir).unwrap();
    let p = dir.join("t.ckpt");
    checkpoint::save(&p, &out.state).unwrap();
    let back = checkpoint::load(&p).unwrap();
    assert_eq!(back.names, out.state.names);
    // evaluation with the restored state matches
    let eval = rt.load("lm_ptb_full_eval").unwrap();
    let mut gen = TaskGen::from_manifest(&eval.manifest, 3).unwrap();
    let b = gen.next_batch();
    let m1 = runtime::run_eval(&eval, &out.state, &b).unwrap();
    let m2 = runtime::run_eval(&eval, &back, &b).unwrap();
    assert!((m1[0] - m2[0]).abs() < 1e-6);
}

#[test]
fn eval_with_posthoc_compressed_table_degrades_gracefully() {
    let rt = require_artifacts!();
    // enough steps that the embedding table actually matters to the loss
    // (otherwise coarse quantization is indistinguishable from noise)
    let tr = Trainer::new(&rt, quick_cfg("lm_ptb_full", 250, 1.0)).quiet();
    let out = tr.run().unwrap();
    let table = out.state.get("emb/table").unwrap().as_f().unwrap().clone();
    let eval = rt.load("lm_ptb_full_eval").unwrap();
    let mut gen = TaskGen::from_manifest(&eval.manifest, 5).unwrap();
    let batches: Vec<Vec<Value>> = (0..4).map(|_| gen.next_batch()).collect();
    let ce_of = |st: &runtime::State| -> f32 {
        batches
            .iter()
            .map(|b| runtime::run_eval(&eval, st, b).unwrap()[0])
            .sum::<f32>()
            / batches.len() as f32
    };
    let base = ce_of(&out.state);
    // 8-bit scalar quant: near-lossless (paper Table 5 top row)
    let sq = ScalarQuant::fit(&table, 8);
    let mut st8 = out.state.clone();
    st8.set("emb/table", Value::F(sq.reconstruct())).unwrap();
    let ce8 = ce_of(&st8);
    assert!((ce8 - base).abs() < 0.05, "8-bit: {base} -> {ce8}");
    // coarse PQ: visibly worse than near-lossless scalar quant (the
    // Table 5 / Table 8 shape: aggressive post-hoc compression costs
    // task metric)
    let pq_coarse = ProductQuant::fit(&table, 8, 8, 8, &mut Rng::new(4));
    let mut stc = out.state.clone();
    stc.set("emb/table", Value::F(pq_coarse.reconstruct())).unwrap();
    let cec = ce_of(&stc);
    assert!(cec > ce8 + 0.02, "coarse PQ should cost ce: {ce8} vs {cec}");
    // moderate PQ: usable and compact
    let pq = ProductQuant::fit(&table, 32, 16, 8, &mut Rng::new(4));
    let mut stp = out.state.clone();
    stp.set("emb/table", Value::F(pq.reconstruct())).unwrap();
    let cep = ce_of(&stp);
    assert!(cep < cec + 1.0, "pq unusable: {cep}");
    assert!(cep > ce8 - 0.05, "moderate PQ should not beat lossless: {cep}");
    assert!(pq.compression_ratio(table.rows(), table.cols()) > 10.0);
}

#[test]
fn nmt_trains_and_bleu_beats_untrained() {
    let rt = require_artifacts!();
    let prefix = "nmt_vien_full";
    let tr = Trainer::new(&rt, quick_cfg(prefix, 150, 3e-3)).quiet();
    // untrained BLEU
    let init = rt.load(&format!("{prefix}_init")).unwrap();
    let state0 = runtime::run_init(&init, 11).unwrap();
    let bleu0 = tr.bleu(&state0, 2).unwrap();
    let out = tr.run().unwrap();
    let bleu1 = tr.bleu(&out.state, 2).unwrap();
    assert!(bleu1 > bleu0 + 2.0, "bleu {bleu0} -> {bleu1}");
}

#[test]
fn textc_accuracy_above_chance() {
    let rt = require_artifacts!();
    let tr = Trainer::new(&rt, quick_cfg("textc_agnews_sx_K32D16", 60, 3e-3))
        .quiet();
    let out = tr.run().unwrap();
    let acc = out.metric("acc").unwrap();
    assert!(acc > 0.4, "acc {acc} (chance = 0.25)");
}

#[test]
fn code_snapshots_stabilize() {
    let rt = require_artifacts!();
    let mut cfg = quick_cfg("lm_ptb_vq_K32D32", 60, 1.0);
    cfg.export_every = 15;
    let tr = Trainer::new(&rt, cfg).quiet();
    let out = tr.run().unwrap();
    assert!(out.code_snapshots.len() >= 3);
    let rates: Vec<f64> = out
        .code_snapshots
        .windows(2)
        .map(|w| dstats::code_change_rate(&w[0].1, &w[1].1))
        .collect();
    // change rate must drop as training converges (Fig. 6 shape)
    assert!(rates.last().unwrap() < rates.first().unwrap(),
            "rates {rates:?}");
}

#[test]
fn manifest_shapes_match_execution() {
    let rt = require_artifacts!();
    let train = rt.load("lm_ptb_full_train").unwrap();
    let m = &train.manifest;
    assert_eq!(m.kind, "train");
    assert_eq!(m.inputs.last().unwrap().name, "lr");
    let n_state = m.state_inputs().len();
    // outputs = metrics + state (same names, same order)
    let metric_n = m.metric_outputs().len();
    let out_state: Vec<&str> = m.outputs[metric_n..]
        .iter()
        .map(|s| s.name.as_str())
        .collect();
    let in_state: Vec<&str> = m
        .state_inputs()
        .iter()
        .map(|s| s.name.as_str())
        .collect();
    assert_eq!(out_state, in_state);
    assert_eq!(n_state + 2 + 1, m.inputs.len()); // state + x,y + lr
}

#[test]
fn perplexity_metric_consistency() {
    // exp of the manifest-reported ce must equal TrainOutcome::ppl
    let rt = require_artifacts!();
    let tr = Trainer::new(&rt, quick_cfg("lm_ptb_full", 10, 1.0)).quiet();
    let out = tr.run().unwrap();
    let ce = out.metric("ce").unwrap() as f64;
    assert!((out.ppl().unwrap() - metrics::perplexity(ce)).abs() < 1e-9);
}
