//! PJRT runtime: loads AOT artifacts (HLO text + JSON manifest) produced
//! by `python/compile/aot.py`, compiles them once on the PJRT CPU client,
//! and exposes typed execution (init / train-step / eval / decode /
//! export). Python never runs here -- this is the request/training path.

pub mod manifest;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{anyhow, bail, Context, Result};

pub use manifest::{IoSpec, Manifest};

use crate::tensor::{TensorF, TensorI};

/// A host-side value crossing the XLA boundary.
#[derive(Clone, Debug)]
pub enum Value {
    /// An f32 tensor.
    F(TensorF),
    /// An i32 tensor.
    I(TensorI),
}

impl Value {
    /// Convert into an XLA literal.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        match self {
            Value::F(t) => t.to_literal(),
            Value::I(t) => t.to_literal(),
        }
    }

    /// The f32 tensor, or an error for i32 values.
    pub fn as_f(&self) -> Result<&TensorF> {
        match self {
            Value::F(t) => Ok(t),
            _ => bail!("expected f32 tensor"),
        }
    }

    /// The i32 tensor, or an error for f32 values.
    pub fn as_i(&self) -> Result<&TensorI> {
        match self {
            Value::I(t) => Ok(t),
            _ => bail!("expected i32 tensor"),
        }
    }

    /// The single f32 of a scalar-shaped value.
    pub fn scalar_f(&self) -> Result<f32> {
        let t = self.as_f()?;
        if t.data.len() != 1 {
            bail!("expected scalar, shape {:?}", t.shape);
        }
        Ok(t.data[0])
    }

    /// Copy a literal back into a typed value (`dtype` from the manifest).
    pub fn from_literal(lit: &xla::Literal, dtype: &str) -> Result<Value> {
        Ok(match dtype {
            "f32" => Value::F(TensorF::from_literal(lit)?),
            "i32" => Value::I(TensorI::from_literal(lit)?),
            other => bail!("unsupported dtype {other}"),
        })
    }
}

/// One compiled artifact: manifest + PJRT executable.
pub struct Artifact {
    /// The artifact's IO contract (shapes, dtypes, roles, meta).
    pub manifest: Manifest,
    exe: xla::PjRtLoadedExecutable,
}

impl Artifact {
    /// Execute with positional literals; returns raw output literals in
    /// manifest order. This is the hot-path entry: no host-side tensor
    /// conversions beyond PJRT's own transfers.
    pub fn execute_raw<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        args: &[L],
    ) -> Result<Vec<xla::Literal>> {
        if args.len() != self.manifest.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.manifest.name,
                self.manifest.inputs.len(),
                args.len()
            );
        }
        let result = self.exe.execute::<L>(args)
            .map_err(|e| anyhow!("execute {}: {e:?}", self.manifest.name))?;
        let mut tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        let parts = tuple.decompose_tuple()
            .map_err(|e| anyhow!("untuple result: {e:?}"))?;
        if parts.len() != self.manifest.outputs.len() {
            bail!(
                "{}: manifest declares {} outputs, executable returned {}",
                self.manifest.name,
                self.manifest.outputs.len(),
                parts.len()
            );
        }
        Ok(parts)
    }

    /// Execute with positional literals; returns typed outputs.
    pub fn execute_literals(&self, args: &[xla::Literal]) -> Result<Vec<Value>> {
        let parts = self.execute_raw(args)?;
        parts
            .iter()
            .zip(&self.manifest.outputs)
            .map(|(lit, spec)| Value::from_literal(lit, &spec.dtype))
            .collect()
    }

    /// Execute with typed values (converts in and out).
    pub fn execute(&self, args: &[Value]) -> Result<Vec<Value>> {
        let lits: Vec<xla::Literal> = args
            .iter()
            .map(|v| v.to_literal())
            .collect::<Result<_>>()?;
        self.execute_literals(&lits)
    }

    /// The artifact's manifest name.
    pub fn name(&self) -> &str {
        &self.manifest.name
    }
}

/// PJRT client + compiled-executable cache, keyed by artifact name.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: Mutex<HashMap<String, std::sync::Arc<Artifact>>>,
}

impl Runtime {
    /// `dir` is the artifacts directory (default: ./artifacts).
    pub fn new(dir: impl AsRef<Path>) -> Result<Self> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(Runtime {
            client,
            dir: dir.as_ref().to_path_buf(),
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// The directory artifacts are loaded from.
    pub fn artifacts_dir(&self) -> &Path {
        &self.dir
    }

    /// True when both the HLO text and manifest for `name` exist.
    pub fn exists(&self, name: &str) -> bool {
        self.dir.join(format!("{name}.hlo.txt")).exists()
            && self.dir.join(format!("{name}.manifest.json")).exists()
    }

    /// Load + compile an artifact (cached).
    pub fn load(&self, name: &str) -> Result<std::sync::Arc<Artifact>> {
        if let Some(a) = self.cache.lock().unwrap().get(name) {
            return Ok(a.clone());
        }
        let hlo = self.dir.join(format!("{name}.hlo.txt"));
        let man = self.dir.join(format!("{name}.manifest.json"));
        let manifest = Manifest::load(&man)
            .with_context(|| format!("manifest for {name}"))?;
        let proto = xla::HloModuleProto::from_text_file(
            hlo.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parse HLO {hlo:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
        let art = std::sync::Arc::new(Artifact { manifest, exe });
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), art.clone());
        Ok(art)
    }

    /// All artifact names present in the directory.
    pub fn available(&self) -> Result<Vec<String>> {
        let mut names = Vec::new();
        for entry in std::fs::read_dir(&self.dir)? {
            let p = entry?.path();
            if let Some(fname) = p.file_name().and_then(|s| s.to_str()) {
                if let Some(stem) = fname.strip_suffix(".manifest.json") {
                    names.push(stem.to_string());
                }
            }
        }
        names.sort();
        Ok(names)
    }
}

/// Named state vector (parameters + optimizer slots) threaded through
/// train steps. Keys follow the manifest's state input order.
///
/// Entries are stored as `xla::Literal`s so the training loop feeds the
/// previous step's outputs straight back into `execute` without host-side
/// tensor conversions (§Perf: this halves the per-step host copies).
/// Typed access converts on demand via [`State::get`] / [`State::set`].
#[derive(Clone)]
pub struct State {
    /// Entry names, in the manifest's state-input order.
    pub names: Vec<String>,
    dtypes: Vec<String>,
    lits: Vec<xla::Literal>,
}

impl State {
    /// Assemble from parallel name/dtype/literal vectors.
    pub fn from_literals(names: Vec<String>, dtypes: Vec<String>,
                         lits: Vec<xla::Literal>) -> Result<State> {
        if names.len() != lits.len() || names.len() != dtypes.len() {
            bail!("state arity mismatch");
        }
        Ok(State { names, dtypes, lits })
    }

    /// The raw literals, in entry order (fed straight to `execute`).
    pub fn literals(&self) -> &[xla::Literal] {
        &self.lits
    }

    fn index(&self, name: &str) -> Option<usize> {
        self.names.iter().position(|n| n == name)
    }

    /// Typed (converting) read of one entry.
    pub fn get(&self, name: &str) -> Option<Value> {
        let i = self.index(name)?;
        Value::from_literal(&self.lits[i], &self.dtypes[i]).ok()
    }

    /// Typed write of one entry (converts to a literal).
    pub fn set(&mut self, name: &str, v: Value) -> Result<()> {
        let i = self
            .index(name)
            .ok_or_else(|| anyhow!("no state entry {name}"))?;
        self.lits[i] = v.to_literal()?;
        self.dtypes[i] = match v {
            Value::F(_) => "f32".into(),
            Value::I(_) => "i32".into(),
        };
        Ok(())
    }

    /// Iterate typed entries (used by checkpointing; converts each).
    pub fn entries(&self) -> impl Iterator<Item = (&str, Result<Value>)> {
        self.names.iter().zip(self.lits.iter().zip(&self.dtypes)).map(
            |(n, (l, d))| (n.as_str(), Value::from_literal(l, d)),
        )
    }

    /// Total element count across all state tensors (for logging).
    pub fn numel(&self) -> usize {
        self.lits.iter().map(|l| l.element_count()).sum()
    }
}

/// Run an `_init` artifact -> initial State.
pub fn run_init(art: &Artifact, seed: i32) -> Result<State> {
    if art.manifest.kind != "init" {
        bail!("{} is not an init artifact", art.manifest.name);
    }
    let seed_lit = TensorI::scalar(seed).to_literal()?;
    let out = art.execute_raw(&[seed_lit])?;
    State::from_literals(
        art.manifest.outputs.iter().map(|o| o.name.clone()).collect(),
        art.manifest.outputs.iter().map(|o| o.dtype.clone()).collect(),
        out,
    )
}

/// Outcome of one train step: metric values in manifest order.
pub struct StepOut {
    /// Metric values, aligned with the manifest's metric outputs.
    pub metrics: Vec<f32>,
}

/// Run a `_train` artifact: state + batch inputs + lr. `batch` must match
/// the manifest's non-state inputs minus the trailing lr.
pub fn run_train(art: &Artifact, state: &mut State, batch: &[Value],
                 lr: f32) -> Result<StepOut> {
    if art.manifest.kind != "train" {
        bail!("{} is not a train artifact", art.manifest.name);
    }
    let n_state = art.manifest.state_inputs().len();
    let n_batch = art.manifest.inputs.len() - n_state - 1;
    if batch.len() != n_batch {
        bail!(
            "{}: expected {} batch inputs, got {}",
            art.manifest.name, n_batch, batch.len()
        );
    }
    // state literals are borrowed straight into execute; only the (small)
    // batch + lr are converted this step.
    let mut extra: Vec<xla::Literal> = Vec::with_capacity(n_batch + 1);
    for v in batch {
        extra.push(v.to_literal()?);
    }
    extra.push(TensorF::scalar(lr).to_literal()?);
    let mut args: Vec<&xla::Literal> =
        Vec::with_capacity(art.manifest.inputs.len());
    args.extend(state.lits.iter());
    args.extend(extra.iter());
    let out = art.execute_raw(&args)?;
    let n_metrics = art.manifest.metric_outputs().len();
    let metrics = out[..n_metrics]
        .iter()
        .map(|l| Ok(l.get_first_element::<f32>()?))
        .collect::<Result<Vec<_>>>()?;
    // feed outputs back as the new state -- no host conversion
    state.lits = out.into_iter().skip(n_metrics).collect();
    Ok(StepOut { metrics })
}

/// Run an `_eval` artifact: state + batch -> metrics.
pub fn run_eval(art: &Artifact, state: &State, batch: &[Value]) -> Result<Vec<f32>> {
    if art.manifest.kind != "eval" {
        bail!("{} is not an eval artifact", art.manifest.name);
    }
    let extra: Vec<xla::Literal> = batch
        .iter()
        .map(|v| v.to_literal())
        .collect::<Result<_>>()?;
    let mut args: Vec<&xla::Literal> = state.lits.iter().collect();
    args.extend(extra.iter());
    let out = art.execute_raw(&args)?;
    out.iter()
        .map(|l| Ok(l.get_first_element::<f32>()?))
        .collect()
}

/// Run a `_decode` / `_export`-style artifact: state + extra inputs.
pub fn run_aux(art: &Artifact, state: &State, extra: &[Value]) -> Result<Vec<Value>> {
    let extra_lits: Vec<xla::Literal> = extra
        .iter()
        .map(|v| v.to_literal())
        .collect::<Result<_>>()?;
    let mut args: Vec<&xla::Literal> = state.lits.iter().collect();
    args.extend(extra_lits.iter());
    let parts = art.execute_raw(&args)?;
    parts
        .iter()
        .zip(&art.manifest.outputs)
        .map(|(lit, spec)| Value::from_literal(lit, &spec.dtype))
        .collect()
}

#[cfg(test)]
mod tests {
    // Runtime behavior against real artifacts is covered by
    // rust/tests/integration.rs (requires `make artifacts` first).
    use super::*;

    #[test]
    fn state_get_set() {
        let mut s = State::from_literals(
            vec!["a".into(), "b".into()],
            vec!["f32".into(), "f32".into()],
            vec![
                TensorF::scalar(1.0).to_literal().unwrap(),
                TensorF::scalar(2.0).to_literal().unwrap(),
            ],
        )
        .unwrap();
        assert_eq!(s.get("b").unwrap().scalar_f().unwrap(), 2.0);
        s.set("a", Value::F(TensorF::scalar(9.0))).unwrap();
        assert_eq!(s.get("a").unwrap().scalar_f().unwrap(), 9.0);
        assert!(s.set("zz", Value::F(TensorF::scalar(0.0))).is_err());
        assert_eq!(s.numel(), 2);
        assert_eq!(s.literals().len(), 2);
    }

    #[test]
    fn state_arity_mismatch_rejected() {
        assert!(State::from_literals(vec!["a".into()], vec![], vec![]).is_err());
    }

    #[test]
    fn value_scalar_checks() {
        let v = Value::F(TensorF::new(vec![2], vec![1.0, 2.0]).unwrap());
        assert!(v.scalar_f().is_err());
        assert!(v.as_i().is_err());
    }
}
