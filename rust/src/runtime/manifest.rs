//! Artifact manifest: the JSON sidecar `aot.py` writes next to each HLO
//! text file, describing input/output names, shapes, dtypes and roles plus
//! the experiment metadata (task, dataset, variant, K, D, CR, ...).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::jsonx::Json;

/// One typed input or output of an artifact.
#[derive(Clone, Debug, PartialEq)]
pub struct IoSpec {
    /// Input/output name from the Python layer.
    pub name: String,
    /// Static shape (row-major dims).
    pub shape: Vec<usize>,
    /// Element type: `"f32"` or `"i32"`.
    pub dtype: String,
    /// Role tag -- inputs: `state` | `input`; outputs: `metric` |
    /// `state` | `output`.
    pub role: String,
}

/// The JSON sidecar describing an artifact's IO contract.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Artifact name (file stem of the HLO/manifest pair).
    pub name: String,
    /// Artifact kind: `init` | `train` | `eval` | `decode` | `export`.
    pub kind: String,
    /// Input specs in positional order.
    pub inputs: Vec<IoSpec>,
    /// Output specs in positional order.
    pub outputs: Vec<IoSpec>,
    /// Free-form metadata recorded by `aot.py` (vocab sizes, flags...).
    pub meta: BTreeMap<String, Json>,
}

fn io_spec(j: &Json) -> Result<IoSpec> {
    let name = j
        .get("name")
        .and_then(|v| v.as_str())
        .ok_or_else(|| anyhow!("io spec missing name"))?
        .to_string();
    let shape = j
        .get("shape")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| anyhow!("{name}: missing shape"))?
        .iter()
        .map(|x| x.as_usize().ok_or_else(|| anyhow!("{name}: bad dim")))
        .collect::<Result<Vec<_>>>()?;
    let dtype = j
        .get("dtype")
        .and_then(|v| v.as_str())
        .ok_or_else(|| anyhow!("{name}: missing dtype"))?
        .to_string();
    if dtype != "f32" && dtype != "i32" {
        bail!("{name}: unsupported dtype {dtype}");
    }
    let role = j
        .get("role")
        .and_then(|v| v.as_str())
        .ok_or_else(|| anyhow!("{name}: missing role"))?
        .to_string();
    Ok(IoSpec { name, shape, dtype, role })
}

impl Manifest {
    /// Parse a manifest JSON document.
    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow!("manifest json: {e}"))?;
        let name = j
            .get("name")
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow!("missing name"))?
            .to_string();
        let kind = j
            .get("kind")
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow!("missing kind"))?
            .to_string();
        let inputs = j
            .get("inputs")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow!("missing inputs"))?
            .iter()
            .map(io_spec)
            .collect::<Result<Vec<_>>>()?;
        let outputs = j
            .get("outputs")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow!("missing outputs"))?
            .iter()
            .map(io_spec)
            .collect::<Result<Vec<_>>>()?;
        let meta = match j.get("meta") {
            Some(Json::Obj(m)) => m.clone(),
            _ => BTreeMap::new(),
        };
        Ok(Manifest { name, kind, inputs, outputs, meta })
    }

    /// Read and parse a manifest file.
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read {path:?}"))?;
        Self::parse(&text)
    }

    /// Inputs with role `state`, in order.
    pub fn state_inputs(&self) -> Vec<&IoSpec> {
        self.inputs.iter().filter(|s| s.role == "state").collect()
    }

    /// Inputs with role `input` (the per-step batch), in order.
    pub fn batch_inputs(&self) -> Vec<&IoSpec> {
        self.inputs
            .iter()
            .filter(|s| s.role == "input" && s.name != "lr" && s.name != "seed")
            .collect()
    }

    /// Outputs with role `metric`, in order.
    pub fn metric_outputs(&self) -> Vec<&IoSpec> {
        self.outputs.iter().filter(|s| s.role == "metric").collect()
    }

    // ---- typed meta accessors ----
    /// Meta value as usize, if present and numeric.
    pub fn meta_usize(&self, key: &str) -> Option<usize> {
        self.meta.get(key).and_then(|v| v.as_usize())
    }

    /// Meta value as f64, if present and numeric.
    pub fn meta_f64(&self, key: &str) -> Option<f64> {
        self.meta.get(key).and_then(|v| v.as_f64())
    }

    /// Meta value as a string, if present.
    pub fn meta_str(&self, key: &str) -> Option<&str> {
        self.meta.get(key).and_then(|v| v.as_str())
    }

    /// Meta value as a bool, if present.
    pub fn meta_bool(&self, key: &str) -> Option<bool> {
        self.meta.get(key).and_then(|v| v.as_bool())
    }

    /// Metric names from meta (ordered), falling back to output roles.
    pub fn metric_names(&self) -> Vec<String> {
        if let Some(Json::Arr(a)) = self.meta.get("metrics") {
            return a
                .iter()
                .filter_map(|v| v.as_str().map(|s| s.to_string()))
                .collect();
        }
        self.metric_outputs()
            .iter()
            .map(|s| s.name.clone())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"{
      "name": "lm_ptb_sx_K32D32_train",
      "kind": "train",
      "inputs": [
        {"name": "emb/key", "shape": [32, 32, 4], "dtype": "f32", "role": "state"},
        {"name": "emb/q", "shape": [2000, 128], "dtype": "f32", "role": "state"},
        {"name": "x", "shape": [16, 24], "dtype": "i32", "role": "input"},
        {"name": "lr", "shape": [], "dtype": "f32", "role": "input"}
      ],
      "outputs": [
        {"name": "ce", "shape": [], "dtype": "f32", "role": "metric"},
        {"name": "emb/key", "shape": [32, 32, 4], "dtype": "f32", "role": "state"},
        {"name": "emb/q", "shape": [2000, 128], "dtype": "f32", "role": "state"}
      ],
      "meta": {"task": "lm", "vocab": 2000, "cr": 18.25,
               "metrics": ["ce"], "share": false}
    }"#;

    #[test]
    fn parse_full_manifest() {
        let m = Manifest::parse(DOC).unwrap();
        assert_eq!(m.kind, "train");
        assert_eq!(m.state_inputs().len(), 2);
        assert_eq!(m.batch_inputs().len(), 1);
        assert_eq!(m.metric_outputs().len(), 1);
        assert_eq!(m.meta_usize("vocab"), Some(2000));
        assert_eq!(m.meta_str("task"), Some("lm"));
        assert_eq!(m.meta_bool("share"), Some(false));
        assert_eq!(m.metric_names(), vec!["ce"]);
        assert_eq!(m.inputs[0].shape, vec![32, 32, 4]);
    }

    #[test]
    fn rejects_bad_dtype() {
        let doc = DOC.replace("\"i32\"", "\"f64\"");
        assert!(Manifest::parse(&doc).is_err());
    }

    #[test]
    fn rejects_missing_fields() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse(r#"{"name": "x"}"#).is_err());
    }

    #[test]
    fn scalar_shapes_are_empty() {
        let m = Manifest::parse(DOC).unwrap();
        assert!(m.inputs.last().unwrap().shape.is_empty());
    }
}
