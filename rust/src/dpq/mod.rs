//! Runtime representation of a DPQ-compressed embedding layer: the
//! bit-packed codebook `C` (n x D codes, ceil(log2 K) bits each), the value
//! matrix `V` [K, D, d/D], reconstruction (Algorithm 1), the paper's
//! compression-ratio accounting, a binary save/load format, and the
//! code-statistics used by Appendix C (Figures 5 and 6).

pub mod stats;

use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

use crate::tensor::{TensorF, TensorI};

/// Bit-packed KD codebook: n symbols x D groups, `bits` bits per code.
#[derive(Clone, Debug, PartialEq)]
pub struct Codebook {
    /// Number of symbols (vocabulary size).
    pub n: usize,
    /// Number of subspace groups D.
    pub d_groups: usize,
    /// Centroids per group K (codes are in `0..k`).
    pub k: usize,
    bits: u32,
    packed: Vec<u64>,
}

/// Bits needed for one code in {0..k-1}.
pub fn bits_for(k: usize) -> u32 {
    assert!(k >= 2, "K must be >= 2");
    (usize::BITS - (k - 1).leading_zeros()).max(1)
}

impl Codebook {
    /// Pack an `[n, D]` integer code tensor at the minimal bit width
    /// for `k`; codes outside `[0, k)` are rejected.
    pub fn from_codes(codes: &TensorI, k: usize) -> Result<Self> {
        if codes.shape.len() != 2 {
            bail!("codes must be [n, D], got {:?}", codes.shape);
        }
        let (n, d_groups) = (codes.shape[0], codes.shape[1]);
        let bits = bits_for(k);
        let total_bits = n * d_groups * bits as usize;
        let mut packed = vec![0u64; total_bits.div_ceil(64)];
        for (idx, &c) in codes.data.iter().enumerate() {
            if c < 0 || c as usize >= k {
                bail!("code {c} out of range [0, {k}) at index {idx}");
            }
            put_bits(&mut packed, idx * bits as usize, bits, c as u64);
        }
        Ok(Codebook { n, d_groups, k, bits, packed })
    }

    /// Code of symbol `row` in subspace `group`.
    pub fn get(&self, row: usize, group: usize) -> usize {
        let idx = (row * self.d_groups + group) * self.bits as usize;
        get_bits(&self.packed, idx, self.bits) as usize
    }

    /// All D codes of one symbol.
    pub fn row(&self, row: usize) -> Vec<usize> {
        (0..self.d_groups).map(|g| self.get(row, g)).collect()
    }

    /// Unpack into an `[n, D]` integer tensor.
    pub fn to_tensor(&self) -> TensorI {
        let mut data = Vec::with_capacity(self.n * self.d_groups);
        for i in 0..self.n {
            for g in 0..self.d_groups {
                data.push(self.get(i, g) as i32);
            }
        }
        TensorI { shape: vec![self.n, self.d_groups], data }
    }

    /// Paper storage accounting: n * D * log2 K bits (we store ceil(log2 K)).
    pub fn storage_bits(&self) -> usize {
        self.n * self.d_groups * self.bits as usize
    }

    /// Bits per stored code (may exceed the minimum for `k` when a file
    /// was written with wider packing).
    pub fn bits(&self) -> u32 {
        self.bits
    }

    /// Raw packed words (for the sequential-decode fast path).
    pub(crate) fn packed_words(&self) -> &[u64] {
        &self.packed
    }
}

fn put_bits(buf: &mut [u64], bit_idx: usize, bits: u32, v: u64) {
    let word = bit_idx / 64;
    let off = (bit_idx % 64) as u32;
    buf[word] |= v << off;
    if off + bits > 64 {
        buf[word + 1] |= v >> (64 - off);
    }
}

fn get_bits(buf: &[u64], bit_idx: usize, bits: u32) -> u64 {
    let word = bit_idx / 64;
    let off = (bit_idx % 64) as u32;
    let mask = if bits == 64 { u64::MAX } else { (1u64 << bits) - 1 };
    let mut v = buf[word] >> off;
    if off + bits > 64 {
        v |= buf[word + 1] << (64 - off);
    }
    v & mask
}

/// The inference-time artifact the paper ships: codebook + value matrix.
#[derive(Clone, Debug)]
pub struct CompressedEmbedding {
    /// Per-symbol bit-packed codes.
    pub codebook: Codebook,
    /// [K, D, s] flattened row-major; s = d / D.
    pub values: TensorF,
    /// Embedding width d = D * s.
    pub d: usize,
    /// subspace-sharing flag (affects storage accounting only; a shared
    /// value matrix is materialized as identical groups).
    pub shared: bool,
}

impl CompressedEmbedding {
    /// Pair a codebook with its `[K, D, s]` value matrix (shapes
    /// cross-checked).
    pub fn new(codebook: Codebook, values: TensorF, shared: bool) -> Result<Self> {
        if values.shape.len() != 3 {
            bail!("values must be [K, D, s], got {:?}", values.shape);
        }
        if values.shape[0] != codebook.k || values.shape[1] != codebook.d_groups {
            bail!(
                "values {:?} inconsistent with codebook (K={}, D={})",
                values.shape, codebook.k, codebook.d_groups
            );
        }
        let d = values.shape[1] * values.shape[2];
        Ok(CompressedEmbedding { codebook, values, d, shared })
    }

    /// Number of symbols (rows) this embedding serves.
    pub fn vocab(&self) -> usize {
        self.codebook.n
    }

    /// Algorithm 1: reconstruct one symbol embedding into `out` `[d]`.
    ///
    /// A row's codes are bit-contiguous in the packed codebook, so this
    /// walks a single bit cursor instead of re-deriving word/offset per
    /// group (§Perf: ~35% faster than the naive per-group `get`).
    pub fn reconstruct_row_into(&self, row: usize, out: &mut [f32]) {
        let dg = self.values.shape[1];
        let s = self.values.shape[2];
        debug_assert_eq!(out.len(), self.d);
        let bits = self.codebook.bits();
        // same guarded mask as `get_bits`: 1u64 << 64 overflows in debug
        let mask = if bits == 64 { u64::MAX } else { (1u64 << bits) - 1 };
        let packed = self.codebook.packed_words();
        let mut bit = row * dg * bits as usize;
        let values = &self.values.data;
        for g in 0..dg {
            let word = bit >> 6;
            let off = (bit & 63) as u32;
            let mut v = packed[word] >> off;
            if off + bits > 64 {
                v |= packed[word + 1] << (64 - off);
            }
            let code = (v & mask) as usize;
            let base = (code * dg + g) * s;
            out[g * s..(g + 1) * s].copy_from_slice(&values[base..base + s]);
            bit += bits as usize;
        }
    }

    /// Allocating convenience wrapper around
    /// [`reconstruct_row_into`](Self::reconstruct_row_into).
    pub fn reconstruct_row(&self, row: usize) -> Vec<f32> {
        let mut out = vec![0.0; self.d];
        self.reconstruct_row_into(row, &mut out);
        out
    }

    /// Reconstruct an arbitrary id list into `out` ([ids.len(), d]
    /// row-major), sharded over the worker pool via
    /// [`backend::gather_rows_pooled`](crate::backend::gather_rows_pooled)
    /// (small gathers run serial). Panics (slice bounds) if an id is out
    /// of range -- callers validate first.
    pub fn reconstruct_rows_into(&self, ids: &[usize], out: &mut [f32]) {
        assert_eq!(out.len(), ids.len() * self.d);
        crate::backend::gather_rows_pooled(self.d, ids.len(), out, |r, orow| {
            self.reconstruct_row_into(ids[r], orow)
        });
    }

    /// Reconstruct the full [n, d] table, sharded over the worker pool.
    /// Used at model-load time and by the experiment harness.
    pub fn reconstruct_table(&self) -> TensorF {
        let n = self.codebook.n;
        let mut data = vec![0.0f32; n * self.d];
        crate::backend::gather_rows_pooled(self.d, n, &mut data, |r, orow| {
            self.reconstruct_row_into(r, orow)
        });
        TensorF { shape: vec![n, self.d], data }
    }

    /// Inference storage in bits (paper Sec. 3): codes + value matrix.
    pub fn storage_bits(&self) -> usize {
        let value_bits = if self.shared {
            32 * self.values.shape[0] * self.values.shape[2]
        } else {
            32 * self.values.numel()
        };
        self.codebook.storage_bits() + value_bits
    }

    /// CR vs a 32-bit full table of the same [n, d].
    pub fn compression_ratio(&self) -> f64 {
        (32.0 * self.codebook.n as f64 * self.d as f64)
            / self.storage_bits() as f64
    }

    // ---- binary serialization (magic, dims, packed codes, f32 values) ----

    /// Write the `DPQE` artifact: magic, u64 header dims, packed code
    /// words, f32 values. Bit-exact roundtrip through [`load`](Self::load).
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("create {path:?}"))?;
        let cb = &self.codebook;
        f.write_all(b"DPQE")?;
        for v in [
            cb.n as u64,
            cb.d_groups as u64,
            cb.k as u64,
            cb.bits as u64,
            self.values.shape[2] as u64,
            self.shared as u64,
        ] {
            f.write_all(&v.to_le_bytes())?;
        }
        for w in &cb.packed {
            f.write_all(&w.to_le_bytes())?;
        }
        for v in &self.values.data {
            f.write_all(&v.to_le_bytes())?;
        }
        Ok(())
    }

    /// Load a `DPQE` artifact written by [`save`](Self::save); corrupt
    /// or truncated files fail loudly before any allocation is sized
    /// from the header.
    pub fn load(path: &Path) -> Result<Self> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("open {path:?}"))?;
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        if &magic != b"DPQE" {
            bail!("bad magic {magic:?}");
        }
        let mut u64buf = [0u8; 8];
        let mut next = |f: &mut std::fs::File| -> Result<u64> {
            f.read_exact(&mut u64buf)?;
            Ok(u64::from_le_bytes(u64buf))
        };
        let n = next(&mut f)? as usize;
        let dg = next(&mut f)? as usize;
        let k = next(&mut f)? as usize;
        let bits = next(&mut f)? as u32;
        let s = next(&mut f)? as usize;
        let shared = next(&mut f)? != 0;
        // Header sanity BEFORE sizing any allocation from it: a corrupt
        // or truncated-then-padded file must fail loudly here, not OOM or
        // shift-overflow later. `bits` may exceed bits_for(k) (the format
        // permits wider-than-minimal packing, up to one u64 per code) but
        // never 0 or > 64.
        if bits == 0 || bits > 64 {
            bail!("corrupt header: bits={bits} (must be in 1..=64)");
        }
        if k < 2 {
            bail!("corrupt header: K={k} (must be >= 2)");
        }
        let code_bits = n
            .checked_mul(dg)
            .and_then(|x| x.checked_mul(bits as usize))
            .ok_or_else(|| anyhow::anyhow!(
                "corrupt header: n={n} D={dg} bits={bits} overflows"))?;
        let value_len = k
            .checked_mul(dg)
            .and_then(|x| x.checked_mul(s))
            .ok_or_else(|| anyhow::anyhow!(
                "corrupt header: K={k} D={dg} s={s} overflows"))?;
        let words = code_bits.div_ceil(64);
        // Check the declared payload against the actual file size before
        // allocating for it: a truncated file is a typed "truncated"
        // error up front, not a giant zeroed allocation followed by an
        // EOF partway through the read.
        let header_bytes = 4u128 + 6 * 8;
        let expect = header_bytes + words as u128 * 8 + value_len as u128 * 4;
        let actual = f.metadata().map(|m| m.len()).unwrap_or(u64::MAX) as u128;
        if actual < expect {
            bail!(
                "truncated file: {path:?} is {actual} bytes, header \
                 declares {expect}"
            );
        }
        let mut packed = vec![0u64; words];
        for w in packed.iter_mut() {
            f.read_exact(&mut u64buf)?;
            *w = u64::from_le_bytes(u64buf);
        }
        let mut vals = vec![0.0f32; value_len];
        let mut f32buf = [0u8; 4];
        for v in vals.iter_mut() {
            f.read_exact(&mut f32buf)?;
            *v = f32::from_le_bytes(f32buf);
        }
        Ok(CompressedEmbedding {
            codebook: Codebook { n, d_groups: dg, k, bits, packed },
            values: TensorF::new(vec![k, dg, s], vals)?,
            d: dg * s,
            shared,
        })
    }
}

/// The DPQ artifact served as a registry table. Fully-qualified trait
/// path on purpose: it keeps `EmbeddingBackend` out of this module's
/// method-resolution scope, so the inherent `vocab`/`storage_bits`/
/// `reconstruct_rows_into` stay unambiguous at every call site here.
impl crate::backend::EmbeddingBackend for CompressedEmbedding {
    fn kind(&self) -> &'static str {
        "dpq"
    }

    fn d(&self) -> usize {
        self.d
    }

    fn vocab(&self) -> usize {
        CompressedEmbedding::vocab(self)
    }

    fn reconstruct_rows_into(&self, ids: &[usize], out: &mut [f32]) {
        CompressedEmbedding::reconstruct_rows_into(self, ids, out)
    }

    fn storage_bits(&self) -> usize {
        CompressedEmbedding::storage_bits(self)
    }

    fn save_artifact(&self, path: &Path) -> Result<()> {
        CompressedEmbedding::save(self, path)
    }

    fn scorer(&self) -> Option<&dyn crate::scoring::ScoreBackend> {
        Some(self)
    }
}

/// ADC lookup table over the DPQ artifact: `lut[g * K + c]` is the dot
/// product of the query's subspace `g` slice with centroid `c` of group
/// `g`, built once per query (`K * d` multiplies). A candidate is then
/// scored with `D` table reads along the same packed-code bit cursor
/// `reconstruct_row_into` walks -- no f32 reconstruction at all.
struct DpqLutScorer<'a> {
    emb: &'a CompressedEmbedding,
    /// `[D, K]` row-major subspace dot-product table.
    lut: Vec<f32>,
}

impl<'a> DpqLutScorer<'a> {
    fn new(emb: &'a CompressedEmbedding, query: &[f32]) -> Self {
        debug_assert_eq!(query.len(), emb.d);
        let (k, dg, s) = (
            emb.values.shape[0],
            emb.values.shape[1],
            emb.values.shape[2],
        );
        let mut lut = vec![0.0f32; dg * k];
        for g in 0..dg {
            let q = &query[g * s..(g + 1) * s];
            for code in 0..k {
                let base = (code * dg + g) * s;
                let mut acc = 0.0f32;
                for (x, y) in q.iter().zip(&emb.values.data[base..base + s]) {
                    acc += x * y;
                }
                lut[g * k + code] = acc;
            }
        }
        DpqLutScorer { emb, lut }
    }
}

impl crate::scoring::QueryScorer for DpqLutScorer<'_> {
    fn score_block(&self, start: usize, out: &mut [f32]) {
        let cb = &self.emb.codebook;
        let (k, dg) = (cb.k, cb.d_groups);
        let bits = cb.bits();
        let mask = if bits == 64 { u64::MAX } else { (1u64 << bits) - 1 };
        let packed = cb.packed_words();
        for (i, o) in out.iter_mut().enumerate() {
            // group-order serial sum: a row's score never depends on how
            // the candidate range was chunked (the pool determinism rule)
            let mut bit = (start + i) * dg * bits as usize;
            let mut acc = 0.0f32;
            for g in 0..dg {
                let word = bit >> 6;
                let off = (bit & 63) as u32;
                let mut v = packed[word] >> off;
                if off + bits > 64 {
                    v |= packed[word + 1] << (64 - off);
                }
                acc += self.lut[g * k + (v & mask) as usize];
                bit += bits as usize;
            }
            *o = acc;
        }
    }

    fn path(&self) -> &'static str {
        "lut"
    }
}

impl crate::scoring::ScoreBackend for CompressedEmbedding {
    fn query_scorer<'a>(
        &'a self,
        query: &'a [f32],
    ) -> Box<dyn crate::scoring::QueryScorer + 'a> {
        Box::new(DpqLutScorer::new(self, query))
    }
}

/// Deterministic random DPQ fixture (uniform codes, normal values) --
/// the one shared toy-embedding builder for in-repo tests, benches and
/// the serving examples. Hidden from docs: not part of the compression
/// API.
#[doc(hidden)]
pub fn toy_embedding(n: usize, k: usize, dg: usize, s: usize, seed: u64)
                     -> CompressedEmbedding {
    let mut rng = crate::util::Rng::new(seed);
    let codes = TensorI::new(
        vec![n, dg],
        (0..n * dg).map(|_| rng.below(k) as i32).collect(),
    )
    .unwrap();
    let values = TensorF::new(
        vec![k, dg, s],
        (0..k * dg * s).map(|_| rng.normal()).collect(),
    )
    .unwrap();
    CompressedEmbedding::new(Codebook::from_codes(&codes, k).unwrap(),
                             values, false)
        .unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::{prop::prop_check, Rng};

    fn toy(n: usize, k: usize, dg: usize, s: usize, seed: u64) -> CompressedEmbedding {
        toy_embedding(n, k, dg, s, seed)
    }

    #[test]
    fn bits_for_matches_log2() {
        assert_eq!(bits_for(2), 1);
        assert_eq!(bits_for(3), 2);
        assert_eq!(bits_for(4), 2);
        assert_eq!(bits_for(32), 5);
        assert_eq!(bits_for(33), 6);
        assert_eq!(bits_for(128), 7);
    }

    #[test]
    fn codebook_roundtrip_exact() {
        let codes = TensorI::new(vec![5, 3],
                                 vec![0, 1, 2, 3, 4, 5, 6, 7, 0, 7, 3, 1, 2, 2, 2])
            .unwrap();
        let cb = Codebook::from_codes(&codes, 8).unwrap();
        assert_eq!(cb.to_tensor(), codes);
        assert_eq!(cb.get(1, 0), 3);
        assert_eq!(cb.row(3), vec![7, 3, 1]);
    }

    #[test]
    fn codebook_rejects_out_of_range() {
        let codes = TensorI::new(vec![1, 2], vec![0, 9]).unwrap();
        assert!(Codebook::from_codes(&codes, 8).is_err());
    }

    #[test]
    fn storage_bits_formula() {
        // n=1000, D=16, K=32 -> 1000*16*5 bits of codes
        let mut rng = Rng::new(1);
        let codes = TensorI::new(vec![1000, 16],
                                 (0..16000).map(|_| rng.below(32) as i32).collect())
            .unwrap();
        let cb = Codebook::from_codes(&codes, 32).unwrap();
        assert_eq!(cb.storage_bits(), 1000 * 16 * 5);
    }

    #[test]
    fn reconstruct_row_matches_manual_gather() {
        let ce = toy(10, 4, 4, 2, 2);
        for row in [0usize, 3, 9] {
            let got = ce.reconstruct_row(row);
            for g in 0..4 {
                let code = ce.codebook.get(row, g);
                let s = 2;
                let base = (code * 4 + g) * s;
                assert_eq!(&got[g * s..(g + 1) * s],
                           &ce.values.data[base..base + s]);
            }
        }
    }

    #[test]
    fn reconstruct_table_consistent_with_rows() {
        let ce = toy(7, 8, 2, 3, 3);
        let table = ce.reconstruct_table();
        for i in 0..7 {
            assert_eq!(table.row(i), &ce.reconstruct_row(i)[..]);
        }
    }

    #[test]
    fn cr_matches_paper_formula() {
        // CR = 32nd / (nD log2 K + 32Kd)
        let ce = toy(1000, 32, 16, 4, 4); // d = 64
        let want = (32.0 * 1000.0 * 64.0)
            / (1000.0 * 16.0 * 5.0 + 32.0 * 32.0 * 64.0);
        assert!((ce.compression_ratio() - want).abs() < 1e-9);
    }

    #[test]
    fn shared_values_increase_cr() {
        let mut a = toy(1000, 32, 16, 4, 5);
        let cr0 = a.compression_ratio();
        a.shared = true;
        assert!(a.compression_ratio() > cr0);
    }

    #[test]
    fn save_load_roundtrip() {
        let ce = toy(64, 32, 8, 2, 6);
        let dir = std::env::temp_dir().join("dpq_test_save");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("emb.dpq");
        ce.save(&path).unwrap();
        let back = CompressedEmbedding::load(&path).unwrap();
        assert_eq!(back.codebook, ce.codebook);
        assert_eq!(back.values, ce.values);
        assert_eq!(back.reconstruct_table(), ce.reconstruct_table());
        // storage accounting must survive the trip bit-for-bit
        assert_eq!(back.storage_bits(), ce.storage_bits());
        assert_eq!(back.compression_ratio().to_bits(),
                   ce.compression_ratio().to_bits());
        assert_eq!(back.shared, ce.shared);
    }

    /// Regression for the PR-1 `bits == 64` shift-overflow fix: a
    /// codebook packed at the maximum width (one full u64 per code, legal
    /// in the on-disk format even when K is small) must reconstruct and
    /// roundtrip through save/load. Built by struct literal because
    /// `from_codes` always packs at the minimal width.
    #[test]
    fn save_load_roundtrip_at_bits_64() {
        let (n, dg, k, s) = (6usize, 3usize, 4usize, 2usize);
        let mut rng = Rng::new(9);
        let codes: Vec<u64> = (0..n * dg).map(|_| rng.below(k) as u64).collect();
        // bits=64 => code i occupies exactly word i of `packed`
        let cb = Codebook { n, d_groups: dg, k, bits: 64, packed: codes.clone() };
        for (i, &c) in codes.iter().enumerate() {
            assert_eq!(cb.get(i / dg, i % dg), c as usize);
        }
        let values = TensorF::new(
            vec![k, dg, s],
            (0..k * dg * s).map(|_| rng.normal()).collect(),
        )
        .unwrap();
        let ce = CompressedEmbedding {
            codebook: cb,
            values,
            d: dg * s,
            shared: false,
        };
        // reconstruction exercises the bits==64 mask guard
        let manual: Vec<f32> = (0..dg)
            .flat_map(|g| {
                let code = codes[g] as usize;
                let base = (code * dg + g) * s;
                ce.values.data[base..base + s].to_vec()
            })
            .collect();
        assert_eq!(ce.reconstruct_row(0), manual);
        let dir = std::env::temp_dir().join("dpq_test_save");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("emb64.dpq");
        ce.save(&path).unwrap();
        let back = CompressedEmbedding::load(&path).unwrap();
        assert_eq!(back.codebook, ce.codebook);
        assert_eq!(back.codebook.bits(), 64);
        assert_eq!(back.values, ce.values);
        assert_eq!(back.reconstruct_table(), ce.reconstruct_table());
        assert_eq!(back.storage_bits(), ce.storage_bits());
        assert_eq!(back.compression_ratio().to_bits(),
                   ce.compression_ratio().to_bits());
    }

    #[test]
    fn load_rejects_bad_magic_and_truncation() {
        let ce = toy(16, 8, 4, 2, 11);
        let dir = std::env::temp_dir().join("dpq_test_save");
        std::fs::create_dir_all(&dir).unwrap();
        let good = dir.join("good.dpq");
        ce.save(&good).unwrap();
        let bytes = std::fs::read(&good).unwrap();

        // bad magic
        let mut corrupt = bytes.clone();
        corrupt[0] = b'X';
        let bad_magic = dir.join("bad_magic.dpq");
        std::fs::write(&bad_magic, &corrupt).unwrap();
        let err = CompressedEmbedding::load(&bad_magic).unwrap_err();
        assert!(err.to_string().contains("bad magic"), "{err}");

        // truncation at several depths: mid-magic, mid-header, mid-codes,
        // mid-values -- every one must be an error, never a short read
        // silently zero-filled
        for cut in [2usize, 20, bytes.len() / 2, bytes.len() - 3] {
            let t = dir.join(format!("trunc_{cut}.dpq"));
            std::fs::write(&t, &bytes[..cut]).unwrap();
            assert!(
                CompressedEmbedding::load(&t).is_err(),
                "truncation at {cut}/{} must fail",
                bytes.len()
            );
        }

        // corrupt bits field (offset 4 + 3*8 = 28): 0 and 65 both rejected
        for bad_bits in [0u64, 65] {
            let mut c = bytes.clone();
            c[28..36].copy_from_slice(&bad_bits.to_le_bytes());
            let p = dir.join(format!("bad_bits_{bad_bits}.dpq"));
            std::fs::write(&p, &c).unwrap();
            let err = CompressedEmbedding::load(&p).unwrap_err();
            assert!(err.to_string().contains("bits"), "{err}");
        }
    }

    #[test]
    fn lut_scorer_matches_reference_within_tolerance() {
        use crate::scoring::{self, ScoreBackend as _};
        let ce = toy(200, 16, 8, 4, 13); // d = 32
        let mut rng = Rng::new(14);
        let query: Vec<f32> = (0..32).map(|_| rng.normal()).collect();
        let ids: Vec<usize> = (0..64).map(|i| (i * 17) % 200).collect();
        let want = scoring::reference_scores(&ce, &query, &ids);
        let qs = ce.query_scorer(&query);
        assert_eq!(qs.path(), "lut");
        let mut got = vec![0.0f32; ids.len()];
        scoring::score_into(qs.as_ref(), &ids, &mut got);
        let tol = scoring::adc_tolerance(32);
        for (i, (a, b)) in got.iter().zip(&want).enumerate() {
            assert!((a - b).abs() <= tol, "id {}: {a} vs {b}", ids[i]);
        }
    }

    #[test]
    fn prop_pack_unpack_roundtrip_all_k() {
        prop_check(60, |rng| {
            let n = 1 + rng.below(80);
            let dg = 1 + rng.below(20);
            let k = 2 + rng.below(200);
            let data: Vec<i32> =
                (0..n * dg).map(|_| rng.below(k) as i32).collect();
            let codes = TensorI::new(vec![n, dg], data.clone()).unwrap();
            let cb = Codebook::from_codes(&codes, k)
                .map_err(|e| e.to_string())?;
            prop_assert!(cb.to_tensor().data == data,
                         "roundtrip mismatch n={n} dg={dg} k={k}");
            Ok(())
        });
    }
}
