//! Code statistics from Appendix C: per-group code-usage histograms
//! (Fig. 5 heat-maps), the rate of code change between checkpoints
//! (Fig. 6), and codebook perplexity/utilization summaries.

use crate::tensor::TensorI;

/// Count_k^(j) = sum_i [C_i^(j) == k]  (Appendix C.1).
/// codes: `[n, D]` -> histogram `[D][K]`.
pub fn code_distribution(codes: &TensorI, k: usize) -> Vec<Vec<usize>> {
    let (n, dg) = (codes.shape[0], codes.shape[1]);
    let mut hist = vec![vec![0usize; k]; dg];
    for i in 0..n {
        for (g, h) in hist.iter_mut().enumerate() {
            h[codes.data[i * dg + g] as usize] += 1;
        }
    }
    hist
}

/// Fraction of code slots used at least once, per group, averaged.
pub fn utilization(codes: &TensorI, k: usize) -> f64 {
    let hist = code_distribution(codes, k);
    let used: usize = hist
        .iter()
        .map(|h| h.iter().filter(|&&c| c > 0).count())
        .sum();
    used as f64 / (hist.len() * k) as f64
}

/// Perplexity of the code distribution (2^entropy), averaged over groups.
/// High perplexity = evenly used codes (the paper observes DPQ-VQ spreads
/// usage more evenly than DPQ-SX).
pub fn code_perplexity(codes: &TensorI, k: usize) -> f64 {
    let hist = code_distribution(codes, k);
    let n = codes.shape[0] as f64;
    let mut total = 0.0;
    for h in &hist {
        let mut ent = 0.0;
        for &c in h {
            if c > 0 {
                let p = c as f64 / n;
                ent -= p * p.log2();
            }
        }
        total += ent.exp2();
    }
    total / hist.len() as f64
}

/// Percentage of code bits changed between two checkpoints (Appendix C.2,
/// Fig. 6). Operates on code *entries* (one K-way choice each).
pub fn code_change_rate(prev: &TensorI, cur: &TensorI) -> f64 {
    assert_eq!(prev.shape, cur.shape, "codebooks must have equal shape");
    let changed = prev
        .data
        .iter()
        .zip(&cur.data)
        .filter(|(a, b)| a != b)
        .count();
    changed as f64 / prev.data.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn codes(shape: Vec<usize>, data: Vec<i32>) -> TensorI {
        TensorI::new(shape, data).unwrap()
    }

    #[test]
    fn distribution_counts() {
        let c = codes(vec![3, 2], vec![0, 1, 0, 1, 2, 1]);
        let h = code_distribution(&c, 3);
        assert_eq!(h[0], vec![2, 0, 1]); // group 0 saw codes 0,0,2
        assert_eq!(h[1], vec![0, 3, 0]); // group 1 saw 1,1,1
    }

    #[test]
    fn utilization_bounds() {
        let c = codes(vec![4, 1], vec![0, 0, 0, 0]);
        assert!((utilization(&c, 4) - 0.25).abs() < 1e-9);
        let c2 = codes(vec![4, 1], vec![0, 1, 2, 3]);
        assert!((utilization(&c2, 4) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn perplexity_uniform_equals_k() {
        let c = codes(vec![4, 1], vec![0, 1, 2, 3]);
        assert!((code_perplexity(&c, 4) - 4.0).abs() < 1e-6);
    }

    #[test]
    fn perplexity_concentrated_is_one() {
        let c = codes(vec![5, 2], vec![1, 0, 1, 0, 1, 0, 1, 0, 1, 0]);
        assert!((code_perplexity(&c, 4) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn change_rate() {
        let a = codes(vec![2, 2], vec![0, 1, 2, 3]);
        let b = codes(vec![2, 2], vec![0, 1, 2, 0]);
        assert!((code_change_rate(&a, &b) - 0.25).abs() < 1e-9);
        assert_eq!(code_change_rate(&a, &a), 0.0);
    }
}
