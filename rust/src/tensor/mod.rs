//! Minimal dense tensors used by the coordinator: row-major f32 / i32
//! arrays with shape checking, plus the conversions to and from the xla
//! crate's `Literal`. Heavy math happens inside the AOT-compiled XLA
//! executables; these tensors carry data across the boundary and back and
//! power the post-hoc compression baselines in `quant/`.

use anyhow::{bail, Context, Result};

/// Row-major f32 tensor (shape `[]` is a scalar of one element).
#[derive(Clone, Debug, PartialEq)]
pub struct TensorF {
    /// Dimension sizes, outermost first.
    pub shape: Vec<usize>,
    /// Row-major values; `data.len()` equals the product of `shape`.
    pub data: Vec<f32>,
}

/// Row-major i32 tensor (ids, codes, labels).
#[derive(Clone, Debug, PartialEq)]
pub struct TensorI {
    /// Dimension sizes, outermost first.
    pub shape: Vec<usize>,
    /// Row-major values; `data.len()` equals the product of `shape`.
    pub data: Vec<i32>,
}

fn numel(shape: &[usize]) -> usize {
    shape.iter().product()
}

impl TensorF {
    /// Build a tensor, checking `data.len()` against the shape product.
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        if numel(&shape) != data.len() {
            bail!("shape {:?} != data len {}", shape, data.len());
        }
        Ok(TensorF { shape, data })
    }

    /// All-zeros tensor of the given shape.
    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = numel(&shape);
        TensorF { shape, data: vec![0.0; n] }
    }

    /// Rank-0 tensor holding one value.
    pub fn scalar(v: f32) -> Self {
        TensorF { shape: vec![], data: vec![v] }
    }

    /// Total number of elements.
    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Rows view for a 2-D tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        let cols = self.shape[1];
        &self.data[i * cols..(i + 1) * cols]
    }

    /// Mutable row view for a 2-D tensor.
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let cols = self.shape[1];
        &mut self.data[i * cols..(i + 1) * cols]
    }

    /// Leading dimension of a 2-D tensor.
    pub fn rows(&self) -> usize {
        self.shape[0]
    }

    /// Trailing dimension of a 2-D tensor.
    pub fn cols(&self) -> usize {
        self.shape[1]
    }

    /// Convert into an XLA literal of the same shape.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let lit = xla::Literal::vec1(&self.data);
        if self.shape.is_empty() {
            // scalar: vec1 of len 1 reshaped to rank 0
            return Ok(lit.reshape(&[])?);
        }
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        Ok(lit.reshape(&dims)?)
    }

    /// Copy an f32 XLA literal back into a tensor.
    pub fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = literal_dims(lit)?;
        let data = lit.to_vec::<f32>().context("literal not f32")?;
        TensorF::new(shape, data)
    }

    /// Frobenius norm of the difference (reconstruction-error metric).
    pub fn rel_err(&self, other: &TensorF) -> f32 {
        assert_eq!(self.shape, other.shape);
        let num: f32 = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b) * (a - b))
            .sum();
        let den: f32 = self.data.iter().map(|a| a * a).sum();
        (num / den.max(1e-12)).sqrt()
    }
}

impl TensorI {
    /// Build a tensor, checking `data.len()` against the shape product.
    pub fn new(shape: Vec<usize>, data: Vec<i32>) -> Result<Self> {
        if numel(&shape) != data.len() {
            bail!("shape {:?} != data len {}", shape, data.len());
        }
        Ok(TensorI { shape, data })
    }

    /// Rank-0 tensor holding one value.
    pub fn scalar(v: i32) -> Self {
        TensorI { shape: vec![], data: vec![v] }
    }

    /// Leading dimension of a 2-D tensor.
    pub fn rows(&self) -> usize {
        self.shape[0]
    }

    /// Rows view for a 2-D tensor.
    pub fn row(&self, i: usize) -> &[i32] {
        let cols = self.shape[1];
        &self.data[i * cols..(i + 1) * cols]
    }

    /// Convert into an XLA literal of the same shape.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let lit = xla::Literal::vec1(&self.data);
        if self.shape.is_empty() {
            return Ok(lit.reshape(&[])?);
        }
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        Ok(lit.reshape(&dims)?)
    }

    /// Copy an i32 XLA literal back into a tensor.
    pub fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = literal_dims(lit)?;
        let data = lit.to_vec::<i32>().context("literal not i32")?;
        TensorI::new(shape, data)
    }
}

fn literal_dims(lit: &xla::Literal) -> Result<Vec<usize>> {
    let shape = lit.array_shape()?;
    Ok(shape.dims().iter().map(|&d| d as usize).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_mismatch_rejected() {
        assert!(TensorF::new(vec![2, 3], vec![0.0; 5]).is_err());
        assert!(TensorI::new(vec![2], vec![1, 2, 3]).is_err());
    }

    #[test]
    fn rows_and_cols() {
        let t = TensorF::new(vec![2, 3], (0..6).map(|x| x as f32).collect())
            .unwrap();
        assert_eq!(t.row(1), &[3.0, 4.0, 5.0]);
        assert_eq!(t.rows(), 2);
        assert_eq!(t.cols(), 3);
    }

    #[test]
    fn rel_err_zero_for_identical() {
        let t = TensorF::new(vec![4], vec![1.0, -2.0, 3.0, 0.5]).unwrap();
        assert_eq!(t.rel_err(&t), 0.0);
    }

    #[test]
    fn rel_err_scales() {
        let a = TensorF::new(vec![2], vec![1.0, 0.0]).unwrap();
        let b = TensorF::new(vec![2], vec![0.0, 0.0]).unwrap();
        assert!((a.rel_err(&b) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn literal_roundtrip_f32() {
        let t = TensorF::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let lit = t.to_literal().unwrap();
        let back = TensorF::from_literal(&lit).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn literal_roundtrip_scalar() {
        let t = TensorF::scalar(3.5);
        let lit = t.to_literal().unwrap();
        let back = TensorF::from_literal(&lit).unwrap();
        assert_eq!(back.data, vec![3.5]);
        assert!(back.shape.is_empty());
    }

    #[test]
    fn literal_roundtrip_i32() {
        let t = TensorI::new(vec![3], vec![7, -1, 2]).unwrap();
        let back = TensorI::from_literal(&t.to_literal().unwrap()).unwrap();
        assert_eq!(t, back);
    }
}
