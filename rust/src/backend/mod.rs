//! `EmbeddingBackend` -- the one lookup interface every embedding store
//! in this crate serves through.
//!
//! The paper's inference claim (a DPQ codebook gather is as cheap as a
//! full-table row read at a fraction of the memory) only pays off at
//! production scale when one server hosts *many* compressed tables. The
//! server therefore routes every lookup through this trait instead of
//! hardcoding [`CompressedEmbedding`](crate::dpq::CompressedEmbedding):
//! any row store that can gather ids into a flat `[n, d]` buffer and
//! account for its own storage can be registered as a served table.
//!
//! Implementors in-crate:
//! * [`crate::dpq::CompressedEmbedding`] -- the DPQ artifact (`kind = "dpq"`),
//! * [`crate::quant::ScalarQuant`] -- b-bit uniform codes (`"scalar_quant"`),
//! * [`crate::quant::LowRank`] -- truncated-SVD factors (`"low_rank"`),
//! * [`DenseTable`] -- the uncompressed `[n, d]` baseline (`"dense"`),
//! * [`MultiGranular`] -- id ranges routed to per-segment sub-backends,
//!   the MGQE dense-head/DPQ-tail arrangement (`"multi_granular"`),
//! * [`HashingTable`] -- the hashing-trick baseline: ids share bucket
//!   rows through a fixed hash (`"hashing"`).
//!
//! Gathers must be *deterministic across thread counts*: every impl
//! routes through [`gather_rows_pooled`], which shards rows over the
//! shared worker pool (`util::pool`) under the crate's determinism rule
//! (a row's bits never depend on which chunk it landed in), so a served
//! vector is bit-identical for every `DPQ_THREADS` setting.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::tensor::TensorF;
use crate::util::pool;

pub mod multigranular;

pub use multigranular::{HashingTable, MultiGranular};

/// A row store the embedding server can host as one named table.
///
/// Object-safe on purpose: the registry holds `Arc<dyn EmbeddingBackend>`.
pub trait EmbeddingBackend: Send + Sync {
    /// Short scheme tag shown by `tables`/`stats` ("dpq", "dense", ...).
    fn kind(&self) -> &'static str;

    /// Embedding width (columns per row).
    fn d(&self) -> usize;

    /// Number of rows (valid ids are `0..vocab()`).
    fn vocab(&self) -> usize;

    /// Gather `ids` into `out` (`[ids.len(), d]` row-major). Callers
    /// validate ids against [`vocab`](Self::vocab) first; an impl may
    /// panic on an out-of-range id. Must be bit-identical for every
    /// worker-pool size.
    fn reconstruct_rows_into(&self, ids: &[usize], out: &mut [f32]);

    /// Total inference-time storage in bits (codes + side tables).
    fn storage_bits(&self) -> usize;

    /// Serialize this backend to `path` in its kind's binary artifact
    /// format, such that [`load_backend`] with the same
    /// [`kind`](Self::kind) reconstructs a backend serving bit-identical
    /// rows. Registry snapshots (`TableRegistry::snapshot`) call this
    /// for every resident table, and the registry's spill tier
    /// (`--spill-dir` demotion + transparent reload) reuses the exact
    /// same format -- one serialization path, two lifecycles. The
    /// default refuses, so external impls that never snapshot or spill
    /// don't have to invent a format.
    fn save_artifact(&self, path: &Path) -> Result<()> {
        let _ = path;
        bail!(
            "backend kind {:?} does not support artifact serialization",
            self.kind()
        )
    }

    /// Scoring capability: a backend that can serve similarity queries
    /// over its representation returns itself as a
    /// [`ScoreBackend`](crate::scoring::ScoreBackend). The default is
    /// `None`, so the server rejects `score`/`topk` against an external
    /// backend kind with a typed error instead of guessing. All four
    /// in-crate kinds implement it: `dpq`/`scalar_quant` with the ADC
    /// lookup-table fast path, `dense`/`low_rank` with the exact
    /// reconstruct-then-dot path.
    fn scorer(&self) -> Option<&dyn crate::scoring::ScoreBackend> {
        None
    }
}

/// Deserialize a backend artifact previously written by
/// [`EmbeddingBackend::save_artifact`], dispatching on the `kind` tag a
/// snapshot manifest recorded for it. The returned backend serves rows
/// bit-identical to the snapshotted one.
pub fn load_backend(kind: &str, path: &Path) -> Result<std::sync::Arc<dyn EmbeddingBackend>> {
    Ok(match kind {
        "dpq" => std::sync::Arc::new(crate::dpq::CompressedEmbedding::load(path)?),
        "dense" => std::sync::Arc::new(DenseTable::load(path)?),
        "scalar_quant" => std::sync::Arc::new(crate::quant::ScalarQuant::load(path)?),
        "low_rank" => std::sync::Arc::new(crate::quant::LowRank::load(path)?),
        "multi_granular" => std::sync::Arc::new(MultiGranular::load(path)?),
        "hashing" => std::sync::Arc::new(HashingTable::load(path)?),
        other => bail!("unknown backend kind {other:?} (not one of dpq, dense, scalar_quant, low_rank, multi_granular, hashing)"),
    })
}

/// Map an artifact file's 4-byte magic to its backend kind, so the
/// admin `load` op can hot-load any in-crate artifact without being
/// told the kind (snapshot and spill manifests record kinds explicitly
/// and never need this). Short files and unknown magics fail typed.
pub fn sniff_kind(path: &Path) -> Result<&'static str> {
    use std::io::Read as _;
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("open {path:?}"))?;
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)
        .with_context(|| format!("read artifact magic of {path:?}"))?;
    Ok(match &magic {
        b"DPQE" => "dpq",
        b"DPQD" => "dense",
        b"DPQS" => "scalar_quant",
        b"DPQL" => "low_rank",
        b"DPQM" => "multi_granular",
        b"DPQH" => "hashing",
        other => bail!("unknown artifact magic {other:?} in {path:?}"),
    })
}

/// Shared helpers for the per-kind binary artifact formats: a 4-byte
/// magic, a fixed number of u64 LE header dims, then a raw payload whose
/// exact size is a function of the dims. `open` verifies magic, header,
/// and total file size BEFORE any allocation is sized from the header, so
/// corrupt or truncated artifacts fail loudly up front (the same
/// discipline as `CompressedEmbedding::load`).
pub(crate) mod artifact_io {
    use std::io::{BufReader, BufWriter, Read, Write};
    use std::path::Path;

    use anyhow::{bail, Context, Result};

    /// Create `path` and write `magic` + the u64 LE header `dims`.
    pub fn create(path: &Path, magic: &[u8; 4], dims: &[u64])
                  -> Result<BufWriter<std::fs::File>> {
        let f = std::fs::File::create(path)
            .with_context(|| format!("create {path:?}"))?;
        let mut w = BufWriter::new(f);
        w.write_all(magic)?;
        for v in dims {
            w.write_all(&v.to_le_bytes())?;
        }
        Ok(w)
    }

    /// Open `path`, check `magic`, read `n_dims` header values, and verify
    /// the file size matches `payload_bytes(dims)` exactly (`None` from
    /// the closure means the dims overflow). Strict equality also rejects
    /// trailing garbage.
    pub fn open(
        path: &Path,
        magic: &[u8; 4],
        n_dims: usize,
        payload_bytes: impl FnOnce(&[u64]) -> Option<u128>,
    ) -> Result<(BufReader<std::fs::File>, Vec<u64>)> {
        let f = std::fs::File::open(path)
            .with_context(|| format!("open {path:?}"))?;
        let actual = f.metadata().map(|m| m.len()).unwrap_or(u64::MAX) as u128;
        let mut r = BufReader::new(f);
        let mut got = [0u8; 4];
        r.read_exact(&mut got)?;
        if &got != magic {
            bail!("bad magic {got:?} in {path:?} (want {magic:?})");
        }
        let mut dims = vec![0u64; n_dims];
        let mut b = [0u8; 8];
        for v in dims.iter_mut() {
            r.read_exact(&mut b)?;
            *v = u64::from_le_bytes(b);
        }
        let payload = payload_bytes(&dims).ok_or_else(|| {
            anyhow::anyhow!("corrupt header {dims:?} in {path:?}: size overflows")
        })?;
        let expect = 4 + 8 * n_dims as u128 + payload;
        if actual != expect {
            bail!(
                "corrupt or truncated file {path:?}: {actual} bytes, \
                 header declares {expect}"
            );
        }
        Ok((r, dims))
    }

    /// Write a f32 slice as LE bytes.
    pub fn write_f32s(w: &mut impl Write, vals: &[f32]) -> Result<()> {
        for v in vals {
            w.write_all(&v.to_le_bytes())?;
        }
        Ok(())
    }

    /// Read `n` LE f32 values.
    pub fn read_f32s(r: &mut impl Read, n: usize) -> Result<Vec<f32>> {
        let mut out = vec![0.0f32; n];
        let mut b = [0u8; 4];
        for v in out.iter_mut() {
            r.read_exact(&mut b)?;
            *v = f32::from_le_bytes(b);
        }
        Ok(out)
    }

    /// Write a u16 slice as LE bytes.
    pub fn write_u16s(w: &mut impl Write, vals: &[u16]) -> Result<()> {
        for v in vals {
            w.write_all(&v.to_le_bytes())?;
        }
        Ok(())
    }

    /// Read `n` LE u16 values.
    pub fn read_u16s(r: &mut impl Read, n: usize) -> Result<Vec<u16>> {
        let mut out = vec![0u16; n];
        let mut b = [0u8; 2];
        for v in out.iter_mut() {
            r.read_exact(&mut b)?;
            *v = u16::from_le_bytes(b);
        }
        Ok(out)
    }

    /// SHA-256 of the file at `path` as `(64-hex digest, byte length)`,
    /// streamed in 64 KiB windows so hashing a spilled artifact never
    /// costs its size in memory. The content-addressing primitive every
    /// artifact write path records and every reload path verifies
    /// BEFORE parsing -- a digest mismatch is detected without trusting
    /// a single header byte of the corrupt file.
    pub fn file_sha256(path: &Path) -> Result<(String, u64)> {
        let f = std::fs::File::open(path)
            .with_context(|| format!("open {path:?} for hashing"))?;
        let mut r = BufReader::new(f);
        let mut h = crate::util::sha256::Sha256::new();
        let mut total = 0u64;
        let mut buf = [0u8; 64 * 1024];
        loop {
            let n = r.read(&mut buf)
                .with_context(|| format!("read {path:?} for hashing"))?;
            if n == 0 {
                break;
            }
            h.update(&buf[..n]);
            total += n as u64;
        }
        Ok((h.finalize_hex(), total))
    }
}

/// Compression ratio vs an f32 table of the same `[vocab, d]` shape.
/// A free function (not a trait method) so the name cannot collide with
/// the `quant::Compressor` method of the same purpose at call sites that
/// import both traits.
pub fn compression_ratio(b: &dyn EmbeddingBackend) -> f64 {
    (32.0 * b.vocab() as f64 * b.d() as f64) / b.storage_bits().max(1) as f64
}

/// Shared pool-sharded gather: reconstruct `n_rows` rows into `out`
/// (`[n_rows, d]` row-major), row `r`'s content produced by
/// `row_into(r, slice)`. Single home for the chunk-sizing arithmetic used
/// by every backend (and by whole-table reconstruction in `dpq`). Small
/// workloads run serial (`pool::workers_for`); rows are independent, so
/// every thread count produces identical bits.
pub fn gather_rows_pooled(
    d: usize,
    n_rows: usize,
    out: &mut [f32],
    row_into: impl Fn(usize, &mut [f32]) + Sync,
) {
    debug_assert_eq!(out.len(), n_rows * d);
    if d == 0 || n_rows == 0 {
        return;
    }
    pool::with_threads(pool::workers_for(n_rows * d), || {
        let rows_per_chunk = pool::chunk_len(n_rows);
        pool::par_chunks_mut(out, rows_per_chunk * d, |ci, chunk| {
            let row0 = ci * rows_per_chunk;
            for (ri, orow) in chunk.chunks_mut(d).enumerate() {
                row_into(row0 + ri, orow);
            }
        });
    });
}

/// The uncompressed baseline: a plain f32 `[n, d]` table served through
/// the same interface as the compressed stores, so benches and tests can
/// compare serving cost at CR 1.0.
pub struct DenseTable {
    table: TensorF,
}

impl DenseTable {
    /// Wrap an `[n, d]` tensor (rejects other ranks).
    pub fn new(table: TensorF) -> Result<Self> {
        if table.shape.len() != 2 {
            bail!("DenseTable expects [n, d], got {:?}", table.shape);
        }
        Ok(DenseTable { table })
    }

    /// The underlying `[n, d]` table.
    pub fn table(&self) -> &TensorF {
        &self.table
    }

    /// Serialize as a `DPQD` artifact: magic, `n`/`d` header, raw f32 LE
    /// rows. Bit-exact roundtrip through [`DenseTable::load`].
    pub fn save(&self, path: &Path) -> Result<()> {
        use std::io::Write as _;
        let (n, d) = (self.table.shape[0], self.table.shape[1]);
        let mut w = artifact_io::create(path, b"DPQD", &[n as u64, d as u64])?;
        artifact_io::write_f32s(&mut w, &self.table.data)?;
        w.flush()?;
        Ok(())
    }

    /// Load a `DPQD` artifact written by [`DenseTable::save`].
    pub fn load(path: &Path) -> Result<Self> {
        let (mut r, dims) = artifact_io::open(path, b"DPQD", 2, |d| {
            (d[0] as u128).checked_mul(d[1] as u128)?.checked_mul(4)
        })?;
        let (n, d) = (dims[0] as usize, dims[1] as usize);
        let data = artifact_io::read_f32s(&mut r, n * d)?;
        DenseTable::new(TensorF { shape: vec![n, d], data })
    }
}

impl EmbeddingBackend for DenseTable {
    fn kind(&self) -> &'static str {
        "dense"
    }

    fn d(&self) -> usize {
        self.table.shape[1]
    }

    fn vocab(&self) -> usize {
        self.table.shape[0]
    }

    fn reconstruct_rows_into(&self, ids: &[usize], out: &mut [f32]) {
        assert_eq!(out.len(), ids.len() * self.d());
        gather_rows_pooled(self.d(), ids.len(), out, |r, orow| {
            orow.copy_from_slice(self.table.row(ids[r]));
        });
    }

    fn storage_bits(&self) -> usize {
        32 * self.table.numel()
    }

    fn save_artifact(&self, path: &Path) -> Result<()> {
        self.save(path)
    }

    fn scorer(&self) -> Option<&dyn crate::scoring::ScoreBackend> {
        Some(self)
    }
}

/// Dense scoring is the exact path by definition: reconstruct (a row
/// copy) then serial dot -- bit-identical to the reference
/// implementation at every thread count.
impl crate::scoring::ScoreBackend for DenseTable {
    fn query_scorer<'a>(
        &'a self,
        query: &'a [f32],
    ) -> Box<dyn crate::scoring::QueryScorer + 'a> {
        Box::new(crate::scoring::ExactScorer::new(self, query))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::pool::with_threads;
    use crate::util::Rng;

    fn toy_table(n: usize, d: usize, seed: u64) -> TensorF {
        let mut rng = Rng::new(seed);
        TensorF {
            shape: vec![n, d],
            data: (0..n * d).map(|_| rng.normal()).collect(),
        }
    }

    #[test]
    fn dense_table_round_trips_rows() {
        let t = toy_table(20, 6, 1);
        let dt = DenseTable::new(t.clone()).unwrap();
        assert_eq!(dt.vocab(), 20);
        assert_eq!(dt.d(), 6);
        assert_eq!(dt.storage_bits(), 32 * 120);
        assert!((compression_ratio(&dt) - 1.0).abs() < 1e-12);
        let ids = [3usize, 0, 19, 3];
        let mut out = vec![0.0f32; ids.len() * 6];
        dt.reconstruct_rows_into(&ids, &mut out);
        for (r, &id) in ids.iter().enumerate() {
            assert_eq!(&out[r * 6..(r + 1) * 6], t.row(id));
        }
    }

    #[test]
    fn dense_table_rejects_non_2d() {
        assert!(DenseTable::new(TensorF::zeros(vec![2, 3, 4])).is_err());
    }

    #[test]
    fn gather_is_thread_count_invariant() {
        let t = toy_table(100, 16, 2);
        let dt = DenseTable::new(t).unwrap();
        let ids: Vec<usize> = (0..257).map(|i| (i * 37) % 100).collect();
        let mut base = vec![0.0f32; ids.len() * 16];
        with_threads(1, || dt.reconstruct_rows_into(&ids, &mut base));
        for threads in [2usize, 7] {
            let mut got = vec![0.0f32; ids.len() * 16];
            with_threads(threads, || dt.reconstruct_rows_into(&ids, &mut got));
            assert!(
                got.iter().zip(&base).all(|(a, b)| a.to_bits() == b.to_bits()),
                "threads={threads}"
            );
        }
    }

    #[test]
    fn dense_table_artifact_roundtrip_bit_exact() {
        let t = toy_table(30, 5, 9);
        let dt = DenseTable::new(t.clone()).unwrap();
        let dir = std::env::temp_dir().join("dpq_backend_artifacts");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("dense.dense");
        dt.save_artifact(&path).unwrap();
        let back = load_backend("dense", &path).unwrap();
        assert_eq!((back.kind(), back.vocab(), back.d()), ("dense", 30, 5));
        assert_eq!(back.storage_bits(), dt.storage_bits());
        let ids: Vec<usize> = vec![0, 29, 7, 7];
        let mut a = vec![0.0f32; ids.len() * 5];
        let mut b = vec![0.0f32; ids.len() * 5];
        dt.reconstruct_rows_into(&ids, &mut a);
        back.reconstruct_rows_into(&ids, &mut b);
        assert!(a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()));
        // corrupt magic and truncation both fail loudly
        let bytes = std::fs::read(&path).unwrap();
        let bad = dir.join("bad.dense");
        std::fs::write(&bad, &bytes[..bytes.len() - 2]).unwrap();
        assert!(DenseTable::load(&bad).is_err());
        let mut flipped = bytes.clone();
        flipped[0] = b'X';
        std::fs::write(&bad, &flipped).unwrap();
        assert!(load_backend("dense", &bad).is_err());
        assert!(load_backend("nope", &path).is_err());
    }

    #[test]
    fn gather_handles_empty_and_zero_d() {
        let dt = DenseTable::new(toy_table(4, 3, 3)).unwrap();
        let mut out: Vec<f32> = Vec::new();
        dt.reconstruct_rows_into(&[], &mut out);
        gather_rows_pooled(0, 5, &mut out, |_, _| panic!("d=0 gathers nothing"));
    }
}
