//! `EmbeddingBackend` -- the one lookup interface every embedding store
//! in this crate serves through.
//!
//! The paper's inference claim (a DPQ codebook gather is as cheap as a
//! full-table row read at a fraction of the memory) only pays off at
//! production scale when one server hosts *many* compressed tables. The
//! server therefore routes every lookup through this trait instead of
//! hardcoding [`CompressedEmbedding`](crate::dpq::CompressedEmbedding):
//! any row store that can gather ids into a flat `[n, d]` buffer and
//! account for its own storage can be registered as a served table.
//!
//! Implementors in-crate:
//! * [`crate::dpq::CompressedEmbedding`] -- the DPQ artifact (`kind = "dpq"`),
//! * [`crate::quant::ScalarQuant`] -- b-bit uniform codes (`"scalar_quant"`),
//! * [`crate::quant::LowRank`] -- truncated-SVD factors (`"low_rank"`),
//! * [`DenseTable`] -- the uncompressed `[n, d]` baseline (`"dense"`).
//!
//! Gathers must be *deterministic across thread counts*: every impl
//! routes through [`gather_rows_pooled`], which shards rows over the
//! shared worker pool (`util::pool`) under the crate's determinism rule
//! (a row's bits never depend on which chunk it landed in), so a served
//! vector is bit-identical for every `DPQ_THREADS` setting.

use anyhow::{bail, Result};

use crate::tensor::TensorF;
use crate::util::pool;

/// A row store the embedding server can host as one named table.
///
/// Object-safe on purpose: the registry holds `Arc<dyn EmbeddingBackend>`.
pub trait EmbeddingBackend: Send + Sync {
    /// Short scheme tag shown by `tables`/`stats` ("dpq", "dense", ...).
    fn kind(&self) -> &'static str;

    /// Embedding width (columns per row).
    fn d(&self) -> usize;

    /// Number of rows (valid ids are `0..vocab()`).
    fn vocab(&self) -> usize;

    /// Gather `ids` into `out` (`[ids.len(), d]` row-major). Callers
    /// validate ids against [`vocab`](Self::vocab) first; an impl may
    /// panic on an out-of-range id. Must be bit-identical for every
    /// worker-pool size.
    fn reconstruct_rows_into(&self, ids: &[usize], out: &mut [f32]);

    /// Total inference-time storage in bits (codes + side tables).
    fn storage_bits(&self) -> usize;
}

/// Compression ratio vs an f32 table of the same `[vocab, d]` shape.
/// A free function (not a trait method) so the name cannot collide with
/// the `quant::Compressor` method of the same purpose at call sites that
/// import both traits.
pub fn compression_ratio(b: &dyn EmbeddingBackend) -> f64 {
    (32.0 * b.vocab() as f64 * b.d() as f64) / b.storage_bits().max(1) as f64
}

/// Shared pool-sharded gather: reconstruct `n_rows` rows into `out`
/// (`[n_rows, d]` row-major), row `r`'s content produced by
/// `row_into(r, slice)`. Single home for the chunk-sizing arithmetic used
/// by every backend (and by whole-table reconstruction in `dpq`). Small
/// workloads run serial (`pool::workers_for`); rows are independent, so
/// every thread count produces identical bits.
pub fn gather_rows_pooled(
    d: usize,
    n_rows: usize,
    out: &mut [f32],
    row_into: impl Fn(usize, &mut [f32]) + Sync,
) {
    debug_assert_eq!(out.len(), n_rows * d);
    if d == 0 || n_rows == 0 {
        return;
    }
    pool::with_threads(pool::workers_for(n_rows * d), || {
        let rows_per_chunk = pool::chunk_len(n_rows);
        pool::par_chunks_mut(out, rows_per_chunk * d, |ci, chunk| {
            let row0 = ci * rows_per_chunk;
            for (ri, orow) in chunk.chunks_mut(d).enumerate() {
                row_into(row0 + ri, orow);
            }
        });
    });
}

/// The uncompressed baseline: a plain f32 `[n, d]` table served through
/// the same interface as the compressed stores, so benches and tests can
/// compare serving cost at CR 1.0.
pub struct DenseTable {
    table: TensorF,
}

impl DenseTable {
    pub fn new(table: TensorF) -> Result<Self> {
        if table.shape.len() != 2 {
            bail!("DenseTable expects [n, d], got {:?}", table.shape);
        }
        Ok(DenseTable { table })
    }

    pub fn table(&self) -> &TensorF {
        &self.table
    }
}

impl EmbeddingBackend for DenseTable {
    fn kind(&self) -> &'static str {
        "dense"
    }

    fn d(&self) -> usize {
        self.table.shape[1]
    }

    fn vocab(&self) -> usize {
        self.table.shape[0]
    }

    fn reconstruct_rows_into(&self, ids: &[usize], out: &mut [f32]) {
        assert_eq!(out.len(), ids.len() * self.d());
        gather_rows_pooled(self.d(), ids.len(), out, |r, orow| {
            orow.copy_from_slice(self.table.row(ids[r]));
        });
    }

    fn storage_bits(&self) -> usize {
        32 * self.table.numel()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::pool::with_threads;
    use crate::util::Rng;

    fn toy_table(n: usize, d: usize, seed: u64) -> TensorF {
        let mut rng = Rng::new(seed);
        TensorF {
            shape: vec![n, d],
            data: (0..n * d).map(|_| rng.normal()).collect(),
        }
    }

    #[test]
    fn dense_table_round_trips_rows() {
        let t = toy_table(20, 6, 1);
        let dt = DenseTable::new(t.clone()).unwrap();
        assert_eq!(dt.vocab(), 20);
        assert_eq!(dt.d(), 6);
        assert_eq!(dt.storage_bits(), 32 * 120);
        assert!((compression_ratio(&dt) - 1.0).abs() < 1e-12);
        let ids = [3usize, 0, 19, 3];
        let mut out = vec![0.0f32; ids.len() * 6];
        dt.reconstruct_rows_into(&ids, &mut out);
        for (r, &id) in ids.iter().enumerate() {
            assert_eq!(&out[r * 6..(r + 1) * 6], t.row(id));
        }
    }

    #[test]
    fn dense_table_rejects_non_2d() {
        assert!(DenseTable::new(TensorF::zeros(vec![2, 3, 4])).is_err());
    }

    #[test]
    fn gather_is_thread_count_invariant() {
        let t = toy_table(100, 16, 2);
        let dt = DenseTable::new(t).unwrap();
        let ids: Vec<usize> = (0..257).map(|i| (i * 37) % 100).collect();
        let mut base = vec![0.0f32; ids.len() * 16];
        with_threads(1, || dt.reconstruct_rows_into(&ids, &mut base));
        for threads in [2usize, 7] {
            let mut got = vec![0.0f32; ids.len() * 16];
            with_threads(threads, || dt.reconstruct_rows_into(&ids, &mut got));
            assert!(
                got.iter().zip(&base).all(|(a, b)| a.to_bits() == b.to_bits()),
                "threads={threads}"
            );
        }
    }

    #[test]
    fn gather_handles_empty_and_zero_d() {
        let dt = DenseTable::new(toy_table(4, 3, 3)).unwrap();
        let mut out: Vec<f32> = Vec::new();
        dt.reconstruct_rows_into(&[], &mut out);
        gather_rows_pooled(0, 5, &mut out, |_, _| panic!("d=0 gathers nothing"));
    }
}
