//! Multi-granular and hashing-trick embedding backends.
//!
//! [`MultiGranular`] is the MGQE serving arrangement: one logical id
//! space routed across differently-compressed sub-backends by id range
//! -- typically an uncompressed (or lightly compressed) head for the
//! frequent ids and an aggressively compressed DPQ tail for the long
//! tail, matching the skew of real lookup traffic. [`HashingTable`] is
//! the compositional hashing-trick baseline the paper compares against:
//! ids share bucket rows via a hash, trading collisions for memory.
//!
//! Both are full [`EmbeddingBackend`]s: they serve through the registry
//! (snapshot/spill/restore included) and score through the exact
//! reconstruct-then-dot path, so every determinism guarantee the server
//! makes holds for them unchanged.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::backend::{artifact_io, gather_rows_pooled, EmbeddingBackend};
use crate::tensor::TensorF;

/// Longest backend kind tag accepted when parsing an embedded segment
/// header: kinds are short scheme names, so anything longer is a
/// corrupt length field, rejected before it can size an allocation.
const MAX_KIND_LEN: u64 = 64;

/// Per-process sequence for the temp files embedded sub-artifacts pass
/// through (two concurrent save/load calls must not share a path).
static TMP_SEQ: AtomicU64 = AtomicU64::new(0);

fn tmp_path(stem: &str) -> PathBuf {
    let seq = TMP_SEQ.fetch_add(1, Ordering::Relaxed);
    std::env::temp_dir().join(format!(
        "dpq_mg_{stem}.{}-{seq}.tmp", std::process::id()))
}

/// One contiguous id range served by one sub-backend: global ids
/// `start..end` map to sub-backend rows `0..end-start`.
struct Segment {
    start: usize,
    end: usize,
    backend: Arc<dyn EmbeddingBackend>,
}

/// Multi-granular table: a partition of `0..vocab` into contiguous
/// segments, each served by its own sub-backend (MGQE: dense head +
/// DPQ tail). Rows are bit-identical to querying the owning sub-backend
/// directly, so the arrangement is invisible to every serving contract.
pub struct MultiGranular {
    segments: Vec<Segment>,
    d: usize,
    vocab: usize,
}

impl MultiGranular {
    /// Assemble segments from `(start, backend)` pairs; each segment
    /// covers `start .. start + backend.vocab()`. The pairs must tile
    /// `0..vocab` exactly in order: a first segment not starting at 0,
    /// a gap, or an overlap is a typed construction error (so a
    /// mis-specified head/tail split fails loudly instead of serving
    /// rows from the wrong store). Sub-backends must agree on `d`, and
    /// nesting a `multi_granular` inside another is rejected -- segments
    /// are leaf stores, which keeps the artifact format non-recursive.
    pub fn new(segments: Vec<(usize, Arc<dyn EmbeddingBackend>)>) -> Result<Self> {
        if segments.is_empty() {
            bail!("MultiGranular needs at least one segment");
        }
        let d = segments[0].1.d();
        let mut segs = Vec::with_capacity(segments.len());
        let mut cursor = 0usize;
        for (i, (start, backend)) in segments.into_iter().enumerate() {
            if backend.kind() == "multi_granular" {
                bail!("segment {i} is itself multi_granular: segments \
                       must be leaf backends");
            }
            if backend.d() != d {
                bail!("segment {i} has d={} but segment 0 has d={d}",
                      backend.d());
            }
            if backend.vocab() == 0 {
                bail!("segment {i} is empty (sub-backend vocab 0)");
            }
            if start > cursor {
                bail!("gap in id space: segment {i} starts at {start} but \
                       coverage ends at {cursor}");
            }
            if start < cursor {
                bail!("overlapping segments: segment {i} starts at {start} \
                       inside the range ending at {cursor}");
            }
            let end = start
                .checked_add(backend.vocab())
                .with_context(|| format!("segment {i} overflows the id space"))?;
            segs.push(Segment { start, end, backend });
            cursor = end;
        }
        Ok(MultiGranular { segments: segs, d, vocab: cursor })
    }

    /// Number of segments.
    pub fn segment_count(&self) -> usize {
        self.segments.len()
    }

    /// The segment boundaries as `(start, end, kind)` in id order
    /// (surfaced by tests and tooling; the routing itself is internal).
    pub fn segment_ranges(&self) -> Vec<(usize, usize, &'static str)> {
        self.segments
            .iter()
            .map(|s| (s.start, s.end, s.backend.kind()))
            .collect()
    }

    /// Index of the segment owning `id` (callers validated `id < vocab`).
    fn segment_of(&self, id: usize) -> usize {
        self.segments.partition_point(|s| s.end <= id)
    }

    /// Write the `DPQM` artifact: magic, `vocab`/`d`/`n_segments`/
    /// `payload_bytes` header, then per segment `end`, kind tag, and the
    /// sub-backend's own artifact bytes embedded verbatim (serialized
    /// through a temp file -- one serialization path per kind, reused).
    /// Bit-exact roundtrip through [`load`](Self::load).
    pub fn save(&self, path: &Path) -> Result<()> {
        use std::io::Write as _;
        let mut blob: Vec<u8> = Vec::new();
        for (i, seg) in self.segments.iter().enumerate() {
            let tmp = tmp_path("seg");
            let written = seg
                .backend
                .save_artifact(&tmp)
                .and_then(|_| {
                    std::fs::read(&tmp)
                        .with_context(|| format!("read back {tmp:?}"))
                });
            let _ = std::fs::remove_file(&tmp);
            let bytes = written
                .with_context(|| format!("serialize segment {i}"))?;
            let kind = seg.backend.kind().as_bytes();
            blob.extend_from_slice(&(seg.end as u64).to_le_bytes());
            blob.extend_from_slice(&(kind.len() as u64).to_le_bytes());
            blob.extend_from_slice(kind);
            blob.extend_from_slice(&(bytes.len() as u64).to_le_bytes());
            blob.extend_from_slice(&bytes);
        }
        let mut w = artifact_io::create(path, b"DPQM", &[
            self.vocab as u64,
            self.d as u64,
            self.segments.len() as u64,
            blob.len() as u64,
        ])?;
        w.write_all(&blob)?;
        w.flush()?;
        Ok(())
    }

    /// Load a `DPQM` artifact written by [`save`](Self::save). Every
    /// segment is revalidated through [`new`](Self::new), so a
    /// hand-edited artifact with overlapping or gapped ranges fails
    /// with the same typed errors as direct construction.
    pub fn load(path: &Path) -> Result<Self> {
        use std::io::Read as _;
        let (mut r, dims) =
            artifact_io::open(path, b"DPQM", 4, |d| Some(d[3] as u128))?;
        let (vocab, d, n_seg) =
            (dims[0] as usize, dims[1] as usize, dims[2] as usize);
        let mut blob = vec![0u8; dims[3] as usize];
        r.read_exact(&mut blob)?;
        fn take_u64(blob: &[u8], at: &mut usize, path: &Path) -> Result<u64> {
            let Some(b) = blob.get(*at..*at + 8) else {
                bail!("corrupt segment blob in {path:?}: truncated header");
            };
            *at += 8;
            Ok(u64::from_le_bytes(b.try_into().unwrap()))
        }
        let mut at = 0usize;
        let mut segments: Vec<(usize, Arc<dyn EmbeddingBackend>)> =
            Vec::with_capacity(n_seg);
        let mut start = 0usize;
        for i in 0..n_seg {
            let end = take_u64(&blob, &mut at, path)?;
            let kind_len = take_u64(&blob, &mut at, path)?;
            if kind_len > MAX_KIND_LEN {
                bail!("corrupt segment {i} in {path:?}: kind length {kind_len}");
            }
            let Some(kind) = blob
                .get(at..at + kind_len as usize)
                .and_then(|b| std::str::from_utf8(b).ok())
                .map(str::to_string)
            else {
                bail!("corrupt segment {i} in {path:?}: bad kind tag");
            };
            at += kind_len as usize;
            let byte_len = take_u64(&blob, &mut at, path)?;
            // checked end: a hostile 64-bit length must fail typed, not
            // overflow the slice arithmetic
            let Some(bytes) = at
                .checked_add(byte_len as usize)
                .and_then(|e| blob.get(at..e))
            else {
                bail!("corrupt segment {i} in {path:?}: truncated payload");
            };
            at += byte_len as usize;
            // the embedded bytes ARE the segment kind's own artifact:
            // round them through a temp file into the kind's loader so
            // its magic/size checks apply unchanged
            let tmp = tmp_path("load");
            let loaded = std::fs::write(&tmp, bytes)
                .with_context(|| format!("stage segment {i} to {tmp:?}"))
                .and_then(|_| crate::backend::load_backend(&kind, &tmp));
            let _ = std::fs::remove_file(&tmp);
            let backend =
                loaded.with_context(|| format!("load segment {i} of {path:?}"))?;
            segments.push((start, backend));
            start = end as usize;
        }
        if at != blob.len() {
            bail!("corrupt segment blob in {path:?}: {} trailing bytes",
                  blob.len() - at);
        }
        let mg = MultiGranular::new(segments)?;
        if mg.vocab != vocab || mg.d != d {
            bail!(
                "artifact {path:?} header declares [{vocab}, {d}] but \
                 segments assemble to [{}, {}]", mg.vocab, mg.d);
        }
        Ok(mg)
    }
}

impl EmbeddingBackend for MultiGranular {
    fn kind(&self) -> &'static str {
        "multi_granular"
    }

    fn d(&self) -> usize {
        self.d
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn reconstruct_rows_into(&self, ids: &[usize], out: &mut [f32]) {
        let d = self.d;
        assert_eq!(out.len(), ids.len() * d);
        // Group ids by owning segment, gather each group through the
        // sub-backend's own pooled gather, then scatter to the request
        // positions. Each sub-gather is thread-count invariant and the
        // scatter is positional, so the whole gather is too -- and the
        // pool is entered once per segment, never nested.
        let mut local: Vec<Vec<usize>> = vec![Vec::new(); self.segments.len()];
        let mut pos: Vec<Vec<usize>> = vec![Vec::new(); self.segments.len()];
        for (p, &id) in ids.iter().enumerate() {
            let si = self.segment_of(id);
            local[si].push(id - self.segments[si].start);
            pos[si].push(p);
        }
        for (si, seg) in self.segments.iter().enumerate() {
            if local[si].is_empty() {
                continue;
            }
            let mut flat = vec![0.0f32; local[si].len() * d];
            seg.backend.reconstruct_rows_into(&local[si], &mut flat);
            for (k, &p) in pos[si].iter().enumerate() {
                out[p * d..(p + 1) * d]
                    .copy_from_slice(&flat[k * d..(k + 1) * d]);
            }
        }
    }

    fn storage_bits(&self) -> usize {
        // sub-backend storage plus two u64 range bounds per segment
        self.segments
            .iter()
            .map(|s| s.backend.storage_bits() + 128)
            .sum()
    }

    fn save_artifact(&self, path: &Path) -> Result<()> {
        self.save(path)
    }

    fn scorer(&self) -> Option<&dyn crate::scoring::ScoreBackend> {
        Some(self)
    }
}

/// Multi-granular scoring is the exact path: reconstruct (routed to the
/// owning segment) then serial dot. Sub-backends may have ADC fast
/// paths, but stitching per-segment LUT scores would change result bits
/// at segment boundaries -- exact-everywhere keeps `score`/`topk`
/// answers bit-identical to a single-backend table of the same rows.
impl crate::scoring::ScoreBackend for MultiGranular {
    fn query_scorer<'a>(
        &'a self,
        query: &'a [f32],
    ) -> Box<dyn crate::scoring::QueryScorer + 'a> {
        Box::new(crate::scoring::ExactScorer::new(self, query))
    }
}

/// The hashing-trick baseline: `vocab` logical ids share `buckets`
/// dense rows through an FNV-1a hash, so memory scales with the bucket
/// count while collisions blur the embedding. Serves and scores through
/// the same contracts as every other backend (`kind = "hashing"`).
pub struct HashingTable {
    vocab: usize,
    table: TensorF, // [buckets, d]
}

impl HashingTable {
    /// Wrap a `[buckets, d]` bucket table serving `vocab` logical ids.
    pub fn new(vocab: usize, table: TensorF) -> Result<Self> {
        if table.shape.len() != 2 {
            bail!("HashingTable expects [buckets, d], got {:?}", table.shape);
        }
        if vocab == 0 || table.shape[0] == 0 || table.shape[1] == 0 {
            bail!(
                "HashingTable has degenerate shape: vocab={vocab}, \
                 buckets={}, d={}", table.shape[0], table.shape[1]);
        }
        Ok(HashingTable { vocab, table })
    }

    /// Compress a full `[vocab, d]` table into `buckets` rows by
    /// averaging the rows that hash to each bucket (empty buckets stay
    /// zero) -- the standard post-hoc hashing-trick baseline the
    /// DPQ/MGQE comparisons run against.
    pub fn compress(full: &TensorF, buckets: usize) -> Result<Self> {
        if full.shape.len() != 2 {
            bail!("HashingTable expects [vocab, d], got {:?}", full.shape);
        }
        let (vocab, d) = (full.shape[0], full.shape[1]);
        let mut table = TensorF::zeros(vec![buckets.max(1), d]);
        let mut counts = vec![0u32; buckets.max(1)];
        let probe = HashingTable::new(vocab.max(1), table.clone())?;
        for id in 0..vocab {
            let b = probe.bucket_of(id);
            counts[b] += 1;
            let row = full.row(id);
            let dst = &mut table.data[b * d..(b + 1) * d];
            for (o, v) in dst.iter_mut().zip(row) {
                *o += v;
            }
        }
        for (b, &c) in counts.iter().enumerate() {
            if c > 1 {
                for v in &mut table.data[b * d..(b + 1) * d] {
                    *v /= c as f32;
                }
            }
        }
        HashingTable::new(vocab, table)
    }

    /// Bucket count (rows actually stored).
    pub fn buckets(&self) -> usize {
        self.table.shape[0]
    }

    /// The bucket `id` reads from: FNV-1a over the id's LE bytes. Fixed
    /// (not seeded) so an artifact round-trip cannot re-route ids.
    pub fn bucket_of(&self, id: usize) -> usize {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in (id as u64).to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x1_0000_0000_01b3);
        }
        (h % self.buckets() as u64) as usize
    }

    /// Write the `DPQH` artifact: magic, `vocab`/`buckets`/`d` header,
    /// raw f32 LE bucket rows. Bit-exact roundtrip through
    /// [`load`](Self::load).
    pub fn save(&self, path: &Path) -> Result<()> {
        use std::io::Write as _;
        let (buckets, d) = (self.table.shape[0], self.table.shape[1]);
        let mut w = artifact_io::create(path, b"DPQH", &[
            self.vocab as u64, buckets as u64, d as u64,
        ])?;
        artifact_io::write_f32s(&mut w, &self.table.data)?;
        w.flush()?;
        Ok(())
    }

    /// Load a `DPQH` artifact written by [`save`](Self::save).
    pub fn load(path: &Path) -> Result<Self> {
        let (mut r, dims) = artifact_io::open(path, b"DPQH", 3, |d| {
            (d[1] as u128).checked_mul(d[2] as u128)?.checked_mul(4)
        })?;
        let (vocab, buckets, d) =
            (dims[0] as usize, dims[1] as usize, dims[2] as usize);
        let data = artifact_io::read_f32s(&mut r, buckets * d)?;
        HashingTable::new(vocab, TensorF { shape: vec![buckets, d], data })
    }
}

impl EmbeddingBackend for HashingTable {
    fn kind(&self) -> &'static str {
        "hashing"
    }

    fn d(&self) -> usize {
        self.table.shape[1]
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn reconstruct_rows_into(&self, ids: &[usize], out: &mut [f32]) {
        assert_eq!(out.len(), ids.len() * self.d());
        gather_rows_pooled(self.d(), ids.len(), out, |r, orow| {
            orow.copy_from_slice(self.table.row(self.bucket_of(ids[r])));
        });
    }

    fn storage_bits(&self) -> usize {
        32 * self.table.numel()
    }

    fn save_artifact(&self, path: &Path) -> Result<()> {
        self.save(path)
    }

    fn scorer(&self) -> Option<&dyn crate::scoring::ScoreBackend> {
        Some(self)
    }
}

/// Hashing scoring is the exact path: a bucket-row copy then serial dot.
impl crate::scoring::ScoreBackend for HashingTable {
    fn query_scorer<'a>(
        &'a self,
        query: &'a [f32],
    ) -> Box<dyn crate::scoring::QueryScorer + 'a> {
        Box::new(crate::scoring::ExactScorer::new(self, query))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::DenseTable;
    use crate::util::pool::with_threads;
    use crate::util::Rng;

    fn toy_table(n: usize, d: usize, seed: u64) -> TensorF {
        let mut rng = Rng::new(seed);
        TensorF {
            shape: vec![n, d],
            data: (0..n * d).map(|_| rng.normal()).collect(),
        }
    }

    fn dense(n: usize, d: usize, seed: u64) -> Arc<dyn EmbeddingBackend> {
        Arc::new(DenseTable::new(toy_table(n, d, seed)).unwrap())
    }

    #[test]
    fn multigranular_routes_ids_to_owning_segment() {
        let head = toy_table(10, 4, 1);
        let tail = toy_table(30, 4, 2);
        let mg = MultiGranular::new(vec![
            (0, Arc::new(DenseTable::new(head.clone()).unwrap()) as _),
            (10, Arc::new(DenseTable::new(tail.clone()).unwrap()) as _),
        ])
        .unwrap();
        assert_eq!((mg.vocab(), mg.d(), mg.segment_count()), (40, 4, 2));
        // boundary ids: 9 is the head's last row, 10 the tail's first
        let ids = [9usize, 10, 0, 39, 10];
        let mut out = vec![0.0f32; ids.len() * 4];
        mg.reconstruct_rows_into(&ids, &mut out);
        for (r, &id) in ids.iter().enumerate() {
            let want = if id < 10 { head.row(id) } else { tail.row(id - 10) };
            assert_eq!(&out[r * 4..(r + 1) * 4], want, "id {id}");
        }
    }

    #[test]
    fn multigranular_gather_is_thread_count_invariant() {
        let mg = MultiGranular::new(vec![
            (0, dense(16, 8, 3)),
            (16, dense(64, 8, 4)),
            (80, dense(20, 8, 5)),
        ])
        .unwrap();
        let ids: Vec<usize> = (0..301).map(|i| (i * 37) % 100).collect();
        let mut base = vec![0.0f32; ids.len() * 8];
        with_threads(1, || mg.reconstruct_rows_into(&ids, &mut base));
        for threads in [2usize, 7] {
            let mut got = vec![0.0f32; ids.len() * 8];
            with_threads(threads, || mg.reconstruct_rows_into(&ids, &mut got));
            assert!(
                got.iter().zip(&base).all(|(a, b)| a.to_bits() == b.to_bits()),
                "threads={threads}"
            );
        }
    }

    #[test]
    fn multigranular_rejects_bad_partitions() {
        // gap: second segment starts past the head's end
        let err = MultiGranular::new(vec![(0, dense(10, 4, 1)), (12, dense(5, 4, 2))])
            .unwrap_err()
            .to_string();
        assert!(err.contains("gap"), "{err}");
        // overlap: second segment starts inside the head
        let err = MultiGranular::new(vec![(0, dense(10, 4, 1)), (8, dense(5, 4, 2))])
            .unwrap_err()
            .to_string();
        assert!(err.contains("overlap"), "{err}");
        // first segment must start at 0
        assert!(MultiGranular::new(vec![(3, dense(10, 4, 1))]).is_err());
        // d mismatch, empty list, empty tail segment
        assert!(MultiGranular::new(vec![(0, dense(10, 4, 1)), (10, dense(5, 6, 2))])
            .is_err());
        assert!(MultiGranular::new(vec![]).is_err());
        let empty = Arc::new(DenseTable::new(TensorF::zeros(vec![0, 4])).unwrap());
        assert!(MultiGranular::new(vec![(0, dense(10, 4, 1)), (10, empty as _)])
            .is_err());
        // no nesting
        let inner = Arc::new(MultiGranular::new(vec![(0, dense(4, 4, 1))]).unwrap());
        let err = MultiGranular::new(vec![(0, inner as _)]).unwrap_err().to_string();
        assert!(err.contains("leaf"), "{err}");
    }

    #[test]
    fn hashing_table_is_deterministic_and_collides_consistently() {
        let ht = HashingTable::compress(&toy_table(100, 6, 7), 16).unwrap();
        assert_eq!((ht.vocab(), ht.d(), ht.buckets()), (100, 6, 16));
        assert_eq!(ht.storage_bits(), 32 * 16 * 6);
        let ids: Vec<usize> = (0..100).collect();
        let mut base = vec![0.0f32; ids.len() * 6];
        with_threads(1, || ht.reconstruct_rows_into(&ids, &mut base));
        for threads in [2usize, 7] {
            let mut got = vec![0.0f32; ids.len() * 6];
            with_threads(threads, || ht.reconstruct_rows_into(&ids, &mut got));
            assert!(
                got.iter().zip(&base).all(|(a, b)| a.to_bits() == b.to_bits()),
                "threads={threads}"
            );
        }
        // two ids in the same bucket serve identical rows
        let (a, b) = (0usize, (1..100).find(|&i| ht.bucket_of(i) == ht.bucket_of(0))
            .expect("100 ids into 16 buckets must collide"));
        assert_eq!(&base[a * 6..a * 6 + 6], &base[b * 6..b * 6 + 6]);
        assert!(HashingTable::new(0, toy_table(4, 2, 1)).is_err());
        assert!(HashingTable::new(5, TensorF::zeros(vec![2, 3, 4])).is_err());
    }
}
