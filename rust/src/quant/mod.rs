//! Post-hoc embedding-table compressors: the traditional baselines of
//! Table 5 and Table 8. Each takes a *trained* full table [n, d], produces
//! a compact representation, and reconstructs an approximate table that the
//! Rust coordinator feeds back into the full-variant eval artifact (whose
//! embedding table is an ordinary input literal).

use std::io::Write as _;
use std::path::Path;

use anyhow::{bail, Result};

use crate::backend::artifact_io;
use crate::dpq::{Codebook, CompressedEmbedding};
use crate::linalg;
use crate::tensor::{TensorF, TensorI};
use crate::util::{pool, Rng};

/// A fitted compressor: storage accounting + reconstruction.
pub trait Compressor {
    /// Human-readable scheme name (e.g. `"scalar8bit"`).
    fn name(&self) -> String;
    /// Total bits needed at inference for the embedding layer.
    fn storage_bits(&self) -> usize;
    /// Materialize the approximate `[n, d]` table.
    fn reconstruct(&self) -> TensorF;
    /// Compression ratio vs a 32-bit `[n, d]` table.
    fn compression_ratio(&self, n: usize, d: usize) -> f64 {
        (32.0 * n as f64 * d as f64) / self.storage_bits() as f64
    }
}

// ---------------------------------------------------------------------------
// Scalar quantization (b-bit uniform, per-column min/max)
// ---------------------------------------------------------------------------

/// b-bit uniform scalar quantization with per-column `(lo, step)`
/// ranges (paper Table 5's "scalar quant" baseline).
pub struct ScalarQuant {
    /// Bits per code (1..=16).
    pub bits: u32,
    n: usize,
    d: usize,
    codes: Vec<u16>,       // n*d entries, < 2^bits
    lo: Vec<f32>,          // per-column
    step: Vec<f32>,        // per-column
}

impl ScalarQuant {
    /// Fit runs on the worker pool: the per-column min/max scan computes
    /// chunk-local extrema merged in chunk order (min/max are exact, so
    /// any merge order is bit-identical to the serial scan), and the code
    /// assignment shards rows (each element quantized independently).
    pub fn fit(table: &TensorF, bits: u32) -> Self {
        assert!(bits >= 1 && bits <= 16);
        let (n, d) = (table.shape[0], table.shape[1]);
        let levels = (1u32 << bits) - 1;
        let workers = pool::workers_for(n * d * 2);
        let mut lo = vec![f32::INFINITY; d];
        let mut hi = vec![f32::NEG_INFINITY; d];
        pool::with_threads(workers, || {
            // chunk-local (lo, hi) partials, merged below
            let rows_per_chunk = pool::chunk_len(n);
            let n_chunks = n.div_ceil(rows_per_chunk).max(1);
            let mut partials: Vec<(Vec<f32>, Vec<f32>)> =
                vec![(vec![f32::INFINITY; d], vec![f32::NEG_INFINITY; d]);
                     n_chunks];
            pool::par_chunks_mut(&mut partials, 1, |ci, slot| {
                let (plo, phi) = &mut slot[0];
                let row0 = ci * rows_per_chunk;
                let row1 = (row0 + rows_per_chunk).min(n);
                for i in row0..row1 {
                    for (j, &v) in table.row(i).iter().enumerate() {
                        plo[j] = plo[j].min(v);
                        phi[j] = phi[j].max(v);
                    }
                }
            });
            for (plo, phi) in &partials {
                for j in 0..d {
                    lo[j] = lo[j].min(plo[j]);
                    hi[j] = hi[j].max(phi[j]);
                }
            }
        });
        let step: Vec<f32> = (0..d)
            .map(|j| ((hi[j] - lo[j]) / levels as f32).max(1e-12))
            .collect();
        let mut codes = vec![0u16; n * d];
        if d > 0 {
            pool::with_threads(workers, || {
                let rows_per_chunk = pool::chunk_len(n);
                let (lo_ref, step_ref) = (&lo, &step);
                pool::par_chunks_mut(&mut codes, rows_per_chunk * d, |ci, chunk| {
                    let row0 = ci * rows_per_chunk;
                    for (o, out_row) in chunk.chunks_mut(d).enumerate() {
                        for (j, &v) in table.row(row0 + o).iter().enumerate() {
                            let q = ((v - lo_ref[j]) / step_ref[j]).round();
                            out_row[j] = q.clamp(0.0, levels as f32) as u16;
                        }
                    }
                });
            });
        }
        ScalarQuant { bits, n, d, codes, lo, step }
    }

    /// Serialize as a `DPQS` artifact: magic, `n`/`d`/`bits` header, u16
    /// LE codes, then the per-column `lo` and `step` f32 vectors.
    /// Bit-exact roundtrip through [`ScalarQuant::load`].
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut w = artifact_io::create(
            path, b"DPQS",
            &[self.n as u64, self.d as u64, self.bits as u64])?;
        artifact_io::write_u16s(&mut w, &self.codes)?;
        artifact_io::write_f32s(&mut w, &self.lo)?;
        artifact_io::write_f32s(&mut w, &self.step)?;
        w.flush()?;
        Ok(())
    }

    /// Load a `DPQS` artifact written by [`ScalarQuant::save`]; corrupt
    /// headers and out-of-range codes fail loudly.
    pub fn load(path: &Path) -> Result<Self> {
        let (mut r, dims) = artifact_io::open(path, b"DPQS", 3, |d| {
            let nd = (d[0] as u128).checked_mul(d[1] as u128)?;
            // codes (2 bytes each) + lo + step (4 bytes each per column)
            nd.checked_mul(2)?.checked_add((d[1] as u128).checked_mul(8)?)
        })?;
        let (n, d, bits) = (dims[0] as usize, dims[1] as usize, dims[2] as u32);
        if bits == 0 || bits > 16 {
            bail!("corrupt header: bits={bits} (must be in 1..=16)");
        }
        let codes = artifact_io::read_u16s(&mut r, n * d)?;
        let levels = (1u32 << bits) - 1;
        if let Some(&bad) = codes.iter().find(|&&c| c as u32 > levels) {
            bail!("corrupt code {bad} exceeds {levels} ({bits}-bit table)");
        }
        let lo = artifact_io::read_f32s(&mut r, d)?;
        let step = artifact_io::read_f32s(&mut r, d)?;
        Ok(ScalarQuant { bits, n, d, codes, lo, step })
    }
}

/// b-bit scalar codes served as a registry table. Fully-qualified trait
/// path on purpose: keeping `EmbeddingBackend` out of this module's
/// scope means `storage_bits`/`compression_ratio` calls here still
/// resolve to the [`Compressor`] methods without turbofish.
impl crate::backend::EmbeddingBackend for ScalarQuant {
    fn kind(&self) -> &'static str {
        "scalar_quant"
    }

    fn d(&self) -> usize {
        self.d
    }

    fn vocab(&self) -> usize {
        self.n
    }

    fn reconstruct_rows_into(&self, ids: &[usize], out: &mut [f32]) {
        assert_eq!(out.len(), ids.len() * self.d);
        let d = self.d;
        crate::backend::gather_rows_pooled(d, ids.len(), out, |r, orow| {
            let i = ids[r];
            for j in 0..d {
                orow[j] =
                    self.lo[j] + self.codes[i * d + j] as f32 * self.step[j];
            }
        });
    }

    fn storage_bits(&self) -> usize {
        Compressor::storage_bits(self)
    }

    fn save_artifact(&self, path: &Path) -> Result<()> {
        self.save(path)
    }

    fn scorer(&self) -> Option<&dyn crate::scoring::ScoreBackend> {
        Some(self)
    }
}

/// ADC table for scalar-quant codes: one `2^bits`-entry column of
/// pre-multiplied levels per embedding column, `lut[j * L + c] =
/// query[j] * (lo[j] + c * step[j])`. Each LUT entry is the exact f32
/// product the reconstruct-then-dot reference computes for that
/// (column, code) pair, and candidates accumulate in column order, so
/// this path is bit-identical to the reference -- the documented
/// tolerance is only needed for the DPQ LUT.
struct SqLutScorer<'a> {
    sq: &'a ScalarQuant,
    /// Levels per code (`2^bits`), the LUT column stride.
    levels: usize,
    lut: Vec<f32>,
}

impl<'a> SqLutScorer<'a> {
    fn new(sq: &'a ScalarQuant, query: &[f32]) -> Self {
        debug_assert_eq!(query.len(), sq.d);
        let levels = 1usize << sq.bits;
        let mut lut = vec![0.0f32; sq.d * levels];
        for j in 0..sq.d {
            for c in 0..levels {
                lut[j * levels + c] =
                    query[j] * (sq.lo[j] + c as f32 * sq.step[j]);
            }
        }
        SqLutScorer { sq, levels, lut }
    }
}

impl crate::scoring::QueryScorer for SqLutScorer<'_> {
    fn score_block(&self, start: usize, out: &mut [f32]) {
        let d = self.sq.d;
        for (i, o) in out.iter_mut().enumerate() {
            let row = &self.sq.codes[(start + i) * d..(start + i + 1) * d];
            let mut acc = 0.0f32;
            for (j, &c) in row.iter().enumerate() {
                acc += self.lut[j * self.levels + c as usize];
            }
            *o = acc;
        }
    }

    fn path(&self) -> &'static str {
        "lut"
    }
}

/// Per-query LUT memory is `d * 2^bits` floats; above this bit width the
/// table would dwarf the batch it serves, so scoring falls back to the
/// exact path (codes stay <= 16 bit, so the cap only affects outliers).
const SQ_LUT_MAX_BITS: u32 = 10;

impl crate::scoring::ScoreBackend for ScalarQuant {
    fn query_scorer<'a>(
        &'a self,
        query: &'a [f32],
    ) -> Box<dyn crate::scoring::QueryScorer + 'a> {
        if self.bits <= SQ_LUT_MAX_BITS {
            Box::new(SqLutScorer::new(self, query))
        } else {
            Box::new(crate::scoring::ExactScorer::new(self, query))
        }
    }
}

impl Compressor for ScalarQuant {
    fn name(&self) -> String {
        format!("scalar{}bit", self.bits)
    }

    fn storage_bits(&self) -> usize {
        // codes + per-column (lo, step) floats
        self.n * self.d * self.bits as usize + 32 * 2 * self.d
    }

    fn reconstruct(&self) -> TensorF {
        let mut data = vec![0.0f32; self.n * self.d];
        for i in 0..self.n {
            for j in 0..self.d {
                data[i * self.d + j] =
                    self.lo[j] + self.codes[i * self.d + j] as f32 * self.step[j];
            }
        }
        TensorF { shape: vec![self.n, self.d], data }
    }
}

// ---------------------------------------------------------------------------
// Product quantization (k-means per subspace; Jegou et al. 2010)
// ---------------------------------------------------------------------------

/// Post-hoc product quantization (k-means per subspace; Jegou et al.
/// 2010) -- the paper's strongest traditional baseline.
pub struct ProductQuant {
    /// Centroids per subspace.
    pub k: usize,
    /// Number of subspaces D.
    pub d_groups: usize,
    emb: CompressedEmbedding,
}

impl ProductQuant {
    /// Split columns into `d_groups` subspaces, k-means each, store codes.
    ///
    /// Subspaces are fitted in parallel on the worker pool. Each group
    /// draws a dedicated RNG stream ([`Rng::fork`], forked from `rng` in
    /// group order before any worker runs), so the result is a pure
    /// function of the seed -- independent of thread count and schedule.
    /// Inside a pool worker the nested k-means runs its assignment step
    /// serially (the pool forbids nested parallelism); a top-level call
    /// with one group still parallelizes inside k-means.
    pub fn fit(table: &TensorF, k: usize, d_groups: usize, iters: usize,
               rng: &mut Rng) -> Self {
        let (n, d) = (table.shape[0], table.shape[1]);
        assert!(d % d_groups == 0, "d={d} % D={d_groups} != 0");
        let s = d / d_groups;
        // per-group work slots: (rng stream, assignments, centroids)
        let mut groups: Vec<(Rng, Vec<usize>, TensorF)> = (0..d_groups)
            .map(|g| (rng.fork(g as u64), Vec::new(), TensorF::zeros(vec![0, 0])))
            .collect();
        // k-means dominates: ~n*k*s distance ops per Lloyd iteration/group
        pool::with_threads(pool::workers_for(n * d * k * iters.max(1)), || {
            pool::par_chunks_mut(&mut groups, 1, |g, slot| {
                let (grng, assign_out, cent_out) = &mut slot[0];
                // gather subspace columns
                let mut sub = vec![0.0f32; n * s];
                for i in 0..n {
                    sub[i * s..(i + 1) * s]
                        .copy_from_slice(&table.row(i)[g * s..(g + 1) * s]);
                }
                let x = TensorF { shape: vec![n, s], data: sub };
                let (cent, assign, _) = linalg::kmeans(&x, k, iters, grng);
                *assign_out = assign;
                *cent_out = cent;
            });
        });
        let mut codes = vec![0i32; n * d_groups];
        let mut values = vec![0.0f32; k * d_groups * s];
        for (g, (_, assign, cent)) in groups.iter().enumerate() {
            let kk = cent.shape[0];
            for i in 0..n {
                codes[i * d_groups + g] = assign[i] as i32;
            }
            for c in 0..kk {
                let base = (c * d_groups + g) * s;
                values[base..base + s].copy_from_slice(cent.row(c));
            }
        }
        let codes = TensorI::new(vec![n, d_groups], codes).unwrap();
        let values = TensorF::new(vec![k, d_groups, s], values).unwrap();
        let emb = CompressedEmbedding::new(
            Codebook::from_codes(&codes, k).unwrap(), values, false)
            .unwrap();
        ProductQuant { k, d_groups, emb }
    }

    /// The fitted codes + centroids as a servable [`CompressedEmbedding`].
    pub fn embedding(&self) -> &CompressedEmbedding {
        &self.emb
    }
}

impl Compressor for ProductQuant {
    fn name(&self) -> String {
        format!("pq_K{}_D{}", self.k, self.d_groups)
    }

    fn storage_bits(&self) -> usize {
        self.emb.storage_bits()
    }

    fn reconstruct(&self) -> TensorF {
        self.emb.reconstruct_table()
    }
}

// ---------------------------------------------------------------------------
// Low-rank factorization (truncated SVD)
// ---------------------------------------------------------------------------

/// Low-rank factorization baseline: `table ~= left @ right` via
/// truncated SVD.
pub struct LowRank {
    /// Retained rank r.
    pub rank: usize,
    left: TensorF,   // [n, r]
    right: TensorF,  // [r, d]
}

impl LowRank {
    /// Factor `table` at the given rank.
    pub fn fit(table: &TensorF, rank: usize) -> Self {
        let (left, right) = linalg::low_rank_factors(table, rank);
        LowRank { rank, left, right }
    }

    /// Rank that yields (approximately) the requested compression ratio.
    pub fn rank_for_cr(n: usize, d: usize, cr: f64) -> usize {
        // 32 n d / (32 r (n + d)) = cr  =>  r = n d / (cr (n + d))
        ((n * d) as f64 / (cr * (n + d) as f64)).round().max(1.0) as usize
    }

    /// Serialize as a `DPQL` artifact: magic, `n`/`rank`/`d` header, then
    /// the `left [n, r]` and `right [r, d]` f32 factor matrices. Bit-exact
    /// roundtrip through [`LowRank::load`], so a restored table serves the
    /// same row products bit for bit (the row kernel accumulates serially
    /// in a fixed order).
    pub fn save(&self, path: &Path) -> Result<()> {
        let (n, r, d) = (self.left.shape[0], self.left.shape[1],
                         self.right.shape[1]);
        let mut w = artifact_io::create(
            path, b"DPQL", &[n as u64, r as u64, d as u64])?;
        artifact_io::write_f32s(&mut w, &self.left.data)?;
        artifact_io::write_f32s(&mut w, &self.right.data)?;
        w.flush()?;
        Ok(())
    }

    /// Load a `DPQL` artifact written by [`LowRank::save`].
    pub fn load(path: &Path) -> Result<Self> {
        let (mut r, dims) = artifact_io::open(path, b"DPQL", 3, |d| {
            let left = (d[0] as u128).checked_mul(d[1] as u128)?;
            let right = (d[1] as u128).checked_mul(d[2] as u128)?;
            left.checked_add(right)?.checked_mul(4)
        })?;
        let (n, rank, d) = (dims[0] as usize, dims[1] as usize, dims[2] as usize);
        if rank == 0 {
            bail!("corrupt header: rank=0");
        }
        let left = TensorF {
            shape: vec![n, rank],
            data: artifact_io::read_f32s(&mut r, n * rank)?,
        };
        let right = TensorF {
            shape: vec![rank, d],
            data: artifact_io::read_f32s(&mut r, rank * d)?,
        };
        Ok(LowRank { rank, left, right })
    }
}

/// Low-rank factors served as a registry table: row `i` is the `[1, r] x
/// [r, d]` product `left[i, :] @ right`, accumulated serially per row so
/// the served bits are identical for every worker-pool size (the blocked
/// `linalg::matmul` used by [`Compressor::reconstruct`] may sum in a
/// different order; serving always goes through this row kernel).
impl crate::backend::EmbeddingBackend for LowRank {
    fn kind(&self) -> &'static str {
        "low_rank"
    }

    fn d(&self) -> usize {
        self.right.shape[1]
    }

    fn vocab(&self) -> usize {
        self.left.shape[0]
    }

    fn reconstruct_rows_into(&self, ids: &[usize], out: &mut [f32]) {
        let d = self.right.shape[1];
        assert_eq!(out.len(), ids.len() * d);
        crate::backend::gather_rows_pooled(d, ids.len(), out, |ri, orow| {
            orow.fill(0.0);
            for (k, &lv) in self.left.row(ids[ri]).iter().enumerate() {
                let rrow = self.right.row(k);
                for j in 0..d {
                    orow[j] += lv * rrow[j];
                }
            }
        });
    }

    fn storage_bits(&self) -> usize {
        Compressor::storage_bits(self)
    }

    fn save_artifact(&self, path: &Path) -> Result<()> {
        self.save(path)
    }

    fn scorer(&self) -> Option<&dyn crate::scoring::ScoreBackend> {
        Some(self)
    }
}

/// Low-rank scoring goes through the exact path: the factored form
/// `left[i] . (right @ q)` would be cheaper but re-associates the sum,
/// and the serving contract here is bit-equality with the
/// reconstruct-then-dot reference (the row kernel accumulates serially
/// in a fixed order; see `reconstruct_rows_into` above).
impl crate::scoring::ScoreBackend for LowRank {
    fn query_scorer<'a>(
        &'a self,
        query: &'a [f32],
    ) -> Box<dyn crate::scoring::QueryScorer + 'a> {
        Box::new(crate::scoring::ExactScorer::new(self, query))
    }
}

impl Compressor for LowRank {
    fn name(&self) -> String {
        format!("lowrank{}", self.rank)
    }

    fn storage_bits(&self) -> usize {
        32 * (self.left.numel() + self.right.numel())
    }

    fn reconstruct(&self) -> TensorF {
        linalg::matmul(&self.left, &self.right)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::prop_check;

    fn table(n: usize, d: usize, seed: u64) -> TensorF {
        let mut rng = Rng::new(seed);
        TensorF {
            shape: vec![n, d],
            data: (0..n * d).map(|_| rng.normal() * 0.1).collect(),
        }
    }

    #[test]
    fn scalar_quant_error_shrinks_with_bits() {
        let t = table(200, 16, 1);
        let mut prev = f32::INFINITY;
        for bits in [2, 4, 6, 8] {
            let sq = ScalarQuant::fit(&t, bits);
            let err = t.rel_err(&sq.reconstruct());
            assert!(err < prev, "bits={bits}: {err} !< {prev}");
            prev = err;
        }
        assert!(prev < 0.01); // 8-bit is near-exact on smooth data
    }

    #[test]
    fn scalar_quant_cr() {
        let t = table(1000, 64, 2);
        let sq = ScalarQuant::fit(&t, 8);
        // paper Table 5: 8-bit scalar quant ~= 4x
        let cr = sq.compression_ratio(1000, 64);
        assert!((cr - 4.0).abs() < 0.1, "cr={cr}");
    }

    #[test]
    fn pq_reconstruction_reasonable() {
        let t = table(300, 16, 3);
        let mut rng = Rng::new(4);
        let pq = ProductQuant::fit(&t, 16, 4, 15, &mut rng);
        let err = t.rel_err(&pq.reconstruct());
        assert!(err < 0.9, "err={err}");
        // more centroids -> lower error
        let pq2 = ProductQuant::fit(&t, 64, 4, 15, &mut Rng::new(4));
        assert!(t.rel_err(&pq2.reconstruct()) < err);
    }

    #[test]
    fn pq_cr_formula() {
        let t = table(1000, 64, 5);
        let pq = ProductQuant::fit(&t, 32, 16, 5, &mut Rng::new(6));
        let want = (32.0 * 1000.0 * 64.0)
            / (1000.0 * 16.0 * 5.0 + 32.0 * 32.0 * 64.0);
        assert!((pq.compression_ratio(1000, 64) - want).abs() < 1e-9);
    }

    #[test]
    fn lowrank_exact_on_lowrank_input() {
        let mut rng = Rng::new(7);
        let l = TensorF {
            shape: vec![50, 3],
            data: (0..150).map(|_| rng.normal()).collect(),
        };
        let r = TensorF {
            shape: vec![3, 12],
            data: (0..36).map(|_| rng.normal()).collect(),
        };
        let t = linalg::matmul(&l, &r);
        let lr = LowRank::fit(&t, 3);
        assert!(t.rel_err(&lr.reconstruct()) < 1e-3);
    }

    #[test]
    fn rank_for_cr_inverts() {
        let r = LowRank::rank_for_cr(10000, 64, 10.0);
        let bits = 32 * (10000 * r + r * 64);
        let cr = (32.0 * 10000.0 * 64.0) / bits as f64;
        assert!((cr - 10.0).abs() < 2.0, "r={r} cr={cr}");
    }

    /// The serving-side row gather must agree with the batch
    /// `reconstruct()` used by the experiment harness: bit-exact for
    /// scalar quant (same formula), within float-reassociation tolerance
    /// for low rank (matmul blocks its sums; the row kernel is serial).
    #[test]
    fn backend_rows_match_compressor_reconstruct() {
        use crate::backend::EmbeddingBackend as _;
        let t = table(60, 12, 8);
        let ids: Vec<usize> = vec![0, 59, 7, 7, 31];

        let sq = ScalarQuant::fit(&t, 6);
        let full = Compressor::reconstruct(&sq);
        let mut rows = vec![0.0f32; ids.len() * 12];
        sq.reconstruct_rows_into(&ids, &mut rows);
        for (r, &id) in ids.iter().enumerate() {
            assert_eq!(&rows[r * 12..(r + 1) * 12], full.row(id), "sq id {id}");
        }

        let lr = LowRank::fit(&t, 4);
        let full = Compressor::reconstruct(&lr);
        let mut rows = vec![0.0f32; ids.len() * 12];
        lr.reconstruct_rows_into(&ids, &mut rows);
        for (r, &id) in ids.iter().enumerate() {
            for (a, b) in rows[r * 12..(r + 1) * 12].iter().zip(full.row(id)) {
                assert!((a - b).abs() < 1e-4, "lr id {id}: {a} vs {b}");
            }
        }
    }

    /// The snapshot artifact formats must roundtrip the serving-side row
    /// gather bit for bit: a restored registry's answers are only
    /// guaranteed identical if every backend kind reloads exactly.
    #[test]
    fn artifact_roundtrips_serve_identical_bits() {
        use crate::backend::{load_backend, EmbeddingBackend};
        let dir = std::env::temp_dir().join("dpq_quant_artifacts");
        std::fs::create_dir_all(&dir).unwrap();
        let t = table(80, 12, 21);
        let ids: Vec<usize> = vec![0, 79, 13, 13, 40];

        let sq = ScalarQuant::fit(&t, 7);
        let p = dir.join("t.scalar_quant");
        sq.save(&p).unwrap();
        let back = load_backend("scalar_quant", &p).unwrap();
        assert_eq!((back.kind(), back.vocab(), back.d()), ("scalar_quant", 80, 12));
        assert_eq!(back.storage_bits(), EmbeddingBackend::storage_bits(&sq));
        let mut a = vec![0.0f32; ids.len() * 12];
        let mut b = vec![0.0f32; ids.len() * 12];
        sq.reconstruct_rows_into(&ids, &mut a);
        back.reconstruct_rows_into(&ids, &mut b);
        assert!(a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()));
        // a code pushed past the bit width is corruption, not data
        let mut bytes = std::fs::read(&p).unwrap();
        let header = 4 + 3 * 8;
        bytes[header..header + 2].copy_from_slice(&u16::MAX.to_le_bytes());
        let bad = dir.join("bad.scalar_quant");
        std::fs::write(&bad, &bytes).unwrap();
        assert!(ScalarQuant::load(&bad).is_err());

        let lr = LowRank::fit(&t, 4);
        let p = dir.join("t.low_rank");
        lr.save(&p).unwrap();
        let back = load_backend("low_rank", &p).unwrap();
        assert_eq!((back.kind(), back.vocab(), back.d()), ("low_rank", 80, 12));
        assert_eq!(back.storage_bits(), EmbeddingBackend::storage_bits(&lr));
        lr.reconstruct_rows_into(&ids, &mut a);
        back.reconstruct_rows_into(&ids, &mut b);
        assert!(a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()));
        let bytes = std::fs::read(&p).unwrap();
        let bad = dir.join("bad.low_rank");
        std::fs::write(&bad, &bytes[..bytes.len() - 1]).unwrap();
        assert!(LowRank::load(&bad).is_err());
    }

    /// Scalar-quant's LUT entries are the exact per-column products the
    /// reference computes, so its fast path must be BIT-equal -- and
    /// low-rank's exact path shares the reference's accumulation order,
    /// so it must be too.
    #[test]
    fn scorers_match_reference_bits() {
        use crate::scoring::{self, ScoreBackend as _};
        let t = table(90, 10, 30);
        let mut rng = Rng::new(31);
        let query: Vec<f32> = (0..10).map(|_| rng.normal()).collect();
        let ids: Vec<usize> = vec![0, 89, 17, 17, 44];

        let sq = ScalarQuant::fit(&t, 8);
        let want = scoring::reference_scores(&sq, &query, &ids);
        let qs = sq.query_scorer(&query);
        assert_eq!(qs.path(), "lut");
        let mut got = vec![0.0f32; ids.len()];
        scoring::score_into(qs.as_ref(), &ids, &mut got);
        assert!(got.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()));

        let lr = LowRank::fit(&t, 4);
        let want = scoring::reference_scores(&lr, &query, &ids);
        let qs = lr.query_scorer(&query);
        assert_eq!(qs.path(), "exact");
        scoring::score_into(qs.as_ref(), &ids, &mut got);
        assert!(got.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    fn prop_scalar_quant_within_step_bound() {
        prop_check(20, |rng| {
            let n = 2 + rng.below(40);
            let d = 1 + rng.below(12);
            let t = TensorF {
                shape: vec![n, d],
                data: (0..n * d).map(|_| rng.normal()).collect(),
            };
            let bits = 2 + rng.below(7) as u32;
            let sq = ScalarQuant::fit(&t, bits);
            let rec = sq.reconstruct();
            // every entry within half a quantization step
            for j in 0..d {
                let step = {
                    let lo = (0..n).map(|i| t.row(i)[j]).fold(f32::INFINITY, f32::min);
                    let hi = (0..n).map(|i| t.row(i)[j]).fold(f32::NEG_INFINITY, f32::max);
                    (hi - lo) / ((1u32 << bits) - 1) as f32
                };
                for i in 0..n {
                    let err = (t.row(i)[j] - rec.row(i)[j]).abs();
                    prop_assert!(err <= 0.51 * step + 1e-6,
                                 "err {err} > half step {step} (bits={bits})");
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_pq_codes_in_range_and_cr_positive() {
        prop_check(8, |rng| {
            let n = 20 + rng.below(80);
            let dgs = [1usize, 2, 4];
            let d_groups = dgs[rng.below(3)];
            let d = d_groups * (1 + rng.below(4));
            let k = 2 + rng.below(14);
            let t = TensorF {
                shape: vec![n, d],
                data: (0..n * d).map(|_| rng.normal()).collect(),
            };
            let pq = ProductQuant::fit(&t, k, d_groups, 8, rng);
            let codes = pq.embedding().codebook.to_tensor();
            prop_assert!(codes.data.iter().all(|&c| (c as usize) < k),
                         "code out of range");
            prop_assert!(pq.compression_ratio(n, d) > 0.0);
            Ok(())
        });
    }
}
