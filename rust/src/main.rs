//! `repro` -- the launcher CLI for the DPQ reproduction.
//!
//! Subcommands:
//!   repro list                          list available artifacts
//!   repro train   [--artifact P ...]    train one artifact family
//!   repro experiment <id|all> [--steps N]  regenerate a paper table/figure
//!   repro experiment --list             list experiment ids
//!   repro compress [--artifact P ...]   train + export compressed embedding
//!   repro serve   [--table N=F ...]     serve compressed embedding tables
//!   repro fuzz    [--seed S --iters N]  fuzz the wire protocol in-process
//!   repro hydrate --from HOST:PORT --spill-dir DIR   pull a peer's spill
//!                                       artifacts by content digest
//!   repro codes   [--artifact P ...]    print code statistics
//!
//! All flags are `--key value`; unknown keys are rejected with the list of
//! valid ones (see config::RunConfig).
//!
//! The global `--threads N` flag (any subcommand) pins the worker-pool
//! size used by the parallel hot paths (matmul, k-means, post-hoc
//! quantizer fits, table reconstruction, the server batcher). Default:
//! the `DPQ_THREADS` env var, else all available cores. Results are
//! bit-identical for every thread count.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use dpq_embed::config::{parse_cli_overrides, RunConfig};
use dpq_embed::coordinator::experiments::{self, ExpCfg};
use dpq_embed::coordinator::Trainer;
use dpq_embed::dpq::stats as dstats;
use dpq_embed::metrics;
use dpq_embed::runtime::Runtime;
use dpq_embed::server::{
    hydrate_from_peer, Client, EmbeddingServer, ServerConfig, TableRegistry,
};
use dpq_embed::util::pool;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(mut args: Vec<String>) -> Result<()> {
    apply_threads_flag(&mut args)?;
    dispatch(&args)
}

/// Extract the global `--threads N` flag (valid for every subcommand) and
/// configure the worker pool before dispatch.
fn apply_threads_flag(args: &mut Vec<String>) -> Result<()> {
    let Some(i) = args.iter().position(|a| a == "--threads") else {
        return Ok(());
    };
    let n: usize = args
        .get(i + 1)
        .ok_or_else(|| anyhow!("--threads missing value"))?
        .parse()
        .map_err(|_| anyhow!("--threads expects a positive integer"))?;
    if n == 0 {
        bail!("--threads must be >= 1");
    }
    pool::set_threads(n);
    args.drain(i..=i + 1);
    Ok(())
}

fn take_or<'a>(kv: &'a BTreeMap<String, String>, key: &str, default: &'a str) -> &'a str {
    kv.get(key).map(|s| s.as_str()).unwrap_or(default)
}

/// Parse a byte size for `flag`: plain bytes or a binary-prefixed
/// suffix (`64MiB`, `2g`, `512k`; K/M/G all mean KiB/MiB/GiB). Used by
/// `--mem-budget`, `--row-cache`, and `:row_cache=` table suffixes; the
/// caller handles `none`/`off`/`0` (explicitly disabled) before this.
fn parse_byte_size(flag: &str, s: &str) -> Result<u64> {
    let t = s.trim().to_ascii_lowercase();
    let (digits, mult): (&str, u64) = [
        ("gib", 1u64 << 30), ("gb", 1 << 30), ("g", 1 << 30),
        ("mib", 1 << 20), ("mb", 1 << 20), ("m", 1 << 20),
        ("kib", 1 << 10), ("kb", 1 << 10), ("k", 1 << 10),
    ]
    .iter()
    .find_map(|(suf, m)| t.strip_suffix(suf).map(|d| (d, *m)))
    .unwrap_or((t.as_str(), 1));
    let v: f64 = digits.trim().parse().map_err(|_| {
        anyhow!("{flag} expects bytes or a K/M/G suffix, got {s:?}")
    })?;
    // validate the FINAL byte count, not the pre-multiply value: "0.5"
    // (user forgot the suffix) would otherwise truncate to a 0-byte
    // budget that evicts every unpinned table on every load
    let bytes = (v * mult as f64) as u64;
    if !v.is_finite() || bytes < 1 {
        bail!("{flag} must be at least 1 byte, got {s:?}");
    }
    Ok(bytes)
}

fn dispatch(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    let rest = &args[1..];
    match cmd.as_str() {
        "list" => {
            let kv = parse_cli_overrides(rest)?;
            let rt = Runtime::new(take_or(&kv, "artifacts_dir", "artifacts"))?;
            for name in rt.available()? {
                println!("{name}");
            }
            Ok(())
        }
        "train" => {
            let kv = parse_cli_overrides(rest)?;
            let mut cfg = RunConfig::default();
            cfg.apply(&kv)?;
            let rt = Runtime::new(&cfg.artifacts_dir)?;
            let tr = Trainer::new(&rt, cfg.clone());
            let out = tr.run()?;
            let named: Vec<String> = out
                .metric_names
                .iter()
                .zip(&out.final_metrics)
                .map(|(n, v)| format!("{n}={v:.4}"))
                .collect();
            println!(
                "done: {} steps, {:.2} steps/s, held-out {}",
                cfg.steps, out.steps_per_sec, named.join(" ")
            );
            if let Some(ppl) = out.ppl() {
                println!("perplexity: {ppl:.2}");
            }
            if cfg.artifact.starts_with("nmt_") {
                let bleu = tr.bleu(&out.state, 4)?;
                println!("BLEU (greedy, 4 fresh batches): {bleu:.2}");
            }
            if let Some(dir) = &cfg.checkpoint_dir {
                std::fs::create_dir_all(dir)?;
                let p = dir.join(format!("{}_final.ckpt", cfg.artifact));
                dpq_embed::coordinator::checkpoint::save(&p, &out.state)?;
                println!("checkpoint: {}", p.display());
            }
            Ok(())
        }
        "experiment" => {
            if rest.iter().any(|a| a == "--list") {
                for (id, desc) in experiments::registry() {
                    println!("{id:<10} {desc}");
                }
                return Ok(());
            }
            let Some(id) = rest.first() else {
                bail!("usage: repro experiment <id|all> [--steps N]")
            };
            let kv = parse_cli_overrides(&rest[1..])?;
            let mut cfg = ExpCfg::default();
            if let Some(s) = kv.get("steps") {
                cfg.steps = s.parse()?;
            }
            if let Some(s) = kv.get("seed") {
                cfg.seed = s.parse()?;
            }
            if let Some(s) = kv.get("reports_dir") {
                cfg.reports_dir = s.into();
            }
            if let Some(s) = kv.get("artifacts_dir") {
                cfg.artifacts_dir = s.into();
            }
            let rt = Runtime::new(&cfg.artifacts_dir)?;
            if id == "all" {
                for (eid, _) in experiments::registry() {
                    eprintln!("== experiment {eid} ==");
                    experiments::run(eid, &rt, &cfg)?;
                }
            } else {
                experiments::run(id, &rt, &cfg)?;
            }
            Ok(())
        }
        "compress" => {
            let kv = parse_cli_overrides(rest)?;
            let mut cfg = RunConfig::default();
            cfg.apply(&kv)?;
            let rt = Runtime::new(&cfg.artifacts_dir)?;
            let tr = Trainer::new(&rt, cfg.clone()).quiet();
            eprintln!("training {} for {} steps...", cfg.artifact, cfg.steps);
            let out = tr.run()?;
            let man = rt.load(&format!("{}_train", cfg.artifact))?;
            let shared = man.manifest.meta_bool("share").unwrap_or(false);
            let ce = experiments::compress_state(&rt, &cfg.artifact,
                                                 &out.state, shared)?;
            let path = std::path::PathBuf::from(
                take_or(&kv, "out", "compressed.dpq"));
            ce.save(&path)?;
            println!(
                "saved {} (vocab={} d={} K={} D={}): {} bits, CR {:.1}x",
                path.display(), ce.vocab(), ce.d, ce.codebook.k,
                ce.codebook.d_groups, ce.storage_bits(),
                ce.compression_ratio()
            );
            Ok(())
        }
        "serve" => {
            // `--table name=path[:replicas=N][:row_cache=BYTES]` is
            // repeatable, so peel those off before the map-based flag
            // parser (which keeps only the last value per key) sees the
            // rest.
            let mut tables: Vec<(String, std::path::PathBuf, usize,
                                 Option<u64>)> = Vec::new();
            let mut plain: Vec<String> = Vec::new();
            let mut it = rest.iter();
            while let Some(a) = it.next() {
                if a == "--table" {
                    let spec = it
                        .next()
                        .ok_or_else(|| anyhow!("--table missing name=path"))?;
                    let (name, rest) = spec.split_once('=').ok_or_else(|| {
                        anyhow!("--table expects \
                                 name=path[:replicas=N][:row_cache=BYTES], \
                                 got {spec:?}")
                    })?;
                    // trailing `:key=value` options peel from the RIGHT
                    // (in any order) so a path containing ':' stays
                    // intact -- an unrecognized `:..` is path, not flag
                    let mut path = rest;
                    let mut replicas = 1usize;
                    let mut row_cache: Option<u64> = None;
                    while let Some((head, opt)) = path.rsplit_once(':') {
                        if let Some(n) = opt.strip_prefix("replicas=") {
                            let n: usize = n.parse().map_err(|_| anyhow!(
                                "--table {spec:?}: replicas expects a \
                                 positive integer"))?;
                            if n == 0 {
                                bail!("--table {spec:?}: replicas must be \
                                       >= 1");
                            }
                            replicas = n;
                        } else if let Some(b) = opt.strip_prefix("row_cache=")
                        {
                            row_cache = Some(match b
                                .trim()
                                .to_ascii_lowercase()
                                .as_str()
                            {
                                "none" | "off" | "0" => 0,
                                _ => parse_byte_size(
                                    &format!("--table {spec:?} row_cache"),
                                    b)?,
                            });
                        } else {
                            break;
                        }
                        path = head;
                    }
                    tables.push(
                        (name.to_string(), path.into(), replicas, row_cache));
                } else {
                    plain.push(a.clone());
                }
            }
            let kv = parse_cli_overrides(&plain)?;
            let addr = take_or(&kv, "addr", "127.0.0.1:7878").to_string();
            let max_batch: usize = take_or(&kv, "max_batch", "64").parse()?;
            let shards_per_table: usize = take_or(&kv, "shards", "1").parse()?;
            if max_batch == 0 || shards_per_table == 0 {
                bail!("--max-batch and --shards must be >= 1");
            }
            // Spill tier: --spill-dir arms eviction-to-disk + transparent
            // reload; --spill picks what budget evictions do with victims
            // (disk = demote, drop = PR-3 discard; the `demote` admin op
            // works either way as long as a spill dir is set).
            // Outer None = flag absent; Some(None) = explicitly no spill
            // tier ("none"/"off" -- the way to drop a spill dir a
            // --restore manifest recorded, mirroring --mem-budget none);
            // Some(Some(dir)) = use dir.
            let spill_dir: Option<Option<std::path::PathBuf>> =
                match kv.get("spill_dir") {
                    None => None,
                    Some(s)
                        if matches!(s.trim().to_ascii_lowercase().as_str(),
                                    "none" | "off") =>
                    {
                        Some(None)
                    }
                    Some(s) => Some(Some(std::path::PathBuf::from(s))),
                };
            let spill_on_evict: Option<bool> = match kv.get("spill") {
                None => None,
                Some(s) => match s.trim().to_ascii_lowercase().as_str() {
                    "disk" => Some(true),
                    "drop" => Some(false),
                    other => bail!("--spill expects disk|drop, got {other:?}"),
                },
            };
            // (the restore path re-checks this against the merged config
            // below, since the manifest may itself record a spill dir)
            if spill_on_evict.is_some()
                && spill_dir.clone().flatten().is_none()
                && !kv.contains_key("restore")
            {
                bail!("--spill needs --spill-dir (no spill tier configured)");
            }
            // Outer None = flag absent; Some(None) = explicitly
            // unlimited ("none"/"off"/"0" -- the way to drop a budget a
            // --restore manifest recorded); Some(Some(b)) = b bytes.
            let mem_budget: Option<Option<u64>> = match kv.get("mem_budget") {
                None => None,
                Some(s)
                    if matches!(s.trim().to_ascii_lowercase().as_str(),
                                "none" | "off" | "0") =>
                {
                    Some(None)
                }
                Some(s) => Some(Some(parse_byte_size("--mem-budget", s)?)),
            };
            // --row-cache BYTES: default per-table hot-row cache cap
            // (raw f32 rows, LRU; capacity counts against --mem-budget).
            // "none"/"off"/"0" disables, including a cap a --restore
            // manifest recorded; absent = disabled.
            let row_cache_bytes: Option<u64> = match kv.get("row_cache") {
                None => None,
                Some(s)
                    if matches!(s.trim().to_ascii_lowercase().as_str(),
                                "none" | "off" | "0") =>
                {
                    Some(0)
                }
                Some(s) => Some(parse_byte_size("--row-cache", s)?),
            };
            // --ttl SECS: idle tables expire past SECS (demoted with a
            // spill tier, dropped without). Same outer/inner Option
            // shape as --mem-budget: "none"/"off"/"0" drops a TTL a
            // --restore manifest recorded.
            let ttl_secs: Option<Option<u64>> = match kv.get("ttl") {
                None => None,
                Some(s)
                    if matches!(s.trim().to_ascii_lowercase().as_str(),
                                "none" | "off" | "0") =>
                {
                    Some(None)
                }
                Some(s) => {
                    let t: u64 = s.trim().parse().map_err(|_| anyhow!(
                        "--ttl expects whole seconds (or none), got {s:?}"))?;
                    if t == 0 {
                        bail!("--ttl must be >= 1 second (or none)");
                    }
                    Some(Some(t))
                }
            };
            // --conn-timeout SECS: per-connection idle + whole-frame
            // deadline (fractional seconds ok). Same outer/inner Option
            // shape: "none"/"off"/"0" disables deadlines, including one
            // a --restore manifest recorded. Absent = the 30s default.
            let conn_timeout: Option<Option<std::time::Duration>> =
                match kv.get("conn_timeout") {
                    None => None,
                    Some(s)
                        if matches!(s.trim().to_ascii_lowercase().as_str(),
                                    "none" | "off" | "0") =>
                    {
                        Some(None)
                    }
                    Some(s) => {
                        let t: f64 = s.trim().parse().map_err(|_| anyhow!(
                            "--conn-timeout expects seconds (or none), \
                             got {s:?}"))?;
                        if !t.is_finite() || t <= 0.0 || t > 31_557_600.0 {
                            bail!("--conn-timeout must be in (0, 1 year] \
                                   seconds (or none), got {s:?}");
                        }
                        Some(Some(std::time::Duration::from_secs_f64(t)))
                    }
                };
            // --max-conns N: cap on concurrently open connections
            // (over-cap peers get a typed `busy` close). Same shape:
            // "none"/"off"/"0" unbounds it. Absent = the 1024 default.
            let max_conns: Option<Option<usize>> = match kv.get("max_conns") {
                None => None,
                Some(s)
                    if matches!(s.trim().to_ascii_lowercase().as_str(),
                                "none" | "off" | "0") =>
                {
                    Some(None)
                }
                Some(s) => {
                    let n: usize = s.trim().parse().map_err(|_| anyhow!(
                        "--max-conns expects a positive integer (or none), \
                         got {s:?}"))?;
                    Some(Some(n))
                }
            };
            // --pollers N: poller threads for the event-driven
            // connection plane (thread count flat in the connection
            // count). 0 selects the legacy thread-per-connection plane;
            // served bytes are bit-identical either way. Absent = 2 (or
            // whatever a --restore manifest recorded).
            let pollers: Option<usize> = match kv.get("pollers") {
                None => None,
                Some(s) => {
                    let n: usize = s.trim().parse().map_err(|_| anyhow!(
                        "--pollers expects a non-negative integer \
                         (0 = thread-per-connection), got {s:?}"))?;
                    if n > 1024 {
                        bail!("--pollers must be <= 1024, got {s:?}");
                    }
                    Some(n)
                }
            };
            let registry = if let Some(manifest) = kv.get("restore") {
                // rebuild a whole registry from a snapshot manifest; the
                // snapshot's recorded config applies unless a flag was
                // given explicitly on this command line
                let manifest = std::path::Path::new(manifest);
                let mut cfg = TableRegistry::snapshot_config(manifest)?;
                if kv.contains_key("max_batch") {
                    cfg.max_batch = max_batch;
                }
                if kv.contains_key("shards") {
                    cfg.shards_per_table = shards_per_table;
                }
                if let Some(b) = mem_budget {
                    cfg.mem_budget_bytes = b;
                }
                if let Some(t) = ttl_secs {
                    cfg.ttl_secs = t;
                }
                if let Some(sd) = spill_dir.clone() {
                    // Some(None) = --spill-dir none: drop the recorded tier
                    cfg.spill_dir = sd;
                }
                if let Some(on) = spill_on_evict {
                    cfg.spill_on_evict = on;
                }
                if let Some(t) = conn_timeout {
                    cfg.conn_timeout = t;
                }
                if let Some(n) = max_conns {
                    cfg.max_conns = n;
                }
                if let Some(b) = row_cache_bytes {
                    cfg.row_cache_bytes = b;
                }
                if let Some(p) = pollers {
                    cfg.pollers = p;
                }
                // same loud failure as the non-restore path: an explicit
                // --spill policy with no spill dir anywhere (flag OR
                // manifest) would otherwise be silently inert
                if spill_on_evict.is_some() && cfg.spill_dir.is_none() {
                    bail!("--spill needs a spill tier: pass --spill-dir \
                           (the restored manifest records none)");
                }
                let reg = TableRegistry::restore(manifest, Some(cfg))?;
                println!(
                    "restored {} table(s) from snapshot {}",
                    reg.len(), manifest.display()
                );
                reg
            } else {
                // legacy single-table form: --embedding F serves as
                // "default"
                if tables.is_empty() {
                    let path = std::path::PathBuf::from(
                        take_or(&kv, "embedding", "compressed.dpq"));
                    tables.push(("default".to_string(), path, 1, None));
                }
                // `open`, not `new`: a configured spill dir that does
                // not exist must fail loudly at startup, not at the
                // first eviction -- and a spill.json a previous process
                // left behind is re-adopted (spilled tables reload
                // transparently on their first lookup)
                TableRegistry::open(ServerConfig {
                    max_batch,
                    shards_per_table,
                    mem_budget_bytes: mem_budget.flatten(),
                    spill_dir: spill_dir.flatten(),
                    spill_on_evict: spill_on_evict.unwrap_or(true),
                    ttl_secs: ttl_secs.flatten(),
                    // a networked server defends itself by default; the
                    // permissive None defaults are for in-process tests
                    conn_timeout: conn_timeout.unwrap_or(Some(
                        std::time::Duration::from_secs(30))),
                    max_conns: max_conns.unwrap_or(Some(1024)),
                    debug_ops: false,
                    row_cache_bytes: row_cache_bytes.unwrap_or(0),
                    pollers: pollers.unwrap_or(2),
                })?
            };
            // `--table` flags load on top of either path (extra tables
            // alongside a restored snapshot are fine)
            for (name, path, replicas, row_cache) in &tables {
                let emb = dpq_embed::dpq::CompressedEmbedding::load(path)
                    .map_err(|e| anyhow!(
                        "load {path:?}: {e} (run `repro compress` first)"))?;
                registry.insert_with_replicas(
                    name, std::sync::Arc::new(emb), *replicas)?;
                // per-table suffix overrides the --row-cache default the
                // insert applied (0 disables just this table's cache)
                if let Some(b) = row_cache {
                    registry.set_row_cache(name, *b)?;
                }
            }
            if let Some(def) = kv.get("default") {
                registry.set_default(def)?;
            }
            for e in registry.list() {
                println!(
                    "table {}: {} symbols x d={} [{}] ({} KiB resident, \
                     CR {:.1}x, {} shard(s) x {} replica(s))",
                    e.name, e.backend.vocab(), e.backend.d(),
                    e.backend.kind(), e.resident_bytes() / 1024,
                    dpq_embed::backend::compression_ratio(&*e.backend),
                    e.shard_count(), e.replica_count()
                );
                if e.row_cache.cap_bytes() > 0 {
                    println!(
                        "  hot-row cache: {} bytes (raw f32 rows, LRU; \
                         counts against --mem-budget)",
                        e.row_cache.cap_bytes()
                    );
                }
            }
            for s in registry.list_spilled() {
                println!(
                    "table {}: {} symbols x d={} [{}] (recovered from the \
                     spill tier; reloads on first lookup)",
                    s.name(), s.vocab(), s.d(), s.kind()
                );
            }
            let cfg = registry.config();
            if let Some(b) = cfg.mem_budget_bytes {
                println!(
                    "memory budget: {b} bytes (LRU eviction; the default \
                     table is pinned), {} bytes resident",
                    registry.resident_bytes()
                );
            }
            if let Some(t) = cfg.ttl_secs {
                println!(
                    "idle TTL: {t}s (tables nobody looks up for that long \
                     are demoted; the default table is pinned)"
                );
            }
            if let Some(d) = &cfg.spill_dir {
                println!(
                    "spill tier: {} (budget evictions {} victims; demoted \
                     tables reload transparently on lookup)",
                    d.display(),
                    if cfg.spill_on_evict {
                        "demote to disk"
                    } else {
                        "drop (--spill drop)"
                    }
                );
            }
            println!(
                "default table: {} (v1 clients are routed here)",
                registry.default_name().unwrap_or_default()
            );
            println!(
                "connection plane: {}, timeout {}, max conns {}",
                if cfg.pollers > 0 && cfg!(target_os = "linux") {
                    format!("{} poller(s) (event-driven)", cfg.pollers)
                } else {
                    "thread-per-connection".into()
                },
                cfg.conn_timeout
                    .map(|t| format!("{}s", t.as_secs_f64()))
                    .unwrap_or_else(|| "off".into()),
                cfg.max_conns
                    .map(|n| n.to_string())
                    .unwrap_or_else(|| "unbounded".into())
            );
            let server = EmbeddingServer::new(registry);
            server.serve(&addr, |a| println!("listening on {a}"))?;
            Ok(())
        }
        "fuzz" => {
            let kv = parse_cli_overrides(rest)?;
            let seed: u64 = take_or(&kv, "seed", "42").parse()
                .map_err(|_| anyhow!("--seed expects an integer"))?;
            let iters: usize = take_or(&kv, "iters", "2000").parse()
                .map_err(|_| anyhow!("--iters expects an integer"))?;
            // default corpus: the committed regression corpus, found
            // whether the CLI runs from the repo root or rust/
            let corpus = match kv.get("corpus").map(|s| s.trim()) {
                Some("none") | Some("off") => None,
                Some(s) => Some(std::path::PathBuf::from(s)),
                None => ["rust/tests/corpus", "tests/corpus"]
                    .iter()
                    .map(std::path::PathBuf::from)
                    .find(|p| p.is_dir()),
            };
            match &corpus {
                Some(d) => eprintln!(
                    "fuzz: seed {seed}, {iters} iters, corpus {}",
                    d.display()),
                None => eprintln!(
                    "fuzz: seed {seed}, {iters} iters, no corpus"),
            }
            let report = dpq_embed::server::fuzz::run(
                &dpq_embed::server::fuzz::FuzzConfig {
                    seed,
                    iters,
                    corpus_dir: corpus,
                    ..Default::default()
                })?;
            println!(
                "fuzz: {} cases ({} corpus replays + {} generated), \
                 {} handler panic(s) isolated, {} failure(s)",
                report.cases_sent, report.corpus_replayed,
                report.cases_sent - report.corpus_replayed,
                report.handler_panics, report.failures.len()
            );
            for f in &report.failures {
                let at = f.iter
                    .map(|i| format!("iter {i}"))
                    .unwrap_or_else(|| "corpus".into());
                let file = f.file.as_ref()
                    .map(|p| format!(" -> {}", p.display()))
                    .unwrap_or_default();
                println!(
                    "  FAIL [{at}] {}: {} ({} bytes){file}",
                    f.kind, f.detail, f.bytes
                );
            }
            if !report.ok() {
                bail!("fuzz run found {} failure(s)", report.failures.len());
            }
            Ok(())
        }
        "hydrate" => {
            let kv = parse_cli_overrides(rest)?;
            let from = kv.get("from").ok_or_else(|| anyhow!(
                "hydrate needs --from HOST:PORT (a running repro serve)"))?;
            let dir = std::path::PathBuf::from(kv.get("spill_dir")
                .ok_or_else(|| anyhow!(
                    "hydrate needs --spill-dir DIR (where pulled \
                     artifacts land; must exist)"))?);
            let timeout: f64 = take_or(&kv, "timeout", "30").parse()
                .map_err(|_| anyhow!("--timeout expects seconds"))?;
            if !timeout.is_finite() || timeout <= 0.0 {
                bail!("--timeout must be positive seconds");
            }
            let addr = std::net::ToSocketAddrs::to_socket_addrs(from.as_str())
                .map_err(|e| anyhow!("--from {from:?}: {e}"))?
                .next()
                .ok_or_else(|| anyhow!(
                    "--from {from:?} resolved to no address"))?;
            // `open`, not `new`: the dir must exist, and a spill.json a
            // previous process (or previous hydrate) left there is
            // re-adopted first, so only genuinely missing artifacts are
            // pulled over the wire
            let registry = TableRegistry::open(ServerConfig {
                spill_dir: Some(dir.clone()),
                ..ServerConfig::default()
            })?;
            let already = registry.list_spilled().len();
            let mut client = Client::with_timeout(
                addr, std::time::Duration::from_secs_f64(timeout))
                .map_err(|e| anyhow!("connecting to {from}: {e}"))?;
            let pulled = hydrate_from_peer(&registry, &mut client)
                .map_err(|e| anyhow!("hydrating from {from}: {e}"))?;
            println!(
                "hydrated {pulled} table(s) from {from} into {} \
                 ({already} already present, {} spilled total); serve \
                 them with `repro serve --spill-dir {} ...`",
                dir.display(), registry.list_spilled().len(), dir.display()
            );
            Ok(())
        }
        "codes" => {
            let kv = parse_cli_overrides(rest)?;
            let mut cfg = RunConfig::default();
            cfg.apply(&kv)?;
            let rt = Runtime::new(&cfg.artifacts_dir)?;
            let tr = Trainer::new(&rt, cfg.clone()).quiet();
            let out = tr.run()?;
            let ce = experiments::compress_state(&rt, &cfg.artifact,
                                                 &out.state, false)?;
            let codes = ce.codebook.to_tensor();
            let k = ce.codebook.k;
            println!("codebook {}x{} (K={k})", codes.shape[0], codes.shape[1]);
            println!("utilization: {:.3}", dstats::utilization(&codes, k));
            println!("code perplexity: {:.2}", dstats::code_perplexity(&codes, k));
            if let Some(ce_metric) = out.metric("ce") {
                println!("task ce: {:.4} (ppl {:.2})", ce_metric,
                         metrics::perplexity(ce_metric as f64));
            }
            Ok(())
        }
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown command {other}; try `repro help`"),
    }
}

fn print_usage() {
    println!(
        "repro -- DPQ embedding-compression reproduction (ICML 2020)\n\
         \n\
         commands:\n\
         \x20 list                         list available AOT artifacts\n\
         \x20 train      [--artifact P --steps N --lr X ...]\n\
         \x20 experiment <id|all> [--steps N] | --list\n\
         \x20 compress   [--artifact P --out F]\n\
         \x20 serve      [--table NAME=F[:replicas=N][:row_cache=B] ...\n\
         \x20             --default NAME\n\
         \x20             --addr A --max-batch N --shards N\n\
         \x20             --row-cache BYTES|none\n\
         \x20             --mem-budget BYTES|none --ttl SECS|none\n\
         \x20             --conn-timeout SECS|none --max-conns N|none\n\
         \x20             --pollers N\n\
         \x20             --restore MANIFEST\n\
         \x20             --spill-dir DIR|none --spill disk|drop]\n\
         \x20            (--table is repeatable: one server, many tables,\n\
         \x20             routed by table name over protocol v2; legacy\n\
         \x20             --embedding F serves one table named \"default\";\n\
         \x20             :replicas=N serves a hot table through N\n\
         \x20             independent batcher-shard sets over one shared\n\
         \x20             backend (least-loaded routing, bit-identical\n\
         \x20             bytes; resize live with the set_replicas op);\n\
         \x20             --row-cache B keeps each table's hottest rows\n\
         \x20             as raw f32 under an LRU byte cap (bit-identical\n\
         \x20             serving, skew-aware speedup; :row_cache=B\n\
         \x20             overrides per table, resize live with the\n\
         \x20             set_row_cache op; cache capacity counts against\n\
         \x20             --mem-budget);\n\
         \x20             --mem-budget evicts least-recently-used tables\n\
         \x20             past BYTES (K/M/G suffixes ok, default pinned);\n\
         \x20             --ttl SECS demotes tables idle past SECS even\n\
         \x20             under budget (default pinned, \"none\" drops a\n\
         \x20             restored TTL);\n\
         \x20             --spill-dir DIR turns eviction into demotion:\n\
         \x20             victims spill to DIR (must exist) and reload\n\
         \x20             transparently on the next lookup (\"none\" drops\n\
         \x20             a tier a --restore manifest recorded); a\n\
         \x20             spill.json left by a previous process is\n\
         \x20             re-adopted at startup, so a restarted server\n\
         \x20             keeps serving its spilled tables; --spill\n\
         \x20             drop keeps discard-on-evict while still allowing\n\
         \x20             the `demote` admin op;\n\
         \x20             --restore rebuilds a registry from a snapshot\n\
         \x20             manifest written by the `snapshot` wire op;\n\
         \x20             --conn-timeout SECS closes connections that idle\n\
         \x20             or trickle past SECS with a typed `timeout` frame\n\
         \x20             (default 30, fractional ok, \"none\" disables);\n\
         \x20             --max-conns N answers connections over the cap\n\
         \x20             with a typed `busy` frame (default 1024);\n\
         \x20             --pollers N multiplexes every connection onto N\n\
         \x20             event-loop threads (default 2; thread count flat\n\
         \x20             in the connection count, pipelined requests,\n\
         \x20             streamed large responses; 0 = one thread per\n\
         \x20             connection, bit-identical bytes either way);\n\
         \x20             v2 clients also get the `score`/`topk` ops:\n\
         \x20             similarity served straight off the compressed\n\
         \x20             codes via per-query ADC lookup tables, no rows\n\
         \x20             materialized -- see docs/WIRE_PROTOCOL.md)\n\
         \x20 fuzz       [--seed N --iters N --corpus DIR|none]\n\
         \x20            (structure-aware wire fuzzer against a live\n\
         \x20             in-process server; replays the regression corpus\n\
         \x20             (default rust/tests/corpus), then N generated\n\
         \x20             cases; exits nonzero on any panic/wedge)\n\
         \x20 hydrate    --from HOST:PORT --spill-dir DIR [--timeout SECS]\n\
         \x20            (walk a running peer's spilled tables, pull each\n\
         \x20             missing spill artifact by SHA-256 content digest\n\
         \x20             over the v2 `fetch_artifact` op, verify it as it\n\
         \x20             lands, and adopt it into DIR's spill.json; a\n\
         \x20             follow-up `repro serve --spill-dir DIR` then\n\
         \x20             serves the hydrated tables bit-identically --\n\
         \x20             cold-replica provisioning with zero shared disk)\n\
         \x20 codes      [--artifact P --steps N]\n\
         \n\
         global flags:\n\
         \x20 --threads N   worker-pool size for parallel hot paths\n\
         \x20               (default: DPQ_THREADS env var, else all cores)\n\
         \n\
         run `make artifacts` first to build the AOT artifacts."
    );
}
