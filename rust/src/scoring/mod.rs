//! Compute-on-codes retrieval: ADC score tables + deterministic top-k.
//!
//! The point of serving *compressed* embeddings is that similarity can be
//! computed on the codes themselves (asymmetric distance computation,
//! Jegou et al. 2011): a per-query lookup table of subspace dot-products
//! is built once, and each candidate row is then scored with `D` table
//! reads instead of `d` float multiplies. This module holds the
//! backend-independent machinery:
//!
//! * [`ScoreBackend`] -- the capability a backend advertises through
//!   [`EmbeddingBackend::scorer`](crate::backend::EmbeddingBackend::scorer);
//!   it builds a per-query [`QueryScorer`] (the LUT fast path for
//!   `dpq`/`scalar_quant`, the exact row-product path for
//!   `dense`/`low_rank`).
//! * [`ExactScorer`] -- reconstruct-then-dot over any
//!   [`EmbeddingBackend`]; the *reference* implementation every fast
//!   path is tested against (see [`reference_scores`]).
//! * [`score_into`] / [`topk`] -- pool-sharded drivers over a built
//!   scorer, bit-stable at every `DPQ_THREADS` setting, with top-k ties
//!   broken by ascending id so results are reproducible at any
//!   thread/shard count.
//!
//! # Determinism
//!
//! Every scorer computes one candidate's score with a self-contained
//! serial accumulation (group order for the LUT paths, column order for
//! the exact path), so a score never depends on which pool chunk the
//! candidate landed in -- the crate-wide rule from [`crate::util::pool`].
//! The top-k merge sorts the per-shard survivors by `(score desc, id
//! asc)` under `f32::total_cmp`, which is a total order, so the merged
//! result is a pure function of the per-candidate scores.
//!
//! # LUT tolerance
//!
//! The LUT path sums per-group partials instead of walking all `d`
//! columns in one serial chain, so its result differs from
//! [`reference_scores`] only by float re-association: a few ULPs per
//! group. [`adc_tolerance`] documents the bound the equivalence tests
//! enforce (`1e-4 * (1 + sqrt(d))` absolute -- generous against the
//! ~`d * eps` worst case for unit-scale embeddings).

use crate::backend::EmbeddingBackend;
use crate::util::pool;

/// Estimated scalar ops to score one candidate row -- the work-sizing
/// proxy handed to [`pool::workers_for`] (LUT reads ~D, exact dot ~2d;
/// one conservative middle ground keeps small requests serial).
const ROW_COST: usize = 128;

/// Per-query scoring state built once by [`ScoreBackend::query_scorer`]
/// (e.g. the K x D table of subspace dot-products), then shared read-only
/// across pool workers.
pub trait QueryScorer: Sync {
    /// Score the contiguous candidate block `start..start + out.len()`
    /// into `out`. Each row's score must be a self-contained serial
    /// accumulation (the determinism rule): bits may not depend on the
    /// blocking.
    fn score_block(&self, start: usize, out: &mut [f32]);

    /// Score an explicit id list (`out.len() == ids.len()`). The default
    /// routes each id through [`score_block`](Self::score_block);
    /// scorers that need per-block scratch override it.
    fn score_ids(&self, ids: &[usize], out: &mut [f32]) {
        let mut one = [0.0f32];
        for (o, &id) in out.iter_mut().zip(ids) {
            self.score_block(id, &mut one);
            *o = one[0];
        }
    }

    /// Which path this scorer runs: `"lut"` (compute on codes) or
    /// `"exact"` (reconstruct-then-dot). Surfaced in `score`/`topk`
    /// responses so clients and benches can tell them apart.
    fn path(&self) -> &'static str;
}

/// The scoring capability of an embedding backend: build a per-query
/// [`QueryScorer`] over this table. `query.len()` must equal the
/// backend's `d()` -- callers validate width first (the server rejects a
/// mismatch with a typed error before ever reaching this trait).
pub trait ScoreBackend: Send + Sync {
    /// Build the per-query scoring state (LUT where the representation
    /// allows it, exact otherwise).
    fn query_scorer<'a>(&'a self, query: &'a [f32]) -> Box<dyn QueryScorer + 'a>;
}

/// Serial dot product in index order -- the one accumulation order every
/// exact/reference path shares, so "bit-equal to the reference" is well
/// defined.
pub fn dot_serial(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f32;
    for (x, y) in a.iter().zip(b) {
        acc += x * y;
    }
    acc
}

/// Absolute tolerance for LUT-vs-reference score comparison at width `d`
/// (see the module docs): `1e-4 * (1 + sqrt(d))`.
pub fn adc_tolerance(d: usize) -> f32 {
    1e-4 * (1.0 + (d as f32).sqrt())
}

/// A read-only source of already-materialized rows the exact path may
/// consult before reconstructing -- the server's hot-row cache
/// implements this. `copy_row` fills `out` (width `d`) and returns
/// `true` on a hit. The contract that keeps the exact path *exact*: a
/// provided row must be a verbatim copy of what
/// [`EmbeddingBackend::reconstruct_rows_into`] would produce, bit for
/// bit -- reconstruction is deterministic, so any cached copy of a real
/// reconstruction qualifies.
pub trait RowBits: Sync {
    /// Copy row `id` into `out` and return `true`, or return `false`
    /// to send the caller down the reconstruction path.
    fn copy_row(&self, id: usize, out: &mut [f32]) -> bool;
}

/// Reconstruct-then-score over any [`EmbeddingBackend`]: materialize the
/// candidate row (through the backend's own bit-stable gather), then
/// [`dot_serial`] against the query. This is both the *reference* the
/// LUT paths are tested against and the serving path for backends whose
/// representation has no cheaper form (`dense`, `low_rank`). With a
/// [`RowBits`] source attached ([`ExactScorer::with_rows`]) hot rows
/// skip reconstruction -- bit-identical by the `RowBits` contract.
pub struct ExactScorer<'a> {
    backend: &'a dyn EmbeddingBackend,
    query: &'a [f32],
    rows: Option<&'a dyn RowBits>,
}

impl<'a> ExactScorer<'a> {
    /// Pair a backend with a query of width `backend.d()` (asserted).
    pub fn new(backend: &'a dyn EmbeddingBackend, query: &'a [f32]) -> Self {
        assert_eq!(query.len(), backend.d(), "query width != backend d");
        ExactScorer { backend, query, rows: None }
    }

    /// Like [`new`](Self::new), but consult `rows` before
    /// reconstructing each candidate.
    pub fn with_rows(
        backend: &'a dyn EmbeddingBackend,
        query: &'a [f32],
        rows: &'a dyn RowBits,
    ) -> Self {
        assert_eq!(query.len(), backend.d(), "query width != backend d");
        ExactScorer { backend, query, rows: Some(rows) }
    }

    /// Fill `row` with candidate `id`: from the attached [`RowBits`]
    /// source on a hit, by backend reconstruction otherwise.
    fn fetch_row(&self, id: usize, row: &mut [f32]) {
        if let Some(rows) = self.rows {
            if rows.copy_row(id, row) {
                return;
            }
        }
        self.backend.reconstruct_rows_into(&[id], row);
    }
}

impl QueryScorer for ExactScorer<'_> {
    fn score_block(&self, start: usize, out: &mut [f32]) {
        let d = self.query.len();
        let mut row = vec![0.0f32; d];
        for (i, o) in out.iter_mut().enumerate() {
            self.fetch_row(start + i, &mut row);
            *o = dot_serial(self.query, &row);
        }
    }

    fn score_ids(&self, ids: &[usize], out: &mut [f32]) {
        let d = self.query.len();
        let mut row = vec![0.0f32; d];
        for (o, &id) in out.iter_mut().zip(ids) {
            self.fetch_row(id, &mut row);
            *o = dot_serial(self.query, &row);
        }
    }

    fn path(&self) -> &'static str {
        "exact"
    }
}

/// The documented reference: reconstruct each id and [`dot_serial`] it
/// against `query`, serially, in id-list order. Equivalence tests
/// compare every fast path against this (bit-equal for exact paths,
/// within [`adc_tolerance`] for LUT paths).
pub fn reference_scores(
    backend: &dyn EmbeddingBackend,
    query: &[f32],
    ids: &[usize],
) -> Vec<f32> {
    let sc = ExactScorer::new(backend, query);
    let mut out = vec![0.0f32; ids.len()];
    pool::with_threads(1, || sc.score_ids(ids, &mut out));
    out
}

/// Score an explicit id list into `out` (`out.len() == ids.len()`),
/// sharded over the worker pool. Callers validate ids against `vocab`
/// first. Bit-identical at every thread count: each id's score is
/// self-contained, and chunking only partitions the id list.
pub fn score_into(scorer: &dyn QueryScorer, ids: &[usize], out: &mut [f32]) {
    assert_eq!(out.len(), ids.len());
    if ids.is_empty() {
        return;
    }
    pool::with_threads(pool::workers_for(ids.len() * ROW_COST), || {
        let per = pool::chunk_len(ids.len());
        pool::par_chunks_mut(out, per, |ci, chunk| {
            let i0 = ci * per;
            scorer.score_ids(&ids[i0..i0 + chunk.len()], chunk);
        });
    });
}

/// One top-k candidate: id + score. Ordered "better first": higher score
/// wins, ties broken by *ascending* id (under `f32::total_cmp`, a total
/// order), so sorting or heap-merging candidates is deterministic even
/// with duplicated scores.
#[derive(Clone, Copy, Debug)]
pub struct Cand {
    /// Candidate row id.
    pub id: usize,
    /// Dot-product score against the query.
    pub score: f32,
}

impl Cand {
    /// `true` if `self` outranks `other` (higher score, or equal score
    /// and smaller id).
    fn beats(&self, other: &Cand) -> bool {
        match self.score.total_cmp(&other.score) {
            std::cmp::Ordering::Greater => true,
            std::cmp::Ordering::Less => false,
            std::cmp::Ordering::Equal => self.id < other.id,
        }
    }
}

/// Bounded "keep the best k" buffer: a binary min-heap on the ranking
/// order, so the worst kept candidate is at the root and is evicted
/// first. Capacity is fixed at construction; inserting into a full heap
/// either replaces the root or is a no-op.
struct BoundedTopK {
    k: usize,
    // min-heap by hand: heap[0] is the WORST kept candidate
    heap: Vec<Cand>,
}

impl BoundedTopK {
    fn new(k: usize) -> Self {
        BoundedTopK { k, heap: Vec::with_capacity(k) }
    }

    fn offer(&mut self, c: Cand) {
        if self.heap.len() < self.k {
            self.heap.push(c);
            self.sift_up(self.heap.len() - 1);
        } else if c.beats(&self.heap[0]) {
            self.heap[0] = c;
            self.sift_down(0);
        }
    }

    // Min-heap invariant: every parent is outranked by (or ranks equal
    // to) its children -- i.e. a child never ranks below its parent --
    // so `heap[0]` is the worst kept candidate.
    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let p = (i - 1) / 2;
            if self.heap[p].beats(&self.heap[i]) {
                self.heap.swap(p, i);
                i = p;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        loop {
            let (l, r) = (2 * i + 1, 2 * i + 2);
            let mut worst = i;
            if l < self.heap.len() && self.heap[worst].beats(&self.heap[l]) {
                worst = l;
            }
            if r < self.heap.len() && self.heap[worst].beats(&self.heap[r]) {
                worst = r;
            }
            if worst == i {
                break;
            }
            self.heap.swap(i, worst);
            i = worst;
        }
    }

    fn into_vec(self) -> Vec<Cand> {
        self.heap
    }
}

/// Candidates scored per inner block inside a top-k shard (bounds the
/// scratch buffer; the value has no effect on results).
const TOPK_BLOCK: usize = 512;

/// Deterministic parallel top-k over the candidate range `lo..hi`:
/// per-shard bounded heaps (each shard keeps its own best `k`), merged
/// by sorting the survivors "better first" (ties ascending id) and
/// truncating to `k`. Returns at most `min(k, hi - lo)` candidates, best
/// first. Reproducible at every thread/shard count because each
/// candidate's score is shard-independent and the merge order is total.
pub fn topk(scorer: &dyn QueryScorer, lo: usize, hi: usize, k: usize) -> Vec<Cand> {
    let n = hi.saturating_sub(lo);
    let k = k.min(n);
    if k == 0 {
        return Vec::new();
    }
    let mut shards: Vec<Vec<Cand>> = Vec::new();
    pool::with_threads(pool::workers_for(n * ROW_COST), || {
        let per = pool::chunk_len(n);
        shards = vec![Vec::new(); n.div_ceil(per)];
        pool::par_chunks_mut(&mut shards, 1, |si, slot| {
            let start = lo + si * per;
            let end = (start + per).min(hi);
            let mut best = BoundedTopK::new(k);
            let mut buf = [0.0f32; TOPK_BLOCK];
            let mut at = start;
            while at < end {
                let take = (end - at).min(TOPK_BLOCK);
                scorer.score_block(at, &mut buf[..take]);
                for (o, &score) in buf[..take].iter().enumerate() {
                    best.offer(Cand { id: at + o, score });
                }
                at += take;
            }
            slot[0] = best.into_vec();
        });
    });
    let mut all: Vec<Cand> = shards.into_iter().flatten().collect();
    all.sort_by(|a, b| {
        b.score
            .total_cmp(&a.score)
            .then_with(|| a.id.cmp(&b.id))
    });
    all.truncate(k);
    all
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::DenseTable;
    use crate::tensor::TensorF;
    use crate::util::pool::with_threads;
    use crate::util::Rng;

    fn toy_dense(n: usize, d: usize, seed: u64) -> DenseTable {
        let mut rng = Rng::new(seed);
        DenseTable::new(TensorF {
            shape: vec![n, d],
            data: (0..n * d).map(|_| rng.normal()).collect(),
        })
        .unwrap()
    }

    #[test]
    fn exact_scorer_matches_reference_bit_for_bit() {
        let dt = toy_dense(40, 8, 1);
        let query: Vec<f32> = (0..8).map(|i| (i as f32) * 0.25 - 1.0).collect();
        let ids: Vec<usize> = vec![0, 39, 7, 7, 13];
        let reference = reference_scores(&dt, &query, &ids);
        let sc = ExactScorer::new(&dt, &query);
        for threads in [1usize, 2, 7] {
            let mut got = vec![0.0f32; ids.len()];
            with_threads(threads, || score_into(&sc, &ids, &mut got));
            assert!(
                got.iter().zip(&reference).all(|(a, b)| a.to_bits() == b.to_bits()),
                "threads={threads}"
            );
        }
    }

    /// A `RowBits` source holding verbatim reconstructions must be both
    /// actually consulted (a marker row proves the hit path runs) and
    /// bit-invisible when honest: partial coverage mixes cached and
    /// reconstructed candidates and still matches the reference.
    #[test]
    fn with_rows_source_is_consulted_and_bit_exact() {
        struct EvenRows {
            d: usize,
            table: DenseTable,
            hits: std::sync::atomic::AtomicU64,
        }
        impl RowBits for EvenRows {
            fn copy_row(&self, id: usize, out: &mut [f32]) -> bool {
                if id % 2 != 0 {
                    return false;
                }
                self.table.reconstruct_rows_into(&[id], &mut out[..self.d]);
                self.hits.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                true
            }
        }
        let dt = toy_dense(30, 6, 11);
        let query: Vec<f32> = (0..6).map(|i| 0.5 - i as f32 * 0.1).collect();
        let ids: Vec<usize> = (0..30).collect();
        let reference = reference_scores(&dt, &query, &ids);
        let src = EvenRows {
            d: 6,
            table: toy_dense(30, 6, 11), // same seed: identical bits
            hits: std::sync::atomic::AtomicU64::new(0),
        };
        let sc = ExactScorer::with_rows(&dt, &query, &src);
        for threads in [1usize, 2, 7] {
            let mut got = vec![0.0f32; ids.len()];
            with_threads(threads, || score_into(&sc, &ids, &mut got));
            assert!(
                got.iter().zip(&reference).all(|(a, b)| a.to_bits() == b.to_bits()),
                "threads={threads}"
            );
            let top = with_threads(threads, || topk(&sc, 0, 30, 5));
            for c in &top {
                assert_eq!(c.score.to_bits(), reference[c.id].to_bits());
            }
        }
        assert!(
            src.hits.load(std::sync::atomic::Ordering::Relaxed) > 0,
            "the RowBits source was never consulted"
        );
    }

    #[test]
    fn topk_orders_best_first_and_breaks_ties_ascending() {
        // all-identical rows: every score ties, so top-k must be the k
        // smallest ids in order
        let dt = DenseTable::new(TensorF {
            shape: vec![10, 4],
            data: vec![0.5f32; 40],
        })
        .unwrap();
        let query = [1.0f32, 2.0, 3.0, 4.0];
        let sc = ExactScorer::new(&dt, &query);
        for threads in [1usize, 2, 7] {
            let got = with_threads(threads, || topk(&sc, 0, 10, 3));
            assert_eq!(
                got.iter().map(|c| c.id).collect::<Vec<_>>(),
                vec![0, 1, 2],
                "threads={threads}"
            );
        }
    }

    #[test]
    fn topk_matches_full_sort_at_every_thread_count() {
        let dt = toy_dense(300, 12, 3);
        let mut rng = Rng::new(9);
        let query: Vec<f32> = (0..12).map(|_| rng.normal()).collect();
        let sc = ExactScorer::new(&dt, &query);
        // reference: score everything serially, full sort
        let ids: Vec<usize> = (0..300).collect();
        let scores = reference_scores(&dt, &query, &ids);
        let mut order: Vec<usize> = (0..300).collect();
        order.sort_by(|&a, &b| {
            scores[b].total_cmp(&scores[a]).then_with(|| a.cmp(&b))
        });
        for threads in [1usize, 2, 7] {
            let got = with_threads(threads, || topk(&sc, 0, 300, 17));
            assert_eq!(got.len(), 17, "threads={threads}");
            for (rank, c) in got.iter().enumerate() {
                assert_eq!(c.id, order[rank], "threads={threads} rank={rank}");
                assert_eq!(
                    c.score.to_bits(),
                    scores[c.id].to_bits(),
                    "threads={threads} rank={rank}"
                );
            }
        }
    }

    #[test]
    fn topk_respects_range_and_k_clamp() {
        let dt = toy_dense(50, 4, 4);
        let query = [1.0f32, 0.0, -1.0, 0.5];
        let sc = ExactScorer::new(&dt, &query);
        let got = topk(&sc, 10, 20, 99);
        assert_eq!(got.len(), 10); // clamped to the range
        assert!(got.iter().all(|c| (10..20).contains(&c.id)));
        assert!(topk(&sc, 5, 5, 3).is_empty());
        assert!(topk(&sc, 0, 50, 0).is_empty());
    }

    #[test]
    fn bounded_heap_keeps_exactly_the_best_k() {
        let mut h = BoundedTopK::new(3);
        for (id, score) in
            [(0, 1.0f32), (1, 5.0), (2, 3.0), (3, 5.0), (4, -2.0), (5, 4.0)]
        {
            h.offer(Cand { id, score });
        }
        let mut kept: Vec<usize> = h.into_vec().iter().map(|c| c.id).collect();
        kept.sort_unstable();
        // best three: 5.0(id1), 5.0(id3), 4.0(id5)
        assert_eq!(kept, vec![1, 3, 5]);
    }
}
