//! Run configuration for the coordinator: which artifact family to train,
//! for how long, at what learning-rate schedule, where to checkpoint.
//! Parsed from simple `key = value` config files (TOML subset) and/or CLI
//! `--key value` overrides -- the offline build has no serde/clap, so both
//! parsers live here.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Result};

/// Learning-rate schedule: constant warmup-free base LR with optional
/// multiplicative decay after a step threshold (the Zaremba LM recipe).
#[derive(Clone, Debug, PartialEq)]
pub struct LrSchedule {
    /// Base learning rate.
    pub base: f32,
    /// Step after which decay kicks in (`usize::MAX` = never).
    pub decay_after: usize,
    /// Multiplicative decay per `decay_after`-sized epoch past the
    /// threshold (`>= 1.0` disables decay).
    pub decay: f32,
}

impl LrSchedule {
    /// Learning rate in force at `step`.
    pub fn at(&self, step: usize) -> f32 {
        if step <= self.decay_after || self.decay >= 1.0 {
            self.base
        } else {
            let epochs = (step - self.decay_after) as f32
                / self.decay_after.max(1) as f32;
            self.base * self.decay.powf(epochs.ceil())
        }
    }
}

/// A full training-run configuration.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// artifact prefix, e.g. "lm_ptb_sx_K32D32"
    pub artifact: String,
    /// Training steps to run.
    pub steps: usize,
    /// RNG seed for data generation and init.
    pub seed: u64,
    /// Learning-rate schedule.
    pub lr: LrSchedule,
    /// Print metrics every N steps.
    pub log_every: usize,
    /// Held-out batches per evaluation.
    pub eval_batches: usize,
    /// Directory holding the AOT artifacts.
    pub artifacts_dir: PathBuf,
    /// Where to write checkpoints (`None` = don't).
    pub checkpoint_dir: Option<PathBuf>,
    /// Checkpoint every N steps (0 = only at the end).
    pub checkpoint_every: usize,
    /// export codes every N steps (0 = never); powers Fig. 6
    pub export_every: usize,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            artifact: "lm_ptb_sx_K32D32".into(),
            steps: 300,
            seed: 17,
            lr: LrSchedule { base: 1.0, decay_after: usize::MAX, decay: 1.0 },
            log_every: 50,
            eval_batches: 8,
            artifacts_dir: PathBuf::from("artifacts"),
            checkpoint_dir: None,
            checkpoint_every: 0,
            export_every: 0,
        }
    }
}

impl RunConfig {
    /// Parse `key = value` lines (comments with #, blank lines ok).
    pub fn from_kv(text: &str) -> Result<Self> {
        let mut cfg = RunConfig::default();
        let kv = parse_kv(text)?;
        cfg.apply(&kv)?;
        Ok(cfg)
    }

    /// Read and parse a `key = value` config file.
    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("read {path:?}: {e}"))?;
        Self::from_kv(&text)
    }

    /// Apply overrides (CLI `--key value` pairs arrive as a map too).
    pub fn apply(&mut self, kv: &BTreeMap<String, String>) -> Result<()> {
        for (k, v) in kv {
            match k.as_str() {
                "artifact" => self.artifact = v.clone(),
                "steps" => self.steps = v.parse()?,
                "seed" => self.seed = v.parse()?,
                "lr" => self.lr.base = v.parse()?,
                "lr_decay_after" => self.lr.decay_after = v.parse()?,
                "lr_decay" => self.lr.decay = v.parse()?,
                "log_every" => self.log_every = v.parse()?,
                "eval_batches" => self.eval_batches = v.parse()?,
                "artifacts_dir" => self.artifacts_dir = PathBuf::from(v),
                "checkpoint_dir" => {
                    self.checkpoint_dir = Some(PathBuf::from(v))
                }
                "checkpoint_every" => self.checkpoint_every = v.parse()?,
                "export_every" => self.export_every = v.parse()?,
                other => bail!("unknown config key: {other}"),
            }
        }
        Ok(())
    }
}

/// Parse a TOML-subset `key = value` document into a string map. Values
/// may be bare words, numbers, or double-quoted strings.
pub fn parse_kv(text: &str) -> Result<BTreeMap<String, String>> {
    let mut out = BTreeMap::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() || line.starts_with('[') {
            continue; // section headers tolerated and ignored
        }
        let (k, v) = line
            .split_once('=')
            .ok_or_else(|| anyhow!("line {}: expected key = value", lineno + 1))?;
        let v = v.trim().trim_matches('"').to_string();
        out.insert(k.trim().to_string(), v);
    }
    Ok(out)
}

/// Parse CLI tail args of the form `--key value` into a map.
pub fn parse_cli_overrides(args: &[String]) -> Result<BTreeMap<String, String>> {
    let mut out = BTreeMap::new();
    let mut i = 0;
    while i < args.len() {
        let k = args[i]
            .strip_prefix("--")
            .ok_or_else(|| anyhow!("expected --key, got {}", args[i]))?;
        let v = args
            .get(i + 1)
            .ok_or_else(|| anyhow!("--{k} missing value"))?;
        out.insert(k.replace('-', "_"), v.clone());
        i += 2;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_kv_document() {
        let cfg = RunConfig::from_kv(
            "# demo\nartifact = \"lm_ptb_full\"\nsteps = 42\nlr = 0.5\n\
             [ignored section]\nseed = 9\n",
        )
        .unwrap();
        assert_eq!(cfg.artifact, "lm_ptb_full");
        assert_eq!(cfg.steps, 42);
        assert_eq!(cfg.lr.base, 0.5);
        assert_eq!(cfg.seed, 9);
    }

    #[test]
    fn unknown_key_rejected() {
        assert!(RunConfig::from_kv("bogus = 1").is_err());
    }

    #[test]
    fn cli_overrides() {
        let kv = parse_cli_overrides(&[
            "--steps".into(), "10".into(),
            "--lr-decay".into(), "0.5".into(),
        ])
        .unwrap();
        let mut cfg = RunConfig::default();
        cfg.apply(&kv).unwrap();
        assert_eq!(cfg.steps, 10);
        assert_eq!(cfg.lr.decay, 0.5);
    }

    #[test]
    fn cli_rejects_bad_form() {
        assert!(parse_cli_overrides(&["steps".into(), "10".into()]).is_err());
        assert!(parse_cli_overrides(&["--steps".into()]).is_err());
    }

    #[test]
    fn lr_schedule_decays() {
        let s = LrSchedule { base: 1.0, decay_after: 100, decay: 0.5 };
        assert_eq!(s.at(50), 1.0);
        assert_eq!(s.at(100), 1.0);
        assert!(s.at(150) < 1.0);
        assert!(s.at(350) < s.at(150));
    }

    #[test]
    fn lr_constant_when_no_decay() {
        let s = LrSchedule { base: 0.3, decay_after: usize::MAX, decay: 1.0 };
        assert_eq!(s.at(0), 0.3);
        assert_eq!(s.at(10_000_000), 0.3);
    }
}
