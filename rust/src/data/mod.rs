//! Data substrate: vocabulary, tokenizers (whitespace + in-repo BPE
//! subword learner standing in for SentencePiece), synthetic corpus
//! generators for every task family (see DESIGN.md "Substitutions"), and
//! batchers (LM BPTT, padded seq2seq, classification, MLM masking).

pub mod batcher;
pub mod bpe;
pub mod synth;
pub mod vocab;

pub use batcher::{ClassBatch, LmBatch, MlmBatch, NmtBatch};
pub use vocab::Vocab;

/// Padding token id (reserved across the pipeline; match python/compile).
pub const PAD: i32 = 0;
/// Beginning-of-sequence token id.
pub const BOS: i32 = 1;
/// End-of-sequence token id.
pub const EOS: i32 = 2;
/// Unknown-token id.
pub const UNK: i32 = 3;
/// Number of reserved special token ids (real tokens start here).
pub const NUM_SPECIAL: usize = 4;
