//! Batchers: convert the synthetic sources into the fixed-shape integer
//! batches the AOT train/eval artifacts expect (shapes come from artifact
//! manifests; callers pass batch/seq so shapes always agree).

use super::synth::{MarkovLm, SynthMlm, SynthNmt, SynthTextC};
use super::{BOS, EOS, PAD};
use crate::tensor::TensorI;
use crate::util::Rng;

/// Language-model batch: x = tokens, y = next tokens (BPTT-style).
pub struct LmBatch {
    /// `[batch, seq]` input tokens.
    pub x: TensorI,
    /// `[batch, seq]` next-token targets.
    pub y: TensorI,
}

/// Draw one BPTT batch from a Markov LM source.
pub fn lm_batch(src: &mut MarkovLm, batch: usize, seq: usize) -> LmBatch {
    let mut x = Vec::with_capacity(batch * seq);
    let mut y = Vec::with_capacity(batch * seq);
    for _ in 0..batch {
        let toks = src.tokens(seq + 1);
        x.extend_from_slice(&toks[..seq]);
        y.extend_from_slice(&toks[1..]);
    }
    LmBatch {
        x: TensorI::new(vec![batch, seq], x).unwrap(),
        y: TensorI::new(vec![batch, seq], y).unwrap(),
    }
}

/// Seq2seq batch with teacher forcing: tgt_in = BOS + tgt, tgt_out = tgt +
/// EOS, both padded to tgt_len; src padded to src_len.
pub struct NmtBatch {
    /// `[batch, src_len]` padded source tokens.
    pub src: TensorI,
    /// `[batch, tgt_len]` teacher-forcing input (BOS + target).
    pub tgt_in: TensorI,
    /// `[batch, tgt_len]` prediction target (target + EOS).
    pub tgt_out: TensorI,
    /// unpadded reference targets for BLEU
    pub refs: Vec<Vec<i32>>,
    /// unpadded source sentences (for decode-time re-encoding)
    pub srcs: Vec<Vec<i32>>,
}

/// Draw one padded teacher-forcing batch from the synthetic NMT task.
pub fn nmt_batch(gen: &mut SynthNmt, batch: usize, src_len: usize,
                 tgt_len: usize) -> NmtBatch {
    let mut src = vec![PAD; batch * src_len];
    let mut tin = vec![PAD; batch * tgt_len];
    let mut tout = vec![PAD; batch * tgt_len];
    let mut refs = Vec::with_capacity(batch);
    let mut srcs = Vec::with_capacity(batch);
    for b in 0..batch {
        let max_src = src_len.min(tgt_len - 1); // room for EOS on target
        let (s, t) = gen.pair(3.min(max_src), max_src);
        for (i, &v) in s.iter().enumerate() {
            src[b * src_len + i] = v;
        }
        tin[b * tgt_len] = BOS;
        for (i, &v) in t.iter().enumerate() {
            if i + 1 < tgt_len {
                tin[b * tgt_len + i + 1] = v;
            }
            tout[b * tgt_len + i] = v;
        }
        if t.len() < tgt_len {
            tout[b * tgt_len + t.len()] = EOS;
        }
        refs.push(t);
        srcs.push(s);
    }
    NmtBatch {
        src: TensorI::new(vec![batch, src_len], src).unwrap(),
        tgt_in: TensorI::new(vec![batch, tgt_len], tin).unwrap(),
        tgt_out: TensorI::new(vec![batch, tgt_len], tout).unwrap(),
        refs,
        srcs,
    }
}

/// Classification batch: x = padded token matrix, y = labels.
pub struct ClassBatch {
    /// `[batch, seq]` padded token matrix.
    pub x: TensorI,
    /// `[batch]` class labels.
    pub y: TensorI,
}

/// Draw one padded classification batch.
pub fn class_batch(gen: &mut SynthTextC, batch: usize, seq: usize,
                   rng: &mut Rng) -> ClassBatch {
    let mut x = vec![PAD; batch * seq];
    let mut y = vec![0i32; batch];
    for b in 0..batch {
        let len = seq / 2 + rng.below(seq / 2);
        let (toks, label) = gen.doc(len);
        for (i, &t) in toks.iter().take(seq).enumerate() {
            x[b * seq + i] = t;
        }
        y[b] = label;
    }
    ClassBatch {
        x: TensorI::new(vec![batch, seq], x).unwrap(),
        y: TensorI::new(vec![batch], y).unwrap(),
    }
}

/// MLM batch: x = masked ids, y = original ids, w = mask indicator.
pub struct MlmBatch {
    /// `[batch, seq]` masked input ids.
    pub x: TensorI,
    /// `[batch, seq]` original ids (the prediction target).
    pub y: TensorI,
    /// `[batch, seq]` 0/1 indicator of masked positions.
    pub w: TensorI,
}

/// BERT-style masking: `mask_rate` of positions, 80% -> UNK-as-`[MASK]`,
/// 10% -> random token, 10% -> unchanged.
pub fn mlm_batch(gen: &mut SynthMlm, batch: usize, seq: usize,
                 mask_rate: f64, rng: &mut Rng) -> MlmBatch {
    const MASK: i32 = super::UNK; // reuse UNK slot as [MASK]
    let vocab = gen.lm.vocab;
    let mut x = Vec::with_capacity(batch * seq);
    let mut y = Vec::with_capacity(batch * seq);
    let mut w = Vec::with_capacity(batch * seq);
    for _ in 0..batch {
        let s = gen.sentence(seq);
        for (i, &t) in s.iter().enumerate() {
            y.push(t);
            let maskable = i != 0 && i != seq - 1; // keep BOS/EOS intact
            if maskable && rng.f64() < mask_rate {
                w.push(1);
                let roll = rng.f64();
                if roll < 0.8 {
                    x.push(MASK);
                } else if roll < 0.9 {
                    x.push((super::NUM_SPECIAL + rng.below(vocab - super::NUM_SPECIAL)) as i32);
                } else {
                    x.push(t);
                }
            } else {
                w.push(0);
                x.push(t);
            }
        }
    }
    MlmBatch {
        x: TensorI::new(vec![batch, seq], x).unwrap(),
        y: TensorI::new(vec![batch, seq], y).unwrap(),
        w: TensorI::new(vec![batch, seq], w).unwrap(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::prop_check;

    #[test]
    fn lm_batch_shifted_by_one() {
        let mut lm = MarkovLm::new(100, 1);
        let b = lm_batch(&mut lm, 4, 10);
        assert_eq!(b.x.shape, vec![4, 10]);
        // within a row, y[t] is the source's continuation; regenerate to
        // check shapes only (stream is stateful), so check row-consistency:
        for r in 0..4 {
            assert_eq!(&b.x.row(r)[1..], &b.y.row(r)[..9]);
        }
    }

    #[test]
    fn nmt_batch_teacher_forcing_layout() {
        let mut g = SynthNmt::new(200, 200, 2);
        let b = nmt_batch(&mut g, 8, 10, 12);
        for r in 0..8 {
            assert_eq!(b.tgt_in.row(r)[0], BOS);
            let t = &b.refs[r];
            // tgt_out row begins with the reference then EOS then PAD
            assert_eq!(&b.tgt_out.row(r)[..t.len()], &t[..]);
            assert_eq!(b.tgt_out.row(r)[t.len()], EOS);
            // tgt_in is tgt_out shifted right by one
            assert_eq!(&b.tgt_in.row(r)[1..t.len() + 1], &t[..]);
        }
    }

    #[test]
    fn class_batch_labels_in_range() {
        let mut g = SynthTextC::new(104, 4, 3);
        let mut rng = Rng::new(4);
        let b = class_batch(&mut g, 16, 20, &mut rng);
        assert!(b.y.data.iter().all(|&l| (0..4).contains(&l)));
        assert_eq!(b.x.shape, vec![16, 20]);
    }

    #[test]
    fn mlm_batch_mask_invariants() {
        let mut g = SynthMlm::new(150, 5);
        let mut rng = Rng::new(6);
        let b = mlm_batch(&mut g, 8, 16, 0.3, &mut rng);
        let mut masked = 0;
        for i in 0..8 * 16 {
            if b.w.data[i] == 1 {
                masked += 1;
            } else {
                // unmasked positions pass through unchanged
                assert_eq!(b.x.data[i], b.y.data[i]);
            }
        }
        let rate = masked as f64 / (8.0 * 14.0); // maskable positions
        assert!((0.1..0.5).contains(&rate), "mask rate {rate}");
    }

    #[test]
    fn prop_batches_never_exceed_vocab() {
        prop_check(10, |rng| {
            let vocab = 50 + rng.below(200);
            let mut lm = MarkovLm::new(vocab, rng.next_u64());
            let b = lm_batch(&mut lm, 4, 16);
            prop_assert!(
                b.x.data.iter().chain(&b.y.data).all(|&t| (t as usize) < vocab),
                "token out of range (vocab {vocab})"
            );
            Ok(())
        });
    }
}
