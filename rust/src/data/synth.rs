//! Synthetic workload generators -- the stand-ins for the paper's corpora
//! (PTB/Wikitext LM, IWSLT/WMT NMT, AG-News-family classification, BERT
//! pre-training text). Each generator produces a *learnable* task whose
//! difficulty is controlled, so compression-induced capacity loss shows up
//! in the metric exactly as it does on the real datasets (see DESIGN.md
//! "Substitutions" for the argument).

use super::bpe::Bpe;
use super::{BOS, EOS, NUM_SPECIAL};
use crate::util::Rng;

/// Zipfian Markov-chain language source: unigram ranks are Zipf(s), and
/// each token has a sparse successor distribution (low conditional
/// entropy), so an LM that can represent tokens well predicts well.
pub struct MarkovLm {
    /// Vocabulary size (ids in `[NUM_SPECIAL, vocab)`).
    pub vocab: usize,
    succ: Vec<[i32; 4]>, // per token: 4 preferred successors
    rng: Rng,
    state: i32,
    #[allow(dead_code)]
    zipf_s: f64,
    /// mixing weight of the deterministic bigram structure
    pub coherence: f64,
}

impl MarkovLm {
    /// Structure and stream both derived from one seed.
    pub fn new(vocab: usize, seed: u64) -> Self {
        Self::with_stream(vocab, seed, seed ^ 0xC0FFEE)
    }

    /// `structure_seed` fixes the language itself (the successor table);
    /// `stream_seed` only varies which sentences are drawn. Training and
    /// evaluation must share the structure seed or they would literally
    /// speak different languages.
    pub fn with_stream(vocab: usize, structure_seed: u64,
                       stream_seed: u64) -> Self {
        assert!(vocab > NUM_SPECIAL + 8);
        let mut rng = Rng::new(structure_seed);
        let succ = (0..vocab)
            .map(|_| {
                [
                    sample_tok(&mut rng, vocab),
                    sample_tok(&mut rng, vocab),
                    sample_tok(&mut rng, vocab),
                    sample_tok(&mut rng, vocab),
                ]
            })
            .collect();
        MarkovLm {
            vocab,
            succ,
            rng: Rng::new(stream_seed),
            state: NUM_SPECIAL as i32,
            zipf_s: 1.1,
            coherence: 0.85,
        }
    }

    /// Sample the next token of the chain.
    pub fn next_token(&mut self) -> i32 {
        let t = if self.rng.f64() < self.coherence {
            let opts = &self.succ[self.state as usize];
            opts[self.rng.below(4)]
        } else {
            sample_tok(&mut self.rng, self.vocab)
        };
        self.state = t;
        t
    }

    /// Sample `n` consecutive tokens.
    pub fn tokens(&mut self, n: usize) -> Vec<i32> {
        (0..n).map(|_| self.next_token()).collect()
    }
}

fn sample_tok(rng: &mut Rng, vocab: usize) -> i32 {
    (NUM_SPECIAL + rng.zipf(vocab - NUM_SPECIAL, 1.1)) as i32
}

/// Synthetic translation task: target = deterministic lexical relabel of
/// the source with a local swap (tests reordering), plus EOS. Solvable to
/// near-perfect BLEU by an attentive seq2seq, so embedding-compression
/// damage is visible.
pub struct SynthNmt {
    /// Source-side vocabulary size.
    pub src_vocab: usize,
    /// Target-side vocabulary size.
    pub tgt_vocab: usize,
    map: Vec<i32>,
    rng: Rng,
    src_zipf: f64,
}

impl SynthNmt {
    /// Structure and stream both derived from one seed.
    pub fn new(src_vocab: usize, tgt_vocab: usize, seed: u64) -> Self {
        Self::with_stream(src_vocab, tgt_vocab, seed, seed ^ 0xBEEF)
    }

    /// `structure_seed` fixes the lexical mapping (the "language pair");
    /// `stream_seed` varies the sampled sentences only.
    pub fn with_stream(src_vocab: usize, tgt_vocab: usize,
                       structure_seed: u64, stream_seed: u64) -> Self {
        let mut rng = Rng::new(structure_seed);
        // bijective-ish lexical mapping src -> tgt
        let mut targets: Vec<i32> = (0..src_vocab)
            .map(|i| (NUM_SPECIAL + (i * 7 + 5) % (tgt_vocab - NUM_SPECIAL)) as i32)
            .collect();
        rng.shuffle(&mut targets);
        SynthNmt {
            src_vocab,
            tgt_vocab,
            map: targets,
            rng: Rng::new(stream_seed),
            // head-heavy source unigrams: the frequent-word mappings are
            // learnable within a few hundred steps (so BLEU moves), while
            // the long tail still exercises the full embedding table.
            src_zipf: 1.5,
        }
    }

    /// One (src, tgt) pair; src length in [min_len, max_len].
    pub fn pair(&mut self, min_len: usize, max_len: usize) -> (Vec<i32>, Vec<i32>) {
        let len = min_len + self.rng.below(max_len - min_len + 1);
        let src: Vec<i32> = (0..len)
            .map(|_| {
                (NUM_SPECIAL
                    + self.rng.zipf(self.src_vocab - NUM_SPECIAL, self.src_zipf))
                    as i32
            })
            .collect();
        let mut tgt: Vec<i32> =
            src.iter().map(|&s| self.map[s as usize]).collect();
        // deterministic local reordering: swap each adjacent pair
        let mut i = 0;
        while i + 1 < tgt.len() {
            tgt.swap(i, i + 1);
            i += 2;
        }
        (src, tgt)
    }

    /// Reference translation of a given source (for BLEU scoring).
    pub fn reference(&self, src: &[i32]) -> Vec<i32> {
        let mut tgt: Vec<i32> =
            src.iter().map(|&s| self.map[s as usize]).collect();
        let mut i = 0;
        while i + 1 < tgt.len() {
            tgt.swap(i, i + 1);
            i += 2;
        }
        tgt
    }
}

/// Topic-mixture classification: class c prefers a slice of the vocabulary
/// plus shared common words (the fastText regime of the paper's TextC
/// datasets). Difficulty set by `noise` (share of off-topic tokens).
pub struct SynthTextC {
    /// Vocabulary size.
    pub vocab: usize,
    /// Number of classes.
    pub classes: usize,
    /// Share of off-topic (shared) tokens per document.
    pub noise: f64,
    rng: Rng,
}

impl SynthTextC {
    /// Generator with the default 0.5 noise share.
    pub fn new(vocab: usize, classes: usize, seed: u64) -> Self {
        SynthTextC { vocab, classes, noise: 0.5, rng: Rng::new(seed) }
    }

    /// One (tokens, label) document of exactly `len` tokens.
    pub fn doc(&mut self, len: usize) -> (Vec<i32>, i32) {
        let label = self.rng.below(self.classes);
        let usable = self.vocab - NUM_SPECIAL;
        let slice = usable / self.classes;
        let toks = (0..len)
            .map(|_| {
                if self.rng.f64() < self.noise {
                    // shared/common word (zipf over whole vocab)
                    (NUM_SPECIAL + self.rng.zipf(usable, 1.1)) as i32
                } else {
                    // topical word from the class slice
                    (NUM_SPECIAL + label * slice + self.rng.below(slice)) as i32
                }
            })
            .collect();
        (toks, label as i32)
    }
}

/// MLM corpus for the tiny-BERT experiment: Markov sentences with BOS
/// framing; masking is applied by the batcher.
pub struct SynthMlm {
    /// The underlying Markov sentence source.
    pub lm: MarkovLm,
}

impl SynthMlm {
    /// Structure and stream both derived from one seed.
    pub fn new(vocab: usize, seed: u64) -> Self {
        SynthMlm { lm: MarkovLm::new(vocab, seed) }
    }

    /// Separate structure seed (the language) from stream seed (the
    /// sampled sentences); see [`MarkovLm::with_stream`].
    pub fn with_stream(vocab: usize, structure_seed: u64,
                       stream_seed: u64) -> Self {
        SynthMlm { lm: MarkovLm::with_stream(vocab, structure_seed, stream_seed) }
    }

    /// One BOS ... EOS framed sentence of exactly `len` tokens.
    pub fn sentence(&mut self, len: usize) -> Vec<i32> {
        let mut s = vec![BOS];
        s.extend(self.lm.tokens(len - 2));
        s.push(EOS);
        s
    }
}

/// Word-shaped string corpus for the BPE learner tests / demos: renders
/// Markov token ids as pseudo-words so `Bpe::learn` sees natural-ish
/// morphology (shared stems + suffixes).
pub fn pseudo_word(id: i32) -> String {
    const STEMS: [&str; 12] = ["kan", "bor", "tel", "mun", "sar", "vik",
                               "lod", "pra", "gim", "hol", "nek", "dus"];
    const SUFFIXES: [&str; 8] = ["", "a", "en", "ir", "os", "ut", "ane", "ik"];
    let i = id as usize;
    format!("{}{}", STEMS[i % 12], SUFFIXES[(i / 12) % 8])
}

/// Learn a BPE model from a Markov corpus rendered as pseudo-words.
pub fn bpe_from_markov(vocab: usize, tokens: usize, merges: usize,
                       seed: u64) -> Bpe {
    let mut lm = MarkovLm::new(vocab, seed);
    let mut counts = std::collections::HashMap::new();
    for t in lm.tokens(tokens) {
        *counts.entry(pseudo_word(t)).or_insert(0) += 1;
    }
    Bpe::learn(&counts, merges)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markov_tokens_in_range_and_skewed() {
        let mut lm = MarkovLm::new(500, 1);
        let toks = lm.tokens(20000);
        assert!(toks.iter().all(|&t| (NUM_SPECIAL as i32) <= t && t < 500));
        // head-heavy unigram: top-50 tokens should cover > 25% of mass
        let mut counts = vec![0usize; 500];
        for &t in &toks {
            counts[t as usize] += 1;
        }
        counts.sort_by(|a, b| b.cmp(a));
        let head: usize = counts[..50].iter().sum();
        assert!(head * 4 > toks.len(), "head mass {head}/{}", toks.len());
    }

    #[test]
    fn markov_is_predictable() {
        // with coherence, successor entropy is low: the 4 designated
        // successors should cover ~coherence of transitions
        let mut lm = MarkovLm::new(200, 2);
        let toks = lm.tokens(5000);
        let lm2 = MarkovLm::new(200, 2); // same seed -> same succ table
        let mut hits = 0;
        for w in toks.windows(2) {
            if lm2.succ[w[0] as usize].contains(&w[1]) {
                hits += 1;
            }
        }
        let rate = hits as f64 / (toks.len() - 1) as f64;
        assert!(rate > 0.7, "successor hit rate {rate}");
    }

    #[test]
    fn markov_deterministic_per_seed() {
        let a = MarkovLm::new(100, 7).tokens(50);
        let b = MarkovLm::new(100, 7).tokens(50);
        assert_eq!(a, b);
    }

    #[test]
    fn nmt_reference_matches_pair_generation() {
        let mut g = SynthNmt::new(300, 300, 3);
        let (src, tgt) = g.pair(4, 10);
        assert_eq!(g.reference(&src), tgt);
    }

    #[test]
    fn nmt_mapping_is_deterministic_function() {
        let g = SynthNmt::new(300, 300, 4);
        let src = vec![10, 11, 12, 13];
        assert_eq!(g.reference(&src), g.reference(&src));
        // relabel + adjacent swap: position 0 holds map[src[1]]
        let r = g.reference(&src);
        assert_eq!(r[0], g.map[11]);
        assert_eq!(r[1], g.map[10]);
    }

    #[test]
    fn textc_docs_are_classifiable_by_slice() {
        let mut g = SynthTextC::new(404, 4, 5);
        g.noise = 0.3;
        let usable = 400;
        let slice = usable / 4;
        for _ in 0..50 {
            let (toks, label) = g.doc(30);
            // majority of tokens should land in the label's slice
            let inslice = toks
                .iter()
                .filter(|&&t| {
                    let x = t as usize - NUM_SPECIAL;
                    x >= label as usize * slice && x < (label as usize + 1) * slice
                })
                .count();
            assert!(inslice * 2 > toks.len() / 2,
                    "label {label}: {inslice}/{}", toks.len());
        }
    }

    #[test]
    fn mlm_sentence_framed() {
        let mut g = SynthMlm::new(200, 6);
        let s = g.sentence(12);
        assert_eq!(s.len(), 12);
        assert_eq!(s[0], BOS);
        assert_eq!(s[11], EOS);
    }

    #[test]
    fn bpe_from_markov_learns_stems() {
        let bpe = bpe_from_markov(300, 5000, 50, 7);
        assert!(bpe.num_merges() > 10);
        // frequent stem "kan" should segment to few tokens
        assert!(bpe.segment("kana").len() <= 3);
    }
}
