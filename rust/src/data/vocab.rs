//! Frequency-ranked vocabulary with special tokens, built from an iterator
//! of tokens. Id layout: 0=PAD, 1=BOS, 2=EOS, 3=UNK, then tokens by
//! descending frequency (ties broken lexicographically for determinism).

use std::collections::HashMap;

use super::{NUM_SPECIAL, UNK};

/// Frequency-ranked token <-> id mapping with the reserved specials.
#[derive(Clone, Debug)]
pub struct Vocab {
    token_to_id: HashMap<String, i32>,
    id_to_token: Vec<String>,
}

impl Vocab {
    /// Build from token counts, keeping the `max_size - NUM_SPECIAL` most
    /// frequent tokens.
    pub fn from_counts(counts: &HashMap<String, usize>, max_size: usize) -> Self {
        let mut items: Vec<(&String, &usize)> = counts.iter().collect();
        items.sort_by(|a, b| b.1.cmp(a.1).then(a.0.cmp(b.0)));
        let mut id_to_token: Vec<String> =
            ["<pad>", "<bos>", "<eos>", "<unk>"].iter().map(|s| s.to_string()).collect();
        for (tok, _) in items.into_iter().take(max_size.saturating_sub(NUM_SPECIAL)) {
            id_to_token.push(tok.clone());
        }
        let token_to_id = id_to_token
            .iter()
            .enumerate()
            .map(|(i, t)| (t.clone(), i as i32))
            .collect();
        Vocab { token_to_id, id_to_token }
    }

    /// Count tokens from an iterator, then build via
    /// [`from_counts`](Self::from_counts).
    pub fn from_corpus<'a>(tokens: impl Iterator<Item = &'a str>,
                           max_size: usize) -> Self {
        let mut counts: HashMap<String, usize> = HashMap::new();
        for t in tokens {
            *counts.entry(t.to_string()).or_insert(0) += 1;
        }
        Self::from_counts(&counts, max_size)
    }

    /// Total ids, specials included.
    pub fn len(&self) -> usize {
        self.id_to_token.len()
    }

    /// True when the vocabulary holds no ids at all.
    pub fn is_empty(&self) -> bool {
        self.id_to_token.is_empty()
    }

    /// Id of `token` (UNK when out of vocabulary).
    pub fn id(&self, token: &str) -> i32 {
        *self.token_to_id.get(token).unwrap_or(&UNK)
    }

    /// Token string of `id` (`"<unk>"` when out of range).
    pub fn token(&self, id: i32) -> &str {
        self.id_to_token
            .get(id as usize)
            .map(|s| s.as_str())
            .unwrap_or("<unk>")
    }

    /// Whitespace-tokenize and map to ids.
    pub fn encode(&self, text: &str) -> Vec<i32> {
        text.split_whitespace().map(|t| self.id(t)).collect()
    }

    /// Map ids back to a space-joined string.
    pub fn decode(&self, ids: &[i32]) -> String {
        ids.iter()
            .map(|&i| self.token(i))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{EOS, PAD};

    fn sample() -> Vocab {
        Vocab::from_corpus(
            "the cat sat on the mat the cat".split_whitespace(), 100)
    }

    #[test]
    fn specials_first() {
        let v = sample();
        assert_eq!(v.token(PAD), "<pad>");
        assert_eq!(v.token(EOS), "<eos>");
        assert_eq!(v.id("<unk>"), UNK);
    }

    #[test]
    fn frequency_order() {
        let v = sample();
        // "the" (3) before "cat" (2) before singletons
        assert!(v.id("the") < v.id("cat"));
        assert!(v.id("cat") < v.id("mat"));
    }

    #[test]
    fn unknown_maps_to_unk() {
        let v = sample();
        assert_eq!(v.id("zebra"), UNK);
    }

    #[test]
    fn encode_decode_roundtrip_known() {
        let v = sample();
        let ids = v.encode("the cat sat");
        assert_eq!(v.decode(&ids), "the cat sat");
    }

    #[test]
    fn max_size_truncates() {
        let v = Vocab::from_corpus(
            "a b c d e f g h".split_whitespace(), NUM_SPECIAL + 3);
        assert_eq!(v.len(), NUM_SPECIAL + 3);
    }

    #[test]
    fn deterministic_ties() {
        let a = Vocab::from_corpus("x y z".split_whitespace(), 10);
        let b = Vocab::from_corpus("z y x".split_whitespace(), 10);
        assert_eq!(a.id("x"), b.id("x"));
        assert_eq!(a.id("z"), b.id("z"));
    }
}
