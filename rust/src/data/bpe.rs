//! Byte-pair-encoding subword learner + tokenizer, the in-repo stand-in
//! for SentencePiece (paper Sec. 3 / WMT19 sub-words). Learns merges over
//! a word-frequency table, then segments words greedily by learned merge
//! rank. Word boundaries use the "_" prefix convention like the paper's
//! code-visualization tables ("_Monday", "monopol", ...).

use std::collections::HashMap;

/// A learned BPE model: ordered merges + the derived token inventory.
#[derive(Clone, Debug)]
pub struct Bpe {
    /// merge rules in learn order: (left, right) -> merged
    merges: Vec<(String, String)>,
    ranks: HashMap<(String, String), usize>,
}

impl Bpe {
    /// Learn `num_merges` merges from word counts.
    pub fn learn(word_counts: &HashMap<String, usize>, num_merges: usize) -> Self {
        // represent each distinct word as a symbol sequence, "_" marks BOW
        let mut words: Vec<(Vec<String>, usize)> = word_counts
            .iter()
            .map(|(w, &c)| {
                let mut syms = vec![format!("_{}", first_char(w))];
                for ch in w.chars().skip(1) {
                    syms.push(ch.to_string());
                }
                (syms, c)
            })
            .collect();
        words.sort_by(|a, b| a.0.cmp(&b.0)); // determinism
        let mut merges = Vec::new();
        for _ in 0..num_merges {
            // count adjacent pairs
            let mut pair_counts: HashMap<(String, String), usize> = HashMap::new();
            for (syms, c) in &words {
                for w in syms.windows(2) {
                    *pair_counts
                        .entry((w[0].clone(), w[1].clone()))
                        .or_insert(0) += c;
                }
            }
            let best = pair_counts
                .into_iter()
                .max_by(|a, b| a.1.cmp(&b.1).then(b.0.cmp(&a.0)));
            let Some(((l, r), count)) = best else { break };
            if count < 2 {
                break;
            }
            let merged = format!("{l}{r}");
            for (syms, _) in words.iter_mut() {
                let mut i = 0;
                while i + 1 < syms.len() {
                    if syms[i] == l && syms[i + 1] == r {
                        syms[i] = merged.clone();
                        syms.remove(i + 1);
                    } else {
                        i += 1;
                    }
                }
            }
            merges.push((l, r));
        }
        let ranks = merges
            .iter()
            .cloned()
            .enumerate()
            .map(|(i, p)| (p, i))
            .collect();
        Bpe { merges, ranks }
    }

    /// Number of learned merges.
    pub fn num_merges(&self) -> usize {
        self.merges.len()
    }

    /// Segment one word into subword tokens by applying merges in rank
    /// order (the standard greedy BPE segmenter).
    pub fn segment(&self, word: &str) -> Vec<String> {
        if word.is_empty() {
            return vec![];
        }
        let mut syms = vec![format!("_{}", first_char(word))];
        for ch in word.chars().skip(1) {
            syms.push(ch.to_string());
        }
        loop {
            let mut best: Option<(usize, usize)> = None; // (rank, pos)
            for i in 0..syms.len().saturating_sub(1) {
                if let Some(&r) =
                    self.ranks.get(&(syms[i].clone(), syms[i + 1].clone()))
                {
                    if best.map(|(br, _)| r < br).unwrap_or(true) {
                        best = Some((r, i));
                    }
                }
            }
            let Some((_, i)) = best else { break };
            let merged = format!("{}{}", syms[i], syms[i + 1]);
            syms[i] = merged;
            syms.remove(i + 1);
        }
        syms
    }

    /// Tokenize whitespace-split text into subwords.
    pub fn tokenize(&self, text: &str) -> Vec<String> {
        text.split_whitespace()
            .flat_map(|w| self.segment(w))
            .collect()
    }
}

fn first_char(w: &str) -> char {
    w.chars().next().unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn counts(pairs: &[(&str, usize)]) -> HashMap<String, usize> {
        pairs.iter().map(|(w, c)| (w.to_string(), *c)).collect()
    }

    #[test]
    fn learns_frequent_merge_first() {
        let c = counts(&[("aaab", 100), ("aab", 50), ("xyz", 1)]);
        let bpe = Bpe::learn(&c, 10);
        assert!(bpe.num_merges() >= 1);
        // 'a'+'a' dominates; "aaab" should compress below 4 symbols
        assert!(bpe.segment("aaab").len() < 4);
    }

    #[test]
    fn segment_unknown_word_falls_back_to_chars() {
        let c = counts(&[("hello", 5)]);
        let bpe = Bpe::learn(&c, 3);
        let segs = bpe.segment("zq");
        assert_eq!(segs, vec!["_z".to_string(), "q".to_string()]);
    }

    #[test]
    fn segmentation_concat_reconstructs_word() {
        let c = counts(&[("lowest", 5), ("lower", 7), ("low", 9), ("newest", 6)]);
        let bpe = Bpe::learn(&c, 20);
        for w in ["lowest", "lower", "low", "newest", "newer"] {
            let joined: String = bpe.segment(w).concat();
            assert_eq!(joined, format!("_{w}"), "word {w}");
        }
    }

    #[test]
    fn more_merges_fewer_tokens() {
        let c = counts(&[("internationalization", 50), ("international", 80),
                         ("nation", 90), ("nationalization", 40)]);
        let small = Bpe::learn(&c, 2);
        let large = Bpe::learn(&c, 40);
        let w = "internationalization";
        assert!(large.segment(w).len() <= small.segment(w).len());
    }

    #[test]
    fn tokenize_splits_on_whitespace() {
        let c = counts(&[("ab", 10)]);
        let bpe = Bpe::learn(&c, 5);
        let toks = bpe.tokenize("ab ab");
        let joined = toks.concat();
        assert_eq!(joined, "_ab_ab");
    }

    #[test]
    fn deterministic() {
        let c = counts(&[("abc", 5), ("abd", 5), ("bcd", 5)]);
        let a = Bpe::learn(&c, 10);
        let b = Bpe::learn(&c, 10);
        assert_eq!(a.segment("abcd"), b.segment("abcd"));
    }
}
