//! Dense linear-algebra substrate for the post-hoc compression baselines
//! (Table 5 / Table 8): matmul, one-sided Jacobi SVD for the low-rank
//! baseline, and k-means++ / Lloyd for the product-quantization baseline.
//! Implemented from scratch -- the offline build has no BLAS/LAPACK.
//!
//! `matmul` and the k-means assignment step run on the shared worker pool
//! (`util::pool`, thread count from `DPQ_THREADS`). Both are bit-exact
//! with the serial path for any thread count: rows are independent work
//! units and every per-element accumulation keeps the serial order.

use crate::tensor::TensorF;
use crate::util::{pool, Rng};

/// k-dimension block size for `matmul`: keeps the active panel of B
/// (KC x n f32 rows) resident in L2 while a row chunk streams over it.
const MATMUL_KC: usize = 256;

/// C = A @ B for row-major 2-D tensors. [m,k] x [k,n] -> [m,n].
///
/// Parallel over chunks of output rows; within a row the k loop runs in
/// ascending blocks of [`MATMUL_KC`], so each output element accumulates
/// in exactly the serial order (no float reassociation across chunk
/// boundaries) and the result is bit-identical for every thread count.
pub fn matmul(a: &TensorF, b: &TensorF) -> TensorF {
    assert_eq!(a.shape.len(), 2);
    assert_eq!(b.shape.len(), 2);
    let (m, k) = (a.shape[0], a.shape[1]);
    let (k2, n) = (b.shape[0], b.shape[1]);
    assert_eq!(k, k2, "inner dims {k} vs {k2}");
    let mut out = vec![0.0f32; m * n];
    if n == 0 {
        return TensorF { shape: vec![m, n], data: out };
    }
    // ~2 flops per (i, kk, j) triple; small products run serial
    pool::with_threads(pool::workers_for(m * k * n), || {
        let rows_per_chunk = pool::chunk_len(m);
        pool::par_chunks_mut(&mut out, rows_per_chunk * n, |ci, ochunk| {
            let row0 = ci * rows_per_chunk;
            // k-blocked ikj: the k0 block loop is OUTSIDE the row loop, so
            // one KC x n panel of B is reused across every row of the
            // chunk before the next panel is touched. Each output element
            // still accumulates over kk in ascending order (blocks are
            // visited in order, rows within a block don't share elements),
            // so the result is bit-identical to the serial ikj loop.
            let mut k0 = 0;
            while k0 < k {
                let k1 = (k0 + MATMUL_KC).min(k);
                for (ri, orow) in ochunk.chunks_mut(n).enumerate() {
                    let i = row0 + ri;
                    let ablock = &a.data[i * k + k0..i * k + k1];
                    for (kk, &av) in ablock.iter().enumerate() {
                        if av == 0.0 {
                            continue;
                        }
                        let brow = &b.data[(k0 + kk) * n..(k0 + kk + 1) * n];
                        // inner j loop vectorizes; streams the B row
                        for j in 0..n {
                            orow[j] += av * brow[j];
                        }
                    }
                }
                k0 = k1;
            }
        });
    });
    TensorF { shape: vec![m, n], data: out }
}

/// Transpose a 2-D tensor: `[m, n]` -> `[n, m]`.
pub fn transpose(a: &TensorF) -> TensorF {
    let (m, n) = (a.shape[0], a.shape[1]);
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            out[j * m + i] = a.data[i * n + j];
        }
    }
    TensorF { shape: vec![n, m], data: out }
}

/// Thin SVD via one-sided Jacobi rotations on A [m, n] (m >= n is not
/// required; we operate on columns of A). Returns (U `[m,r]`, S `[r]`, Vt `[r,n]`)
/// with r = min(m, n), singular values descending.
pub fn svd(a: &TensorF, sweeps: usize) -> (TensorF, Vec<f32>, TensorF) {
    let (m, n) = (a.shape[0], a.shape[1]);
    // Work on column-major copy of A; V accumulates rotations.
    let mut u = transpose(a).data; // u[j*m + i] = column j
    let mut v = vec![0.0f32; n * n];
    for j in 0..n {
        v[j * n + j] = 1.0;
    }
    let eps = 1e-9f64;
    for _ in 0..sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                let (mut app, mut aqq, mut apq) = (0.0f64, 0.0f64, 0.0f64);
                for i in 0..m {
                    let x = u[p * m + i] as f64;
                    let y = u[q * m + i] as f64;
                    app += x * x;
                    aqq += y * y;
                    apq += x * y;
                }
                off += apq * apq;
                if apq.abs() < eps * (app * aqq).sqrt().max(1e-30) {
                    continue;
                }
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..m {
                    let x = u[p * m + i];
                    let y = u[q * m + i];
                    u[p * m + i] = (c as f32) * x - (s as f32) * y;
                    u[q * m + i] = (s as f32) * x + (c as f32) * y;
                }
                for i in 0..n {
                    let x = v[p * n + i];
                    let y = v[q * n + i];
                    v[p * n + i] = (c as f32) * x - (s as f32) * y;
                    v[q * n + i] = (s as f32) * x + (c as f32) * y;
                }
            }
        }
        if off.sqrt() < 1e-12 {
            break;
        }
    }
    // singular values = column norms of rotated A; U = normalized columns.
    let r = n.min(m);
    let mut order: Vec<usize> = (0..n).collect();
    let norms: Vec<f32> = (0..n)
        .map(|j| (0..m).map(|i| u[j * m + i] * u[j * m + i]).sum::<f32>().sqrt())
        .collect();
    order.sort_by(|&x, &y| norms[y].partial_cmp(&norms[x]).unwrap());
    let mut uu = vec![0.0f32; m * r];
    let mut s = vec![0.0f32; r];
    let mut vt = vec![0.0f32; r * n];
    for (slot, &j) in order.iter().take(r).enumerate() {
        s[slot] = norms[j];
        let inv = if norms[j] > 1e-12 { 1.0 / norms[j] } else { 0.0 };
        for i in 0..m {
            uu[i * r + slot] = u[j * m + i] * inv;
        }
        for i in 0..n {
            vt[slot * n + i] = v[j * n + i];
        }
    }
    (
        TensorF { shape: vec![m, r], data: uu },
        s,
        TensorF { shape: vec![r, n], data: vt },
    )
}

/// Best rank-k approximation A ~= (U_k * S_k) @ Vt_k. Returns (A_kfactors):
/// left [m, k] (U*S) and right [k, n] (Vt).
pub fn low_rank_factors(a: &TensorF, k: usize) -> (TensorF, TensorF) {
    let (u, s, vt) = svd(a, 30);
    let (m, n) = (a.shape[0], a.shape[1]);
    let k = k.min(s.len());
    let mut left = vec![0.0f32; m * k];
    for i in 0..m {
        for j in 0..k {
            left[i * k + j] = u.data[i * u.shape[1] + j] * s[j];
        }
    }
    let mut right = vec![0.0f32; k * n];
    right.copy_from_slice(&vt.data[..k * n]);
    (
        TensorF { shape: vec![m, k], data: left },
        TensorF { shape: vec![k, n], data: right },
    )
}

/// k-means++ initialization + Lloyd iterations over rows of `x` [n, d].
/// Returns (centroids `[k, d]`, assignment `[n]`, inertia).
pub fn kmeans(
    x: &TensorF,
    k: usize,
    iters: usize,
    rng: &mut Rng,
) -> (TensorF, Vec<usize>, f64) {
    let (n, d) = (x.shape[0], x.shape[1]);
    assert!(k >= 1 && n >= 1);
    let k = k.min(n);
    // k-means++ seeding
    let mut centroids = vec![0.0f32; k * d];
    let first = rng.below(n);
    centroids[..d].copy_from_slice(x.row(first));
    let mut dist2: Vec<f64> = (0..n)
        .map(|i| sq_dist(x.row(i), &centroids[..d]) as f64)
        .collect();
    for c in 1..k {
        let pick = rng.weighted(&dist2);
        let (dst, src) = centroids.split_at_mut(c * d);
        let _ = dst;
        src[..d].copy_from_slice(x.row(pick));
        for i in 0..n {
            let nd = sq_dist(x.row(i), &centroids[c * d..(c + 1) * d]) as f64;
            if nd < dist2[i] {
                dist2[i] = nd;
            }
        }
    }
    // Lloyd
    let mut assign = vec![0usize; n];
    // (nearest centroid, squared distance) per row; the parallel
    // assignment step writes here, the inertia fold below reads it.
    let mut nearest: Vec<(u32, f32)> = vec![(0, 0.0); n];
    let mut inertia = f64::INFINITY;
    for _ in 0..iters {
        // assignment step: rows are independent -> sharded across the
        // pool (serial when n*k*d is too small to amortize a spawn).
        // Each row's best-centroid scan is exactly the serial loop.
        pool::with_threads(pool::workers_for(n * k * d), || {
            let rows_per_chunk = pool::chunk_len(n);
            let cent = &centroids;
            pool::par_chunks_mut(&mut nearest, rows_per_chunk, |ci, chunk| {
                let row0 = ci * rows_per_chunk;
                for (o, slot) in chunk.iter_mut().enumerate() {
                    let i = row0 + o;
                    let (mut best, mut bd) = (0usize, f32::INFINITY);
                    for c in 0..k {
                        let dd = sq_dist(x.row(i), &cent[c * d..(c + 1) * d]);
                        if dd < bd {
                            bd = dd;
                            best = c;
                        }
                    }
                    *slot = (best as u32, bd);
                }
            });
        });
        // inertia fold on the caller thread, in row order: bit-identical
        // to the serial accumulation (per-row partials, nothing folded
        // per chunk, so chunk boundaries cannot reassociate it).
        let mut new_inertia = 0.0f64;
        for (i, &(best, bd)) in nearest.iter().enumerate() {
            assign[i] = best as usize;
            new_inertia += bd as f64;
        }
        // update step
        let mut sums = vec![0.0f64; k * d];
        let mut counts = vec![0usize; k];
        for i in 0..n {
            let c = assign[i];
            counts[c] += 1;
            for (j, &v) in x.row(i).iter().enumerate() {
                sums[c * d + j] += v as f64;
            }
        }
        for c in 0..k {
            if counts[c] == 0 {
                // re-seed empty cluster at the farthest point
                let far = (0..n)
                    .max_by(|&a, &b| {
                        let da = sq_dist(x.row(a), &centroids[assign[a] * d..assign[a] * d + d]);
                        let db = sq_dist(x.row(b), &centroids[assign[b] * d..assign[b] * d + d]);
                        da.partial_cmp(&db).unwrap()
                    })
                    .unwrap();
                centroids[c * d..(c + 1) * d].copy_from_slice(x.row(far));
                continue;
            }
            for j in 0..d {
                centroids[c * d + j] = (sums[c * d + j] / counts[c] as f64) as f32;
            }
        }
        if (inertia - new_inertia).abs() < 1e-9 * inertia.max(1.0) {
            inertia = new_inertia;
            break;
        }
        inertia = new_inertia;
    }
    (
        TensorF { shape: vec![k, d], data: centroids },
        assign,
        inertia,
    )
}

fn sq_dist(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// Numerical rank with relative tolerance (used by the Prop. 1 tests).
pub fn rank(a: &TensorF, tol: f32) -> usize {
    let (_, s, _) = svd(a, 30);
    let smax = s.iter().cloned().fold(0.0f32, f32::max);
    s.iter().filter(|&&x| x > tol * smax).count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::prop_check;

    fn randn(shape: Vec<usize>, seed: u64) -> TensorF {
        let mut rng = Rng::new(seed);
        let n: usize = shape.iter().product();
        TensorF { shape, data: (0..n).map(|_| rng.normal()).collect() }
    }

    #[test]
    fn matmul_known() {
        let a = TensorF::new(vec![2, 2], vec![1., 2., 3., 4.]).unwrap();
        let b = TensorF::new(vec![2, 2], vec![5., 6., 7., 8.]).unwrap();
        assert_eq!(matmul(&a, &b).data, vec![19., 22., 43., 50.]);
    }

    #[test]
    fn transpose_roundtrip() {
        let a = randn(vec![3, 5], 1);
        assert_eq!(transpose(&transpose(&a)), a);
    }

    #[test]
    fn svd_reconstructs() {
        let a = randn(vec![12, 8], 2);
        let (u, s, vt) = svd(&a, 30);
        // A ?= U diag(S) Vt
        let mut us = u.clone();
        for i in 0..u.shape[0] {
            for j in 0..u.shape[1] {
                us.data[i * u.shape[1] + j] *= s[j];
            }
        }
        let rec = matmul(&us, &vt);
        assert!(a.rel_err(&rec) < 1e-4, "rel err {}", a.rel_err(&rec));
    }

    #[test]
    fn svd_singular_values_sorted_nonneg() {
        let a = randn(vec![10, 6], 3);
        let (_, s, _) = svd(&a, 30);
        for w in s.windows(2) {
            assert!(w[0] >= w[1] - 1e-6);
        }
        assert!(s.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn svd_orthonormal_u() {
        let a = randn(vec![20, 5], 4);
        let (u, _, _) = svd(&a, 30);
        let g = matmul(&transpose(&u), &u);
        for i in 0..5 {
            for j in 0..5 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((g.data[i * 5 + j] - want).abs() < 1e-3,
                        "gram[{i}][{j}]={}", g.data[i * 5 + j]);
            }
        }
    }

    #[test]
    fn low_rank_exact_when_rank_suffices() {
        // A of true rank 3: random [10,3] @ [3,7]
        let l = randn(vec![10, 3], 5);
        let r = randn(vec![3, 7], 6);
        let a = matmul(&l, &r);
        let (lf, rf) = low_rank_factors(&a, 3);
        let rec = matmul(&lf, &rf);
        assert!(a.rel_err(&rec) < 1e-3, "rel err {}", a.rel_err(&rec));
    }

    #[test]
    fn low_rank_error_decreases_with_rank() {
        let a = randn(vec![20, 10], 7);
        let errs: Vec<f32> = [1usize, 3, 6, 10]
            .iter()
            .map(|&k| {
                let (l, r) = low_rank_factors(&a, k);
                a.rel_err(&matmul(&l, &r))
            })
            .collect();
        for w in errs.windows(2) {
            assert!(w[1] <= w[0] + 1e-5, "{errs:?}");
        }
        assert!(errs[3] < 1e-3); // full rank = exact
    }

    #[test]
    fn kmeans_recovers_separated_clusters() {
        let mut rng = Rng::new(8);
        let mut data = Vec::new();
        for c in 0..3 {
            for _ in 0..40 {
                data.push(c as f32 * 10.0 + 0.1 * rng.normal());
                data.push(c as f32 * -5.0 + 0.1 * rng.normal());
            }
        }
        let x = TensorF::new(vec![120, 2], data).unwrap();
        let (cent, assign, inertia) = kmeans(&x, 3, 25, &mut rng);
        assert_eq!(cent.shape, vec![3, 2]);
        assert!(inertia < 10.0, "inertia {inertia}");
        // all members of an input cluster share an assignment
        for c in 0..3 {
            let a0 = assign[c * 40];
            assert!(assign[c * 40..(c + 1) * 40].iter().all(|&a| a == a0));
        }
    }

    #[test]
    fn kmeans_inertia_decreases_with_k() {
        let x = randn(vec![100, 4], 9);
        let mut prev = f64::INFINITY;
        for k in [1, 2, 8, 32] {
            let (_, _, inertia) = kmeans(&x, k, 20, &mut Rng::new(10));
            assert!(inertia <= prev + 1e-6, "k={k}: {inertia} > {prev}");
            prev = inertia;
        }
    }

    #[test]
    fn rank_of_low_rank_matrix() {
        let l = randn(vec![16, 2], 11);
        let r = randn(vec![2, 12], 12);
        let a = matmul(&l, &r);
        assert_eq!(rank(&a, 1e-4), 2);
    }

    #[test]
    fn prop_svd_reconstruction_random_shapes() {
        prop_check(12, |rng| {
            let m = 2 + rng.below(12);
            let n = 2 + rng.below(8);
            let a = {
                let total = m * n;
                TensorF {
                    shape: vec![m, n],
                    data: (0..total).map(|_| rng.normal()).collect(),
                }
            };
            let (u, s, vt) = svd(&a, 40);
            let mut us = u.clone();
            for i in 0..u.shape[0] {
                for j in 0..u.shape[1] {
                    us.data[i * u.shape[1] + j] *= s[j];
                }
            }
            let rec = matmul(&us, &vt);
            let err = a.rel_err(&rec);
            prop_assert!(err < 1e-3, "m={m} n={n} err={err}");
            Ok(())
        });
    }

    #[test]
    fn prop_kmeans_assignment_is_nearest() {
        prop_check(10, |rng| {
            let n = 10 + rng.below(60);
            let d = 1 + rng.below(5);
            let k = 1 + rng.below(6);
            let x = TensorF {
                shape: vec![n, d],
                data: (0..n * d).map(|_| rng.normal()).collect(),
            };
            let (cent, assign, _) = kmeans(&x, k, 15, rng);
            let k = cent.shape[0];
            for i in 0..n {
                let mine = sq_dist(x.row(i), cent.row(assign[i]));
                for c in 0..k {
                    let other = sq_dist(x.row(i), cent.row(c));
                    prop_assert!(mine <= other + 1e-4,
                                 "row {i}: assigned {} not nearest", assign[i]);
                }
            }
            Ok(())
        });
    }
}
