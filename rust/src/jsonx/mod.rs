//! Minimal JSON parser/serializer (the offline vendor set has no serde).
//! Covers the full JSON grammar needed by artifact manifests, checkpoint
//! metadata, the embedding-server wire protocol and the report writer:
//! objects, arrays, strings (with escapes), numbers, booleans, null.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Maximum container nesting depth the parser accepts. The parser is
/// recursive-descent, so without a cap a document of `[[[[...` recurses
/// once per bracket and overflows the thread stack -- which is an
/// uncatchable process abort, not a panic. 128 is far deeper than any
/// manifest or wire frame this codebase produces (they nest < 10).
const MAX_DEPTH: usize = 128;

/// A parsed JSON value. Objects keep keys in a `BTreeMap`, so
/// serialization is deterministic (lexicographic key order).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any JSON number (always stored as f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (key order is lexicographic, not insertion).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document (trailing bytes are an error).
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value(0)?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    /// Object field by key (`None` for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// The string value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The numeric value truncated to usize, if this is a number.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// The boolean value, if this is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The element slice, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    // ---- builders ---------------------------------------------------------

    /// Object from `(key, value)` pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// String value.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Number value.
    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    /// Array value.
    pub fn arr(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }

    /// Serialize (integers without a fractional part print as integers).
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json, String> {
        if depth > MAX_DEPTH {
            return Err(format!(
                "nesting deeper than {MAX_DEPTH} at byte {}", self.i));
        }
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(depth),
            Some(b'[') => self.array(depth),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other, self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i..self.i + 4])
                                    .map_err(|_| "bad \\u escape")?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape")?;
                            self.i += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(format!("bad escape \\{}", c as char)),
                    }
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf8")?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self, depth: usize) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected , or ] at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self, depth: usize) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value(depth + 1)?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected , or }} at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{"name": "lm_train", "inputs": [{"name": "x",
            "shape": [16, 24], "dtype": "i32", "role": "input"}],
            "meta": {"cr": 18.25, "share": false, "note": null}}"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.get("name").unwrap().as_str(), Some("lm_train"));
        let inp = &j.get("inputs").unwrap().as_arr().unwrap()[0];
        assert_eq!(inp.get("shape").unwrap().as_arr().unwrap()[1].as_usize(),
                   Some(24));
        assert_eq!(j.get("meta").unwrap().get("cr").unwrap().as_f64(),
                   Some(18.25));
        assert_eq!(j.get("meta").unwrap().get("share").unwrap().as_bool(),
                   Some(false));
    }

    #[test]
    fn roundtrip() {
        let j = Json::obj(vec![
            ("a", Json::num(1.5)),
            ("b", Json::arr(vec![Json::num(1.0), Json::str("x\"y\n")])),
            ("c", Json::Bool(true)),
            ("d", Json::Null),
        ]);
        let s = j.to_string();
        assert_eq!(Json::parse(&s).unwrap(), j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{\"a\": }").is_err());
        assert!(Json::parse("[1, 2").is_err());
        assert!(Json::parse("hello").is_err());
        assert!(Json::parse("{} trailing").is_err());
    }

    /// A hostile `[[[[...` document must parse-error, not overflow the
    /// stack (a recursive-descent overflow is a process ABORT, which no
    /// server-side catch_unwind can contain).
    #[test]
    fn deep_nesting_is_an_error_not_an_abort() {
        let deep = "[".repeat(100_000);
        assert!(Json::parse(&deep).is_err());
        let mut closed = "[".repeat(5000);
        closed.push_str(&"]".repeat(5000));
        assert!(Json::parse(&closed).is_err());
        // mixed object/array nesting counts against the same budget
        let objs = "{\"k\":".repeat(50_000);
        assert!(Json::parse(&objs).is_err());
        // ... while anything a real frame nests remains fine
        let mut ok = "[".repeat(100);
        ok.push('1');
        ok.push_str(&"]".repeat(100));
        assert!(Json::parse(&ok).is_ok());
    }

    #[test]
    fn escapes_and_unicode() {
        let j = Json::parse(r#""aA\n\t\\""#).unwrap();
        assert_eq!(j.as_str(), Some("aA\n\t\\"));
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::num(42.0).to_string(), "42");
        assert_eq!(Json::num(2.5).to_string(), "2.5");
    }

    #[test]
    fn prop_roundtrip_random_trees() {
        use crate::util::{prop::prop_check, Rng};
        fn random_json(rng: &mut Rng, depth: usize) -> Json {
            match if depth == 0 { rng.below(4) } else { rng.below(6) } {
                0 => Json::Null,
                1 => Json::Bool(rng.below(2) == 0),
                2 => Json::num((rng.below(10000) as f64) / 8.0),
                3 => Json::str(format!("s{}", rng.below(1000))),
                4 => Json::arr((0..rng.below(4))
                    .map(|_| random_json(rng, depth - 1))
                    .collect()),
                _ => Json::Obj((0..rng.below(4))
                    .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                    .collect()),
            }
        }
        prop_check(200, |rng| {
            let j = random_json(rng, 3);
            let parsed = Json::parse(&j.to_string())
                .map_err(|e| format!("parse error: {e}"))?;
            crate::prop_assert!(parsed == j, "roundtrip mismatch");
            Ok(())
        });
    }
}
