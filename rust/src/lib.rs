//! # dpq-embed
//!
//! Reproduction of **"Differentiable Product Quantization for End-to-End
//! Embedding Compression"** (Chen, Li, Sun -- ICML 2020) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **L1/L2 (build-time Python)** -- Pallas DPQ kernels + JAX task graphs,
//!   AOT-lowered to HLO text by `python/compile/aot.py`.
//! * **L3 (this crate)** -- the runtime: PJRT artifact loading and
//!   execution ([`runtime`]), synthetic data pipeline ([`data`]), training
//!   coordinator and experiment harness ([`coordinator`]), compressed
//!   embedding store ([`dpq`]), post-hoc compression baselines ([`quant`]),
//!   the [`backend::EmbeddingBackend`] serving abstraction,
//!   compute-on-codes similarity scoring ([`scoring`]), metrics
//!   ([`metrics`]) and a multi-table embedding-lookup server ([`server`]).
//!
//! See DESIGN.md for the system inventory and the paper-experiment index,
//! EXPERIMENTS.md for measured results, and `docs/ARCHITECTURE.md` /
//! `docs/WIRE_PROTOCOL.md` for the serving subsystem and its wire
//! format.

// Every public item carries documentation; tier-1 builds rustdoc with
// broken intra-doc links denied (tools/tier1.sh).
#![warn(missing_docs)]

pub mod backend;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod dpq;
pub mod jsonx;
pub mod linalg;
pub mod metrics;
pub mod quant;
pub mod runtime;
pub mod scoring;
pub mod server;
pub mod tensor;
pub mod util;
