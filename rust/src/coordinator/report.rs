//! Report writer: renders experiment results as aligned markdown tables
//! (mirroring the paper's tables) and CSV series (for the figures), and
//! writes them under `reports/`.

use std::fmt::Write as _;
use std::path::{Path, PathBuf};

use anyhow::Result;

/// One experiment report accumulating tables / series / notes.
pub struct Report {
    /// Experiment id (also the output file stem).
    pub id: String,
    /// Human title rendered as the heading.
    pub title: String,
    body: String,
}

impl Report {
    /// Start a report with its heading line.
    pub fn new(id: &str, title: &str) -> Self {
        let mut body = String::new();
        let _ = writeln!(body, "# {id}: {title}\n");
        Report { id: id.to_string(), title: title.to_string(), body }
    }

    /// Append a free-form paragraph.
    pub fn note(&mut self, text: &str) {
        let _ = writeln!(self.body, "{text}\n");
    }

    /// Append an aligned markdown table.
    pub fn table(&mut self, header: &[&str], rows: &[Vec<String>]) {
        let mut widths: Vec<usize> =
            header.iter().map(|h| h.len()).collect();
        for row in rows {
            for (i, cell) in row.iter().enumerate() {
                if i < widths.len() {
                    widths[i] = widths[i].max(cell.len());
                }
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                let w = widths.get(i).copied().unwrap_or(c.len());
                let _ = write!(s, " {c:<w$} |");
            }
            s
        };
        let hdr: Vec<String> = header.iter().map(|s| s.to_string()).collect();
        let _ = writeln!(self.body, "{}", line(&hdr));
        let sep: Vec<String> =
            widths.iter().map(|w| "-".repeat(*w)).collect();
        let _ = writeln!(self.body, "{}", line(&sep));
        for row in rows {
            let _ = writeln!(self.body, "{}", line(row));
        }
        let _ = writeln!(self.body);
    }

    /// Append a CSV series block (figures): header + rows, fenced.
    pub fn series(&mut self, name: &str, header: &[&str],
                  rows: &[Vec<String>]) {
        let _ = writeln!(self.body, "## series: {name}\n");
        let _ = writeln!(self.body, "```csv");
        let _ = writeln!(self.body, "{}", header.join(","));
        for row in rows {
            let _ = writeln!(self.body, "{}", row.join(","));
        }
        let _ = writeln!(self.body, "```\n");
    }

    /// The rendered markdown so far.
    pub fn render(&self) -> &str {
        &self.body
    }

    /// Write `<dir>/<id>.md`; returns the path.
    pub fn save(&self, dir: &Path) -> Result<PathBuf> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{}.md", self.id));
        std::fs::write(&path, &self.body)?;
        Ok(path)
    }
}

/// Format helpers shared by experiments.
pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

/// Format with one decimal place.
pub fn f1(x: f64) -> String {
    format!("{x:.1}")
}

/// Format a fraction as a percentage with one decimal.
pub fn pct(x: f64) -> String {
    format!("{:.1}%", 100.0 * x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut r = Report::new("t", "demo");
        r.table(&["method", "ppl"], &[
            vec!["full".into(), "83.4".into()],
            vec!["dpq-sx-long-name".into(), "82.0".into()],
        ]);
        let s = r.render();
        assert!(s.contains("| method"));
        assert!(s.contains("| dpq-sx-long-name |"));
        // all rows equal width
        let lens: Vec<usize> = s.lines()
            .filter(|l| l.starts_with('|'))
            .map(|l| l.len())
            .collect();
        assert!(lens.windows(2).all(|w| w[0] == w[1]), "{lens:?}");
    }

    #[test]
    fn series_renders_csv() {
        let mut r = Report::new("f", "fig");
        r.series("ppl_vs_k", &["k", "ppl"],
                 &[vec!["2".into(), "90".into()]]);
        assert!(r.render().contains("k,ppl\n2,90"));
    }

    #[test]
    fn save_writes_file() {
        let dir = std::env::temp_dir().join("dpq_report_test");
        let r = Report::new("table9", "x");
        let p = r.save(&dir).unwrap();
        assert!(p.exists());
        assert!(std::fs::read_to_string(p).unwrap().contains("table9"));
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(f2(1.234), "1.23");
        assert_eq!(f1(1.25), "1.2");
        assert_eq!(pct(0.123), "12.3%");
    }
}
