//! Experiment registry: one entry per table/figure of the paper (see
//! DESIGN.md section 5). Each experiment runs the relevant training /
//! compression / analysis jobs through the coordinator and renders a
//! report under `reports/`.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use super::report::{f1, f2, Report};
use super::trainer::{bleu_with, TaskGen, Trainer};
use crate::config::RunConfig;
use crate::dpq::{stats as dstats, Codebook, CompressedEmbedding};
use crate::metrics;
use crate::quant::{Compressor, LowRank, ProductQuant, ScalarQuant};
use crate::runtime::{self, Runtime, State, Value};
use crate::tensor::TensorF;
use crate::util::Rng;

/// Global knobs for experiment scale (CPU budget).
#[derive(Clone, Debug)]
pub struct ExpCfg {
    /// Training steps per run.
    pub steps: usize,
    /// Base RNG seed.
    pub seed: u64,
    /// Where reports are written.
    pub reports_dir: std::path::PathBuf,
    /// Where the AOT artifacts live.
    pub artifacts_dir: std::path::PathBuf,
}

impl Default for ExpCfg {
    fn default() -> Self {
        ExpCfg {
            steps: 300,
            seed: 17,
            reports_dir: "reports".into(),
            artifacts_dir: "artifacts".into(),
        }
    }
}

/// `(experiment id, description)` pairs, in run order for `all`.
pub fn registry() -> Vec<(&'static str, &'static str)> {
    vec![
        ("table3", "DPQ vs full embedding on ten datasets"),
        ("table4", "DPQ vs Shu'17 / Chen'18 / Chen'18+ on PTB (3 sizes)"),
        ("table5", "DPQ vs scalar/product quantization and low-rank"),
        ("table6", "Text classification vs low-rank baselines"),
        ("table7", "DPQ on tiny-BERT pre-train + fine-tune"),
        ("table8", "End-to-end DPQ vs post-hoc PQ on NMT"),
        ("fig3", "K x D heat-maps: task metric and CR"),
        ("fig4", "Extra training cost of DPQ vs K, D"),
        ("fig5", "Code-distribution heat-maps (SX vs VQ)"),
        ("fig6", "Rate of code change during training"),
        ("neighbors", "Nearest neighbours of reconstructed embeddings"),
        ("codes", "Example KD codes for related symbols"),
        ("ablations", "Subspace-sharing and distance-BN ablations"),
    ]
}

/// Run one experiment by id; returns the written report path.
pub fn run(id: &str, rt: &Runtime, cfg: &ExpCfg) -> Result<std::path::PathBuf> {
    let rep = match id {
        "table3" => table3(rt, cfg)?,
        "table4" => table4(rt, cfg)?,
        "table5" => table5(rt, cfg)?,
        "table6" => table6(rt, cfg)?,
        "table7" => table7(rt, cfg)?,
        "table8" => table8(rt, cfg)?,
        "fig3" => fig3(rt, cfg)?,
        "fig4" => fig4(rt, cfg)?,
        "fig5" => fig5(rt, cfg)?,
        "fig6" => fig6(rt, cfg)?,
        "neighbors" => neighbors(rt, cfg)?,
        "codes" => codes_demo(rt, cfg)?,
        "ablations" => ablations(rt, cfg)?,
        other => bail!("unknown experiment {other}; see `repro experiment --list`"),
    };
    let path = rep.save(&cfg.reports_dir)?;
    eprintln!("wrote {}", path.display());
    Ok(path)
}

// ---------------------------------------------------------------------------
// shared helpers
// ---------------------------------------------------------------------------

fn run_cfg(cfg: &ExpCfg, artifact: &str, steps: usize, lr: f32) -> RunConfig {
    // Per-task step budgets (multiples of ExpCfg::steps): NMT needs ~3x
    // before greedy decode is coherent enough for BLEU to move; the LM
    // DPQ variants converge more slowly than the full baseline, so LM
    // families get 2x to compare at (closer to) convergence.
    let steps = if artifact.starts_with("nmt_") {
        steps * 3
    } else if artifact.starts_with("lm_") || artifact.starts_with("shu17_") {
        steps * 2
    } else {
        steps
    };
    RunConfig {
        artifact: artifact.to_string(),
        steps,
        seed: cfg.seed,
        lr: crate::config::LrSchedule {
            base: lr,
            decay_after: usize::MAX,
            decay: 1.0,
        },
        log_every: (steps / 4).max(1),
        eval_batches: 10,
        artifacts_dir: cfg.artifacts_dir.clone(),
        checkpoint_dir: None,
        checkpoint_every: 0,
        export_every: 0,
    }
}

fn task_lr(prefix: &str) -> f32 {
    if prefix.starts_with("lm_") || prefix.starts_with("shu17_") {
        1.0 // SGD families
    } else {
        3e-3 // Adam families
    }
}

/// Train an artifact family, return (final metrics by name, CR from meta).
fn train_family(rt: &Runtime, cfg: &ExpCfg, prefix: &str, steps: usize)
                -> Result<(BTreeMap<String, f64>, f64, super::trainer::TrainOutcome)> {
    let tr = Trainer::new(rt, run_cfg(cfg, prefix, steps, task_lr(prefix)))
        .quiet();
    let out = tr.run()?;
    let mut m = BTreeMap::new();
    for (n, v) in out.metric_names.iter().zip(&out.final_metrics) {
        m.insert(n.clone(), *v as f64);
    }
    let man = &rt.load(&format!("{prefix}_train"))?.manifest;
    let cr = man.meta_f64("cr").unwrap_or(1.0);
    Ok((m, cr, out))
}

/// Pull the trained full-embedding table out of a full-variant state.
fn full_table(state: &State) -> Result<TensorF> {
    Ok(state
        .get("emb/table")
        .ok_or_else(|| anyhow!("state has no emb/table"))?
        .as_f()?
        .clone())
}

/// Evaluate an LM full-variant eval artifact with a (possibly replaced)
/// embedding table -> perplexity over fresh batches.
fn lm_eval_with_table(rt: &Runtime, cfg: &ExpCfg, prefix: &str,
                      state: &State, table: Option<TensorF>,
                      batches: usize) -> Result<f64> {
    let eval = rt.load(&format!("{prefix}_eval"))?;
    let mut st = state.clone();
    if let Some(t) = table {
        st.set("emb/table", Value::F(t))?;
    }
    let mut gen = TaskGen::from_manifest(&eval.manifest, cfg.seed ^ 0xE7A1)?;
    let mut total = 0.0;
    for _ in 0..batches {
        let b = gen.next_batch();
        let m = runtime::run_eval(&eval, &st, &b)?;
        total += m[0] as f64;
    }
    Ok(metrics::perplexity(total / batches as f64))
}

// ---------------------------------------------------------------------------
// Table 3: DPQ-SX / DPQ-VQ vs full on ten datasets
// ---------------------------------------------------------------------------

fn table3(rt: &Runtime, cfg: &ExpCfg) -> Result<Report> {
    let mut rep = Report::new("table3",
        "DPQ variants vs full embedding on ten (synthetic-substituted) datasets");
    rep.note("Paper Table 3. Metrics: PPL (LM, lower better), BLEU (NMT, \
              higher better), Acc% (TextC, higher better). CR in parens. \
              Datasets are synthetic stand-ins shaped like the originals \
              (see DESIGN.md Substitutions).");
    let mut rows = Vec::new();
    // LM rows
    for ds in ["ptb", "wiki2"] {
        let mut cells = vec![format!("LM/{ds} (PPL)")];
        for v in ["full", "sx_K32D32", "vq_K32D32"] {
            let prefix = format!("lm_{ds}_{v}");
            let (m, cr, _) = train_family(rt, cfg, &prefix, cfg.steps)?;
            let ppl = metrics::perplexity(m["ce"]);
            cells.push(if v == "full" {
                f2(ppl)
            } else {
                format!("{} ({})", f2(ppl), f1(cr))
            });
        }
        rows.push(cells);
    }
    // NMT rows (BLEU via greedy decode)
    for ds in ["envi", "vien", "ende"] {
        let mut cells = vec![format!("NMT/{ds} (BLEU)")];
        for v in ["full", "sx_K32D16", "vq_K32D16"] {
            let prefix = format!("nmt_{ds}_{v}");
            let tr = Trainer::new(rt, run_cfg(cfg, &prefix, cfg.steps,
                                              task_lr(&prefix)))
                .quiet();
            let out = tr.run()?;
            let bleu = tr.bleu(&out.state, 4)?;
            let man = rt.load(&format!("{prefix}_train"))?;
            let cr = man.manifest.meta_f64("cr").unwrap_or(1.0);
            cells.push(if v == "full" {
                f2(bleu)
            } else {
                format!("{} ({})", f2(bleu), f1(cr))
            });
        }
        rows.push(cells);
    }
    // TextC rows
    for ds in ["agnews", "yahoo", "dbpedia", "yelpp", "yelpf"] {
        let mut cells = vec![format!("TextC/{ds} (Acc%)")];
        for v in ["full", "sx_K32D16", "vq_K32D16"] {
            let prefix = format!("textc_{ds}_{v}");
            let (m, cr, _) = train_family(rt, cfg, &prefix, cfg.steps)?;
            let acc = 100.0 * m["acc"];
            cells.push(if v == "full" {
                f1(acc)
            } else {
                format!("{} ({})", f1(acc), f1(cr))
            });
        }
        rows.push(cells);
    }
    rep.table(&["task/dataset", "Baseline(full)", "DPQ-SX (CR)",
                "DPQ-VQ (CR)"], &rows);
    Ok(rep)
}

// ---------------------------------------------------------------------------
// Table 4: vs Shu'17 / Chen'18 / Chen'18+ on PTB, three LSTM sizes
// ---------------------------------------------------------------------------

fn table4(rt: &Runtime, cfg: &ExpCfg) -> Result<Report> {
    let mut rep = Report::new("table4",
        "DPQ vs discrete-code baselines on PTB-shaped LM (3 LSTM sizes)");
    rep.note("Paper Table 4. PPL lower-better, CR higher-better. Shu'17 = \
              3-step (train full, learn codes by reconstruction, retrain \
              with frozen codes); Chen'18 = end-to-end code learning with \
              MLP composition; Chen'18+ = Chen'18 + distillation from the \
              trained full table.");
    let sizes = [("small", "ptbsmall"), ("medium", "ptb"), ("large", "ptblarge")];
    let mut rows: Vec<Vec<String>> = Vec::new();

    // Full + DPQ rows for all three sizes
    let add_simple = |label: &str, variant: &str| -> Result<Vec<String>> {
        let mut cells = vec![label.to_string()];
        for (_, ds) in &sizes {
            let prefix = format!("lm_{ds}_{variant}");
            let (m, cr, _) = train_family(rt, cfg, &prefix, cfg.steps)?;
            cells.push(f2(metrics::perplexity(m["ce"])));
            cells.push(if variant == "full" { "1".into() } else { f1(cr) });
        }
        Ok(cells)
    };
    rows.push(add_simple("Full", "full")?);
    rows.push(add_simple("DPQ-SX", "sx_K32D32")?);
    rows.push(add_simple("DPQ-VQ", "vq_K32D32")?);

    // medium-only baselines
    let med_pad = |ppl: f64, cr: f64| {
        vec!["-".into(), "-".into(), f2(ppl), f1(cr), "-".into(), "-".into()]
    };
    // Chen'18 (single-stage)
    {
        let (m, cr, _) = train_family(rt, cfg, "lm_ptb_chen18_K32D16",
                                      cfg.steps)?;
        let mut cells = vec!["Chen'18".to_string()];
        cells.extend(med_pad(metrics::perplexity(m["ce"]), cr));
        rows.push(cells);
    }
    // Chen'18+ (distillation from a trained full table)
    {
        let (_, _, full_out) = train_family(rt, cfg, "lm_ptb_full",
                                            cfg.steps)?;
        let table = full_table(&full_out.state)?;
        let prefix = "lm_ptb_chen18p_K32D16";
        let init = rt.load(&format!("{prefix}_init"))?;
        let train = rt.load(&format!("{prefix}_train"))?;
        let mut state = runtime::run_init(&init, cfg.seed as i32)?;
        let mut gen = TaskGen::from_manifest(&train.manifest, cfg.seed)?;
        let tr = Trainer::new(rt, run_cfg(cfg, prefix, cfg.steps, 1.0))
            .with_extra(vec![Value::F(table), Value::F(TensorF::scalar(0.5))])
            .quiet();
        let out = tr.run_with(&train, None, &mut state, &mut gen)?;
        let cr = train.manifest.meta_f64("cr").unwrap_or(1.0);
        let mut cells = vec!["Chen'18+".to_string()];
        cells.extend(med_pad(
            metrics::perplexity(out.final_metrics[0] as f64), cr));
        rows.push(cells);
    }
    // Shu'17 three-step
    {
        let (_, _, full_out) = train_family(rt, cfg, "lm_ptb_full",
                                            cfg.steps)?;
        let table = full_table(&full_out.state)?;
        // stage 2: code learning by reconstruction
        let cl_prefix = "shu17_ptb_codelearn_K32D16";
        let cl_init = rt.load(&format!("{cl_prefix}_init"))?;
        let cl_train = rt.load(&format!("{cl_prefix}_train"))?;
        let cl_export = rt.load(&format!("{cl_prefix}_export"))?;
        let mut cl_state = runtime::run_init(&cl_init, cfg.seed as i32)?;
        let mut cl_gen = TaskGen::CodeLearn {
            table: table.clone(),
            batch: 256,
            rng: Rng::new(cfg.seed ^ 0x51),
        };
        let tr2 = Trainer::new(rt, run_cfg(cfg, cl_prefix, cfg.steps.max(200), 3e-3))
            .quiet();
        tr2.run_with(&cl_train, None, &mut cl_state, &mut cl_gen)?;
        let codes = runtime::run_aux(&cl_export, &cl_state, &[])?[0]
            .as_i()?
            .clone();
        // stage 3: task training with frozen codes
        let t_prefix = "shu17_ptb_task_K32D16";
        let t_init = rt.load(&format!("{t_prefix}_init"))?;
        let t_train = rt.load(&format!("{t_prefix}_train"))?;
        let mut t_state = runtime::run_init(&t_init, cfg.seed as i32)?;
        let mut t_gen = TaskGen::from_manifest(&t_train.manifest, cfg.seed)?;
        let tr3 = Trainer::new(rt, run_cfg(cfg, t_prefix, cfg.steps, 1.0))
            .with_extra(vec![Value::I(codes)])
            .quiet();
        let out = tr3.run_with(&t_train, None, &mut t_state, &mut t_gen)?;
        let cr = t_train.manifest.meta_f64("cr").unwrap_or(1.0);
        let mut cells = vec!["Shu'17".to_string()];
        cells.extend(med_pad(
            metrics::perplexity(out.final_metrics[0] as f64), cr));
        rows.push(cells);
    }

    rep.table(&["method", "small PPL", "small CR", "medium PPL",
                "medium CR", "large PPL", "large CR"], &rows);
    Ok(rep)
}

// ---------------------------------------------------------------------------
// Table 5: traditional compression baselines on PTB medium
// ---------------------------------------------------------------------------

fn table5(rt: &Runtime, cfg: &ExpCfg) -> Result<Report> {
    let mut rep = Report::new("table5",
        "DPQ vs traditional post-hoc compression on PTB-shaped LM (medium)");
    rep.note("Paper Table 5. Post-hoc methods compress the *trained* full \
              table and re-evaluate without retraining (exactly the paper's \
              setup); DPQ rows are trained end-to-end.");
    // 1) train the full model
    let (full_m, _, full_out) = train_family(rt, cfg, "lm_ptb_full",
                                             cfg.steps)?;
    let table = full_table(&full_out.state)?;
    let (n, d) = (table.rows(), table.cols());
    let base_ppl = lm_eval_with_table(rt, cfg, "lm_ptb_full",
                                      &full_out.state, None, 10)?;
    let mut rows = vec![vec![
        "Full".to_string(), f2(base_ppl), "1.0".to_string(),
    ]];
    let _ = full_m;
    // 2) post-hoc compressors
    let posthoc = |name: String, c: &dyn Compressor| -> Result<Vec<String>> {
        let rec = c.reconstruct();
        let ppl = lm_eval_with_table(rt, cfg, "lm_ptb_full",
                                     &full_out.state, Some(rec), 10)?;
        Ok(vec![name, f2(ppl), f1(c.compression_ratio(n, d))])
    };
    for bits in [8u32, 6, 4] {
        let sq = ScalarQuant::fit(&table, bits);
        rows.push(posthoc(format!("Scalar quantization ({bits} bits)"), &sq)?);
    }
    for (k, dg) in [(64usize, 32usize), (128, 32), (256, 32)] {
        let pq = ProductQuant::fit(&table, k, dg, 12,
                                   &mut Rng::new(cfg.seed ^ k as u64));
        rows.push(posthoc(format!("Product quantization ({k}x{dg})"), &pq)?);
    }
    for cr_target in [5.0, 10.0] {
        let r = LowRank::rank_for_cr(n, d, cr_target);
        let lr = LowRank::fit(&table, r);
        rows.push(posthoc(format!("Low-rank ({cr_target:.0}x, r={r})"), &lr)?);
    }
    // 3) DPQ end-to-end rows
    for v in ["vq", "sx"] {
        let prefix = format!("lm_ptb_{v}_K32D32");
        let (m, cr, _) = train_family(rt, cfg, &prefix, cfg.steps)?;
        rows.push(vec![
            format!("Ours (DPQ-{})", v.to_uppercase()),
            f2(metrics::perplexity(m["ce"])),
            f1(cr),
        ]);
    }
    rep.table(&["method", "PPL", "CR"], &rows);
    Ok(rep)
}

// ---------------------------------------------------------------------------
// Table 6: text classification vs low-rank
// ---------------------------------------------------------------------------

fn table6(rt: &Runtime, cfg: &ExpCfg) -> Result<Report> {
    let mut rep = Report::new("table6",
        "Text classification: accuracy (CR) for DPQ vs trained low-rank");
    rep.note("Paper Table 6. Acc% with CR in parens; low-rank rows are \
              end-to-end trained factorizations (~10x / ~20x).");
    let datasets = ["agnews", "yahoo", "dbpedia", "yelpp", "yelpf"];
    let variants = [
        ("Full", "full"),
        ("Low-rank(~10x)", "lowrank6"),
        ("Low-rank(~20x)", "lowrank3"),
        ("DPQ-VQ", "vq_K32D16"),
        ("DPQ-SX", "sx_K32D16"),
    ];
    let mut rows = Vec::new();
    for (label, v) in variants {
        let mut cells = vec![label.to_string()];
        for ds in datasets {
            let prefix = format!("textc_{ds}_{v}");
            let (m, cr, _) = train_family(rt, cfg, &prefix, cfg.steps)?;
            let acc = 100.0 * m["acc"];
            cells.push(if v == "full" {
                format!("{} (1.0)", f1(acc))
            } else {
                format!("{} ({})", f1(acc), f1(cr))
            });
        }
        rows.push(cells);
    }
    let mut hdr = vec!["method"];
    hdr.extend(datasets);
    rep.table(&hdr, &rows);
    Ok(rep)
}

// ---------------------------------------------------------------------------
// Table 7: tiny-BERT MLM pre-train + fine-tune probe
// ---------------------------------------------------------------------------

fn table7(rt: &Runtime, cfg: &ExpCfg) -> Result<Report> {
    let mut rep = Report::new("table7",
        "DPQ on tiny-BERT: MLM pre-training + classification fine-tune");
    rep.note("Paper Table 7 (scaled: 2-layer BERT, synthetic MLM corpus, \
              lexical probe task). DPQ-SX uses the paper's K=32, D=128.");
    let mut rows = Vec::new();
    for (label, v) in [("Full", "full"), ("DPQ-SX", "sx_K32D128")] {
        let prefix = format!("bert_{v}");
        // pre-train MLM
        let (m, cr, out) = train_family(rt, cfg, &prefix, cfg.steps)?;
        let mlm_ce = m["ce"];
        // fine-tune probe from the pre-trained state
        let ft = rt.load(&format!("{prefix}_ft_train"))?;
        let mut state = out.state.clone();
        let vocab = ft.manifest.meta_usize("vocab").unwrap();
        let batch = ft.manifest.meta_usize("batch").unwrap();
        let seq = ft.manifest.meta_usize("seq").unwrap();
        let mut gen = TaskGen::Probe {
            src: crate::data::synth::SynthMlm::new(vocab, cfg.seed ^ 0xF7),
            batch,
            seq,
        };
        let tr = Trainer::new(rt, run_cfg(cfg, &prefix, cfg.steps / 2 + 50,
                                          3e-3))
            .quiet();
        let ft_out = tr.run_with(&ft, None, &mut state, &mut gen)?;
        let acc = 100.0 * ft_out.metric("acc").unwrap_or(0.0) as f64;
        rows.push(vec![
            label.to_string(),
            if v == "full" { "1.0".into() } else { f1(cr) },
            f2(mlm_ce),
            f1(acc),
        ]);
    }
    rep.table(&["embeddings", "CR", "MLM CE (pre-train)",
                "probe Acc% (fine-tune)"], &rows);
    Ok(rep)
}

// ---------------------------------------------------------------------------
// Table 8: end-to-end DPQ vs post-hoc PQ reconstruction on NMT (ende)
// ---------------------------------------------------------------------------

fn table8(rt: &Runtime, cfg: &ExpCfg) -> Result<Report> {
    let mut rep = Report::new("table8",
        "End-to-end DPQ vs post-hoc PQ of the trained table (NMT ende)");
    rep.note("Paper Table 8. PQ rows: train full model, k-means-PQ the \
              encoder embedding table, decode with the reconstructed \
              table. DPQ rows are end-to-end.");
    // full baseline
    let prefix = "nmt_ende_full";
    let tr = Trainer::new(rt, run_cfg(cfg, prefix, cfg.steps,
                                      task_lr(prefix)))
        .quiet();
    let out = tr.run()?;
    let decode = rt.load(&format!("{prefix}_decode"))?;
    let train_art = rt.load(&format!("{prefix}_train"))?;
    let bleu_full = tr.bleu(&out.state, 4)?;
    let table = out
        .state
        .get("emb/q")
        .or_else(|| out.state.get("emb/table"))
        .ok_or_else(|| anyhow!("no embedding table in state"))?
        .as_f()?
        .clone();
    let (n, d) = (table.rows(), table.cols());
    let mut rows = vec![vec!["Full".to_string(), f2(bleu_full), "1".into()]];
    // post-hoc PQ grid
    for (k, dg) in [(128usize, 8usize), (32, 16), (128, 16), (32, 32), (128, 32)] {
        let pq = ProductQuant::fit(&table, k, dg, 10,
                                   &mut Rng::new(cfg.seed ^ (k * dg) as u64));
        let mut st = out.state.clone();
        st.set("emb/table", Value::F(pq.reconstruct()))?;
        let mut gen = TaskGen::from_manifest(&train_art.manifest,
                                             cfg.seed ^ 0x5EED)?;
        let bleu = bleu_with(&decode, &st, &mut gen, 4)?;
        rows.push(vec![
            format!("PQ (K={k}, D={dg})"),
            f2(bleu),
            f1(pq.compression_ratio(n, d)),
        ]);
    }
    // DPQ end-to-end
    for v in ["vq", "sx"] {
        let prefix = format!("nmt_ende_{v}_K32D16");
        let tr = Trainer::new(rt, run_cfg(cfg, &prefix, cfg.steps,
                                          task_lr(&prefix)))
            .quiet();
        let out = tr.run()?;
        let bleu = tr.bleu(&out.state, 4)?;
        let cr = rt.load(&format!("{prefix}_train"))?
            .manifest
            .meta_f64("cr")
            .unwrap_or(1.0);
        rows.push(vec![
            format!("DPQ-{} (K=32, D=16)", v.to_uppercase()),
            f2(bleu),
            f1(cr),
        ]);
    }
    rep.table(&["method", "BLEU", "CR"], &rows);
    Ok(rep)
}

// ---------------------------------------------------------------------------
// Fig 3: K x D sweep heat-maps (LM medium + NMT envi)
// ---------------------------------------------------------------------------

fn fig3(rt: &Runtime, cfg: &ExpCfg) -> Result<Report> {
    let mut rep = Report::new("fig3",
        "K x D sweep: task metric and compression ratio");
    rep.note("Paper Figure 3. Series rows: variant, K, D, metric, CR. \
              LM metric = PPL (lower better); NMT metric = BLEU.");
    // LM grid
    let mut rows = Vec::new();
    for v in ["sx", "vq"] {
        for k in [2usize, 8, 32, 128] {
            for dg in [8usize, 32] {
                let prefix = format!("lm_ptb_{v}_K{k}D{dg}");
                if !rt.exists(&format!("{prefix}_train")) {
                    continue;
                }
                let (m, cr, _) = train_family(rt, cfg, &prefix, cfg.steps)?;
                rows.push(vec![
                    v.to_string(), k.to_string(), dg.to_string(),
                    f2(metrics::perplexity(m["ce"])), f1(cr),
                ]);
            }
        }
    }
    rep.series("lm_ptb (PPL)", &["variant", "K", "D", "ppl", "cr"], &rows);
    // NMT grid
    let mut rows = Vec::new();
    for v in ["sx", "vq"] {
        for k in [2usize, 32, 128] {
            for dg in [8usize, 16] {
                let prefix = format!("nmt_envi_{v}_K{k}D{dg}");
                if !rt.exists(&format!("{prefix}_train")) {
                    continue;
                }
                let tr = Trainer::new(rt, run_cfg(cfg, &prefix, cfg.steps,
                                                  3e-3))
                    .quiet();
                let out = tr.run()?;
                let bleu = tr.bleu(&out.state, 3)?;
                let cr = rt.load(&format!("{prefix}_train"))?
                    .manifest
                    .meta_f64("cr")
                    .unwrap_or(1.0);
                rows.push(vec![
                    v.to_string(), k.to_string(), dg.to_string(),
                    f2(bleu), f1(cr),
                ]);
            }
        }
    }
    rep.series("nmt_envi (BLEU)", &["variant", "K", "D", "bleu", "cr"],
               &rows);
    Ok(rep)
}

// ---------------------------------------------------------------------------
// Fig 4: training-cost overhead of DPQ vs full
// ---------------------------------------------------------------------------

fn fig4(rt: &Runtime, _cfg: &ExpCfg) -> Result<Report> {
    let mut rep = Report::new("fig4",
        "Extra training cost of DPQ vs full embedding (step wall-clock)");
    rep.note("Paper Figure 4(a), reported as relative step-time overhead \
              on this testbed (CPU PJRT). Memory overhead (4b) is zero by \
              construction at inference; training-state sizes are listed.");
    let warm = 3usize;
    let reps = 12usize;
    let bench = |prefix: &str| -> Result<(f64, usize)> {
        let init = rt.load(&format!("{prefix}_init"))?;
        let train = rt.load(&format!("{prefix}_train"))?;
        let mut state = runtime::run_init(&init, 7)?;
        let mut gen = TaskGen::from_manifest(&train.manifest, 7)?;
        let numel = state.numel();
        for _ in 0..warm {
            let b = gen.next_batch();
            runtime::run_train(&train, &mut state, &b, 0.1)?;
        }
        let t0 = std::time::Instant::now();
        for _ in 0..reps {
            let b = gen.next_batch();
            runtime::run_train(&train, &mut state, &b, 0.1)?;
        }
        Ok((t0.elapsed().as_secs_f64() / reps as f64, numel))
    };
    let (full_t, full_numel) = bench("lm_ptb_full")?;
    let mut rows = vec![vec![
        "full".into(), "-".into(), "-".into(),
        format!("{:.1}", full_t * 1e3), "0.0%".into(),
        full_numel.to_string(),
    ]];
    for v in ["sx", "vq"] {
        for k in [2usize, 8, 32, 128] {
            for dg in [8usize, 32] {
                let prefix = format!("lm_ptb_{v}_K{k}D{dg}");
                if !rt.exists(&format!("{prefix}_train")) {
                    continue;
                }
                let (t, numel) = bench(&prefix)?;
                rows.push(vec![
                    v.into(), k.to_string(), dg.to_string(),
                    format!("{:.1}", t * 1e3),
                    format!("{:+.1}%", 100.0 * (t - full_t) / full_t),
                    numel.to_string(),
                ]);
            }
        }
    }
    rep.series("step_time",
               &["variant", "K", "D", "ms_per_step", "overhead_vs_full",
                 "train_state_elems"],
               &rows);
    Ok(rep)
}

// ---------------------------------------------------------------------------
// Fig 5: code-distribution heat-maps
// ---------------------------------------------------------------------------

fn fig5(rt: &Runtime, cfg: &ExpCfg) -> Result<Report> {
    let mut rep = Report::new("fig5",
        "Code distribution over groups (SX vs VQ), K=D=32");
    rep.note("Paper Figure 5 / Appendix C.1. Count_k^(j) histograms after \
              training; plus utilization and code perplexity summaries \
              (the paper observes SX concentrates, VQ spreads).");
    for v in ["sx", "vq"] {
        let prefix = format!("lm_ptb_{v}_K32D32");
        let mut rc = run_cfg(cfg, &prefix, cfg.steps, task_lr(&prefix));
        rc.export_every = cfg.steps; // just need the final snapshot
        let tr = Trainer::new(rt, rc).quiet();
        let out = tr.run()?;
        let codes = &out.code_snapshots.last().unwrap().1;
        let k = 32;
        let hist = dstats::code_distribution(codes, k);
        let rows: Vec<Vec<String>> = hist
            .iter()
            .enumerate()
            .map(|(g, h)| {
                let mut r = vec![g.to_string()];
                r.extend(h.iter().map(|c| c.to_string()));
                r
            })
            .collect();
        let mut hdr: Vec<String> = vec!["group".into()];
        hdr.extend((0..k).map(|i| format!("k{i}")));
        let hdr_refs: Vec<&str> = hdr.iter().map(|s| s.as_str()).collect();
        rep.series(&format!("counts_{v}"), &hdr_refs, &rows);
        rep.note(&format!(
            "DPQ-{}: utilization={:.2} code-perplexity={:.1} (of K=32)",
            v.to_uppercase(),
            dstats::utilization(codes, k),
            dstats::code_perplexity(codes, k)
        ));
    }
    Ok(rep)
}

// ---------------------------------------------------------------------------
// Fig 6: rate of code change during training
// ---------------------------------------------------------------------------

fn fig6(rt: &Runtime, cfg: &ExpCfg) -> Result<Report> {
    let mut rep = Report::new("fig6",
        "Percentage of code bits changed between checkpoints");
    rep.note("Paper Figure 6 / Appendix C.2 (D=32 here; K in {8,32,128}). \
              Snapshots every steps/10 steps.");
    for v in ["sx", "vq"] {
        let mut rows = Vec::new();
        for k in [8usize, 32, 128] {
            let prefix = format!("lm_ptb_{v}_K{k}D32");
            if !rt.exists(&format!("{prefix}_export")) {
                continue;
            }
            let mut rc = run_cfg(cfg, &prefix, cfg.steps, task_lr(&prefix));
            rc.export_every = (cfg.steps / 10).max(1);
            let tr = Trainer::new(rt, rc).quiet();
            let out = tr.run()?;
            for w in out.code_snapshots.windows(2) {
                let (s0, c0) = &w[0];
                let (s1, c1) = &w[1];
                let _ = s0;
                rows.push(vec![
                    k.to_string(),
                    s1.to_string(),
                    format!("{:.4}", dstats::code_change_rate(c0, c1)),
                ]);
            }
        }
        rep.series(&format!("change_rate_{v}"), &["K", "step", "frac_changed"],
                   &rows);
    }
    Ok(rep)
}

// ---------------------------------------------------------------------------
// Appendix C.3 / C.4: nearest neighbours + example codes
// ---------------------------------------------------------------------------

fn cosine(a: &[f32], b: &[f32]) -> f64 {
    let (mut ab, mut aa, mut bb) = (0.0f64, 0.0f64, 0.0f64);
    for (x, y) in a.iter().zip(b) {
        ab += (*x as f64) * (*y as f64);
        aa += (*x as f64) * (*x as f64);
        bb += (*y as f64) * (*y as f64);
    }
    ab / (aa.sqrt() * bb.sqrt()).max(1e-12)
}

fn top_neighbors(table: &TensorF, row: usize, topk: usize) -> Vec<(usize, f64)> {
    let mut sims: Vec<(usize, f64)> = (0..table.rows())
        .map(|i| (i, cosine(table.row(row), table.row(i))))
        .collect();
    sims.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    sims.truncate(topk);
    sims
}

fn neighbors(rt: &Runtime, cfg: &ExpCfg) -> Result<Report> {
    let mut rep = Report::new("neighbors",
        "Nearest neighbours in the reconstructed embedding space");
    rep.note("Paper Tables 9-11 (Appendix C.3), on the synthetic LM vocab: \
              cosine neighbours of probe symbols under full vs DPQ-SX vs \
              DPQ-VQ reconstructed tables. Symbols are synthetic ids; the \
              check is structural (overlap of neighbour sets).");
    // tables from the three variants
    let mut tables: Vec<(String, TensorF)> = Vec::new();
    {
        let (_, _, out) = train_family(rt, cfg, "lm_ptb_full", cfg.steps)?;
        tables.push(("full".into(), full_table(&out.state)?));
    }
    for v in ["sx", "vq"] {
        let prefix = format!("lm_ptb_{v}_K32D32");
        let (_, _, out) = train_family(rt, cfg, &prefix, cfg.steps)?;
        let exp = rt.load(&format!("{prefix}_export"))?;
        let res = runtime::run_aux(&exp, &out.state, &[])?;
        tables.push((v.into(), res[2].as_f()?.clone()));
    }
    let probes = [10usize, 50, 200];
    for &p in &probes {
        let mut rows = Vec::new();
        for (name, t) in &tables {
            let nn = top_neighbors(t, p, 8);
            let cells: Vec<String> = nn
                .iter()
                .map(|(i, s)| format!("{i}:{s:.3}"))
                .collect();
            let mut row = vec![name.clone()];
            row.extend(cells);
            rows.push(row);
        }
        rep.table(&["table", "nn1", "nn2", "nn3", "nn4", "nn5", "nn6",
                    "nn7", "nn8"], &rows);
        // structural overlap stat
        let full_nn: std::collections::HashSet<usize> =
            top_neighbors(&tables[0].1, p, 10).iter().map(|x| x.0).collect();
        for (name, t) in tables.iter().skip(1) {
            let got: std::collections::HashSet<usize> =
                top_neighbors(t, p, 10).iter().map(|x| x.0).collect();
            let overlap = full_nn.intersection(&got).count();
            rep.note(&format!(
                "probe {p}: DPQ-{} shares {overlap}/10 top-neighbours with full",
                name.to_uppercase()
            ));
        }
    }
    Ok(rep)
}

fn codes_demo(rt: &Runtime, cfg: &ExpCfg) -> Result<Report> {
    let mut rep = Report::new("codes",
        "Example KD codes for related symbols (paper Table 12)");
    rep.note("Synthetic analogue of Table 12: symbols sharing Markov \
              successor structure should share code coordinates. We list \
              codes of 8 probe symbols per variant and report the mean \
              intra-group vs inter-group code Hamming agreement.");
    for v in ["sx", "vq"] {
        let prefix = format!("lm_ptb_{v}_K32D32");
        let (_, _, out) = train_family(rt, cfg, &prefix, cfg.steps)?;
        let exp = rt.load(&format!("{prefix}_export"))?;
        let res = runtime::run_aux(&exp, &out.state, &[])?;
        let codes = res[0].as_i()?.clone();
        let table = res[2].as_f()?.clone();
        // probe group: a symbol and its nearest neighbours (related), plus
        // random symbols (unrelated)
        let anchor = 25usize;
        let related: Vec<usize> =
            top_neighbors(&table, anchor, 4).iter().map(|x| x.0).collect();
        let mut rng = Rng::new(cfg.seed ^ 0xC0DE);
        let unrelated: Vec<usize> =
            (0..4).map(|_| 4 + rng.below(codes.rows() - 4)).collect();
        let mut rows = Vec::new();
        for (label, ids) in [("related", &related), ("random", &unrelated)] {
            for &i in ids.iter() {
                let c: Vec<String> =
                    codes.row(i).iter().map(|x| x.to_string()).collect();
                rows.push(vec![label.to_string(), i.to_string(),
                               c[..8.min(c.len())].join(" ")]);
            }
        }
        rep.table(&["group", "symbol", "first 8 of D codes"], &rows);
        let agree = |ids: &[usize]| -> f64 {
            let mut total = 0.0;
            let mut cnt = 0;
            for (ii, &a) in ids.iter().enumerate() {
                for &b in ids.iter().skip(ii + 1) {
                    let same = codes
                        .row(a)
                        .iter()
                        .zip(codes.row(b))
                        .filter(|(x, y)| x == y)
                        .count();
                    total += same as f64 / codes.shape[1] as f64;
                    cnt += 1;
                }
            }
            total / cnt.max(1) as f64
        };
        rep.note(&format!(
            "DPQ-{}: intra-group code agreement {:.3} vs random {:.3}",
            v.to_uppercase(), agree(&related), agree(&unrelated)));
    }
    Ok(rep)
}

// ---------------------------------------------------------------------------
// Ablations: the Sec. 2.4 design choices (subspace-sharing, distance BN)
// ---------------------------------------------------------------------------

fn ablations(rt: &Runtime, cfg: &ExpCfg) -> Result<Report> {
    let mut rep = Report::new("ablations",
        "Design-choice ablations: subspace-sharing and distance batch-norm");
    rep.note("Paper Sec. 2.4: sharing the key/value matrices across the D \
              groups buys extra CR (use it when no metric drop); distance \
              batch-norm stabilizes straight-through training. Rows: LM \
              medium, K=32, D=32.");
    let mut rows = Vec::new();
    for v in ["sx", "vq"] {
        for (label, suffix) in [
            ("default", format!("{v}_K32D32")),
            ("+ subspace-sharing", format!("{v}_K32D32s")),
            ("- distance BN", format!("{v}_K32D32nb")),
        ] {
            let prefix = format!("lm_ptb_{suffix}");
            if !rt.exists(&format!("{prefix}_train")) {
                continue;
            }
            let (m, cr, _) = train_family(rt, cfg, &prefix, cfg.steps)?;
            rows.push(vec![
                format!("DPQ-{} {label}", v.to_uppercase()),
                f2(metrics::perplexity(m["ce"])),
                f1(cr),
            ]);
        }
    }
    rep.table(&["config", "PPL", "CR"], &rows);
    Ok(rep)
}

// ---------------------------------------------------------------------------
// also used by the CLI: post-hoc compression of a checkpointed table
// ---------------------------------------------------------------------------

/// Compress a trained DPQ state into the inference artifact (codes+values)
/// and report its CR; returns the compressed embedding.
pub fn compress_state(rt: &Runtime, prefix: &str, state: &State,
                      shared: bool) -> Result<CompressedEmbedding> {
    let exp = rt.load(&format!("{prefix}_export"))?;
    let out = runtime::run_aux(&exp, state, &[])?;
    let codes = out[0].as_i()?;
    let values = out[1].as_f()?;
    let k = values.shape[0];
    let ce = CompressedEmbedding::new(Codebook::from_codes(codes, k)?,
                                      values.clone(), shared)?;
    Ok(ce)
}
