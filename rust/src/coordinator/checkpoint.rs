//! Binary checkpoint format for training state: a simple tagged container
//! of named tensors (name, dtype, shape, raw little-endian data). Used by
//! the trainer for periodic snapshots and by the multi-stage experiments
//! (Shu'17 / distillation) to hand trained tables between stages.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::runtime::{State, Value};
use crate::tensor::{TensorF, TensorI};

const MAGIC: &[u8; 4] = b"DPQC";

/// Write a checkpoint of the training state to `path`.
pub fn save(path: &Path, state: &State) -> Result<()> {
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("create {path:?}"))?;
    f.write_all(MAGIC)?;
    f.write_all(&(state.names.len() as u64).to_le_bytes())?;
    for (name, value) in state.entries() {
        let value = value?;
        let nb = name.as_bytes();
        f.write_all(&(nb.len() as u64).to_le_bytes())?;
        f.write_all(nb)?;
        match &value {
            Value::F(t) => {
                f.write_all(&[0u8])?;
                write_shape(&mut f, &t.shape)?;
                for v in &t.data {
                    f.write_all(&v.to_le_bytes())?;
                }
            }
            Value::I(t) => {
                f.write_all(&[1u8])?;
                write_shape(&mut f, &t.shape)?;
                for v in &t.data {
                    f.write_all(&v.to_le_bytes())?;
                }
            }
        }
    }
    Ok(())
}

/// Read a checkpoint written by [`save`].
pub fn load(path: &Path) -> Result<State> {
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("open {path:?}"))?;
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("bad checkpoint magic");
    }
    let count = read_u64(&mut f)? as usize;
    let mut names = Vec::with_capacity(count);
    let mut dtypes = Vec::with_capacity(count);
    let mut lits = Vec::with_capacity(count);
    for _ in 0..count {
        let nlen = read_u64(&mut f)? as usize;
        let mut nb = vec![0u8; nlen];
        f.read_exact(&mut nb)?;
        names.push(String::from_utf8(nb).context("name utf8")?);
        let mut tag = [0u8; 1];
        f.read_exact(&mut tag)?;
        let shape = read_shape(&mut f)?;
        let numel: usize = shape.iter().product();
        match tag[0] {
            0 => {
                let mut data = vec![0.0f32; numel];
                let mut buf = [0u8; 4];
                for v in data.iter_mut() {
                    f.read_exact(&mut buf)?;
                    *v = f32::from_le_bytes(buf);
                }
                dtypes.push("f32".to_string());
                lits.push(TensorF::new(shape, data)?.to_literal()?);
            }
            1 => {
                let mut data = vec![0i32; numel];
                let mut buf = [0u8; 4];
                for v in data.iter_mut() {
                    f.read_exact(&mut buf)?;
                    *v = i32::from_le_bytes(buf);
                }
                dtypes.push("i32".to_string());
                lits.push(TensorI::new(shape, data)?.to_literal()?);
            }
            t => bail!("bad tensor tag {t}"),
        }
    }
    State::from_literals(names, dtypes, lits)
}

fn write_shape(f: &mut std::fs::File, shape: &[usize]) -> Result<()> {
    f.write_all(&(shape.len() as u64).to_le_bytes())?;
    for &d in shape {
        f.write_all(&(d as u64).to_le_bytes())?;
    }
    Ok(())
}

fn read_shape(f: &mut std::fs::File) -> Result<Vec<usize>> {
    let rank = read_u64(f)? as usize;
    if rank > 16 {
        bail!("implausible rank {rank}");
    }
    (0..rank).map(|_| Ok(read_u64(f)? as usize)).collect()
}

fn read_u64(f: &mut std::fs::File) -> Result<u64> {
    let mut buf = [0u8; 8];
    f.read_exact(&mut buf)?;
    Ok(u64::from_le_bytes(buf))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_state() -> State {
        State::from_literals(
            vec!["emb/q".into(), "codes".into(), "scalar".into()],
            vec!["f32".into(), "i32".into(), "f32".into()],
            vec![
                TensorF::new(vec![2, 3], vec![1.0, -2.5, 3.0, 0.0, 9.9, -1e-7])
                    .unwrap()
                    .to_literal()
                    .unwrap(),
                TensorI::new(vec![4], vec![1, 2, 3, -4])
                    .unwrap()
                    .to_literal()
                    .unwrap(),
                TensorF::scalar(42.0).to_literal().unwrap(),
            ],
        )
        .unwrap()
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("dpq_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("s.ckpt");
        let s = sample_state();
        save(&p, &s).unwrap();
        let back = load(&p).unwrap();
        assert_eq!(back.names, s.names);
        for ((_, a), (_, b)) in back.entries().zip(s.entries()) {
            match (a.unwrap(), b.unwrap()) {
                (Value::F(x), Value::F(y)) => assert_eq!(x, y),
                (Value::I(x), Value::I(y)) => assert_eq!(x, y),
                _ => panic!("dtype changed"),
            }
        }
    }

    #[test]
    fn rejects_corrupt_magic() {
        let dir = std::env::temp_dir().join("dpq_ckpt_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.ckpt");
        std::fs::write(&p, b"NOPE....").unwrap();
        assert!(load(&p).is_err());
    }
}
