//! The L3 coordinator: owns the training loop over AOT train-step
//! executables, the task-specific data generators, BLEU/PPL/accuracy
//! evaluation, checkpointing, K/D sweep running and the experiment
//! registry that regenerates every table and figure of the paper.

pub mod checkpoint;
pub mod experiments;
pub mod report;
pub mod trainer;

pub use trainer::{TaskGen, TrainOutcome, Trainer};
