//! Training-loop driver: given a RunConfig and the artifact family prefix,
//! run init -> N train steps (fresh synthetic batches each step -- the
//! synthetic sources are infinite streams, so per-step training loss on an
//! unseen batch doubles as held-out loss), with periodic logging, metric
//! history, codebook export (Fig. 6) and checkpointing.

use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::config::RunConfig;
use crate::data::{batcher, synth};
use crate::metrics;
use crate::runtime::{self, Artifact, Runtime, State, Value};
use crate::tensor::{TensorF, TensorI};
use crate::util::Rng;

/// Task-specific synthetic batch source, dispatched on manifest meta.
pub enum TaskGen {
    Lm { src: synth::MarkovLm, batch: usize, seq: usize },
    Nmt {
        src: synth::SynthNmt,
        batch: usize,
        src_len: usize,
        tgt_len: usize,
        /// kept from the last batch for BLEU scoring
        last_refs: Vec<Vec<i32>>,
        last_srcs: Vec<Vec<i32>>,
    },
    TextC { src: synth::SynthTextC, batch: usize, seq: usize, rng: Rng },
    Mlm { src: synth::SynthMlm, batch: usize, seq: usize, rng: Rng },
    /// BERT fine-tune probe: label = first content token in lower half of
    /// the vocabulary (purely lexical -> learnable through the embedding).
    Probe { src: synth::SynthMlm, batch: usize, seq: usize },
    /// Shu'17 stage-2 code learning: random rows of a fixed target table.
    CodeLearn { table: TensorF, batch: usize, rng: Rng },
}

impl TaskGen {
    /// Build from an artifact manifest (task/vocab/shape metadata).
    ///
    /// The *structure* of each synthetic dataset (Markov successor table,
    /// NMT lexical mapping, topic slices) is seeded from the dataset name
    /// alone, so training / evaluation / BLEU scoring always see the same
    /// underlying "language"; `seed` only varies the sampled stream.
    pub fn from_manifest(m: &runtime::Manifest, seed: u64) -> Result<TaskGen> {
        let task = m.meta_str("task").ok_or_else(|| anyhow!("meta.task"))?;
        let vocab = m.meta_usize("vocab").unwrap_or(0);
        let batch = m.meta_usize("batch").unwrap_or(16);
        let dataset = m.meta_str("dataset").unwrap_or("");
        let structure = crate::util::fnv1a64(dataset);
        Ok(match task {
            "lm" => TaskGen::Lm {
                src: synth::MarkovLm::with_stream(vocab, structure, seed),
                batch,
                seq: m.meta_usize("seq").ok_or_else(|| anyhow!("meta.seq"))?,
            },
            "nmt" => TaskGen::Nmt {
                src: synth::SynthNmt::with_stream(
                    vocab,
                    m.meta_usize("tgt_vocab").unwrap_or(vocab),
                    structure,
                    seed,
                ),
                batch,
                src_len: m.meta_usize("src_len").unwrap(),
                tgt_len: m.meta_usize("tgt_len").unwrap(),
                last_refs: vec![],
                last_srcs: vec![],
            },
            // class slices are structural by construction; only sampling
            // uses the stream seed.
            "textc" => TaskGen::TextC {
                src: synth::SynthTextC::new(
                    vocab,
                    m.meta_usize("classes").unwrap(),
                    seed,
                ),
                batch,
                seq: m.meta_usize("seq").unwrap(),
                rng: Rng::new(seed ^ 0x17),
            },
            "bert" => TaskGen::Mlm {
                src: synth::SynthMlm::with_stream(vocab, structure, seed),
                batch,
                seq: m.meta_usize("seq").unwrap(),
                rng: Rng::new(seed ^ 0x23),
            },
            other => bail!("unknown task {other}"),
        })
    }

    /// Produce the positional batch inputs the train artifact expects.
    pub fn next_batch(&mut self) -> Vec<Value> {
        match self {
            TaskGen::Lm { src, batch, seq } => {
                let b = batcher::lm_batch(src, *batch, *seq);
                vec![Value::I(b.x), Value::I(b.y)]
            }
            TaskGen::Nmt { src, batch, src_len, tgt_len, last_refs, last_srcs } => {
                let b = batcher::nmt_batch(src, *batch, *src_len, *tgt_len);
                *last_refs = b.refs;
                *last_srcs = b.srcs;
                vec![Value::I(b.src), Value::I(b.tgt_in), Value::I(b.tgt_out)]
            }
            TaskGen::TextC { src, batch, seq, rng } => {
                let b = batcher::class_batch(src, *batch, *seq, rng);
                vec![Value::I(b.x), Value::I(b.y)]
            }
            TaskGen::Mlm { src, batch, seq, rng } => {
                let b = batcher::mlm_batch(src, *batch, *seq, 0.2, rng);
                vec![Value::I(b.x), Value::I(b.y), Value::I(b.w)]
            }
            TaskGen::Probe { src, batch, seq } => {
                let half = (src.lm.vocab / 2) as i32;
                let mut xs = Vec::with_capacity(*batch * *seq);
                let mut ys = Vec::with_capacity(*batch);
                for _ in 0..*batch {
                    let s = src.sentence(*seq);
                    ys.push(if s[1] < half { 0 } else { 1 });
                    xs.extend(s);
                }
                vec![
                    Value::I(TensorI::new(vec![*batch, *seq], xs).unwrap()),
                    Value::I(TensorI::new(vec![*batch], ys).unwrap()),
                ]
            }
            TaskGen::CodeLearn { table, batch, rng } => {
                let n = table.rows();
                let d = table.cols();
                let ids: Vec<i32> =
                    (0..*batch).map(|_| rng.below(n) as i32).collect();
                let mut rows = Vec::with_capacity(*batch * d);
                for &i in &ids {
                    rows.extend_from_slice(table.row(i as usize));
                }
                vec![
                    Value::I(TensorI::new(vec![*batch], ids).unwrap()),
                    Value::F(TensorF::new(vec![*batch, d], rows).unwrap()),
                ]
            }
        }
    }
}

/// Result of a training run.
pub struct TrainOutcome {
    /// Final model state (the executable's state literals).
    pub state: State,
    /// per-logged-step history: (step, metric values)
    pub history: Vec<(usize, Vec<f32>)>,
    /// mean metrics over the final `eval_batches` fresh batches (pre-update
    /// loss on unseen data = held-out metric)
    pub final_metrics: Vec<f32>,
    /// Names aligned with `final_metrics` / `history` columns.
    pub metric_names: Vec<String>,
    /// Sustained training throughput.
    pub steps_per_sec: f64,
    /// codebook snapshots if export_every > 0: (step, codes)
    pub code_snapshots: Vec<(usize, TensorI)>,
}

impl TrainOutcome {
    /// Final held-out value of the named metric, if produced.
    pub fn metric(&self, name: &str) -> Option<f32> {
        self.metric_names
            .iter()
            .position(|n| n == name)
            .map(|i| self.final_metrics[i])
    }

    /// Perplexity derived from the `ce` metric, if produced.
    pub fn ppl(&self) -> Option<f64> {
        self.metric("ce").map(|ce| metrics::perplexity(ce as f64))
    }
}

/// The training coordinator for one artifact family.
pub struct Trainer<'rt> {
    /// Artifact runtime to execute against.
    pub rt: &'rt Runtime,
    /// Run configuration (steps, lr schedule, seeds, dirs).
    pub cfg: RunConfig,
    /// extra constant inputs appended after the generated batch (before
    /// lr), e.g. the distillation target table or frozen codes.
    pub extra_inputs: Vec<Value>,
    /// Suppress per-log-step printing.
    pub quiet: bool,
}

impl<'rt> Trainer<'rt> {
    /// Trainer with no extra inputs, printing enabled.
    pub fn new(rt: &'rt Runtime, cfg: RunConfig) -> Self {
        Trainer { rt, cfg, extra_inputs: vec![], quiet: false }
    }

    /// Attach extra constant inputs (builder style).
    pub fn with_extra(mut self, extra: Vec<Value>) -> Self {
        self.extra_inputs = extra;
        self
    }

    /// Silence per-step logging (builder style).
    pub fn quiet(mut self) -> Self {
        self.quiet = true;
        self
    }

    /// Run the configured number of steps; returns the outcome.
    pub fn run(&self) -> Result<TrainOutcome> {
        let prefix = &self.cfg.artifact;
        let init = self.rt.load(&format!("{prefix}_init"))?;
        let train = self.rt.load(&format!("{prefix}_train"))?;
        let export = if self.cfg.export_every > 0 {
            Some(self.rt.load(&format!("{prefix}_export"))?)
        } else {
            None
        };
        let mut state = runtime::run_init(&init, self.cfg.seed as i32)?;
        let mut gen = TaskGen::from_manifest(&train.manifest, self.cfg.seed)?;
        self.run_with(&train, export.as_deref(), &mut state, &mut gen)
    }

    /// Run with an externally-prepared state and generator (used by the
    /// multi-stage baselines: distillation, Shu'17, fine-tuning).
    pub fn run_with(
        &self,
        train: &Artifact,
        export: Option<&Artifact>,
        state: &mut State,
        gen: &mut TaskGen,
    ) -> Result<TrainOutcome> {
        let metric_names = train.manifest.metric_names();
        let mut history = Vec::new();
        let mut code_snapshots = Vec::new();
        let t0 = Instant::now();
        let mut window: Vec<Vec<f32>> = Vec::new();
        for step in 0..self.cfg.steps {
            let mut batch = gen.next_batch();
            batch.extend(self.extra_inputs.iter().cloned());
            let lr = self.cfg.lr.at(step);
            let out = runtime::run_train(train, state, &batch, lr)?;
            window.push(out.metrics.clone());
            if window.len() > self.cfg.eval_batches.max(1) {
                window.remove(0);
            }
            if step % self.cfg.log_every.max(1) == 0
                || step + 1 == self.cfg.steps
            {
                history.push((step, out.metrics.clone()));
                if !self.quiet {
                    let ms: Vec<String> = metric_names
                        .iter()
                        .zip(&out.metrics)
                        .map(|(n, v)| format!("{n}={v:.4}"))
                        .collect();
                    eprintln!("[{}] step {:>5} lr={:.3} {}",
                              self.cfg.artifact, step, lr, ms.join(" "));
                }
            }
            if let Some(exp) = export {
                if self.cfg.export_every > 0
                    && (step % self.cfg.export_every == 0
                        || step + 1 == self.cfg.steps)
                {
                    let out = runtime::run_aux(exp, state, &[])?;
                    code_snapshots.push((step, out[0].as_i()?.clone()));
                }
            }
            if let (Some(dir), true) = (
                self.cfg.checkpoint_dir.as_ref(),
                self.cfg.checkpoint_every > 0
                    && step > 0
                    && step % self.cfg.checkpoint_every.max(1) == 0,
            ) {
                checkpoint_now(dir, &self.cfg.artifact, step, state)?;
            }
        }
        let elapsed = t0.elapsed().as_secs_f64();
        // mean of the trailing window = held-out metric (fresh batches)
        let k = window.len().max(1);
        let final_metrics = (0..metric_names.len())
            .map(|i| window.iter().map(|m| m[i]).sum::<f32>() / k as f32)
            .collect();
        Ok(TrainOutcome {
            state: state.clone(),
            history,
            final_metrics,
            metric_names,
            steps_per_sec: self.cfg.steps as f64 / elapsed.max(1e-9),
            code_snapshots,
        })
    }

    /// Greedy-decode BLEU for an NMT family: decode fresh batches and
    /// score against the generator's references.
    pub fn bleu(&self, state: &State, batches: usize) -> Result<f64> {
        let prefix = &self.cfg.artifact;
        let decode = self.rt.load(&format!("{prefix}_decode"))?;
        let train = self.rt.load(&format!("{prefix}_train"))?;
        let mut gen = TaskGen::from_manifest(&train.manifest,
                                             self.cfg.seed ^ 0x5EED)?;
        bleu_with(&decode, state, &mut gen, batches)
    }
}

/// Decode + BLEU against generator references (shared with experiments
/// that hold a decode artifact directly, e.g. the post-hoc PQ rows of
/// Table 8 which swap the embedding table inside `state`).
pub fn bleu_with(decode: &Artifact, state: &State, gen: &mut TaskGen,
                 batches: usize) -> Result<f64> {
    let mut pairs = Vec::new();
    for _ in 0..batches {
        let b = gen.next_batch(); // fills last_refs/last_srcs
        let src = b[0].clone();
        let (refs, _) = match gen {
            TaskGen::Nmt { last_refs, last_srcs, .. } => (last_refs.clone(), last_srcs.clone()),
            _ => bail!("bleu_with requires an NMT generator"),
        };
        let out = runtime::run_aux(decode, state, &[src])?;
        let hyp = out[0].as_i()?;
        if std::env::var("DPQ_DEBUG_DECODE").is_ok() && pairs.is_empty() {
            for r in 0..3.min(refs.len()) {
                eprintln!("ref[{r}]: {:?}", &refs[r]);
                eprintln!("hyp[{r}]: {:?}", hyp.row(r));
            }
        }
        for (r, rf) in refs.iter().enumerate() {
            pairs.push((metrics::trim_hyp(hyp.row(r)), rf.clone()));
        }
    }
    Ok(metrics::corpus_bleu(&pairs))
}

fn checkpoint_now(dir: &std::path::Path, artifact: &str, step: usize,
                  state: &State) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    let path = dir.join(format!("{artifact}_step{step}.ckpt"));
    super::checkpoint::save(&path, state)
}
