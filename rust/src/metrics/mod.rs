//! Task metrics: perplexity, corpus BLEU (n-gram precision + brevity
//! penalty, the standard BLEU-4 of the NMT literature), accuracy, and a
//! small latency-statistics helper for the benchmarks.

use std::collections::HashMap;

use crate::data::{EOS, PAD};

/// Perplexity from mean cross-entropy (nats).
pub fn perplexity(ce: f64) -> f64 {
    ce.exp()
}

/// Truncate a hypothesis at the first EOS and drop padding.
pub fn trim_hyp(ids: &[i32]) -> Vec<i32> {
    let mut out = Vec::new();
    for &t in ids {
        if t == EOS {
            break;
        }
        if t != PAD {
            out.push(t);
        }
    }
    out
}

fn ngram_counts(seq: &[i32], n: usize) -> HashMap<&[i32], usize> {
    let mut m = HashMap::new();
    if seq.len() >= n {
        for w in seq.windows(n) {
            *m.entry(w).or_insert(0) += 1;
        }
    }
    m
}

/// Corpus-level BLEU-4 with +0 smoothing on counts but standard brevity
/// penalty; returns 0..100. `pairs` = (hypothesis, reference).
pub fn corpus_bleu(pairs: &[(Vec<i32>, Vec<i32>)]) -> f64 {
    let max_n = 4;
    let mut match_n = vec![0usize; max_n];
    let mut total_n = vec![0usize; max_n];
    let (mut hyp_len, mut ref_len) = (0usize, 0usize);
    for (hyp, rf) in pairs {
        hyp_len += hyp.len();
        ref_len += rf.len();
        for n in 1..=max_n {
            let h = ngram_counts(hyp, n);
            let r = ngram_counts(rf, n);
            for (g, &c) in &h {
                let rc = *r.get(g).unwrap_or(&0);
                match_n[n - 1] += c.min(&rc + 0).min(rc);
                total_n[n - 1] += c;
            }
        }
    }
    if hyp_len == 0 || match_n[0] == 0 {
        // no unigram overlap at all: BLEU is 0 (avoid smoothed inflation)
        return 0.0;
    }
    // geometric mean of clipped precisions; zero any order -> BLEU 0
    let mut logsum = 0.0;
    for n in 0..max_n {
        if total_n[n] == 0 || match_n[n] == 0 {
            // smooth very short corpora: count an epsilon match
            let p = 1.0 / (2.0 * total_n[n].max(1) as f64);
            logsum += p.ln();
        } else {
            logsum += (match_n[n] as f64 / total_n[n] as f64).ln();
        }
    }
    let geo = (logsum / max_n as f64).exp();
    let bp = if hyp_len > ref_len {
        1.0
    } else {
        (1.0 - ref_len as f64 / hyp_len as f64).exp()
    };
    100.0 * bp * geo
}

/// Classification accuracy.
pub fn accuracy(pred: &[i32], truth: &[i32]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    if pred.is_empty() {
        return 0.0;
    }
    pred.iter().zip(truth).filter(|(a, b)| a == b).count() as f64
        / pred.len() as f64
}

/// Online latency statistics for benches (mean / p50 / p99 in seconds).
#[derive(Default, Clone)]
pub struct LatencyStats {
    samples: Vec<f64>,
}

impl LatencyStats {
    /// Record one sample (seconds).
    pub fn record(&mut self, seconds: f64) {
        self.samples.push(seconds);
    }

    /// Merge another stats object's samples into this one.
    pub fn merge(&mut self, other: &LatencyStats) {
        self.samples.extend_from_slice(&other.samples);
    }

    /// Number of recorded samples.
    pub fn count(&self) -> usize {
        self.samples.len()
    }

    /// Mean of the samples in seconds (0.0 when empty).
    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    /// The `p`-th percentile (0..=100) in seconds (0.0 when empty).
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut v = self.samples.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
        v[idx]
    }

    /// One-line human summary; `unit_per_sec` scales the throughput
    /// figure (e.g. ids per request).
    pub fn summary(&self, unit_per_sec: f64) -> String {
        format!(
            "n={} mean={:.3}ms p50={:.3}ms p99={:.3}ms thpt={:.1}/s",
            self.count(),
            self.mean() * 1e3,
            self.percentile(50.0) * 1e3,
            self.percentile(99.0) * 1e3,
            unit_per_sec / self.mean().max(1e-12)
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perplexity_of_uniform() {
        let v = 100.0f64;
        assert!((perplexity(v.ln()) - 100.0).abs() < 1e-6);
    }

    #[test]
    fn bleu_perfect_match_is_100() {
        let pairs = vec![
            (vec![5, 6, 7, 8, 9], vec![5, 6, 7, 8, 9]),
            (vec![10, 11, 12, 13, 14, 15], vec![10, 11, 12, 13, 14, 15]),
        ];
        assert!((corpus_bleu(&pairs) - 100.0).abs() < 1e-6);
    }

    #[test]
    fn bleu_no_overlap_is_near_zero() {
        let pairs = vec![(vec![5, 6, 7, 8], vec![9, 10, 11, 12])];
        assert!(corpus_bleu(&pairs) < 5.0);
    }

    #[test]
    fn bleu_partial_between() {
        let perfect = vec![(vec![5, 6, 7, 8, 9, 10], vec![5, 6, 7, 8, 9, 10])];
        let partial = vec![(vec![5, 6, 7, 99, 98, 97], vec![5, 6, 7, 8, 9, 10])];
        let b = corpus_bleu(&partial);
        assert!(b > 0.0 && b < corpus_bleu(&perfect));
    }

    #[test]
    fn bleu_brevity_penalty_applies() {
        let short = vec![(vec![5, 6, 7], vec![5, 6, 7, 8, 9, 10, 11, 12])];
        let full = vec![(vec![5, 6, 7, 8, 9, 10, 11, 12],
                         vec![5, 6, 7, 8, 9, 10, 11, 12])];
        assert!(corpus_bleu(&short) < corpus_bleu(&full) * 0.6);
    }

    #[test]
    fn bleu_known_value_hand_computed() {
        // hyp: a b c d ; ref: a b c e
        // p1 = 3/4, p2 = 2/3, p3 = 1/2, p4 -> smoothed 1/(2*1)
        let pairs = vec![(vec![10, 11, 12, 13], vec![10, 11, 12, 14])];
        let want = 100.0
            * ((0.75f64.ln() + (2.0 / 3.0f64).ln() + 0.5f64.ln()
                + 0.5f64.ln())
                / 4.0)
                .exp();
        assert!((corpus_bleu(&pairs) - want).abs() < 1e-9);
    }

    #[test]
    fn trim_hyp_cuts_eos_and_pad() {
        assert_eq!(trim_hyp(&[5, 6, EOS, 7, 8]), vec![5, 6]);
        assert_eq!(trim_hyp(&[PAD, 5, PAD, 6]), vec![5, 6]);
    }

    #[test]
    fn accuracy_basic() {
        assert_eq!(accuracy(&[1, 2, 3, 4], &[1, 2, 0, 4]), 0.75);
        assert_eq!(accuracy(&[], &[]), 0.0);
    }

    #[test]
    fn latency_stats_percentiles() {
        let mut s = LatencyStats::default();
        for i in 1..=100 {
            s.record(i as f64 / 1000.0);
        }
        assert!((s.percentile(50.0) - 0.0505).abs() < 0.002);
        assert!(s.percentile(99.0) >= 0.099);
        assert!((s.mean() - 0.0505).abs() < 1e-9);
    }
}
