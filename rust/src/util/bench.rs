//! Micro-benchmark harness (the offline vendor set has no criterion):
//! warmup + timed iterations with mean / stddev / throughput reporting.
//! `cargo bench` targets (rust/benches/*) are plain mains built on this.
//!
//! Machine-readable trail: a bench main calls [`init`] once and every
//! measurement is ALSO appended as one JSON object per line to
//! `BENCH_<name>.json` in the working directory (append, never truncate,
//! so the perf trajectory across PRs accumulates). Ad-hoc numbers (e.g.
//! whole-run throughput) can be appended with [`record`].

use std::io::Write as _;
use std::path::PathBuf;
use std::sync::OnceLock;
use std::time::Instant;

use crate::jsonx::Json;
use crate::util::pool;

static BENCH_FILE: OnceLock<PathBuf> = OnceLock::new();

/// Route all measurements of this process to `BENCH_<name>.json`.
pub fn init(name: &str) {
    let _ = BENCH_FILE.set(PathBuf::from(format!("BENCH_{name}.json")));
}

/// Append one measurement as a JSON line (no-op before [`init`]).
/// Records the resolved worker-pool thread count so speedups across
/// `DPQ_THREADS` settings can be compared from the file alone.
pub fn record(name: &str, mean_s: f64, stddev_s: f64, iters: usize) {
    let Some(path) = BENCH_FILE.get() else { return };
    let line = Json::obj(vec![
        ("bench", Json::str(name)),
        ("mean_s", Json::num(mean_s)),
        ("stddev_s", Json::num(stddev_s)),
        ("iters", Json::num(iters as f64)),
        ("per_sec", Json::num(1.0 / mean_s.max(1e-12))),
        ("threads", Json::num(pool::current_threads() as f64)),
    ])
    .to_string();
    let appended = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)
        .and_then(|mut f| writeln!(f, "{line}"));
    if let Err(e) = appended {
        eprintln!("bench: could not append to {path:?}: {e}");
    }
}

/// One benchmark measurement.
pub struct Measurement {
    /// Benchmark name (also the JSON-line `bench` field).
    pub name: String,
    /// Measured iterations.
    pub iters: usize,
    /// Mean seconds per iteration.
    pub mean_s: f64,
    /// Standard deviation of the per-iteration seconds.
    pub stddev_s: f64,
}

impl Measurement {
    /// Human-readable one-liner with auto-scaled units.
    pub fn report(&self) -> String {
        let (scaled, unit) = scale(self.mean_s);
        let (sd, sd_unit) = scale(self.stddev_s);
        format!(
            "{:<44} {:>10.3} {}  (+/- {:.3} {}, {} iters)",
            self.name, scaled, unit, sd, sd_unit, self.iters
        )
    }

    /// Iterations per second implied by the mean.
    pub fn per_sec(&self) -> f64 {
        1.0 / self.mean_s.max(1e-12)
    }
}

fn scale(s: f64) -> (f64, &'static str) {
    if s >= 1.0 {
        (s, "s ")
    } else if s >= 1e-3 {
        (s * 1e3, "ms")
    } else if s >= 1e-6 {
        (s * 1e6, "us")
    } else {
        (s * 1e9, "ns")
    }
}

/// Time `f` with `warmup` unmeasured and `iters` measured runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize,
                         mut f: F) -> Measurement {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let var = samples
        .iter()
        .map(|x| (x - mean) * (x - mean))
        .sum::<f64>()
        / samples.len() as f64;
    let m = Measurement {
        name: name.to_string(),
        iters,
        mean_s: mean,
        stddev_s: var.sqrt(),
    };
    println!("{}", m.report());
    record(&m.name, m.mean_s, m.stddev_s, m.iters);
    m
}

/// Section header for bench output.
pub fn section(title: &str) {
    println!("\n== {title} ==");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_positive_time() {
        let m = bench("noop-ish", 1, 5, || {
            std::hint::black_box((0..1000).sum::<usize>());
        });
        assert!(m.mean_s >= 0.0);
        assert_eq!(m.iters, 5);
        assert!(m.per_sec() > 0.0);
    }

    #[test]
    fn scale_picks_unit() {
        assert_eq!(scale(2.0).1, "s ");
        assert_eq!(scale(2e-3).1, "ms");
        assert_eq!(scale(2e-6).1, "us");
        assert_eq!(scale(2e-9).1, "ns");
    }
}
