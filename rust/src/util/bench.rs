//! Micro-benchmark harness (the offline vendor set has no criterion):
//! warmup + timed iterations with mean / stddev / throughput reporting.
//! `cargo bench` targets (rust/benches/*) are plain mains built on this.

use std::time::Instant;

/// One benchmark measurement.
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub stddev_s: f64,
}

impl Measurement {
    pub fn report(&self) -> String {
        let (scaled, unit) = scale(self.mean_s);
        let (sd, sd_unit) = scale(self.stddev_s);
        format!(
            "{:<44} {:>10.3} {}  (+/- {:.3} {}, {} iters)",
            self.name, scaled, unit, sd, sd_unit, self.iters
        )
    }

    pub fn per_sec(&self) -> f64 {
        1.0 / self.mean_s.max(1e-12)
    }
}

fn scale(s: f64) -> (f64, &'static str) {
    if s >= 1.0 {
        (s, "s ")
    } else if s >= 1e-3 {
        (s * 1e3, "ms")
    } else if s >= 1e-6 {
        (s * 1e6, "us")
    } else {
        (s * 1e9, "ns")
    }
}

/// Time `f` with `warmup` unmeasured and `iters` measured runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize,
                         mut f: F) -> Measurement {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let var = samples
        .iter()
        .map(|x| (x - mean) * (x - mean))
        .sum::<f64>()
        / samples.len() as f64;
    let m = Measurement {
        name: name.to_string(),
        iters,
        mean_s: mean,
        stddev_s: var.sqrt(),
    };
    println!("{}", m.report());
    m
}

/// Section header for bench output.
pub fn section(title: &str) {
    println!("\n== {title} ==");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_positive_time() {
        let m = bench("noop-ish", 1, 5, || {
            std::hint::black_box((0..1000).sum::<usize>());
        });
        assert!(m.mean_s >= 0.0);
        assert_eq!(m.iters, 5);
        assert!(m.per_sec() > 0.0);
    }

    #[test]
    fn scale_picks_unit() {
        assert_eq!(scale(2.0).1, "s ");
        assert_eq!(scale(2e-3).1, "ms");
        assert_eq!(scale(2e-6).1, "us");
        assert_eq!(scale(2e-9).1, "ns");
    }
}
