//! Shared parallel-compute substrate: a scoped worker pool over
//! `std::thread::scope` (no external dependencies, no persistent threads)
//! used by every hot path -- `linalg::matmul` / `kmeans`, the `quant`
//! post-hoc fitters, `dpq::reconstruct_table`, and the server's sharded
//! micro-batch reconstruction.
//!
//! # Thread-count resolution
//!
//! Highest priority first:
//! 1. [`with_threads`] scoped override (thread-local; used by tests and
//!    short sections that must pin a count),
//! 2. [`set_threads`] process-wide override (the `repro --threads N` CLI
//!    flag),
//! 3. the `DPQ_THREADS` environment variable,
//! 4. `std::thread::available_parallelism()`.
//!
//! Inside a pool worker the resolved count is always 1: nested `par_*`
//! calls degrade to the serial path instead of oversubscribing (e.g.
//! `ProductQuant::fit` parallelizes over subspaces, and each subspace's
//! k-means then runs its assignment step serially).
//!
//! # Determinism
//!
//! Chunk/range boundaries are computed from the input length and the
//! caller's chunk size -- and the usual chunk size ([`chunk_len`]) scales
//! with the thread count, so boundaries DO vary across `DPQ_THREADS`
//! settings. Bit-exactness therefore comes from a rule every kernel in
//! this crate follows: a unit's output must not depend on which chunk it
//! landed in. Concretely, (1) per-element/per-row arithmetic inside a
//! chunk is exactly the serial loop's, (2) no float reduction crosses a
//! chunk boundary -- reductions either use order-insensitive exact ops
//! (min/max) or write per-ROW partials that the caller thread folds in
//! row order. Under that rule every parallel kernel is bit-exact with
//! `DPQ_THREADS=1` and with every other thread count (enforced by
//! `rust/tests/parallel_equivalence.rs`). A kernel that folds per-CHUNK
//! float sums would break the rule -- don't write one.

use std::cell::Cell;
use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock};

static GLOBAL_THREADS: AtomicUsize = AtomicUsize::new(0); // 0 = unset

thread_local! {
    static SCOPED_THREADS: Cell<usize> = Cell::new(0); // 0 = unset
    static IN_POOL: Cell<bool> = Cell::new(false);
}

fn env_threads() -> usize {
    static ENV: OnceLock<usize> = OnceLock::new();
    *ENV.get_or_init(|| {
        std::env::var("DPQ_THREADS")
            .ok()
            .and_then(|s| s.trim().parse::<usize>().ok())
            .filter(|&n| n > 0)
            .unwrap_or_else(|| {
                std::thread::available_parallelism()
                    .map(|n| n.get())
                    .unwrap_or(1)
            })
    })
}

/// Process-wide worker count override (0 restores env/auto resolution).
pub fn set_threads(n: usize) {
    GLOBAL_THREADS.store(n, Ordering::Relaxed);
}

/// The worker count `par_*` calls on this thread would use right now.
pub fn current_threads() -> usize {
    if IN_POOL.with(|c| c.get()) {
        return 1; // no nested parallelism
    }
    let scoped = SCOPED_THREADS.with(|c| c.get());
    if scoped > 0 {
        return scoped;
    }
    let global = GLOBAL_THREADS.load(Ordering::Relaxed);
    if global > 0 {
        return global;
    }
    env_threads()
}

/// Run `f` with the worker count pinned to `n` on this thread (restored on
/// exit, panic-safe). The override is thread-local: it governs `par_*`
/// calls made by `f` itself, not by threads `f` spawns.
pub fn with_threads<R>(n: usize, f: impl FnOnce() -> R) -> R {
    struct Restore(usize);
    impl Drop for Restore {
        fn drop(&mut self) {
            SCOPED_THREADS.with(|c| c.set(self.0));
        }
    }
    let _restore = Restore(SCOPED_THREADS.with(|c| c.replace(n)));
    f()
}

/// Split `data` into consecutive `chunk_len`-element chunks (last one may
/// be shorter) and run `f(chunk_index, chunk)` across the pool. Chunk
/// boundaries are a pure function of `data.len()` and `chunk_len` -- but
/// callers usually derive `chunk_len` from [`chunk_len`](chunk_len) which
/// scales with the thread count, so `f` must follow the module's
/// determinism rule: a unit's output may not depend on which chunk it
/// lands in. Workers pull chunks from a shared queue (dynamic load
/// balance); a panicking `f` propagates.
pub fn par_chunks_mut<T, F>(data: &mut [T], chunk_len: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(chunk_len > 0, "chunk_len must be positive");
    let n_chunks = data.len().div_ceil(chunk_len);
    let workers = current_threads().min(n_chunks);
    if workers <= 1 {
        for (i, chunk) in data.chunks_mut(chunk_len).enumerate() {
            f(i, chunk);
        }
        return;
    }
    let queue = Mutex::new(data.chunks_mut(chunk_len).enumerate());
    let drain = || loop {
        let next = queue.lock().unwrap().next();
        match next {
            Some((i, chunk)) => f(i, chunk),
            None => break,
        }
    };
    std::thread::scope(|s| {
        // the caller participates in the drain instead of idling at the
        // join, so only workers-1 threads are spawned
        for _ in 1..workers {
            s.spawn(|| {
                IN_POOL.with(|c| c.set(true));
                drain();
            });
        }
        let _guard = InPoolGuard::enter();
        drain();
    });
}

/// Marks the current thread as a pool worker for a scope (restores the
/// previous flag on drop, panic-safe) -- used when the caller thread
/// itself drains the queue, so nested `par_*` calls stay serial there too.
struct InPoolGuard(bool);

impl InPoolGuard {
    fn enter() -> InPoolGuard {
        InPoolGuard(IN_POOL.with(|c| c.replace(true)))
    }
}

impl Drop for InPoolGuard {
    fn drop(&mut self) {
        IN_POOL.with(|c| c.set(self.0));
    }
}

/// Run `f(start..end)` over `0..n` in `grain`-sized index ranges across
/// the pool. Range boundaries depend only on `n` and `grain`; ranges are
/// dispensed from an atomic cursor, so sibling ranges may run in any
/// order -- `f` must only write state owned by its range. This is the
/// index-range counterpart of [`par_chunks_mut`] for callers whose output
/// is not one contiguous slice (e.g. the planned sharded multi-table
/// serving, see ROADMAP); in-repo kernels currently all use the slice
/// form.
pub fn par_ranges<F>(n: usize, grain: usize, f: F)
where
    F: Fn(Range<usize>) + Sync,
{
    let grain = grain.max(1);
    let workers = current_threads().min(n.div_ceil(grain));
    if workers <= 1 {
        let mut start = 0;
        while start < n {
            let end = (start + grain).min(n);
            f(start..end);
            start = end;
        }
        return;
    }
    let cursor = AtomicUsize::new(0);
    let drain = || loop {
        let start = cursor.fetch_add(grain, Ordering::Relaxed);
        if start >= n {
            break;
        }
        f(start..(start + grain).min(n));
    };
    std::thread::scope(|s| {
        for _ in 1..workers {
            s.spawn(|| {
                IN_POOL.with(|c| c.set(true));
                drain();
            });
        }
        let _guard = InPoolGuard::enter();
        drain();
    });
}

/// Chunk length that gives each worker a few units of `total` items
/// (dynamic balance without excessive queue traffic). Always >= 1.
pub fn chunk_len(total: usize) -> usize {
    total.div_ceil(4 * current_threads().max(1)).max(1)
}

/// Spawning a scoped worker costs on the order of 10us; below this many
/// scalar operations an extra worker costs more than it computes.
const MIN_WORK_PER_WORKER: usize = 64 * 1024;

/// Worker count worth spawning for an estimated `work` (scalar ops):
/// capped so each worker gets at least [`MIN_WORK_PER_WORKER`], and never
/// above the configured thread count. Callers wrap their `par_*` call in
/// [`with_threads`]`(workers_for(est), ..)` so a 16-row micro-batch runs
/// serially instead of paying thread spawn/join on every request.
///
/// An active [`with_threads`] pin is returned as-is: an explicit scoped
/// pin means "use exactly this many workers" (how the equivalence tests
/// force real multi-worker execution on small inputs). The global
/// `--threads` / `DPQ_THREADS` / auto resolution acts as a ceiling under
/// the heuristic instead.
pub fn workers_for(work: usize) -> usize {
    let cap = current_threads();
    if SCOPED_THREADS.with(|c| c.get()) > 0 {
        return cap; // explicit scoped pin wins over the work heuristic
    }
    (work / MIN_WORK_PER_WORKER).clamp(1, cap)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn par_chunks_mut_touches_every_element_once() {
        for threads in [1usize, 2, 7] {
            with_threads(threads, || {
                let mut v = vec![0u32; 1000];
                par_chunks_mut(&mut v, 13, |ci, chunk| {
                    for (o, x) in chunk.iter_mut().enumerate() {
                        *x += (ci * 13 + o) as u32 + 1;
                    }
                });
                for (i, &x) in v.iter().enumerate() {
                    assert_eq!(x, i as u32 + 1, "threads={threads} idx={i}");
                }
            });
        }
    }

    #[test]
    fn par_chunks_mut_empty_and_single() {
        let mut empty: Vec<u8> = Vec::new();
        par_chunks_mut(&mut empty, 4, |_, _| panic!("no chunks expected"));
        let mut one = vec![1u8];
        par_chunks_mut(&mut one, 4, |ci, c| {
            assert_eq!((ci, c.len()), (0, 1));
            c[0] = 9;
        });
        assert_eq!(one, vec![9]);
    }

    #[test]
    fn par_ranges_covers_exactly() {
        for threads in [1usize, 2, 7] {
            with_threads(threads, || {
                let hits = AtomicU64::new(0);
                let sum = AtomicU64::new(0);
                par_ranges(100, 7, |r| {
                    for i in r {
                        hits.fetch_add(1, Ordering::Relaxed);
                        sum.fetch_add(i as u64, Ordering::Relaxed);
                    }
                });
                assert_eq!(hits.load(Ordering::Relaxed), 100);
                assert_eq!(sum.load(Ordering::Relaxed), 99 * 100 / 2);
            });
        }
    }

    #[test]
    fn with_threads_restores_even_on_panic() {
        // outer pin makes the expectation immune to concurrent tests
        // touching the global override
        with_threads(2, || {
            let r = std::panic::catch_unwind(|| {
                with_threads(3, || -> () { panic!("inner") })
            });
            assert!(r.is_err());
            assert_eq!(current_threads(), 2);
        });
    }

    #[test]
    fn nested_calls_run_serial() {
        with_threads(4, || {
            let mut outer = vec![0usize; 8];
            par_chunks_mut(&mut outer, 1, |_, chunk| {
                // inside a worker the pool degrades to serial
                assert_eq!(current_threads(), 1);
                let mut inner = vec![0u8; 16];
                par_chunks_mut(&mut inner, 4, |_, c| {
                    for x in c.iter_mut() {
                        *x = 1;
                    }
                });
                chunk[0] = inner.iter().map(|&x| x as usize).sum();
            });
            assert!(outer.iter().all(|&x| x == 16));
        });
    }

    #[test]
    fn scoped_override_beats_global() {
        // scoped override is thread-local, so this cannot race with other
        // tests; only assert the resolution order, then restore.
        with_threads(5, || assert_eq!(current_threads(), 5));
    }

    #[test]
    fn workers_for_scales_with_work() {
        // an explicit scoped pin wins outright, whatever the work size
        with_threads(5, || assert_eq!(workers_for(1), 5));
        with_threads(1, || assert_eq!(workers_for(usize::MAX / 2), 1));
        // under global/env resolution the heuristic caps by work. Pin the
        // global so the expectation is stable; concurrent tests are
        // thread-count invariant, so the transient global is harmless.
        set_threads(8);
        assert_eq!(workers_for(0), 1);
        assert_eq!(workers_for(MIN_WORK_PER_WORKER - 1), 1);
        assert_eq!(workers_for(3 * MIN_WORK_PER_WORKER), 3);
        assert_eq!(workers_for(usize::MAX / 2), 8); // capped at threads
        set_threads(0);
    }
}
