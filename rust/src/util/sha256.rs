//! Dependency-free SHA-256 (FIPS 180-4), vendored-style: the artifact
//! store's content-addressing digest. Nothing crates.io is pulled in --
//! same discipline as the epoll shim. Not a general crypto library:
//! one-shot and streaming hashing of byte slices is all the artifact
//! paths need, and all this exposes.
//!
//! Digests are rendered as 64 lowercase hex characters -- the exact
//! string recorded in `manifest.json` / `spill.json` and requested by
//! the `fetch_artifact` wire op, so the wire form and the manifest form
//! can never disagree on case or length.

/// Round constants: the first 32 bits of the fractional parts of the
/// cube roots of the first 64 primes (FIPS 180-4 §4.2.2).
const K: [u32; 64] = [
    0x428a2f98, 0x71374491, 0xb5c0fbcf, 0xe9b5dba5,
    0x3956c25b, 0x59f111f1, 0x923f82a4, 0xab1c5ed5,
    0xd807aa98, 0x12835b01, 0x243185be, 0x550c7dc3,
    0x72be5d74, 0x80deb1fe, 0x9bdc06a7, 0xc19bf174,
    0xe49b69c1, 0xefbe4786, 0x0fc19dc6, 0x240ca1cc,
    0x2de92c6f, 0x4a7484aa, 0x5cb0a9dc, 0x76f988da,
    0x983e5152, 0xa831c66d, 0xb00327c8, 0xbf597fc7,
    0xc6e00bf3, 0xd5a79147, 0x06ca6351, 0x14292967,
    0x27b70a85, 0x2e1b2138, 0x4d2c6dfc, 0x53380d13,
    0x650a7354, 0x766a0abb, 0x81c2c92e, 0x92722c85,
    0xa2bfe8a1, 0xa81a664b, 0xc24b8b70, 0xc76c51a3,
    0xd192e819, 0xd6990624, 0xf40e3585, 0x106aa070,
    0x19a4c116, 0x1e376c08, 0x2748774c, 0x34b0bcb5,
    0x391c0cb3, 0x4ed8aa4a, 0x5b9cca4f, 0x682e6ff3,
    0x748f82ee, 0x78a5636f, 0x84c87814, 0x8cc70208,
    0x90befffa, 0xa4506ceb, 0xbef9a3f7, 0xc67178f2,
];

/// Initial hash state: the first 32 bits of the fractional parts of the
/// square roots of the first 8 primes (FIPS 180-4 §5.3.3).
const H0: [u32; 8] = [
    0x6a09e667, 0xbb67ae85, 0x3c6ef372, 0xa54ff53a,
    0x510e527f, 0x9b05688c, 0x1f83d9ab, 0x5be0cd19,
];

/// Streaming SHA-256 state: feed bytes with [`update`](Self::update),
/// finish with [`finalize_hex`](Self::finalize_hex). Suitable for
/// hashing artifacts in bounded windows without holding the file in
/// memory.
pub struct Sha256 {
    state: [u32; 8],
    /// Partial block carried between `update` calls.
    buf: [u8; 64],
    buf_len: usize,
    /// Total message length in bytes (the trailer encodes it in bits).
    total: u64,
}

impl Default for Sha256 {
    fn default() -> Self {
        Self::new()
    }
}

impl Sha256 {
    /// Fresh hasher (FIPS initial state).
    pub fn new() -> Sha256 {
        Sha256 { state: H0, buf: [0u8; 64], buf_len: 0, total: 0 }
    }

    /// Absorb `data`; call as many times as needed, in any chunking --
    /// the digest depends only on the byte sequence.
    pub fn update(&mut self, data: &[u8]) {
        self.total = self.total.wrapping_add(data.len() as u64);
        let mut rest = data;
        // top up a partial block first
        if self.buf_len > 0 {
            let take = rest.len().min(64 - self.buf_len);
            self.buf[self.buf_len..self.buf_len + take]
                .copy_from_slice(&rest[..take]);
            self.buf_len += take;
            rest = &rest[take..];
            if self.buf_len == 64 {
                let block = self.buf;
                self.compress(&block);
                self.buf_len = 0;
            }
        }
        // whole blocks straight from the input
        while rest.len() >= 64 {
            let (block, tail) = rest.split_at(64);
            let mut b = [0u8; 64];
            b.copy_from_slice(block);
            self.compress(&b);
            rest = tail;
        }
        // stash the tail
        if !rest.is_empty() {
            self.buf[..rest.len()].copy_from_slice(rest);
            self.buf_len = rest.len();
        }
    }

    /// Finish the message (padding + length trailer) and return the
    /// digest as 64 lowercase hex characters. Consumes the hasher --
    /// the padded state cannot absorb further bytes.
    pub fn finalize_hex(mut self) -> String {
        let bit_len = self.total.wrapping_mul(8);
        // 0x80 terminator, zero padding to 56 mod 64, then the 64-bit
        // big-endian bit length
        self.update(&[0x80]);
        while self.buf_len != 56 {
            self.update(&[0]);
        }
        // feed the trailer directly: `update` would re-count it
        self.buf[56..64].copy_from_slice(&bit_len.to_be_bytes());
        let block = self.buf;
        self.compress(&block);
        let mut hex = String::with_capacity(64);
        for w in self.state {
            for b in w.to_be_bytes() {
                hex.push(char::from_digit((b >> 4) as u32, 16).unwrap());
                hex.push(char::from_digit((b & 0xf) as u32, 16).unwrap());
            }
        }
        hex
    }

    /// One FIPS 180-4 §6.2.2 compression round over a 64-byte block.
    fn compress(&mut self, block: &[u8; 64]) {
        let mut w = [0u32; 64];
        for (i, chunk) in block.chunks_exact(4).enumerate() {
            w[i] = u32::from_be_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
        }
        for i in 16..64 {
            let s0 = w[i - 15].rotate_right(7)
                ^ w[i - 15].rotate_right(18)
                ^ (w[i - 15] >> 3);
            let s1 = w[i - 2].rotate_right(17)
                ^ w[i - 2].rotate_right(19)
                ^ (w[i - 2] >> 10);
            w[i] = w[i - 16]
                .wrapping_add(s0)
                .wrapping_add(w[i - 7])
                .wrapping_add(s1);
        }
        let [mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut h] =
            self.state;
        for i in 0..64 {
            let s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
            let ch = (e & f) ^ (!e & g);
            let t1 = h
                .wrapping_add(s1)
                .wrapping_add(ch)
                .wrapping_add(K[i])
                .wrapping_add(w[i]);
            let s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
            let maj = (a & b) ^ (a & c) ^ (b & c);
            let t2 = s0.wrapping_add(maj);
            h = g;
            g = f;
            f = e;
            e = d.wrapping_add(t1);
            d = c;
            c = b;
            b = a;
            a = t1.wrapping_add(t2);
        }
        self.state[0] = self.state[0].wrapping_add(a);
        self.state[1] = self.state[1].wrapping_add(b);
        self.state[2] = self.state[2].wrapping_add(c);
        self.state[3] = self.state[3].wrapping_add(d);
        self.state[4] = self.state[4].wrapping_add(e);
        self.state[5] = self.state[5].wrapping_add(f);
        self.state[6] = self.state[6].wrapping_add(g);
        self.state[7] = self.state[7].wrapping_add(h);
    }
}

/// One-shot digest of `data` as 64 lowercase hex characters.
pub fn hex_digest(data: &[u8]) -> String {
    let mut h = Sha256::new();
    h.update(data);
    h.finalize_hex()
}

/// True iff `s` is a well-formed digest string: exactly 64 lowercase
/// hex characters (the only form this crate ever writes or serves).
/// Uppercase is rejected -- accepting both cases would let one artifact
/// answer to two different names and break dedupe-by-name.
pub fn is_hex_digest(s: &str) -> bool {
    s.len() == 64
        && s.bytes().all(|b| b.is_ascii_digit() || (b'a'..=b'f').contains(&b))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// FIPS 180-4 / NIST CAVP vectors, plus the classic million-'a'
    /// long-message vector.
    #[test]
    fn fips_vectors() {
        assert_eq!(
            hex_digest(b""),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
        assert_eq!(
            hex_digest(b"abc"),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
        assert_eq!(
            hex_digest(b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq"),
            "248d6a61d20638b8e5c026930c3e6039a33ce45964ff2167f6ecedd419db06c1"
        );
        let million_a = vec![b'a'; 1_000_000];
        assert_eq!(
            hex_digest(&million_a),
            "cdc76e5c9914fb9281a1c7e284d73e67f1809a48a497200e046d39ccc7112cd0"
        );
    }

    /// The digest must depend only on the byte sequence, not on how the
    /// caller chunks its `update` calls -- the artifact paths hash in
    /// 64 KiB windows while tests hash one-shot.
    #[test]
    fn chunking_is_invisible() {
        let msg: Vec<u8> = (0..1000u32).flat_map(|i| i.to_le_bytes()).collect();
        let oneshot = hex_digest(&msg);
        for chunk in [1usize, 3, 63, 64, 65, 100, 4096] {
            let mut h = Sha256::new();
            for c in msg.chunks(chunk) {
                h.update(c);
            }
            assert_eq!(h.finalize_hex(), oneshot, "chunk size {chunk}");
        }
    }

    /// Boundary lengths around the 55/56-byte padding split and the
    /// 64-byte block edge (the classic off-by-one sites), pinned
    /// against a second independent property: two different messages
    /// never collide in this set.
    #[test]
    fn padding_boundaries_distinct() {
        let mut seen = std::collections::BTreeSet::new();
        for len in [0usize, 1, 54, 55, 56, 57, 63, 64, 65, 127, 128, 129] {
            let msg = vec![0xabu8; len];
            assert!(seen.insert(hex_digest(&msg)), "collision at len {len}");
        }
        // 55 bytes pads within one block; 56 forces a second block --
        // both must still be plain 64-hex strings
        assert!(is_hex_digest(&hex_digest(&[0u8; 55])));
        assert!(is_hex_digest(&hex_digest(&[0u8; 56])));
    }

    #[test]
    fn digest_string_validation() {
        let d = hex_digest(b"x");
        assert!(is_hex_digest(&d));
        assert!(!is_hex_digest(&d[..63]));            // truncated
        assert!(!is_hex_digest(&format!("{d}0")));    // too long
        assert!(!is_hex_digest(&d.to_uppercase()));   // case-sensitive
        assert!(!is_hex_digest(&format!("g{}", &d[1..]))); // non-hex
    }
}
