//! Small shared utilities: a deterministic PRNG (no external `rand` --
//! this repository builds fully offline), an in-repo property-testing
//! helper used across the test suite, a micro-benchmark harness with
//! machine-readable output ([`bench`]), the scoped worker pool that
//! powers every parallel hot path ([`pool`], thread count from
//! `DPQ_THREADS` / `repro --threads`), and a dependency-free SHA-256
//! ([`sha256`]) -- the content-addressing digest of the artifact store.

pub mod bench;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod sha256;

pub use rng::Rng;

/// FNV-1a 64-bit hash of a string: cheap, stable, dependency-free.
/// One shared implementation for every name-keyed hash in the crate
/// (deterministic data-stream seeds in the trainer, collision-proofed
/// spill-artifact file names in the registry).
pub fn fnv1a64(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64; // FNV-64 offset basis
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3); // FNV-64 prime
    }
    h
}
