//! Small shared utilities: a deterministic PRNG (no external `rand` --
//! this repository builds fully offline) and an in-repo property-testing
//! helper used across the test suite.

pub mod bench;
pub mod prop;
pub mod rng;

pub use rng::Rng;
