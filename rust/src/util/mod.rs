//! Small shared utilities: a deterministic PRNG (no external `rand` --
//! this repository builds fully offline), an in-repo property-testing
//! helper used across the test suite, a micro-benchmark harness with
//! machine-readable output ([`bench`]), and the scoped worker pool that
//! powers every parallel hot path ([`pool`], thread count from
//! `DPQ_THREADS` / `repro --threads`).

pub mod bench;
pub mod pool;
pub mod prop;
pub mod rng;

pub use rng::Rng;
