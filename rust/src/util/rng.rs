//! Deterministic xoshiro256** PRNG. The offline vendor set has no `rand`
//! crate, and determinism across runs matters for the experiment harness
//! (every table in EXPERIMENTS.md is reproducible from a seed).

/// xoshiro256** by Blackman & Vigna; seeded via SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed a generator (any u64, including 0, is fine).
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the 256-bit state.
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Independent stream derived from this one (for parallel workers).
    pub fn fork(&mut self, salt: u64) -> Rng {
        Rng::new(self.next_u64() ^ salt.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Next raw 64-bit output of the generator.
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        // Lemire's multiply-shift rejection-free approximation is fine for
        // our non-cryptographic use (n << 2^64 so bias is negligible).
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()) as f32
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut t = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// Zipf-distributed rank in [0, n) with exponent `s` (unigram shape of
    /// natural-language vocabularies; used by every synthetic corpus).
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        // Inverse-CDF on the (precomputation-free) continuous approximation,
        // clamped to the support. Good enough for corpus synthesis.
        let u = self.f64().max(1e-12);
        if (s - 1.0).abs() < 1e-9 {
            let h = (n as f64).ln();
            return (((u * h).exp() - 1.0).floor() as usize).min(n - 1);
        }
        let a = 1.0 - s;
        let h = ((n as f64).powf(a) - 1.0) / a;
        let x = (1.0 + u * h * a).powf(1.0 / a) - 1.0;
        (x.floor() as usize).min(n - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn f32_unit_interval() {
        let mut r = Rng::new(4);
        for _ in 0..1000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_mean_and_var() {
        let mut r = Rng::new(5);
        let xs: Vec<f32> = (0..20000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>()
            / xs.len() as f32;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn zipf_is_skewed_and_in_range() {
        let mut r = Rng::new(6);
        let mut counts = vec![0usize; 100];
        for _ in 0..20000 {
            let k = r.zipf(100, 1.1);
            assert!(k < 100);
            counts[k] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[0] > 20000 / 20); // head is heavy
    }

    #[test]
    fn weighted_respects_weights() {
        let mut r = Rng::new(8);
        let mut c = [0usize; 3];
        for _ in 0..30000 {
            c[r.weighted(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(c[2] > c[1] && c[1] > c[0]);
        assert!((c[2] as f64 / 30000.0 - 0.7).abs() < 0.05);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
