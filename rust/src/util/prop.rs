//! Minimal in-repo property-testing harness (the vendored crate set has no
//! `proptest`). Usage mirrors the common pattern:
//!
//! ```ignore
//! prop_check(100, |rng| {
//!     let n = 1 + rng.below(50);
//!     /* build a random case, return Err(msg) on violation */
//!     Ok(())
//! });
//! ```
//!
//! Each case is seeded deterministically from the case index, so a failure
//! message pinpoints a reproducible seed.

use super::rng::Rng;

/// Run `cases` random checks; panics with the failing seed + message.
pub fn prop_check<F>(cases: u64, mut f: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = 0xD1F9_u64.wrapping_mul(case + 1);
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!("property failed (case {case}, seed {seed}): {msg}");
        }
    }
}

/// Convenience assertion that returns Err instead of panicking, so checks
/// compose inside `prop_check` closures.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
    ($cond:expr) => {
        if !$cond {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prop_check_runs_all_cases() {
        let mut count = 0;
        prop_check(25, |_| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn prop_check_reports_failure() {
        prop_check(10, |rng| {
            let x = rng.below(10);
            if x > 5 {
                return Err(format!("x={x}"));
            }
            Ok(())
        });
    }
}
