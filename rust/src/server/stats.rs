//! Per-table serving statistics: lock-free counters plus a fixed-size
//! ring of recent batch latencies (p50/p99 exposed via the `stats` op and
//! recorded to `BENCH_server.json` by `benches/bench_server.rs`).

use std::sync::atomic::AtomicU64;
use std::sync::Mutex;

/// Ring capacity: percentiles reflect the most recent batches only, so a
/// long-lived server reports current latency, not its lifetime average.
pub const LATENCY_RING: usize = 512;

/// One table's serving statistics. Counters are relaxed atomics (exact
/// totals, no ordering requirements); the latency ring takes a short
/// mutex per drained batch -- batches are the unit of batcher work, so
/// the lock is far off the per-id hot path.
#[derive(Default)]
pub struct Stats {
    /// Lookup requests routed to this table (JSON + binary).
    pub requests: AtomicU64,
    /// Ids reconstructed for this table.
    pub ids_served: AtomicU64,
    /// Micro-batches drained by this table's batcher shards.
    pub batches: AtomicU64,
    ring: Mutex<LatRing>,
}

#[derive(Default)]
struct LatRing {
    buf: Vec<f64>,
    next: usize,
}

impl Stats {
    /// Record one drained batch's wall-clock reconstruction time.
    pub fn record_batch_secs(&self, seconds: f64) {
        let mut r = self.ring.lock().unwrap();
        if r.buf.len() < LATENCY_RING {
            r.buf.push(seconds);
        } else {
            let at = r.next;
            r.buf[at] = seconds;
        }
        r.next = (r.next + 1) % LATENCY_RING;
    }

    /// `(p50, p99)` over the latency ring, `None` before the first batch.
    pub fn batch_latency(&self) -> Option<(f64, f64)> {
        let v = {
            let r = self.ring.lock().unwrap();
            if r.buf.is_empty() {
                return None;
            }
            r.buf.clone()
        };
        let mut v = v;
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |p: f64| v[((p / 100.0) * (v.len() - 1) as f64).round() as usize];
        Some((pct(50.0), pct(99.0)))
    }

    /// Number of latency samples currently in the ring (capped at
    /// [`LATENCY_RING`]).
    pub fn latency_samples(&self) -> usize {
        self.ring.lock().unwrap().buf.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_empty_is_none() {
        assert!(Stats::default().batch_latency().is_none());
    }

    #[test]
    fn latency_percentiles_ordered() {
        let s = Stats::default();
        for i in 1..=100 {
            s.record_batch_secs(i as f64 / 1000.0);
        }
        let (p50, p99) = s.batch_latency().unwrap();
        assert!(p50 >= 0.045 && p50 <= 0.055, "p50={p50}");
        assert!(p99 >= 0.098, "p99={p99}");
        assert!(p50 <= p99);
        assert_eq!(s.latency_samples(), 100);
    }

    #[test]
    fn ring_wraps_and_forgets_old_samples() {
        let s = Stats::default();
        // fill with slow batches, then overwrite the whole ring with fast
        for _ in 0..LATENCY_RING {
            s.record_batch_secs(1.0);
        }
        for _ in 0..LATENCY_RING {
            s.record_batch_secs(0.001);
        }
        assert_eq!(s.latency_samples(), LATENCY_RING);
        let (p50, p99) = s.batch_latency().unwrap();
        assert!(p50 < 0.01 && p99 < 0.01, "ring kept stale samples: {p50} {p99}");
    }
}
