//! Per-table serving statistics: lock-free counters plus a fixed-size
//! ring of recent batch latencies (p50/p99 exposed via the `stats` op and
//! recorded to `BENCH_server.json` by `benches/bench_server.rs`).

use std::sync::atomic::AtomicU64;
use std::sync::{Mutex, MutexGuard, PoisonError};

/// Ring capacity: percentiles reflect the most recent samples only, so a
/// long-lived server reports current latency, not its lifetime average.
pub const LATENCY_RING: usize = 512;

/// A fixed-size ring of recent latency samples with p50/p99 readout.
/// One instance records batch reconstruction times per table; the
/// registry keeps another for spill-tier promote (reload) latencies.
/// Recording takes a short mutex per sample -- samples are per batch /
/// per promotion, far off the per-id hot path.
#[derive(Default)]
pub struct LatencyRing {
    inner: Mutex<LatRing>,
}

#[derive(Default)]
struct LatRing {
    buf: Vec<f64>,
    next: usize,
}

impl LatencyRing {
    /// Lock the ring, recovering from a poisoned mutex: a panic caught
    /// by the connection-plane isolation barrier may have interrupted a
    /// recording thread, and a ring of plain f64 samples is never torn
    /// -- stats must keep working after an isolated handler panic.
    fn lock(&self) -> MutexGuard<'_, LatRing> {
        self.inner.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Record one wall-clock sample in seconds.
    pub fn record(&self, seconds: f64) {
        let mut r = self.lock();
        if r.buf.len() < LATENCY_RING {
            r.buf.push(seconds);
        } else {
            let at = r.next;
            r.buf[at] = seconds;
        }
        r.next = (r.next + 1) % LATENCY_RING;
    }

    /// `(p50, p99)` over the ring, `None` before the first sample.
    pub fn percentiles(&self) -> Option<(f64, f64)> {
        let mut v = {
            let r = self.lock();
            if r.buf.is_empty() {
                return None;
            }
            r.buf.clone()
        };
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |p: f64| v[((p / 100.0) * (v.len() - 1) as f64).round() as usize];
        Some((pct(50.0), pct(99.0)))
    }

    /// Number of samples currently in the ring (capped at
    /// [`LATENCY_RING`]).
    pub fn samples(&self) -> usize {
        self.lock().buf.len()
    }
}

/// Connection-plane counters for one serving process, shared by the
/// accept loop and every connection thread and surfaced by the
/// aggregate `stats` op. All relaxed atomics: exact totals, no ordering
/// requirements.
#[derive(Default)]
pub struct ConnStats {
    /// Connections currently open (accepted and not yet closed).
    pub conns_open: AtomicU64,
    /// Connections accepted over the server's lifetime (excludes
    /// `busy`-rejected ones).
    pub conns_total: AtomicU64,
    /// Connections refused with the typed `busy` close because the
    /// server was at its `--max-conns` cap.
    pub busy_rejections: AtomicU64,
    /// Connections closed with the typed `timeout` close because a
    /// `--conn-timeout` idle or mid-frame deadline expired.
    pub conn_timeouts: AtomicU64,
    /// Handler panics caught by the per-connection isolation barrier.
    /// Each one closed only its own connection; a nonzero value means a
    /// server bug was survived, not that service degraded.
    pub handler_panics: AtomicU64,
}

/// One replica's serving statistics: its live queue depth (the signal
/// lookup routing balances on), the batches its shards have drained,
/// and its own batch-latency ring. A replicated table has one of these
/// per replica beside the table-level [`Stats`] (the merged view that
/// also rides across the spill tier); replica stats are reset by a
/// `set_replicas` resize, table stats are not.
#[derive(Default)]
pub struct ReplicaStats {
    /// Lookups routed to this replica and not yet answered. Incremented
    /// when a request is queued on the replica's shards, decremented
    /// when its answer is assembled -- so the router's "least loaded"
    /// read sees genuinely outstanding work, not lifetime totals.
    pub queue_depth: AtomicU64,
    /// Micro-batches drained by this replica's shards.
    pub batches: AtomicU64,
    ring: LatencyRing,
}

impl ReplicaStats {
    /// Record one drained batch's wall-clock time for this replica.
    pub fn record_batch_secs(&self, seconds: f64) {
        self.batches.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.ring.record(seconds);
    }

    /// `(p50, p99)` over this replica's latency ring, `None` before its
    /// first batch.
    pub fn batch_latency(&self) -> Option<(f64, f64)> {
        self.ring.percentiles()
    }
}

/// One table's serving statistics. Counters are relaxed atomics (exact
/// totals, no ordering requirements). The registry carries a table's
/// `Stats` across demote/promote cycles, so counters survive a trip
/// through the spill tier.
#[derive(Default)]
pub struct Stats {
    /// Lookup requests routed to this table (JSON + binary).
    pub requests: AtomicU64,
    /// Ids reconstructed for this table.
    pub ids_served: AtomicU64,
    /// Micro-batches drained by this table's batcher shards.
    pub batches: AtomicU64,
    /// `score` requests served over this table (compute-on-codes plane).
    pub score_requests: AtomicU64,
    /// `topk` requests served over this table.
    pub topk_requests: AtomicU64,
    /// Hot-row cache hits: rows served by memcpy from the per-table
    /// row cache instead of a code-walk reconstruction. Lives here (not
    /// on the cache) so the count survives the cache being invalidated
    /// by demote/promote/`set_replicas` -- the `Arc<Stats>` rides every
    /// residency transition.
    pub cache_hits: AtomicU64,
    /// Hot-row cache misses: rows that went through full reconstruction
    /// while the cache was enabled. Disabled caches count nothing.
    pub cache_misses: AtomicU64,
    ring: LatencyRing,
    score_ring: LatencyRing,
}

impl Stats {
    /// Record one drained batch's wall-clock reconstruction time.
    pub fn record_batch_secs(&self, seconds: f64) {
        self.ring.record(seconds);
    }

    /// `(p50, p99)` over the latency ring, `None` before the first batch.
    pub fn batch_latency(&self) -> Option<(f64, f64)> {
        self.ring.percentiles()
    }

    /// Number of latency samples currently in the ring (capped at
    /// [`LATENCY_RING`]).
    pub fn latency_samples(&self) -> usize {
        self.ring.samples()
    }

    /// Record one `score`/`topk` request's wall-clock compute time
    /// (LUT/plan build + candidate scan; excludes frame I/O).
    pub fn record_score_secs(&self, seconds: f64) {
        self.score_ring.record(seconds);
    }

    /// `(p50, p99)` over the score-latency ring, `None` before the
    /// first scoring request.
    pub fn score_latency(&self) -> Option<(f64, f64)> {
        self.score_ring.percentiles()
    }

    /// Hot-row cache hit rate over the table's lifetime, `None` before
    /// the first cache-enabled lookup (hits + misses == 0). The two
    /// counters are snapshotted once each and summed saturating: they
    /// are independently updated u64s, so an unchecked `h + m` could
    /// overflow (a debug-build panic) on a very long-lived server.
    pub fn cache_hit_rate(&self) -> Option<f64> {
        let h = self.cache_hits.load(std::sync::atomic::Ordering::Relaxed);
        let m = self.cache_misses.load(std::sync::atomic::Ordering::Relaxed);
        let total = h.saturating_add(m);
        if total == 0 {
            None
        } else {
            Some(h as f64 / total as f64)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_empty_is_none() {
        assert!(Stats::default().batch_latency().is_none());
    }

    #[test]
    fn latency_percentiles_ordered() {
        let s = Stats::default();
        for i in 1..=100 {
            s.record_batch_secs(i as f64 / 1000.0);
        }
        let (p50, p99) = s.batch_latency().unwrap();
        assert!(p50 >= 0.045 && p50 <= 0.055, "p50={p50}");
        assert!(p99 >= 0.098, "p99={p99}");
        assert!(p50 <= p99);
        assert_eq!(s.latency_samples(), 100);
    }

    #[test]
    fn cache_hit_rate_survives_saturated_counters() {
        use std::sync::atomic::Ordering::Relaxed;
        let s = Stats::default();
        assert!(s.cache_hit_rate().is_none());
        s.cache_hits.store(3, Relaxed);
        s.cache_misses.store(1, Relaxed);
        assert_eq!(s.cache_hit_rate(), Some(0.75));
        // the old unchecked `h + m` panicked (debug) or wrapped here
        s.cache_hits.store(u64::MAX, Relaxed);
        s.cache_misses.store(u64::MAX, Relaxed);
        let r = s.cache_hit_rate().unwrap();
        assert!(r.is_finite() && r > 0.0 && r <= 1.0, "rate={r}");
    }

    #[test]
    fn ring_wraps_and_forgets_old_samples() {
        let s = Stats::default();
        // fill with slow batches, then overwrite the whole ring with fast
        for _ in 0..LATENCY_RING {
            s.record_batch_secs(1.0);
        }
        for _ in 0..LATENCY_RING {
            s.record_batch_secs(0.001);
        }
        assert_eq!(s.latency_samples(), LATENCY_RING);
        let (p50, p99) = s.batch_latency().unwrap();
        assert!(p50 < 0.01 && p99 < 0.01, "ring kept stale samples: {p50} {p99}");
    }
}
