//! [`TableRegistry`]: named [`EmbeddingBackend`] tables with hot
//! `load`/`unload`/`list` admin ops, per-table [`Stats`], and per-table
//! batcher shards.
//!
//! # Sharding
//!
//! Every table owns `shards_per_table` batcher shards; shard `s` of a
//! table with vocab `n` serves the id range `[s*n/S, (s+1)*n/S)`. A
//! request's ids are split by range, each sub-list queued on its shard,
//! and the handler stitches the shard answers back in id order -- so two
//! hot tables (or two halves of one huge vocab) never serialize behind
//! one batcher thread. Each shard reconstructs its micro-batch through
//! the shared worker pool (`util::pool`); row gathers are bit-identical
//! for every shard count and thread count, so sharding is invisible in
//! the served bytes. With one shard per table (the default) the answer
//! is a zero-copy view of the batch buffer, exactly the PR-1 fast path.
//!
//! # Lifecycle
//!
//! `insert`/`load_dpq` spawn the table's shard threads immediately;
//! `unload` closes the shard queues (failing any queued lookups, typed)
//! and joins the threads. Dropping the registry shuts everything down.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::backend::{self, EmbeddingBackend};
use crate::dpq::CompressedEmbedding;
use crate::jsonx::Json;
use crate::server::batcher::{run_batch, Answer, BatchQueue, Pending};
use crate::server::protocol::WireError;
use crate::server::stats::Stats;

/// Serving knobs shared by every table in a registry.
#[derive(Clone, Copy, Debug)]
pub struct ServerConfig {
    /// Max pending lookups drained into one micro-batch per shard.
    pub max_batch: usize,
    /// Batcher shards per table; the id space is range-partitioned
    /// across them. 1 keeps the single-queue zero-copy fast path.
    pub shards_per_table: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { max_batch: 64, shards_per_table: 1 }
    }
}

/// One served table: backend + stats + its batcher shards.
pub struct TableEntry {
    pub name: String,
    pub backend: Arc<dyn EmbeddingBackend>,
    pub stats: Arc<Stats>,
    shards: Vec<Arc<BatchQueue>>,
    handles: Mutex<Vec<JoinHandle<()>>>,
}

impl TableEntry {
    fn spawn(
        name: &str,
        backend: Arc<dyn EmbeddingBackend>,
        cfg: &ServerConfig,
        stop: &Arc<AtomicBool>,
    ) -> Arc<TableEntry> {
        let stats = Arc::new(Stats::default());
        let shards: Vec<Arc<BatchQueue>> = (0..cfg.shards_per_table.max(1))
            .map(|_| Arc::new(BatchQueue::new(cfg.max_batch)))
            .collect();
        let handles = shards
            .iter()
            .map(|shard| {
                let backend = backend.clone();
                let shard = shard.clone();
                let stats = stats.clone();
                let stop = stop.clone();
                std::thread::spawn(move || {
                    while !stop.load(Ordering::Relaxed) && !shard.is_closed() {
                        let batch = shard.pop_batch(Duration::from_millis(20));
                        if batch.is_empty() {
                            continue;
                        }
                        run_batch(&*backend, &batch, &stats);
                    }
                    // close() fails anything still queued; calling it from
                    // the exiting thread covers the global-stop path too
                    shard.close();
                })
            })
            .collect();
        Arc::new(TableEntry {
            name: name.to_string(),
            backend,
            stats,
            shards,
            handles: Mutex::new(handles),
        })
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Shard owning `id` under range partitioning.
    fn shard_of(&self, id: usize, vocab: usize) -> usize {
        debug_assert!(id < vocab);
        ((id as u128 * self.shards.len() as u128) / vocab as u128) as usize
    }

    /// Route one validated id list through this table's shards and
    /// assemble the answer in id order. `None` means the batcher failed
    /// the request (table unloading / server bug path); callers turn it
    /// into a typed error. Ids MUST already be validated `< vocab`.
    pub(crate) fn lookup(&self, ids: &[usize]) -> Option<Answer> {
        self.stats.requests.fetch_add(1, Ordering::Relaxed);
        let d = self.backend.d();
        if ids.is_empty() {
            return Some(Answer::Owned(Vec::new()));
        }
        let n_shards = self.shards.len();
        if n_shards == 1 {
            let (p, done) = Pending::new(ids.to_vec());
            self.shards[0].push(p);
            let rows = crate::server::batcher::wait_rows(&done);
            if rows.as_slice().len() != ids.len() * d {
                return None;
            }
            return Some(Answer::View(rows));
        }
        let vocab = self.backend.vocab();
        // split ids by owning shard, remembering each id's original slot
        let mut positions: Vec<Vec<usize>> = vec![Vec::new(); n_shards];
        let mut sub_ids: Vec<Vec<usize>> = vec![Vec::new(); n_shards];
        for (pos, &id) in ids.iter().enumerate() {
            let s = self.shard_of(id, vocab);
            positions[s].push(pos);
            sub_ids[s].push(id);
        }
        // all ids on one shard: keep the zero-copy fast path (positions
        // are in request order, so the shard's view IS the answer)
        if let Some(only) = (0..n_shards).find(|&s| sub_ids[s].len() == ids.len()) {
            let (p, done) = Pending::new(std::mem::take(&mut sub_ids[only]));
            self.shards[only].push(p);
            let rows = crate::server::batcher::wait_rows(&done);
            if rows.as_slice().len() != ids.len() * d {
                return None;
            }
            return Some(Answer::View(rows));
        }
        // enqueue every non-empty sub-lookup BEFORE waiting on any, so
        // the shards reconstruct concurrently
        let mut waits = Vec::new();
        for s in 0..n_shards {
            if sub_ids[s].is_empty() {
                continue;
            }
            let (p, done) = Pending::new(std::mem::take(&mut sub_ids[s]));
            let n_sub = p.ids.len();
            self.shards[s].push(p);
            waits.push((s, n_sub, done));
        }
        let mut flat = vec![0.0f32; ids.len() * d];
        let mut failed = false;
        for (s, n_sub, done) in waits {
            let rows = crate::server::batcher::wait_rows(&done);
            let got = rows.as_slice();
            if got.len() != n_sub * d {
                failed = true;
                continue; // keep draining the other shards' slots
            }
            for (k, &pos) in positions[s].iter().enumerate() {
                flat[pos * d..(pos + 1) * d]
                    .copy_from_slice(&got[k * d..(k + 1) * d]);
            }
        }
        if failed { None } else { Some(Answer::Owned(flat)) }
    }

    /// Close this table's shards and join their threads (idempotent).
    fn stop(&self) {
        for shard in &self.shards {
            shard.close();
        }
        let handles: Vec<JoinHandle<()>> =
            std::mem::take(&mut *self.handles.lock().unwrap());
        for h in handles {
            let _ = h.join();
        }
    }

    /// JSON description used by the `tables` / `load` responses.
    pub fn desc_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.as_str())),
            ("kind", Json::str(self.backend.kind())),
            ("vocab", Json::num(self.backend.vocab() as f64)),
            ("d", Json::num(self.backend.d() as f64)),
            ("storage_bits", Json::num(self.backend.storage_bits() as f64)),
            ("compression_ratio",
             Json::num(backend::compression_ratio(&*self.backend))),
            ("shards", Json::num(self.shards.len() as f64)),
        ])
    }
}

/// Named tables behind one server: lookup routing, default-table
/// resolution for v1 frames, and hot admin ops.
pub struct TableRegistry {
    cfg: ServerConfig,
    tables: RwLock<BTreeMap<String, Arc<TableEntry>>>,
    default: Mutex<Option<String>>,
    stop: Arc<AtomicBool>,
}

impl TableRegistry {
    pub fn new(cfg: ServerConfig) -> Self {
        TableRegistry {
            cfg,
            tables: RwLock::new(BTreeMap::new()),
            default: Mutex::new(None),
            stop: Arc::new(AtomicBool::new(false)),
        }
    }

    /// The flag the accept loop and every batcher shard watch.
    pub fn stop_flag(&self) -> Arc<AtomicBool> {
        self.stop.clone()
    }

    /// Register `backend` as table `name` and start its batcher shards.
    /// The first inserted table becomes the default (v1 frames route to
    /// it) until [`set_default`](Self::set_default) says otherwise.
    pub fn insert(
        &self,
        name: &str,
        backend: Arc<dyn EmbeddingBackend>,
    ) -> Result<Arc<TableEntry>, WireError> {
        if name.is_empty() || name.contains('=') {
            return Err(WireError::Rejected {
                code: "bad_table_name".into(),
                message: format!("invalid table name {name:?}"),
            });
        }
        // A zero-width or zero-vocab table could never serve a lookup,
        // and d == 0 would additionally make the batcher's failure view
        // (an empty slice) indistinguishable from a successful answer --
        // the typed-failure guarantee depends on d >= 1.
        if backend.d() == 0 || backend.vocab() == 0 {
            return Err(WireError::Rejected {
                code: "bad_table".into(),
                message: format!(
                    "table {name:?} has degenerate shape [{}, {}]",
                    backend.vocab(), backend.d()),
            });
        }
        if self.stop.load(Ordering::Relaxed) {
            return Err(WireError::Rejected {
                code: "shutting_down".into(),
                message: "registry is shutting down".into(),
            });
        }
        // Default election happens INSIDE the tables write lock (same
        // lock order as `unload`: tables, then default) -- electing it
        // after releasing the lock could race an `unload` of this very
        // table and leave `default` naming a table that no longer
        // exists, permanently breaking v1 routing.
        let entry = {
            let mut map = self.tables.write().unwrap();
            if map.contains_key(name) {
                return Err(WireError::TableExists(name.to_string()));
            }
            let entry = TableEntry::spawn(name, backend, &self.cfg, &self.stop);
            map.insert(name.to_string(), entry.clone());
            let mut def = self.default.lock().unwrap();
            if def.is_none() {
                *def = Some(name.to_string());
            }
            entry
        };
        Ok(entry)
    }

    /// Hot-load a `.dpq` artifact as a new table (the `load` admin op).
    pub fn load_dpq(&self, name: &str, path: &Path) -> Result<Arc<TableEntry>, WireError> {
        let emb = CompressedEmbedding::load(path).map_err(|e| WireError::Rejected {
            code: "load_failed".into(),
            message: format!("load {path:?}: {e}"),
        })?;
        self.insert(name, Arc::new(emb))
    }

    /// Drop a table: later lookups get `no_such_table`; lookups already
    /// queued on its shards are failed, typed, not stranded. If the
    /// default table is unloaded the first remaining table (by name)
    /// becomes the default.
    pub fn unload(&self, name: &str) -> Result<(), WireError> {
        let entry = {
            let mut map = self.tables.write().unwrap();
            let entry = map
                .remove(name)
                .ok_or_else(|| WireError::NoSuchTable(name.to_string()))?;
            let mut def = self.default.lock().unwrap();
            if def.as_deref() == Some(name) {
                *def = map.keys().next().cloned();
            }
            entry
        };
        entry.stop();
        Ok(())
    }

    pub fn get(&self, name: &str) -> Option<Arc<TableEntry>> {
        self.tables.read().unwrap().get(name).cloned()
    }

    /// Route a request's optional table name: `None` means the default
    /// table (v1 frames and table-less v2 frames).
    pub fn resolve(&self, name: Option<&str>) -> Result<Arc<TableEntry>, WireError> {
        match name {
            Some(n) => self
                .get(n)
                .ok_or_else(|| WireError::NoSuchTable(n.to_string())),
            None => {
                let def = self.default.lock().unwrap().clone();
                let def = def.ok_or_else(|| {
                    WireError::NoSuchTable("(default: no tables loaded)".into())
                })?;
                self.get(&def)
                    .ok_or_else(|| WireError::NoSuchTable(def))
            }
        }
    }

    pub fn default_name(&self) -> Option<String> {
        self.default.lock().unwrap().clone()
    }

    pub fn set_default(&self, name: &str) -> Result<(), WireError> {
        // existence check and assignment under the tables lock (same
        // order as insert/unload) so a racing unload cannot leave the
        // default naming a just-removed table
        let map = self.tables.read().unwrap();
        if !map.contains_key(name) {
            return Err(WireError::NoSuchTable(name.to_string()));
        }
        *self.default.lock().unwrap() = Some(name.to_string());
        Ok(())
    }

    /// All tables in name order.
    pub fn list(&self) -> Vec<Arc<TableEntry>> {
        self.tables.read().unwrap().values().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.tables.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Stop every table's shards and join their threads (idempotent).
    /// Leaves the table map readable so late `stats` frames still answer.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::Relaxed);
        let entries = self.list();
        for e in entries {
            e.stop();
        }
    }
}

impl Drop for TableRegistry {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::DenseTable;
    use crate::tensor::TensorF;
    use crate::util::Rng;

    fn dense(n: usize, d: usize, seed: u64) -> (Arc<DenseTable>, TensorF) {
        let mut rng = Rng::new(seed);
        let t = TensorF {
            shape: vec![n, d],
            data: (0..n * d).map(|_| rng.normal()).collect(),
        };
        (Arc::new(DenseTable::new(t.clone()).unwrap()), t)
    }

    fn cfg(shards: usize) -> ServerConfig {
        ServerConfig { max_batch: 8, shards_per_table: shards }
    }

    #[test]
    fn insert_resolve_default_unload() {
        let reg = TableRegistry::new(cfg(1));
        assert!(reg.resolve(None).is_err());
        let (a, _) = dense(10, 4, 1);
        let (b, _) = dense(20, 6, 2);
        reg.insert("a", a).unwrap();
        reg.insert("b", b).unwrap();
        assert_eq!(
            reg.insert("a", dense(5, 2, 3).0).unwrap_err(),
            WireError::TableExists("a".into())
        );
        assert_eq!(reg.default_name().as_deref(), Some("a"));
        assert_eq!(reg.resolve(None).unwrap().name, "a");
        assert_eq!(reg.resolve(Some("b")).unwrap().name, "b");
        assert_eq!(
            reg.resolve(Some("zzz")).unwrap_err(),
            WireError::NoSuchTable("zzz".into())
        );
        reg.set_default("b").unwrap();
        assert_eq!(reg.resolve(None).unwrap().name, "b");
        // unloading the default falls back to the first remaining table
        reg.unload("b").unwrap();
        assert_eq!(reg.default_name().as_deref(), Some("a"));
        assert_eq!(reg.unload("b").unwrap_err(),
                   WireError::NoSuchTable("b".into()));
        assert_eq!(reg.list().len(), 1);
        reg.shutdown();
    }

    #[test]
    fn rejects_bad_table_names_and_degenerate_shapes() {
        let reg = TableRegistry::new(cfg(1));
        assert!(reg.insert("", dense(4, 2, 1).0).is_err());
        assert!(reg.insert("a=b", dense(4, 2, 1).0).is_err());
        // d == 0 would make the batcher failure view indistinguishable
        // from a real (empty) answer; vocab == 0 can never serve an id
        assert!(reg.insert("w0", dense(4, 0, 1).0).is_err());
        assert!(reg.insert("v0", dense(0, 4, 1).0).is_err());
        assert!(reg.is_empty());
    }

    /// Shard routing must be invisible in the answer: for every shard
    /// count the assembled rows are bit-identical to a direct backend
    /// gather, whichever shards the ids land on.
    #[test]
    fn sharded_lookup_matches_direct_gather() {
        let (backend, table) = dense(50, 6, 7);
        let patterns: Vec<Vec<usize>> = vec![
            vec![0, 49, 25, 1, 48, 2, 47],     // straddles every shard
            vec![3, 4, 5],                     // single-shard fast path
            (0..50).rev().collect(),           // all ids, reversed
            vec![49, 49, 0, 0, 24],            // duplicates across shards
            vec![],
        ];
        for shards in [1usize, 2, 3, 7] {
            let reg = TableRegistry::new(cfg(shards));
            let entry = reg.insert("t", backend.clone()).unwrap();
            assert_eq!(entry.shard_count(), shards);
            for ids in &patterns {
                let ans = entry.lookup(ids).unwrap();
                let got = ans.as_slice();
                assert_eq!(got.len(), ids.len() * 6);
                for (r, &id) in ids.iter().enumerate() {
                    assert_eq!(&got[r * 6..(r + 1) * 6], table.row(id),
                               "shards={shards} id={id}");
                }
            }
            reg.shutdown();
        }
    }

    #[test]
    fn lookup_after_unload_fails_typed_not_hung() {
        let reg = TableRegistry::new(cfg(2));
        let (backend, _) = dense(10, 4, 9);
        let entry = reg.insert("t", backend).unwrap();
        reg.unload("t").unwrap();
        // the entry handle still exists, but its shards are closed: the
        // lookup must return None promptly instead of blocking forever
        assert!(entry.lookup(&[1, 2, 9]).is_none());
    }

    #[test]
    fn shard_of_covers_range_evenly() {
        let reg = TableRegistry::new(cfg(4));
        let (backend, _) = dense(100, 2, 11);
        let entry = reg.insert("t", backend).unwrap();
        let mut counts = [0usize; 4];
        for id in 0..100 {
            let s = entry.shard_of(id, 100);
            assert!(s < 4);
            counts[s] += 1;
        }
        assert_eq!(counts, [25, 25, 25, 25]);
        reg.shutdown();
    }
}
