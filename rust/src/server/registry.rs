//! [`TableRegistry`]: named [`EmbeddingBackend`] tables with hot
//! `load`/`unload`/`list` admin ops, per-table [`Stats`], per-table
//! batcher shards, an optional memory budget with LRU eviction, and
//! whole-registry snapshot/restore.
//!
//! # Sharding
//!
//! Every table owns `shards_per_table` batcher shards; shard `s` of a
//! table with vocab `n` serves the id range `[s*n/S, (s+1)*n/S)`. A
//! request's ids are split by range, each sub-list queued on its shard,
//! and the handler stitches the shard answers back in id order -- so two
//! hot tables (or two halves of one huge vocab) never serialize behind
//! one batcher thread. Each shard reconstructs its micro-batch through
//! the shared worker pool (`util::pool`); row gathers are bit-identical
//! for every shard count and thread count, so sharding is invisible in
//! the served bytes. With one shard per table (the default) the answer
//! is a zero-copy view of the batch buffer, exactly the PR-1 fast path.
//!
//! # Lifecycle
//!
//! `insert`/`load_dpq` spawn the table's shard threads immediately;
//! `unload` closes the shard queues (failing any queued lookups, typed)
//! and joins the threads. Unloading the **default** table explicitly
//! re-elects the first remaining table (in name order) as the new
//! default -- the returned [`UnloadOutcome`] names it, and the wire-level
//! `unload` response carries it -- so the default name can never dangle
//! on a table that no longer exists. Dropping the registry shuts
//! everything down.
//!
//! # Memory budget and LRU eviction
//!
//! With [`ServerConfig::mem_budget_bytes`] set, the registry tracks the
//! resident bytes of every table (via
//! [`EmbeddingBackend::storage_bits`]) and, whenever an insert pushes
//! the total over the budget, evicts least-recently-looked-up tables
//! until the total fits again. Two tables are never evicted: the
//! **default table** (pinned -- v1 clients route to it) and the table
//! being inserted (evicting a table the operator just loaded would make
//! the load a no-op). The budget is therefore *soft*: if only pinned
//! tables remain, the registry stays over budget and keeps serving --
//! and if the pinned tables ALONE exceed the budget (an insert bigger
//! than the whole budget), nothing is evicted at all, since no sequence
//! of evictions could reach the budget anyway.
//! Lookups to an evicted table fail with the same typed
//! `no_such_table` rejection as any unknown table (the JSON error frame
//! additionally carries `"evicted": true` and `"residency": "evicted"`);
//! reloading the table under the same name clears the marker. Eviction
//! counts are surfaced by the aggregate `stats` op.
//!
//! # Tiered residency: the spill tier
//!
//! With [`ServerConfig::spill_dir`] set, the registry is a two-tier
//! store and every registered table is in one of three residency
//! states:
//!
//! ```text
//!               budget eviction / `demote` op
//!            ------------------------------------>
//!   Resident                                        Spilled
//!            <------------------------------------
//!               transparent reload on next lookup       | artifact
//!               (single-flight `Promoting` claim)       | deleted
//!                                                       v out-of-band
//!                                                     Lost
//! ```
//!
//! A budget eviction (or an explicit `demote` admin op) serializes the
//! victim through its kind's [`EmbeddingBackend::save_artifact`] format
//! into the spill directory (write-then-rename, tracked by a
//! [`SPILL_MANIFEST`] rewritten on every transition) instead of
//! discarding it. A later lookup to a spilled table transparently
//! reloads it: the first caller claims the slot's single-flight
//! `Promoting` gate and performs the reload while concurrent callers
//! block on the gate and then re-resolve (exactly one reload happens,
//! however many clients hammer the cold table). Promotion re-enters the
//! LRU and may evict another table to make room -- the promoted table
//! and the default are pinned for that pass, and a per-request cycle
//! guard bounds promotion attempts so a resolve can never thrash-loop
//! between promoting and being re-demoted. A spilled table whose
//! artifact is corrupt answers a typed `reload_failed` rejection (the
//! registry keeps serving its other tables); one whose artifact was
//! deleted out-of-band is reported as `Lost` by `stats` instead of
//! panicking anything. Without a spill dir (or with
//! [`ServerConfig::spill_on_evict`] off), budget eviction drops tables
//! exactly as before.
//!
//! # Replicated hot tables
//!
//! Real traffic is heavily skewed: one hot table saturates its batcher
//! shards while cold tables idle. A table registered with `replicas: N`
//! (CLI `--table name=path:replicas=N`, or the live `set_replicas`
//! wire op) materializes N **independent batcher-shard sets over one
//! shared backend `Arc`** -- N× the batcher/drain parallelism for the
//! cost of zero extra table memory. Each incoming lookup is routed to
//! the **least-loaded replica** (live queue-depth counter per replica;
//! round-robin among ties, so an idle server still spreads load), and
//! its ids are then range-partitioned across that replica's shards
//! exactly as before. Row gathers are a pure function of the id, so
//! replication is invisible in the served bytes: `replicas=N` is
//! bit-identical to `replicas=1` at every thread count
//! (`tests/replica_equivalence.rs`). A live `set_replicas` resize swaps
//! the table's entry in place -- in-flight batches finish serving, and
//! a lookup whose queue was closed by the swap is transparently retried
//! against the new entry by the connection handler. The replica count
//! survives the spill tier (recorded at demote time, in `spill.json`,
//! and in snapshot manifests, so promote and `--restore` rebuild it).
//!
//! # TTL eviction
//!
//! With [`ServerConfig::ttl_secs`] set, a non-default table that no
//! lookup has touched for at least that long is demoted (or dropped,
//! under `--spill drop` / no spill tier) **even while under the memory
//! budget** -- idle tables should not hold budget a hot table's
//! promotion may need. TTL shares the whole eviction path with the
//! budget: same spill-vs-drop policy, same pinned-default rule, same
//! victim finishing outside the lock; the two compose (whichever fires
//! first wins) and `stats` attributes causes separately (`evictions`
//! vs `ttl_demotions`). The sweep is lazy -- it runs at the top of
//! every resolve and insert, and the serve accept loop ticks it while
//! idle -- and reads time through the injectable [`Clock`] so tests
//! drive it deterministically with a [`ManualClock`]
//! (idle-time decisions only; LRU *ordering* stays on the logical
//! resolution counter).
//!
//! [`Clock`]: crate::server::clock::Clock
//! [`ManualClock`]: crate::server::clock::ManualClock
//!
//! # Startup spill recovery
//!
//! [`TableRegistry::open`] over a spill directory that already holds a
//! [`SPILL_MANIFEST`] (a previous process crashed or was restarted with
//! tables demoted) **re-adopts** every recorded table as a `Spilled`
//! slot: shape metadata is taken from the manifest, a missing artifact
//! adopts as `Lost` instead of failing startup, and the first lookup
//! transparently promotes -- a restarted server serves every
//! previously-spilled table bit-exactly with no operator intervention.
//! A corrupt or future-versioned `spill.json` fails `open` loudly
//! (`spill_recover_failed`): silently dropping a recorded table WOULD
//! be data loss.
//!
//! # Snapshot / restore
//!
//! [`TableRegistry::snapshot`] serializes every resident table into a
//! directory (one artifact file per table, via
//! [`EmbeddingBackend::save_artifact`]) plus a versioned
//! `manifest.json` recording table names, backend kinds, artifact
//! files, shapes, the default table, and the serving config.
//! [`TableRegistry::restore`] rebuilds a registry from the manifest
//! that serves **bit-identical** rows (every artifact format roundtrips
//! exactly). Every file -- artifacts and manifest alike -- is published
//! with a write-then-rename, so a crash mid-snapshot never leaves a
//! half-written file that an older manifest in the same directory could
//! still point at. See
//! `docs/WIRE_PROTOCOL.md` for the `snapshot` wire op and
//! `docs/ARCHITECTURE.md` for the operational story.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::Duration;

use std::sync::Condvar;
use std::time::Instant;

use crate::backend::{self, EmbeddingBackend};
use crate::jsonx::Json;
use crate::server::batcher::{run_batch, Answer, BatchQueue, DoneSlot, Pending};
use crate::server::clock::{Clock, MonotonicClock};
use crate::server::protocol::WireError;
use crate::server::row_cache::RowCache;
use crate::server::stats::{ConnStats, LatencyRing, ReplicaStats, Stats};

/// Manifest `format` tag written by [`TableRegistry::snapshot`].
pub const SNAPSHOT_FORMAT: &str = "dpq_registry_snapshot";

/// Per-process sequence for snapshot temp-file names: two concurrent
/// `snapshot` ops into the same directory must not share a temp path,
/// or one could atomically rename the other's half-written bytes into
/// place (the pid covers concurrent processes).
static SNAP_SEQ: AtomicU64 = AtomicU64::new(0);

/// A temp-file name unique to this process + call.
fn snap_tmp_name(stem: &str) -> String {
    let seq = SNAP_SEQ.fetch_add(1, Ordering::Relaxed);
    format!("{stem}.{}-{seq}.tmp", std::process::id())
}

/// Manifest schema version written by [`TableRegistry::snapshot`] and
/// required by [`TableRegistry::restore`].
pub const SNAPSHOT_VERSION: u64 = 1;

/// File name of the snapshot manifest inside a snapshot directory.
pub const SNAPSHOT_MANIFEST: &str = "manifest.json";

/// Most eviction-history entries kept (and serialized into aggregate
/// `stats` frames): under rotating table names the history would
/// otherwise grow -- and bloat every stats response -- forever. Oldest
/// evictions are forgotten first; the total [`eviction_count`]
/// (a plain counter) is never truncated.
///
/// [`eviction_count`]: TableRegistry::eviction_count
pub const EVICTED_HISTORY: usize = 64;

/// File name of the spill-tier manifest inside a spill directory: the
/// durable record of which tables are currently spilled (name, kind,
/// artifact file, shape), rewritten write-then-rename on every
/// demote/promote/unload transition so the directory is always
/// inspectable offline.
pub const SPILL_MANIFEST: &str = "spill.json";

/// Manifest `format` tag written into [`SPILL_MANIFEST`].
pub const SPILL_FORMAT: &str = "dpq_spill_tier";

/// Cycle guard: most promotions one `resolve` performs before giving up
/// with a typed rejection. Each attempt re-resolves from the table map,
/// so a table demoted out from under its own promotion (budget thrash)
/// is bounded per request instead of looping forever.
const PROMOTE_ATTEMPTS: usize = 3;

/// Most batcher-shard replicas one table may be resized to. Each
/// replica costs `shards_per_table` OS threads; past this the thread
/// count, not the batcher, is the bottleneck -- an absurd request is a
/// typo, reject it typed (`bad_replicas`) instead of spawning it.
pub const MAX_REPLICAS: usize = 64;

/// Serving knobs shared by every table in a registry.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Max pending lookups drained into one micro-batch per shard.
    pub max_batch: usize,
    /// Batcher shards per table; the id space is range-partitioned
    /// across them. 1 keeps the single-queue zero-copy fast path.
    pub shards_per_table: usize,
    /// Optional resident-bytes budget across all tables; exceeding it on
    /// insert evicts least-recently-looked-up tables (the default table
    /// and the table being inserted are pinned). `None` never evicts.
    pub mem_budget_bytes: Option<u64>,
    /// Optional spill-tier directory. When set, budget evictions (with
    /// [`spill_on_evict`](Self::spill_on_evict)) and the `demote` admin
    /// op serialize tables here instead of discarding them, and a lookup
    /// to a spilled table transparently reloads it. The directory must
    /// exist: [`TableRegistry::open`] fails loudly when it is missing.
    pub spill_dir: Option<PathBuf>,
    /// Whether budget evictions demote victims to the spill tier (true,
    /// the default) or drop them exactly as a spill-less registry would
    /// (false -- the `--spill drop` policy). Meaningless without
    /// [`spill_dir`](Self::spill_dir).
    pub spill_on_evict: bool,
    /// Optional idle TTL in seconds (`--ttl SECS`): a non-default table
    /// that no lookup has touched for at least this long is demoted
    /// (spill tier) or dropped (otherwise) even while under the memory
    /// budget. `None` never expires. The sweep runs lazily on
    /// resolves/inserts and on the serve accept loop's idle tick,
    /// reading the registry's injectable [`Clock`].
    pub ttl_secs: Option<u64>,
    /// Optional per-connection deadline (`--conn-timeout SECS`). Applies
    /// as the idle deadline before a frame's first byte AND as the
    /// absolute whole-frame deadline from that first byte (so a
    /// byte-at-a-time slow-loris cannot reset it), plus the write
    /// timeout on responses. Expiry closes the connection with a typed
    /// `timeout` error frame. `None` disables deadlines (the in-process
    /// test default; the `repro serve` CLI defaults to 30s).
    pub conn_timeout: Option<Duration>,
    /// Optional cap on concurrently open connections
    /// (`--max-conns N`). A connection accepted over the cap is
    /// answered with a typed `busy` error frame and closed without
    /// spawning a handler thread. `None` is unbounded (the in-process
    /// test default; the `repro serve` CLI defaults to 1024).
    pub max_conns: Option<usize>,
    /// Enable test-only debug ops (`debug_panic`, the handler-panic
    /// injection the isolation tests drive). Never enabled by the CLI
    /// and never recorded in snapshots; with it off (the default) the
    /// op answers `unknown_op` like any other unrecognized name.
    pub debug_ops: bool,
    /// Default hot-row cache byte cap per table (`--row-cache BYTES`).
    /// 0 (the default) disables the cache. Per-table overrides come
    /// from `:row_cache=` suffixes on `--table` specs and the v2
    /// `set_row_cache` op. Cache CAPACITY counts against
    /// [`mem_budget_bytes`](Self::mem_budget_bytes): capacity bounds
    /// actual cache bytes at all times, so `resident + cached <=
    /// budget` holds without racing the fill level.
    pub row_cache_bytes: u64,
    /// Poller threads for the event-driven connection plane
    /// (`--pollers N`). Every socket is multiplexed onto this fixed
    /// pool, so the OS-thread count stays flat in the connection count;
    /// `0` selects the legacy thread-per-connection plane (and on
    /// non-Linux targets, where the epoll shim is empty, any value
    /// falls back to it). Served bytes are bit-identical across planes.
    pub pollers: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_batch: 64,
            shards_per_table: 1,
            mem_budget_bytes: None,
            spill_dir: None,
            spill_on_evict: true,
            ttl_secs: None,
            conn_timeout: None,
            max_conns: None,
            debug_ops: false,
            row_cache_bytes: 0,
            pollers: 2,
        }
    }
}

/// Where a registered table currently lives (see the module docs'
/// state diagram). Surfaced by `stats` and on `no_such_table`
/// rejection frames as the three-state `residency` field.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Residency {
    /// In memory, batcher shards running.
    Resident,
    /// Serialized in the spill tier; the next lookup promotes it.
    Spilled,
    /// Spilled, but its artifact is missing (deleted out-of-band).
    /// Lookups answer `reload_failed`; `stats` keeps reporting the
    /// table so operators see what was lost.
    Lost,
}

impl Residency {
    /// Wire string for this state (`"resident"` / `"spilled"` /
    /// `"lost"`).
    pub fn as_str(&self) -> &'static str {
        match self {
            Residency::Resident => "resident",
            Residency::Spilled => "spilled",
            Residency::Lost => "lost",
        }
    }
}

/// Lifecycle phase of a spilled slot. `Spilling` and `Promoting` are
/// the two in-transition phases; both are single-holder claims that
/// concurrent accessors wait out on the slot's condvar.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum SpillPhase {
    /// The evictor/demoter is still writing the artifact.
    Spilling,
    /// Artifact published; a lookup may claim promotion.
    Ready,
    /// Exactly one reload is in flight (the single-flight gate).
    Promoting,
    /// The artifact was observed missing. Advisory: a later probe or
    /// promotion attempt re-checks the filesystem, so an out-of-band
    /// restore of the file heals the slot.
    Lost,
}

/// A table demoted to the spill tier: its serving metadata plus the
/// single-flight promotion gate. The table's [`Stats`] ride along so
/// counters survive a demote/promote round trip.
pub struct SpilledTable {
    name: String,
    kind: String,
    /// Artifact file name inside the spill directory.
    file: String,
    vocab: usize,
    d: usize,
    storage_bits: usize,
    /// Replica count to rebuild at promotion. Atomic so a live
    /// `set_replicas` on a spilled table takes effect when it comes
    /// back, without waking the slot.
    replicas: AtomicUsize,
    /// Hot-row cache byte cap to rebuild at promotion (0 = disabled).
    /// Atomic for the same reason as `replicas`: a `set_row_cache` on a
    /// spilled table takes effect when it comes back. The CONTENTS are
    /// never spilled -- a promoted table starts with an empty cache.
    row_cache: AtomicU64,
    stats: Arc<Stats>,
    state: Mutex<SpillPhase>,
    cv: Condvar,
    /// Content digest of the published artifact as `(sha256 hex, byte
    /// length)`. `None` while the artifact is still being written
    /// (phase `Spilling`) and for slots adopted from a legacy
    /// (pre-digest) manifest -- those backfill on the first manifest
    /// rewrite. A slot with a digest is verify-before-parse on every
    /// reload and addressable by the `fetch_artifact` wire op.
    digest: Mutex<Option<(String, u64)>>,
}

impl SpilledTable {
    fn from_entry(entry: &TableEntry) -> SpilledTable {
        let kind = entry.backend.kind();
        SpilledTable {
            name: entry.name.clone(),
            kind: kind.to_string(),
            file: spill_file_name(&entry.name, kind),
            vocab: entry.backend.vocab(),
            d: entry.backend.d(),
            storage_bits: entry.backend.storage_bits(),
            replicas: AtomicUsize::new(entry.replica_count()),
            row_cache: AtomicU64::new(entry.row_cache.cap_bytes()),
            stats: entry.stats.clone(),
            state: Mutex::new(SpillPhase::Spilling),
            cv: Condvar::new(),
            digest: Mutex::new(None),
        }
    }

    /// Registry name this table is served under.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Backend scheme tag recorded at demote time ("dpq", "dense", ...).
    pub fn kind(&self) -> &str {
        &self.kind
    }

    /// Artifact file name inside the spill directory.
    pub fn file(&self) -> &str {
        &self.file
    }

    /// Number of rows the spilled table serves once promoted.
    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// Embedding width of the spilled table.
    pub fn d(&self) -> usize {
        self.d
    }

    /// Inference-time storage in bits, recorded at demote time.
    pub fn storage_bits(&self) -> usize {
        self.storage_bits
    }

    /// Bytes the table will occupy once promoted back (the amount the
    /// demotion freed from the budget).
    pub fn spilled_bytes(&self) -> u64 {
        (self.storage_bits as u64).div_ceil(8)
    }

    /// The table's serving counters, carried across the spill tier.
    pub fn stats(&self) -> &Stats {
        &self.stats
    }

    /// Batcher-shard replica count the table will be rebuilt with when
    /// it is promoted back.
    pub fn replicas(&self) -> usize {
        self.replicas.load(Ordering::Relaxed).max(1)
    }

    /// Hot-row cache byte cap the table will be rebuilt with when it is
    /// promoted back (0 = disabled).
    pub fn row_cache_bytes(&self) -> u64 {
        self.row_cache.load(Ordering::Relaxed)
    }

    /// Content digest of the published artifact (`sha256` hex, byte
    /// length), when known. `None` means the slot predates digests
    /// (legacy manifest) or the artifact is still being written.
    pub fn digest(&self) -> Option<(String, u64)> {
        self.digest.lock().unwrap().clone()
    }

    fn set_digest(&self, sha256: String, bytes: u64) {
        *self.digest.lock().unwrap() = Some((sha256, bytes));
    }

    fn set_phase(&self, phase: SpillPhase) {
        *self.state.lock().unwrap() = phase;
        self.cv.notify_all();
    }

    /// Block until the slot is out of its in-transition phases
    /// (`Spilling`/`Promoting`); the artifact's on-disk state is only
    /// defined outside them. Used by `snapshot` so racing a demotion
    /// fails neither the snapshot nor the demote.
    fn wait_settled(&self) {
        let mut ph = self.state.lock().unwrap();
        while matches!(*ph, SpillPhase::Spilling | SpillPhase::Promoting) {
            ph = self.cv.wait(ph).unwrap();
        }
    }
}

/// One name's residency slot in the table map. Crate-visible so the
/// server's `stats` op can read a name's residency in ONE consistent
/// map access instead of racing separate resident/spilled reads.
#[derive(Clone)]
pub(crate) enum Slot {
    /// In memory, batcher shards running.
    Resident(Arc<TableEntry>),
    /// Demoted to the spill tier.
    Spilled(Arc<SpilledTable>),
}

/// Why a table was evicted -- `stats` attributes the two causes with
/// separate counters (`evictions` vs `ttl_demotions`), and a rollback
/// after a failed spill write must decrement the right one.
#[derive(Clone, Copy, PartialEq, Eq)]
enum EvictCause {
    /// The resident total exceeded `--mem-budget`.
    Budget,
    /// The table sat idle past `--ttl`.
    Ttl,
}

/// An eviction victim chosen under the tables lock, finished (artifact
/// write / shard stop) after the lock is released.
struct Eviction {
    entry: Arc<TableEntry>,
    /// `Some`: demote to this spill slot; `None`: drop (PR-3 behavior).
    spill_to: Option<Arc<SpilledTable>>,
    cause: EvictCause,
}

/// Deterministic spill artifact name for a table. The FNV-1a hash of
/// the RAW name keeps two names that sanitize identically (`"a/b"` vs
/// `"a_b"`) from sharing a file.
fn spill_file_name(name: &str, kind: &str) -> String {
    let h = crate::util::fnv1a64(name);
    format!("spill_{h:016x}_{}.{kind}", sanitize_file_stem(name))
}

/// What [`TableRegistry::unload`] did to the default-table assignment.
#[derive(Debug, Clone, PartialEq)]
pub struct UnloadOutcome {
    /// The unloaded table was the default table.
    pub was_default: bool,
    /// The registry's default table AFTER the unload (`None` when the
    /// registry is now empty). If `was_default`, this is the re-elected
    /// default: the first remaining table in name order.
    pub new_default: Option<String>,
}

/// One batcher-shard replica of a table: its own shard queues (and
/// therefore its own batcher threads) plus the live stats routing
/// balances on. All replicas of a table share one backend `Arc`, so a
/// replica costs threads, not memory.
struct Replica {
    shards: Vec<Arc<BatchQueue>>,
    stats: Arc<ReplicaStats>,
}

/// Decrements a replica's queue depth when the routed lookup's answer
/// has been assembled (or the ticket is dropped) -- drop-based so no
/// exit path can leak depth and starve the replica forever.
pub(crate) struct DepthGuard(Option<Arc<ReplicaStats>>);

impl DepthGuard {
    fn track(rs: &Arc<ReplicaStats>) -> DepthGuard {
        rs.queue_depth.fetch_add(1, Ordering::Relaxed);
        DepthGuard(Some(rs.clone()))
    }
}

impl Drop for DepthGuard {
    fn drop(&mut self) {
        if let Some(rs) = &self.0 {
            rs.queue_depth.fetch_sub(1, Ordering::Relaxed);
        }
    }
}

/// One served table: backend + stats + its batcher-shard replicas.
pub struct TableEntry {
    /// Registry name this table is served under.
    pub name: String,
    /// The row store behind this table.
    pub backend: Arc<dyn EmbeddingBackend>,
    /// Serving counters and batch-latency percentiles for this table.
    pub stats: Arc<Stats>,
    /// This entry's hot-row cache (shared with its batcher-shard
    /// threads). Created EMPTY at spawn: every residency transition
    /// that respawns the entry -- demote/promote round trips,
    /// `set_replicas` resizes -- structurally invalidates the cache, so
    /// there is no stale-row window. Capacity carries across those
    /// transitions; contents never do.
    pub row_cache: Arc<RowCache>,
    /// Logical LRU clock tick of the last lookup routed here (ticks come
    /// from the owning registry's clock; larger = more recent).
    last_used: AtomicU64,
    /// Injectable-clock milliseconds of the last lookup (TTL idleness;
    /// see [`crate::server::clock::Clock`]).
    last_used_at: AtomicU64,
    /// Independent batcher-shard sets over the shared backend; lookups
    /// route to the least-loaded one (round-robin among ties).
    replicas: Vec<Replica>,
    /// Rotates the replica scan's starting point so equal-depth
    /// replicas are picked in turn instead of always the first.
    rr: AtomicUsize,
    handles: Mutex<Vec<JoinHandle<()>>>,
}

/// An in-flight lookup whose sub-requests are already queued on the
/// table's batcher shards. [`LookupTicket::wait`] blocks for the shard
/// answers and assembles them in id order. Splitting enqueue from wait
/// lets the cross-table fan-out op queue EVERY table's sub-lookups
/// before waiting on any, so the tables' batchers reconstruct
/// concurrently.
pub(crate) enum LookupTicket {
    /// Empty id list: answered without touching any shard.
    Empty,
    /// Whole request on one shard (also the 1-shard fast path): the
    /// shard's buffer view IS the answer, zero-copy.
    Single {
        n: usize,
        d: usize,
        done: Arc<DoneSlot>,
        /// Holds the routed replica's queue depth until answered.
        _depth: DepthGuard,
    },
    /// Ids split across shards: `waits` holds `(shard, n_sub, slot)` per
    /// touched shard, `positions[shard][k]` the original slot of that
    /// shard's k-th id.
    Sharded {
        n: usize,
        d: usize,
        waits: Vec<(usize, usize, Arc<DoneSlot>)>,
        positions: Vec<Vec<usize>>,
        /// Holds the routed replica's queue depth until answered.
        _depth: DepthGuard,
    },
}

impl LookupTicket {
    /// Block for the shard answers and assemble them in request order.
    /// `None` means a batcher failed the request (table unloading /
    /// server bug path); callers turn it into a typed error.
    pub(crate) fn wait(self) -> Option<Answer> {
        match self {
            LookupTicket::Empty => Some(Answer::Owned(Vec::new())),
            LookupTicket::Single { n, d, done, _depth } => {
                let rows = crate::server::batcher::wait_rows(&done);
                if rows.as_slice().len() != n * d {
                    return None;
                }
                Some(Answer::View(rows))
            }
            LookupTicket::Sharded { n, d, waits, positions, _depth } => {
                let mut flat = vec![0.0f32; n * d];
                let mut failed = false;
                for (s, n_sub, done) in waits {
                    let rows = crate::server::batcher::wait_rows(&done);
                    let got = rows.as_slice();
                    if got.len() != n_sub * d {
                        failed = true;
                        continue; // keep draining the other shards' slots
                    }
                    for (k, &pos) in positions[s].iter().enumerate() {
                        flat[pos * d..(pos + 1) * d]
                            .copy_from_slice(&got[k * d..(k + 1) * d]);
                    }
                }
                if failed { None } else { Some(Answer::Owned(flat)) }
            }
        }
    }
}

impl TableEntry {
    /// Spawn a table's batcher-shard replicas. `stats` is fresh for an
    /// insert and the carried-over counters for a spill-tier promotion
    /// or a live `set_replicas` resize. `row_cache_bytes` is the
    /// hot-row cache cap the fresh (always empty) cache starts with;
    /// every replica's shards share the ONE cache -- the working set is
    /// a property of the table's traffic, not of which replica served
    /// it, and a shared cache keeps hit rates identical at every
    /// replica count.
    fn spawn(
        name: &str,
        backend: Arc<dyn EmbeddingBackend>,
        cfg: &ServerConfig,
        stop: &Arc<AtomicBool>,
        stats: Arc<Stats>,
        replicas: usize,
        row_cache_bytes: u64,
    ) -> Arc<TableEntry> {
        let row_cache = Arc::new(RowCache::new(backend.d(), row_cache_bytes));
        let mut reps = Vec::with_capacity(replicas.max(1));
        let mut handles = Vec::new();
        for _ in 0..replicas.max(1) {
            let shards: Vec<Arc<BatchQueue>> = (0..cfg.shards_per_table.max(1))
                .map(|_| Arc::new(BatchQueue::new(cfg.max_batch)))
                .collect();
            let rstats = Arc::new(ReplicaStats::default());
            for shard in &shards {
                let backend = backend.clone();
                let shard = shard.clone();
                let stats = stats.clone();
                let rstats = rstats.clone();
                let stop = stop.clone();
                let cache = row_cache.clone();
                handles.push(std::thread::spawn(move || {
                    while !stop.load(Ordering::Relaxed) && !shard.is_closed() {
                        let batch = shard.pop_batch(Duration::from_millis(20));
                        if batch.is_empty() {
                            continue;
                        }
                        let t0 = Instant::now();
                        run_batch(&*backend, &batch, &stats, &cache);
                        rstats.record_batch_secs(t0.elapsed().as_secs_f64());
                    }
                    // close() fails anything still queued; calling it from
                    // the exiting thread covers the global-stop path too
                    shard.close();
                }));
            }
            reps.push(Replica { shards, stats: rstats });
        }
        Arc::new(TableEntry {
            name: name.to_string(),
            backend,
            stats,
            row_cache,
            last_used: AtomicU64::new(0),
            last_used_at: AtomicU64::new(0),
            replicas: reps,
            rr: AtomicUsize::new(0),
            handles: Mutex::new(handles),
        })
    }

    /// Number of batcher shards range-partitioning each replica's ids.
    pub fn shard_count(&self) -> usize {
        self.replicas[0].shards.len()
    }

    /// Number of independent batcher-shard replicas serving this table.
    pub fn replica_count(&self) -> usize {
        self.replicas.len()
    }

    /// Each replica's live queue depth (outstanding routed lookups), in
    /// replica order -- the signal routing balances on.
    pub fn replica_depths(&self) -> Vec<u64> {
        self.replicas
            .iter()
            .map(|r| r.stats.queue_depth.load(Ordering::Relaxed))
            .collect()
    }

    /// Per-replica stats as a JSON array (`queue_depth`, `batches`, and
    /// -- once a replica has drained a batch -- `batch_p50_s` /
    /// `batch_p99_s`), for the `stats` op's merged table view.
    pub fn replica_stats_json(&self) -> Json {
        Json::arr(
            self.replicas
                .iter()
                .map(|r| {
                    let mut pairs = vec![
                        ("queue_depth",
                         Json::num(r.stats.queue_depth.load(Ordering::Relaxed)
                                   as f64)),
                        ("batches",
                         Json::num(r.stats.batches.load(Ordering::Relaxed)
                                   as f64)),
                    ];
                    if let Some((p50, p99)) = r.stats.batch_latency() {
                        pairs.push(("batch_p50_s", Json::num(p50)));
                        pairs.push(("batch_p99_s", Json::num(p99)));
                    }
                    Json::obj(pairs)
                })
                .collect(),
        )
    }

    /// The least-loaded replica by live queue depth. The scan starts at
    /// a rotating offset so ties (the common idle case: every depth 0)
    /// resolve round-robin instead of always replica 0.
    fn pick_replica(&self) -> &Replica {
        if self.replicas.len() == 1 {
            return &self.replicas[0];
        }
        let start = self.rr.fetch_add(1, Ordering::Relaxed) % self.replicas.len();
        let mut best = start;
        let mut best_depth = u64::MAX;
        for k in 0..self.replicas.len() {
            let i = (start + k) % self.replicas.len();
            let depth = self.replicas[i].stats.queue_depth.load(Ordering::Relaxed);
            if depth < best_depth {
                best = i;
                best_depth = depth;
            }
        }
        &self.replicas[best]
    }

    /// Bytes this table keeps resident at serve time (codes + side
    /// tables), the unit the registry's memory budget is enforced in.
    pub fn resident_bytes(&self) -> u64 {
        (self.backend.storage_bits() as u64).div_ceil(8)
    }

    /// Shard owning `id` under range partitioning (identical for every
    /// replica: all replicas have the same shard count).
    fn shard_of(&self, id: usize, vocab: usize) -> usize {
        debug_assert!(id < vocab);
        ((id as u128 * self.shard_count() as u128) / vocab as u128) as usize
    }

    /// Route one validated id list to the least-loaded replica and
    /// queue it on that replica's shards WITHOUT waiting; the returned
    /// ticket collects the answer. Ids MUST already be validated
    /// `< vocab`. Which replica is picked is invisible in the answer
    /// bytes -- row gathers are a pure function of the id.
    pub(crate) fn begin_lookup(&self, ids: &[usize]) -> LookupTicket {
        self.stats.requests.fetch_add(1, Ordering::Relaxed);
        let d = self.backend.d();
        if ids.is_empty() {
            return LookupTicket::Empty;
        }
        let rep = self.pick_replica();
        let depth = DepthGuard::track(&rep.stats);
        let n_shards = rep.shards.len();
        if n_shards == 1 {
            let (p, done) = Pending::new(ids.to_vec());
            rep.shards[0].push(p);
            return LookupTicket::Single { n: ids.len(), d, done, _depth: depth };
        }
        let vocab = self.backend.vocab();
        // split ids by owning shard, remembering each id's original slot
        let mut positions: Vec<Vec<usize>> = vec![Vec::new(); n_shards];
        let mut sub_ids: Vec<Vec<usize>> = vec![Vec::new(); n_shards];
        for (pos, &id) in ids.iter().enumerate() {
            let s = self.shard_of(id, vocab);
            positions[s].push(pos);
            sub_ids[s].push(id);
        }
        // all ids on one shard: keep the zero-copy fast path (positions
        // are in request order, so the shard's view IS the answer)
        if let Some(only) = (0..n_shards).find(|&s| sub_ids[s].len() == ids.len()) {
            let (p, done) = Pending::new(std::mem::take(&mut sub_ids[only]));
            rep.shards[only].push(p);
            return LookupTicket::Single { n: ids.len(), d, done, _depth: depth };
        }
        // enqueue every non-empty sub-lookup BEFORE the caller waits on
        // any, so the shards reconstruct concurrently
        let mut waits = Vec::new();
        for s in 0..n_shards {
            if sub_ids[s].is_empty() {
                continue;
            }
            let (p, done) = Pending::new(std::mem::take(&mut sub_ids[s]));
            let n_sub = p.ids.len();
            rep.shards[s].push(p);
            waits.push((s, n_sub, done));
        }
        LookupTicket::Sharded {
            n: ids.len(), d, waits, positions, _depth: depth,
        }
    }

    /// Route one validated id list through this table's shards and
    /// assemble the answer in id order. `None` means the batcher failed
    /// the request (table unloading / server bug path); callers turn it
    /// into a typed error. Ids MUST already be validated `< vocab`.
    pub(crate) fn lookup(&self, ids: &[usize]) -> Option<Answer> {
        self.begin_lookup(ids).wait()
    }

    /// Account one `score`/`topk` request against the least-loaded
    /// replica for the duration of its compute: scoring runs on the
    /// connection thread directly over the shared backend `Arc` (no
    /// batcher hop), but it is real table load, so it must be visible
    /// to the same queue-depth signal lookup routing balances on. The
    /// caller holds the guard across the scan and drops it when the
    /// response is assembled.
    pub(crate) fn begin_score(&self) -> DepthGuard {
        DepthGuard::track(&self.pick_replica().stats)
    }

    /// Close every replica's shards and join their threads (idempotent).
    fn stop(&self) {
        for rep in &self.replicas {
            for shard in &rep.shards {
                shard.close();
            }
        }
        let handles: Vec<JoinHandle<()>> =
            std::mem::take(&mut *self.handles.lock().unwrap());
        for h in handles {
            let _ = h.join();
        }
    }

    /// JSON description used by the `tables` / `load` responses.
    pub fn desc_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.as_str())),
            ("kind", Json::str(self.backend.kind())),
            ("vocab", Json::num(self.backend.vocab() as f64)),
            ("d", Json::num(self.backend.d() as f64)),
            ("storage_bits", Json::num(self.backend.storage_bits() as f64)),
            ("resident_bytes", Json::num(self.resident_bytes() as f64)),
            ("compression_ratio",
             Json::num(backend::compression_ratio(&*self.backend))),
            ("shards", Json::num(self.shard_count() as f64)),
            ("replicas", Json::num(self.replica_count() as f64)),
        ])
    }
}

/// Everything [`TableRegistry::adopt_spilled`] needs to register one
/// hydrated table: the serving metadata a peer advertises through its
/// `tables` listing and per-table `stats`, plus the content digest the
/// fetched artifact must hash to.
pub struct SpillSeed {
    /// Registry name to serve the table under.
    pub name: String,
    /// Backend scheme tag ("dpq", "dense", ...).
    pub kind: String,
    /// Artifact file name inside the local spill directory.
    pub file: String,
    /// Number of rows.
    pub vocab: usize,
    /// Embedding width.
    pub d: usize,
    /// Inference-time storage in bits.
    pub storage_bits: usize,
    /// Batcher-shard replica count to rebuild at promotion.
    pub replicas: usize,
    /// Hot-row cache byte cap to rebuild at promotion (0 = disabled).
    pub row_cache: u64,
    /// Expected SHA-256 of the artifact file, 64 lowercase hex chars.
    pub sha256: String,
    /// Expected artifact length in bytes.
    pub bytes: u64,
}

/// Named tables behind one server: lookup routing, default-table
/// resolution for v1 frames, hot admin ops, LRU eviction under a memory
/// budget, and snapshot/restore.
pub struct TableRegistry {
    cfg: ServerConfig,
    tables: RwLock<BTreeMap<String, Slot>>,
    default: Mutex<Option<String>>,
    /// True while the current default was elected PROVISIONALLY by
    /// spill-tier adoption (no resident table existed yet). The next
    /// `insert` overrides a provisional default -- a restart must not
    /// let a previously-spilled side table hijack v1 routing from the
    /// table the CLI is about to load. Always mutated under the tables
    /// lock + default mutex, like `default` itself.
    default_provisional: AtomicBool,
    /// Eviction history: table name -> (times evicted, tick of the last
    /// eviction). A name is removed when a table is (re)inserted under
    /// it; capped at [`EVICTED_HISTORY`] entries (oldest forgotten).
    /// Only DROPPED tables land here -- a spilled table is still
    /// registered and tracked by its [`Slot`].
    evicted: Mutex<BTreeMap<String, (u64, u64)>>,
    /// Logical LRU clock; every successful `resolve` stamps the entry.
    clock: AtomicU64,
    /// Injectable time source for TTL idleness (production: monotonic;
    /// tests: a [`crate::server::clock::ManualClock`]).
    wall: Arc<dyn Clock>,
    /// Injected-clock ms of the last hot-path TTL sweep (throttle state
    /// for [`maybe_expire_idle`](Self::maybe_expire_idle)).
    last_sweep: AtomicU64,
    evictions: AtomicU64,
    ttl_demotions: AtomicU64,
    spills: AtomicU64,
    promotes: AtomicU64,
    promote_ring: LatencyRing,
    /// Serializes spill-manifest rewrites (never held together with the
    /// tables write lock).
    spill_mu: Mutex<()>,
    /// Spill-manifest rewrites whose write-then-rename FAILED, leaving
    /// the published `spill.json` drifted from the registry until the
    /// next transition rewrites it (every rewrite serializes the whole
    /// live map, so one success heals all prior failures). Surfaced in
    /// aggregate `stats` -- a climbing count means the spill dir itself
    /// is sick.
    spill_manifest_write_failures: AtomicU64,
    /// One-shot latch for the legacy (digest-less) manifest warning, so
    /// adopting a pre-digest spill tier logs once, not per table.
    legacy_digest_warned: AtomicBool,
    fanout_requests: AtomicU64,
    stop: Arc<AtomicBool>,
    /// Connection-plane counters (open/total/busy/timeout/panic),
    /// shared by the accept loop and every connection thread.
    conn: ConnStats,
}

impl TableRegistry {
    /// Empty registry with the given serving knobs. Does NOT validate
    /// [`ServerConfig::spill_dir`]; use [`open`](Self::open) at startup
    /// so a missing spill directory fails loudly before serving begins
    /// (with `new`, a bogus dir surfaces as a typed `demote_failed` on
    /// the first spill instead).
    pub fn new(cfg: ServerConfig) -> Self {
        Self::with_clock(cfg, Arc::new(MonotonicClock::new()))
    }

    /// [`new`](Self::new) with an injected time source for TTL
    /// idleness -- the deterministic-test hook ([`crate::server::clock::ManualClock`]).
    /// Like `new`, performs no spill-dir validation or recovery.
    pub fn with_clock(cfg: ServerConfig, wall: Arc<dyn Clock>) -> Self {
        TableRegistry {
            cfg,
            tables: RwLock::new(BTreeMap::new()),
            default: Mutex::new(None),
            default_provisional: AtomicBool::new(false),
            evicted: Mutex::new(BTreeMap::new()),
            clock: AtomicU64::new(0),
            wall,
            last_sweep: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            ttl_demotions: AtomicU64::new(0),
            spills: AtomicU64::new(0),
            promotes: AtomicU64::new(0),
            promote_ring: LatencyRing::default(),
            spill_mu: Mutex::new(()),
            spill_manifest_write_failures: AtomicU64::new(0),
            legacy_digest_warned: AtomicBool::new(false),
            fanout_requests: AtomicU64::new(0),
            stop: Arc::new(AtomicBool::new(false)),
            conn: ConnStats::default(),
        }
    }

    /// [`new`](Self::new) plus startup validation and spill-tier
    /// recovery: a configured spill directory that does not exist is a
    /// typed `spill_dir_missing` error (serving with a spill tier that
    /// silently cannot accept artifacts would turn every eviction into
    /// data loss), and tables a previous process left recorded in the
    /// directory's [`SPILL_MANIFEST`] are re-adopted as `Spilled` slots
    /// that the first lookup transparently promotes (an entry whose
    /// artifact is missing adopts as `Lost`). A corrupt spill manifest
    /// is a typed `spill_recover_failed`.
    pub fn open(cfg: ServerConfig) -> Result<TableRegistry, WireError> {
        Self::open_with_clock(cfg, Arc::new(MonotonicClock::new()))
    }

    /// [`open`](Self::open) with an injected [`Clock`] -- validation
    /// and spill recovery included; tests drive TTL with a
    /// [`crate::server::clock::ManualClock`] through this.
    pub fn open_with_clock(
        cfg: ServerConfig,
        wall: Arc<dyn Clock>,
    ) -> Result<TableRegistry, WireError> {
        Self::validate_spill(&cfg)?;
        let reg = Self::with_clock(cfg, wall);
        reg.adopt_spill_tier()?;
        Ok(reg)
    }

    /// Re-adopt tables a previous process left in the spill tier: every
    /// entry of [`SPILL_MANIFEST`] becomes a `Spilled` slot (fresh
    /// counters; shape metadata from the manifest; phase `Lost` when
    /// the artifact file is missing, so a deleted artifact degrades to
    /// the usual typed `reload_failed` instead of failing startup).
    /// Names already registered are skipped loudly -- that happens when
    /// a `--restore` snapshot already rebuilt the table resident. If no
    /// default table is set afterwards, the first adopted name becomes
    /// a PROVISIONAL default (a spilled default transparently promotes
    /// on the first v1 frame) that the first real `insert` overrides --
    /// so a restart's `--table` flags end up owning v1 routing exactly
    /// as they would have without the restart. Returns the number of
    /// tables adopted.
    fn adopt_spill_tier(&self) -> Result<usize, WireError> {
        let Some(dir) = self.cfg.spill_dir.clone() else {
            return Ok(0);
        };
        // GC stray temp files first: artifacts and manifest rewrites
        // both publish write-then-rename under process-unique `.tmp`
        // names, so any `.tmp` here was left by a process that died (or
        // hit a failed rename) mid-write -- never by this one, which
        // has not written yet. Without this sweep, crash orphans
        // accumulate forever.
        if let Ok(rd) = std::fs::read_dir(&dir) {
            for entry in rd.flatten() {
                if entry.file_name().to_string_lossy().ends_with(".tmp") {
                    let _ = std::fs::remove_file(entry.path());
                }
            }
        }
        let manifest = dir.join(SPILL_MANIFEST);
        if !manifest.is_file() {
            return Ok(0);
        }
        let fail = |m: String| WireError::Rejected {
            code: "spill_recover_failed".into(),
            message: format!("spill manifest {manifest:?}: {m}"),
        };
        let text = std::fs::read_to_string(&manifest)
            .map_err(|e| fail(format!("read: {e}")))?;
        let j = Json::parse(&text).map_err(|e| fail(format!("parse: {e}")))?;
        if j.get("format").and_then(|v| v.as_str()) != Some(SPILL_FORMAT) {
            return Err(fail(format!("not a {SPILL_FORMAT} manifest")));
        }
        match j.get("v").and_then(|v| v.as_usize()) {
            Some(1) => {}
            other => {
                return Err(fail(format!(
                    "version {other:?}; this build reads v1")))
            }
        }
        let tables = j
            .get("tables")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| fail("no tables array".into()))?;
        let mut slots: Vec<Arc<SpilledTable>> = Vec::new();
        for t in tables {
            let get_str = |k: &str| t.get(k).and_then(|v| v.as_str());
            let get_n = |k: &str| t.get(k).and_then(|v| v.as_usize());
            let (Some(name), Some(kind), Some(file)) =
                (get_str("name"), get_str("kind"), get_str("file"))
            else {
                return Err(fail("table entry missing name/kind/file".into()));
            };
            let (Some(vocab), Some(d), Some(storage_bits)) =
                (get_n("vocab"), get_n("d"), get_n("storage_bits"))
            else {
                return Err(fail(format!(
                    "table {name:?} missing vocab/d/storage_bits")));
            };
            // same shape floor `insert` enforces: a degenerate shape
            // could never serve, and d == 0 breaks the typed-failure
            // guarantee -- a manifest recording one is corrupt
            if vocab == 0 || d == 0 || name.is_empty() || name.contains('=') {
                return Err(fail(format!(
                    "table {name:?} has invalid shape [{vocab}, {d}]")));
            }
            let replicas = get_n("replicas").unwrap_or(1).clamp(1, MAX_REPLICAS);
            // hot-row cache cap recorded at demote time; absent in
            // pre-cache manifests, which adopt as cache-disabled
            let row_cache = get_n("row_cache").unwrap_or(0) as u64;
            // Content digest recorded at publish time. ABSENT = legacy
            // manifest (pre-digest build): adopt unverified, warn once;
            // the digest is backfilled on the first manifest rewrite.
            // PRESENT but malformed = corrupt manifest, typed like any
            // other bad field.
            let digest = match t.get("sha256") {
                None => None,
                Some(v) => {
                    let (Some(hex), Some(bytes)) = (v.as_str(), get_n("bytes"))
                    else {
                        return Err(fail(format!(
                            "table {name:?} has a malformed sha256/bytes \
                             pair")));
                    };
                    if !crate::util::sha256::is_hex_digest(hex) {
                        return Err(fail(format!(
                            "table {name:?} sha256 {hex:?} is not a 64-char \
                             lowercase hex digest")));
                    }
                    Some((hex.to_string(), bytes as u64))
                }
            };
            if digest.is_none()
                && !self.legacy_digest_warned.swap(true, Ordering::Relaxed)
            {
                eprintln!(
                    "spill recovery: manifest {manifest:?} predates content \
                     digests; adopting unverified (digests are recorded on \
                     the first rewrite)");
            }
            let path = dir.join(file);
            // Verify the digest BEFORE the slot can serve: a mismatch
            // degrades to Lost (like a missing artifact -- the rest of
            // the registry keeps serving, and a later lookup answers
            // the usual typed reload_failed) instead of failing the
            // whole startup for one rotted file.
            let phase = if !path.is_file() {
                eprintln!(
                    "spill recovery: artifact {file:?} for table {name:?} \
                     is missing; adopting as lost");
                SpillPhase::Lost
            } else if let Some((want_hex, want_bytes)) = &digest {
                match backend::artifact_io::file_sha256(&path) {
                    Ok((got_hex, got_bytes))
                        if got_hex == *want_hex && got_bytes == *want_bytes =>
                    {
                        SpillPhase::Ready
                    }
                    Ok((got_hex, got_bytes)) => {
                        eprintln!(
                            "spill recovery: artifact {file:?} for table \
                             {name:?} does not match its recorded digest \
                             (manifest: {want_bytes} bytes sha256 \
                             {want_hex}; disk: {got_bytes} bytes \
                             {got_hex}); adopting as lost");
                        SpillPhase::Lost
                    }
                    Err(e) => {
                        eprintln!(
                            "spill recovery: artifact {file:?} for table \
                             {name:?} is unreadable ({e}); adopting as lost");
                        SpillPhase::Lost
                    }
                }
            } else {
                SpillPhase::Ready
            };
            slots.push(Arc::new(SpilledTable {
                name: name.to_string(),
                kind: kind.to_string(),
                file: file.to_string(),
                vocab,
                d,
                storage_bits,
                replicas: AtomicUsize::new(replicas),
                row_cache: AtomicU64::new(row_cache),
                stats: Arc::new(Stats::default()),
                state: Mutex::new(phase),
                cv: Condvar::new(),
                digest: Mutex::new(digest),
            }));
        }
        // one atomic registration pass (lock order: tables, then
        // default -- same as insert/unload); adoption is all-or-nothing
        // from a concurrent observer's point of view
        let mut adopted = 0usize;
        let mut map = self.tables.write().unwrap();
        let mut def = self.default.lock().unwrap();
        for slot in slots {
            if map.contains_key(slot.name()) {
                eprintln!(
                    "spill recovery: table {:?} is already registered \
                     (restored resident?); keeping the resident copy",
                    slot.name());
                continue;
            }
            if def.is_none() {
                *def = Some(slot.name().to_string());
                self.default_provisional.store(true, Ordering::Relaxed);
            }
            map.insert(slot.name().to_string(), Slot::Spilled(slot));
            adopted += 1;
        }
        Ok(adopted)
    }

    fn validate_spill(cfg: &ServerConfig) -> Result<(), WireError> {
        if let Some(dir) = &cfg.spill_dir {
            if !dir.is_dir() {
                return Err(WireError::Rejected {
                    code: "spill_dir_missing".into(),
                    message: format!(
                        "spill dir {dir:?} does not exist or is not a \
                         directory; create it before serving"),
                });
            }
        }
        Ok(())
    }

    /// The flag the accept loop and every batcher shard watch.
    pub fn stop_flag(&self) -> Arc<AtomicBool> {
        self.stop.clone()
    }

    /// The serving knobs this registry was built with.
    pub fn config(&self) -> ServerConfig {
        self.cfg.clone()
    }

    /// Connection-plane counters for the server fronting this registry.
    /// Live on the registry (not the server) so the aggregate `stats`
    /// op, which only sees the registry, can report them.
    pub fn conn_stats(&self) -> &ConnStats {
        &self.conn
    }

    /// Register `backend` as table `name` and start its batcher shards.
    /// The first inserted table becomes the default (v1 frames route to
    /// it) until [`set_default`](Self::set_default) says otherwise. If a
    /// memory budget is configured and this insert pushes the resident
    /// total over it, least-recently-looked-up tables are evicted (the
    /// default table and `name` itself are pinned) before this returns.
    pub fn insert(
        &self,
        name: &str,
        backend: Arc<dyn EmbeddingBackend>,
    ) -> Result<Arc<TableEntry>, WireError> {
        self.insert_with_replicas(name, backend, 1)
    }

    /// [`insert`](Self::insert) with `replicas` independent
    /// batcher-shard sets over the one shared backend (see the module
    /// docs): lookups route to the least-loaded replica and the served
    /// bytes are bit-identical to `replicas = 1`. `replicas` outside
    /// `1..=`[`MAX_REPLICAS`] is a typed `bad_replicas` rejection.
    pub fn insert_with_replicas(
        &self,
        name: &str,
        backend: Arc<dyn EmbeddingBackend>,
        replicas: usize,
    ) -> Result<Arc<TableEntry>, WireError> {
        validate_replicas(replicas)?;
        if name.is_empty() || name.contains('=') {
            return Err(WireError::Rejected {
                code: "bad_table_name".into(),
                message: format!("invalid table name {name:?}"),
            });
        }
        // A zero-width or zero-vocab table could never serve a lookup,
        // and d == 0 would additionally make the batcher's failure view
        // (an empty slice) indistinguishable from a successful answer --
        // the typed-failure guarantee depends on d >= 1.
        if backend.d() == 0 || backend.vocab() == 0 {
            return Err(WireError::Rejected {
                code: "bad_table".into(),
                message: format!(
                    "table {name:?} has degenerate shape [{}, {}]",
                    backend.vocab(), backend.d()),
            });
        }
        if self.stop.load(Ordering::Relaxed) {
            return Err(WireError::Rejected {
                code: "shutting_down".into(),
                message: "registry is shutting down".into(),
            });
        }
        // TTL sweep before the insert: tables that sat idle past their
        // TTL should expire BEFORE the budget pass ranks LRU victims
        // (whichever fires first wins; the insert itself is protected)
        self.expire_idle_protected(&[name]);
        // Default election happens INSIDE the tables write lock (same
        // lock order as `unload`: tables, then default) -- electing it
        // after releasing the lock could race an `unload` of this very
        // table and leave `default` naming a table that no longer
        // exists, permanently breaking v1 routing. Budget enforcement
        // runs under the same lock so two concurrent inserts can't both
        // conclude "still under budget".
        let (entry, evicted) = {
            let mut map = self.tables.write().unwrap();
            // a SPILLED name is still a registered table (its next
            // lookup reloads it), so it collides exactly like a
            // resident one
            if map.contains_key(name) {
                return Err(WireError::TableExists(name.to_string()));
            }
            let entry = TableEntry::spawn(
                name, backend, &self.cfg, &self.stop,
                Arc::new(Stats::default()), replicas,
                self.cfg.row_cache_bytes);
            // fresh LRU + idle stamps: a just-inserted table is the
            // most recent (and not TTL-idle)
            entry.last_used.store(
                self.clock.fetch_add(1, Ordering::Relaxed) + 1,
                Ordering::Relaxed,
            );
            entry.last_used_at.store(self.now_ms(), Ordering::Relaxed);
            map.insert(name.to_string(), Slot::Resident(entry.clone()));
            {
                // a default elected provisionally by spill-tier
                // adoption yields to the first real insert (v1 routing
                // must end up where the CLI's --table flags put it, as
                // it would have without a restart)
                let mut def = self.default.lock().unwrap();
                if def.is_none()
                    || self.default_provisional.load(Ordering::Relaxed)
                {
                    *def = Some(name.to_string());
                    self.default_provisional.store(false, Ordering::Relaxed);
                }
            }
            // a reloaded table is no longer "evicted"
            self.evicted.lock().unwrap().remove(name);
            let evicted = self.enforce_budget_locked(&mut map, &[name]);
            (entry, evicted)
        };
        // spill artifacts are written and shard threads joined OUTSIDE
        // the map lock: a shard mid-batch (or a disk write) must not
        // block every other table's lookups
        self.finish_evictions(evicted);
        Ok(entry)
    }

    /// Evict least-recently-used tables until the resident total fits
    /// the budget. Runs under the tables write lock; victims are either
    /// swapped to a `Spilled` placeholder (spill tier configured) or
    /// removed outright, and returned for the caller to finish --
    /// artifact write + shard stop -- outside the lock. The default
    /// table and `protect` are never evicted, so the budget is soft when
    /// only those remain.
    fn enforce_budget_locked(
        &self,
        map: &mut BTreeMap<String, Slot>,
        protect: &[&str],
    ) -> Vec<Eviction> {
        let Some(budget) = self.cfg.mem_budget_bytes else {
            return Vec::new();
        };
        // The default cannot change while the tables write lock is held
        // (set_default/unload both need the tables lock), so one read
        // is enough.
        let def = self.default.lock().unwrap().clone();
        let pinned = |e: &TableEntry| {
            def.as_deref() == Some(e.name.as_str())
                || protect.iter().any(|p| *p == e.name)
        };
        // One pass over the map (we hold the write lock that blocks
        // every lookup's resolve -- no per-iteration re-collection):
        // the resident set, its total bytes, and the pinned bytes.
        let mut live: Vec<Arc<TableEntry>> = map
            .values()
            .filter_map(|s| match s {
                Slot::Resident(e) => Some(e.clone()),
                Slot::Spilled(_) => None,
            })
            .collect();
        // Hot-row cache CAPACITY counts against the budget (capacity,
        // not fill: fill only grows toward capacity, so bounding the
        // capacity bounds actual bytes without racing the fill level).
        let mut total: u64 = live
            .iter()
            .map(|e| e.resident_bytes() + e.row_cache.cap_bytes())
            .sum();
        // Phase 1: shrink hot-row caches before destroying any table.
        // A cache holds purely derived state (every byte re-derivable
        // from the backend), so reclaiming its capacity is strictly
        // cheaper than evicting a table -- and pinned tables' caches
        // shrink too, since shrinking never takes a table down.
        // LRU-first: the stalest table's working set is the least worth
        // keeping warm.
        if total > budget {
            let mut order: Vec<Arc<TableEntry>> = live.clone();
            order.sort_by_key(|e| e.last_used.load(Ordering::Relaxed));
            for e in &order {
                if total <= budget {
                    break;
                }
                let cap = e.row_cache.cap_bytes();
                if cap == 0 {
                    continue;
                }
                let new_cap = cap.saturating_sub(total - budget);
                e.row_cache.set_capacity(new_cap);
                total -= cap - new_cap;
            }
        }
        // Zero-gain guard: if the pinned tables ALONE exceed the budget
        // (e.g. the fresh insert is bigger than the whole budget), no
        // sequence of evictions can reach it -- destroying every
        // unpinned table would take clients down for nothing. Stay
        // (softly) over budget with everything resident instead.
        // (Cache caps are already zero whenever this loop still has
        // work, so `resident_bytes` alone is the exact pinned total.)
        let pinned_bytes: u64 = live
            .iter()
            .filter(|e| pinned(e))
            .map(|e| e.resident_bytes())
            .sum();
        if pinned_bytes > budget {
            return Vec::new();
        }
        let mut out = Vec::new();
        while total > budget {
            let victim = live
                .iter()
                .enumerate()
                .filter(|(_, e)| !pinned(e))
                .min_by_key(|(_, e)| e.last_used.load(Ordering::Relaxed))
                .map(|(i, _)| i);
            let Some(i) = victim else {
                break; // only pinned tables left: stay (softly) over budget
            };
            let chosen = live.swap_remove(i);
            total -= chosen.resident_bytes();
            out.push(self.remove_victim_locked(
                map, &chosen.name, EvictCause::Budget));
        }
        out
    }

    /// Remove one chosen eviction victim from the table map -- the ONE
    /// place both budget and TTL eviction go through, so spill-vs-drop
    /// policy and bookkeeping can never diverge between the causes.
    /// With a spill tier, a `Spilled` placeholder (phase `Spilling`)
    /// takes the slot NOW, under the lock, so a racing lookup blocks on
    /// the single-flight gate until the artifact write outside the lock
    /// publishes; otherwise the PR-3 drop semantics apply byte for byte
    /// (eviction history marked so `no_such_table` can say "evicted").
    /// The caller finishes the returned [`Eviction`] outside the lock.
    fn remove_victim_locked(
        &self,
        map: &mut BTreeMap<String, Slot>,
        name: &str,
        cause: EvictCause,
    ) -> Eviction {
        let Some(Slot::Resident(entry)) = map.remove(name) else {
            unreachable!("victim chosen from this map's residents");
        };
        match cause {
            EvictCause::Budget => self.evictions.fetch_add(1, Ordering::Relaxed),
            EvictCause::Ttl => self.ttl_demotions.fetch_add(1, Ordering::Relaxed),
        };
        if self.cfg.spill_on_evict && self.cfg.spill_dir.is_some() {
            let slot = Arc::new(SpilledTable::from_entry(&entry));
            map.insert(name.to_string(), Slot::Spilled(slot.clone()));
            Eviction { entry, spill_to: Some(slot), cause }
        } else {
            let tick = self.clock.fetch_add(1, Ordering::Relaxed) + 1;
            let mut ev = self.evicted.lock().unwrap();
            let slot = ev.entry(name.to_string()).or_insert((0, 0));
            slot.0 += 1;
            slot.1 = tick;
            while ev.len() > EVICTED_HISTORY {
                // forget the stalest eviction, keep the history bounded
                let oldest = ev
                    .iter()
                    .min_by_key(|(_, (_, t))| *t)
                    .map(|(k, _)| k.clone())
                    .expect("non-empty map");
                ev.remove(&oldest);
            }
            drop(ev);
            Eviction { entry, spill_to: None, cause }
        }
    }

    /// Complete evictions chosen under the lock: write spill artifacts
    /// (demotions) or just stop shard threads (drops). Must run with NO
    /// registry lock held. A failed spill write rolls the victim back to
    /// resident -- staying softly over budget beats losing a table.
    fn finish_evictions(&self, evicted: Vec<Eviction>) {
        for ev in evicted {
            match ev.spill_to {
                None => ev.entry.stop(),
                Some(slot) => {
                    if let Err(e) = self.write_spill(&ev.entry, &slot) {
                        // the table was rolled back to resident: undo
                        // the cause's counter too, or telemetry would
                        // report an eviction that never happened
                        match ev.cause {
                            EvictCause::Budget => self
                                .evictions
                                .fetch_sub(1, Ordering::Relaxed),
                            EvictCause::Ttl => self
                                .ttl_demotions
                                .fetch_sub(1, Ordering::Relaxed),
                        };
                        eprintln!(
                            "spill of evicted table {:?} failed ({e}); \
                             keeping it resident",
                            ev.entry.name
                        );
                    }
                }
            }
        }
    }

    /// Current injectable-clock time in milliseconds (TTL idleness).
    fn now_ms(&self) -> u64 {
        self.wall.now().as_millis() as u64
    }

    /// TTL sweep: demote (or drop, per the spill policy) every
    /// non-default resident table whose last lookup is at least
    /// [`ServerConfig::ttl_secs`] ago. Runs automatically at the top of
    /// every resolve and insert and on the serve accept loop's idle
    /// tick; public so tests (and embedders with their own timers) can
    /// drive it explicitly. A no-op without a configured TTL. Returns
    /// the number of tables expired.
    ///
    /// The sweep completes its demotions SYNCHRONOUSLY -- artifact
    /// write included -- the same discipline as budget eviction on
    /// insert, so quiescent state is deterministic (the soak asserts
    /// resident bytes after every op) and a sweep that returned early
    /// could never hide a half-spilled table. The cost: the sweeping
    /// thread (an accept-loop tick or an unrelated resolve) pays the
    /// victim's artifact write when a TTL actually fires. TTL fires at
    /// most once per idle table per TTL period, so this is a rare
    /// stall, not a steady-state tax; move `finish_evictions` to a
    /// background thread only if spilling multi-GB tables inline ever
    /// shows up in promote/accept latency.
    pub fn expire_idle(&self) -> usize {
        self.expire_idle_protected(&[])
    }

    /// [`expire_idle`](Self::expire_idle) with extra protection: tables
    /// named in `protect` are not expired, however idle. Resolves pass
    /// the table they are about to serve (a lookup arriving AT the
    /// deadline is still a lookup -- it must win the race against its
    /// own sweep), and fan-out frames pass every table they name.
    pub(crate) fn expire_idle_protected(&self, protect: &[&str]) -> usize {
        let Some(ttl) = self.cfg.ttl_secs else {
            return 0;
        };
        let ttl_ms = ttl.saturating_mul(1000);
        let now = self.now_ms();
        // cheap read-only pass first: the common case is nothing expired
        let idle: Vec<String> = {
            let map = self.tables.read().unwrap();
            let def = self.default.lock().unwrap().clone();
            map.values()
                .filter_map(|s| match s {
                    Slot::Resident(e)
                        if def.as_deref() != Some(e.name.as_str())
                            && !protect.iter().any(|p| *p == e.name)
                            && now.saturating_sub(
                                e.last_used_at.load(Ordering::Relaxed))
                                >= ttl_ms =>
                    {
                        Some(e.name.clone())
                    }
                    _ => None,
                })
                .collect()
        };
        if idle.is_empty() {
            return 0;
        }
        let evicted: Vec<Eviction> = {
            let mut map = self.tables.write().unwrap();
            let def = self.default.lock().unwrap().clone();
            let mut out = Vec::new();
            for name in idle {
                // re-check under the write lock: the table may have been
                // touched, unloaded, demoted, or re-elected default while
                // the read pass's lock was released
                let Some(Slot::Resident(e)) = map.get(&name) else {
                    continue;
                };
                if def.as_deref() == Some(name.as_str())
                    || now.saturating_sub(
                        e.last_used_at.load(Ordering::Relaxed)) < ttl_ms
                {
                    continue;
                }
                out.push(self.remove_victim_locked(
                    &mut map, &name, EvictCause::Ttl));
            }
            out
        };
        let n = evicted.len();
        // artifact writes / shard joins outside the lock, same as every
        // other eviction
        self.finish_evictions(evicted);
        n
    }

    /// Throttled TTL sweep for the hot paths (every resolve, the serve
    /// accept loop's idle tick): at most one full sweep per second of
    /// injected-clock time, so `--ttl` costs one atomic load per lookup
    /// instead of an O(tables) scan plus the default-table mutex. TTL
    /// deadlines are whole seconds, so a sub-second sweep lag cannot
    /// change which period a table expires in. Explicit
    /// [`expire_idle`](Self::expire_idle) calls (tests, embedders'
    /// timers) and the insert path are never throttled.
    pub(crate) fn maybe_expire_idle(&self, protect: &[&str]) {
        if self.sweep_due() {
            self.expire_idle_protected(protect);
        }
    }

    /// Claim the current one-second sweep window. `true` means the
    /// caller MUST sweep (it won the CAS; skipping would waste the
    /// window); `false` means no TTL is configured, a sweep ran within
    /// the last clock-second, or another thread just claimed it. Split
    /// out so resolve can check the throttle BEFORE building its
    /// protect list -- the common no-sweep case costs one atomic load,
    /// zero allocation.
    fn sweep_due(&self) -> bool {
        if self.cfg.ttl_secs.is_none() {
            return false;
        }
        let now = self.now_ms();
        let last = self.last_sweep.load(Ordering::Relaxed);
        if now >= last && now - last < 1000 {
            return false;
        }
        // one winner per window; a loser's sweep is already covered
        self.last_sweep
            .compare_exchange(last, now, Ordering::Relaxed, Ordering::Relaxed)
            .is_ok()
    }

    /// Tables expired by the idle TTL since startup (`--ttl`); budget
    /// evictions are counted separately by
    /// [`eviction_count`](Self::eviction_count).
    pub fn ttl_demotion_count(&self) -> u64 {
        self.ttl_demotions.load(Ordering::Relaxed)
    }

    /// Hot-load an embedding artifact as a new table (the `load` admin
    /// op). The backend kind is sniffed from the artifact's 4-byte
    /// magic, so every in-crate kind -- DPQ, dense, scalar-quant,
    /// low-rank, multi-granular, hashing -- hot-loads through the one
    /// op; a short or unknown-magic file is a typed `load_failed`.
    pub fn load_dpq(&self, name: &str, path: &Path) -> Result<Arc<TableEntry>, WireError> {
        let backend = backend::sniff_kind(path)
            .and_then(|kind| backend::load_backend(kind, path))
            .map_err(|e| WireError::Rejected {
                code: "load_failed".into(),
                message: format!("load {path:?}: {e}"),
            })?;
        self.insert(name, backend)
    }

    /// Drop a table -- resident or spilled: later lookups get
    /// `no_such_table`; lookups already queued on its shards are failed,
    /// typed, not stranded; a spilled table's artifact is
    /// garbage-collected from the spill tier. Unloading the default
    /// table explicitly re-elects the first remaining table (by name) as
    /// default; the returned [`UnloadOutcome`] reports the default in
    /// force after the unload.
    pub fn unload(&self, name: &str) -> Result<UnloadOutcome, WireError> {
        let (slot, outcome) = {
            let mut map = self.tables.write().unwrap();
            let slot = map
                .remove(name)
                .ok_or_else(|| WireError::NoSuchTable(name.to_string()))?;
            let mut def = self.default.lock().unwrap();
            let was_default = def.as_deref() == Some(name);
            if was_default {
                *def = map.keys().next().cloned();
            }
            (slot, UnloadOutcome { was_default, new_default: def.clone() })
        };
        match slot {
            Slot::Resident(entry) => entry.stop(),
            Slot::Spilled(s) => {
                // GC the artifact (a promoter mid-reload fails its map
                // identity check and answers no_such_table) and wake
                // anyone blocked on the orphaned slot's gate
                if let Some(dir) = &self.cfg.spill_dir {
                    let _ = std::fs::remove_file(dir.join(&s.file));
                }
                self.sync_spill_manifest();
                s.cv.notify_all();
            }
        }
        Ok(outcome)
    }

    /// The RESIDENT table registered as `name`, if any. A spilled table
    /// returns `None` here (this accessor must never trigger a reload);
    /// use [`residency`](Self::residency) / [`spilled`](Self::spilled)
    /// to observe the spill tier, or [`resolve`](Self::resolve) to
    /// promote.
    pub fn get(&self, name: &str) -> Option<Arc<TableEntry>> {
        match self.tables.read().unwrap().get(name) {
            Some(Slot::Resident(e)) => Some(e.clone()),
            _ => None,
        }
    }

    /// The full residency slot for `name` in one map read -- the
    /// consistent view `stats` answers from (a `get` + `spilled` pair
    /// could race a promotion and see neither tier).
    pub(crate) fn slot_of(&self, name: &str) -> Option<Slot> {
        self.tables.read().unwrap().get(name).cloned()
    }

    /// One consistent snapshot of every slot, in name order -- so an
    /// aggregate `stats` poll can never count a table in both tiers
    /// (separate resident/spilled listings could, around a demotion).
    pub(crate) fn snapshot_slots(&self) -> Vec<(String, Slot)> {
        self.tables
            .read()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.clone()))
            .collect()
    }

    /// Route a request's optional table name: `None` means the default
    /// table (v1 frames and table-less v2 frames). A successful resolve
    /// stamps the table's LRU clock -- this is the "recently looked up"
    /// signal eviction ranks by. Resolving a SPILLED table transparently
    /// promotes it first (single-flight; see the module docs), so the
    /// spill tier is invisible to lookups except in latency. A bounded
    /// number of promotion attempts guards against promote/demote
    /// thrash within one request.
    pub fn resolve(&self, name: Option<&str>) -> Result<Arc<TableEntry>, WireError> {
        self.resolve_protected(name, &[])
    }

    /// [`resolve`](Self::resolve) with extra eviction protection: any
    /// promotion this resolve performs will not evict a table named in
    /// `protect`. The fan-out op protects EVERY table of its frame, so
    /// promoting section N can never demote section M's table out from
    /// under the same frame (which would livelock a tight budget: each
    /// retry re-plays the same promote/evict cycle). The registry may
    /// go softly over budget for the frame's duration; the caller
    /// re-enforces via [`enforce_budget`](Self::enforce_budget).
    pub(crate) fn resolve_protected(
        &self,
        name: Option<&str>,
        protect: &[&str],
    ) -> Result<Arc<TableEntry>, WireError> {
        // TTL sweep rides on resolves (traffic to ANY table expires the
        // idle ones), throttled to one sweep per clock-second -- the
        // throttle is checked FIRST so the common no-sweep case costs
        // one atomic load and no allocation. The table this request is
        // about to serve is protected: a lookup arriving at the
        // deadline is a lookup.
        if self.sweep_due() {
            let mut prot: Vec<&str> = protect.to_vec();
            if let Some(n) = name {
                prot.push(n);
            }
            self.expire_idle_protected(&prot);
        }
        let name = match name {
            Some(n) => n.to_string(),
            None => {
                let def = self.default.lock().unwrap().clone();
                def.ok_or_else(|| {
                    WireError::NoSuchTable("(default: no tables loaded)".into())
                })?
            }
        };
        for _ in 0..PROMOTE_ATTEMPTS {
            match self.slot_of(&name) {
                None => return Err(WireError::NoSuchTable(name)),
                Some(Slot::Resident(e)) => {
                    self.touch(&e);
                    return Ok(e);
                }
                Some(Slot::Spilled(s)) => match self.promote(&s, protect)? {
                    Some(e) => {
                        self.touch(&e);
                        return Ok(e);
                    }
                    // the world changed while we waited on the gate
                    // (promoted by another caller, re-spilled, unloaded,
                    // replaced): re-resolve from the map
                    None => continue,
                },
            }
        }
        Err(WireError::Rejected {
            code: "reload_failed".into(),
            message: format!(
                "table {name:?} is being promoted and demoted concurrently \
                 (thrashing); retry"),
        })
    }

    /// Re-enforce the memory budget now (default table pinned, nothing
    /// else protected). Called after an op that deliberately went
    /// softly over budget -- e.g. a fan-out frame whose promotions
    /// protected all of its tables -- so quiescent state respects the
    /// budget again. A no-op without a configured budget.
    pub fn enforce_budget(&self) {
        if self.cfg.mem_budget_bytes.is_none() {
            return;
        }
        let evicted = {
            let mut map = self.tables.write().unwrap();
            self.enforce_budget_locked(&mut map, &[])
        };
        self.finish_evictions(evicted);
    }

    /// Stamp `entry` as most-recently-used: the logical LRU tick (for
    /// eviction ordering) and the injectable-clock time (for TTL
    /// idleness).
    pub(crate) fn touch(&self, entry: &TableEntry) {
        entry.last_used.store(
            self.clock.fetch_add(1, Ordering::Relaxed) + 1,
            Ordering::Relaxed,
        );
        entry.last_used_at.store(self.now_ms(), Ordering::Relaxed);
    }

    /// The current default table name (v1 frames route here).
    pub fn default_name(&self) -> Option<String> {
        self.default.lock().unwrap().clone()
    }

    /// Make `name` the default table. The default is pinned: eviction
    /// never removes it.
    pub fn set_default(&self, name: &str) -> Result<(), WireError> {
        // existence check and assignment under the tables lock (same
        // order as insert/unload) so a racing unload cannot leave the
        // default naming a just-removed table
        let map = self.tables.read().unwrap();
        if !map.contains_key(name) {
            return Err(WireError::NoSuchTable(name.to_string()));
        }
        *self.default.lock().unwrap() = Some(name.to_string());
        // an explicit choice is never provisional
        self.default_provisional.store(false, Ordering::Relaxed);
        Ok(())
    }

    /// All RESIDENT tables in name order (spilled tables are listed by
    /// [`list_spilled`](Self::list_spilled)).
    pub fn list(&self) -> Vec<Arc<TableEntry>> {
        self.tables
            .read()
            .unwrap()
            .values()
            .filter_map(|s| match s {
                Slot::Resident(e) => Some(e.clone()),
                Slot::Spilled(_) => None,
            })
            .collect()
    }

    /// All SPILLED tables in name order.
    pub fn list_spilled(&self) -> Vec<Arc<SpilledTable>> {
        self.tables
            .read()
            .unwrap()
            .values()
            .filter_map(|s| match s {
                Slot::Spilled(sp) => Some(sp.clone()),
                Slot::Resident(_) => None,
            })
            .collect()
    }

    /// The spill-tier record for `name`, if that table is currently
    /// spilled.
    pub fn spilled(&self, name: &str) -> Option<Arc<SpilledTable>> {
        match self.tables.read().unwrap().get(name) {
            Some(Slot::Spilled(s)) => Some(s.clone()),
            _ => None,
        }
    }

    /// Resolve a content digest to the spilled slot carrying it, as
    /// `(slot, artifact path)`. Only the spill tier is addressable by
    /// digest -- a resident table has no published artifact to serve.
    /// This is the registry half of the `fetch_artifact` wire op.
    pub fn spilled_by_digest(
        &self,
        sha256: &str,
    ) -> Option<(Arc<SpilledTable>, PathBuf)> {
        let dir = self.cfg.spill_dir.clone()?;
        self.list_spilled().into_iter().find_map(|s| match s.digest() {
            Some((hex, _)) if hex == sha256 => {
                let path = dir.join(&s.file);
                Some((s, path))
            }
            _ => None,
        })
    }

    /// Register a table as a `Spilled` slot over an artifact that
    /// already sits in the spill directory -- the adoption half of peer
    /// hydration: `repro hydrate` writes the fetched bytes into the
    /// tier (write-then-rename) and then calls this. The on-disk file
    /// is re-hashed against the seed's digest before anything is
    /// registered, so a torn or tampered landing never becomes a
    /// serveable slot. Same shape floor and provisional-default
    /// election as startup spill adoption; the spill manifest is synced
    /// afterwards, so a restart re-adopts the table without the peer.
    /// Typed rejections: `table_exists`, `spill_disabled`,
    /// `hydrate_failed` (bad seed or digest mismatch).
    pub fn adopt_spilled(&self, seed: SpillSeed) -> Result<(), WireError> {
        let fail = |m: String| WireError::Rejected {
            code: "hydrate_failed".into(),
            message: m,
        };
        let Some(dir) = self.cfg.spill_dir.clone() else {
            return Err(WireError::Rejected {
                code: "spill_disabled".into(),
                message: format!(
                    "cannot adopt table {:?}: no spill dir is configured",
                    seed.name),
            });
        };
        // same shape floor `insert` and spill adoption enforce
        if seed.vocab == 0 || seed.d == 0 || seed.name.is_empty()
            || seed.name.contains('=')
        {
            return Err(fail(format!(
                "table {:?} has invalid shape [{}, {}]",
                seed.name, seed.vocab, seed.d)));
        }
        if !crate::util::sha256::is_hex_digest(&seed.sha256) {
            return Err(fail(format!(
                "table {:?} sha256 {:?} is not a 64-char lowercase hex \
                 digest", seed.name, seed.sha256)));
        }
        let path = dir.join(&seed.file);
        match backend::artifact_io::file_sha256(&path) {
            Ok((hex, bytes)) if hex == seed.sha256 && bytes == seed.bytes => {}
            Ok((hex, bytes)) => {
                return Err(fail(format!(
                    "artifact {:?} for table {:?} does not match its \
                     advertised digest (expected {} bytes sha256 {}; found \
                     {bytes} bytes {hex})",
                    seed.file, seed.name, seed.bytes, seed.sha256)));
            }
            Err(e) => {
                return Err(fail(format!(
                    "artifact {:?} for table {:?} is unreadable: {e}",
                    seed.file, seed.name)));
            }
        }
        let slot = Arc::new(SpilledTable {
            name: seed.name.clone(),
            kind: seed.kind,
            file: seed.file,
            vocab: seed.vocab,
            d: seed.d,
            storage_bits: seed.storage_bits,
            replicas: AtomicUsize::new(seed.replicas.clamp(1, MAX_REPLICAS)),
            row_cache: AtomicU64::new(seed.row_cache),
            stats: Arc::new(Stats::default()),
            state: Mutex::new(SpillPhase::Ready),
            cv: Condvar::new(),
            digest: Mutex::new(Some((seed.sha256, seed.bytes))),
        });
        {
            // lock order: tables, then default -- same as insert/unload
            let mut map = self.tables.write().unwrap();
            let mut def = self.default.lock().unwrap();
            if map.contains_key(&seed.name) {
                return Err(WireError::TableExists(seed.name));
            }
            if def.is_none() {
                *def = Some(seed.name.clone());
                self.default_provisional.store(true, Ordering::Relaxed);
            }
            map.insert(seed.name, Slot::Spilled(slot));
        }
        self.sync_spill_manifest();
        Ok(())
    }

    /// Spill-manifest rewrites whose write-then-rename failed (the
    /// published `spill.json` was left drifted until the next
    /// transition rewrote it). Surfaced as a registry-level stat.
    pub fn spill_manifest_write_failures(&self) -> u64 {
        self.spill_manifest_write_failures.load(Ordering::Relaxed)
    }

    /// Current residency of `name`, `None` when no such table is
    /// registered. Reports `Lost` from the slot's sticky phase without
    /// touching the filesystem; [`probe_spilled`](Self::probe_spilled)
    /// re-checks the disk.
    pub fn residency(&self, name: &str) -> Option<Residency> {
        match self.tables.read().unwrap().get(name) {
            None => None,
            Some(Slot::Resident(_)) => Some(Residency::Resident),
            Some(Slot::Spilled(s)) => {
                Some(match *s.state.lock().unwrap() {
                    SpillPhase::Lost => Residency::Lost,
                    _ => Residency::Spilled,
                })
            }
        }
    }

    /// Probe a spilled slot against the filesystem: a missing artifact
    /// (deleted out-of-band) is `Lost`; a reappeared one heals a sticky
    /// `Lost` back to `Spilled`. Slots mid-transition report `Spilled`
    /// without touching the disk (their file state is owned by the
    /// transition holder).
    pub fn probe_spilled(&self, s: &SpilledTable) -> Residency {
        let Some(dir) = &self.cfg.spill_dir else {
            return Residency::Lost; // spilled slot without a tier: defect
        };
        let mut ph = s.state.lock().unwrap();
        match *ph {
            SpillPhase::Spilling | SpillPhase::Promoting => Residency::Spilled,
            SpillPhase::Ready | SpillPhase::Lost => {
                if dir.join(&s.file).is_file() {
                    *ph = SpillPhase::Ready;
                    Residency::Spilled
                } else {
                    *ph = SpillPhase::Lost;
                    Residency::Lost
                }
            }
        }
    }

    /// Number of registered tables, resident AND spilled.
    pub fn len(&self) -> usize {
        self.tables.read().unwrap().len()
    }

    /// True when no tables are registered.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total resident bytes across all RESIDENT tables (the quantity the
    /// memory budget bounds; spilled tables cost disk, not budget).
    pub fn resident_bytes(&self) -> u64 {
        self.list().iter().map(|e| e.resident_bytes()).sum()
    }

    /// Tables evicted under memory pressure since startup.
    pub fn eviction_count(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// True if a table named `name` was evicted and not since reloaded.
    pub fn was_evicted(&self, name: &str) -> bool {
        self.evicted.lock().unwrap().contains_key(name)
    }

    /// Eviction history as `(table name, times evicted)`, for tables not
    /// since reloaded (the most recent [`EVICTED_HISTORY`] names).
    pub fn evicted_tables(&self) -> Vec<(String, u64)> {
        self.evicted
            .lock()
            .unwrap()
            .iter()
            .map(|(k, (count, _))| (k.clone(), *count))
            .collect()
    }

    /// Count one cross-table fan-out frame (surfaced by `stats`).
    pub(crate) fn note_fanout(&self) {
        self.fanout_requests.fetch_add(1, Ordering::Relaxed);
    }

    /// Cross-table fan-out frames served since startup.
    pub fn fanout_count(&self) -> u64 {
        self.fanout_requests.load(Ordering::Relaxed)
    }

    // ---- spill tier: demote / promote ----

    /// Tables demoted to the spill tier since startup (budget evictions
    /// in spill mode plus explicit `demote` ops).
    pub fn spill_count(&self) -> u64 {
        self.spills.load(Ordering::Relaxed)
    }

    /// Tables promoted back from the spill tier since startup. Exactly
    /// one promotion happens per cold table however many concurrent
    /// lookups hit it (the single-flight gate).
    pub fn promote_count(&self) -> u64 {
        self.promotes.load(Ordering::Relaxed)
    }

    /// `(p50, p99)` over recent promote (reload) wall-clock times in
    /// seconds; `None` before the first promotion.
    pub fn promote_latency(&self) -> Option<(f64, f64)> {
        self.promote_ring.percentiles()
    }

    /// Explicitly demote a RESIDENT table to the spill tier (the
    /// `demote` admin op): serialize it through its kind's artifact
    /// format into the spill directory (write-then-rename,
    /// manifest-tracked) and release its memory. The next lookup
    /// transparently promotes it back. Typed rejections: no spill dir
    /// configured (`spill_disabled`), unknown table (`no_such_table`),
    /// already spilled (`not_resident`), artifact write failure
    /// (`demote_failed` -- the table stays resident; a failed spill
    /// must never lose data). Demoting the default table is allowed --
    /// the next v1 frame just pays one reload.
    pub fn demote(&self, name: &str) -> Result<Arc<SpilledTable>, WireError> {
        if self.cfg.spill_dir.is_none() {
            return Err(WireError::Rejected {
                code: "spill_disabled".into(),
                message: "no spill tier configured (start the server with \
                          --spill-dir)".into(),
            });
        }
        let (entry, slot) = {
            let mut map = self.tables.write().unwrap();
            match map.get(name) {
                None => return Err(WireError::NoSuchTable(name.to_string())),
                Some(Slot::Spilled(_)) => {
                    return Err(WireError::Rejected {
                        code: "not_resident".into(),
                        message: format!("table {name:?} is already spilled"),
                    })
                }
                Some(Slot::Resident(e)) => {
                    let entry = e.clone();
                    // the Spilling placeholder takes the slot under the
                    // lock; racing lookups block on its gate until the
                    // artifact write below publishes (or rolls back)
                    let slot = Arc::new(SpilledTable::from_entry(&entry));
                    map.insert(name.to_string(), Slot::Spilled(slot.clone()));
                    (entry, slot)
                }
            }
        };
        if !self.write_spill(&entry, &slot)? {
            // lost a race with `unload`: the table is gone and the
            // artifact was garbage-collected -- reporting "spilled"
            // would name a file that does not exist
            return Err(WireError::NoSuchTable(name.to_string()));
        }
        Ok(slot)
    }

    /// Live-resize a table's batcher-shard replica count (the
    /// `set_replicas` wire op). A RESIDENT table is swapped to a fresh
    /// entry with `n` replicas sharing the same backend `Arc` and
    /// table-level [`Stats`] (counters continue; per-replica rings
    /// reset); the old entry's shards are stopped OUTSIDE the lock --
    /// in-flight batches finish serving, and a lookup whose queue was
    /// closed by the swap is transparently retried against the new
    /// entry by the connection handler, so a resize is invisible
    /// mid-traffic. A SPILLED table just records `n` for its next
    /// promotion. Returns the replica count now in force. Typed
    /// rejections: `bad_replicas` (outside `1..=`[`MAX_REPLICAS`]),
    /// `no_such_table`.
    pub fn set_replicas(&self, name: &str, n: usize) -> Result<usize, WireError> {
        validate_replicas(n)?;
        if self.stop.load(Ordering::Relaxed) {
            return Err(WireError::Rejected {
                code: "shutting_down".into(),
                message: "registry is shutting down".into(),
            });
        }
        let old = {
            let mut map = self.tables.write().unwrap();
            match map.get(name) {
                None => return Err(WireError::NoSuchTable(name.to_string())),
                Some(Slot::Spilled(s)) => {
                    s.replicas.store(n, Ordering::Relaxed);
                    None // manifest rewritten below, outside the lock
                }
                Some(Slot::Resident(e)) if e.replica_count() == n => {
                    return Ok(n); // already there: no swap, no churn
                }
                Some(Slot::Resident(e)) => {
                    let old = e.clone();
                    // the fresh entry's cache starts EMPTY at the old
                    // capacity: a resize swaps batcher shards, and a
                    // stale cache surviving the swap would be the one
                    // state the twin-registry equivalence test cannot
                    // reach -- structural invalidation keeps the
                    // contract trivially true
                    let entry = TableEntry::spawn(
                        name, old.backend.clone(), &self.cfg, &self.stop,
                        old.stats.clone(), n,
                        old.row_cache.cap_bytes());
                    // carry the LRU/idle stamps: a resize is an admin
                    // action, not a lookup -- it must not refresh the
                    // table's eviction rank
                    entry.last_used.store(
                        old.last_used.load(Ordering::Relaxed),
                        Ordering::Relaxed);
                    entry.last_used_at.store(
                        old.last_used_at.load(Ordering::Relaxed),
                        Ordering::Relaxed);
                    map.insert(name.to_string(), Slot::Resident(entry));
                    Some(old)
                }
            }
        };
        match old {
            Some(old) => old.stop(), // outside the lock: batches finish
            None => self.sync_spill_manifest(), // spilled: record n
        }
        Ok(n)
    }

    /// Live-resize a table's hot-row cache byte capacity (the
    /// `set_row_cache` wire op). A RESIDENT table's cache is resized in
    /// place -- shrinking evicts LRU-first immediately, `0` disables
    /// and frees everything, growing takes effect on the next misses --
    /// and the budget pass then reconciles the new capacity against
    /// `--mem-budget` (so the call may come back with a SMALLER cap
    /// than requested, or evict colder tables to make room). A SPILLED
    /// table just records the capacity for its next promotion. Returns
    /// the capacity now in force. Typed rejection: `no_such_table`.
    pub fn set_row_cache(&self, name: &str, bytes: u64) -> Result<u64, WireError> {
        if self.stop.load(Ordering::Relaxed) {
            return Err(WireError::Rejected {
                code: "shutting_down".into(),
                message: "registry is shutting down".into(),
            });
        }
        let (cap, spilled, evicted) = {
            let mut map = self.tables.write().unwrap();
            match map.get(name) {
                None => return Err(WireError::NoSuchTable(name.to_string())),
                Some(Slot::Spilled(s)) => {
                    s.row_cache.store(bytes, Ordering::Relaxed);
                    (bytes, true, Vec::new())
                }
                Some(Slot::Resident(e)) => {
                    let entry = e.clone();
                    entry.row_cache.set_capacity(bytes);
                    // the resized table is protected: the budget pass
                    // may shrink its fresh cache, but must not evict
                    // the very table the operator is tuning
                    let evicted = self.enforce_budget_locked(&mut map, &[name]);
                    (entry.row_cache.cap_bytes(), false, evicted)
                }
            }
        };
        if spilled {
            self.sync_spill_manifest(); // record the cap for promotion
        } else {
            self.finish_evictions(evicted);
        }
        Ok(cap)
    }

    /// Write a demotion's artifact and finish the transition. Runs with
    /// NO registry lock held; the slot is already in the map in phase
    /// `Spilling`. On success (`Ok(true)`): artifact published
    /// write-then-rename, manifest synced, phase -> `Ready`, shard
    /// threads stopped (queued lookups fail typed; in-flight batches
    /// finish serving). `Ok(false)`: the table was UNLOADED while the
    /// artifact was being written -- the orphaned artifact is GC'd and
    /// the entry stopped; the demotion did not take effect. On write
    /// failure: the table is rolled back to `Resident` -- nothing is
    /// lost -- and the error is returned.
    fn write_spill(
        &self,
        entry: &Arc<TableEntry>,
        slot: &Arc<SpilledTable>,
    ) -> Result<bool, WireError> {
        let dir = self
            .cfg
            .spill_dir
            .clone()
            .expect("write_spill requires a configured spill dir");
        let publish = dir.join(&slot.file);
        let tmp = dir.join(snap_tmp_name(&slot.file));
        let written = entry
            .backend
            .save_artifact(&tmp)
            .map_err(|e| format!("serialize: {e}"))
            .and_then(|_| {
                // hash BEFORE publish: the digest lands on the slot the
                // moment the artifact is visible, so a published
                // artifact is never in an unverifiable window (and a
                // hash failure rolls back like any other write failure)
                backend::artifact_io::file_sha256(&tmp)
                    .map_err(|e| format!("hash: {e}"))
            })
            .and_then(|(hex, bytes)| {
                std::fs::rename(&tmp, &publish)
                    .map_err(|e| format!("publish: {e}"))?;
                slot.set_digest(hex, bytes);
                Ok(())
            });
        if let Err(msg) = written {
            let _ = std::fs::remove_file(&tmp);
            // roll back to Resident: the entry was never stopped, so
            // the table keeps serving (softly over budget beats gone)
            let mut map = self.tables.write().unwrap();
            match map.get(&slot.name) {
                Some(Slot::Spilled(cur)) if Arc::ptr_eq(cur, slot) => {
                    map.insert(slot.name.clone(), Slot::Resident(entry.clone()));
                    drop(map);
                }
                _ => {
                    // unloaded/replaced while we wrote: nothing to roll
                    // back into; just stop the orphaned entry
                    drop(map);
                    entry.stop();
                }
            }
            slot.set_phase(SpillPhase::Ready);
            // a concurrent transition may have snapshotted the manifest
            // while this slot was still in the map as Spilled; rewrite
            // it so the rolled-back table is not recorded as spilled
            // with an artifact that never published
            self.sync_spill_manifest();
            return Err(WireError::Rejected {
                code: "demote_failed".into(),
                message: format!(
                    "spill of table {:?} to {publish:?} failed: {msg}",
                    slot.name),
            });
        }
        // the table may have been unloaded while we wrote: GC the
        // now-orphaned artifact instead of leaving untracked files
        {
            let map = self.tables.read().unwrap();
            match map.get(&slot.name) {
                Some(Slot::Spilled(cur)) if Arc::ptr_eq(cur, slot) => {}
                _ => {
                    drop(map);
                    let _ = std::fs::remove_file(&publish);
                    slot.set_phase(SpillPhase::Ready);
                    entry.stop();
                    self.sync_spill_manifest();
                    return Ok(false);
                }
            }
        }
        self.spills.fetch_add(1, Ordering::Relaxed);
        // manifest BEFORE the phase flip: a promoter released by the
        // gate must find the tier consistent
        self.sync_spill_manifest();
        slot.set_phase(SpillPhase::Ready);
        // stop LAST: in-flight batches finish serving (the backend is
        // alive until the last Arc drops); still-queued lookups fail
        // typed and re-resolve into a promotion
        entry.stop();
        Ok(true)
    }

    /// Promote a spilled table back to resident. Single-flight: exactly
    /// one caller performs the reload; concurrent callers block on the
    /// slot's gate and re-resolve. Returns `Ok(None)` when the world
    /// changed under the claim (promoted by another caller, unloaded,
    /// replaced) -- the caller re-resolves from the map. Typed
    /// `reload_failed` on a corrupt or missing artifact (the registry
    /// keeps serving every other table).
    fn promote(
        &self,
        s: &Arc<SpilledTable>,
        protect: &[&str],
    ) -> Result<Option<Arc<TableEntry>>, WireError> {
        if self.stop.load(Ordering::Relaxed) {
            return Err(WireError::Rejected {
                code: "shutting_down".into(),
                message: "registry is shutting down".into(),
            });
        }
        let dir = self.cfg.spill_dir.clone().ok_or_else(|| {
            WireError::Rejected {
                code: "reload_failed".into(),
                message: format!(
                    "table {:?} is spilled but no spill dir is configured",
                    s.name),
            }
        })?;
        let path = dir.join(&s.file);
        // ---- claim the single-flight gate ----
        {
            let mut ph = s.state.lock().unwrap();
            loop {
                match *ph {
                    SpillPhase::Spilling | SpillPhase::Promoting => {
                        ph = s.cv.wait(ph).unwrap();
                    }
                    SpillPhase::Lost => {
                        // advisory: re-probe, the operator may have
                        // restored the artifact out-of-band
                        if path.is_file() {
                            *ph = SpillPhase::Promoting;
                            break;
                        }
                        return Err(WireError::Rejected {
                            code: "reload_failed".into(),
                            message: format!(
                                "table {:?} is lost: spill artifact {:?} is \
                                 missing (deleted out-of-band?)",
                                s.name, s.file),
                        });
                    }
                    SpillPhase::Ready => {
                        *ph = SpillPhase::Promoting;
                        break;
                    }
                }
            }
        }
        // We hold the sole Promoting claim; every exit below MUST
        // un-claim via set_phase. First re-check the map: while we
        // waited, another caller may have promoted (slot gone), or the
        // table may have been unloaded/replaced.
        {
            let map = self.tables.read().unwrap();
            match map.get(&s.name) {
                Some(Slot::Spilled(cur)) if Arc::ptr_eq(cur, s) => {}
                _ => {
                    s.set_phase(SpillPhase::Ready);
                    return Ok(None);
                }
            }
        }
        let t0 = Instant::now();
        let reload_failed = |message: String| WireError::Rejected {
            code: "reload_failed".into(),
            message,
        };
        // Verify the artifact's content digest BEFORE parsing: a
        // flipped bit in codebook bytes can survive every shape check
        // and silently serve wrong embeddings. Legacy slots (adopted
        // from a digest-less manifest) have nothing to verify against;
        // they gain a digest on their next demote. An unreadable file
        // falls through to the load below, whose error path already
        // distinguishes a concurrent unload from genuine loss.
        if let Some((want_hex, want_bytes)) = s.digest() {
            if let Ok((got_hex, got_bytes)) =
                backend::artifact_io::file_sha256(&path)
            {
                if got_hex != want_hex || got_bytes != want_bytes {
                    s.set_phase(SpillPhase::Ready);
                    return Err(reload_failed(format!(
                        "spill artifact {:?} for table {:?} does not match \
                         its recorded digest (expected {want_bytes} bytes \
                         sha256 {want_hex}; found {got_bytes} bytes \
                         {got_hex}); refusing to parse",
                        s.file, s.name)));
                }
            }
        }
        let backend = match backend::load_backend(&s.kind, &path) {
            Ok(b) => b,
            Err(e) => {
                // A concurrent unload removes the slot AND GCs the
                // artifact: that is a deliberate removal, not data loss
                // -- re-resolve so the caller answers no_such_table
                // instead of a misleading "lost" error.
                {
                    let map = self.tables.read().unwrap();
                    match map.get(&s.name) {
                        Some(Slot::Spilled(cur)) if Arc::ptr_eq(cur, s) => {}
                        _ => {
                            drop(map);
                            s.set_phase(SpillPhase::Ready);
                            return Ok(None);
                        }
                    }
                }
                let lost = !path.is_file();
                s.set_phase(if lost { SpillPhase::Lost } else { SpillPhase::Ready });
                return Err(reload_failed(if lost {
                    format!(
                        "table {:?} is lost: spill artifact {:?} is missing \
                         (deleted out-of-band?)", s.name, s.file)
                } else {
                    format!(
                        "reload of table {:?} from spill artifact {:?} \
                         failed: {e}", s.name, s.file)
                }));
            }
        };
        // a swapped artifact must fail loudly, not serve the wrong table
        if backend.vocab() != s.vocab || backend.d() != s.d {
            s.set_phase(SpillPhase::Ready);
            return Err(reload_failed(format!(
                "spill artifact {:?} has shape [{}, {}] but table {:?} was \
                 demoted with [{}, {}]",
                s.file, backend.vocab(), backend.d(), s.name, s.vocab, s.d)));
        }
        let (entry, evicted) = {
            let mut map = self.tables.write().unwrap();
            match map.get(&s.name) {
                Some(Slot::Spilled(cur)) if Arc::ptr_eq(cur, s) => {}
                _ => {
                    drop(map);
                    s.set_phase(SpillPhase::Ready);
                    return Ok(None);
                }
            }
            let entry = TableEntry::spawn(
                &s.name, backend, &self.cfg, &self.stop, s.stats.clone(),
                s.replicas(), s.row_cache_bytes());
            entry.last_used.store(
                self.clock.fetch_add(1, Ordering::Relaxed) + 1,
                Ordering::Relaxed,
            );
            entry.last_used_at.store(self.now_ms(), Ordering::Relaxed);
            map.insert(s.name.clone(), Slot::Resident(entry.clone()));
            // The artifact is consumed: a later demote rewrites it, and
            // leaving it would let the manifest drift from the registry.
            // The unlink MUST happen while the write lock is still held:
            // a re-demote of this very table (which needs the write lock
            // to swap the slot back to Spilled) publishes a FRESH
            // artifact at the same deterministic path -- deleting after
            // the lock is released could destroy that fresh artifact and
            // lose the table permanently.
            let _ = std::fs::remove_file(&path);
            // promotion re-enters the LRU and may evict someone else to
            // make room; the promoted table (plus the caller's protect
            // set -- e.g. a fan-out frame's other tables) is pinned so
            // this pass can never evict what the request still needs
            let mut prot: Vec<&str> = protect.to_vec();
            prot.push(s.name.as_str());
            let evicted = self.enforce_budget_locked(&mut map, &prot);
            (entry, evicted)
        };
        self.promotes.fetch_add(1, Ordering::Relaxed);
        self.promote_ring.record(t0.elapsed().as_secs_f64());
        self.sync_spill_manifest();
        s.set_phase(SpillPhase::Ready);
        self.finish_evictions(evicted);
        Ok(Some(entry))
    }

    /// Rewrite the spill-tier manifest from the current table map
    /// (write-then-rename; serialized by `spill_mu`). Best-effort: a
    /// manifest write failure never fails the serving path, it only
    /// degrades offline inspectability.
    fn sync_spill_manifest(&self) {
        let Some(dir) = &self.cfg.spill_dir else {
            return;
        };
        let _g = self.spill_mu.lock().unwrap();
        let tables: Vec<Json> = self
            .list_spilled()
            .iter()
            .map(|s| {
                let mut pairs = vec![
                    ("name", Json::str(s.name.as_str())),
                    ("kind", Json::str(s.kind.as_str())),
                    ("file", Json::str(s.file.as_str())),
                    ("vocab", Json::num(s.vocab as f64)),
                    ("d", Json::num(s.d as f64)),
                    ("storage_bits", Json::num(s.storage_bits as f64)),
                    ("replicas", Json::num(s.replicas() as f64)),
                    ("row_cache", Json::num(s.row_cache_bytes() as f64)),
                ];
                // Content digest, recorded at publish time. A legacy
                // slot (adopted from a pre-digest manifest) is
                // backfilled HERE by hashing its on-disk artifact --
                // "legacy verifies on first rewrite". A slot whose
                // artifact is not hashable right now (mid-Spilling,
                // lost) stays digest-less this round and retries on
                // the next rewrite.
                let digest = s.digest().or_else(|| {
                    backend::artifact_io::file_sha256(&dir.join(&s.file))
                        .ok()
                        .map(|(hex, bytes)| {
                            s.set_digest(hex.clone(), bytes);
                            (hex, bytes)
                        })
                });
                if let Some((hex, bytes)) = &digest {
                    pairs.push(("sha256", Json::str(hex.as_str())));
                    pairs.push(("bytes", Json::num(*bytes as f64)));
                }
                // provenance: which write path produced the artifact
                pairs.push(("op", Json::str("spill")));
                Json::obj(pairs)
            })
            .collect();
        let j = Json::obj(vec![
            ("format", Json::str(SPILL_FORMAT)),
            ("v", Json::num(1.0)),
            ("tables", Json::arr(tables)),
        ]);
        let tmp = dir.join(snap_tmp_name(SPILL_MANIFEST));
        // Write-then-rename, counting a failure of EITHER step: until
        // some later transition rewrites it, the published spill.json
        // is drifted from the registry. No explicit retry machinery is
        // needed -- every spill/promote/unload transition rewrites the
        // whole manifest from the live map, so the next one heals the
        // drift; the counter is what makes the episode observable.
        // (Ignoring the rename result here used to strand the .tmp
        // file forever AND hide the drift entirely.)
        let ok = std::fs::write(&tmp, j.to_string()).is_ok()
            && std::fs::rename(&tmp, dir.join(SPILL_MANIFEST)).is_ok();
        if !ok {
            let _ = std::fs::remove_file(&tmp);
            self.spill_manifest_write_failures
                .fetch_add(1, Ordering::Relaxed);
        }
    }

    // ---- snapshot / restore ----

    /// Serialize the whole registry into `dir`: one artifact file per
    /// table plus a versioned [`SNAPSHOT_MANIFEST`]. Returns the
    /// manifest path. Every file (artifacts included) is written to a
    /// temp file and renamed, so a crash mid-snapshot never publishes a
    /// half-written file; after the manifest is published, artifact
    /// files from PREVIOUS snapshots into the same directory that the
    /// new manifest no longer references are removed (best-effort), so
    /// a scheduled snapshot into a fixed directory does not grow
    /// without bound as tables come and go. Backends are immutable once
    /// registered, so a snapshot taken mid-serving is consistent;
    /// tables loaded or unloaded while the snapshot runs may or may not
    /// be included. SPILLED tables are included too -- their published
    /// spill artifacts are copied into the snapshot (re-serialized from
    /// memory if a concurrent promotion consumes the artifact
    /// mid-copy), so restoring a snapshot never silently drops the cold
    /// tier (restored tables all come back resident). Concurrent
    /// snapshots into the SAME directory are never torn (unique temp
    /// names, and GC leaves `.tmp` files alone) but may garbage-collect
    /// each other's just-published artifacts -- give each schedule its
    /// own directory.
    pub fn snapshot(&self, dir: &Path) -> Result<PathBuf, WireError> {
        let fail = |what: String| {
            move |e: &dyn std::fmt::Display| WireError::Rejected {
                code: "snapshot_failed".into(),
                message: format!("{what}: {e}"),
            }
        };
        std::fs::create_dir_all(dir)
            .map_err(|e| fail(format!("create {dir:?}"))(&e))?;
        let default = self.default_name();
        let slots = self.snapshot_slots();
        let mut tables = Vec::new();
        let mut fresh: Vec<String> = Vec::with_capacity(slots.len());
        let mut included: Vec<&str> = Vec::with_capacity(slots.len());
        for (name, slot) in slots.iter() {
            let (kind, vocab, d, storage_bits, replicas, row_cache) =
                match slot {
                    Slot::Resident(e) => (
                        e.backend.kind().to_string(),
                        e.backend.vocab(),
                        e.backend.d(),
                        e.backend.storage_bits(),
                        e.replica_count(),
                        e.row_cache.cap_bytes(),
                    ),
                    Slot::Spilled(s) => {
                        (s.kind.clone(), s.vocab, s.d, s.storage_bits,
                         s.replicas(), s.row_cache_bytes())
                    }
                };
            // Artifacts get the same write-then-rename discipline as the
            // manifest: re-snapshotting into the SAME directory must
            // never half-overwrite an artifact the surviving (old)
            // manifest still points at -- a same-shape partial rewrite
            // would pass every size/shape check on restore and silently
            // serve wrong bytes. The PUBLISHED name is content-addressed
            // (`sha256-<hex>.art`, computed after the write below), so
            // the temp name is derived from the table name instead.
            let tmp = dir.join(snap_tmp_name(&sanitize_file_stem(name)));
            // Ok(true) = artifact written; Ok(false) = the table was
            // deliberately unloaded mid-snapshot (skip it -- same
            // contract as a resident table unloaded mid-run: "may or
            // may not be included"); Err = genuine serialization
            // failure (fails the snapshot).
            let written: Result<bool, String> = match slot {
                Slot::Resident(e) => e
                    .backend
                    .save_artifact(&tmp)
                    .map(|_| true)
                    .map_err(|e| e.to_string()),
                Slot::Spilled(s) => {
                    // The spill artifact IS the per-kind snapshot format:
                    // copy it. First wait out an in-flight demote/promote
                    // (phase Spilling/Promoting -- the artifact's on-disk
                    // state is undefined mid-transition), then copy; if a
                    // promotion consumed the artifact between the wait
                    // and the copy, re-fetch the (now resident) table
                    // and serialize from memory. A LOST artifact skips
                    // the table (its data is already gone; the rest of
                    // the registry still deserves a backup) -- only a
                    // real serialization failure fails the snapshot.
                    s.wait_settled();
                    // Marker prefix distinguishing a content-verification
                    // failure from an I/O failure in the guarded copy:
                    // verification failures mean the SOURCE data is
                    // already damaged, so (like the Lost path) they skip
                    // the table loudly instead of failing the backup --
                    // or recording a torn copy under a "good" manifest.
                    const VERIFY_ERR: &str = "verify: ";
                    // Copy with a length stat BEFORE (cheap: catches a
                    // source truncated out-of-band between the phase
                    // wait and the copy) and a digest check AFTER (the
                    // copy itself raced nothing else that can mutate
                    // the destination; this pins the copied bytes to
                    // the manifest-recorded digest). Legacy slots with
                    // no digest copy unguarded, as before.
                    let guarded_copy = |src: &Path,
                                        want: &Option<(String, u64)>|
                     -> Result<(), String> {
                        if let Some((_, want_bytes)) = want {
                            let got = std::fs::metadata(src)
                                .map_err(|e| e.to_string())?
                                .len();
                            if got != *want_bytes {
                                return Err(format!(
                                    "{VERIFY_ERR}spill artifact {src:?} is \
                                     {got} bytes but its manifest records \
                                     {want_bytes} (truncated out-of-band?)"));
                            }
                        }
                        std::fs::copy(src, &tmp).map_err(|e| e.to_string())?;
                        if let Some((want_hex, want_bytes)) = want {
                            let (got_hex, got_bytes) =
                                backend::artifact_io::file_sha256(&tmp)
                                    .map_err(|e| e.to_string())?;
                            if got_hex != *want_hex || got_bytes != *want_bytes
                            {
                                return Err(format!(
                                    "{VERIFY_ERR}copy of spill artifact \
                                     {src:?} does not match its recorded \
                                     digest (expected {want_bytes} bytes \
                                     sha256 {want_hex}; copied {got_bytes} \
                                     bytes {got_hex})"));
                            }
                        }
                        Ok(())
                    };
                    let copied = self
                        .cfg
                        .spill_dir
                        .as_ref()
                        .ok_or_else(|| "no spill dir".to_string())
                        .and_then(|sd| {
                            guarded_copy(&sd.join(&s.file), &s.digest())
                        });
                    copied.map(|_| true).or_else(|copy_err| {
                        match self.slot_of(name) {
                            Some(Slot::Resident(e)) => e
                                .backend
                                .save_artifact(&tmp)
                                .map(|_| true)
                                .map_err(|e| e.to_string()),
                            Some(Slot::Spilled(cur)) => {
                                // settled but unreadable: retry once
                                // against the CURRENT slot (the table
                                // may have been re-demoted under a
                                // fresh artifact)
                                cur.wait_settled();
                                let retried = self
                                    .cfg
                                    .spill_dir
                                    .as_ref()
                                    .ok_or_else(|| "no spill dir".to_string())
                                    .and_then(|sd| {
                                        guarded_copy(
                                            &sd.join(&cur.file),
                                            &cur.digest())
                                        .map(|_| true)
                                    });
                                match retried {
                                    Ok(ok) => Ok(ok),
                                    // LOST (deleted out-of-band) or
                                    // VERIFIABLY DAMAGED (truncated /
                                    // bit-rotted under its recorded
                                    // digest): that table's on-disk
                                    // data is already gone -- failing
                                    // the WHOLE backup would compound
                                    // the damage. Skip it, loudly, and
                                    // snapshot the rest.
                                    Err(e) if self.probe_spilled(&cur)
                                        == Residency::Lost
                                        || e.contains(VERIFY_ERR) =>
                                    {
                                        eprintln!(
                                            "snapshot: skipping table \
                                             {name:?}: spill artifact is \
                                             unusable ({copy_err}; \
                                             retry: {e})");
                                        Ok(false)
                                    }
                                    Err(e) => Err(format!(
                                        "spill artifact unreadable \
                                         ({copy_err}; retry: {e})")),
                                }
                            }
                            // unloaded mid-snapshot: a deliberate removal
                            // must not fail the whole backup -- skip it
                            None => Ok(false),
                        }
                    })
                }
            };
            match written {
                Err(err) => {
                    let _ = std::fs::remove_file(&tmp); // no tmp litter
                    return Err(fail(format!("serialize table {name:?}"))(&err));
                }
                Ok(false) => {
                    let _ = std::fs::remove_file(&tmp);
                    continue; // not in the manifest: it no longer exists
                }
                Ok(true) => {}
            }
            // Content-addressed publish: hash what was just written and
            // name the artifact by its digest. Identical tables (same
            // serialized bytes) collapse onto ONE file -- a later table
            // whose digest is already in `fresh` drops its tmp instead
            // of renaming, and its manifest entry points at the shared
            // file. Restore re-links by name, so dedupe is invisible
            // there.
            let (hex, bytes) = match backend::artifact_io::file_sha256(&tmp) {
                Ok(hb) => hb,
                Err(e) => {
                    let _ = std::fs::remove_file(&tmp);
                    return Err(fail(format!("hash table {name:?}"))(&e));
                }
            };
            let file = format!("sha256-{hex}.art");
            if fresh.iter().any(|f| f == &file) {
                let _ = std::fs::remove_file(&tmp); // deduped: file exists
            } else {
                std::fs::rename(&tmp, dir.join(&file)).map_err(|err| {
                    fail(format!("publish table {name:?}"))(&err)
                })?;
                fresh.push(file.clone());
            }
            included.push(name.as_str());
            tables.push(Json::obj(vec![
                ("name", Json::str(name.as_str())),
                ("kind", Json::str(kind.as_str())),
                ("file", Json::str(file.as_str())),
                ("vocab", Json::num(vocab as f64)),
                ("d", Json::num(d as f64)),
                ("storage_bits", Json::num(storage_bits as f64)),
                ("replicas", Json::num(replicas as f64)),
                ("row_cache", Json::num(row_cache as f64)),
                ("sha256", Json::str(hex.as_str())),
                ("bytes", Json::num(bytes as f64)),
                ("op", Json::str("snapshot")),
            ]));
        }
        let mut pairs = vec![
            ("format", Json::str(SNAPSHOT_FORMAT)),
            ("v", Json::num(SNAPSHOT_VERSION as f64)),
            ("max_batch", Json::num(self.cfg.max_batch as f64)),
            ("shards_per_table", Json::num(self.cfg.shards_per_table as f64)),
            ("row_cache_bytes", Json::num(self.cfg.row_cache_bytes as f64)),
        ];
        if let Some(b) = self.cfg.mem_budget_bytes {
            pairs.push(("mem_budget_bytes", Json::num(b as f64)));
        }
        if let Some(t) = self.cfg.ttl_secs {
            pairs.push(("ttl_secs", Json::num(t as f64)));
        }
        // Connection-plane knobs: 0 is the explicit "disabled/unbounded"
        // marker (a restore of an old manifest without these keys gets
        // the CLI defaults instead -- see `config_from_manifest`).
        pairs.push((
            "conn_timeout_secs",
            Json::num(self.cfg.conn_timeout.map_or(0.0, |t| t.as_secs_f64())),
        ));
        pairs.push((
            "max_conns",
            Json::num(self.cfg.max_conns.map_or(0.0, |n| n as f64)),
        ));
        // 0 here genuinely means "legacy threaded plane", unlike the
        // knobs above where 0 is a disabled marker; a manifest without
        // the key restores to the event-plane default.
        pairs.push(("pollers", Json::num(self.cfg.pollers as f64)));
        if let Some(sd) = &self.cfg.spill_dir {
            pairs.push(("spill_dir",
                        Json::str(sd.to_string_lossy().as_ref())));
            pairs.push(("spill", Json::str(
                if self.cfg.spill_on_evict { "disk" } else { "drop" })));
        }
        if let Some(d) = &default {
            // `default` and the slot list are separate reads; only
            // record a default the snapshot actually contains (a table
            // skipped because it was unloaded mid-snapshot must not be
            // recorded either, or restore would fail on it)
            if included.iter().any(|n| *n == d.as_str()) {
                pairs.push(("default", Json::str(d.as_str())));
            }
        }
        pairs.push(("tables", Json::arr(tables)));
        let manifest = dir.join(SNAPSHOT_MANIFEST);
        let tmp = dir.join(snap_tmp_name(SNAPSHOT_MANIFEST));
        if let Err(e) = std::fs::write(&tmp, Json::obj(pairs).to_string()) {
            let _ = std::fs::remove_file(&tmp);
            return Err(fail("write manifest".into())(&e));
        }
        std::fs::rename(&tmp, &manifest)
            .map_err(|e| fail("publish manifest".into())(&e))?;
        // Best-effort garbage collection AFTER the manifest is live:
        // snapshot artifacts (`t<index>_*`) that the fresh manifest does
        // not reference are from previous snapshots into this directory
        // (unloaded tables) and would otherwise accumulate forever under
        // a snapshot schedule. Temp files are deliberately NOT collected
        // here -- a concurrent snapshot's in-flight `.tmp` must survive
        // (that is the whole point of the unique temp names); failed
        // writes remove their own tmp above, so only a hard crash can
        // leave one behind.
        if let Ok(rd) = std::fs::read_dir(dir) {
            for entry in rd.flatten() {
                let name = entry.file_name();
                let Some(name) = name.to_str() else { continue };
                if name.ends_with(".tmp") {
                    continue;
                }
                let b = name.as_bytes();
                // legacy (pre-digest) artifact name: `t` + 1..n digits
                // + `_` (format! padded to 3 but grew past 999 tables,
                // so match any digit run) -- still collected so old
                // snapshots into this directory don't pin stale files
                let digits = b
                    .get(1..)
                    .map(|rest| {
                        rest.iter().take_while(|c| c.is_ascii_digit()).count()
                    })
                    .unwrap_or(0);
                let legacy = b.first() == Some(&b't')
                    && digits >= 1
                    && b.get(1 + digits) == Some(&b'_');
                // content-addressed artifact name: `sha256-<64 hex>.art`
                let content_addressed = name
                    .strip_prefix("sha256-")
                    .and_then(|r| r.strip_suffix(".art"))
                    .is_some_and(crate::util::sha256::is_hex_digest);
                let stale_artifact = (legacy || content_addressed)
                    && !fresh.iter().any(|f| f == name);
                if stale_artifact {
                    let _ = std::fs::remove_file(entry.path());
                }
            }
        }
        Ok(manifest)
    }

    /// Parse and validate a snapshot manifest; `path` may be the
    /// manifest file or the snapshot directory containing it.
    fn read_manifest(path: &Path) -> Result<(Json, PathBuf), WireError> {
        let manifest = if path.is_dir() {
            path.join(SNAPSHOT_MANIFEST)
        } else {
            path.to_path_buf()
        };
        let fail = |m: String| WireError::Rejected {
            code: "restore_failed".into(),
            message: m,
        };
        let text = std::fs::read_to_string(&manifest)
            .map_err(|e| fail(format!("read {manifest:?}: {e}")))?;
        let j = Json::parse(&text)
            .map_err(|e| fail(format!("parse {manifest:?}: {e}")))?;
        if j.get("format").and_then(|v| v.as_str()) != Some(SNAPSHOT_FORMAT) {
            return Err(fail(format!(
                "{manifest:?} is not a {SNAPSHOT_FORMAT} manifest")));
        }
        match j.get("v").and_then(|v| v.as_usize()) {
            Some(v) if v as u64 == SNAPSHOT_VERSION => {}
            other => {
                return Err(WireError::Rejected {
                    code: "unsupported_snapshot".into(),
                    message: format!(
                        "snapshot version {other:?}; this build reads \
                         v{SNAPSHOT_VERSION}"),
                })
            }
        }
        Ok((j, manifest))
    }

    /// The [`ServerConfig`] a snapshot manifest records, so callers can
    /// apply per-field CLI overrides before [`restore`](Self::restore).
    pub fn snapshot_config(path: &Path) -> Result<ServerConfig, WireError> {
        let (j, _) = Self::read_manifest(path)?;
        Ok(Self::config_from_manifest(&j))
    }

    fn config_from_manifest(j: &Json) -> ServerConfig {
        let def = ServerConfig::default();
        ServerConfig {
            max_batch: j
                .get("max_batch")
                .and_then(|v| v.as_usize())
                .unwrap_or(def.max_batch)
                .max(1),
            shards_per_table: j
                .get("shards_per_table")
                .and_then(|v| v.as_usize())
                .unwrap_or(def.shards_per_table)
                .max(1),
            // same floor the CLI's --mem-budget parser enforces: a
            // negative/NaN/zero value from a hand-edited manifest must
            // not arm a 0-byte budget that evicts everything unpinned
            mem_budget_bytes: j
                .get("mem_budget_bytes")
                .and_then(|v| v.as_f64())
                .filter(|b| b.is_finite() && *b >= 1.0)
                .map(|b| b as u64),
            spill_dir: j
                .get("spill_dir")
                .and_then(|v| v.as_str())
                .map(PathBuf::from),
            spill_on_evict: j
                .get("spill")
                .and_then(|v| v.as_str())
                .map(|s| s != "drop")
                .unwrap_or(def.spill_on_evict),
            // same floor as --ttl: a hand-edited zero must not arm a
            // sweep that expires every non-default table instantly
            ttl_secs: j
                .get("ttl_secs")
                .and_then(|v| v.as_f64())
                .filter(|t| t.is_finite() && *t >= 1.0)
                .map(|t| t as u64),
            // Written as 0 for "explicitly disabled"; a pre-hardening
            // manifest without the key gets the CLI defaults (30s/1024)
            // rather than an unprotected server. Bogus hand-edited
            // values (NaN, negative, absurd) fall back the same way; the
            // one-year cap keeps `from_secs_f64` well inside range.
            conn_timeout: match j.get("conn_timeout_secs").and_then(|v| v.as_f64()) {
                Some(t) if t == 0.0 => None,
                Some(t) if t.is_finite() && t > 0.0 && t <= 31_557_600.0 => {
                    Some(Duration::from_secs_f64(t))
                }
                _ => Some(Duration::from_secs(30)),
            },
            max_conns: match j.get("max_conns").and_then(|v| v.as_f64()) {
                Some(n) if n == 0.0 => None,
                Some(n) if n.is_finite() && n >= 1.0 => Some(n as usize),
                _ => Some(1024),
            },
            // 0 means cache-disabled (also what a pre-cache manifest
            // without the key gets); bogus values fall back to disabled
            row_cache_bytes: j
                .get("row_cache_bytes")
                .and_then(|v| v.as_f64())
                .filter(|b| b.is_finite() && *b >= 0.0)
                .map(|b| b as u64)
                .unwrap_or(0),
            // never restored: debug ops are a test-construction knob,
            // deliberately unreachable via snapshot round-trips
            debug_ops: false,
            // 0 IS meaningful here (legacy threaded plane); only a
            // missing or bogus value falls back to the event-plane
            // default
            pollers: j
                .get("pollers")
                .and_then(|v| v.as_f64())
                .filter(|p| p.is_finite() && *p >= 0.0)
                .map(|p| p as usize)
                .unwrap_or(def.pollers),
        }
    }

    /// Rebuild a registry from a snapshot manifest (`path` may be the
    /// manifest file or its directory). Every table is reloaded from its
    /// recorded artifact and serves bytes **bit-identical** to the
    /// snapshotted registry; the default table and serving config are
    /// restored too (`cfg` overrides the recorded config wholesale when
    /// given). The memory budget is NOT enforced against the snapshot's
    /// own tables -- all of them are restored even if they exceed it
    /// (a snapshot can legitimately be softly over budget); the budget
    /// applies to loads made after the restore. Artifact shapes are
    /// cross-checked against the manifest so a swapped file fails
    /// loudly instead of serving the wrong table.
    pub fn restore(path: &Path, cfg: Option<ServerConfig>) -> Result<TableRegistry, WireError> {
        let (j, manifest) = Self::read_manifest(path)?;
        let fail = |m: String| WireError::Rejected {
            code: "restore_failed".into(),
            message: m,
        };
        let cfg = cfg.unwrap_or_else(|| Self::config_from_manifest(&j));
        // a manifest-recorded (or overridden) spill dir that does not
        // exist must fail the restore loudly, same as `open` at startup
        Self::validate_spill(&cfg)?;
        // Budget enforcement AND the idle TTL are DISABLED while the
        // snapshot's tables are re-inserted: a snapshot can
        // legitimately be (softly) over its own budget, and restore
        // must rebuild exactly the manifest's contents -- evicting (or
        // TTL-expiring, on a slow rebuild) one of them mid-rebuild
        // would break the bit-identical guarantee. Both are re-armed
        // below, so they govern traffic after the restore completes.
        let mut reg = TableRegistry::new(ServerConfig {
            mem_budget_bytes: None,
            ttl_secs: None,
            ..cfg.clone()
        });
        let base = manifest
            .parent()
            .map(Path::to_path_buf)
            .unwrap_or_else(|| PathBuf::from("."));
        let tables = j
            .get("tables")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| fail("manifest without tables".into()))?;
        let want_default = j.get("default").and_then(|v| v.as_str());
        // one-shot latch: a legacy (pre-digest) manifest restores
        // unverified, warned once, not once per table
        let mut legacy_warned = false;
        for t in tables {
            let name = t
                .get("name")
                .and_then(|v| v.as_str())
                .ok_or_else(|| fail("table entry without name".into()))?;
            let kind = t
                .get("kind")
                .and_then(|v| v.as_str())
                .ok_or_else(|| fail(format!("table {name:?} without kind")))?;
            let file = t
                .get("file")
                .and_then(|v| v.as_str())
                .ok_or_else(|| fail(format!("table {name:?} without file")))?;
            // Verify the artifact's content digest BEFORE parsing:
            // bit-rot in codebook bytes can pass every shape check and
            // silently restore wrong embeddings. Manifests without
            // digests (pre-digest builds) load unverified, once loudly.
            match t.get("sha256").and_then(|v| v.as_str()) {
                Some(hex) => {
                    if !crate::util::sha256::is_hex_digest(hex) {
                        return Err(fail(format!(
                            "table {name:?} sha256 {hex:?} is not a 64-char \
                             lowercase hex digest")));
                    }
                    let (got_hex, got_bytes) =
                        backend::artifact_io::file_sha256(&base.join(file))
                            .map_err(|e| fail(format!(
                                "hash table {name:?} artifact {file:?}: \
                                 {e}")))?;
                    let want_bytes = t.get("bytes").and_then(|v| v.as_usize());
                    if got_hex != hex
                        || want_bytes.is_some_and(|b| b as u64 != got_bytes)
                    {
                        return Err(fail(format!(
                            "table {name:?}: artifact {file:?} does not \
                             match its manifest digest (expected {} bytes \
                             sha256 {hex}; found {got_bytes} bytes \
                             {got_hex}); refusing to parse",
                            want_bytes.map_or_else(
                                || "?".to_string(), |b| b.to_string()))));
                    }
                }
                None => {
                    if !legacy_warned {
                        legacy_warned = true;
                        eprintln!(
                            "restore: manifest {manifest:?} predates \
                             content digests; artifacts load unverified \
                             (re-snapshot to record digests)");
                    }
                }
            }
            let backend = backend::load_backend(kind, &base.join(file))
                .map_err(|e| fail(format!("load table {name:?}: {e}")))?;
            for (key, got) in [("vocab", backend.vocab()), ("d", backend.d())] {
                if let Some(want) = t.get(key).and_then(|v| v.as_usize()) {
                    if want != got {
                        return Err(fail(format!(
                            "table {name:?}: artifact has {key}={got} but \
                             manifest declares {want}")));
                    }
                }
            }
            // replica counts are part of the serving config the
            // snapshot promised to rebuild (clamped like adoption: a
            // hand-edited count must not spawn absurd thread counts)
            let replicas = t
                .get("replicas")
                .and_then(|v| v.as_usize())
                .unwrap_or(1)
                .clamp(1, MAX_REPLICAS);
            reg.insert_with_replicas(name, backend, replicas)?;
            // per-table cache caps are serving config too; a pre-cache
            // manifest without the key keeps the config-level default
            // the insert already applied (the budget is disarmed here,
            // so the cap is recorded verbatim, not shrunk)
            if let Some(cap) = t.get("row_cache").and_then(|v| v.as_usize()) {
                reg.set_row_cache(name, cap as u64)?;
            }
        }
        if let Some(d) = want_default {
            reg.set_default(d).map_err(|_| fail(format!(
                "manifest default {d:?} is not among the snapshot's tables")))?;
        }
        // re-arm the budget and TTL for post-restore traffic
        reg.cfg.mem_budget_bytes = cfg.mem_budget_bytes;
        reg.cfg.ttl_secs = cfg.ttl_secs;
        // a spill dir carried over (or overridden) may hold tables a
        // previous process demoted that are NOT in the snapshot --
        // adopt them too (names the snapshot restored are kept
        // resident; adoption skips them loudly)
        reg.adopt_spill_tier()?;
        Ok(reg)
    }

    /// Stop every table's shards and join their threads (idempotent).
    /// Leaves the table map readable so late `stats` frames still answer.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::Relaxed);
        let entries = self.list();
        for e in entries {
            e.stop();
        }
    }
}

/// Typed `bad_replicas` rejection for a replica count outside
/// `1..=`[`MAX_REPLICAS`].
fn validate_replicas(n: usize) -> Result<(), WireError> {
    if n == 0 || n > MAX_REPLICAS {
        return Err(WireError::Rejected {
            code: "bad_replicas".into(),
            message: format!(
                "replicas must be in 1..={MAX_REPLICAS}, got {n}"),
        });
    }
    Ok(())
}

/// File-name-safe version of a table name for snapshot artifacts (the
/// manifest keeps the exact name; the index prefix keeps stems unique).
fn sanitize_file_stem(name: &str) -> String {
    name.chars()
        .map(|c| {
            if c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.') {
                c
            } else {
                '_'
            }
        })
        .collect()
}

impl Drop for TableRegistry {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::DenseTable;
    use crate::dpq::toy_embedding;
    use crate::quant::{LowRank, ScalarQuant};
    use crate::tensor::TensorF;
    use crate::util::Rng;

    fn dense(n: usize, d: usize, seed: u64) -> (Arc<DenseTable>, TensorF) {
        let mut rng = Rng::new(seed);
        let t = TensorF {
            shape: vec![n, d],
            data: (0..n * d).map(|_| rng.normal()).collect(),
        };
        (Arc::new(DenseTable::new(t.clone()).unwrap()), t)
    }

    fn cfg(shards: usize) -> ServerConfig {
        ServerConfig {
            max_batch: 8,
            shards_per_table: shards,
            ..ServerConfig::default()
        }
    }

    /// A fresh spill-tier test dir (created, emptied) + a config using it.
    fn spill_cfg(tag: &str, budget: Option<u64>) -> (std::path::PathBuf, ServerConfig) {
        let dir = std::env::temp_dir().join(format!("dpq_registry_spill_{tag}"));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let cfg = ServerConfig {
            max_batch: 8,
            shards_per_table: 1,
            mem_budget_bytes: budget,
            spill_dir: Some(dir.clone()),
            spill_on_evict: true,
            ..ServerConfig::default()
        };
        (dir, cfg)
    }

    #[test]
    fn insert_resolve_default_unload() {
        let reg = TableRegistry::new(cfg(1));
        assert!(reg.resolve(None).is_err());
        let (a, _) = dense(10, 4, 1);
        let (b, _) = dense(20, 6, 2);
        reg.insert("a", a).unwrap();
        reg.insert("b", b).unwrap();
        assert_eq!(
            reg.insert("a", dense(5, 2, 3).0).unwrap_err(),
            WireError::TableExists("a".into())
        );
        assert_eq!(reg.default_name().as_deref(), Some("a"));
        assert_eq!(reg.resolve(None).unwrap().name, "a");
        assert_eq!(reg.resolve(Some("b")).unwrap().name, "b");
        assert_eq!(
            reg.resolve(Some("zzz")).unwrap_err(),
            WireError::NoSuchTable("zzz".into())
        );
        reg.set_default("b").unwrap();
        assert_eq!(reg.resolve(None).unwrap().name, "b");
        // unloading the default explicitly re-elects the first remaining
        // table; the outcome names it so callers never see a dangling
        // default
        let out = reg.unload("b").unwrap();
        assert_eq!(out, UnloadOutcome {
            was_default: true,
            new_default: Some("a".into()),
        });
        assert_eq!(reg.default_name().as_deref(), Some("a"));
        assert_eq!(reg.unload("b").unwrap_err(),
                   WireError::NoSuchTable("b".into()));
        assert_eq!(reg.list().len(), 1);
        // unloading the last table leaves no default, explicitly
        let out = reg.unload("a").unwrap();
        assert_eq!(out, UnloadOutcome { was_default: true, new_default: None });
        assert!(reg.default_name().is_none());
        reg.shutdown();
    }

    #[test]
    fn rejects_bad_table_names_and_degenerate_shapes() {
        let reg = TableRegistry::new(cfg(1));
        assert!(reg.insert("", dense(4, 2, 1).0).is_err());
        assert!(reg.insert("a=b", dense(4, 2, 1).0).is_err());
        // d == 0 would make the batcher failure view indistinguishable
        // from a real (empty) answer; vocab == 0 can never serve an id
        assert!(reg.insert("w0", dense(4, 0, 1).0).is_err());
        assert!(reg.insert("v0", dense(0, 4, 1).0).is_err());
        assert!(reg.is_empty());
    }

    /// Shard routing must be invisible in the answer: for every shard
    /// count the assembled rows are bit-identical to a direct backend
    /// gather, whichever shards the ids land on.
    #[test]
    fn sharded_lookup_matches_direct_gather() {
        let (backend, table) = dense(50, 6, 7);
        let patterns: Vec<Vec<usize>> = vec![
            vec![0, 49, 25, 1, 48, 2, 47],     // straddles every shard
            vec![3, 4, 5],                     // single-shard fast path
            (0..50).rev().collect(),           // all ids, reversed
            vec![49, 49, 0, 0, 24],            // duplicates across shards
            vec![],
        ];
        for shards in [1usize, 2, 3, 7] {
            let reg = TableRegistry::new(cfg(shards));
            let entry = reg.insert("t", backend.clone()).unwrap();
            assert_eq!(entry.shard_count(), shards);
            for ids in &patterns {
                let ans = entry.lookup(ids).unwrap();
                let got = ans.as_slice();
                assert_eq!(got.len(), ids.len() * 6);
                for (r, &id) in ids.iter().enumerate() {
                    assert_eq!(&got[r * 6..(r + 1) * 6], table.row(id),
                               "shards={shards} id={id}");
                }
            }
            reg.shutdown();
        }
    }

    #[test]
    fn lookup_after_unload_fails_typed_not_hung() {
        let reg = TableRegistry::new(cfg(2));
        let (backend, _) = dense(10, 4, 9);
        let entry = reg.insert("t", backend).unwrap();
        reg.unload("t").unwrap();
        // the entry handle still exists, but its shards are closed: the
        // lookup must return None promptly instead of blocking forever
        assert!(entry.lookup(&[1, 2, 9]).is_none());
    }

    #[test]
    fn shard_of_covers_range_evenly() {
        let reg = TableRegistry::new(cfg(4));
        let (backend, _) = dense(100, 2, 11);
        let entry = reg.insert("t", backend).unwrap();
        let mut counts = [0usize; 4];
        for id in 0..100 {
            let s = entry.shard_of(id, 100);
            assert!(s < 4);
            counts[s] += 1;
        }
        assert_eq!(counts, [25, 25, 25, 25]);
        reg.shutdown();
    }

    /// LRU eviction: the budget fires on insert, evicts the
    /// least-recently-LOOKED-UP table (not insertion order), pins the
    /// default, and marks the victim so operators can tell "evicted"
    /// from "never existed".
    #[test]
    fn eviction_is_lru_and_pins_default() {
        // three 10x4 dense tables at 160 bytes each; budget fits two
        let bytes_per = 10 * 4 * 4u64;
        let reg = TableRegistry::new(ServerConfig {
            max_batch: 8,
            shards_per_table: 1,
            mem_budget_bytes: Some(2 * bytes_per),
            ..ServerConfig::default()
        });
        reg.insert("base", dense(10, 4, 1).0).unwrap(); // default, pinned
        reg.insert("hot", dense(10, 4, 2).0).unwrap();
        assert_eq!(reg.eviction_count(), 0);
        assert_eq!(reg.resident_bytes(), 2 * bytes_per);
        // touch hot, then base: "hot" is now the stalest unpinned table
        // (base is more recent AND pinned as default)
        reg.resolve(Some("hot")).unwrap();
        reg.resolve(Some("base")).unwrap();
        // inserting a third table exceeds the budget; "base" is pinned
        // (default) and "cold" is the fresh insert, so "hot" is evicted
        // even though it was inserted after "base"
        reg.insert("cold", dense(10, 4, 3).0).unwrap();
        assert_eq!(reg.eviction_count(), 1);
        assert!(reg.was_evicted("hot"));
        assert!(reg.get("hot").is_none());
        assert!(reg.get("base").is_some(), "default must be pinned");
        assert!(reg.get("cold").is_some(), "fresh insert must be pinned");
        assert_eq!(
            reg.resolve(Some("hot")).unwrap_err(),
            WireError::NoSuchTable("hot".into())
        );
        assert_eq!(reg.evicted_tables(), vec![("hot".into(), 1)]);
        // reloading under the same name clears the eviction marker
        reg.resolve(Some("cold")).unwrap(); // make "cold" recent
        reg.insert("hot", dense(10, 4, 2).0).unwrap();
        assert!(!reg.was_evicted("hot"));
        assert_eq!(reg.eviction_count(), 2, "reload re-evicted the LRU");
        // the budget is soft: with every survivor pinned, a huge insert
        // stays resident and the registry stays over budget
        let reg2 = TableRegistry::new(ServerConfig {
            max_batch: 8,
            shards_per_table: 1,
            mem_budget_bytes: Some(bytes_per / 2),
            ..ServerConfig::default()
        });
        reg2.insert("only", dense(10, 4, 5).0).unwrap();
        assert_eq!(reg2.len(), 1);
        assert!(reg2.resident_bytes() > bytes_per / 2);
        // zero-gain guard: when the pinned tables alone exceed the
        // budget, evicting unpinned tables cannot reach it -- so nothing
        // is evicted and every table stays resident
        let reg4 = TableRegistry::new(ServerConfig {
            max_batch: 8,
            shards_per_table: 1,
            mem_budget_bytes: Some(3 * bytes_per),
            ..ServerConfig::default()
        });
        reg4.insert("base", dense(10, 4, 6).0).unwrap(); // default, pinned
        reg4.insert("y", dense(10, 4, 7).0).unwrap();
        // "big" alone exceeds the budget: pinned (base + big) > budget,
        // so "y" must NOT be sacrificed for nothing
        let mut rng = Rng::new(8);
        let big = Arc::new(DenseTable::new(TensorF {
            shape: vec![100, 4],
            data: (0..400).map(|_| rng.normal()).collect(),
        }).unwrap());
        reg4.insert("big", big).unwrap();
        assert_eq!(reg4.eviction_count(), 0,
                   "zero-gain eviction must not fire");
        assert!(reg4.get("y").is_some());
        assert!(reg4.resident_bytes() > 3 * bytes_per);
        reg4.shutdown();

        // genuine LRU ordering: with TWO unpinned candidates, the one
        // whose last lookup is older goes, not the one inserted earlier
        let reg3 = TableRegistry::new(ServerConfig {
            max_batch: 8,
            shards_per_table: 1,
            mem_budget_bytes: Some(3 * bytes_per),
            ..ServerConfig::default()
        });
        reg3.insert("base", dense(10, 4, 6).0).unwrap();
        reg3.insert("t1", dense(10, 4, 7).0).unwrap();
        reg3.insert("t2", dense(10, 4, 8).0).unwrap();
        // t2 was inserted last (freshest), but touching t1 makes t2 the
        // least-recently-looked-up candidate
        reg3.resolve(Some("t1")).unwrap();
        reg3.insert("t3", dense(10, 4, 9).0).unwrap();
        assert!(reg3.was_evicted("t2"), "LRU victim must be t2");
        assert!(reg3.get("t1").is_some());
        assert_eq!(reg3.eviction_count(), 1);
        reg.shutdown();
        reg2.shutdown();
        reg3.shutdown();
    }

    /// Snapshot -> restore must rebuild every backend kind bit-exactly,
    /// preserve the default table, and roundtrip the serving config.
    #[test]
    fn snapshot_restore_all_kinds_bit_exact() {
        let dir = std::env::temp_dir().join("dpq_registry_snapshot_unit");
        let _ = std::fs::remove_dir_all(&dir);
        let mut rng = Rng::new(3);
        let table = TensorF {
            shape: vec![40, 8],
            data: (0..40 * 8).map(|_| rng.normal()).collect(),
        };
        let reg = TableRegistry::new(ServerConfig {
            max_batch: 16,
            shards_per_table: 2,
            mem_budget_bytes: Some(1 << 20),
            ..ServerConfig::default()
        });
        reg.insert("dpq", Arc::new(toy_embedding(30, 8, 4, 2, 7))).unwrap();
        reg.insert("dense", Arc::new(DenseTable::new(table.clone()).unwrap()))
            .unwrap();
        reg.insert("sq", Arc::new(ScalarQuant::fit(&table, 6))).unwrap();
        reg.insert("lr", Arc::new(LowRank::fit(&table, 3))).unwrap();
        reg.set_default("sq").unwrap();
        let manifest = reg.snapshot(&dir).unwrap();
        assert_eq!(manifest, dir.join(SNAPSHOT_MANIFEST));

        // restore from the directory (manifest path works too)
        let back = TableRegistry::restore(&dir, None).unwrap();
        assert_eq!(back.default_name().as_deref(), Some("sq"));
        let cfg = back.config();
        assert_eq!((cfg.max_batch, cfg.shards_per_table, cfg.mem_budget_bytes),
                   (16, 2, Some(1 << 20)));
        assert_eq!(back.len(), 4);
        for e in reg.list() {
            let r = back.get(&e.name).expect("restored table");
            assert_eq!(r.backend.kind(), e.backend.kind());
            assert_eq!(r.shard_count(), 2);
            let ids: Vec<usize> =
                (0..e.backend.vocab()).step_by(3).collect();
            let d = e.backend.d();
            let mut a = vec![0.0f32; ids.len() * d];
            let mut b = vec![0.0f32; ids.len() * d];
            e.backend.reconstruct_rows_into(&ids, &mut a);
            r.backend.reconstruct_rows_into(&ids, &mut b);
            assert!(
                a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()),
                "restored table {:?} is not bit-identical", e.name
            );
        }
        // a snapshot of the restored registry must agree with the first
        let dir2 = std::env::temp_dir().join("dpq_registry_snapshot_unit2");
        let _ = std::fs::remove_dir_all(&dir2);
        back.snapshot(&dir2).unwrap();
        assert_eq!(std::fs::read_to_string(dir.join(SNAPSHOT_MANIFEST)).unwrap(),
                   std::fs::read_to_string(dir2.join(SNAPSHOT_MANIFEST)).unwrap());
        reg.shutdown();
        back.shutdown();
    }

    /// Restore must rebuild EXACTLY the snapshot's tables even when the
    /// (possibly overridden) budget cannot hold them all -- the budget
    /// is disarmed during the rebuild and re-armed for loads made
    /// afterwards, where it evicts with the restored default pinned.
    #[test]
    fn restore_ignores_budget_until_after_rebuild() {
        let dir = std::env::temp_dir().join("dpq_registry_restore_budget");
        let _ = std::fs::remove_dir_all(&dir);
        let reg = TableRegistry::new(cfg(1));
        reg.insert("a", dense(10, 4, 1).0).unwrap();
        reg.insert("b", dense(10, 4, 2).0).unwrap();
        reg.insert("c", dense(10, 4, 3).0).unwrap();
        reg.set_default("b").unwrap();
        reg.snapshot(&dir).unwrap();
        let bytes_per = 10 * 4 * 4u64;
        let back = TableRegistry::restore(&dir, Some(ServerConfig {
            max_batch: 8,
            shards_per_table: 1,
            mem_budget_bytes: Some(2 * bytes_per), // fits only 2 of the 3
            ..ServerConfig::default()
        }))
        .unwrap();
        // all three tables restored, zero evictions, default preserved
        assert_eq!(back.len(), 3);
        assert_eq!(back.eviction_count(), 0);
        assert_eq!(back.default_name().as_deref(), Some("b"));
        assert!(back.resident_bytes() > 2 * bytes_per);
        // the budget is armed for POST-restore loads: the next insert
        // evicts down to the budget with "b" (default) + "d" (fresh)
        // pinned, so both restored non-default tables go
        back.insert("d", dense(10, 4, 4).0).unwrap();
        assert_eq!(back.eviction_count(), 2);
        assert!(back.get("b").is_some());
        assert!(back.get("d").is_some());
        assert!(back.get("a").is_none() && back.get("c").is_none());
        assert_eq!(back.resident_bytes(), 2 * bytes_per);
        reg.shutdown();
        back.shutdown();
    }

    #[test]
    fn restore_rejects_corrupt_manifests() {
        let dir = std::env::temp_dir().join("dpq_registry_restore_bad");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        // missing manifest
        assert!(TableRegistry::restore(&dir, None).is_err());
        // wrong format tag
        std::fs::write(dir.join(SNAPSHOT_MANIFEST), r#"{"format":"nope"}"#)
            .unwrap();
        assert!(TableRegistry::restore(&dir, None).is_err());
        // future version is a typed unsupported_snapshot
        std::fs::write(
            dir.join(SNAPSHOT_MANIFEST),
            format!(r#"{{"format":"{SNAPSHOT_FORMAT}","v":99,"tables":[]}}"#),
        )
        .unwrap();
        match TableRegistry::restore(&dir, None) {
            Err(WireError::Rejected { code, .. }) => {
                assert_eq!(code, "unsupported_snapshot")
            }
            other => panic!("{other:?}"),
        }
        // a manifest whose artifact shape disagrees with the file fails
        // loudly instead of serving the wrong table
        let reg = TableRegistry::new(cfg(1));
        reg.insert("t", dense(10, 4, 1).0).unwrap();
        reg.snapshot(&dir).unwrap();
        let text = std::fs::read_to_string(dir.join(SNAPSHOT_MANIFEST)).unwrap();
        std::fs::write(dir.join(SNAPSHOT_MANIFEST),
                       text.replace("\"vocab\":10", "\"vocab\":11"))
            .unwrap();
        match TableRegistry::restore(&dir, None) {
            Err(WireError::Rejected { code, message }) => {
                assert_eq!(code, "restore_failed");
                assert!(message.contains("vocab"), "{message}");
            }
            other => panic!("{other:?}"),
        }
        reg.shutdown();
    }

    #[test]
    fn open_rejects_missing_spill_dir() {
        let cfg = ServerConfig {
            spill_dir: Some(std::env::temp_dir().join("dpq_no_such_spill_dir")),
            ..ServerConfig::default()
        };
        let _ = std::fs::remove_dir_all(cfg.spill_dir.as_ref().unwrap());
        match TableRegistry::open(cfg) {
            Err(WireError::Rejected { code, .. }) => {
                assert_eq!(code, "spill_dir_missing")
            }
            other => panic!("expected spill_dir_missing, got {other:?}"),
        }
        // a spill-less config opens fine
        assert!(TableRegistry::open(ServerConfig::default()).is_ok());
    }

    #[test]
    fn demote_without_spill_dir_is_typed() {
        let reg = TableRegistry::new(cfg(1));
        reg.insert("t", dense(10, 4, 1).0).unwrap();
        match reg.demote("t") {
            Err(WireError::Rejected { code, .. }) => {
                assert_eq!(code, "spill_disabled")
            }
            other => panic!("{other:?}"),
        }
        reg.shutdown();
    }

    /// Demote -> lookup must round-trip bit-exactly through the spill
    /// tier: the promoted table serves the same bytes, the LRU/stats
    /// counters survive, the artifact and manifest appear on demote and
    /// the artifact is GC'd on promote.
    #[test]
    fn demote_promote_roundtrip_bit_exact_and_manifest_tracked() {
        let (dir, cfg) = spill_cfg("roundtrip", None);
        let reg = TableRegistry::open(cfg).unwrap();
        let (backend, table) = dense(30, 6, 5);
        reg.insert("t", backend).unwrap();
        reg.insert("other", dense(10, 4, 6).0).unwrap();
        let ids: Vec<usize> = vec![0, 29, 7, 7, 13];
        let before = reg.resolve(Some("t")).unwrap().lookup(&ids).unwrap();
        let before: Vec<f32> = before.as_slice().to_vec();

        let slot = reg.demote("t").unwrap();
        assert_eq!((slot.kind(), slot.vocab(), slot.d()), ("dense", 30, 6));
        assert_eq!(reg.residency("t"), Some(Residency::Spilled));
        assert!(reg.get("t").is_none(), "get() must not see spilled tables");
        assert!(dir.join(slot.file()).is_file(), "artifact not published");
        let man = std::fs::read_to_string(dir.join(SPILL_MANIFEST)).unwrap();
        assert!(man.contains("\"t\""), "manifest must track the spill: {man}");
        assert_eq!(reg.spill_count(), 1);
        // double demote is a typed not_resident
        match reg.demote("t") {
            Err(WireError::Rejected { code, .. }) => {
                assert_eq!(code, "not_resident")
            }
            other => panic!("{other:?}"),
        }

        // transparent reload on resolve; bytes bit-identical
        let entry = reg.resolve(Some("t")).unwrap();
        let after = entry.lookup(&ids).unwrap();
        assert!(
            before.iter().zip(after.as_slice())
                .all(|(a, b)| a.to_bits() == b.to_bits()),
            "promoted table serves different bytes"
        );
        for (r, &id) in ids.iter().enumerate() {
            assert_eq!(&after.as_slice()[r * 6..(r + 1) * 6], table.row(id));
        }
        assert_eq!(reg.residency("t"), Some(Residency::Resident));
        assert_eq!(reg.promote_count(), 1);
        assert!(reg.promote_latency().is_some());
        assert!(!dir.join(slot.file()).is_file(),
                "promote must GC the consumed artifact");
        let man = std::fs::read_to_string(dir.join(SPILL_MANIFEST)).unwrap();
        assert!(!man.contains("\"t\""), "manifest must drop the promoted table");
        // stats survived the round trip (1 pre-demotion + 1 post lookup)
        assert_eq!(entry.stats.requests.load(Ordering::Relaxed), 2);
        reg.shutdown();
    }

    /// Budget eviction with a spill tier demotes instead of dropping:
    /// the victim stays registered (residency spilled, NOT the PR-3
    /// evicted marker) and a later lookup brings it back bit-exactly --
    /// possibly demoting someone else to make room.
    #[test]
    fn budget_eviction_spills_and_promotion_reenters_lru() {
        let bytes_per = 10 * 4 * 4u64;
        let (dir, cfg) = spill_cfg("evict", Some(2 * bytes_per));
        let reg = TableRegistry::open(cfg).unwrap();
        let (b_base, _) = dense(10, 4, 1);
        let (b_hot, t_hot) = dense(10, 4, 2);
        reg.insert("base", b_base).unwrap(); // default, pinned
        reg.insert("hot", b_hot).unwrap();
        reg.resolve(Some("hot")).unwrap();
        reg.resolve(Some("base")).unwrap();
        // third insert exceeds the budget; "hot" (stalest unpinned) is
        // DEMOTED, not dropped
        reg.insert("cold", dense(10, 4, 3).0).unwrap();
        assert_eq!(reg.eviction_count(), 1);
        assert_eq!(reg.spill_count(), 1);
        assert!(!reg.was_evicted("hot"),
                "spilled tables must not carry the dropped-evicted marker");
        assert_eq!(reg.residency("hot"), Some(Residency::Spilled));
        assert_eq!(reg.resident_bytes(), 2 * bytes_per);
        assert_eq!(reg.len(), 3, "spilled tables stay registered");
        assert_eq!(reg.list_spilled().len(), 1);

        // promoting "hot" re-enters the LRU and must demote the stalest
        // unpinned resident ("cold": base is default-pinned, hot is the
        // promotion's protect) to stay under budget
        let entry = reg.resolve(Some("hot")).unwrap();
        let rows = entry.lookup(&[3, 9]).unwrap();
        assert_eq!(&rows.as_slice()[..4], t_hot.row(3));
        assert_eq!(reg.residency("hot"), Some(Residency::Resident));
        assert_eq!(reg.residency("cold"), Some(Residency::Spilled));
        assert_eq!(reg.resident_bytes(), 2 * bytes_per);
        assert_eq!(reg.spill_count(), 2);
        assert_eq!(reg.promote_count(), 1);
        let _ = dir;
        reg.shutdown();
    }

    #[test]
    fn insert_over_spilled_name_is_table_exists() {
        let (_dir, cfg) = spill_cfg("collide", None);
        let reg = TableRegistry::open(cfg).unwrap();
        reg.insert("t", dense(10, 4, 1).0).unwrap();
        reg.insert("u", dense(10, 4, 3).0).unwrap();
        reg.demote("t").unwrap();
        assert_eq!(
            reg.insert("t", dense(10, 4, 2).0).unwrap_err(),
            WireError::TableExists("t".into()),
            "a spilled table is still registered under its name"
        );
        reg.shutdown();
    }

    /// Unloading a spilled table GCs its artifact and drops it from the
    /// manifest; a lost artifact is reported by probe, not a panic.
    #[test]
    fn unload_spilled_gcs_artifact_and_probe_reports_lost() {
        let (dir, cfg) = spill_cfg("unload", None);
        let reg = TableRegistry::open(cfg).unwrap();
        reg.insert("a", dense(10, 4, 1).0).unwrap();
        reg.insert("b", dense(10, 4, 2).0).unwrap();
        let slot_a = reg.demote("a").unwrap();
        let slot_b = reg.demote("b").unwrap();

        // out-of-band deletion: probe flips to Lost, resolve is typed
        std::fs::remove_file(dir.join(slot_b.file())).unwrap();
        assert_eq!(reg.probe_spilled(&slot_b), Residency::Lost);
        assert_eq!(reg.residency("b"), Some(Residency::Lost));
        match reg.resolve(Some("b")) {
            Err(WireError::Rejected { code, message }) => {
                assert_eq!(code, "reload_failed");
                assert!(message.contains("lost"), "{message}");
            }
            other => panic!("{other:?}"),
        }
        // probe heals when an artifact reappears at the slot's path
        // (out-of-band restore); Lost is advisory, never a tombstone
        std::fs::copy(dir.join(slot_a.file()), dir.join(slot_b.file())).unwrap();
        assert_eq!(reg.probe_spilled(&slot_b), Residency::Spilled);

        let out = reg.unload("a").unwrap();
        assert!(!out.was_default || out.new_default.is_some());
        assert!(!dir.join(slot_a.file()).is_file(),
                "unload must GC the spilled artifact");
        let man = std::fs::read_to_string(dir.join(SPILL_MANIFEST)).unwrap();
        assert!(!man.contains("\"a\""), "{man}");
        assert!(man.contains("\"b\""), "{man}");
        reg.shutdown();
    }

    // ---- replicas ----

    /// Replication must be invisible in the bytes: a 3-replica table
    /// serves exactly what a direct gather does for every pattern, and
    /// idle-time ties round-robin across replicas so sequential traffic
    /// still exercises more than one.
    #[test]
    fn replicated_lookup_matches_direct_gather_and_spreads() {
        let (backend, table) = dense(40, 6, 17);
        let reg = TableRegistry::new(cfg(2)); // 2 shards x 3 replicas
        let entry = reg.insert_with_replicas("t", backend, 3).unwrap();
        assert_eq!(entry.replica_count(), 3);
        assert_eq!(entry.shard_count(), 2);
        for round in 0..12 {
            let ids: Vec<usize> =
                (0..5).map(|i| (round * 7 + i * 3) % 40).collect();
            let ans = entry.lookup(&ids).unwrap();
            let got = ans.as_slice();
            for (r, &id) in ids.iter().enumerate() {
                assert_eq!(&got[r * 6..(r + 1) * 6], table.row(id),
                           "round={round} id={id}");
            }
        }
        // every routed lookup was answered: no leaked queue depth
        assert_eq!(entry.replica_depths(), vec![0, 0, 0]);
        // sequential (depth-tied) traffic rotates: several replicas
        // must have drained batches, not just replica 0
        let st = entry.replica_stats_json().to_string();
        let busy = entry
            .replicas
            .iter()
            .filter(|r| r.stats.batches.load(Ordering::Relaxed) > 0)
            .count();
        assert!(busy >= 2, "round-robin tiebreak must spread load: {st}");
        // replica batches and table batches agree (merged view)
        let sum: u64 = entry
            .replicas
            .iter()
            .map(|r| r.stats.batches.load(Ordering::Relaxed))
            .sum();
        assert_eq!(sum, entry.stats.batches.load(Ordering::Relaxed));
        reg.shutdown();
    }

    /// `set_replicas`: live resizes swap the entry (counters carried),
    /// out-of-range counts are typed `bad_replicas`, resizing a spilled
    /// table takes effect at promotion, and the count survives the
    /// demote -> promote round trip.
    #[test]
    fn set_replicas_resizes_live_and_survives_spill() {
        let (dir, cfg) = spill_cfg("set_replicas", None);
        let reg = TableRegistry::open(cfg).unwrap();
        let (backend, table) = dense(30, 4, 23);
        reg.insert("t", backend).unwrap();
        reg.resolve(Some("t")).unwrap().lookup(&[1, 2]).unwrap();
        let before = reg.get("t").unwrap().stats.requests.load(Ordering::Relaxed);

        assert_eq!(reg.set_replicas("t", 3).unwrap(), 3);
        let entry = reg.get("t").unwrap();
        assert_eq!(entry.replica_count(), 3);
        // table-level counters carried across the swap
        assert_eq!(entry.stats.requests.load(Ordering::Relaxed), before);
        let ans = entry.lookup(&[0, 29]).unwrap();
        assert_eq!(&ans.as_slice()[..4], table.row(0));
        // no-op resize does not swap the entry
        assert_eq!(reg.set_replicas("t", 3).unwrap(), 3);
        assert!(Arc::ptr_eq(&reg.get("t").unwrap(), &entry));

        // typed rejections
        match reg.set_replicas("t", 0) {
            Err(WireError::Rejected { code, .. }) => {
                assert_eq!(code, "bad_replicas")
            }
            other => panic!("{other:?}"),
        }
        assert!(reg.set_replicas("t", MAX_REPLICAS + 1).is_err());
        assert_eq!(
            reg.set_replicas("nope", 2).unwrap_err(),
            WireError::NoSuchTable("nope".into())
        );

        // replica count rides the spill tier: demote at 3, promote at 3;
        // resizing WHILE spilled applies at the next promotion
        let slot = reg.demote("t").unwrap();
        assert_eq!(slot.replicas(), 3);
        let man = std::fs::read_to_string(dir.join(SPILL_MANIFEST)).unwrap();
        assert!(man.contains("\"replicas\""), "{man}");
        reg.set_replicas("t", 2).unwrap();
        assert_eq!(slot.replicas(), 2);
        let entry = reg.resolve(Some("t")).unwrap();
        assert_eq!(entry.replica_count(), 2);
        let ans = entry.lookup(&[7]).unwrap();
        assert_eq!(ans.as_slice(), table.row(7));
        reg.shutdown();
    }

    // ---- TTL (deterministic via the injected ManualClock) ----

    use crate::server::clock::ManualClock;

    fn ttl_reg(
        tag: &str,
        budget: Option<u64>,
        ttl: u64,
    ) -> (std::path::PathBuf, Arc<ManualClock>, TableRegistry) {
        let (dir, cfg) = spill_cfg(&format!("ttl_{tag}"), budget);
        let cfg = ServerConfig { ttl_secs: Some(ttl), ..cfg };
        let clock = Arc::new(ManualClock::new());
        let reg = TableRegistry::open_with_clock(cfg, clock.clone()).unwrap();
        (dir, clock, reg)
    }

    /// An idle table is demoted EXACTLY at the TTL deadline -- one
    /// millisecond earlier it survives -- a touched table's deadline
    /// moves with its last lookup, and the default is never expired.
    #[test]
    fn ttl_demotes_exactly_at_deadline_touch_resets_default_pinned() {
        let (_dir, clock, reg) = ttl_reg("exact", None, 10);
        reg.insert("base", dense(10, 4, 1).0).unwrap(); // default, pinned
        reg.insert("a", dense(10, 4, 2).0).unwrap();
        reg.insert("b", dense(10, 4, 3).0).unwrap();

        // t = 5s: touch b; its deadline moves to t = 15s
        clock.advance(Duration::from_secs(5));
        reg.resolve(Some("b")).unwrap();

        // t = 9.999s: nobody has hit a's 10s deadline yet
        clock.advance(Duration::from_millis(4999));
        assert_eq!(reg.expire_idle(), 0);
        assert_eq!(reg.residency("a"), Some(Residency::Resident));

        // t = 10s exactly: a (idle 10s) expires; b (idle 5s) and the
        // default survive
        clock.advance(Duration::from_millis(1));
        assert_eq!(reg.expire_idle(), 1);
        assert_eq!(reg.residency("a"), Some(Residency::Spilled));
        assert_eq!(reg.residency("b"), Some(Residency::Resident));
        assert_eq!(reg.residency("base"), Some(Residency::Resident));
        assert_eq!(reg.ttl_demotion_count(), 1);
        assert_eq!(reg.eviction_count(), 0, "TTL is not a budget eviction");

        // far future: b expires too; the default NEVER does
        clock.advance(Duration::from_secs(3600));
        assert_eq!(reg.expire_idle(), 1);
        assert_eq!(reg.residency("b"), Some(Residency::Spilled));
        assert_eq!(reg.residency("base"), Some(Residency::Resident));
        assert_eq!(reg.ttl_demotion_count(), 2);

        // the expired table transparently reloads -- and the reload
        // resets its idle clock (resolve touches)
        let entry = reg.resolve(Some("a")).unwrap();
        assert!(entry.lookup(&[3]).is_some());
        assert_eq!(reg.residency("a"), Some(Residency::Resident));
        reg.shutdown();
    }

    /// The sweep rides on resolves: traffic to ANY table expires the
    /// idle ones, and the table being served is protected even when it
    /// is itself at the deadline (a lookup at the deadline is a lookup).
    #[test]
    fn ttl_sweep_rides_on_resolve_and_protects_the_resolved_table() {
        let (_dir, clock, reg) = ttl_reg("resolve", None, 10);
        reg.insert("base", dense(10, 4, 1).0).unwrap();
        reg.insert("a", dense(10, 4, 2).0).unwrap();
        reg.insert("b", dense(10, 4, 3).0).unwrap();
        clock.advance(Duration::from_secs(10));
        // both a and b are exactly at the deadline; resolving a must
        // serve a (protected) and expire b as a side effect
        let entry = reg.resolve(Some("a")).unwrap();
        assert_eq!(entry.name, "a");
        assert_eq!(reg.residency("a"), Some(Residency::Resident));
        assert_eq!(reg.residency("b"), Some(Residency::Spilled));
        assert_eq!(reg.ttl_demotion_count(), 1);
        reg.shutdown();
    }

    /// TTL and the memory budget compose: whichever fires first wins,
    /// and the counters attribute each eviction to its cause.
    #[test]
    fn ttl_and_budget_compose_with_attributed_counters() {
        let bytes_per = 10 * 4 * 4u64;
        let (_dir, clock, reg) = ttl_reg("compose", Some(2 * bytes_per), 10);
        reg.insert("base", dense(10, 4, 1).0).unwrap(); // default
        reg.insert("hot", dense(10, 4, 2).0).unwrap();
        // t = 5s: the budget fires FIRST (insert pushes over), long
        // before any TTL deadline -- a budget eviction, not a TTL one
        clock.advance(Duration::from_secs(5));
        reg.resolve(Some("hot")).unwrap();
        reg.insert("cold", dense(10, 4, 3).0).unwrap();
        assert_eq!((reg.eviction_count(), reg.ttl_demotion_count()), (1, 0));
        assert_eq!(reg.residency("base"), Some(Residency::Resident));
        // (hot was just touched, so the LRU victim was... the touched
        // ordering decides; whichever spilled, exactly one did)
        assert_eq!(reg.list_spilled().len(), 1);

        // t = 16s: the survivor that nobody touched since t=5 crosses
        // its TTL deadline -- now the TTL fires, under budget
        clock.advance(Duration::from_secs(11));
        let expired = reg.expire_idle();
        assert_eq!(expired, 1);
        assert_eq!((reg.eviction_count(), reg.ttl_demotion_count()), (1, 1));
        assert_eq!(reg.residency("base"), Some(Residency::Resident));
        reg.shutdown();
    }

    /// Without a spill tier, TTL expiry DROPS the victim (PR-3 drop
    /// semantics: evicted marker, typed no_such_table), still counted
    /// as a TTL demotion, default still pinned.
    #[test]
    fn ttl_without_spill_tier_drops_with_evicted_marker() {
        let clock = Arc::new(ManualClock::new());
        let reg = TableRegistry::with_clock(
            ServerConfig {
                max_batch: 8,
                ttl_secs: Some(7),
                ..ServerConfig::default()
            },
            clock.clone(),
        );
        reg.insert("base", dense(10, 4, 1).0).unwrap();
        reg.insert("idle", dense(10, 4, 2).0).unwrap();
        clock.advance(Duration::from_secs(7));
        assert_eq!(reg.expire_idle(), 1);
        assert_eq!(reg.ttl_demotion_count(), 1);
        assert!(reg.was_evicted("idle"));
        assert!(reg.residency("idle").is_none(), "dropped, not spilled");
        assert_eq!(
            reg.resolve(Some("idle")).unwrap_err(),
            WireError::NoSuchTable("idle".into())
        );
        assert_eq!(reg.residency("base"), Some(Residency::Resident));
        reg.shutdown();
    }

    // ---- startup spill recovery ----

    /// `open` over a spill dir with a populated spill.json re-adopts
    /// every recorded table: registered, residency spilled, promoted on
    /// first lookup with the recorded replica count; a missing artifact
    /// adopts as Lost; a corrupt manifest fails open loudly.
    #[test]
    fn open_readopts_spill_manifest_tables() {
        let (dir, cfg) = spill_cfg("recover_unit", None);
        let (backend, table) = dense(20, 4, 41);
        {
            let reg = TableRegistry::open(cfg.clone()).unwrap();
            reg.insert_with_replicas("keep", backend, 2).unwrap();
            reg.insert("gone", dense(12, 3, 42).0).unwrap();
            reg.demote("keep").unwrap();
            let slot = reg.demote("gone").unwrap();
            // "gone"'s artifact vanishes out-of-band before the restart
            std::fs::remove_file(dir.join(slot.file())).unwrap();
            reg.shutdown();
        }
        // restart: both tables re-adopted from spill.json
        let reg = TableRegistry::open(cfg.clone()).unwrap();
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.residency("keep"), Some(Residency::Spilled));
        assert_eq!(reg.residency("gone"), Some(Residency::Lost));
        // the first adopted table (name order: "gone") became default;
        // adopted defaults are allowed to be spilled
        assert!(reg.default_name().is_some());
        // first lookup transparently promotes with the recorded replicas
        let entry = reg.resolve(Some("keep")).unwrap();
        assert_eq!(entry.replica_count(), 2);
        let ans = entry.lookup(&[0, 19, 7]).unwrap();
        assert_eq!(&ans.as_slice()[..4], table.row(0));
        assert_eq!(&ans.as_slice()[8..12], table.row(7));
        // the lost table answers typed reload_failed, not a panic
        match reg.resolve(Some("gone")) {
            Err(WireError::Rejected { code, .. }) => {
                assert_eq!(code, "reload_failed")
            }
            other => panic!("{other:?}"),
        }
        reg.shutdown();

        // corrupt manifest: open fails loudly and typed
        std::fs::write(dir.join(SPILL_MANIFEST), "{not json").unwrap();
        match TableRegistry::open(cfg) {
            Err(WireError::Rejected { code, .. }) => {
                assert_eq!(code, "spill_recover_failed")
            }
            other => panic!("{other:?}"),
        }
    }
}
