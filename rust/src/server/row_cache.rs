//! Per-table hot-row cache: a bounded-bytes store of recently-served
//! rows kept as raw f32, so a hot id is a memcpy instead of a code-walk.
//!
//! Correctness rests on the repo's determinism rule: every backend
//! gathers through `gather_rows_pooled`, so a row's bits never depend
//! on batch shape or thread count. A cached row is a verbatim copy of
//! one such reconstruction -- serving it back is bit-identical to
//! re-reconstructing, which is exactly what `tests/cache_equivalence.rs`
//! pins against a cache-disabled twin registry.
//!
//! Admission is LRU: every hit refreshes a row's recency stamp, every
//! miss (on the lookup path) admits the freshly-reconstructed row and
//! evicts least-recently-used rows until the cache fits its byte cap.
//! Scoring probes are read-only -- `score`/`topk` candidates never
//! churn the working set the lookup traffic built.
//!
//! Invalidation is structural: the cache lives on a `TableEntry` and a
//! fresh (empty) one is created whenever the registry respawns an entry
//! -- demote, promote, `set_replicas` -- so there is no stale-row
//! window to reason about. Hit/miss counters live on the table's shared
//! [`Stats`] and therefore survive those transitions.

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, PoisonError};

use crate::server::stats::Stats;

/// Fixed per-row bookkeeping overhead charged against the byte cap on
/// top of the `d * 4` payload (map entries, recency index). Keeping it
/// a pinned constant makes budget accounting deterministic and
/// testable; the true allocator cost is the same order of magnitude.
pub const ROW_OVERHEAD_BYTES: u64 = 64;

#[derive(Default)]
struct CacheInner {
    /// id -> (recency stamp, row payload).
    rows: HashMap<usize, (u64, Vec<f32>)>,
    /// recency stamp -> id, oldest first (BTreeMap iteration order).
    /// Stamps are unique (monotonic counter), so this is a total order.
    lru: BTreeMap<u64, usize>,
    /// Monotonic recency counter; bumped on every admit and every hit.
    tick: u64,
    /// Bytes currently held (payload + [`ROW_OVERHEAD_BYTES`] per row).
    bytes: u64,
}

/// A bounded-bytes LRU row cache for one table. Capacity 0 = disabled:
/// probes miss without counting, admits are dropped, and the fast-path
/// [`RowCache::enabled`] check is one relaxed atomic load.
pub struct RowCache {
    d: usize,
    /// Byte cap. Outside the mutex so `enabled()` and `cap_bytes()`
    /// never contend with a batch mid-probe; `set_capacity` stores it
    /// first, then trims under the lock.
    cap: AtomicU64,
    inner: Mutex<CacheInner>,
}

impl RowCache {
    /// New cache for rows of width `d`, capped at `cap_bytes` (0 =
    /// disabled).
    pub fn new(d: usize, cap_bytes: u64) -> RowCache {
        RowCache {
            d,
            cap: AtomicU64::new(cap_bytes),
            inner: Mutex::new(CacheInner::default()),
        }
    }

    /// Recover from a poisoned lock: cache state is never torn (every
    /// mutation leaves `rows`/`lru`/`bytes` consistent at panic-visible
    /// points only between full operations), and a poisoned cache must
    /// degrade to slow-but-correct serving, not wedge the batcher.
    fn lock(&self) -> MutexGuard<'_, CacheInner> {
        self.inner.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Bytes one cached row costs against the cap.
    fn row_cost(&self) -> u64 {
        (self.d as u64) * 4 + ROW_OVERHEAD_BYTES
    }

    /// True when the cache can hold anything (cap > 0).
    pub fn enabled(&self) -> bool {
        self.cap.load(Ordering::Relaxed) > 0
    }

    /// The configured byte cap (what budget accounting charges).
    pub fn cap_bytes(&self) -> u64 {
        self.cap.load(Ordering::Relaxed)
    }

    /// Bytes currently held (always <= the cap).
    pub fn bytes(&self) -> u64 {
        self.lock().bytes
    }

    /// Rows currently held.
    pub fn len(&self) -> usize {
        self.lock().rows.len()
    }

    /// True when no row is cached.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Probe for `id`; on a hit copy the row into `out` (must be `d`
    /// wide), refresh its recency, count a hit and return true. On a
    /// miss count a miss and return false. A disabled cache returns
    /// false without counting -- disabled serving has zero counter
    /// traffic, so the twin-registry equivalence suite can compare
    /// everything else about `stats` too.
    pub fn try_copy(&self, id: usize, out: &mut [f32], stats: &Stats) -> bool {
        if !self.enabled() {
            return false;
        }
        debug_assert_eq!(out.len(), self.d);
        let mut g = self.lock();
        let hit = if let Some((stamp, row)) = g.rows.get(&id) {
            out.copy_from_slice(row);
            Some(*stamp)
        } else {
            None
        };
        match hit {
            Some(old) => {
                g.tick += 1;
                let now = g.tick;
                g.lru.remove(&old);
                g.lru.insert(now, id);
                if let Some((stamp, _)) = g.rows.get_mut(&id) {
                    *stamp = now;
                }
                drop(g);
                stats.cache_hits.fetch_add(1, Ordering::Relaxed);
                true
            }
            None => {
                drop(g);
                stats.cache_misses.fetch_add(1, Ordering::Relaxed);
                false
            }
        }
    }

    /// Read-only probe for the scoring plane: copy the row on a hit
    /// WITHOUT counting or refreshing recency. A `topk` scan touches
    /// every candidate in its range -- letting it churn recency (or
    /// record a miss per cold row) would let one scan destroy both the
    /// working set the lookup traffic built and the hit-rate signal.
    pub fn peek(&self, id: usize, out: &mut [f32]) -> bool {
        if !self.enabled() {
            return false;
        }
        debug_assert_eq!(out.len(), self.d);
        let g = self.lock();
        match g.rows.get(&id) {
            Some((_, row)) => {
                out.copy_from_slice(row);
                true
            }
            None => false,
        }
    }

    /// Admit a freshly-reconstructed row (must be `d` wide), evicting
    /// least-recently-used rows until the cache fits its cap. A row
    /// wider than the whole cap is simply not admitted; re-admitting a
    /// present id refreshes its payload and recency.
    pub fn admit(&self, id: usize, row: &[f32]) {
        let cap = self.cap.load(Ordering::Relaxed);
        if cap == 0 {
            return;
        }
        debug_assert_eq!(row.len(), self.d);
        let cost = self.row_cost();
        if cost > cap {
            return;
        }
        let mut g = self.lock();
        g.tick += 1;
        let now = g.tick;
        if let Some((old, payload)) = g.rows.get_mut(&id) {
            let old = std::mem::replace(old, now);
            payload.copy_from_slice(row);
            g.lru.remove(&old);
            g.lru.insert(now, id);
            return;
        }
        g.rows.insert(id, (now, row.to_vec()));
        g.lru.insert(now, id);
        g.bytes += cost;
        Self::trim_locked(&mut g, cap, cost);
    }

    /// Evict oldest-first until `bytes <= cap`.
    fn trim_locked(g: &mut CacheInner, cap: u64, cost: u64) {
        while g.bytes > cap {
            let Some((&stamp, &victim)) = g.lru.iter().next() else { break };
            g.lru.remove(&stamp);
            g.rows.remove(&victim);
            g.bytes -= cost;
        }
    }

    /// Change the byte cap in place (0 disables), evicting down to the
    /// new cap immediately. Returns the cap now in force.
    pub fn set_capacity(&self, cap_bytes: u64) -> u64 {
        self.cap.store(cap_bytes, Ordering::Relaxed);
        let cost = self.row_cost();
        let mut g = self.lock();
        if cap_bytes == 0 {
            g.rows.clear();
            g.lru.clear();
            g.bytes = 0;
        } else {
            Self::trim_locked(&mut g, cap_bytes, cost);
        }
        cap_bytes
    }

    /// Drop every cached row, keeping the cap.
    pub fn clear(&self) {
        let mut g = self.lock();
        g.rows.clear();
        g.lru.clear();
        g.bytes = 0;
    }

    /// The ids currently cached, most-recently-used last (test hook for
    /// pinning admission/eviction ordering).
    pub fn ids_lru_order(&self) -> Vec<usize> {
        self.lock().lru.values().copied().collect()
    }
}

/// The scoring plane's view of the cache: hot candidates skip
/// reconstruction through the exact scorer
/// ([`ExactScorer::with_rows`](crate::scoring::ExactScorer::with_rows)).
/// Cached rows are verbatim reconstructions, so the [`RowBits`]
/// bit-exactness contract holds by construction.
impl crate::scoring::RowBits for RowCache {
    fn copy_row(&self, id: usize, out: &mut [f32]) -> bool {
        self.peek(id, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(d: usize, v: f32) -> Vec<f32> {
        vec![v; d]
    }

    #[test]
    fn peek_hits_without_counting_or_touching_recency() {
        let d = 4;
        let cost = (d as u64) * 4 + ROW_OVERHEAD_BYTES;
        let c = RowCache::new(d, 2 * cost);
        let stats = Stats::default();
        c.admit(0, &row(d, 0.0));
        c.admit(1, &row(d, 1.0));
        let mut out = row(d, 9.0);
        assert!(c.peek(0, &mut out));
        assert_eq!(out, row(d, 0.0));
        assert!(!c.peek(5, &mut out));
        // no counters moved, and id 0's recency was NOT refreshed: the
        // next admission still evicts id 0 as the LRU victim
        assert_eq!(stats.cache_hits.load(Ordering::Relaxed), 0);
        assert_eq!(stats.cache_misses.load(Ordering::Relaxed), 0);
        c.admit(2, &row(d, 2.0));
        assert_eq!(c.ids_lru_order(), vec![1, 2]);
    }

    #[test]
    fn disabled_cache_never_hits_or_counts() {
        let c = RowCache::new(4, 0);
        let stats = Stats::default();
        assert!(!c.enabled());
        c.admit(1, &row(4, 1.0));
        let mut out = row(4, 0.0);
        assert!(!c.try_copy(1, &mut out, &stats));
        assert_eq!(stats.cache_hits.load(Ordering::Relaxed), 0);
        assert_eq!(stats.cache_misses.load(Ordering::Relaxed), 0);
        assert_eq!(c.bytes(), 0);
        assert!(stats.cache_hit_rate().is_none());
    }

    #[test]
    fn hit_returns_admitted_bits_and_counts() {
        let d = 6;
        let c = RowCache::new(d, 1 << 20);
        let stats = Stats::default();
        let want: Vec<f32> = (0..d).map(|i| 0.5 + i as f32).collect();
        c.admit(7, &want);
        let mut out = row(d, 0.0);
        assert!(c.try_copy(7, &mut out, &stats));
        for (a, b) in out.iter().zip(&want) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert!(!c.try_copy(8, &mut out, &stats));
        assert_eq!(stats.cache_hits.load(Ordering::Relaxed), 1);
        assert_eq!(stats.cache_misses.load(Ordering::Relaxed), 1);
        assert_eq!(stats.cache_hit_rate(), Some(0.5));
    }

    /// LRU order: hits refresh recency, eviction removes oldest first.
    #[test]
    fn admission_evicts_lru_first() {
        let d = 4;
        let cost = (d as u64) * 4 + ROW_OVERHEAD_BYTES;
        let c = RowCache::new(d, 3 * cost); // exactly three rows fit
        let stats = Stats::default();
        c.admit(0, &row(d, 0.0));
        c.admit(1, &row(d, 1.0));
        c.admit(2, &row(d, 2.0));
        assert_eq!(c.ids_lru_order(), vec![0, 1, 2]);
        // touch 0 so 1 becomes the LRU victim
        let mut out = row(d, 0.0);
        assert!(c.try_copy(0, &mut out, &stats));
        c.admit(3, &row(d, 3.0));
        assert_eq!(c.ids_lru_order(), vec![2, 0, 3]);
        assert!(!c.try_copy(1, &mut out, &stats), "id 1 must be evicted");
        assert_eq!(c.bytes(), 3 * cost);
    }

    #[test]
    fn readmit_refreshes_payload_without_double_charging() {
        let d = 3;
        let c = RowCache::new(d, 1 << 20);
        let stats = Stats::default();
        c.admit(5, &row(d, 1.0));
        let b0 = c.bytes();
        c.admit(5, &row(d, 9.0));
        assert_eq!(c.bytes(), b0, "re-admit must not double-charge");
        let mut out = row(d, 0.0);
        assert!(c.try_copy(5, &mut out, &stats));
        assert_eq!(out, row(d, 9.0));
    }

    #[test]
    fn set_capacity_trims_and_zero_disables() {
        let d = 4;
        let cost = (d as u64) * 4 + ROW_OVERHEAD_BYTES;
        let c = RowCache::new(d, 10 * cost);
        for id in 0..10 {
            c.admit(id, &row(d, id as f32));
        }
        assert_eq!(c.len(), 10);
        assert_eq!(c.set_capacity(2 * cost), 2 * cost);
        assert_eq!(c.len(), 2);
        // the two most recently admitted survive
        assert_eq!(c.ids_lru_order(), vec![8, 9]);
        assert!(c.bytes() <= c.cap_bytes());
        assert_eq!(c.set_capacity(0), 0);
        assert!(!c.enabled());
        assert_eq!(c.bytes(), 0);
        assert!(c.is_empty());
    }

    #[test]
    fn oversized_row_is_not_admitted() {
        let d = 1024;
        let c = RowCache::new(d, 8); // cap smaller than one row
        c.admit(0, &row(d, 1.0));
        assert!(c.is_empty());
        assert_eq!(c.bytes(), 0);
    }
}
